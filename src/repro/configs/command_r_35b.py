"""command-r-35b [dense] — hf:CohereForAI/c4ai-command-r-v01 (unverified tier).

40L d_model=8192 64H (GQA kv=8) d_ff=22528 vocab=256000 — GQA, no bias.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="command-r-35b",
    family="dense",
    num_layers=40,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22528,
    vocab_size=256000,
    rope_theta=4_000_000.0,
    max_seq_len=131_072,
))
