"""The paper's control-plane experiment configuration (§4.1).

Cluster of N serving nodes managed by the MADRL balancer + GPSO autoscaler,
driven by a Google-Cluster-Data-style synthetic trace. Hyperparameters the
paper leaves unspecified are recorded here (see DESIGN.md §8).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ClusterConfig:
    num_nodes: int = 16              # serving nodes (replica groups)
    topology: str = "ring+hub"       # adjacency for the GCN (ring + controller hub)
    horizon: int = 32                # forecast horizon T (ticks) in S_t
    #   (≥ provisioning_delay so proactive scaling can beat the cold start)
    tick_seconds: float = 1.0
    # --- Eq.5 reward weights ---
    alpha: float = 1.0               # response-time weight
    beta: float = 0.25               # resource (idle/overload) cost weight
    slo_gamma: float = 0.5           # tier-weighted SLO-violation weight
    #   (scales metrics['tier_slo_cost'] in the reward; inert when untiered)
    # --- node economics ---
    base_capacity: float = 100.0     # requests/sec per replica (scaled by arch cost)
    max_replicas_per_node: int = 8
    min_replicas_per_node: int = 0
    replica_cost: float = 1.0        # C_i in Eq.9 (per replica-tick)
    provisioning_delay: int = 30     # ticks before a new replica serves (cold start)
    # --- failure model ---
    node_mtbf: float = 20_000.0      # mean ticks between node failures
    node_mttr: float = 120.0         # mean ticks to recover
    straggler_prob: float = 0.02     # steady-state fraction of degraded nodes
    straggler_slowdown: float = 0.35 # capacity multiplier when degraded
    straggler_mean_ticks: float = 20.0  # mean degradation episode length
    # --- GCN/DDPG (sizes unspecified in paper; chosen small, swept in tests) ---
    gcn_layers: int = 2
    gcn_hidden: int = 64
    actor_hidden: int = 128
    critic_hidden: int = 128
    gamma: float = 0.95
    tau: float = 0.01                # polyak
    actor_lr: float = 1e-4
    critic_lr: float = 1e-3
    buffer_size: int = 50_000
    batch_size: int = 128
    noise_sigma: float = 0.1         # exploration noise N_t (Eq.7)
    # --- GPSO (Eq.9-11) ---
    lam: float = 32.0                # λ cost/load balance weight in Eq.9
    target_load: float = 0.7         # provisioning headroom (L_i target)
    slo_lam: float = 8.0             # tier-weighted SLO-violation cost weight
    #   (the Eq.9 extension used when the backend reports tier_pressure)
    risk_lam: float = 4.0            # spot preemption-risk cost weight
    #   (the Eq.9 extension used when the backend reports preempt_risk;
    #    inert while the risk signal is all zeros)
    ga_pop: int = 64
    ga_generations: int = 20
    ga_elite: int = 16
    ga_crossover: float = 0.8
    ga_mutation: float = 0.08
    pso_iters: int = 30
    pso_inertia: float = 0.6         # w
    pso_c1: float = 1.4
    pso_c2: float = 1.4
    # --- forecaster ---
    forecast_window: int = 64
    forecast_hidden: int = 64
    # --- autoscaler policy ---
    scale_interval: int = 10         # run GPSO every k ticks
    cooldown: int = 30               # min ticks between scale-downs


DEFAULT = ClusterConfig()
