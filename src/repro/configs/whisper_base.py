"""whisper-base [audio] — arXiv:2212.04356 (enc-dec; conv/mel frontend STUBBED).

6L d_model=512 8H (kv=8) d_ff=2048 vocab=51865. Encoder consumes precomputed
frame embeddings (B, 1500, 512) from ``input_specs`` — the conv1d/mel frontend
is a stub per the brief. GELU MLPs (original whisper uses GELU, not SwiGLU).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="whisper-base",
    family="audio",
    num_layers=6,              # decoder layers
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51865,
    activation="gelu",
    tie_embeddings=True,
    is_encoder_decoder=True,
    encoder_layers=6,
    encoder_seq_len=1500,
    rope_theta=0.0,            # whisper uses absolute positions, not RoPE
    max_seq_len=32768,         # sized for the assigned prefill_32k cell
))
