"""mamba2-1.3b [ssm] — arXiv:2405.21060 (SSD / state-space duality).

48L d_model=2048 attention-free, vocab=50280, ssm_state=128, expand=2
(d_inner=4096, head_dim=64 -> 64 SSD heads). O(1) decode state -> runs
long_500k. vocab padded for TP=16 by the shard plan.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="mamba2-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    tie_embeddings=True,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    max_seq_len=1_048_576,
))
