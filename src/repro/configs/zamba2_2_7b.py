"""zamba2-2.7b [hybrid] — arXiv:2411.15242.

54 Mamba-2 layers, d_model=2560, d_ff=10240, vocab=32000, ssm_state=64, plus a
SHARED attention block (32H, kv=32, head_dim=80) invoked every 6 layers
(9 invocations, each with its own KV cache, shared weights). Sub-quadratic
decode -> runs the long_500k shape. See DESIGN.md §5 for simplifications vs.
the real Zamba-2 (no embedding-concat / per-invocation LoRA).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab_size=32000,
    tie_embeddings=True,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    attn_every=6,
    rope_theta=10_000.0,
    max_seq_len=1_048_576,
))
