"""Architecture/config system.

Every assigned architecture is expressed as an ``ArchConfig``. The model substrate
(`repro.models`) consumes these; the launchers select them via ``--arch <id>``.

Families:
  dense   — decoder-only transformer (GQA, SwiGLU)
  moe     — decoder-only transformer with top-k mixture-of-experts FFNs
  hybrid  — Mamba-2 backbone with a shared attention block every `attn_every` layers
  ssm     — pure Mamba-2 (attention-free)
  vlm     — dense LM backbone consuming stub patch embeddings + text tokens
  audio   — encoder-decoder transformer (Whisper-style); conv/mel frontend stubbed
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int                   # query heads (0 for attention-free)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    activation: str = "swiglu"       # swiglu | gelu
    norm_eps: float = 1e-5
    rope_theta: float = 1_000_000.0
    max_seq_len: int = 131_072
    # --- MoE ---
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_d_ff: int = 0                # per-expert hidden dim (0 -> d_ff)
    moe_every: int = 1               # MoE FFN every k-th layer (others dense)
    moe_shared_expert: bool = False  # always-on shared expert on MoE layers
    capacity_factor: float = 1.25
    # --- SSM (Mamba-2) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_groups: int = 1
    ssm_chunk: int = 256
    # --- hybrid ---
    attn_every: int = 0              # shared attn block every k layers (0 = never)
    # --- encoder-decoder (audio) ---
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq_len: int = 0         # whisper: 1500 frames
    # --- vlm ---
    num_patches: int = 0             # stub patch embeddings prepended to text

    # ------------------------------------------------------------------ derived
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def d_inner(self) -> int:
        """Mamba-2 inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def sub_quadratic(self) -> bool:
        """True when decode cost/state is O(1)-ish in context (SSM/hybrid)."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_attention(self) -> bool:
        return self.family != "ssm"

    @property
    def uses_moe(self) -> bool:
        return self.num_experts > 0

    # ------------------------------------------------------------ param counts
    def param_count(self) -> int:
        """Analytic parameter count (logical, unpadded). Used by tests + roofline."""
        d, v = self.d_model, self.vocab_size
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.family in ("ssm", "hybrid"):
            n = emb
            di, N, G, H = self.d_inner, self.ssm_state, self.ssm_groups, self.ssm_heads
            in_proj = d * (2 * di + 2 * G * N + H)
            conv = self.ssm_conv_width * (di + 2 * G * N)
            out_proj = di * d
            per_layer = in_proj + conv + out_proj + di + 2 * H + d  # norm+A,D,dt_bias
            n += self.num_layers * per_layer
            if self.family == "hybrid" and self.attn_every:
                hd = self.resolved_head_dim
                qk = d * self.num_heads * hd + d * self.num_kv_heads * hd
                vo = d * self.num_kv_heads * hd + self.num_heads * hd * d
                mlp = 3 * d * self.d_ff
                n += qk + vo + mlp + 2 * d  # one shared block
            n += d  # final norm
            return n
        hd = self.resolved_head_dim
        attn = (d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd
                + self.num_heads * hd * d)
        if self.qkv_bias:
            attn += (self.num_heads + 2 * self.num_kv_heads) * hd
        mlp_mult = 3 if self.activation == "swiglu" else 2
        dense_mlp = mlp_mult * d * self.d_ff
        n = emb + d  # embeddings + final norm
        if self.is_encoder_decoder:
            enc_layer = attn + dense_mlp + 2 * d
            dec_layer = 2 * attn + dense_mlp + 3 * d  # self + cross
            n += self.encoder_layers * enc_layer + self.num_layers * dec_layer
            n += self.encoder_seq_len * 0  # sinusoidal enc pos: not learned
            n += self.max_decoder_pos * d  # learned decoder positions
            return n
        for layer in range(self.num_layers):
            n += attn + 2 * d
            if self.uses_moe and layer % self.moe_every == 0:
                e_ff = self.moe_d_ff or self.d_ff
                n += self.num_experts * mlp_mult * d * e_ff + d * self.num_experts
                if self.moe_shared_expert:
                    n += mlp_mult * d * e_ff
            else:
                n += dense_mlp
        return n

    @property
    def max_decoder_pos(self) -> int:
        # Learned decoder positions sized to the assigned shape set (the real
        # whisper-base table is 448; the assigned prefill_32k cell requires
        # 32k — the +17M params are recorded in DESIGN.md §8).
        return max(self.max_seq_len, 4096) if self.is_encoder_decoder else 0

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts). Drives 6·N_active·D."""
        if not self.uses_moe:
            return self.param_count()
        d = self.d_model
        e_ff = self.moe_d_ff or self.d_ff
        mlp_mult = 3 if self.activation == "swiglu" else 2
        n_moe_layers = len([l for l in range(self.num_layers) if l % self.moe_every == 0])
        inactive = n_moe_layers * (self.num_experts - self.num_experts_per_tok) \
            * mlp_mult * d * e_ff
        return self.param_count() - inactive

    # ------------------------------------------------------------------ reduced
    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            num_layers=min(self.num_layers, 4 if self.family in ("ssm", "hybrid") else 2),
            d_model=128,
            num_heads=4 if self.num_heads else 0,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads else 0,
            head_dim=32 if self.num_heads else 0,
            d_ff=256 if self.d_ff else 0,
            moe_d_ff=128 if self.moe_d_ff else 0,
            vocab_size=512,
            max_seq_len=512,
            num_experts=min(self.num_experts, 4),
            num_experts_per_tok=min(self.num_experts_per_tok, 2),
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=32 if self.ssm_state else 64,
            ssm_chunk=32,
            attn_every=2 if self.attn_every else 0,
            encoder_layers=min(self.encoder_layers, 2),
            encoder_seq_len=min(self.encoder_seq_len, 64),
            num_patches=min(self.num_patches, 16),
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def applicable_shapes(cfg: ArchConfig) -> list:
    """The assigned shape cells that apply to this arch (long_500k is
    sub-quadratic-only per the brief)."""
    out = [SHAPES["train_4k"], SHAPES["prefill_32k"], SHAPES["decode_32k"]]
    if cfg.sub_quadratic:
        out.append(SHAPES["long_500k"])
    return out


# Populated by repro.configs.__init__
REGISTRY: dict = {}


def register(cfg: ArchConfig) -> ArchConfig:
    REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    if name not in REGISTRY:
        import repro.configs  # noqa: F401  (trigger registration)
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(REGISTRY)}")
    return REGISTRY[name]
