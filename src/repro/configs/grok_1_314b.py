"""grok-1-314b [moe] — hf:xai-org/grok-1 (unverified tier).

64L d_model=6144 48H (GQA kv=8) head_dim=128 d_ff=32768 vocab=131072,
MoE 8 experts top-2 on every layer.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab_size=131072,
    num_experts=8,
    num_experts_per_tok=2,
    moe_every=1,
    rope_theta=10_000.0,
    max_seq_len=8192 * 16,
))
