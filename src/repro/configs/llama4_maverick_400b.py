"""llama4-maverick-400b-a17b [moe] — hf:meta-llama/Llama-4 family (unverified).

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128 experts top-1.
Maverick-style: MoE FFN on alternating layers (dense on the rest) plus an
always-on shared expert — this lands total params ~400B with ~17B active.
40 heads padded per kv-group for TP=16 (PaddedDims).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    num_experts=128,
    num_experts_per_tok=1,
    moe_every=2,
    moe_shared_expert=True,
    rope_theta=500_000.0,
    max_seq_len=131_072,
))
