"""Config registry — importing this package registers all assigned architectures."""
from repro.configs.base import (  # noqa: F401
    REGISTRY, SHAPES, ArchConfig, ShapeConfig, applicable_shapes, get_config,
)

# Assigned architectures (10) — importing registers each into REGISTRY.
from repro.configs import mistral_nemo_12b    # noqa: F401
from repro.configs import qwen2_5_14b         # noqa: F401
from repro.configs import command_r_35b       # noqa: F401
from repro.configs import granite_3_8b        # noqa: F401
from repro.configs import whisper_base        # noqa: F401
from repro.configs import grok_1_314b         # noqa: F401
from repro.configs import llama4_maverick_400b  # noqa: F401
from repro.configs import zamba2_2_7b         # noqa: F401
from repro.configs import internvl2_2b        # noqa: F401
from repro.configs import mamba2_1_3b         # noqa: F401

# The paper's own control-plane experiment config.
from repro.configs import paper_cluster       # noqa: F401

ARCH_NAMES = sorted(REGISTRY)
