"""internvl2-2b [vlm] — arXiv:2404.16821 (InternViT frontend STUBBED).

LM backbone (InternLM2-1.8B-like): 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92553. The ViT produces 1025 patch embeddings (stub: ``input_specs``
provides precomputed (B, 1025, 2048) patch embeddings) which are prepended to
the text sequence. vocab padded for TP=16 by the shard plan.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="internvl2-2b",
    family="vlm",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92553,
    num_patches=1025,
    rope_theta=1_000_000.0,
    max_seq_len=131_072,
))
