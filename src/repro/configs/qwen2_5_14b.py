"""qwen2.5-14b [dense] — hf:Qwen/Qwen2.5 family.

48L d_model=5120 40H (GQA kv=8) d_ff=13824 vocab=152064 — GQA, QKV bias.
40 heads is not divisible by TP=16; the shard plan pads q-heads per kv-group
(see repro.distributed.sharding.PaddedDims).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen2.5-14b",
    family="dense",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=13824,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    max_seq_len=131_072,
))
