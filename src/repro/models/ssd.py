"""Mamba-2 (SSD — state-space duality) block: chunked scan + O(1) decode.

Implements the blocked SSD algorithm from arXiv:2405.21060 §6 as a single
``lax.scan`` over chunks: intra-chunk attention-like term + inter-chunk state
recurrence, so the (S × S) semiseparable matrix is never materialized and
peak memory per step is O(chunk²·H). The Pallas kernel in
``repro/kernels/ssd_scan.py`` fuses the intra-chunk math for TPU; this module
is the XLA path and the oracle source of truth.

Recurrence (per head h, state (P, N)):
    s_t = exp(dt_t · A_h) · s_{t-1} + dt_t · B_t ⊗ x_t
    y_t = C_t · s_t + D_h · x_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import he_init, rms_norm, silu, softplus


# --------------------------------------------------------------------- params
def init_mamba2(key, d_model: int, d_inner: int, n_heads: int, head_dim: int,
                d_state: int, n_groups: int, conv_width: int, dtype) -> dict:
    ks = jax.random.split(key, 4)
    d_in_proj = 2 * d_inner + 2 * n_groups * d_state + n_heads  # z, xBC, dt
    conv_ch = d_inner + 2 * n_groups * d_state
    # A in [1, 16] (mamba2 default init), dt in [1e-3, 1e-1]
    a = np.random.RandomState(0).uniform(1.0, 16.0, (n_heads,))
    dt = np.exp(np.random.RandomState(1).uniform(np.log(1e-3), np.log(1e-1),
                                                 (n_heads,)))
    dt_bias = dt + np.log(-np.expm1(-dt))  # inverse softplus
    return {
        "in_proj": he_init(ks[0], (d_model, d_in_proj), dtype, d_model),
        "conv_w": he_init(ks[1], (conv_width, conv_ch), dtype, conv_width),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "out_proj": he_init(ks[2], (d_inner, d_model), dtype, d_inner),
        "A_log": jnp.asarray(np.log(a), jnp.float32),
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.asarray(dt_bias, jnp.float32),
        "norm_scale": jnp.zeros((d_inner,), jnp.float32),
    }


# ----------------------------------------------------------------- core math
def segsum_exp(a):
    """a: (..., Q) log-decays -> L (..., Q, Q) with L[q,k]=exp(Σ_{k+1..q} a),
    lower-triangular (incl. diagonal = 1)."""
    a_cum = jnp.cumsum(a, axis=-1)
    diff = a_cum[..., :, None] - a_cum[..., None, :]
    Q = a.shape[-1]
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(tri, jnp.exp(diff), 0.0)


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int, init_state=None):
    """Blocked SSD scan.

    x:  (B, T, H, P)  inputs (already dt-unweighted)
    dt: (B, T, H)     positive step sizes (softplus applied by caller)
    A:  (H,)          negative decay rates
    Bm, Cm: (B, T, G, N) with H % G == 0
    Returns (y (B,T,H,P), final_state (B,H,P,N)).
    """
    Bsz, T, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    T_orig = T
    if T % chunk:
        # zero-pad to a chunk multiple: padded steps have dt=0 -> decay=1 and
        # zero input, so they are exactly inert (state passes through).
        pad = chunk - T % chunk
        padt = lambda t: jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
        x, dt, Bm, Cm = padt(x), padt(dt), padt(Bm), padt(Cm)
        T += pad
    nc, rep = T // chunk, H // G
    a = (dt * A[None, None, :]).astype(jnp.float32)        # (B,T,H) log decay
    xdt = (x * dt[..., None]).astype(jnp.float32)

    def to_chunks(t):
        return t.reshape(Bsz, nc, chunk, *t.shape[2:]).swapaxes(0, 1)

    xs = (to_chunks(xdt), to_chunks(a),
          to_chunks(Bm.astype(jnp.float32)), to_chunks(Cm.astype(jnp.float32)))
    if init_state is None:
        init_state = jnp.zeros((Bsz, H, P, N), jnp.float32)

    def body(state, inp):
        xc, ac, bc, cc = inp                                # (B,Q,H,P) (B,Q,H) (B,Q,G,N)
        a_cum = jnp.cumsum(ac, axis=1)                      # (B,Q,H)
        L = segsum_exp(ac.transpose(0, 2, 1))               # (B,H,Q,Q)
        bh = jnp.repeat(bc, rep, axis=2)                    # (B,Q,H,N)
        ch = jnp.repeat(cc, rep, axis=2)
        scores = jnp.einsum("bqhn,bkhn->bhqk", ch, bh)      # (B,H,Q,Q)
        y_diag = jnp.einsum("bhqk,bkhp->bqhp", L * scores, xc)
        decay_in = jnp.exp(a_cum)                            # (B,Q,H)
        y_off = jnp.einsum("bqhn,bhpn,bqh->bqhp", ch, state, decay_in)
        decay_out = jnp.exp(a_cum[:, -1:, :] - a_cum)        # (B,Q,H)
        new_state = state * jnp.exp(a_cum[:, -1])[:, :, None, None] + \
            jnp.einsum("bkhn,bkhp,bkh->bhpn", bh, xc, decay_out)
        return new_state, y_diag + y_off

    final_state, y = jax.lax.scan(body, init_state, xs)
    y = y.swapaxes(0, 1).reshape(Bsz, T, H, P)[:, :T_orig]
    return y, final_state


def ssd_reference(x, dt, A, Bm, Cm, init_state=None):
    """Naive per-step recurrence oracle (for tests)."""
    Bsz, T, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    bh = jnp.repeat(Bm, rep, axis=2).astype(jnp.float32)
    ch = jnp.repeat(Cm, rep, axis=2).astype(jnp.float32)
    a = (dt * A[None, None, :]).astype(jnp.float32)
    xdt = (x * dt[..., None]).astype(jnp.float32)
    state = (jnp.zeros((Bsz, H, P, N), jnp.float32)
             if init_state is None else init_state)

    def body(s, inp):
        xt, at, bt, ct = inp  # (B,H,P) (B,H) (B,H,N) (B,H,N)
        s = s * jnp.exp(at)[:, :, None, None] + xt[..., None] * bt[:, :, None, :]
        y = jnp.einsum("bhpn,bhn->bhp", s, ct)
        return s, y

    xs = (xdt.swapaxes(0, 1), a.swapaxes(0, 1),
          bh.swapaxes(0, 1), ch.swapaxes(0, 1))
    state, ys = jax.lax.scan(body, state, xs)
    return ys.swapaxes(0, 1), state


def ssd_decode_step(state, x, dt, A, Bm, Cm):
    """One token. x: (B,H,P), dt: (B,H), Bm/Cm: (B,G,N). Returns (y, state)."""
    H = x.shape[1]
    rep = H // Bm.shape[1]
    bh = jnp.repeat(Bm, rep, axis=1).astype(jnp.float32)
    ch = jnp.repeat(Cm, rep, axis=1).astype(jnp.float32)
    decay = jnp.exp((dt * A[None, :]).astype(jnp.float32))
    xdt = (x * dt[..., None]).astype(jnp.float32)
    state = state * decay[:, :, None, None] + xdt[..., None] * bh[:, :, None, :]
    y = jnp.einsum("bhpn,bhn->bhp", state, ch)
    return y, state


# -------------------------------------------------------------- full block
def causal_conv(x, w, b, left=None):
    """Depthwise causal conv. x: (B,T,C); w: (W,C). ``left`` (B,W-1,C) is the
    raw window carried from a previous chunk (chunked prefill); None means a
    fresh sequence (zero left context)."""
    W = w.shape[0]
    if left is None:
        xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([left.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(W))
    return out + b[None, None, :]


def mamba2_forward(params, x, cfg, *, init_state=None, conv_state=None,
                   return_state=False, shard_fn=None, lengths=None):
    """Full-sequence Mamba-2 block. x: (B,T,d_model).

    ``lengths`` (B,) marks true per-row sequence lengths when x is
    right-padded: padded steps get dt=0 (decay 1, zero input — exactly inert,
    the same trick ``ssd_chunked`` uses for chunk padding), and the decode
    conv state is gathered from the last ``conv_width-1`` *real* positions,
    so the returned state matches an unpadded forward bit-for-bit.

    ``init_state`` / ``conv_state`` continue a sequence from a previous
    chunk (chunked prefill): ``init_state`` (B,H,P,N) seeds the SSM scan and
    ``conv_state`` (B,W-1,C) is the carried raw conv window (same layout the
    decode path keeps), so running a prompt chunk-by-chunk reproduces the
    single-shot forward exactly."""
    d_inner, N, G = cfg.d_inner, cfg.ssm_state, cfg.ssm_groups
    H, P = cfg.ssm_heads, cfg.ssm_head_dim
    proj = x @ params["in_proj"]                              # (B,T,din_proj)
    z = proj[..., :d_inner]
    xBC_raw = proj[..., d_inner:d_inner + d_inner + 2 * G * N]
    dt_raw = proj[..., -H:]
    xBC = silu(causal_conv(xBC_raw, params["conv_w"], params["conv_b"],
                           left=conv_state))
    xs = xBC[..., :d_inner]
    Bm = xBC[..., d_inner:d_inner + G * N].reshape(*x.shape[:2], G, N)
    Cm = xBC[..., d_inner + G * N:].reshape(*x.shape[:2], G, N)
    dt = softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    if lengths is not None:
        tpos = jnp.arange(x.shape[1], dtype=jnp.int32)
        dt = jnp.where(tpos[None, :, None] < lengths[:, None, None], dt, 0.0)
    A = -jnp.exp(params["A_log"])
    xh = xs.reshape(*x.shape[:2], H, P)
    y, state = ssd_chunked(xh, dt, A, Bm, Cm, cfg.ssm_chunk,
                           init_state=init_state)
    y = y + xh.astype(jnp.float32) * params["D"][None, None, :, None]
    y = y.reshape(*x.shape[:2], d_inner).astype(x.dtype)
    y = rms_norm(y * silu(z), params["norm_scale"], cfg.norm_eps)
    out = y @ params["out_proj"]
    if return_state:
        W = cfg.ssm_conv_width
        if conv_state is not None:
            # carried window: the cumulative raw sequence is [carry | chunk],
            # so the next window is its last W-1 real rows — always in bounds
            # (the carry supplies the left context even for tiny chunks).
            window = jnp.concatenate(
                [conv_state.astype(xBC_raw.dtype), xBC_raw], axis=1)
            if lengths is None:
                conv_tail = window[:, -(W - 1):, :]
            else:
                idx = lengths[:, None].astype(jnp.int32) + \
                    jnp.arange(W - 1, dtype=jnp.int32)[None, :]
                conv_tail = jnp.take_along_axis(
                    window, idx[:, :, None], axis=1)
        elif lengths is None:
            conv_tail = xBC_raw[:, -(W - 1):, :]  # raw window for decode conv
            if conv_tail.shape[1] < W - 1:        # prompt shorter than window
                conv_tail = jnp.pad(
                    conv_tail, ((0, 0), (W - 1 - conv_tail.shape[1], 0),
                                (0, 0)))
        else:
            offs = jnp.arange(-(W - 1), 0, dtype=jnp.int32)   # (W-1,)
            idx = lengths[:, None].astype(jnp.int32) + offs[None, :]
            gathered = jnp.take_along_axis(
                xBC_raw, jnp.maximum(idx, 0)[:, :, None], axis=1)
            conv_tail = jnp.where((idx >= 0)[:, :, None], gathered, 0.0)
        return out, {"ssm": state, "conv": conv_tail}
    return out


def mamba2_init_state(batch: int, cfg, dtype=jnp.float32) -> dict:
    conv_ch = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    return {
        "ssm": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim,
                          cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_ch), dtype),
    }


def mamba2_decode(params, x, cfg, state):
    """One-token decode. x: (B,1,d_model); state: {'ssm','conv'}."""
    d_inner, N, G = cfg.d_inner, cfg.ssm_state, cfg.ssm_groups
    H, P = cfg.ssm_heads, cfg.ssm_head_dim
    proj = x[:, 0] @ params["in_proj"]                        # (B, din_proj)
    z = proj[..., :d_inner]
    xBC_new = proj[..., d_inner:d_inner + d_inner + 2 * G * N]
    dt_raw = proj[..., -H:]
    window = jnp.concatenate([state["conv"], xBC_new[:, None]], axis=1)  # (B,W,C)
    conv_out = jnp.einsum("bwc,wc->bc", window, params["conv_w"]) + params["conv_b"]
    xBC = silu(conv_out)
    new_conv = window[:, 1:]
    xs = xBC[..., :d_inner]
    Bm = xBC[..., d_inner:d_inner + G * N].reshape(-1, G, N)
    Cm = xBC[..., d_inner + G * N:].reshape(-1, G, N)
    dt = softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    xh = xs.reshape(-1, H, P)
    y, ssm = ssd_decode_step(state["ssm"], xh, dt, A, Bm, Cm)
    y = y + xh.astype(jnp.float32) * params["D"][None, :, None]
    y = y.reshape(-1, d_inner).astype(x.dtype)
    y = rms_norm(y * silu(z), params["norm_scale"], cfg.norm_eps)
    out = (y @ params["out_proj"])[:, None]
    return out, {"ssm": ssm, "conv": new_conv}
