"""Unified model facade: one API over all assigned architecture families.

    model = make_model(get_config("mistral-nemo-12b"), tp=16)
    params = model.init(key, dtype=jnp.bfloat16)
    loss, metrics = model.loss(params, batch)
    logits, state, pos = model.prefill(params, batch, cache_len=32768)
    logits, state = model.decode(params, state, tokens, pos)

``input_specs`` returns ShapeDtypeStruct stand-ins for every input of a
(shape-kind) cell — the multi-pod dry-run lowers against these without any
device allocation.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import encdec, lm, ssm_lm
from repro.models.dims import PaddedDims, padded_dims
from repro.models.layers import cross_entropy


def _masked_ce_sum(logits, targets, mask, vocab_logical: int):
    """(sum of masked NLL, count). Padded vocab columns excluded."""
    v_phys = logits.shape[-1]
    logits = logits.astype(jnp.float32)
    if v_phys > vocab_logical:
        neg = jnp.full((v_phys - vocab_logical,), -1e9, jnp.float32)
        logits = logits.at[..., vocab_logical:].set(neg)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask.astype(jnp.float32)
    return jnp.sum(nll), jnp.sum(mask.astype(jnp.float32))


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    dims: PaddedDims
    remat: str = "none"

    # ------------------------------------------------------------------ init
    def init(self, key, dtype=jnp.float32):
        c, d = self.cfg, self.dims
        if c.family in ("dense", "moe", "vlm"):
            return lm.init_lm(key, c, d, dtype)
        if c.family in ("ssm", "hybrid"):
            return ssm_lm.init_ssm_lm(key, c, d, dtype)
        if c.family == "audio":
            return encdec.init_encdec(key, c, d, dtype)
        raise ValueError(c.family)

    # --------------------------------------------------------------- forward
    def forward(self, params, batch, shard_fn=None, return_features=False):
        c, d = self.cfg, self.dims
        kw = dict(remat=self.remat, shard_fn=shard_fn,
                  return_features=return_features)
        if c.family in ("dense", "moe", "vlm"):
            return lm.lm_forward(params, batch, c, d, **kw)
        if c.family in ("ssm", "hybrid"):
            return ssm_lm.ssm_forward(params, batch, c, d, **kw)
        if c.family == "audio":
            return encdec.encdec_forward(params, batch, c, d, **kw)
        raise ValueError(c.family)

    def _head(self, params):
        head = params.get("lm_head")
        return head if head is not None else params["embed"].T

    def loss(self, params, batch, shard_fn=None, loss_chunk: int = 2048):
        """Next-token CE via sequence-chunked head+softmax: the (T, V) logits
        tensor is never materialized (a ~V/d memory saving on the loss)."""
        c = self.cfg
        feats, aux = self.forward(params, batch, shard_fn=shard_fn,
                                  return_features=True)
        toks = batch["tokens"]
        if c.family == "vlm":
            P = c.num_patches
            pred_h = feats[:, P - 1:P + toks.shape[1] - 1]
            targets = toks
        else:
            pred_h = feats[:, :-1]
            targets = toks[:, 1:]
        ce = self._chunked_ce(params, pred_h, targets, loss_chunk, shard_fn)
        total = ce + 0.01 * aux
        return total, {"ce": ce, "aux": aux}

    def _chunked_ce(self, params, pred_h, targets, loss_chunk, shard_fn):
        c = self.cfg
        head = self._head(params)
        B, S, dm = pred_h.shape
        loss_chunk = min(loss_chunk, S)
        n_chunks = -(-S // loss_chunk)
        S_pad = n_chunks * loss_chunk
        if S_pad != S:
            pred_h = jnp.pad(pred_h, ((0, 0), (0, S_pad - S), (0, 0)))
            targets = jnp.pad(targets, ((0, 0), (0, S_pad - S)))
        mask = (jnp.arange(S_pad) < S)[None, :]
        hc = pred_h.reshape(B, n_chunks, loss_chunk, dm).swapaxes(0, 1)
        tc = targets.reshape(B, n_chunks, loss_chunk).swapaxes(0, 1)
        mc = jnp.broadcast_to(mask.reshape(1, n_chunks, loss_chunk)
                              .swapaxes(0, 1), tc.shape)

        @jax.checkpoint
        def body(acc, xs):
            h, t, m = xs
            logits = h @ head
            if shard_fn is not None:
                logits = shard_fn(logits, "logits")
            nll_sum, n = _masked_ce_sum(logits, t, m, c.vocab_size)
            return (acc[0] + nll_sum, acc[1] + n), None

        (tot, n), _ = jax.lax.scan(body, (jnp.float32(0.0), jnp.float32(0.0)),
                                   (hc, tc, mc))
        return tot / jnp.maximum(n, 1.0)

    # --------------------------------------------------------------- serving
    def init_serve_state(self, batch: int, cache_len: int,
                         cache_dtype=jnp.bfloat16):
        """``cache_dtype`` may be the string "int8" for dense/moe/vlm: the
        KV pool is stored int8 with per-(token, head) absmax scales (see
        ``repro.serving.kv_quant``) — ~3.6x slot capacity per byte vs f32."""
        c, d = self.cfg, self.dims
        if cache_dtype == "int8" and c.family not in ("dense", "moe", "vlm"):
            raise ValueError(
                f"int8 cache needs an attention KV pool; family={c.family!r} "
                "keeps SSM/conv state in float")
        if c.family in ("dense", "moe", "vlm"):
            return lm.lm_init_cache(c, d, batch, cache_len, cache_dtype)
        if c.family in ("ssm", "hybrid"):
            return ssm_lm.ssm_init_state(c, d, batch, cache_len, cache_dtype)
        if c.family == "audio":
            return encdec.encdec_init_state(c, d, batch, cache_len, cache_dtype)
        raise ValueError(c.family)

    def prefill(self, params, batch, cache_len: int,
                cache_dtype=jnp.bfloat16, shard_fn=None):
        c, d = self.cfg, self.dims
        if c.family in ("dense", "moe", "vlm"):
            # (vlm: _embed_inputs prepends the patch prefix; the cache covers
            # patches + text)
            return lm.lm_prefill(params, batch, c, d, cache_len=cache_len,
                                 cache_dtype=cache_dtype, shard_fn=shard_fn)
        if c.family in ("ssm", "hybrid"):
            return ssm_lm.ssm_prefill(params, batch, c, d, cache_len=cache_len,
                                      cache_dtype=cache_dtype,
                                      shard_fn=shard_fn)
        if c.family == "audio":
            return encdec.encdec_prefill(params, batch, c, d,
                                         cache_len=cache_len,
                                         cache_dtype=cache_dtype,
                                         shard_fn=shard_fn)
        raise ValueError(c.family)

    def prefill_chunk(self, params, state, tokens, offsets, lengths,
                      shard_fn=None):
        """Advance a chunked prefill: run ``tokens`` (B,C) at per-row cache
        ``offsets`` against the carried serve state (KV cache rows for
        dense/moe, SSM/conv/attn state for ssm/hybrid). Returns
        (last-real-token logits, state, pos). Chunk-by-chunk equals the
        single-shot ``prefill`` exactly; vlm/audio requests carry per-request
        extras and stay on the exact-length single-shot path, and moe is
        rejected because expert capacity would scale with the chunk rather
        than the full prompt (per-chunk routing drops differ from
        single-shot — the same reason the engine keeps moe on exact-length
        admission)."""
        c, d = self.cfg, self.dims
        if c.family == "dense":
            return lm.lm_prefill_chunk(params, state, tokens, offsets,
                                       lengths, c, d, shard_fn=shard_fn)
        if c.family in ("ssm", "hybrid"):
            return ssm_lm.ssm_prefill_chunk(params, state, tokens, offsets,
                                            lengths, c, d, shard_fn=shard_fn)
        raise ValueError(f"chunked prefill unsupported for {c.family!r}")

    def decode(self, params, state, tokens, pos, shard_fn=None,
               attn_backend=None):
        """``attn_backend="pallas"`` (dense/moe/vlm only) decodes through
        the flash-decode kernel; None/"einsum" keeps the dense reference
        path. SSM/audio families carry no KV decode loop and ignore it."""
        c, d = self.cfg, self.dims
        if c.family in ("dense", "moe", "vlm"):
            return lm.lm_decode(params, state, tokens, pos, c, d,
                                shard_fn=shard_fn,
                                attn_backend=attn_backend)
        if c.family in ("ssm", "hybrid"):
            return ssm_lm.ssm_decode(params, state, tokens, pos, c, d,
                                     shard_fn=shard_fn)
        if c.family == "audio":
            return encdec.encdec_decode(params, state, tokens, pos, c, d,
                                        shard_fn=shard_fn)
        raise ValueError(c.family)

    # ------------------------------------------------------------ dry-run IO
    def input_specs(self, shape: ShapeConfig, act_dtype=jnp.bfloat16,
                    cache_dtype=jnp.bfloat16) -> dict:
        """ShapeDtypeStruct stand-ins for every input of this (arch×shape)."""
        c = self.cfg
        B, S = shape.global_batch, shape.seq_len
        sds = jax.ShapeDtypeStruct
        if shape.kind in ("train", "prefill"):
            specs = {"tokens": sds((B, S), jnp.int32)}
            if c.family == "vlm":
                specs["patch_embeds"] = sds((B, c.num_patches, c.d_model),
                                            act_dtype)
            if c.family == "audio":
                specs["frame_embeds"] = sds((B, c.encoder_seq_len, c.d_model),
                                            act_dtype)
            return specs
        # decode: one new token against a cache of length S
        state = jax.eval_shape(
            lambda: self.init_serve_state(B, S, cache_dtype))
        return {
            "tokens": sds((B, 1), jnp.int32),
            "pos": sds((), jnp.int32),
            "state": state,
        }


def make_model(cfg: ArchConfig, tp: int = 1, remat: str = "none") -> Model:
    return Model(cfg, padded_dims(cfg, tp), remat)


def make_train_step(model: Model, optimizer, shard_fn=None, donate=True,
                    grad_accum: int = 1, loss_chunk: int = 2048,
                    accum_dtype=jnp.float32):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    ``grad_accum > 1`` scans over microbatches (global batch split on axis 0)
    accumulating gradients before one optimizer step — bounds the per-layer
    activation-checkpoint memory at L·(B/ga)·S·d.
    """
    def grads_of(params, batch):
        return jax.value_and_grad(
            lambda p: model.loss(p, batch, shard_fn=shard_fn,
                                 loss_chunk=loss_chunk),
            has_aux=True)(params)

    def train_step(params, opt_state, batch):
        if grad_accum <= 1:
            (loss, metrics), grads = grads_of(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape(grad_accum, x.shape[0] // grad_accum,
                                    *x.shape[1:]), batch)

            def body(acc, mb):
                (l, m), g = grads_of(params, mb)
                acc_g, acc_l = acc
                g = jax.tree.map(lambda a, b: a + b.astype(accum_dtype),
                                 acc_g, g)
                return (g, acc_l + l), m

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params)
            (grads, loss_sum), ms = jax.lax.scan(
                body, (zero, jnp.float32(0.0)), micro)
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
            loss = loss_sum / grad_accum
            metrics = jax.tree.map(lambda x: x[-1], ms)
        params, opt_state, stats = optimizer.update(grads, opt_state, params)
        metrics = dict(metrics, loss=loss, **stats)
        return params, opt_state, metrics
    return train_step


def make_decode_step(model: Model, shard_fn=None):
    """Returns serve_step(params, state, tokens, pos) -> (logits, state)."""
    def serve_step(params, state, tokens, pos):
        return model.decode(params, state, tokens, pos, shard_fn=shard_fn)
    return serve_step


def make_prefill_step(model: Model, cache_len: int, shard_fn=None):
    def prefill_step(params, batch):
        return model.prefill(params, batch, cache_len=cache_len,
                             shard_fn=shard_fn)
    return prefill_step
