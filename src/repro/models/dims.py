"""Physical (TP-padded) model dimensions.

TPU tensor parallelism requires head counts / vocab divisible by the TP degree.
``PaddedDims`` derives the *physical* dimensions used to build parameters from
the *logical* ``ArchConfig`` plus the TP degree:

  - KV heads: if ``kv < tp`` the kv heads are replicated ``tp // kv`` times
    (vLLM-style). Replicating a GQA kv head is mathematically exact.
  - Q heads: each logical kv-group's queries are split across the replicas of
    its kv head; the per-physical-group query count is padded up so every
    physical group is equal-sized. Padded q-head slots are masked to zero
    after attention so they are exactly inert (forward and backward).
  - Vocab: padded to a multiple of ``vocab_multiple`` (2048 for TP=16) —
    padded logits are masked to -inf before softmax.

With ``tp == 1`` everything collapses to the logical dims (no padding), which
is what the CPU smoke tests exercise; a dedicated test checks padded==unpadded
equivalence.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.configs.base import ArchConfig


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class PaddedDims:
    tp: int
    n_q: int          # physical query heads
    n_kv: int         # physical kv heads (replication included)
    q_per_group: int  # physical q heads per physical kv head
    kv_rep: int       # replication factor of each logical kv head
    vocab: int        # physical (padded) vocab
    q_real: tuple     # bool per physical q head: is it a real (non-pad) head?

    @property
    def pad_flops_ratio(self) -> float:
        """useful q-heads / physical q-heads (roofline useful-ratio term)."""
        return sum(self.q_real) / max(self.n_q, 1)


def padded_dims(cfg: ArchConfig, tp: int = 1, vocab_multiple: int = 0) -> PaddedDims:
    H, KV = cfg.num_heads, cfg.num_kv_heads
    if vocab_multiple == 0:
        vocab_multiple = max(tp * 128, 128) if tp > 1 else 1
    vocab = _round_up(cfg.vocab_size, vocab_multiple)
    if H == 0:  # attention-free
        return PaddedDims(tp, 0, 0, 0, 1, vocab, ())
    if H % KV != 0:
        raise ValueError(f"{cfg.name}: num_heads {H} not divisible by kv {KV}")
    qpg = H // KV
    if KV >= tp:
        if KV % tp != 0:
            raise ValueError(f"{cfg.name}: kv={KV} not divisible by tp={tp}")
        rep = 1
    else:
        if tp % KV != 0:
            raise ValueError(f"{cfg.name}: tp={tp} not a multiple of kv={KV}")
        rep = tp // KV
    n_kv = KV * rep
    qpg_phys = math.ceil(qpg / rep)
    n_q = n_kv * qpg_phys
    # real-head mask: physical group p = (logical group g, replica r);
    # slot j is real iff r*qpg_phys + j < qpg.
    q_real = []
    for p in range(n_kv):
        r = p % rep
        for j in range(qpg_phys):
            q_real.append(r * qpg_phys + j < qpg)
    assert sum(q_real) == H, (sum(q_real), H)
    return PaddedDims(tp, n_q, n_kv, qpg_phys, rep, vocab, tuple(q_real))


def q_head_mask(dims: PaddedDims) -> np.ndarray:
    """(n_q,) float mask — 1 for real heads, 0 for padding."""
    return np.asarray(dims.q_real, dtype=np.float32)
