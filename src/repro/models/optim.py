"""Minimal production optimizer stack (AdamW + clipping + schedules).

Self-contained (no optax dependency). Moments can be stored in bf16 for
very large models (grok-1 / llama4-maverick) so the sharded train state fits
HBM — see DESIGN.md §4.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp


def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    floor_frac: float = 0.1) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / jnp.maximum(warmup, 1)
        t = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor_frac + (1 - floor_frac) * 0.5
                         * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: jnp.dtype = jnp.float32

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else jnp.float32(self.lr)

    def init(self, params):
        zeros = lambda p: jnp.zeros(p.shape, self.moment_dtype)
        return {
            "mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(self, grads, state, params):
        step = state["step"] + 1
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-9)) \
            if self.clip_norm else 1.0

        def upd(g, mu, nu, p):
            g = g.astype(jnp.float32) * scale
            mu1 = self.b1 * mu.astype(jnp.float32) + (1 - self.b1) * g
            nu1 = self.b2 * nu.astype(jnp.float32) + (1 - self.b2) * g * g
            mu_hat = mu1 / (1 - self.b1 ** step.astype(jnp.float32))
            nu_hat = nu1 / (1 - self.b2 ** step.astype(jnp.float32))
            delta = mu_hat / (jnp.sqrt(nu_hat) + self.eps)
            delta = delta + self.weight_decay * p.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - self._lr(step) * delta
            return (new_p.astype(p.dtype), mu1.astype(self.moment_dtype),
                    nu1.astype(self.moment_dtype))

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_mu = treedef.flatten_up_to(state["mu"])
        flat_nu = treedef.flatten_up_to(state["nu"])
        out = [upd(g, mu, nu, p)
               for g, mu, nu, p in zip(flat_g, flat_mu, flat_nu, flat_p)]
        new_params = treedef.unflatten([o[0] for o in out])
        new_state = {
            "mu": treedef.unflatten([o[1] for o in out]),
            "nu": treedef.unflatten([o[2] for o in out]),
            "step": step,
        }
        return new_params, new_state, {"grad_norm": gnorm,
                                       "lr": self._lr(step)}


@dataclasses.dataclass(frozen=True)
class SGD:
    """For the RL inner loops (DDPG actor/critic)."""
    lr: float = 1e-3
    momentum: float = 0.0

    def init(self, params):
        if not self.momentum:
            return {}
        return {"vel": jax.tree.map(jnp.zeros_like, params)}

    def update(self, grads, state, params):
        if not self.momentum:
            new = jax.tree.map(lambda p, g: p - self.lr * g, params, grads)
            return new, state, {}
        vel = jax.tree.map(lambda v, g: self.momentum * v + g,
                           state["vel"], grads)
        new = jax.tree.map(lambda p, v: p - self.lr * v, params, vel)
        return new, {"vel": vel}, {}
