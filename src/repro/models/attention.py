"""GQA attention (prefill, chunked-prefill, decode-with-cache, cross-attn).

Layout is *grouped*: q is (B, S, G, qpg, hd) where G = physical kv heads and
qpg = physical q-heads-per-group (see repro.models.dims). This keeps the TP
sharding of q and kv heads aligned on the same mesh axis ("model") and makes
GQA exact under kv replication.

Long sequences (S >= CHUNK_THRESHOLD) use query-chunked attention via
``lax.scan`` so the (S × S) score matrix is never materialized — each chunk
sees the full key set, so a plain per-row softmax is exact (no online-softmax
needed at this level; the Pallas flash kernel tiles the KV axis too).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.dims import PaddedDims, q_head_mask
from repro.models.layers import apply_rope, he_init

CHUNK_THRESHOLD = 8192
Q_CHUNK = 1024
NEG_INF = -1e9


def init_attention(key, d_model: int, dims: PaddedDims, head_dim: int,
                   qkv_bias: bool, dtype) -> dict:
    ks = jax.random.split(key, 4)
    mask = q_head_mask(dims)  # zero-out padded q heads at init
    p = {
        "wq": he_init(ks[0], (d_model, dims.n_q, head_dim), dtype, d_model)
              * mask[None, :, None].astype(dtype),
        "wk": he_init(ks[1], (d_model, dims.n_kv, head_dim), dtype, d_model),
        "wv": he_init(ks[2], (d_model, dims.n_kv, head_dim), dtype, d_model),
        "wo": he_init(ks[3], (dims.n_q, head_dim, d_model), dtype,
                      dims.n_q * head_dim),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((dims.n_q, head_dim), dtype)
        p["bk"] = jnp.zeros((dims.n_kv, head_dim), dtype)
        p["bv"] = jnp.zeros((dims.n_kv, head_dim), dtype)
    return p


def _project_qkv(params, x, kv_x, dims: PaddedDims):
    q = jnp.einsum("bsd,dnh->bsnh", x, params["wq"])
    k = jnp.einsum("bsd,dgh->bsgh", kv_x, params["wk"])
    v = jnp.einsum("bsd,dgh->bsgh", kv_x, params["wv"])
    if "bq" in params:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    B, S = q.shape[:2]
    q = q.reshape(B, S, dims.n_kv, dims.q_per_group, q.shape[-1])
    return q, k, v


def _mask_pad_heads(ctx, dims: PaddedDims):
    """Zero the padded q-head outputs so they are exactly inert."""
    if all(dims.q_real):
        return ctx
    m = jnp.asarray(q_head_mask(dims).reshape(dims.n_kv, dims.q_per_group))
    return ctx * m[None, None, :, :, None].astype(ctx.dtype)


def _attend(q, k, v, q_pos, k_pos, causal: bool):
    """q: (B,Cq,G,qpg,hd); k,v: (B,T,G,hd); positions are int32 vectors."""
    scale = 1.0 / np.sqrt(q.shape[-1])
    scores = jnp.einsum("bsgqh,btgh->bgqst", q, k,
                        preferred_element_type=jnp.float32) * scale
    if causal:
        mask = q_pos[:, None] >= k_pos[None, :]          # (Cq, T)
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bgqst,btgh->bsgqh", probs.astype(v.dtype), v)
    return ctx


def _attend_maybe_chunked(q, k, v, positions, k_pos, causal):
    """Query-chunked attention when S is long; exact either way.

    Non-multiple S is zero-padded on the query axis (padded rows are computed
    against position 0 and sliced off — keys are never padded, so real rows
    are exact)."""
    B, S = q.shape[:2]
    if S < CHUNK_THRESHOLD:
        return _attend(q, k, v, positions, k_pos, causal)
    S_pad = ((S + Q_CHUNK - 1) // Q_CHUNK) * Q_CHUNK
    if S_pad != S:
        pad = S_pad - S
        q = jnp.pad(q, ((0, 0), (0, pad)) + ((0, 0),) * (q.ndim - 2))
        positions = jnp.pad(positions, (0, pad))
    n_chunks = S_pad // Q_CHUNK
    q_chunks = q.reshape(B, n_chunks, Q_CHUNK, *q.shape[2:]).swapaxes(0, 1)
    pos_chunks = positions.reshape(n_chunks, Q_CHUNK)

    def body(_, qc_pc):
        qc, pc = qc_pc
        return None, _attend(qc, k, v, pc, k_pos, causal)

    _, ctx = jax.lax.scan(body, None, (q_chunks, pos_chunks))
    return ctx.swapaxes(0, 1).reshape(B, S_pad, *q.shape[2:])[:, :S]


def attention(params, x, dims: PaddedDims, *, positions=None, rope_theta=0.0,
              causal=True, kv_x=None, shard_fn=None):
    """Full-sequence (training / prefill) attention. Returns (B,S,d_model)."""
    B, S, _ = x.shape
    kv_x = x if kv_x is None else kv_x
    T = kv_x.shape[1]
    q, k, v = _project_qkv(params, x, kv_x, dims)
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)
    k_pos = jnp.arange(T, dtype=jnp.int32)
    if rope_theta:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, k_pos, rope_theta)
    if shard_fn is not None:
        q, k, v = shard_fn(q, "qkv"), shard_fn(k, "kv"), shard_fn(v, "kv")
    ctx = _attend_maybe_chunked(q, k, v, positions, k_pos, causal)
    ctx = _mask_pad_heads(ctx, dims)
    ctx = ctx.reshape(B, S, dims.n_q, -1)
    return jnp.einsum("bsnh,nhd->bsd", ctx, params["wo"])


def init_kv_cache(batch: int, max_len: int, dims: PaddedDims, head_dim: int,
                  dtype=jnp.bfloat16) -> dict:
    return {
        "k": jnp.zeros((batch, max_len, dims.n_kv, head_dim), dtype),
        "v": jnp.zeros((batch, max_len, dims.n_kv, head_dim), dtype),
    }


def prefill_attention(params, x, dims: PaddedDims, cache, *, rope_theta=0.0,
                      shard_fn=None):
    """Attention that also fills the KV cache for positions [0, S)."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(params, x, x, dims)
    positions = jnp.arange(S, dtype=jnp.int32)
    if rope_theta:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    cache = {
        "k": jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), 0, 1),
        "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), 0, 1),
    }
    ctx = _attend_maybe_chunked(q, k, v, positions, positions, causal=True)
    ctx = _mask_pad_heads(ctx, dims)
    ctx = ctx.reshape(B, S, dims.n_q, -1)
    return jnp.einsum("bsnh,nhd->bsd", ctx, params["wo"]), cache


def chunk_prefill_attention(params, x, dims: PaddedDims, cache, positions,
                            lengths, *, rope_theta=0.0):
    """Continue a prefill one chunk at a time against an existing KV cache.

    x: (B,C,d) chunk activations; ``positions`` (B,C) are per-row absolute
    cache positions (``offset + arange(C)``) and ``lengths`` (B,) the true
    (un-padded) token count of each row's chunk. The chunk's K/V are written
    at those positions (pad columns park at an out-of-bounds index so the
    scatter drops them), then the chunk queries attend causally over the
    *full* cache — prefix chunks included — so chunk-by-chunk prefill equals
    the single-shot forward. Stale cache entries beyond a row's frontier are
    masked by ``k_pos <= q_pos`` exactly like slot reuse in the decode path.
    Returns (out (B,C,d), filled cache)."""
    B, C, _ = x.shape
    q, k, v = _project_qkv(params, x, x, dims)
    if rope_theta:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    S = cache["k"].shape[1]
    j = jnp.arange(C, dtype=jnp.int32)
    wpos = jnp.where(j[None, :] < lengths[:, None], positions, S)
    rows = jnp.arange(B)[:, None]
    kc = cache["k"].at[rows, wpos].set(k.astype(cache["k"].dtype),
                                       mode="drop")
    vc = cache["v"].at[rows, wpos].set(v.astype(cache["v"].dtype),
                                       mode="drop")
    k_pos = jnp.arange(S, dtype=jnp.int32)
    scale = 1.0 / np.sqrt(q.shape[-1])
    scores = jnp.einsum("bsgqh,btgh->bgqst", q, kc.astype(q.dtype),
                        preferred_element_type=jnp.float32) * scale
    mask = (k_pos[None, None, :] <= positions[:, :, None])[:, None, None]
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bgqst,btgh->bsgqh", probs.astype(vc.dtype), vc)
    ctx = _mask_pad_heads(ctx, dims)
    ctx = ctx.reshape(B, C, dims.n_q, -1)
    return jnp.einsum("bsnh,nhd->bsd", ctx, params["wo"]), {"k": kc, "v": vc}


def project_decode_qkv(params, x, dims: PaddedDims, pos, rope_theta):
    """Project the new token's q/k/v with RoPE at `pos` (scalar or (B,))."""
    pos = jnp.asarray(pos, jnp.int32)
    per_seq = pos.ndim == 1
    q, k_new, v_new = _project_qkv(params, x, x, dims)
    pos_vec = pos[:, None] if per_seq else jnp.full((1,), pos, jnp.int32)
    if rope_theta:
        q = apply_rope(q, pos_vec, rope_theta)
        k_new = apply_rope(k_new, pos_vec, rope_theta)
    return q, k_new, v_new


def write_kv(k_cache, v_cache, k_new, v_new, pos):
    """Write one token's k/v at `pos` into (B,S,G,hd) caches — in-place under
    jit (the caches should be loop carries / donated)."""
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 1:
        rows = jnp.arange(k_cache.shape[0])
        k_cache = k_cache.at[rows, pos].set(k_new[:, 0].astype(k_cache.dtype))
        v_cache = v_cache.at[rows, pos].set(v_new[:, 0].astype(v_cache.dtype))
    else:
        k_cache = jax.lax.dynamic_update_slice(
            k_cache, k_new.astype(k_cache.dtype), (0, pos, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            v_cache, v_new.astype(v_cache.dtype), (0, pos, 0, 0))
    return k_cache, v_cache


def decode_attend(params, q, k_cache, v_cache, pos, dims: PaddedDims,
                  backend: str = "einsum"):
    """Read-only attention of a single-token q over cache[0..pos].

    ``backend="pallas"`` routes through the flash-decode kernel
    (``repro.kernels.decode_attention``) instead of the dense einsum: the
    online-softmax tiles the KV axis and skips blocks past the filled cache
    length, so decode cost follows the *filled* cache. The serve cache
    layout is (B, S, G, hd) while the kernel wants (B, G, S, hd) — the
    transpose here is the price of keeping one cache layout for both
    backends (a TPU deployment would store the pool kernel-native). Runs in
    interpret mode off-TPU so the CPU parity tests exercise the same code
    path."""
    if backend == "pallas":
        return _decode_attend_pallas(params, q, k_cache, v_cache, pos, dims)
    B = q.shape[0]
    pos = jnp.asarray(pos, jnp.int32)
    per_seq = pos.ndim == 1
    T = k_cache.shape[1]
    k_pos = jnp.arange(T, dtype=jnp.int32)
    scale = 1.0 / np.sqrt(q.shape[-1])
    scores = jnp.einsum("bsgqh,btgh->bgqst", q, k_cache.astype(q.dtype),
                        preferred_element_type=jnp.float32) * scale
    if per_seq:
        mask = (k_pos[None, :] <= pos[:, None])[:, None, None, None, :]
    else:
        mask = (k_pos <= pos)[None, None, None, None, :]
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bgqst,btgh->bsgqh", probs.astype(v_cache.dtype), v_cache)
    ctx = _mask_pad_heads(ctx, dims)
    ctx = ctx.reshape(B, 1, dims.n_q, -1)
    return jnp.einsum("bsnh,nhd->bsd", ctx, params["wo"])


def _decode_attend_pallas(params, q, k_cache, v_cache, pos, dims: PaddedDims):
    """Flash-decode backend: grouped q (B,1,G,qpg,hd) and the (B,S,G,hd)
    serve caches reshaped to the kernel's (B,Hq,d) / (B,G,S,d) layout, with
    per-row ``pos`` forwarded as the kernel's scalar-prefetch lengths. The
    padded-q-head mask applies after the kernel exactly like the einsum
    path."""
    from repro.kernels.decode_attention import flash_decode

    B = q.shape[0]
    hd = q.shape[-1]
    qf = q.reshape(B, dims.n_kv * dims.q_per_group, hd)
    kc = k_cache.swapaxes(1, 2)                  # (B,S,G,hd) -> (B,G,S,hd)
    vc = v_cache.swapaxes(1, 2)
    out = flash_decode(qf, kc.astype(qf.dtype), vc.astype(qf.dtype), pos,
                       interpret=jax.default_backend() != "tpu")
    ctx = out.reshape(B, 1, dims.n_kv, dims.q_per_group, hd)
    ctx = _mask_pad_heads(ctx, dims)
    ctx = ctx.reshape(B, 1, dims.n_q, -1)
    return jnp.einsum("bsnh,nhd->bsd", ctx, params["wo"])


def decode_attention(params, x, dims: PaddedDims, cache, pos, *,
                     rope_theta=0.0, shard_fn=None):
    """Single-token decode. x: (B,1,d); pos scalar or (B,). Returns
    (out, updated cache). Prefer the split project/write/attend API inside
    scan loops (keeps cache updates in-place on the loop carry)."""
    q, k_new, v_new = project_decode_qkv(params, x, dims, pos, rope_theta)
    k_cache, v_cache = write_kv(cache["k"], cache["v"], k_new, v_new, pos)
    out = decode_attend(params, q, k_cache, v_cache, pos, dims)
    return out, {"k": k_cache, "v": v_cache}
