"""Decoder-only LM covering the dense / moe / vlm families.

Layers are stacked and applied with ``lax.scan`` (small HLO, bounded compile
time at 40-64 layers) with configurable remat. MoE archs with
``moe_every > 1`` scan over *layer groups* (one MoE sublayer + ``moe_every-1``
dense sublayers per group, llama4-maverick style).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models.dims import PaddedDims
from repro.models.layers import gelu, he_init, rms_norm, silu
from repro.models.moe import init_moe, moe_apply


def _remat_policy(name: str):
    if name == "none":
        return None
    if name == "full":
        return jax.checkpoint_policies.nothing_saveable
    if name == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    raise ValueError(name)


def init_mlp(key, d_model, d_ff, activation, dtype):
    ks = jax.random.split(key, 3)
    p = {"w_gate": he_init(ks[0], (d_model, d_ff), dtype, d_model),
         "w_down": he_init(ks[2], (d_ff, d_model), dtype, d_ff)}
    if activation == "swiglu":
        p["w_up"] = he_init(ks[1], (d_model, d_ff), dtype, d_model)
    return p


def mlp_apply(p, x, activation):
    g = x @ p["w_gate"]
    h = silu(g) * (x @ p["w_up"]) if activation == "swiglu" else gelu(g)
    return h @ p["w_down"]


def _init_layer(key, cfg: ArchConfig, dims: PaddedDims, dtype, is_moe: bool):
    k1, k2 = jax.random.split(key)
    p = {
        "attn_norm": jnp.zeros((cfg.d_model,), jnp.float32),
        "attn": attn.init_attention(k1, cfg.d_model, dims,
                                    cfg.resolved_head_dim, cfg.qkv_bias, dtype),
        "ffn_norm": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    if is_moe:
        p["moe"] = init_moe(k2, cfg.d_model, cfg.moe_d_ff or cfg.d_ff,
                            cfg.num_experts, dtype, cfg.moe_shared_expert,
                            cfg.activation)
    else:
        p["mlp"] = init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.activation, dtype)
    return p


def _stack_layers(key, cfg, dims, dtype, n, is_moe):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: _init_layer(k, cfg, dims, dtype, is_moe))(keys)


def init_lm(key, cfg: ArchConfig, dims: PaddedDims, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 5)
    params = {
        "embed": (jax.random.normal(ks[0], (dims.vocab, cfg.d_model))
                  * 0.02).astype(dtype),
        "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = he_init(ks[1], (cfg.d_model, dims.vocab), dtype,
                                    cfg.d_model)
    if cfg.uses_moe and cfg.moe_every > 1:
        n_groups = cfg.num_layers // cfg.moe_every
        params["moe_layers"] = _stack_layers(ks[2], cfg, dims, dtype,
                                             n_groups, True)
        dense_keys = jax.random.split(ks[3], n_groups * (cfg.moe_every - 1))
        dense = jax.vmap(lambda k: _init_layer(k, cfg, dims, dtype, False))(
            dense_keys)
        params["dense_layers"] = jax.tree.map(
            lambda x: x.reshape(n_groups, cfg.moe_every - 1, *x.shape[1:]),
            dense)
    else:
        params["layers"] = _stack_layers(ks[2], cfg, dims, dtype,
                                         cfg.num_layers, cfg.uses_moe)
    if cfg.family == "vlm":
        params["patch_proj"] = he_init(ks[4], (cfg.d_model, cfg.d_model),
                                       dtype, cfg.d_model)
    return params


# ------------------------------------------------------------------ sublayers
def _attn_sublayer(lp, h, cfg, dims, positions, shard_fn):
    y = attn.attention(lp["attn"], rms_norm(h, lp["attn_norm"], cfg.norm_eps),
                       dims, positions=positions, rope_theta=cfg.rope_theta,
                       causal=True, shard_fn=shard_fn)
    return h + y


def _ffn_sublayer(lp, h, cfg, shard_fn):
    x = rms_norm(h, lp["ffn_norm"], cfg.norm_eps)
    if "moe" in lp:
        y, aux = moe_apply(lp["moe"], x, num_experts=cfg.num_experts,
                           top_k=cfg.num_experts_per_tok,
                           capacity_factor=cfg.capacity_factor,
                           activation=cfg.activation, shard_fn=shard_fn)
        return h + y, aux
    return h + mlp_apply(lp["mlp"], x, cfg.activation), 0.0


def _layer(lp, h, cfg, dims, positions, shard_fn):
    h = _attn_sublayer(lp, h, cfg, dims, positions, shard_fn)
    h, aux = _ffn_sublayer(lp, h, cfg, shard_fn)
    if shard_fn is not None:
        h = shard_fn(h, "act_btd")
    return h, aux


# ------------------------------------------------------------------- forward
def _embed_inputs(params, cfg, dims, batch, dtype_ref):
    """Token (+ optional patch) embedding. Returns (h, positions, text_start)."""
    tok = params["embed"][batch["tokens"]]                  # (B,S,d)
    text_start = 0
    if cfg.family == "vlm":
        patches = batch["patch_embeds"].astype(tok.dtype) @ params["patch_proj"]
        tok = jnp.concatenate([patches, tok], axis=1)
        text_start = cfg.num_patches
    positions = jnp.arange(tok.shape[1], dtype=jnp.int32)
    return tok, positions, text_start


def lm_forward(params, batch, cfg: ArchConfig, dims: PaddedDims, *,
               remat="none", shard_fn=None, return_features=False):
    """Full-sequence forward. Returns (logits (B,S_total,V), aux_loss) — or
    (features (B,S_total,d), aux) with ``return_features`` (the chunked-CE
    loss path applies the LM head itself, so the (T,V) logits tensor is never
    materialized)."""
    h, positions, _ = _embed_inputs(params, cfg, dims, batch, None)
    if shard_fn is not None:
        h = shard_fn(h, "act_btd")

    def group_body(carry, lps):
        h, aux = carry
        if "moe_layers" in params:
            moe_lp, dense_lp = lps
            h, a = _layer(moe_lp, h, cfg, dims, positions, shard_fn)
            aux += a
            for j in range(cfg.moe_every - 1):
                sub = jax.tree.map(lambda x: x[j], dense_lp)
                h, _ = _layer(sub, h, cfg, dims, positions, shard_fn)
        else:
            h, a = _layer(lps, h, cfg, dims, positions, shard_fn)
            aux += a
        return (h, aux), None

    body = group_body
    pol = _remat_policy(remat)
    if pol is not None:
        body = jax.checkpoint(group_body, policy=pol)
    if "moe_layers" in params:
        xs = (params["moe_layers"], params["dense_layers"])
    else:
        xs = params["layers"]
    (h, aux), _ = jax.lax.scan(body, (h, jnp.float32(0.0)), xs)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    if return_features:
        return h, aux
    head = params.get("lm_head")
    logits = h @ head if head is not None else h @ params["embed"].T
    if shard_fn is not None:
        logits = shard_fn(logits, "logits")
    return logits, aux


# ---------------------------------------------------------------- serve path
def _is_int8(dtype) -> bool:
    """The string sentinel "int8" selects the quantized KV codec (per-token,
    per-head absmax scales — see ``repro.serving.kv_quant``)."""
    return isinstance(dtype, str) and dtype == "int8"


def lm_init_cache(cfg, dims, batch: int, max_len: int, dtype=jnp.bfloat16):
    n_layers = cfg.num_layers
    hd = cfg.resolved_head_dim
    if cfg.family == "vlm":
        max_len = max_len + cfg.num_patches
    if _is_int8(dtype):
        return {
            "k_q": jnp.zeros((n_layers, batch, max_len, dims.n_kv, hd),
                             jnp.int8),
            "v_q": jnp.zeros((n_layers, batch, max_len, dims.n_kv, hd),
                             jnp.int8),
            "k_s": jnp.ones((n_layers, batch, max_len, dims.n_kv),
                            jnp.float32),
            "v_s": jnp.ones((n_layers, batch, max_len, dims.n_kv),
                            jnp.float32),
        }
    return {
        "k": jnp.zeros((n_layers, batch, max_len, dims.n_kv, hd), dtype),
        "v": jnp.zeros((n_layers, batch, max_len, dims.n_kv, hd), dtype),
    }


def lm_decode(params, cache, tokens, pos, cfg: ArchConfig, dims: PaddedDims, *,
              shard_fn=None, attn_backend=None):
    """One decode step. tokens: (B,1) int32; pos: scalar int32 or (B,) int32
    (cache write index, counting any VLM patch prefix).
    ``attn_backend="pallas"`` reads the cache through the flash-decode
    kernel instead of the dense einsum (see ``attention.decode_attend``).

    The full stacked cache (L,B,S,G,hd) is the scan CARRY with in-place
    single-token writes — no per-layer cache stacking copies (the caches
    should be donated by the caller for true in-place update). An int8
    quantized cache (``k_q``/``v_q``/``k_s``/``v_s`` leaves) is detected
    from its structure: new tokens quantize on write, reads dequantize on
    the fly (the HBM stream is the int8 bytes + scales).
    """
    quant = "k_q" in cache
    backend = attn_backend or "einsum"
    h = params["embed"][tokens]                              # (B,1,d)
    me = cfg.moe_every if "moe_layers" in params else 1
    n_groups = cfg.num_layers // me

    def sublayer(h, lp, layer_idx, cache):
        x = rms_norm(h, lp["attn_norm"], cfg.norm_eps)
        q, k_new, v_new = attn.project_decode_qkv(lp["attn"], x, dims, pos,
                                                  cfg.rope_theta)
        lc = {k: jax.lax.dynamic_index_in_dim(v, layer_idx, 0, False)
              for k, v in cache.items()}
        if quant:
            from repro.serving.kv_quant import dequantize, write_kv_quant
            lc = write_kv_quant(lc, k_new, v_new, pos)
            kc = dequantize(lc["k_q"], lc["k_s"]).astype(q.dtype)
            vc = dequantize(lc["v_q"], lc["v_s"]).astype(q.dtype)
        else:
            kc, vc = attn.write_kv(lc["k"], lc["v"], k_new, v_new, pos)
            lc = {"k": kc, "v": vc}
        cache = {k: jax.lax.dynamic_update_index_in_dim(cache[k], lc[k],
                                                        layer_idx, 0)
                 for k in cache}
        y = attn.decode_attend(lp["attn"], q, kc, vc, pos, dims,
                               backend=backend)
        h = h + y
        h, _ = _ffn_sublayer(lp, h, cfg, shard_fn)
        return h, cache

    def body(carry, xs):
        h, cache = carry
        lps, g = xs
        for j in range(me):
            lp = lps if me == 1 else (
                lps[0] if j == 0
                else jax.tree.map(lambda x: x[j - 1], lps[1]))
            h, cache = sublayer(h, lp, g * me + j, cache)
        return (h, cache), None

    if me == 1:
        xs = (params["layers"], jnp.arange(n_groups))
    else:
        xs = ((params["moe_layers"], params["dense_layers"]),
              jnp.arange(n_groups))
    (h, new_cache), _ = jax.lax.scan(body, (h, cache), xs)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    head = params.get("lm_head")
    logits = h @ head if head is not None else h @ params["embed"].T
    return logits[:, 0], new_cache


def lm_prefill(params, batch, cfg, dims, *, cache_len: int,
               cache_dtype=jnp.bfloat16, shard_fn=None):
    """Prefill: full forward + cache fill. Returns (last-token logits, cache,
    pos). Cache is a scan carry (in-place per-layer writes).

    ``batch["lengths"]`` (B,) marks the true prompt length per row when the
    token matrix is right-padded to a bucket length: logits are gathered at
    ``lengths-1`` and ``pos`` comes back per-row. Causal masking keeps real
    positions exact under trailing pads; pad K/V beyond ``pos`` is masked by
    the decode path until overwritten. (MoE capacity routing sees the pad
    tokens, so padded prefill is exact only when nothing drops.)

    ``cache_dtype="int8"`` runs the forward in f32 and quantizes the filled
    cache once at the end (prefill is compute-bound; only decode needs the
    int8 memory stream)."""
    quant = _is_int8(cache_dtype)
    h, positions, _ = _embed_inputs(params, cfg, dims, batch, None)
    cache = lm_init_cache(cfg, dims, h.shape[0], cache_len,
                          jnp.float32 if quant else cache_dtype)
    S = h.shape[1]
    me = cfg.moe_every if "moe_layers" in params else 1
    n_groups = cfg.num_layers // me

    def sublayer(h, lp, layer_idx, kc_full, vc_full):
        x = rms_norm(h, lp["attn_norm"], cfg.norm_eps)
        kc = jax.lax.dynamic_index_in_dim(kc_full, layer_idx, 0, False)
        vc = jax.lax.dynamic_index_in_dim(vc_full, layer_idx, 0, False)
        y, filled = attn.prefill_attention(lp["attn"], x, dims,
                                           {"k": kc, "v": vc},
                                           rope_theta=cfg.rope_theta)
        kc_full = jax.lax.dynamic_update_index_in_dim(kc_full, filled["k"],
                                                      layer_idx, 0)
        vc_full = jax.lax.dynamic_update_index_in_dim(vc_full, filled["v"],
                                                      layer_idx, 0)
        h = h + y
        h, _ = _ffn_sublayer(lp, h, cfg, shard_fn)
        if shard_fn is not None:
            h = shard_fn(h, "act_btd")
        return h, kc_full, vc_full

    def body(carry, xs):
        h, kc_full, vc_full = carry
        lps, g = xs
        for j in range(me):
            lp = lps if me == 1 else (
                lps[0] if j == 0
                else jax.tree.map(lambda x: x[j - 1], lps[1]))
            h, kc_full, vc_full = sublayer(h, lp, g * me + j, kc_full,
                                           vc_full)
        return (h, kc_full, vc_full), None

    if me == 1:
        xs = (params["layers"], jnp.arange(n_groups))
    else:
        xs = ((params["moe_layers"], params["dense_layers"]),
              jnp.arange(n_groups))
    (h, new_k, new_v), _ = jax.lax.scan(
        body, (h, cache["k"], cache["v"]), xs)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    head = params.get("lm_head")
    lengths = batch.get("lengths")
    if lengths is None:
        last, pos = h[:, -1], S
    else:
        text_start = cfg.num_patches if cfg.family == "vlm" else 0
        idx = (text_start + lengths - 1).astype(jnp.int32)
        last = jnp.take_along_axis(h, idx[:, None, None], axis=1)[:, 0]
        pos = (text_start + lengths).astype(jnp.int32)
    logits = last @ head if head is not None else last @ params["embed"].T
    if quant:
        from repro.serving.kv_quant import quantize
        kq, ks = quantize(new_k)
        vq, vs = quantize(new_v)
        return logits, {"k_q": kq, "v_q": vq, "k_s": ks, "v_s": vs}, pos
    return logits, {"k": new_k, "v": new_v}, pos


def lm_prefill_chunk(params, cache, tokens, offsets, lengths, cfg, dims, *,
                     shard_fn=None):
    """Continue a prefill: run ``tokens`` (B,C) at per-row cache ``offsets``
    (B,) against an existing KV cache (leaves (L,B,S,G,hd)), writing the
    chunk's K/V at [offset, offset+length) and attending causally over the
    whole prefix. ``lengths`` (B,) is each row's true token count within the
    chunk (rows are right-padded to the fixed chunk width). Returns
    (last-real-token logits (B,V), cache, pos (B,) = offset+length).

    Chunk-by-chunk equals single-shot prefill exactly: causal attention
    decomposes over chunks, pad columns never write (parked out of bounds)
    and stale cache beyond a row's frontier is masked by ``k_pos <= q_pos``.
    Only the float cache codec is supported (the int8 path quantizes whole
    prompts at prefill end; the engine routes int8 replicas to single-shot).
    """
    assert "k_q" not in cache, "chunked prefill requires a float KV cache"
    h = params["embed"][tokens]
    C = tokens.shape[1]
    posmat = offsets[:, None].astype(jnp.int32) + \
        jnp.arange(C, dtype=jnp.int32)[None, :]
    me = cfg.moe_every if "moe_layers" in params else 1
    n_groups = cfg.num_layers // me

    def sublayer(h, lp, layer_idx, kc_full, vc_full):
        x = rms_norm(h, lp["attn_norm"], cfg.norm_eps)
        kc = jax.lax.dynamic_index_in_dim(kc_full, layer_idx, 0, False)
        vc = jax.lax.dynamic_index_in_dim(vc_full, layer_idx, 0, False)
        y, filled = attn.chunk_prefill_attention(
            lp["attn"], x, dims, {"k": kc, "v": vc}, posmat, lengths,
            rope_theta=cfg.rope_theta)
        kc_full = jax.lax.dynamic_update_index_in_dim(kc_full, filled["k"],
                                                      layer_idx, 0)
        vc_full = jax.lax.dynamic_update_index_in_dim(vc_full, filled["v"],
                                                      layer_idx, 0)
        h = h + y
        h, _ = _ffn_sublayer(lp, h, cfg, shard_fn)
        if shard_fn is not None:
            h = shard_fn(h, "act_btd")
        return h, kc_full, vc_full

    def body(carry, xs):
        h, kc_full, vc_full = carry
        lps, g = xs
        for j in range(me):
            lp = lps if me == 1 else (
                lps[0] if j == 0
                else jax.tree.map(lambda x: x[j - 1], lps[1]))
            h, kc_full, vc_full = sublayer(h, lp, g * me + j, kc_full,
                                           vc_full)
        return (h, kc_full, vc_full), None

    if me == 1:
        xs = (params["layers"], jnp.arange(n_groups))
    else:
        xs = ((params["moe_layers"], params["dense_layers"]),
              jnp.arange(n_groups))
    (h, new_k, new_v), _ = jax.lax.scan(
        body, (h, cache["k"], cache["v"]), xs)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    head = params.get("lm_head")
    idx = (lengths - 1).astype(jnp.int32)
    last = jnp.take_along_axis(h, idx[:, None, None], axis=1)[:, 0]
    logits = last @ head if head is not None else last @ params["embed"].T
    pos = (offsets + lengths).astype(jnp.int32)
    return logits, {"k": new_k, "v": new_v}, pos
