"""Top-k mixture-of-experts FFN with capacity-bounded scatter dispatch.

Dispatch uses cumsum slot assignment + scatter into an (E, C, d) buffer —
never materializing a (tokens × E × C) one-hot. Tokens over capacity are
dropped (scatter mode='drop'; gather mode='fill' returns zeros), matching
Switch/GShard semantics. Aux load-balance loss included.

Sharding: tokens shard over the data axes, expert hidden dim over "model"
(TP-in-expert). With ``expert_sharding='data'`` and E % |data| == 0 the expert
dim itself shards over data (EP) — GSPMD inserts the all-to-all.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import gelu, he_init, silu


def init_moe(key, d_model: int, d_ff: int, num_experts: int, dtype,
             shared_expert: bool, activation: str) -> dict:
    ks = jax.random.split(key, 5)
    E = num_experts
    p = {
        "router": he_init(ks[0], (d_model, E), jnp.float32, d_model),
        "w_gate": he_init(ks[1], (E, d_model, d_ff), dtype, d_model),
        "w_up": he_init(ks[2], (E, d_model, d_ff), dtype, d_model),
        "w_down": he_init(ks[3], (E, d_ff, d_model), dtype, d_ff),
    }
    if activation != "swiglu":
        del p["w_up"]
    if shared_expert:
        ks2 = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": he_init(ks2[0], (d_model, d_ff), dtype, d_model),
            "w_up": he_init(ks2[1], (d_model, d_ff), dtype, d_model),
            "w_down": he_init(ks2[2], (d_ff, d_model), dtype, d_ff),
        }
    return p


def _expert_ffn(p, buf, activation):
    """buf: (E, C, d) -> (E, C, d)."""
    g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
    if activation == "swiglu":
        u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
        h = silu(g) * u
    else:
        h = gelu(g)
    return jnp.einsum("ecf,efd->ecd", h, p["w_down"])


def _dense_ffn(p, x, activation):
    g = jnp.einsum("td,df->tf", x, p["w_gate"])
    if activation == "swiglu":
        h = silu(g) * jnp.einsum("td,df->tf", x, p["w_up"])
    else:
        h = gelu(g)
    return jnp.einsum("tf,fd->td", h, p["w_down"])


def moe_apply(params, x, *, num_experts: int, top_k: int,
              capacity_factor: float = 1.25, activation: str = "swiglu",
              shard_fn=None):
    """x: (B, S, d) -> (y, aux_loss).

    Dispatch is PER BATCH ROW (capacity and slot cumsum within each
    sequence): sequences cannot displace each other's tokens (deterministic
    under continuous batching / changing co-batched requests) and slot order
    follows sequence order, so drops are causal within a row.
    """
    B, S, d = x.shape
    E, K = num_experts, top_k
    logits = (x.astype(jnp.float32) @ params["router"])            # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, K)                         # (B,S,K)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, axis=-1, keepdims=True), 1e-9)

    # aux load-balance loss (Switch eq. 4): E * <f_e * p_e>
    assign = jax.nn.one_hot(top_i[..., 0], E, dtype=jnp.float32)
    aux = E * jnp.mean(jnp.mean(assign, axis=(0, 1))
                       * jnp.mean(probs, axis=(0, 1)))

    C = int(math.ceil(S * K / E * capacity_factor))
    C = max(C, K)
    flat_e = top_i.reshape(B, S * K)                               # expert ids
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)            # (B,S*K,E)
    slot = (jnp.cumsum(onehot, axis=1) * onehot).sum(-1) - 1       # (B,S*K)
    slot = jnp.where(slot < C, slot, C)                            # C = dropped
    rows = jnp.arange(B, dtype=jnp.int32)[:, None]
    tok = jnp.repeat(jnp.arange(S, dtype=jnp.int32), K)[None]      # (1,S*K)
    xt = x  # (B,S,d)

    buf = jnp.zeros((E, B, C, d), x.dtype)
    src = jnp.take_along_axis(xt, jnp.broadcast_to(tok, (B, S * K))[..., None],
                              axis=1)                              # (B,S*K,d)
    buf = buf.at[flat_e, rows, slot].add(src, mode="drop")
    if shard_fn is not None:
        buf = shard_fn(buf, "moe_buf")
    out_buf = _expert_ffn(params, buf.reshape(E, B * C, d), activation)
    out_buf = out_buf.reshape(E, B, C, d)
    if shard_fn is not None:
        out_buf = shard_fn(out_buf, "moe_buf")

    gathered = out_buf.at[flat_e, rows, slot].get(
        mode="fill", fill_value=0)                                 # (B,S*K,d)
    weighted = gathered * top_p.reshape(B, S * K, 1).astype(gathered.dtype)
    y = jnp.zeros((B, S, d), x.dtype).at[
        rows, jnp.broadcast_to(tok, (B, S * K))].add(weighted)

    if "shared" in params:
        y = y + _dense_ffn(params["shared"], x.reshape(B * S, d),
                           activation).reshape(B, S, d)
    return y, aux


def moe_dense_oracle(params, x, *, num_experts: int, top_k: int,
                     activation: str = "swiglu"):
    """O(T·E) oracle: every expert on every token, combine with top-k gates.

    No capacity drops — used by tests with high capacity_factor.
    """
    B, S, d = x.shape
    xt = x.reshape(B * S, d)
    logits = xt.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, top_k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, axis=-1, keepdims=True), 1e-9)
    # scatter normalized gates back to (T, E)
    gates_full = jnp.zeros_like(probs).at[
        jnp.arange(xt.shape[0])[:, None], top_i].set(top_p)
    outs = _expert_ffn(params, jnp.broadcast_to(xt, (num_experts,) + xt.shape),
                       activation)                       # (E, T, d)
    y = jnp.einsum("te,etd->td", gates_full, outs.astype(jnp.float32))
    if "shared" in params:
        y = y + _dense_ffn(params["shared"], xt, activation).astype(jnp.float32)
    return y.reshape(B, S, d).astype(x.dtype)
