"""Mamba-2 LM (ssm family) and Zamba-2-style hybrid (mamba backbone + shared
attention block every ``attn_every`` layers, per-invocation KV caches).

Decode is O(1) in context for the mamba layers (ssm+conv state) — these are
the two archs that run the long_500k shape.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models.dims import PaddedDims
from repro.models.layers import he_init, rms_norm
from repro.models.lm import init_mlp, mlp_apply, _remat_policy
from repro.models.ssd import (init_mamba2, mamba2_decode, mamba2_forward,
                              mamba2_init_state)


def _n_invocations(cfg: ArchConfig) -> int:
    if cfg.family != "hybrid" or not cfg.attn_every:
        return 0
    return (cfg.num_layers + cfg.attn_every - 1) // cfg.attn_every


def init_ssm_lm(key, cfg: ArchConfig, dims: PaddedDims, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    def layer_init(k):
        return {
            "norm": jnp.zeros((cfg.d_model,), jnp.float32),
            "mamba": init_mamba2(k, cfg.d_model, cfg.d_inner, cfg.ssm_heads,
                                 cfg.ssm_head_dim, cfg.ssm_state,
                                 cfg.ssm_groups, cfg.ssm_conv_width, dtype),
        }
    params = {
        "embed": (jax.random.normal(ks[0], (dims.vocab, cfg.d_model))
                  * 0.02).astype(dtype),
        "layers": jax.vmap(layer_init)(jax.random.split(ks[1], cfg.num_layers)),
        "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = he_init(ks[2], (cfg.d_model, dims.vocab), dtype,
                                    cfg.d_model)
    if cfg.family == "hybrid":
        k1, k2 = jax.random.split(ks[3])
        params["shared_attn"] = {
            "attn_norm": jnp.zeros((cfg.d_model,), jnp.float32),
            "attn": attn.init_attention(k1, cfg.d_model, dims,
                                        cfg.resolved_head_dim, False, dtype),
            "ffn_norm": jnp.zeros((cfg.d_model,), jnp.float32),
            "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.activation, dtype),
        }
    return params


def _shared_block(sp, h, cfg, dims, positions, shard_fn):
    y = attn.attention(sp["attn"], rms_norm(h, sp["attn_norm"], cfg.norm_eps),
                       dims, positions=positions, rope_theta=cfg.rope_theta,
                       causal=True, shard_fn=shard_fn)
    h = h + y
    h = h + mlp_apply(sp["mlp"], rms_norm(h, sp["ffn_norm"], cfg.norm_eps),
                      cfg.activation)
    return h


def ssm_forward(params, batch, cfg: ArchConfig, dims: PaddedDims, *,
                remat="none", shard_fn=None, return_features=False):
    """Training forward: (logits (B,S,V), aux=0)."""
    h = params["embed"][batch["tokens"]]
    if shard_fn is not None:
        h = shard_fn(h, "act_btd")
    positions = jnp.arange(h.shape[1], dtype=jnp.int32)
    hybrid = cfg.family == "hybrid"

    def body(carry, xs):
        h = carry
        lp, idx = xs
        if hybrid:
            h = jax.lax.cond(
                idx % cfg.attn_every == 0,
                lambda hh: _shared_block(params["shared_attn"], hh, cfg, dims,
                                         positions, shard_fn),
                lambda hh: hh, h)
        h = h + mamba2_forward(lp["mamba"],
                               rms_norm(h, lp["norm"], cfg.norm_eps), cfg,
                               shard_fn=shard_fn)
        if shard_fn is not None:
            h = shard_fn(h, "act_btd")
        return h, None

    pol = _remat_policy(remat)
    fn = jax.checkpoint(body, policy=pol) if pol is not None else body
    h, _ = jax.lax.scan(fn, h, (params["layers"],
                                jnp.arange(cfg.num_layers)))
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    if return_features:
        return h, jnp.float32(0.0)
    head = params.get("lm_head")
    logits = h @ head if head is not None else h @ params["embed"].T
    if shard_fn is not None:
        logits = shard_fn(logits, "logits")
    return logits, jnp.float32(0.0)


# ------------------------------------------------------------------ serving
def ssm_init_state(cfg, dims, batch: int, max_len: int, dtype=jnp.bfloat16):
    st = mamba2_init_state(batch, cfg, dtype)
    state = {
        "ssm": jnp.zeros((cfg.num_layers,) + st["ssm"].shape, jnp.float32),
        "conv": jnp.zeros((cfg.num_layers,) + st["conv"].shape, dtype),
    }
    if cfg.family == "hybrid":
        n_inv = _n_invocations(cfg)
        hd = cfg.resolved_head_dim
        state["attn_k"] = jnp.zeros((n_inv, batch, max_len, dims.n_kv, hd), dtype)
        state["attn_v"] = jnp.zeros((n_inv, batch, max_len, dims.n_kv, hd), dtype)
    return state


def _shared_block_decode(sp, h, cfg, dims, kc, vc, pos):
    x = rms_norm(h, sp["attn_norm"], cfg.norm_eps)
    y, nc = attn.decode_attention(sp["attn"], x, dims, {"k": kc, "v": vc},
                                  pos, rope_theta=cfg.rope_theta)
    h = h + y
    h = h + mlp_apply(sp["mlp"], rms_norm(h, sp["ffn_norm"], cfg.norm_eps),
                      cfg.activation)
    return h, nc["k"], nc["v"]


def ssm_decode(params, state, tokens, pos, cfg: ArchConfig, dims: PaddedDims,
               *, shard_fn=None):
    """One decode step. tokens (B,1); pos scalar. Returns (logits (B,V), state)."""
    h = params["embed"][tokens]
    hybrid = cfg.family == "hybrid"
    ak, av = state.get("attn_k"), state.get("attn_v")

    def body(carry, xs):
        h, ak, av = carry
        lp, ssm_st, conv_st, idx = xs

        if hybrid:
            inv = idx // cfg.attn_every

            def with_attn(args):
                h, ak, av = args
                kc = jax.lax.dynamic_index_in_dim(ak, inv, 0, keepdims=False)
                vc = jax.lax.dynamic_index_in_dim(av, inv, 0, keepdims=False)
                h, nk, nv = _shared_block_decode(params["shared_attn"], h, cfg,
                                                 dims, kc, vc, pos)
                ak = jax.lax.dynamic_update_index_in_dim(ak, nk, inv, 0)
                av = jax.lax.dynamic_update_index_in_dim(av, nv, inv, 0)
                return h, ak, av

            h, ak, av = jax.lax.cond(idx % cfg.attn_every == 0, with_attn,
                                     lambda a: a, (h, ak, av))
        y, new_st = mamba2_decode(lp["mamba"],
                                  rms_norm(h, lp["norm"], cfg.norm_eps), cfg,
                                  {"ssm": ssm_st, "conv": conv_st})
        h = h + y
        return (h, ak, av), (new_st["ssm"], new_st["conv"])

    (h, ak, av), (new_ssm, new_conv) = jax.lax.scan(
        body, (h, ak, av),
        (params["layers"], state["ssm"], state["conv"],
         jnp.arange(cfg.num_layers)))
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    head = params.get("lm_head")
    logits = h @ head if head is not None else h @ params["embed"].T
    new_state = {"ssm": new_ssm, "conv": new_conv}
    if hybrid:
        new_state["attn_k"], new_state["attn_v"] = ak, av
    return logits[:, 0], new_state


def ssm_prefill(params, batch, cfg: ArchConfig, dims: PaddedDims, *,
                cache_len: int, cache_dtype=jnp.bfloat16, shard_fn=None):
    """Prefill: returns (last-token logits, serve state, pos).

    ``batch["lengths"]`` (B,) enables right-padded bucketed prompts: padded
    steps are exactly inert for the SSM state (dt=0), the conv state is
    gathered from the last real positions, and logits come from ``lengths-1``
    (``pos`` is then per-row)."""
    h = params["embed"][batch["tokens"]]
    lengths = batch.get("lengths")
    B, S = h.shape[:2]
    positions = jnp.arange(S, dtype=jnp.int32)
    hybrid = cfg.family == "hybrid"
    state = ssm_init_state(cfg, dims, B, cache_len, cache_dtype)
    ak, av = state.get("attn_k"), state.get("attn_v")

    def body(carry, xs):
        h, ak, av = carry
        lp, idx = xs
        if hybrid:
            inv = idx // cfg.attn_every

            def with_attn(args):
                h, ak, av = args
                sp = params["shared_attn"]
                x = rms_norm(h, sp["attn_norm"], cfg.norm_eps)
                kc = jax.lax.dynamic_index_in_dim(ak, inv, 0, keepdims=False)
                vc = jax.lax.dynamic_index_in_dim(av, inv, 0, keepdims=False)
                y, filled = attn.prefill_attention(sp["attn"], x, dims,
                                                   {"k": kc, "v": vc},
                                                   rope_theta=cfg.rope_theta)
                h = h + y
                h = h + mlp_apply(sp["mlp"],
                                  rms_norm(h, sp["ffn_norm"], cfg.norm_eps),
                                  cfg.activation)
                ak = jax.lax.dynamic_update_index_in_dim(ak, filled["k"], inv, 0)
                av = jax.lax.dynamic_update_index_in_dim(av, filled["v"], inv, 0)
                return h, ak, av

            h, ak, av = jax.lax.cond(idx % cfg.attn_every == 0, with_attn,
                                     lambda a: a, (h, ak, av))
        y, st = mamba2_forward(lp["mamba"],
                               rms_norm(h, lp["norm"], cfg.norm_eps), cfg,
                               return_state=True, shard_fn=shard_fn,
                               lengths=lengths)
        h = h + y
        return (h, ak, av), (st["ssm"], st["conv"].astype(cache_dtype))

    (h, ak, av), (ssm_states, conv_states) = jax.lax.scan(
        body, (h, ak, av), (params["layers"], jnp.arange(cfg.num_layers)))
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    head = params.get("lm_head")
    if lengths is None:
        last, pos = h[:, -1], S
    else:
        idx = (lengths - 1).astype(jnp.int32)
        last = jnp.take_along_axis(h, idx[:, None, None], axis=1)[:, 0]
        pos = lengths.astype(jnp.int32)
    logits = last @ head if head is not None else last @ params["embed"].T
    new_state = {"ssm": ssm_states, "conv": conv_states}
    if hybrid:
        new_state["attn_k"], new_state["attn_v"] = ak, av
    return logits, new_state, pos


def ssm_prefill_chunk(params, state, tokens, offsets, lengths,
                      cfg: ArchConfig, dims: PaddedDims, *, shard_fn=None):
    """Continue a prefill one chunk at a time: ``state`` is the serve state
    left by earlier chunks (zeros for the first), ``tokens`` (B,C) the next
    chunk right-padded to the fixed width with ``lengths`` (B,) true counts,
    and ``offsets`` (B,) the absolute position of each row's chunk start.

    The SSM scan seeds from the carried per-layer state, the conv window
    rides the carried raw tail (the same layout ``mamba2_decode`` keeps), and
    hybrid attention layers write/read the per-invocation KV caches at the
    chunk's absolute positions — so chunk-by-chunk equals single-shot prefill
    exactly (pad steps are dt=0 inert). Returns (last-real-token logits,
    state, pos (B,) = offset+length)."""
    h = params["embed"][tokens]
    C = tokens.shape[1]
    hybrid = cfg.family == "hybrid"
    ak, av = state.get("attn_k"), state.get("attn_v")
    posmat = offsets[:, None].astype(jnp.int32) + \
        jnp.arange(C, dtype=jnp.int32)[None, :]
    conv_dtype = state["conv"].dtype

    def body(carry, xs):
        h, ak, av = carry
        lp, ssm_st, conv_st, idx = xs
        if hybrid:
            inv = idx // cfg.attn_every

            def with_attn(args):
                h, ak, av = args
                sp = params["shared_attn"]
                x = rms_norm(h, sp["attn_norm"], cfg.norm_eps)
                kc = jax.lax.dynamic_index_in_dim(ak, inv, 0, keepdims=False)
                vc = jax.lax.dynamic_index_in_dim(av, inv, 0, keepdims=False)
                y, filled = attn.chunk_prefill_attention(
                    sp["attn"], x, dims, {"k": kc, "v": vc}, posmat, lengths,
                    rope_theta=cfg.rope_theta)
                h = h + y
                h = h + mlp_apply(sp["mlp"],
                                  rms_norm(h, sp["ffn_norm"], cfg.norm_eps),
                                  cfg.activation)
                ak = jax.lax.dynamic_update_index_in_dim(ak, filled["k"],
                                                         inv, 0)
                av = jax.lax.dynamic_update_index_in_dim(av, filled["v"],
                                                         inv, 0)
                return h, ak, av

            h, ak, av = jax.lax.cond(idx % cfg.attn_every == 0, with_attn,
                                     lambda a: a, (h, ak, av))
        y, st = mamba2_forward(lp["mamba"],
                               rms_norm(h, lp["norm"], cfg.norm_eps), cfg,
                               init_state=ssm_st, conv_state=conv_st,
                               lengths=lengths, return_state=True,
                               shard_fn=shard_fn)
        h = h + y
        return (h, ak, av), (st["ssm"], st["conv"].astype(conv_dtype))

    (h, ak, av), (ssm_states, conv_states) = jax.lax.scan(
        body, (h, ak, av),
        (params["layers"], state["ssm"], state["conv"],
         jnp.arange(cfg.num_layers)))
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    idx = (lengths - 1).astype(jnp.int32)
    last = jnp.take_along_axis(h, idx[:, None, None], axis=1)[:, 0]
    head = params.get("lm_head")
    logits = last @ head if head is not None else last @ params["embed"].T
    pos = (offsets + lengths).astype(jnp.int32)
    new_state = {"ssm": ssm_states, "conv": conv_states}
    if hybrid:
        new_state["attn_k"], new_state["attn_v"] = ak, av
    return logits, new_state, pos
