"""Shared layer primitives: norms, initializers, RoPE, activations."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def he_init(key, shape, dtype, fan_in=None):
    fan_in = fan_in or shape[0]
    return (jax.random.normal(key, shape) / np.sqrt(fan_in)).astype(dtype)


def rms_norm(x, scale, eps=1e-5):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x, scale, bias, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale + bias).astype(x.dtype)


def silu(x):
    return x * jax.nn.sigmoid(x)


def gelu(x):
    return jax.nn.gelu(x, approximate=True)


def softplus(x):
    return jax.nn.softplus(x)


# ----------------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float):
    """(head_dim//2,) inverse frequencies."""
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (B, S, heads..., head_dim) rotated by `positions`.

    positions: (S,) shared across batch, or (B, S) per-sequence (continuous
    batching). Uses the interleaved-as-halves convention (rotate_half).
    """
    head_dim = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(head_dim, theta))          # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    # insert the head axes (everything between S and head_dim); the count is
    # fixed by x's rank so both (S,) and (B,S) position shapes align.
    for _ in range(x.ndim - 3):
        angles = angles[..., None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n_pos: int, d_model: int) -> np.ndarray:
    """Whisper-style sinusoidal embeddings (n_pos, d_model)."""
    log_timescale = np.log(10_000.0) / (d_model // 2 - 1)
    inv = np.exp(-log_timescale * np.arange(d_model // 2, dtype=np.float32))
    scaled = np.arange(n_pos, dtype=np.float32)[:, None] * inv[None, :]
    return np.concatenate([np.sin(scaled), np.cos(scaled)], axis=1)


def cross_entropy(logits, targets, vocab_logical: int, mask=None):
    """Mean CE over non-masked positions; padded vocab columns are excluded.

    logits: (..., V_phys) float; targets: (...) int32; mask: (...) float/bool.
    """
    v_phys = logits.shape[-1]
    logits = logits.astype(jnp.float32)
    if v_phys > vocab_logical:
        neg = jnp.full((v_phys - vocab_logical,), -1e9, dtype=jnp.float32)
        logits = logits.at[..., vocab_logical:].set(neg)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
