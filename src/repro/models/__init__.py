from repro.models.model import (  # noqa: F401
    Model, make_decode_step, make_model, make_prefill_step, make_train_step,
)
from repro.models.dims import PaddedDims, padded_dims  # noqa: F401
