"""Whisper-style encoder-decoder (audio family).

The conv/mel frontend is a stub per the brief: inputs are precomputed frame
embeddings (B, encoder_seq_len, d_model). Encoder adds sinusoidal positions;
decoder uses learned positions, causal self-attention and cross-attention to
the encoder output. LayerNorm + GELU (original Whisper choices).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models.dims import PaddedDims
from repro.models.layers import he_init, layer_norm, sinusoidal_positions
from repro.models.lm import init_mlp, mlp_apply, _remat_policy


def _ln_init(d):
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def _enc_layer_init(key, cfg, dims, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "attn_norm": _ln_init(cfg.d_model),
        "attn": attn.init_attention(k1, cfg.d_model, dims,
                                    cfg.resolved_head_dim, True, dtype),
        "ffn_norm": _ln_init(cfg.d_model),
        "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.activation, dtype),
    }


def _dec_layer_init(key, cfg, dims, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    p = _enc_layer_init(k1, cfg, dims, dtype)
    p["cross_norm"] = _ln_init(cfg.d_model)
    p["cross"] = attn.init_attention(k2, cfg.d_model, dims,
                                     cfg.resolved_head_dim, True, dtype)
    return p


def init_encdec(key, cfg: ArchConfig, dims: PaddedDims, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    return {
        "embed": (jax.random.normal(ks[0], (dims.vocab, cfg.d_model))
                  * 0.02).astype(dtype),
        "dec_pos": (jax.random.normal(ks[1], (cfg.max_decoder_pos,
                                              cfg.d_model)) * 0.01).astype(dtype),
        "enc_layers": jax.vmap(
            lambda k: _enc_layer_init(k, cfg, dims, dtype))(
                jax.random.split(ks[2], cfg.encoder_layers)),
        "enc_final_norm": _ln_init(cfg.d_model),
        "dec_layers": jax.vmap(
            lambda k: _dec_layer_init(k, cfg, dims, dtype))(
                jax.random.split(ks[3], cfg.num_layers)),
        "dec_final_norm": _ln_init(cfg.d_model),
    }


def _ln(x, p, eps):
    return layer_norm(x, p["scale"], p["bias"], eps)


def encode(params, frame_embeds, cfg, dims, *, remat="none", shard_fn=None):
    pos = jnp.asarray(sinusoidal_positions(frame_embeds.shape[1], cfg.d_model))
    h = frame_embeds + pos.astype(frame_embeds.dtype)[None]

    def body(h, lp):
        x = _ln(h, lp["attn_norm"], cfg.norm_eps)
        h = h + attn.attention(lp["attn"], x, dims, rope_theta=0.0,
                               causal=False, shard_fn=shard_fn)
        x = _ln(h, lp["ffn_norm"], cfg.norm_eps)
        h = h + mlp_apply(lp["mlp"], x, cfg.activation)
        return h, None

    pol = _remat_policy(remat)
    fn = jax.checkpoint(body, policy=pol) if pol is not None else body
    h, _ = jax.lax.scan(fn, h, params["enc_layers"])
    return _ln(h, params["enc_final_norm"], cfg.norm_eps)


def _decoder_stack(params, h, enc_out, cfg, dims, *, remat="none",
                   shard_fn=None):
    def body(h, lp):
        x = _ln(h, lp["attn_norm"], cfg.norm_eps)
        h = h + attn.attention(lp["attn"], x, dims, rope_theta=0.0,
                               causal=True, shard_fn=shard_fn)
        x = _ln(h, lp["cross_norm"], cfg.norm_eps)
        h = h + attn.attention(lp["cross"], x, dims, rope_theta=0.0,
                               causal=False, kv_x=enc_out, shard_fn=shard_fn)
        x = _ln(h, lp["ffn_norm"], cfg.norm_eps)
        h = h + mlp_apply(lp["mlp"], x, cfg.activation)
        return h, None

    pol = _remat_policy(remat)
    fn = jax.checkpoint(body, policy=pol) if pol is not None else body
    h, _ = jax.lax.scan(fn, h, params["dec_layers"])
    return _ln(h, params["dec_final_norm"], cfg.norm_eps)


def encdec_forward(params, batch, cfg: ArchConfig, dims: PaddedDims, *,
                   remat="none", shard_fn=None, return_features=False):
    """Training forward (teacher forcing). batch: frame_embeds + tokens."""
    enc_out = encode(params, batch["frame_embeds"], cfg, dims, remat=remat,
                     shard_fn=shard_fn)
    toks = batch["tokens"]
    S = toks.shape[1]
    h = params["embed"][toks] + params["dec_pos"][:S][None]
    h = _decoder_stack(params, h, enc_out, cfg, dims, remat=remat,
                       shard_fn=shard_fn)
    if return_features:
        return h, jnp.float32(0.0)
    logits = h @ params["embed"].T
    if shard_fn is not None:
        logits = shard_fn(logits, "logits")
    return logits, jnp.float32(0.0)


# ------------------------------------------------------------------ serving
def encdec_init_state(cfg, dims, batch: int, max_len: int,
                      dtype=jnp.bfloat16):
    hd = cfg.resolved_head_dim
    L, Le = cfg.num_layers, cfg.encoder_seq_len
    return {
        "self_k": jnp.zeros((L, batch, max_len, dims.n_kv, hd), dtype),
        "self_v": jnp.zeros((L, batch, max_len, dims.n_kv, hd), dtype),
        "cross_k": jnp.zeros((L, batch, Le, dims.n_kv, hd), dtype),
        "cross_v": jnp.zeros((L, batch, Le, dims.n_kv, hd), dtype),
    }


def encdec_prefill(params, batch, cfg, dims, *, cache_len: int,
                   cache_dtype=jnp.bfloat16, shard_fn=None):
    """Encode + decoder prefill. Returns (last logits, state, pos)."""
    enc_out = encode(params, batch["frame_embeds"], cfg, dims,
                     shard_fn=shard_fn)
    toks = batch["tokens"]
    B, S = toks.shape
    h = params["embed"][toks] + params["dec_pos"][:S][None]
    state = encdec_init_state(cfg, dims, B, cache_len, cache_dtype)

    def body(carry, xs):
        h, sk_full, sv_full = carry
        lp, idx = xs
        x = _ln(h, lp["attn_norm"], cfg.norm_eps)
        kc = jax.lax.dynamic_index_in_dim(sk_full, idx, 0, False)
        vc = jax.lax.dynamic_index_in_dim(sv_full, idx, 0, False)
        y, filled = attn.prefill_attention(lp["attn"], x, dims,
                                           {"k": kc, "v": vc}, rope_theta=0.0)
        sk_full = jax.lax.dynamic_update_index_in_dim(sk_full, filled["k"],
                                                      idx, 0)
        sv_full = jax.lax.dynamic_update_index_in_dim(sv_full, filled["v"],
                                                      idx, 0)
        h = h + y
        x = _ln(h, lp["cross_norm"], cfg.norm_eps)
        ck = jnp.einsum("btd,dgh->btgh", enc_out, lp["cross"]["wk"]) \
            + lp["cross"]["bk"]
        cv = jnp.einsum("btd,dgh->btgh", enc_out, lp["cross"]["wv"]) \
            + lp["cross"]["bv"]
        h = h + attn.attention(lp["cross"], x, dims, rope_theta=0.0,
                               causal=False, kv_x=enc_out)
        x = _ln(h, lp["ffn_norm"], cfg.norm_eps)
        h = h + mlp_apply(lp["mlp"], x, cfg.activation)
        return (h, sk_full, sv_full), (ck.astype(cache_dtype),
                                       cv.astype(cache_dtype))

    (h, sk, sv), (ck, cv) = jax.lax.scan(
        body, (h, state["self_k"], state["self_v"]),
        (params["dec_layers"], jnp.arange(cfg.num_layers)))
    h = _ln(h, params["dec_final_norm"], cfg.norm_eps)
    logits = h[:, -1] @ params["embed"].T
    return logits, {"self_k": sk, "self_v": sv, "cross_k": ck, "cross_v": cv}, S


def _cross_decode(lp, x, dims, ck, cv):
    """Cross-attn for one query token against cached encoder k/v."""
    q = jnp.einsum("bsd,dnh->bsnh", x, lp["cross"]["wq"]) + lp["cross"]["bq"]
    B = x.shape[0]
    q = q.reshape(B, 1, dims.n_kv, dims.q_per_group, -1)
    scale = 1.0 / jnp.sqrt(jnp.float32(q.shape[-1]))
    scores = jnp.einsum("bsgqh,btgh->bgqst", q, ck.astype(q.dtype),
                        preferred_element_type=jnp.float32) * scale
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bgqst,btgh->bsgqh", probs.astype(cv.dtype), cv)
    from repro.models.attention import _mask_pad_heads
    ctx = _mask_pad_heads(ctx, dims)
    ctx = ctx.reshape(B, 1, dims.n_q, -1)
    return jnp.einsum("bsnh,nhd->bsd", ctx, lp["cross"]["wo"])


def encdec_decode(params, state, tokens, pos, cfg, dims, *, shard_fn=None):
    """One decode step. Returns (logits (B,V), state)."""
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 1:  # per-sequence positions (continuous batching)
        pe = params["dec_pos"][pos][:, None]
    else:
        pe = jax.lax.dynamic_slice_in_dim(params["dec_pos"], pos, 1, 0)[None]
    h = params["embed"][tokens] + pe

    def body(carry, xs):
        h, sk_full, sv_full = carry
        lp, ck, cv, idx = xs
        x = _ln(h, lp["attn_norm"], cfg.norm_eps)
        q, k_new, v_new = attn.project_decode_qkv(lp["attn"], x, dims, pos,
                                                  0.0)
        kc = jax.lax.dynamic_index_in_dim(sk_full, idx, 0, False)
        vc = jax.lax.dynamic_index_in_dim(sv_full, idx, 0, False)
        kc, vc = attn.write_kv(kc, vc, k_new, v_new, pos)
        sk_full = jax.lax.dynamic_update_index_in_dim(sk_full, kc, idx, 0)
        sv_full = jax.lax.dynamic_update_index_in_dim(sv_full, vc, idx, 0)
        h = h + attn.decode_attend(lp["attn"], q, kc, vc, pos, dims)
        x = _ln(h, lp["cross_norm"], cfg.norm_eps)
        h = h + _cross_decode(lp, x, dims, ck, cv)
        x = _ln(h, lp["ffn_norm"], cfg.norm_eps)
        h = h + mlp_apply(lp["mlp"], x, cfg.activation)
        return (h, sk_full, sv_full), None

    (h, sk, sv), _ = jax.lax.scan(
        body, (h, state["self_k"], state["self_v"]),
        (params["dec_layers"], state["cross_k"], state["cross_v"],
         jnp.arange(cfg.num_layers)))
    h = _ln(h, params["dec_final_norm"], cfg.norm_eps)
    logits = h[:, 0] @ params["embed"].T
    return logits, {"self_k": sk, "self_v": sv,
                    "cross_k": state["cross_k"], "cross_v": state["cross_v"]}
