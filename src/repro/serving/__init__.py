from repro.serving.engine import ClusterFrontend, ReplicaEngine, Request  # noqa: F401
