from repro.serving.elastic import ElasticClusterFrontend  # noqa: F401
from repro.serving.engine import (  # noqa: F401
    ClusterFrontend, FleetGroup, ReplicaEngine, Request, TieredQueue,
    normalize_fractions, pow2_bucket, total_prefill_traces,
    total_serve_traces,
)
