from repro.serving.elastic import (  # noqa: F401
    ChaosSchedule, ElasticClusterFrontend, RequestLedger,
)
from repro.serving.engine import (  # noqa: F401
    ClusterFrontend, FleetGroup, ReplicaEngine, Request, TieredQueue,
    normalize_fractions, pow2_bucket, total_prefill_traces,
    total_serve_traces,
)
