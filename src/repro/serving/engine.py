"""Request-level serving engine: continuous batching over real model forwards.

``ReplicaEngine`` runs one model replica: slot-based KV/state pool, per-slot
positions (the vector-``pos`` decode path), admit-on-free-slot, greedy
sampling, retire-on-EOS/max-tokens. Prompts are right-padded to power-of-two
length buckets and admitted in batched prefill calls, so the jit'd prefill
compiles O(log max_seq · log max_batch) times total instead of once per
distinct prompt length (``prefill_traces`` counts actual retraces). Padded
prefill is exact for dense/ssm/hybrid: causal attention masks trailing pads
and the SSM path zeroes dt at pad positions (see
``models.ssd.mamba2_forward``). MoE buckets too but is exact only when no
expert-capacity drops occur (capacity scales with the padded length).
Prompts longer than ``max_seq - 1`` are truncated to their last
``max_seq - 1`` tokens at admission (the KV pool can never overflow).

**Fleet-batched decode.** Slot bookkeeping (the ``Request`` objects, host
``pos``/``last_tok`` mirrors, queues, clocks) lives on the engine; the device
cache may live either on the engine (standalone) or stacked along a leading
fleet axis inside a ``FleetGroup`` shared by every replica of the same
``(model, params, max_batch, max_seq, cache_dtype)``. A fleet group advances
*all* member replicas with ONE jitted ``fleet_decode`` dispatch per tick:
greedy argmax and per-slot retire decisions (max-tokens / EOS / cache-full)
are fused into the jitted function and synced back as a single small
``(fleet, batch)`` int/bool array pair — instead of one dispatch plus
per-slot ``int()`` syncs per replica. Membership survives scale-up, drain
and failure by stacking/unstacking cache rows (capacity grows in power-of-two
steps so fleet-size churn retraces O(log F) times, and removed rows are
backfilled swap-style in one device op).

``ReplicaEngine.step()`` remains the standalone per-replica path (exact-length
vlm/audio admission, heterogeneous ``max_seq``) and is the parity oracle for
the fleet path.

``cache_dtype`` accepts the string ``"int8"`` for dense/moe/vlm replicas:
the KV pool is then stored int8 with per-(token, head) f32 absmax scales
(``repro.serving.kv_quant``), roughly 3.6x the slot capacity of an fp32 pool
for the same bytes.

``ClusterFrontend`` stitches several replicas together behind a balancer
policy — the live counterpart of the fluid simulator. The node-structured
elastic frontend that plugs into the unified control plane lives in
``repro.serving.elastic``.
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model

# families whose prefill accepts per-row ``lengths`` (bucketed prompts are
# exact). audio prefill is driven by encoder frames and stays exact-length;
# vlm requests carry patch-embed extras, which take the single-admit path
# below (batching per-request extras is future work).
_BUCKET_FAMILIES = ("dense", "moe", "ssm", "hybrid")


def pow2_bucket(n: int, lo: int = 1) -> int:
    """Smallest power of two >= n (and >= lo)."""
    b = lo
    while b < n:
        b <<= 1
    return b


class _ServeKernels:
    """Shared jit'd prefill/decode for one (model, max_seq, cache_dtype):
    replicas of the same model reuse compiled code instead of re-jitting on
    every cold start (a scale-up would otherwise stall the tick loop on XLA
    compilation of identical shapes). ``traces`` counts actual prefill
    compilations across every replica that shares this object. ``fleet`` /
    ``fleet_masked`` advance a whole stacked fleet of replicas in one
    dispatch with sampling and retire decisions fused on device (the masked
    variant leaves non-stepping rows' cache untouched, for heterogeneous
    replica speeds)."""
    __slots__ = ("prefill", "decode", "fleet", "fleet_masked", "traces")


def _dtype_name(cache_dtype) -> str:
    return cache_dtype if isinstance(cache_dtype, str) else \
        np.dtype(cache_dtype).name


def get_serve_kernels(model: Model, max_seq: int, cache_dtype) -> _ServeKernels:
    # The cache lives on the Model instance (not a module global) so compiled
    # executables are reclaimed with the model instead of pinned forever.
    cache = getattr(model, "_serve_kernels", None)
    if cache is None:
        cache = {}
        object.__setattr__(model, "_serve_kernels", cache)  # frozen dataclass
    key = (max_seq, _dtype_name(cache_dtype))
    k = cache.get(key)
    if k is not None:
        return k
    k = _ServeKernels()
    k.traces = 0

    def _prefill_fn(p, batch):
        k.traces += 1              # runs at trace time only
        return model.prefill(p, batch, cache_len=max_seq,
                             cache_dtype=cache_dtype)

    def _fleet_fn(p, slab, toks, pos, rem, eos, active):
        """One dispatch for a stacked fleet. slab: cache pytree with a
        leading fleet axis; toks/pos/rem/eos/active: (F, B). Returns the
        next greedy token per slot, the fused retire mask, and the advanced
        slab. The retire rule is the exact device twin of the host rule in
        ``ReplicaEngine.finish_step``: after appending this token a slot is
        done when it reached max_new_tokens (rem <= 1), emitted EOS, or its
        next write index would hit the end of the cache."""
        logits, new_slab = jax.vmap(
            lambda c, t, q: model.decode(p, c, t, q))(slab, toks[..., None],
                                                      pos)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        done = active & ((rem <= 1) | (nxt == eos)
                         | (pos + 1 >= max_seq - 1))
        return nxt, done, new_slab

    def _fleet_masked_fn(p, slab, toks, pos, rem, eos, active, rows):
        """Fleet dispatch where only ``rows`` (F,) advance — other rows keep
        their cache bit-for-bit (an SSM state must not step twice)."""
        nxt, done, new_slab = _fleet_fn(p, slab, toks, pos, rem, eos, active)

        def sel(old, new):
            m = rows.reshape((rows.shape[0],) + (1,) * (old.ndim - 1))
            return jnp.where(m, new, old)

        return nxt, done & rows[:, None], jax.tree.map(sel, slab, new_slab)

    k.prefill = jax.jit(_prefill_fn)
    k.decode = jax.jit(lambda p, st, tok, pos: model.decode(p, st, tok, pos))
    # the fleet slab is owned exclusively by the FleetGroup (member engines
    # hold cache=None), so the input buffer can be donated: XLA updates the
    # KV slab in place instead of copying it every dispatch.
    k.fleet = jax.jit(_fleet_fn, donate_argnums=(1,))
    k.fleet_masked = jax.jit(_fleet_masked_fn, donate_argnums=(1,))
    cache[key] = k
    return k


class FleetGroup:
    """Stacks the device state of same-shape replicas along a leading fleet
    axis and advances every member with one jitted dispatch per tick.

    The slab capacity grows in power-of-two steps (O(log F) retraces as the
    fleet scales 1 -> F); spare rows decode throwaway state and are fully
    overwritten when a replica joins, so they need no masking. Removing a
    member (drain retire / failure) backfills its row with the last member's
    row in a single device op, so live rows stay dense."""

    def __init__(self, model: Model, params, *, max_batch: int, max_seq: int,
                 cache_dtype=jnp.float32):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.cache_dtype = cache_dtype
        self.members: list = []     # ReplicaEngine; fleet row == list index
        self.cap = 0                # allocated fleet rows (power of two)
        self.slab = None            # cache pytree, leaves (cap, *per_replica)
        self.dispatches = 0         # jitted fleet decode dispatches issued
        self._kernels = get_serve_kernels(model, max_seq, cache_dtype)

    def __len__(self) -> int:
        return len(self.members)

    # -------------------------------------------------------------- members
    def add(self, eng: "ReplicaEngine"):
        """Stack ``eng``'s device cache into the slab (any in-flight slot
        state rides along, so replicas can join mid-generation)."""
        assert eng._fleet is None, "engine already belongs to a fleet"
        row = len(self.members)
        if row >= self.cap:
            new_cap = pow2_bucket(row + 1)
            if self.slab is None:
                self.slab = jax.tree.map(
                    lambda c: jnp.zeros((new_cap,) + c.shape, c.dtype),
                    eng.cache)
            else:
                self.slab = jax.tree.map(
                    lambda s: jnp.concatenate(
                        [s, jnp.zeros((new_cap - self.cap,) + s.shape[1:],
                                      s.dtype)]), self.slab)
            self.cap = new_cap
        self.slab = jax.tree.map(lambda s, c: s.at[row].set(c),
                                 self.slab, eng.cache)
        eng.cache = None
        eng._fleet, eng._fleet_row = self, row
        self.members.append(eng)

    def remove(self, eng: "ReplicaEngine", restore: bool = True):
        """Detach ``eng``; with ``restore`` its cache row is unstacked back
        onto the engine (drain hand-back), otherwise dropped (failure)."""
        row = eng._fleet_row
        assert eng._fleet is self and self.members[row] is eng
        if restore:
            eng.cache = jax.tree.map(lambda s: s[row], self.slab)
        last = self.members.pop()
        if last is not eng:          # backfill the hole with the last row
            self.slab = jax.tree.map(
                lambda s: s.at[row].set(s[len(self.members)]), self.slab)
            last._fleet_row = row
            self.members[row] = last
        eng._fleet, eng._fleet_row = None, -1

    # -------------------------------------------------------------- slots
    def write_slot(self, f: int, slot: int, small_state, row: int):
        """Copy prefill output row ``row`` into member ``f``'s slot."""
        self.slab = jax.tree.map(
            lambda s, sm: s.at[f, :, slot].set(sm[:, row]),
            self.slab, small_state)

    # -------------------------------------------------------------- decode
    def decode_round(self, stepping_ids=None) -> list:
        """One fused decode step for every member (or the ``id(engine)``
        subset in ``stepping_ids``). Returns finished requests. The whole
        round costs one jitted dispatch and one small (F, B) host sync."""
        movers = [e for e in self.members
                  if stepping_ids is None or id(e) in stepping_ids]
        if not movers or not any(e.n_active for e in movers):
            return []
        cap, B = self.cap, self.max_batch
        toks = np.zeros((cap, B), np.int32)
        pos = np.zeros((cap, B), np.int32)
        rem = np.ones((cap, B), np.int32)
        eos = np.full((cap, B), -1, np.int32)
        active = np.zeros((cap, B), bool)
        rows = np.zeros((cap,), bool)
        for e in movers:
            f = e._fleet_row
            rows[f] = True
            toks[f] = e.last_tok
            pos[f] = e.pos
            for s, req in enumerate(e.slots):
                if req is not None:
                    active[f, s] = True
                    rem[f, s] = req.max_new_tokens - len(req.output)
                    eos[f, s] = req.eos_id
        if len(movers) == len(self.members):
            nxt, done, self.slab = self._kernels.fleet(
                self.params, self.slab, toks, pos, rem, eos, active)
        else:
            nxt, done, self.slab = self._kernels.fleet_masked(
                self.params, self.slab, toks, pos, rem, eos, active, rows)
        self.dispatches += 1
        nxt, done = jax.device_get((nxt, done))   # ONE small host sync
        nxt, done = np.asarray(nxt), np.asarray(done)
        finished: list = []
        for e in movers:
            f = e._fleet_row
            finished.extend(e.commit_decode(nxt[f], done[f]))
        return finished


def total_prefill_traces(engines) -> int:
    """Global prefill compile count, deduped across replicas that share
    kernels (each replica reports its shared counter)."""
    seen = {id(e._kernels): e._kernels.traces for e in engines}
    return sum(seen.values())


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list
    max_new_tokens: int = 16
    eos_id: int = -1               # -1: never stop early
    arrival: float = 0.0
    # filled by the engine
    output: list = dataclasses.field(default_factory=list)
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None

    @property
    def done(self) -> bool:
        return self.finish_time is not None

    def reset_progress(self):
        """Forget generation progress (replica failure -> re-queue)."""
        self.output = []
        self.first_token_time = None
        self.finish_time = None


class ReplicaEngine:
    def __init__(self, model: Model, params, *, max_batch: int = 4,
                 max_seq: int = 256, cache_dtype=jnp.float32, rid: int = 0,
                 speed: float = 1.0, min_bucket: int = 8,
                 bucket_prompts: Optional[bool] = None):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.cache_dtype = cache_dtype
        self.rid = rid
        self.speed = speed            # relative decode speed (hetero hardware)
        self.min_bucket = min_bucket
        self.draining = False         # drained replicas admit nothing new
        self.cache = model.init_serve_state(max_batch, max_seq, cache_dtype)
        self.pos = np.zeros(max_batch, np.int32)       # next cache index
        self.last_tok = np.zeros(max_batch, np.int32)
        self.slots: list = [None] * max_batch
        self.queue: deque = deque()
        self.clock = 0.0
        self.steps = 0
        self._fleet: Optional[FleetGroup] = None   # device state owner when
        self._fleet_row = -1                       # fleet-batched
        if bucket_prompts is None:
            bucket_prompts = model.cfg.family in _BUCKET_FAMILIES
        self.bucket_prompts = bucket_prompts
        self._kernels = get_serve_kernels(model, max_seq, cache_dtype)
        self._prefill = self._kernels.prefill
        self._decode = self._kernels.decode

    @property
    def fleet_key(self) -> tuple:
        """Replicas with equal keys can share one stacked fleet slab."""
        return (id(self.model), id(self.params), self.max_batch,
                self.max_seq, _dtype_name(self.cache_dtype))

    @property
    def prefill_traces(self) -> int:
        """Prefill compilations of this replica's (shared) kernels."""
        return self._kernels.traces

    # ----------------------------------------------------------------- load
    @property
    def n_active(self) -> int:
        return sum(s is not None for s in self.slots)

    @property
    def load(self) -> int:
        return self.n_active + len(self.queue)

    def submit(self, req: Request):
        self.queue.append(req)

    def evacuate(self) -> list:
        """Failure path: pull every in-flight + queued request off this
        replica (generation progress is lost) so the caller can re-queue."""
        lost = [r for r in self.slots if r is not None] + list(self.queue)
        self.slots = [None] * self.max_batch
        self.queue.clear()
        for r in lost:
            r.reset_progress()
        return lost

    # ------------------------------------------------------------- plumbing
    def _insert_slot(self, slot: int, small_state, row: int, prompt_len: int,
                     first_tok: int, req: Request):
        if self._fleet is not None:
            self._fleet.write_slot(self._fleet_row, slot, small_state, row)
        else:
            def put(big, small):
                return big.at[:, slot].set(small[:, row])
            self.cache = jax.tree.map(put, self.cache, small_state)
        self.pos[slot] = prompt_len
        self.last_tok[slot] = first_tok
        self.slots[slot] = req

    def _admit_batch(self, slots: list, reqs: list, finished: list,
                     bucketed: bool):
        if bucketed:
            # a prompt longer than the KV pool keeps only its last
            # max_seq - 1 tokens (one slot must remain for generation);
            # copying the raw prompt would overflow the token buffer.
            prompts = [r.prompt[-(self.max_seq - 1):] for r in reqs]
            lens = [len(p) for p in prompts]
            sb = min(pow2_bucket(max(lens), self.min_bucket), self.max_seq)
            kb = pow2_bucket(len(reqs))
            toks = np.zeros((kb, sb), np.int32)
            lengths = np.ones(kb, np.int32)    # pad rows: length-1 dummies
            for i, p in enumerate(prompts):
                toks[i, :len(p)] = p
                lengths[i] = len(p)
            batch = {"tokens": jnp.asarray(toks),
                     "lengths": jnp.asarray(lengths)}
            logits, small, plen = self._prefill(self.params, batch)
            plen = np.asarray(plen)
        else:
            req = reqs[0]
            # same overflow guard as the bucketed path: the KV pool holds
            # max_seq entries and one must remain for generation
            prompt = req.prompt[-(self.max_seq - 1):]
            batch = {"tokens": jnp.asarray([prompt], jnp.int32)}
            extras = getattr(req, "extras", None)
            if extras:
                batch.update({k: jnp.asarray(v) for k, v in extras.items()})
            logits, small, plen = self._prefill(self.params, batch)
            plen = np.full(1, int(plen), np.int32)
        first = np.asarray(jnp.argmax(logits, axis=-1))
        for i, (slot, req) in enumerate(zip(slots, reqs)):
            tok = int(first[i])
            req.output.append(tok)
            req.first_token_time = self.clock
            if len(req.output) >= req.max_new_tokens or tok == req.eos_id:
                req.finish_time = self.clock
                finished.append(req)
                continue
            self._insert_slot(slot, small, i, int(plen[i]), tok, req)

    def _admit(self, finished: list):
        if self.draining:
            return
        free = [i for i in range(self.max_batch) if self.slots[i] is None]
        while free and self.queue:
            head_has_extras = getattr(self.queue[0], "extras", None)
            if not self.bucket_prompts or head_has_extras:
                # exact-length single admit (audio / extras-carrying requests)
                self._admit_batch([free.pop(0)], [self.queue.popleft()],
                                  finished, bucketed=False)
                continue
            group = []
            while (self.queue and len(group) < len(free)
                   and not getattr(self.queue[0], "extras", None)):
                group.append(self.queue.popleft())
            self._admit_batch([free.pop(0) for _ in group], group,
                              finished, bucketed=True)

    def begin_step(self, dt: float = 1.0) -> list:
        """Tick phase 1: advance the clock and admit from the queue. Returns
        requests that completed at prefill time. The decode phase follows via
        ``finish_step`` (standalone) or one ``FleetGroup.decode_round``."""
        self.clock += dt
        finished: list = []
        self._admit(finished)
        return finished

    def finish_step(self) -> list:
        """Tick phase 2: one decode step for all active slots."""
        if self.n_active == 0:
            return []
        if self._fleet is not None:    # device state lives in the fleet slab
            return self._fleet.decode_round({id(self)})
        toks = jnp.asarray(self.last_tok[:, None])
        pos = jnp.asarray(self.pos)
        logits, self.cache = self._decode(self.params, self.cache, toks, pos)
        self.steps += 1
        finished: list = []
        next_toks = np.asarray(jnp.argmax(logits, axis=-1))
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            tok = int(next_toks[slot])
            req.output.append(tok)
            self.pos[slot] += 1
            self.last_tok[slot] = tok
            if (len(req.output) >= req.max_new_tokens or tok == req.eos_id
                    or self.pos[slot] >= self.max_seq - 1):
                req.finish_time = self.clock
                finished.append(req)
                self.slots[slot] = None
        return finished

    def commit_decode(self, next_toks: np.ndarray, done: np.ndarray) -> list:
        """Apply one fleet decode result to the host-side slot bookkeeping.
        ``next_toks``/``done`` are this engine's (B,) rows of the batched
        sync; the retire mask was already computed on device."""
        finished: list = []
        stepped = False
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            stepped = True
            tok = int(next_toks[slot])
            req.output.append(tok)
            self.pos[slot] += 1
            self.last_tok[slot] = tok
            if done[slot]:
                req.finish_time = self.clock
                finished.append(req)
                self.slots[slot] = None
        if stepped:
            self.steps += 1
        return finished

    def step(self, dt: float = 1.0) -> list:
        """Admit + one decode step for all active slots. Returns finished
        (including requests that completed at prefill time)."""
        finished = self.begin_step(dt)
        finished.extend(self.finish_step())
        return finished


def normalize_fractions(fr: np.ndarray, mask: Optional[np.ndarray] = None
                        ) -> np.ndarray:
    """Simplex-normalize routing fractions with a uniform fallback — the
    numpy twin of ``core.balancer._mask_normalize``. Non-finite or negative
    entries are zeroed; a zero/NaN sum falls back to uniform over the mask."""
    fr = np.asarray(fr, np.float64)
    fr = np.where(np.isfinite(fr) & (fr > 0.0), fr, 0.0)
    if mask is not None:
        fr = fr * (np.asarray(mask, np.float64) > 0.0)
    s = fr.sum()
    if s <= 1e-12:
        if mask is not None and (np.asarray(mask) > 0).any():
            m = (np.asarray(mask) > 0).astype(np.float64)
            return m / m.sum()
        return np.full(fr.shape[0], 1.0 / fr.shape[0])
    return fr / s


class ClusterFrontend:
    """Routes requests to replicas via balancer fractions (or queue depth).

    ``fleet_batch=True`` stacks same-shape replicas into ``FleetGroup``s so a
    ``step`` issues one decode dispatch per group instead of one per replica
    (replicas that can't stack — different shapes — keep stepping solo)."""

    def __init__(self, replicas: list, policy: str = "lc",
                 fractions_fn=None, seed: int = 0, fleet_batch: bool = False):
        self.replicas = replicas
        self.policy = policy
        self.fractions_fn = fractions_fn
        self.rng = np.random.default_rng(seed)
        self.pending: deque = deque()
        self.finished: list = []
        self._rr = itertools.cycle(range(len(replicas)))
        self.fleets: dict = {}
        if fleet_batch:
            for eng in replicas:
                g = self.fleets.get(eng.fleet_key)
                if g is None:
                    g = self.fleets[eng.fleet_key] = FleetGroup(
                        eng.model, eng.params, max_batch=eng.max_batch,
                        max_seq=eng.max_seq, cache_dtype=eng.cache_dtype)
                g.add(eng)

    def submit(self, req: Request):
        self.pending.append(req)

    def _route(self):
        while self.pending:
            req = self.pending.popleft()
            if self.policy == "rr":
                idx = next(self._rr)
            elif self.policy == "lc":
                loads = [r.load for r in self.replicas]
                idx = int(np.argmin(loads))
            elif self.policy == "fractions":
                fr = normalize_fractions(self.fractions_fn(self))
                idx = int(self.rng.choice(len(self.replicas), p=fr))
            else:
                raise ValueError(self.policy)
            self.replicas[idx].submit(req)

    def step(self, dt: float = 1.0):
        self._route()
        if not self.fleets:
            for r in self.replicas:
                self.finished.extend(r.step(dt))
            return
        for r in self.replicas:
            self.finished.extend(r.begin_step(dt))
        for g in self.fleets.values():
            self.finished.extend(g.decode_round())
        for r in self.replicas:          # replicas outside any fleet
            if r._fleet is None:
                self.finished.extend(r.finish_step())

    def run_until_drained(self, max_steps: int = 10_000):
        for _ in range(max_steps):
            self.step()
            if not self.pending and all(r.load == 0 for r in self.replicas):
                return
        raise RuntimeError("engine did not drain")
