"""Request-level serving engine: continuous batching over real model forwards.

``ReplicaEngine`` runs one model replica: slot-based KV/state pool, per-slot
positions (the vector-``pos`` decode path), admit-on-free-slot, greedy
sampling, retire-on-EOS/max-tokens. Prompts are right-padded to power-of-two
length buckets and admitted in batched prefill calls, so the jit'd prefill
compiles O(log max_seq · log max_batch) times total instead of once per
distinct prompt length (``prefill_traces`` counts actual retraces). Padded
prefill is exact for dense/ssm/hybrid: causal attention masks trailing pads
and the SSM path zeroes dt at pad positions (see
``models.ssd.mamba2_forward``). MoE replicas default to the exact-length
single-admit path instead: expert-capacity routing sees the pad tokens, so a
padded bucket is exact only when no capacity drops occur (capacity scales
with the padded length and the whole admit batch — a drop pattern the
per-prompt oracle never sees). Opt back into buckets with
``bucket_prompts=True`` when approximate routing is acceptable.
Prompts longer than ``max_seq - 1`` are truncated to their last
``max_seq - 1`` tokens at admission (the KV pool can never overflow).

**SLO tiers.** Each replica's pending queue is a ``TieredQueue``: one FIFO
per priority class (``workload.trace.TierSet``), drained in weighted-deficit
round-robin order — the top-weight tier admits first, lower-weight tiers are
guaranteed a bounded admission share so batch work never starves. Tiering
only reorders *which* requests enter the admission plans below; the dispatch
structure (one fleet prefill per distinct bucket shape, one fleet decode per
tick) is untouched, and the default single-tier configuration is
bit-identical to the untiered scheduler. Two guards keep long low-tier
prefills from degrading premium latency: a lower-tier chunk start yields the
last free slot while higher-priority work waits, and under pressure at most
one below-decoding-tier chunk cursor advances per tick (see
``plan_admission`` / ``_chunk_due``).

**Admission pipeline** (bucket → chunk → fleet slab). Each tick every
stepping replica *plans* admission from its queue without dispatching
(``plan_admission``): chunk-eligible prompts (longer than ``chunk_len``,
dense/ssm/hybrid, f32 cache) reserve a slot and a chunk cursor; requests
carrying per-request extras (vlm patches, audio frames) become exact-length
single admits; everything else groups into one pow2 ``(bucket_batch,
bucket_len)`` prefill per replica. Execution then depends on the mode:

  * **standalone** — the replica dispatches its own bucketed prefill and one
    batched chunk step (``prefill_dispatches`` counts jitted admission
    dispatches per replica);
  * **fleet-batched prefill** — a ``FleetGroup`` gathers every member's
    bucketed groups, flattens rows of the same pow2 length bucket across ALL
    members and runs ONE jitted ``fleet_prefill`` per *distinct bucket
    shape* per tick: the batched prefill writes each admit row's KV/state
    directly into the donated fleet slab on device (no host-side
    ``write_slot`` copies), and all members' due chunk rows advance in ONE
    ``fleet_chunk`` dispatch. Admission cost becomes O(distinct bucket
    shapes) per tick instead of O(replicas);
    ``FleetGroup.prefill_dispatches`` mirrors ``decode_dispatches``.

**Chunked prefill.** Prompts longer than ``chunk_len`` stream in fixed-size
chunks, one per engine step, interleaved with decode rounds: dense chunks
attend at a cache offset over the already-filled prefix
(``models.attention.chunk_prefill_attention``), ssm/hybrid chunks carry the
SSM state and raw conv window across chunks (``mamba2_forward`` with
``init_state``/``conv_state``). A mid-chunk slot is excluded from decode via
the ``hold`` mask fused into the decode kernels (its carried state must not
be advanced by garbage decode steps), so a long prompt admits over
ceil(len/chunk) ticks while decode TBT for the other slots stays one bounded
dispatch per tick. Chunk-by-chunk equals single-shot prefill exactly.

**Fleet-batched decode.** Slot bookkeeping (the ``Request`` objects, host
``pos``/``last_tok`` mirrors, queues, clocks) lives on the engine; the device
cache may live either on the engine (standalone) or stacked along a leading
fleet axis inside a ``FleetGroup`` shared by every replica of the same
``(model, params, max_batch, max_seq, cache_dtype)``. A fleet group advances
*all* member replicas with ONE jitted ``fleet_decode`` dispatch per tick:
greedy argmax and per-slot retire decisions (max-tokens / EOS / cache-full)
are fused into the jitted function and synced back as a single small
``(fleet, batch)`` int/bool array pair — instead of one dispatch plus
per-slot ``int()`` syncs per replica. Membership survives scale-up, drain
and failure by stacking/unstacking cache rows (capacity grows in power-of-two
steps so fleet-size churn retraces O(log F) times, and removed rows are
backfilled swap-style in one device op).

``ReplicaEngine.step()`` remains the standalone per-replica path (exact-length
vlm/audio admission, heterogeneous ``max_seq``) and is the parity oracle for
the fleet path.

``cache_dtype`` accepts the string ``"int8"`` for dense/moe/vlm replicas:
the KV pool is then stored int8 with per-(token, head) f32 absmax scales
(``repro.serving.kv_quant``), roughly 3.6x the slot capacity of an fp32 pool
for the same bytes. Non-f32 caches stay on single-shot prefill (the int8
codec quantizes whole prompts at prefill end, and a bf16 pool would round
the carried chunk state that single-shot keeps unrounded), so ``chunk_len``
is ignored there.

**Async tick contract (overlapped serving).** In async mode (the elastic
frontend's default) the fleet dispatch methods never block on the device:
``decode_round``/``admit_round`` push their work onto the accelerator queue
and record a ``_Pending`` entry — the small device outputs (next tokens,
fused retire mask, stepped mask, prefill first-tokens) plus the host context
captured at dispatch time (engines, slots, requests, clocks). The decode
*operands* (``toks``/``pos``/``rem``/``eos``/``active``) are persistent
device arrays living next to the slab and advanced inside the same jitted
dispatch (``FleetGroup.ops``), so consecutive ticks chain on device without
the host rebuilding or re-uploading operand arrays. All deferred host
bookkeeping is applied at ONE reconcile point per tick
(``FleetGroup.reconcile`` — a single ``jax.device_get`` over every pending
record, counted by ``syncs``): the host work for tick *t* (queues, tiers,
metrics, the control plane's forecast→balance→scale) therefore overlaps the
device computing tick *t*'s decode. What is pending when:

  * a request admitted at tick *t* is *reserved* in its slot immediately
    (occupancy, ``load`` and tier accounting are live) but its first token,
    TTFT stamp and possible finish-at-prefill apply at the reconcile that
    opens tick *t+1*;
  * decode tokens/retires dispatched at tick *t* commit at tick *t+1*'s
    reconcile, stamped with tick *t*'s clock — token streams and finish
    ticks are **bit-identical** to the eager oracle (``async_tick=False``),
    only the host-side observation is one tick late;
  * because retire/slot-free reconciles *before* admission planning, a slot
    freed by tick *t*'s decode is admittable at tick *t+1* — exactly like
    the eager path, so admission lags the device state by **at most one
    tick** under a full slab (and by zero ticks relative to the oracle);
  * membership churn (drain retire, failure, scale-up joins) force-flushes
    pending futures first, so host mirrors are current before rows unstack.

``decode_block=K`` fuses K decode micro-steps into one dispatch via
``lax.scan`` (one ``(K, F, B)`` sync per block — K× fewer dispatches *and*
syncs). A block only auto-engages on ticks with no admissions at all —
fleet prefill/chunk dispatches (``pending``) and eager single admits
(``_admitted``) both veto it — and no chunk cursors. Queued work behind a
*full* slab does not block engagement; the trade is that any admission
landing *inside* the fused window (a retire freeing a slot, or an arrival
finding one) only starts decoding at the window's end — admission-to-
first-decode may lag up to K-1 ticks (plain async K=1 keeps the <= 1-tick
bound). One block counts as K ticks of decode (finish clocks inside the
block are ``dispatch_clock + k``) and the reconcile is deferred until the
block's ticks are spent.

**Fleet-mesh sharding.** A ``FleetGroup`` built with ``mesh=`` (a mesh
carrying a ``fleet`` axis) lays its slab and async operands out
``P('fleet', ...)`` over the N devices while params replicate, so GSPMD
partitions the *same* jitted kernel families row-parallel: F replicas
decode on N devices under the identical one-dispatch/one-sync tick, with
bit-identical streams. Slab capacity stays a multiple of the shard count
(``shards * pow2_bucket(ceil(F/shards))``; pad rows are masked inactive
and invisible to dispatch/retire accounting) and the dense row packing
that churn already maintains doubles as the cross-shard re-balance. See
the ``FleetGroup`` class docstring for the full contract.

``ClusterFrontend`` stitches several replicas together behind a balancer
policy — the live counterpart of the fluid simulator. The node-structured
elastic frontend that plugs into the unified control plane lives in
``repro.serving.elastic``.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import fleet_slab_shardings
from repro.models.model import Model
from repro.workload.trace import DEFAULT_TIERS, TierSet

# families whose prefill accepts per-row ``lengths`` (bucketed prompts are
# exact). moe is deliberately absent: expert capacity scales with the padded
# bucket, so drops can differ from the exact-length oracle (see module
# docstring). audio prefill is driven by encoder frames and stays
# exact-length; vlm requests carry patch-embed extras, which take the
# single-admit path below (batching per-request extras is future work).
_BUCKET_FAMILIES = ("dense", "ssm", "hybrid")
# families with a chunked-prefill continuation kernel (cache-offset attention
# for dense, carried ssm/conv state for ssm/hybrid). moe is excluded by
# default for the same capacity reason as bucketing.
_CHUNK_FAMILIES = ("dense", "ssm", "hybrid")
# kernel variants whose compilations count as prefill retraces (the async
# admission twins included — same shapes, different sync contract)
_PREFILL_VARIANTS = ("prefill", "fleet_prefill", "chunk", "fleet_chunk",
                     "afleet_prefill", "afleet_chunk")


def pow2_bucket(n: int, lo: int = 1) -> int:
    """Smallest power of two >= n (and >= lo)."""
    b = lo
    while b < n:
        b <<= 1
    return b


class _ServeKernels:
    """Shared jit'd prefill/decode for one (model, max_seq, cache_dtype):
    replicas of the same model reuse compiled code instead of re-jitting on
    every cold start (a scale-up would otherwise stall the tick loop on XLA
    compilation of identical shapes). ``trace_counts`` counts actual
    compilations per kernel variant across every replica that shares this
    object — one deduped accounting covering prefill, decode, the fleet
    decode variants and the fleet/chunk prefill variants. ``fleet`` /
    ``fleet_masked`` advance a whole stacked fleet of replicas in one
    dispatch with sampling and retire decisions fused on device (the masked
    variant leaves non-stepping rows' cache untouched, for heterogeneous
    replica speeds); ``fleet_prefill`` / ``fleet_chunk`` are the admission
    twins writing prefill state straight into the fleet slab. The ``afleet*``
    variants are the async twins: decode operands live on device and advance
    inside the dispatch, so the host syncs nothing until the next reconcile
    (``afleet_block`` fuses K micro-steps per dispatch via ``lax.scan``)."""
    __slots__ = ("prefill", "decode", "decode_hold", "fleet", "fleet_hold",
                 "fleet_masked", "fleet_masked_hold", "fleet_prefill",
                 "chunk", "fleet_chunk", "afleet", "afleet_hold",
                 "afleet_masked", "afleet_masked_hold", "afleet_prefill",
                 "afleet_chunk", "afleet_block", "_block_factory",
                 "trace_counts")

    def block_kernel(self, K: int):
        """The K-micro-step fused decode kernel (jitted on demand, cached
        per K)."""
        fn = self.afleet_block.get(K)
        if fn is None:
            fn = self.afleet_block[K] = jax.jit(self._block_factory(K),
                                                donate_argnums=(1, 2))
        return fn

    @property
    def prefill_traces(self) -> int:
        """Compilations of the prefill-side variants (bucketed, fleet,
        chunked) — the retrace-bound currency."""
        return sum(self.trace_counts.get(v, 0) for v in _PREFILL_VARIANTS)

    @property
    def total_traces(self) -> int:
        """Compilations across every serve-kernel variant."""
        return sum(self.trace_counts.values())


def _dtype_name(cache_dtype) -> str:
    return cache_dtype if isinstance(cache_dtype, str) else \
        np.dtype(cache_dtype).name


def _timed_get(owner, arrays):
    """Blocking fetch of device ``arrays``, accounted on ``owner``: bumps
    ``owner.syncs`` and adds the blocked wall time to ``owner.sync_wait``
    (the host-vs-device tick breakdown the serve bench reports)."""
    t0 = time.perf_counter()
    out = jax.device_get(arrays)
    owner.sync_wait += time.perf_counter() - t0
    owner.syncs += 1
    return out


def _init_ops(cap: int, batch: int) -> dict:
    """Fresh device-resident decode operands for an async fleet slab:
    per-slot next-token / cache-position / remaining-budget / eos-id /
    active-mask arrays, (cap, batch) each. Inactive rows are never read
    through (``active`` masks them), so zero init is fine."""
    return {
        "toks": jnp.zeros((cap, batch), jnp.int32),
        "pos": jnp.zeros((cap, batch), jnp.int32),
        "rem": jnp.ones((cap, batch), jnp.int32),
        "eos": jnp.full((cap, batch), -1, jnp.int32),
        "active": jnp.zeros((cap, batch), bool),
    }


@dataclasses.dataclass
class _Pending:
    """A dispatched device result not yet synced: ``arrays`` are the small
    device outputs to fetch at the next reconcile, ``meta`` the host
    bookkeeping context captured at dispatch time (engines, slots, requests
    and the dispatch-time clocks that stamp TTFT/finish)."""
    kind: str       # "decode" | "block" | "prefill" | "chunk"
    arrays: object
    meta: list


def get_serve_kernels(model: Model, max_seq: int, cache_dtype,
                      attn_backend: str = "einsum") -> _ServeKernels:
    # The cache lives on the Model instance (not a module global) so compiled
    # executables are reclaimed with the model instead of pinned forever.
    cache = getattr(model, "_serve_kernels", None)
    if cache is None:
        cache = {}
        object.__setattr__(model, "_serve_kernels", cache)  # frozen dataclass
    key = (max_seq, _dtype_name(cache_dtype), attn_backend)
    k = cache.get(key)
    if k is not None:
        return k
    k = _ServeKernels()
    k.trace_counts = {}

    def _count(name):
        # runs at trace time only (python side effect inside the traced fn)
        k.trace_counts[name] = k.trace_counts.get(name, 0) + 1

    def _prefill_fn(p, batch):
        _count("prefill")
        return model.prefill(p, batch, cache_len=max_seq,
                             cache_dtype=cache_dtype)

    def _decode(p, st, tok, pos):
        return model.decode(p, st, tok, pos, attn_backend=attn_backend)

    def _decode_fn(p, st, tok, pos):
        _count("decode")
        return _decode(p, st, tok, pos)

    def _decode_hold_fn(p, st, tok, pos, hslots):
        """Standalone decode that leaves the ``hslots`` slots' state
        untouched (mid-chunk-prefill slots must not be advanced by garbage
        tokens). The held slots are gathered before the step and scattered
        back after — touching K slot rows instead of select-copying the
        whole pool (pad entries are out-of-bounds: gather clips, scatter
        drops)."""
        _count("decode_hold")
        held = jax.tree.map(lambda t: jnp.take(t, hslots, axis=1), st)
        logits, new = _decode(p, st, tok, pos)
        new = jax.tree.map(
            lambda t, h: t.at[:, hslots].set(h, mode="drop"), new, held)
        return logits, new

    def _fleet_core(p, slab, toks, pos, rem, eos, active, rows=None,
                    held=None):
        """One dispatch for a stacked fleet. slab: cache pytree with a
        leading fleet axis; toks/pos/rem/eos/active: (F, B). Returns the
        next greedy token per slot, the fused retire mask, and the advanced
        slab. The retire rule is the exact device twin of the host rule in
        ``ReplicaEngine.finish_step``: after appending this token a slot is
        done when it reached max_new_tokens (rem <= 1), emitted EOS, or its
        next write index would hit the end of the cache. ``held``
        ((hrows, hslots) index vectors for mid-chunk-prefill slots) keeps
        those slots' state bit-for-bit by gather-before / scatter-after —
        touching K slot rows, NOT select-copying the whole slab; with
        ``rows`` (F,) only those fleet rows advance at all (hetero speeds).
        Each mask combination is its own kernel variant so the common
        all-decode path keeps the pure donated in-place update."""
        if held is not None:
            hrows, hslots = held
            kept = jax.tree.map(lambda s: s[hrows, :, hslots], slab)
        logits, new_slab = jax.vmap(
            lambda c, t, q: _decode(p, c, t, q))(slab, toks[..., None], pos)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        done = active & ((rem <= 1) | (nxt == eos)
                         | (pos + 1 >= max_seq - 1))

        if rows is not None:
            def sel(old, new):
                rm = rows.reshape((rows.shape[0],) + (1,) * (old.ndim - 1))
                return jnp.where(rm, new, old)

            new_slab = jax.tree.map(sel, slab, new_slab)
            done = done & rows[:, None]
        if held is not None:
            new_slab = jax.tree.map(
                lambda s, h: s.at[hrows, :, hslots].set(h, mode="drop"),
                new_slab, kept)
        return nxt, done, new_slab

    def _fleet_fn(p, slab, toks, pos, rem, eos, active):
        _count("fleet")
        return _fleet_core(p, slab, toks, pos, rem, eos, active)

    def _fleet_hold_fn(p, slab, toks, pos, rem, eos, active, hrows, hslots):
        _count("fleet_hold")
        return _fleet_core(p, slab, toks, pos, rem, eos, active,
                           held=(hrows, hslots))

    def _fleet_masked_fn(p, slab, toks, pos, rem, eos, active, rows):
        """Fleet dispatch where only ``rows`` (F,) advance — other rows keep
        their cache bit-for-bit (an SSM state must not step twice)."""
        _count("fleet_masked")
        return _fleet_core(p, slab, toks, pos, rem, eos, active, rows=rows)

    def _fleet_masked_hold_fn(p, slab, toks, pos, rem, eos, active, rows,
                              hrows, hslots):
        _count("fleet_masked_hold")
        return _fleet_core(p, slab, toks, pos, rem, eos, active, rows=rows,
                           held=(hrows, hslots))

    # ------------------------------------------------------ async variants
    def _afleet_core(p, slab, ops, rows=None, held=None):
        """One async decode micro-step: the operands live on device (``ops``)
        and advance inside the dispatch — the device twin of
        ``ReplicaEngine.apply_decode``. Returns the small sync payload
        (next token, fused retire mask, stepped mask) plus the advanced
        slab and operands; nothing blocks on the host."""
        nxt, done, slab = _fleet_core(p, slab, ops["toks"], ops["pos"],
                                      ops["rem"], ops["eos"], ops["active"],
                                      rows=rows, held=held)
        stepped = ops["active"] if rows is None else \
            ops["active"] & rows[:, None]
        inc = stepped.astype(jnp.int32)
        ops = {
            "toks": jnp.where(stepped, nxt, ops["toks"]),
            "pos": ops["pos"] + inc,
            "rem": ops["rem"] - inc,
            "eos": ops["eos"],
            "active": ops["active"] & ~done,
        }
        return nxt, done, stepped, slab, ops

    def _afleet_fn(p, slab, ops):
        _count("afleet")
        return _afleet_core(p, slab, ops)

    def _afleet_hold_fn(p, slab, ops, hrows, hslots):
        _count("afleet_hold")
        return _afleet_core(p, slab, ops, held=(hrows, hslots))

    def _afleet_masked_fn(p, slab, ops, rows):
        _count("afleet_masked")
        return _afleet_core(p, slab, ops, rows=rows)

    def _afleet_masked_hold_fn(p, slab, ops, rows, hrows, hslots):
        _count("afleet_masked_hold")
        return _afleet_core(p, slab, ops, rows=rows, held=(hrows, hslots))

    def _make_block_fn(K):
        def _afleet_block_fn(p, slab, ops):
            """K fused decode micro-steps in ONE dispatch: ``lax.scan`` over
            the async core (the retire rule is already the device twin of
            the host rule, so EOS/max-tokens/cache-full compose exactly —
            a slot retired at micro-step k is inactive for k+1..K-1). Syncs
            one (K, F, B) token/retire/stepped block."""
            _count("afleet_block")

            def micro(carry, _):
                slab, ops = carry
                nxt, done, stepped, slab, ops = _afleet_core(p, slab, ops)
                return (slab, ops), (nxt, done, stepped)

            (slab, ops), (nxt, done, stepped) = jax.lax.scan(
                micro, (slab, ops), None, length=K)
            return nxt, done, stepped, slab, ops
        return _afleet_block_fn

    def _ops_admit(ops, rows, slots, first, plen, rems, eoss):
        """Device twin of ``commit_admit``: register admitted rows in the
        persistent operands. A request that finishes at prefill time
        (``rem < 1`` i.e. max_new_tokens <= 1, or first token == EOS) never
        activates; the host learns the same outcome at reconcile."""
        return {
            "toks": ops["toks"].at[rows, slots].set(first, mode="drop"),
            "pos": ops["pos"].at[rows, slots].set(plen, mode="drop"),
            "rem": ops["rem"].at[rows, slots].set(rems, mode="drop"),
            "eos": ops["eos"].at[rows, slots].set(eoss, mode="drop"),
            "active": ops["active"].at[rows, slots].set(
                (rems >= 1) & (first != eoss), mode="drop"),
        }

    def _afleet_prefill_fn(p, slab, ops, toks, lens, rows, slots, rems,
                           eoss):
        """Async twin of ``_fleet_prefill_fn``: same slab scatter, plus the
        admitted rows activate in the device operands so the same tick's
        decode dispatch consumes their first token without a host sync."""
        _count("afleet_prefill")
        logits, small, plen = model.prefill(
            p, {"tokens": toks, "lengths": lens}, cache_len=max_seq,
            cache_dtype=cache_dtype)
        first = jnp.argmax(logits, axis=-1).astype(jnp.int32)

        def scatter(s, sm):
            return s.at[rows, :, slots].set(
                sm.swapaxes(0, 1).astype(s.dtype), mode="drop")

        slab = jax.tree.map(scatter, slab, small)
        ops = _ops_admit(ops, rows, slots, first, plen.astype(jnp.int32),
                         rems, eoss)
        return first, slab, ops

    def _afleet_chunk_fn(p, slab, ops, toks, offs, lens, fresh, rows, slots,
                         final, rems, eoss):
        """Async twin of ``_fleet_chunk_fn``: rows finishing their last
        chunk activate in the device operands (non-final rows' operand
        writes are parked out of bounds and drop)."""
        _count("afleet_chunk")
        sub = jax.tree.map(lambda s: s[rows, :, slots].swapaxes(0, 1), slab)
        first, pos, new_sub = _chunk_core(sub, toks, offs, lens, fresh, p)
        slab = jax.tree.map(
            lambda s, ns: s.at[rows, :, slots].set(
                ns.swapaxes(0, 1).astype(s.dtype), mode="drop"),
            slab, new_sub)
        wrows = jnp.where(final, rows, ops["toks"].shape[0])
        ops = _ops_admit(ops, wrows, slots, first, pos.astype(jnp.int32),
                         rems, eoss)
        return first, slab, ops

    def _fleet_prefill_fn(p, slab, toks, lens, rows, slots):
        """ONE admission dispatch for every same-bucket-length admit across
        the fleet: toks (K, sb) flattens every member's admit rows of the
        same pow2 length bucket into one batch (K itself pow2-padded), runs
        the exact same row-independent prefill as the standalone path, and
        scatters each row's KV/state straight into the donated slab at
        (fleet row ``rows[k]``, slot ``slots[k]``). Pad rows carry
        out-of-bounds indices so their writes drop. Keeping the batch flat
        (rather than vmapping per-member groups) keeps the retrace space at
        O(log(F·max_batch) · log max_seq) — a stacked (groups, kb, sb)
        signature would recompile for every fleet-size/group-count combo.
        Returns the greedy first token and per-row prompt length, (K,)
        each."""
        _count("fleet_prefill")
        logits, small, plen = model.prefill(
            p, {"tokens": toks, "lengths": lens}, cache_len=max_seq,
            cache_dtype=cache_dtype)
        first = jnp.argmax(logits, axis=-1).astype(jnp.int32)

        def scatter(s, sm):
            return s.at[rows, :, slots].set(
                sm.swapaxes(0, 1).astype(s.dtype), mode="drop")

        return first, plen.astype(jnp.int32), jax.tree.map(scatter, slab,
                                                           small)

    def _chunk_core(state, toks, offs, lens, fresh, p):
        """Shared chunk step on gathered per-slot state (leaves (L, K, ...)):
        zero fresh rows (a first chunk must not see the slot's previous
        occupant's SSM/conv state), advance one chunk, fuse the greedy
        argmax."""
        def zero(t):
            m = fresh.reshape((1, fresh.shape[0]) + (1,) * (t.ndim - 2))
            return jnp.where(m, jnp.zeros((), t.dtype), t)

        state = jax.tree.map(zero, state)
        logits, new_state, pos = model.prefill_chunk(p, state, toks, offs,
                                                     lens)
        first = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return first, pos, new_state

    def _chunk_fn(p, cache, toks, offs, lens, fresh, slots):
        """Standalone chunk step: gather ``slots`` rows from the engine
        cache (leaves (L, B, ...)), advance, scatter back. Pad rows carry an
        out-of-bounds slot so their writes drop."""
        _count("chunk")
        sub = jax.tree.map(lambda t: jnp.take(t, slots, axis=1), cache)
        first, pos, new_sub = _chunk_core(sub, toks, offs, lens, fresh, p)
        cache = jax.tree.map(
            lambda t, ns: t.at[:, slots].set(ns.astype(t.dtype), mode="drop"),
            cache, new_sub)
        return first, pos, cache

    def _fleet_chunk_fn(p, slab, toks, offs, lens, fresh, rows, slots):
        """ONE chunk dispatch for every due chunk row across the fleet:
        gather (fleet row, slot) state from the donated slab, advance one
        chunk, scatter back."""
        _count("fleet_chunk")
        sub = jax.tree.map(lambda s: s[rows, :, slots].swapaxes(0, 1), slab)
        first, pos, new_sub = _chunk_core(sub, toks, offs, lens, fresh, p)
        slab = jax.tree.map(
            lambda s, ns: s.at[rows, :, slots].set(
                ns.swapaxes(0, 1).astype(s.dtype), mode="drop"),
            slab, new_sub)
        return first, pos, slab

    k.prefill = jax.jit(_prefill_fn)
    k.decode = jax.jit(_decode_fn)
    k.decode_hold = jax.jit(_decode_hold_fn)
    # the fleet slab is owned exclusively by the FleetGroup (member engines
    # hold cache=None), so the input buffer can be donated: XLA updates the
    # KV slab in place instead of copying it every dispatch. The standalone
    # chunk kernel donates the engine cache the same way.
    k.fleet = jax.jit(_fleet_fn, donate_argnums=(1,))
    k.fleet_hold = jax.jit(_fleet_hold_fn, donate_argnums=(1,))
    k.fleet_masked = jax.jit(_fleet_masked_fn, donate_argnums=(1,))
    k.fleet_masked_hold = jax.jit(_fleet_masked_hold_fn, donate_argnums=(1,))
    k.fleet_prefill = jax.jit(_fleet_prefill_fn, donate_argnums=(1,))
    k.chunk = jax.jit(_chunk_fn, donate_argnums=(1,))
    k.fleet_chunk = jax.jit(_fleet_chunk_fn, donate_argnums=(1,))
    # async variants: slab AND operands are donated (both exclusively owned
    # by the FleetGroup), so consecutive ticks chain in place on device
    k.afleet = jax.jit(_afleet_fn, donate_argnums=(1, 2))
    k.afleet_hold = jax.jit(_afleet_hold_fn, donate_argnums=(1, 2))
    k.afleet_masked = jax.jit(_afleet_masked_fn, donate_argnums=(1, 2))
    k.afleet_masked_hold = jax.jit(_afleet_masked_hold_fn,
                                   donate_argnums=(1, 2))
    k.afleet_prefill = jax.jit(_afleet_prefill_fn, donate_argnums=(1, 2))
    k.afleet_chunk = jax.jit(_afleet_chunk_fn, donate_argnums=(1, 2))
    k.afleet_block = {}
    k._block_factory = _make_block_fn
    cache[key] = k
    return k


def _pack_chunk_rows(rows, chunk_len: int):
    """Pack per-slot chunk work items ``(toks, off, ln, fresh)`` into the
    pow2-padded host arrays both chunk kernels take (pad rows: length-1
    dummies whose index columns the caller points out of bounds)."""
    K = pow2_bucket(len(rows))
    toks = np.zeros((K, chunk_len), np.int32)
    offs = np.zeros(K, np.int32)
    lens = np.ones(K, np.int32)
    fresh = np.zeros(K, bool)
    for i, (t, off, ln, fr) in enumerate(rows):
        toks[i], offs[i], lens[i], fresh[i] = t, off, ln, fr
    return K, toks, offs, lens, fresh


@dataclasses.dataclass
class _ChunkCursor:
    """Per-slot chunked-prefill progress: the (truncated) prompt streaming
    into the slot and how many tokens earlier chunks consumed."""
    req: "Request"
    prompt: list
    consumed: int = 0


@dataclasses.dataclass
class _AdmitPlans:
    """Host-side admission decisions for one engine step (no dispatches):
    ``bucketed`` groups share one pow2-bucket prefill each, ``singles`` are
    exact-length admits (vlm/audio extras, moe exactness). Chunk starts are
    recorded directly on the engine's cursor table."""
    bucketed: list          # [(slots, reqs)]
    singles: list           # [(slot, req)]
    expired: list = dataclasses.field(default_factory=list)
    # queue heads whose deadline already passed — popped without consuming
    # a slot (admitting them would waste a prefill on a request that could
    # emit at most one truncated token); retired directly into ``finished``


class TieredQueue:
    """Per-tier FIFO queues drained in weighted-deficit round-robin order.

    Each tier owns a FIFO deque and a deficit counter. ``peek``/``pop``
    implement classic DRR with a unit request cost: when no backlogged tier
    holds a full credit, every backlogged tier earns its quantum
    (``weight / max_weight``), then the highest-priority tier with credit
    supplies the next request. The top-weight tier therefore admits first
    (its quantum is exactly 1.0), while a weight-w tier is still guaranteed
    ~w/w_max of admissions under sustained higher-tier load — weighted
    fairness with a hard no-starvation bound. Deficits persist across ticks
    so short admission windows can't bias the long-run shares; an empty
    tier's banked credit resets (no burst debt).

    With a single tier the discipline degenerates to the plain FIFO deque
    this class replaced: same pops, same order, bit-identical streams.
    ``popleft``/``__iter__`` expose global arrival order for the drain and
    failure hand-back paths, which must not apply scheduling priority."""

    def __init__(self, tiers: TierSet):
        self.tiers = tiers
        self._qs = [deque() for _ in tiers.specs]
        self._deficit = [0.0] * len(tiers)
        wmax = max(float(w) for w in tiers.weights)
        self._quantum = [float(w) / wmax for w in tiers.weights]

    def __len__(self) -> int:
        return sum(len(q) for q in self._qs)

    def __bool__(self) -> bool:
        return any(self._qs)

    def __iter__(self):
        """All queued requests in global arrival order (rid tiebreak)."""
        return iter(sorted((r for q in self._qs for r in q),
                           key=lambda r: (r.arrival, r.rid)))

    def append(self, req):
        self._qs[self.tiers.index(getattr(req, "tier", "standard"))] \
            .append(req)

    def clear(self):
        for q in self._qs:
            q.clear()

    def popleft(self):
        """Earliest-arrival request across all tiers (hand-back order for
        drain/evacuate — deliberately NOT the scheduling order)."""
        cands = [q for q in self._qs if q]
        if not cands:
            raise IndexError("pop from an empty TieredQueue")
        best = min(cands, key=lambda q: (q[0].arrival, q[0].rid))
        return best.popleft()

    def depths(self) -> list:
        """Per-tier queue lengths (declaration order)."""
        return [len(q) for q in self._qs]

    def higher_waiting(self, tier_idx: int) -> bool:
        """Any queued work in a strictly higher-priority tier?"""
        rank = self.tiers._rank[tier_idx]
        return any(self._qs[t] for t in self.tiers.priority[:rank])

    def _head_tier(self, exclude) -> Optional[int]:
        live = [t for t in self.tiers.priority
                if self._qs[t] and t not in exclude]
        if not live:
            return None
        for t, q in enumerate(self._qs):     # empty tiers bank no credit
            if not q:
                self._deficit[t] = 0.0
        while True:
            for t in live:                   # priority order within a round
                if self._deficit[t] >= 1.0 - 1e-9:
                    return t
            for t in live:
                self._deficit[t] += self._quantum[t]

    def peek(self, exclude=()) -> Optional[tuple]:
        """(tier_idx, request) the next ``pop`` would return, or None.
        Idempotent: repeated peeks without a pop return the same head."""
        t = self._head_tier(exclude)
        return None if t is None else (t, self._qs[t][0])

    def pop(self, exclude=()):
        t = self._head_tier(exclude)
        if t is None:
            raise IndexError("pop from an empty TieredQueue")
        self._deficit[t] -= 1.0
        return self._qs[t].popleft()


class FleetGroup:
    """Stacks the device state of same-shape replicas along a leading fleet
    axis and advances every member with one jitted dispatch per tick.

    The slab capacity grows in power-of-two steps (O(log F) retraces as the
    fleet scales 1 -> F); spare rows decode throwaway state and are fully
    overwritten when a replica joins, so they need no masking. Removing a
    member (drain retire / failure) backfills its row with the last member's
    row in a single device op, so live rows stay dense.

    ``admit_round`` is the admission twin of ``decode_round``: members'
    bucketed admit rows of the same pow2 length bucket flatten into ONE
    ``fleet_prefill`` per distinct bucket, and all due chunk rows into ONE
    ``fleet_chunk`` — each writing KV/state straight into the donated slab.
    ``prefill_dispatches`` mirrors ``dispatches``.

    With ``async_mode`` the dispatch methods never block: device results
    queue on ``pending`` and the deferred host bookkeeping applies at the
    next ``reconcile()`` — one blocking sync per tick (``syncs``), with the
    decode operands persistent on device (``ops``). See the module
    docstring's async tick contract.

    **Shard contract** (``mesh`` with a ``fleet`` axis, N = shard count).
    The slab's leading fleet axis (and the async operands') is laid out
    ``NamedSharding(mesh, P('fleet'))`` — device d owns the contiguous row
    block [d·cap/N, (d+1)·cap/N) — while ``params`` replicate across the
    fleet axis, so GSPMD partitions the *existing* jitted kernel families
    row-parallel: still ONE logical dispatch per kernel variant per tick and
    ONE reconcile sync, now fanned out over N devices. Invariants:

      * **divisibility** — slab capacity is always a multiple of N:
        ``cap = N * pow2_bucket(ceil(F / N))`` (per-shard sub-capacity grows
        in pow2 steps, O(log ceil(F/N)) retraces). The extra rows are pad
        rows exactly like the unsharded spares: masked inactive (never in
        ``movers``/``active``) and excluded from dispatch and retire
        accounting, they only burn bounded throwaway compute;
      * **row re-balance on churn** — live rows stay DENSE in [0, F) (joins
        append, removals swap-backfill with the last row), so with block
        layout the F live rows spread across shards as evenly as contiguous
        blocks allow; membership changes force-flush pending futures first,
        exactly like the unsharded async path;
      * **bit-identical streams** — the kernels are mesh-agnostic (sharding
        only partitions them), so token streams and finish clocks equal the
        unsharded oracle across churn/async/chunk/tier (tests/
        test_fleet_shard.py)."""

    def __init__(self, model: Model, params, *, max_batch: int, max_seq: int,
                 cache_dtype=jnp.float32, async_mode: bool = False,
                 decode_block: int = 1, attn_backend: str = "einsum",
                 mesh=None):
        self.model = model
        self.mesh = mesh
        if mesh is not None and "fleet" not in mesh.axis_names:
            raise ValueError(
                f"FleetGroup mesh needs a 'fleet' axis, got "
                f"{mesh.axis_names}")
        self.shards = int(mesh.shape["fleet"]) if mesh is not None else 1
        if mesh is not None:
            # replicate the weights across the fleet axis once: every shard
            # decodes its own slab rows against the full params (serve-mode
            # rule — see distributed.sharding), and a device-0-committed
            # params array mixed with a sharded slab is a placement error
            params = jax.device_put(params, NamedSharding(mesh, P()))
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.cache_dtype = cache_dtype
        self.attn_backend = attn_backend
        self.members: list = []     # ReplicaEngine; fleet row == list index
        self.cap = 0                # allocated fleet rows (power of two)
        self.slab = None            # cache pytree, leaves (cap, *per_replica)
        self.dispatches = 0         # jitted fleet decode dispatches issued
        self.prefill_dispatches = 0  # jitted fleet admission dispatches
        self.async_mode = bool(async_mode)
        self.decode_block = max(1, int(decode_block))
        self.ops = None             # device decode operands (async mode)
        self.pending: list = []     # _Pending device results, un-synced
        self._stash: list = []      # finishes from forced flushes (churn)
        self._admitted = False      # eager single-admit landed this tick
        self.syncs = 0              # blocking host syncs performed
        self.sync_wait = 0.0        # seconds spent blocked on device results
        self._block_credit = 0      # ticks already covered by a decode block
        self._kernels = get_serve_kernels(model, max_seq, cache_dtype,
                                          attn_backend)

    def __len__(self) -> int:
        return len(self.members)

    # ------------------------------------------------------------- sharding
    def _cap_for(self, rows: int) -> int:
        """Slab capacity for ``rows`` members. Unsharded: the next power of
        two. Sharded: the per-shard sub-capacity grows in pow2 steps instead
        (cap = shards * pow2_bucket(ceil(rows / shards))), keeping the fleet
        axis divisible by the shard count (a non-dividing axis would silently
        fall back to replication) at the same O(log) retrace bound."""
        if self.shards == 1:
            return pow2_bucket(rows)
        return self.shards * pow2_bucket(-(-rows // self.shards))

    def _replicated(self, x):
        """Replicate a host/device-0 value over the mesh so eager mixed ops
        against the sharded slab are placement-legal (eager updates with one
        mesh-sharded and one device-0-committed operand are an error)."""
        return jax.device_put(x, NamedSharding(self.mesh, P()))

    def _place_slab(self, slab):
        """Pin the slab's sharding: fleet axis over the mesh's fleet axis,
        per-replica cache dims under the serve-mode rules."""
        return jax.device_put(slab, fleet_slab_shardings(self.mesh, slab))

    def _place_ops(self, ops):
        return jax.device_put(ops, NamedSharding(self.mesh, P("fleet")))

    # -------------------------------------------------------------- members
    def add(self, eng: "ReplicaEngine"):
        """Stack ``eng``'s device cache into the slab (any in-flight slot
        state rides along, so replicas can join mid-generation). Pending
        futures flush first so the operand seed sees current host state."""
        assert eng._fleet is None, "engine already belongs to a fleet"
        if self.pending:
            self._stash += self.reconcile(force=True)
        row = len(self.members)
        if row >= self.cap:
            new_cap = self._cap_for(row + 1)
            if self.slab is None:
                self.slab = jax.tree.map(
                    lambda c: jnp.zeros((new_cap,) + c.shape, c.dtype),
                    eng.cache)
                if self.async_mode:
                    self.ops = _init_ops(new_cap, self.max_batch)
            else:
                grow = lambda s: jnp.concatenate(
                    [s, jnp.zeros((new_cap - self.cap,) + s.shape[1:],
                                  s.dtype)])
                self.slab = jax.tree.map(grow, self.slab)
                if self.async_mode:
                    self.ops = jax.tree.map(grow, self.ops)
            self.cap = new_cap
            if self.mesh is not None:
                # re-pin after (re)allocation: zeros/concatenate land on the
                # default device; the slab must carry the fleet sharding so
                # GSPMD row-partitions every subsequent dispatch
                self.slab = self._place_slab(self.slab)
                if self.async_mode:
                    self.ops = self._place_ops(self.ops)
        cache = eng.cache if self.mesh is None else self._replicated(eng.cache)
        self.slab = jax.tree.map(lambda s, c: s.at[row].set(c),
                                 self.slab, cache)
        if self.async_mode:
            self._seed_ops_row(row, eng)
        eng.cache = None
        eng._fleet, eng._fleet_row = self, row
        self.members.append(eng)

    def _seed_ops_row(self, row: int, eng: "ReplicaEngine"):
        """Initialize the device operands for a joining member from its
        host mirrors (it may carry in-flight slots mid-generation)."""
        B = self.max_batch
        rem = np.zeros(B, np.int32)
        eos = np.full(B, -1, np.int32)
        act = np.zeros(B, bool)
        for s, req in enumerate(eng.slots):
            if req is not None and s not in eng._chunks:
                act[s] = True
                rem[s] = req.rem_tokens(eng.clock)
                eos[s] = req.eos_id
        vals = {"toks": np.asarray(eng.last_tok, np.int32),
                "pos": np.asarray(eng.pos, np.int32),
                "rem": rem, "eos": eos, "active": act}
        self.ops = {kk: self.ops[kk].at[row].set(vals[kk])
                    for kk in self.ops}

    def remove(self, eng: "ReplicaEngine", restore: bool = True):
        """Detach ``eng``; with ``restore`` its cache row is unstacked back
        onto the engine (drain hand-back), otherwise dropped (failure).
        Pending futures flush first (host mirrors must be current before a
        row unstacks or backfills — the churn half of the async contract)."""
        if self.pending:
            self._stash += self.reconcile(force=True)
        row = eng._fleet_row
        assert eng._fleet is self and self.members[row] is eng
        if restore:
            eng.cache = jax.tree.map(lambda s: s[row], self.slab)
            if self.mesh is not None:
                # hand the detached engine a plain single-device cache (the
                # eager slice above is committed to the whole mesh)
                eng.cache = jax.device_put(eng.cache, jax.devices()[0])
        last = self.members.pop()
        if last is not eng:          # backfill the hole with the last row
            backfill = lambda s: s.at[row].set(s[len(self.members)])
            self.slab = jax.tree.map(backfill, self.slab)
            if self.async_mode:
                self.ops = jax.tree.map(backfill, self.ops)
            last._fleet_row = row
            self.members[row] = last
        eng._fleet, eng._fleet_row = None, -1

    # -------------------------------------------------------------- slots
    def write_slot(self, f: int, slot: int, small_state, row: int,
                   req: Optional["Request"] = None, prompt_len: int = 0):
        """Copy prefill output row ``row`` into member ``f``'s slot (the
        exact-length single-admit path; bucketed admits scatter on device
        inside ``fleet_prefill`` instead). In async mode the slot also
        registers in the device operands (``req``'s first token was already
        synced by the eager single-admit path)."""
        if self.mesh is not None:
            small_state = self._replicated(small_state)
        self.slab = jax.tree.map(
            lambda s, sm: s.at[f, :, slot].set(sm[:, row]),
            self.slab, small_state)
        if self.async_mode and req is not None:
            o = self.ops
            self.ops = {
                "toks": o["toks"].at[f, slot].set(int(req.output[-1])),
                "pos": o["pos"].at[f, slot].set(int(prompt_len)),
                "rem": o["rem"].at[f, slot].set(
                    req.rem_tokens(self.members[f].clock)),
                "eos": o["eos"].at[f, slot].set(int(req.eos_id)),
                "active": o["active"].at[f, slot].set(True),
            }
            # single admits bypass ``pending`` (their sync was eager), so
            # they must veto fused-block engagement separately — a tick
            # that admitted anything never fuses
            self._admitted = True

    # -------------------------------------------------------------- admit
    def admit_round(self, stepping_ids=None) -> list:
        """One fused admission step for every member (or the ``id(engine)``
        subset in ``stepping_ids``): plan each member's admissions on the
        host, then flatten same-length-bucket admit rows into one
        ``fleet_prefill`` per distinct bucket and all due chunk rows into
        one ``fleet_chunk``. Exact-length single admits (extras / moe) keep
        the per-request path. Returns requests finished at prefill time."""
        movers = [e for e in self.members
                  if stepping_ids is None or id(e) in stepping_ids]
        finished: list = []
        buckets: dict = {}       # sb -> [(engine, slot, req, prompt)] rows
        chunk_rows: list = []    # (engine, slot, toks, off, ln, fresh, final)
        for e in movers:
            plans = e.plan_admission()
            finished.extend(plans.expired)
            for slot, req in plans.singles:
                e._admit_batch([slot], [req], finished, bucketed=False)
            for slots, reqs in plans.bucketed:
                prompts = [r.prompt[-(self.max_seq - 1):] for r in reqs]
                # the length bucket is chosen per member group exactly like
                # the standalone path; rows of the same bucket then flatten
                # into one fleet-wide batch
                sb = min(pow2_bucket(max(len(p) for p in prompts),
                                     e.min_bucket), self.max_seq)
                buckets.setdefault(sb, []).extend(
                    (e, s, r, p) for s, r, p in zip(slots, reqs, prompts))
            for row in e._chunk_rows():
                chunk_rows.append((e,) + row)
        for sb, entries in sorted(buckets.items()):
            self._dispatch_fleet_prefill(sb, entries, finished)
        if chunk_rows:
            self._dispatch_fleet_chunk(chunk_rows, finished)
        return finished

    def _dispatch_fleet_prefill(self, sb: int, entries: list,
                                finished: list):
        K = pow2_bucket(len(entries))
        toks = np.zeros((K, sb), np.int32)
        lens = np.ones(K, np.int32)             # pad rows: length-1 dummies
        rows = np.full(K, self.cap, np.int32)   # OOB pad rows -> dropped
        slots = np.full(K, self.max_batch, np.int32)
        rems = np.zeros(K, np.int32)
        eoss = np.full(K, -1, np.int32)
        for i, (e, slot, req, p) in enumerate(entries):
            toks[i, :len(p)] = p
            lens[i] = len(p)
            rows[i], slots[i] = e._fleet_row, slot
            rems[i] = req.rem_tokens(e.clock) - 1
            eoss[i] = req.eos_id
        if self.async_mode:
            first, self.slab, self.ops = self._kernels.afleet_prefill(
                self.params, self.slab, self.ops, jnp.asarray(toks),
                jnp.asarray(lens), jnp.asarray(rows), jnp.asarray(slots),
                jnp.asarray(rems), jnp.asarray(eoss))
            self.prefill_dispatches += 1
            meta = []
            for i, (e, slot, req, p) in enumerate(entries):
                e.slots[slot] = req      # reserve now; commit at reconcile
                meta.append((i, e, slot, req, len(p), e.clock))
            self.pending.append(_Pending("prefill", first, meta))
            return
        first, plen, self.slab = self._kernels.fleet_prefill(
            self.params, self.slab, jnp.asarray(toks), jnp.asarray(lens),
            jnp.asarray(rows), jnp.asarray(slots))
        self.prefill_dispatches += 1
        first, plen = _timed_get(self, (first, plen))
        first, plen = np.asarray(first), np.asarray(plen)
        for i, (e, slot, req, p) in enumerate(entries):
            e.commit_admit([slot], [req], first[i:i + 1], plen[i:i + 1],
                           finished)

    def _dispatch_fleet_chunk(self, chunk_rows: list, finished: list):
        # members may carry different chunk_len settings; each width is its
        # own fixed kernel shape
        by_width: dict = {}
        for item in chunk_rows:
            by_width.setdefault(item[0].chunk_len, []).append(item)
        for C, items in sorted(by_width.items()):
            K, toks, offs, lens, fresh = _pack_chunk_rows(
                [(t, off, ln, fr) for _, _, t, off, ln, fr, _ in items], C)
            rows = np.full(K, self.cap, np.int32)       # OOB pads -> dropped
            slots = np.full(K, self.max_batch, np.int32)
            for i, (e, slot, *_rest) in enumerate(items):
                rows[i], slots[i] = e._fleet_row, slot
            if self.async_mode:
                final = np.zeros(K, bool)
                rems = np.zeros(K, np.int32)
                eoss = np.full(K, -1, np.int32)
                for i, (e, slot, t, off, ln, fr, fin) in enumerate(items):
                    req = e._chunks[slot].req
                    final[i] = fin
                    rems[i] = req.rem_tokens(e.clock) - 1
                    eoss[i] = req.eos_id
                first, self.slab, self.ops = self._kernels.afleet_chunk(
                    self.params, self.slab, self.ops, jnp.asarray(toks),
                    jnp.asarray(offs), jnp.asarray(lens), jnp.asarray(fresh),
                    jnp.asarray(rows), jnp.asarray(slots),
                    jnp.asarray(final), jnp.asarray(rems), jnp.asarray(eoss))
                self.prefill_dispatches += 1
                meta = []
                for i, (e, slot, t, off, ln, fr, fin) in enumerate(items):
                    cur = e._chunks[slot]
                    if not fin:          # cursor advance is host-computable
                        cur.consumed += e.chunk_len
                        continue
                    del e._chunks[slot]  # slot stays reserved (slots[slot])
                    meta.append((i, e, slot, cur.req, off + ln, e.clock))
                if meta:
                    self.pending.append(_Pending("chunk", first, meta))
                continue
            first, pos, self.slab = self._kernels.fleet_chunk(
                self.params, self.slab, jnp.asarray(toks), jnp.asarray(offs),
                jnp.asarray(lens), jnp.asarray(fresh), jnp.asarray(rows),
                jnp.asarray(slots))
            self.prefill_dispatches += 1
            first, pos = _timed_get(self, (first, pos))
            first, pos = np.asarray(first), np.asarray(pos)
            for i, (e, slot, t, off, ln, fr, fin) in enumerate(items):
                e.commit_chunk(slot, first[i], pos[i], fin, finished)

    # -------------------------------------------------------------- decode
    def decode_round(self, stepping_ids=None, allow_block: bool = False
                     ) -> list:
        """One fused decode step for every member (or the ``id(engine)``
        subset in ``stepping_ids``). Returns finished requests. Eager: one
        jitted dispatch plus one small (F, B) host sync. Async: one jitted
        dispatch, NO sync (results commit at the next ``reconcile``), and
        with ``allow_block`` a K-micro-step fused block may engage on a
        tick that admitted nothing — covering the next K-1 ticks' decode
        in this single dispatch."""
        movers = [e for e in self.members
                  if stepping_ids is None or id(e) in stepping_ids]
        if self.async_mode:
            return self._decode_round_async(movers, allow_block)
        if not movers or not any(e.n_decoding for e in movers):
            return []
        cap, B = self.cap, self.max_batch
        toks = np.zeros((cap, B), np.int32)
        pos = np.zeros((cap, B), np.int32)
        rem = np.ones((cap, B), np.int32)
        eos = np.full((cap, B), -1, np.int32)
        active = np.zeros((cap, B), bool)
        rows = np.zeros((cap,), bool)
        held: list = []              # mid-chunk (row, slot): state must not
        for e in movers:             # move this round
            f = e._fleet_row
            rows[f] = True
            toks[f] = e.last_tok
            pos[f] = e.pos
            held.extend((f, s) for s in e._chunks)
            for s, req in enumerate(e.slots):
                if req is not None and s not in e._chunks:
                    active[f, s] = True
                    rem[f, s] = req.rem_tokens(e.clock)
                    eos[f, s] = req.eos_id
        if held:                     # pow2-padded OOB -> dropped on scatter
            hk = pow2_bucket(len(held))
            hrows = np.full(hk, cap, np.int32)
            hslots = np.full(hk, B, np.int32)
            for i, (f, s) in enumerate(held):
                hrows[i], hslots[i] = f, s
        if len(movers) == len(self.members):
            if held:
                nxt, done, self.slab = self._kernels.fleet_hold(
                    self.params, self.slab, toks, pos, rem, eos, active,
                    hrows, hslots)
            else:
                nxt, done, self.slab = self._kernels.fleet(
                    self.params, self.slab, toks, pos, rem, eos, active)
        elif held:
            nxt, done, self.slab = self._kernels.fleet_masked_hold(
                self.params, self.slab, toks, pos, rem, eos, active, rows,
                hrows, hslots)
        else:
            nxt, done, self.slab = self._kernels.fleet_masked(
                self.params, self.slab, toks, pos, rem, eos, active, rows)
        self.dispatches += 1
        nxt, done = _timed_get(self, (nxt, done))   # ONE small host sync
        nxt, done = np.asarray(nxt), np.asarray(done)
        finished: list = []
        for e in movers:
            f = e._fleet_row
            finished.extend(e.commit_decode(nxt[f], done[f]))
        return finished

    def _decode_round_async(self, movers: list, allow_block: bool) -> list:
        """Sync-free decode round: operands already live on device, so the
        dispatch takes only the cheap host-known masks (held chunk slots,
        stepping rows). Results queue on ``pending``."""
        if self._block_credit > 0:      # a fused block covers this tick
            self._block_credit -= 1
            return []
        if not movers or not any(e.n_decoding for e in movers):
            return []
        cap, B = self.cap, self.max_batch
        held = [(e._fleet_row, s) for e in movers for s in e._chunks]
        full = len(movers) == len(self.members)
        K = self.decode_block
        meta = [(e, e._fleet_row, e.clock) for e in movers]
        # fused-block engagement: only on ticks with no admissions at all —
        # ``pending`` catches this tick's fleet prefill/chunk dispatches
        # (the tick-start reconcile cleared the previous window) and
        # ``_admitted`` the eager single-admit path — and no chunk cursors
        # anywhere. Queued work behind a FULL slab does not block
        # engagement: any admission landing inside the fused window (a
        # retire freeing a slot, or an arrival finding one) only starts
        # decoding at the window's end, i.e. admission-to-first-decode may
        # lag up to K-1 ticks (the documented decode_block trade; async
        # with K=1 keeps the <= 1-tick bound)
        admitted, self._admitted = self._admitted, False
        if (allow_block and K > 1 and full and not held and not self.pending
                and not admitted
                and all(not e._chunks for e in self.members)):
            nxt, done, stepped, self.slab, self.ops = \
                self._kernels.block_kernel(K)(self.params, self.slab,
                                              self.ops)
            self.dispatches += 1
            self._block_credit = K - 1
            self.pending.append(_Pending("block", (nxt, done, stepped),
                                         meta))
            return []
        if held:                     # pow2-padded OOB -> dropped on scatter
            hk = pow2_bucket(len(held))
            hrows = np.full(hk, cap, np.int32)
            hslots = np.full(hk, B, np.int32)
            for i, (f, s) in enumerate(held):
                hrows[i], hslots[i] = f, s
        if full:
            if held:
                out = self._kernels.afleet_hold(self.params, self.slab,
                                                self.ops, hrows, hslots)
            else:
                out = self._kernels.afleet(self.params, self.slab, self.ops)
        else:
            rows = np.zeros((cap,), bool)
            for e in movers:
                rows[e._fleet_row] = True
            if held:
                out = self._kernels.afleet_masked_hold(
                    self.params, self.slab, self.ops, rows, hrows, hslots)
            else:
                out = self._kernels.afleet_masked(self.params, self.slab,
                                                  self.ops, rows)
        nxt, done, stepped, self.slab, self.ops = out
        self.dispatches += 1
        self.pending.append(_Pending("decode", (nxt, done, stepped), meta))
        return []

    # ----------------------------------------------------------- reconcile
    def take_stash(self) -> list:
        """Drain finishes produced by forced mid-tick flushes (membership
        churn) without touching still-pending futures."""
        out = list(self._stash)
        self._stash.clear()
        return out

    def reconcile(self, force: bool = False) -> list:
        """The ONE blocking host sync per tick: fetch every pending device
        result together and apply the deferred host bookkeeping in dispatch
        order (prefill first-tokens before the same tick's decode tokens —
        the exact replay of the eager host effects, one tick late). Returns
        newly finished requests, stamped with their dispatch-time clocks.
        While a decode block still covers upcoming ticks the fetch is
        deferred (that is the < 1 sync/tick regime) unless ``force``d by
        membership churn."""
        # mutate the stash in place: callers flush via
        # ``self._stash += self.reconcile(...)`` and a reassignment here
        # would strand their appends on the orphaned old list (the bound
        # method/in-place target resolves BEFORE this call runs)
        finished: list = list(self._stash)
        self._stash.clear()
        if not self.pending or (self._block_credit > 0 and not force):
            return finished
        pend, self.pending = self.pending, []
        fetched = _timed_get(self, [p.arrays for p in pend])
        for p, vals in zip(pend, fetched):
            if p.kind == "decode":
                self._apply_decode(vals, p.meta, finished)
            elif p.kind == "block":
                self._apply_block(vals, p.meta, finished)
            else:                    # "prefill" and final-"chunk" commits
                self._apply_admit(vals, p.meta, finished)
        return finished

    def _apply_decode(self, arrays, meta: list, finished: list):
        nxt, done, stepped = (np.asarray(a) for a in arrays)
        for e, row, clock in meta:
            finished.extend(e.apply_decode(nxt[row], done[row], stepped[row],
                                           clock))

    def _apply_block(self, arrays, meta: list, finished: list):
        nxt, done, stepped = (np.asarray(a) for a in arrays)  # (K, F, B)
        for k in range(nxt.shape[0]):        # micro-step k == tick clock+k
            for e, row, clock in meta:
                finished.extend(e.apply_decode(nxt[k, row], done[k, row],
                                               stepped[k, row], clock + k))

    def _apply_admit(self, first, meta: list, finished: list):
        """Deferred ``commit_admit``/final-chunk ``commit_chunk``: the slot
        was reserved at dispatch (and non-final chunk cursor advances were
        committed host-side there); now the first generated token, the TTFT
        stamp and the finish-at-prefill rule apply. ``pos`` in the meta is
        the host-computed cache frontier (prompt length, or chunk offset +
        length)."""
        first = np.asarray(first)
        for i, e, slot, req, pos, clock in meta:
            tok = int(first[i])
            req.output.append(tok)
            req.first_token_time = clock
            if len(req.output) >= req.max_new_tokens or tok == req.eos_id \
                    or req.out_of_time(clock):
                req.finish_time = clock
                finished.append(req)
                e.slots[slot] = None
                continue
            e.pos[slot] = pos
            e.last_tok[slot] = tok


def total_prefill_traces(engines) -> int:
    """Global prefill-side compile count (bucketed + fleet + chunk kernel
    variants), deduped across replicas that share kernels (each replica
    reports its shared counter)."""
    seen = {id(e._kernels): e._kernels.prefill_traces for e in engines}
    return sum(seen.values())


def total_serve_traces(engines) -> int:
    """Global compile count across *every* serve-kernel variant (prefill,
    decode, decode_hold, fleet, fleet_masked, fleet_prefill, chunk,
    fleet_chunk), deduped across replicas sharing kernels."""
    seen = {id(e._kernels): e._kernels.total_traces for e in engines}
    return sum(seen.values())


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list
    max_new_tokens: int = 16
    eos_id: int = -1               # -1: never stop early
    arrival: float = 0.0
    tier: str = "standard"         # SLO tier name (see workload.trace)
    # deadline (absolute tick, None = no deadline): past it the request is
    # worthless to its client — in-flight slots retire through the existing
    # fleet/afleet ``rem <= 1`` rule (the host clamps the remaining-token
    # budget, see ``rem_tokens``; no new kernels, no extra dispatches) and
    # queued copies are culled at admission time. Deadlines are denominated
    # in ticks and enforced at one decode step per tick; a speed>1 replica's
    # extra sub-steps only ever retire it conservatively *earlier*.
    deadline_tick: Optional[float] = None
    # filled by the engine
    output: list = dataclasses.field(default_factory=list)
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None

    @property
    def done(self) -> bool:
        return self.finish_time is not None

    @property
    def expired(self) -> bool:
        """Finished by deadline expiry rather than on its own terms: the
        output was truncated — neither the token budget nor EOS ended it —
        and only the deadline clamp / queue cull truncates. The finish
        stamp can land *before* the deadline (a request admitted at tick t
        also decodes at tick t, outrunning the 1-token/tick clamp budget),
        so truncation, not ``finish_time``, is the signal. Never true
        without a deadline, so deadline-free workloads classify exactly
        as before."""
        return (self.deadline_tick is not None
                and self.finish_time is not None
                and len(self.output) < self.max_new_tokens
                and (not self.output or self.output[-1] != self.eos_id))

    def rem_tokens(self, clock: float) -> int:
        """Remaining-token budget at ``clock`` — the value the fleet/afleet
        retire rule consumes as ``rem``. Without a deadline this is exactly
        the historical ``max_new_tokens - len(output)``; with one, it is
        additionally clamped so the slot retires (``rem <= 1``) no later
        than the deadline tick. Both budgets decrement one per decode step,
        so a value seeded once into the async device operands stays the
        exact min at every later micro-step."""
        rem = self.max_new_tokens - len(self.output)
        if self.deadline_tick is not None:
            rem = min(rem, int(self.deadline_tick - clock) + 1)
        return rem

    def out_of_time(self, clock: float) -> bool:
        """Host twin of the deadline half of the device retire rule: at
        ``clock >= deadline_tick`` the deadline-clamped ``rem`` is <= 1, so
        the token appended at ``clock`` is the slot's last."""
        return self.deadline_tick is not None and clock >= self.deadline_tick

    def reset_progress(self):
        """Forget generation progress (replica failure -> re-queue)."""
        self.output = []
        self.first_token_time = None
        self.finish_time = None


class ReplicaEngine:
    def __init__(self, model: Model, params, *, max_batch: int = 4,
                 max_seq: int = 256, cache_dtype=jnp.float32, rid: int = 0,
                 speed: float = 1.0, min_bucket: int = 8,
                 bucket_prompts: Optional[bool] = None, chunk_len: int = 0,
                 tiers: Optional[TierSet] = None,
                 attn_backend: str = "einsum"):
        if attn_backend not in ("einsum", "pallas"):
            raise ValueError(f"unknown attn_backend {attn_backend!r}")
        if attn_backend == "pallas" and \
                model.cfg.family not in ("dense", "moe", "vlm"):
            raise ValueError(
                "attn_backend='pallas' needs the attention-KV decode path; "
                f"family={model.cfg.family!r} decodes through ssm/encdec "
                "state")
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.cache_dtype = cache_dtype
        self.attn_backend = attn_backend
        self.rid = rid
        self.speed = speed            # relative decode speed (hetero hardware)
        self.min_bucket = min_bucket
        self.draining = False         # drained replicas admit nothing new
        self.cache = model.init_serve_state(max_batch, max_seq, cache_dtype)
        self.pos = np.zeros(max_batch, np.int32)       # next cache index
        self.last_tok = np.zeros(max_batch, np.int32)
        self.slots: list = [None] * max_batch
        self.tiers = tiers or DEFAULT_TIERS
        self.queue: TieredQueue = TieredQueue(self.tiers)
        self.clock = 0.0
        self.steps = 0
        self.syncs = 0                # blocking host syncs performed
        self.sync_wait = 0.0          # seconds spent blocked on the device
        self.prefill_dispatches = 0   # jitted admission dispatches issued
        self._fleet: Optional[FleetGroup] = None   # device state owner when
        self._fleet_row = -1                       # fleet-batched
        self._chunks: dict = {}       # slot -> _ChunkCursor (mid-chunk-prefill)
        if bucket_prompts is None:
            bucket_prompts = model.cfg.family in _BUCKET_FAMILIES
        self.bucket_prompts = bucket_prompts
        # chunked admission needs a continuation kernel and an f32 cache:
        # the int8 codec quantizes whole prompts at prefill end, and a
        # reduced-precision (bf16) cache would make chunked attention read
        # back rounded K/V (and re-round carried ssm/conv state per chunk)
        # where single-shot prefill attends the unrounded values — breaking
        # the chunk-vs-single-shot exactness the parity oracle relies on.
        if chunk_len and (model.cfg.family not in _CHUNK_FAMILIES
                          or _dtype_name(cache_dtype) != "float32"):
            chunk_len = 0
        self.chunk_len = int(chunk_len)
        self._kernels = get_serve_kernels(model, max_seq, cache_dtype,
                                          attn_backend)
        self._prefill = self._kernels.prefill
        self._decode = self._kernels.decode

    @property
    def fleet_key(self) -> tuple:
        """Replicas with equal keys can share one stacked fleet slab."""
        return (id(self.model), id(self.params), self.max_batch,
                self.max_seq, _dtype_name(self.cache_dtype),
                self.attn_backend)

    @property
    def prefill_traces(self) -> int:
        """Prefill-side compilations of this replica's (shared) kernels —
        counts the bucketed, fleet-batched and chunked variants in one
        accounting."""
        return self._kernels.prefill_traces

    # ----------------------------------------------------------------- load
    @property
    def n_active(self) -> int:
        return sum(s is not None for s in self.slots)

    @property
    def n_decoding(self) -> int:
        """Slots in the decode phase (occupied and not mid-chunk-prefill)."""
        return sum(s is not None and i not in self._chunks
                   for i, s in enumerate(self.slots))

    @property
    def load(self) -> int:
        return self.n_active + len(self.queue)

    def tier_load(self) -> list:
        """Per-tier unfinished count on this replica (declaration order):
        queued + in-flight slots (mid-chunk included)."""
        counts = self.queue.depths()
        for req in self.slots:
            if req is not None:
                counts[self.tiers.index(req.tier)] += 1
        return counts

    def submit(self, req: Request):
        self.queue.append(req)

    def evacuate(self) -> list:
        """Failure path: pull every in-flight + queued request off this
        replica (generation progress is lost) so the caller can re-queue."""
        lost = [r for r in self.slots if r is not None] + list(self.queue)
        self.slots = [None] * self.max_batch
        self.queue.clear()
        self._chunks.clear()
        for r in lost:
            r.reset_progress()
        return lost

    # ------------------------------------------------------------- plumbing
    def _insert_slot(self, slot: int, small_state, row: int, prompt_len: int,
                     first_tok: int, req: Request):
        if self._fleet is not None:
            self._fleet.write_slot(self._fleet_row, slot, small_state, row,
                                   req=req, prompt_len=prompt_len)
        else:
            def put(big, small):
                return big.at[:, slot].set(small[:, row])
            self.cache = jax.tree.map(put, self.cache, small_state)
        self.pos[slot] = prompt_len
        self.last_tok[slot] = first_tok
        self.slots[slot] = req

    def _admit_batch(self, slots: list, reqs: list, finished: list,
                     bucketed: bool):
        if bucketed:
            # a prompt longer than the KV pool keeps only its last
            # max_seq - 1 tokens (one slot must remain for generation);
            # copying the raw prompt would overflow the token buffer.
            prompts = [r.prompt[-(self.max_seq - 1):] for r in reqs]
            lens = [len(p) for p in prompts]
            sb = min(pow2_bucket(max(lens), self.min_bucket), self.max_seq)
            kb = pow2_bucket(len(reqs))
            toks = np.zeros((kb, sb), np.int32)
            lengths = np.ones(kb, np.int32)    # pad rows: length-1 dummies
            for i, p in enumerate(prompts):
                toks[i, :len(p)] = p
                lengths[i] = len(p)
            batch = {"tokens": jnp.asarray(toks),
                     "lengths": jnp.asarray(lengths)}
            logits, small, plen = self._prefill(self.params, batch)
        else:
            req = reqs[0]
            # same overflow guard as the bucketed path: the KV pool holds
            # max_seq entries and one must remain for generation
            prompt = req.prompt[-(self.max_seq - 1):]
            batch = {"tokens": jnp.asarray([prompt], jnp.int32)}
            extras = getattr(req, "extras", None)
            if extras:
                batch.update({k: jnp.asarray(v) for k, v in extras.items()})
            logits, small, plen = self._prefill(self.params, batch)
        self.prefill_dispatches += 1
        first, plen = _timed_get(self, (jnp.argmax(logits, axis=-1), plen))
        first = np.asarray(first)
        plen = np.atleast_1d(np.asarray(plen)).astype(np.int32)
        for i, (slot, req) in enumerate(zip(slots, reqs)):
            tok = int(first[i])
            req.output.append(tok)
            req.first_token_time = self.clock
            if len(req.output) >= req.max_new_tokens or tok == req.eos_id \
                    or req.out_of_time(self.clock):
                req.finish_time = self.clock
                finished.append(req)
                continue
            self._insert_slot(slot, small, i, int(plen[i]), tok, req)

    def commit_admit(self, slots: list, reqs: list, first, plen,
                     finished: list):
        """Apply a fleet-prefill result: the slab rows were already written
        on device, so only the host bookkeeping (first token, TTFT, retire
        or register) remains. A request that finishes at prefill time leaves
        stale state in the slab — harmless, exactly like slot reuse."""
        for i, (slot, req) in enumerate(zip(slots, reqs)):
            tok = int(first[i])
            req.output.append(tok)
            req.first_token_time = self.clock
            if len(req.output) >= req.max_new_tokens or tok == req.eos_id \
                    or req.out_of_time(self.clock):
                req.finish_time = self.clock
                finished.append(req)
                continue
            self.pos[slot] = int(plen[i])
            self.last_tok[slot] = tok
            self.slots[slot] = req

    # ------------------------------------------------------------ admission
    def _chunkable(self, req: Request) -> bool:
        return (self.chunk_len > 0
                and getattr(req, "extras", None) is None
                and min(len(req.prompt), self.max_seq - 1) > self.chunk_len)

    def plan_admission(self) -> _AdmitPlans:
        """Pop admittable queue heads into reserved slots WITHOUT
        dispatching — the shared host half of both the standalone and the
        fleet-batched admission paths (identical plans keep the two modes in
        lockstep). Queue heads come out in the tiered weighted-deficit order
        (see ``TieredQueue``): high-weight tiers admit first, low-weight
        tiers keep a bounded share. Chunk-eligible prompts just reserve a
        slot + cursor; their first chunk runs in this step's chunk round —
        but a lower-tier chunk start *yields* the last free slot while
        higher-priority work is waiting (a long batch-tier prefill would
        otherwise hold the slot for ceil(len/chunk) ticks and lock premium
        traffic out)."""
        plans = _AdmitPlans([], [])
        if self.draining:
            return plans
        free = [i for i in range(self.max_batch) if self.slots[i] is None]
        deferred: set = set()         # tiers whose chunk start yielded
        while free:
            picked = self.queue.peek(deferred)
            if picked is None:
                break
            tier_idx, head = picked
            if head.out_of_time(self.clock):
                req = self.queue.pop(deferred)
                req.finish_time = self.clock
                plans.expired.append(req)
                continue
            if self._chunkable(head):
                if len(free) == 1 and self.queue.higher_waiting(tier_idx):
                    deferred.add(tier_idx)    # leave the slot for premium
                    continue
                req = self.queue.pop(deferred)
                slot = free.pop(0)
                self.slots[slot] = req
                self._chunks[slot] = _ChunkCursor(
                    req, req.prompt[-(self.max_seq - 1):])
                continue
            if not self.bucket_prompts or getattr(head, "extras", None):
                # exact-length single admit (audio / extras-carrying
                # requests, and moe replicas by default)
                plans.singles.append((free.pop(0), self.queue.pop(deferred)))
                continue
            group = []
            while len(group) < len(free):
                nxt = self.queue.peek(deferred)
                if nxt is None or getattr(nxt[1], "extras", None) \
                        or self._chunkable(nxt[1]) \
                        or nxt[1].out_of_time(self.clock):
                    break
                group.append(self.queue.pop(deferred))
            plans.bucketed.append(([free.pop(0) for _ in group], group))
        return plans

    def _admit(self, finished: list):
        """Standalone admission: plan, then dispatch this engine's own
        bucketed / exact-length prefill calls."""
        plans = self.plan_admission()
        finished.extend(plans.expired)
        for slot, req in plans.singles:
            self._admit_batch([slot], [req], finished, bucketed=False)
        for slots, reqs in plans.bucketed:
            self._admit_batch(slots, reqs, finished, bucketed=True)

    # --------------------------------------------------------------- chunks
    def _chunk_due(self) -> list:
        """Mid-chunk slots due to advance this step, tier-throttled: a
        cursor whose tier is strictly below some *decoding* slot's tier is
        "pressured" — its chunk compute would stretch the tick every one of
        those higher-tier slots' next token waits on. Under pressure at most
        ONE such low-tier cursor advances per step (the highest-priority,
        lowest-slot one), so a long batch-tier prefill streams through
        without inflating premium TBT by more than one chunk row. Cursors at
        or above every decoding tier (and everything in single-tier mode)
        advance unthrottled."""
        slots = sorted(self._chunks)
        if len(self.tiers) <= 1 or not slots:
            return slots
        decoding = [self.tiers.rank(req.tier)
                    for s, req in enumerate(self.slots)
                    if req is not None and s not in self._chunks]
        if not decoding:
            return slots
        best = min(decoding)                  # rank 0 = highest priority
        rank = lambda s: self.tiers.rank(self._chunks[s].req.tier)
        calm = [s for s in slots if rank(s) <= best]
        pressured = sorted((s for s in slots if rank(s) > best),
                           key=lambda s: (rank(s), s))
        return sorted(calm + pressured[:1])

    def _chunk_rows(self):
        """This step's chunk work items:
        (slot, toks (chunk_len,), offset, true_len, fresh, final)."""
        rows = []
        for slot in self._chunk_due():
            cur = self._chunks[slot]
            off = cur.consumed
            ln = min(self.chunk_len, len(cur.prompt) - off)
            toks = np.zeros(self.chunk_len, np.int32)
            toks[:ln] = cur.prompt[off:off + ln]
            rows.append((slot, toks, off, ln, off == 0,
                         off + ln >= len(cur.prompt)))
        return rows

    def commit_chunk(self, slot: int, first_tok, pos, final: bool,
                     finished: list):
        """Apply one chunk result: advance the cursor, or — on the final
        chunk — record the first generated token and hand the slot to the
        decode phase (or retire it immediately)."""
        cur = self._chunks[slot]
        if not final:
            cur.consumed += self.chunk_len
            return
        del self._chunks[slot]
        req = cur.req
        tok = int(first_tok)
        req.output.append(tok)
        req.first_token_time = self.clock
        if len(req.output) >= req.max_new_tokens or tok == req.eos_id \
                or req.out_of_time(self.clock):
            req.finish_time = self.clock
            finished.append(req)
            self.slots[slot] = None
            return
        self.pos[slot] = int(pos)
        self.last_tok[slot] = tok

    def _chunk_step(self, finished: list):
        """Advance every mid-chunk slot by one chunk in ONE batched
        dispatch (fleet members route through the fleet slab kernel)."""
        rows = self._chunk_rows()
        if not rows:
            return
        if self._fleet is not None:
            self._fleet._dispatch_fleet_chunk(
                [(self,) + row for row in rows], finished)
            return
        K, toks, offs, lens, fresh = _pack_chunk_rows(
            [(t, off, ln, fr) for _, t, off, ln, fr, _ in rows],
            self.chunk_len)
        slots = np.full(K, self.max_batch, np.int32)   # OOB pads -> dropped
        for i, (slot, *_rest) in enumerate(rows):
            slots[i] = slot
        first, pos, self.cache = self._kernels.chunk(
            self.params, self.cache, jnp.asarray(toks), jnp.asarray(offs),
            jnp.asarray(lens), jnp.asarray(fresh), jnp.asarray(slots))
        self.prefill_dispatches += 1
        first, pos = _timed_get(self, (first, pos))
        first, pos = np.asarray(first), np.asarray(pos)
        for i, (slot, t, off, ln, fr, fin) in enumerate(rows):
            self.commit_chunk(slot, first[i], pos[i], fin, finished)

    # ------------------------------------------------------------- stepping
    def begin_step(self, dt: float = 1.0, admit: bool = True) -> list:
        """Tick phase 1: advance the clock and admit from the queue. Returns
        requests that completed at prefill time. With ``admit=False`` only
        the clock moves — the caller batches admission across the fleet via
        ``FleetGroup.admit_round``. The decode phase follows via
        ``finish_step`` (standalone) or one ``FleetGroup.decode_round``."""
        self.clock += dt
        finished: list = []
        if admit:
            self._admit(finished)
            self._chunk_step(finished)
        return finished

    def finish_step(self) -> list:
        """Tick phase 2: one decode step for all active (non-chunking)
        slots."""
        if self._fleet is not None:    # device state lives in the fleet slab
            return self._fleet.decode_round({id(self)})
        if self.n_decoding == 0:
            return []
        toks = jnp.asarray(self.last_tok[:, None])
        pos = jnp.asarray(self.pos)
        if self._chunks:
            # mid-chunk slots must keep their carried state bit-for-bit
            hk = pow2_bucket(len(self._chunks))
            hslots = np.full(hk, self.max_batch, np.int32)  # OOB pads
            hslots[:len(self._chunks)] = sorted(self._chunks)
            logits, self.cache = self._kernels.decode_hold(
                self.params, self.cache, toks, pos, jnp.asarray(hslots))
        else:
            logits, self.cache = self._decode(self.params, self.cache, toks,
                                              pos)
        self.steps += 1
        finished: list = []
        next_toks = np.asarray(_timed_get(self, jnp.argmax(logits, axis=-1)))
        for slot, req in enumerate(self.slots):
            if req is None or slot in self._chunks:
                continue
            tok = int(next_toks[slot])
            req.output.append(tok)
            self.pos[slot] += 1
            self.last_tok[slot] = tok
            if (len(req.output) >= req.max_new_tokens or tok == req.eos_id
                    or self.pos[slot] >= self.max_seq - 1
                    or req.out_of_time(self.clock)):
                req.finish_time = self.clock
                finished.append(req)
                self.slots[slot] = None
        return finished

    def commit_decode(self, next_toks: np.ndarray, done: np.ndarray) -> list:
        """Apply one fleet decode result to the host-side slot bookkeeping.
        ``next_toks``/``done`` are this engine's (B,) rows of the batched
        sync; the retire mask was already computed on device. Mid-chunk
        slots were held on device and are skipped here."""
        finished: list = []
        stepped = False
        for slot, req in enumerate(self.slots):
            if req is None or slot in self._chunks:
                continue
            stepped = True
            tok = int(next_toks[slot])
            req.output.append(tok)
            self.pos[slot] += 1
            self.last_tok[slot] = tok
            if done[slot]:
                req.finish_time = self.clock
                finished.append(req)
                self.slots[slot] = None
        if stepped:
            self.steps += 1
        return finished

    def apply_decode(self, nxt: np.ndarray, done: np.ndarray,
                     stepped: np.ndarray, clock: float) -> list:
        """Apply one *async* fleet decode result at reconcile time: the
        device's ``stepped`` mask (not the possibly-stale host view) says
        which slots advanced, and ``clock`` is the dispatch-time clock that
        stamps finishes. Host mirrors update vectorized (numpy
        struct-of-arrays), python touches only the stepped slots."""
        idx = np.flatnonzero(stepped)
        if idx.size == 0:
            return []
        self.pos[idx] += 1
        self.last_tok[idx] = nxt[idx]
        self.steps += 1
        finished: list = []
        for s in idx:
            req = self.slots[s]
            req.output.append(int(nxt[s]))
            if done[s]:
                req.finish_time = clock
                finished.append(req)
                self.slots[s] = None
        return finished

    def step(self, dt: float = 1.0) -> list:
        """Admit + one decode step for all active slots. Returns finished
        (including requests that completed at prefill time)."""
        finished = self.begin_step(dt)
        finished.extend(self.finish_step())
        return finished


def normalize_fractions(fr: np.ndarray, mask: Optional[np.ndarray] = None
                        ) -> np.ndarray:
    """Simplex-normalize routing fractions with a uniform fallback — the
    numpy twin of ``core.balancer._mask_normalize``. Non-finite or negative
    entries are zeroed; a zero/NaN sum falls back to uniform over the mask.
    An all-false mask (every node/cell down — a full blackout tick) returns
    uniform-over-none, i.e. all zeros: callers must treat a zero-sum result
    as "nothing can serve" and park arrivals (retry pool / pending) rather
    than divide by the mask count — the old fallback silently routed
    uniform over DEAD nodes."""
    fr = np.asarray(fr, np.float64)
    fr = np.where(np.isfinite(fr) & (fr > 0.0), fr, 0.0)
    if mask is not None:
        m = np.asarray(mask, np.float64) > 0.0
        if not m.any():
            return np.zeros(fr.shape[0], np.float64)
        fr = fr * m
    s = fr.sum()
    if s <= 1e-12:
        if mask is not None:
            m = (np.asarray(mask) > 0).astype(np.float64)
            return m / m.sum()
        return np.full(fr.shape[0], 1.0 / fr.shape[0])
    return fr / s


class ClusterFrontend:
    """Routes requests to replicas via balancer fractions (or queue depth).

    ``fleet_batch=True`` stacks same-shape replicas into ``FleetGroup``s so a
    ``step`` issues one decode dispatch per group instead of one per replica
    (replicas that can't stack — different shapes — keep stepping solo).
    ``fleet_prefill`` (default: follows ``fleet_batch``) batches admission
    the same way: one prefill dispatch per distinct bucket shape per group;
    set it False to keep per-replica admission as the parity oracle."""

    def __init__(self, replicas: list, policy: str = "lc",
                 fractions_fn=None, seed: int = 0, fleet_batch: bool = False,
                 fleet_prefill: Optional[bool] = None, mesh=None):
        self.replicas = replicas
        self.policy = policy
        self.fractions_fn = fractions_fn
        self.rng = np.random.default_rng(seed)
        self.pending: deque = deque()
        self.finished: list = []
        self._rr = itertools.cycle(range(len(replicas)))
        self.fleets: dict = {}
        self.mesh = mesh
        self.fleet_prefill = fleet_batch if fleet_prefill is None \
            else (fleet_prefill and fleet_batch)
        if fleet_batch:
            for eng in replicas:
                g = self.fleets.get(eng.fleet_key)
                if g is None:
                    g = self.fleets[eng.fleet_key] = FleetGroup(
                        eng.model, eng.params, max_batch=eng.max_batch,
                        max_seq=eng.max_seq, cache_dtype=eng.cache_dtype,
                        attn_backend=eng.attn_backend, mesh=mesh)
                g.add(eng)

    def submit(self, req: Request):
        self.pending.append(req)

    def _route(self):
        while self.pending:
            req = self.pending.popleft()
            if self.policy == "rr":
                idx = next(self._rr)
            elif self.policy == "lc":
                loads = [r.load for r in self.replicas]
                idx = int(np.argmin(loads))
            elif self.policy == "fractions":
                fr = normalize_fractions(self.fractions_fn(self))
                idx = int(self.rng.choice(len(self.replicas), p=fr))
            else:
                raise ValueError(self.policy)
            self.replicas[idx].submit(req)

    def step(self, dt: float = 1.0):
        self._route()
        if not self.fleets:
            for r in self.replicas:
                self.finished.extend(r.step(dt))
            return
        for r in self.replicas:
            self.finished.extend(r.begin_step(
                dt, admit=r._fleet is None or not self.fleet_prefill))
        if self.fleet_prefill:
            for g in self.fleets.values():
                self.finished.extend(g.admit_round())
        for g in self.fleets.values():
            self.finished.extend(g.decode_round())
        for r in self.replicas:          # replicas outside any fleet
            if r._fleet is None:
                self.finished.extend(r.finish_step())

    def run_until_drained(self, max_steps: int = 10_000):
        for _ in range(max_steps):
            self.step()
            if not self.pending and all(r.load == 0 for r in self.replicas):
                return
        raise RuntimeError("engine did not drain")
