"""Request-level serving engine: continuous batching over real model forwards.

``ReplicaEngine`` runs one model replica: slot-based KV/state pool, per-slot
positions (the vector-``pos`` decode path), admit-on-free-slot, greedy
sampling, retire-on-EOS/max-tokens. ``ClusterFrontend`` stitches several
replicas together behind a balancer policy (the paper's RL allocation or the
baselines) — this is the live counterpart of the fluid simulator, used by the
integration tests and examples with reduced-config models on CPU.
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list
    max_new_tokens: int = 16
    eos_id: int = -1               # -1: never stop early
    arrival: float = 0.0
    # filled by the engine
    output: list = dataclasses.field(default_factory=list)
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None

    @property
    def done(self) -> bool:
        return self.finish_time is not None


class ReplicaEngine:
    def __init__(self, model: Model, params, *, max_batch: int = 4,
                 max_seq: int = 256, cache_dtype=jnp.float32, rid: int = 0):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.rid = rid
        self.cache = model.init_serve_state(max_batch, max_seq, cache_dtype)
        self.pos = np.zeros(max_batch, np.int32)       # next cache index
        self.last_tok = np.zeros(max_batch, np.int32)
        self.slots: list = [None] * max_batch
        self.queue: deque = deque()
        self.clock = 0.0
        self.steps = 0

        self._decode = jax.jit(
            lambda p, st, tok, pos: model.decode(p, st, tok, pos))
        self._prefill = jax.jit(
            lambda p, batch: model.prefill(p, batch, cache_len=max_seq,
                                           cache_dtype=cache_dtype))

    # ----------------------------------------------------------------- load
    @property
    def n_active(self) -> int:
        return sum(s is not None for s in self.slots)

    @property
    def load(self) -> int:
        return self.n_active + len(self.queue)

    def submit(self, req: Request):
        self.queue.append(req)

    # ------------------------------------------------------------- plumbing
    def _insert_slot(self, slot: int, small_state, prompt_len: int,
                     first_tok: int, req: Request):
        def put(big, small):
            return big.at[:, slot].set(small[:, 0])
        self.cache = jax.tree.map(put, self.cache, small_state)
        self.pos[slot] = prompt_len
        self.last_tok[slot] = first_tok
        self.slots[slot] = req

    def _admit(self):
        for slot in range(self.max_batch):
            if self.slots[slot] is None and self.queue:
                req = self.queue.popleft()
                batch = {"tokens": jnp.asarray([req.prompt], jnp.int32)}
                extras = getattr(req, "extras", None)
                if extras:
                    batch.update({k: jnp.asarray(v) for k, v in extras.items()})
                logits, small, plen = self._prefill(self.params, batch)
                tok = int(jnp.argmax(logits[0]))
                req.output.append(tok)
                req.first_token_time = self.clock
                if len(req.output) >= req.max_new_tokens or tok == req.eos_id:
                    req.finish_time = self.clock
                    continue
                self._insert_slot(slot, small, int(plen), tok, req)

    def step(self, dt: float = 1.0) -> list:
        """Admit + one decode step for all active slots. Returns finished."""
        self.clock += dt
        self._admit()
        finished = []
        if self.n_active == 0:
            return finished
        toks = jnp.asarray(self.last_tok[:, None])
        pos = jnp.asarray(self.pos)
        logits, self.cache = self._decode(self.params, self.cache, toks, pos)
        self.steps += 1
        next_toks = np.asarray(jnp.argmax(logits, axis=-1))
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            tok = int(next_toks[slot])
            req.output.append(tok)
            self.pos[slot] += 1
            self.last_tok[slot] = tok
            if (len(req.output) >= req.max_new_tokens or tok == req.eos_id
                    or self.pos[slot] >= self.max_seq - 1):
                req.finish_time = self.clock
                finished.append(req)
                self.slots[slot] = None
        return finished


class ClusterFrontend:
    """Routes requests to replicas via balancer fractions (or queue depth)."""

    def __init__(self, replicas: list, policy: str = "lc",
                 fractions_fn=None, seed: int = 0):
        self.replicas = replicas
        self.policy = policy
        self.fractions_fn = fractions_fn
        self.rng = np.random.default_rng(seed)
        self.pending: deque = deque()
        self.finished: list = []
        self._rr = itertools.cycle(range(len(replicas)))

    def submit(self, req: Request):
        self.pending.append(req)

    def _route(self):
        while self.pending:
            req = self.pending.popleft()
            if self.policy == "rr":
                idx = next(self._rr)
            elif self.policy == "lc":
                loads = [r.load for r in self.replicas]
                idx = int(np.argmin(loads))
            elif self.policy == "fractions":
                fr = np.asarray(self.fractions_fn(self))
                fr = fr / fr.sum()
                idx = int(self.rng.choice(len(self.replicas), p=fr))
            else:
                raise ValueError(self.policy)
            self.replicas[idx].submit(req)

    def step(self, dt: float = 1.0):
        self._route()
        for r in self.replicas:
            self.finished.extend(r.step(dt))

    def run_until_drained(self, max_steps: int = 10_000):
        for _ in range(max_steps):
            self.step()
            if not self.pending and all(r.load == 0 for r in self.replicas):
                return
        raise RuntimeError("engine did not drain")
