"""Request-level serving engine: continuous batching over real model forwards.

``ReplicaEngine`` runs one model replica: slot-based KV/state pool, per-slot
positions (the vector-``pos`` decode path), admit-on-free-slot, greedy
sampling, retire-on-EOS/max-tokens. Prompts are right-padded to power-of-two
length buckets and admitted in batched prefill calls, so the jit'd prefill
compiles O(log max_seq · log max_batch) times total instead of once per
distinct prompt length (``prefill_traces`` counts actual retraces). Padded
prefill is exact for dense/ssm/hybrid: causal attention masks trailing pads
and the SSM path zeroes dt at pad positions (see
``models.ssd.mamba2_forward``). MoE buckets too but is exact only when no
expert-capacity drops occur (capacity scales with the padded length).

``ClusterFrontend`` stitches several replicas together behind a balancer
policy — the live counterpart of the fluid simulator. The node-structured
elastic frontend that plugs into the unified control plane lives in
``repro.serving.elastic``.
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model

# families whose prefill accepts per-row ``lengths`` (bucketed prompts are
# exact). audio prefill is driven by encoder frames and stays exact-length;
# vlm requests carry patch-embed extras, which take the single-admit path
# below (batching per-request extras is future work).
_BUCKET_FAMILIES = ("dense", "moe", "ssm", "hybrid")


def pow2_bucket(n: int, lo: int = 1) -> int:
    """Smallest power of two >= n (and >= lo)."""
    b = lo
    while b < n:
        b <<= 1
    return b


class _ServeKernels:
    """Shared jit'd prefill/decode for one (model, max_seq, cache_dtype):
    replicas of the same model reuse compiled code instead of re-jitting on
    every cold start (a scale-up would otherwise stall the tick loop on XLA
    compilation of identical shapes). ``traces`` counts actual prefill
    compilations across every replica that shares this object."""
    __slots__ = ("prefill", "decode", "traces")


def get_serve_kernels(model: Model, max_seq: int, cache_dtype) -> _ServeKernels:
    # The cache lives on the Model instance (not a module global) so compiled
    # executables are reclaimed with the model instead of pinned forever.
    cache = getattr(model, "_serve_kernels", None)
    if cache is None:
        cache = {}
        object.__setattr__(model, "_serve_kernels", cache)  # frozen dataclass
    key = (max_seq, np.dtype(cache_dtype).name)
    k = cache.get(key)
    if k is not None:
        return k
    k = _ServeKernels()
    k.traces = 0

    def _prefill_fn(p, batch):
        k.traces += 1              # runs at trace time only
        return model.prefill(p, batch, cache_len=max_seq,
                             cache_dtype=cache_dtype)

    k.prefill = jax.jit(_prefill_fn)
    k.decode = jax.jit(lambda p, st, tok, pos: model.decode(p, st, tok, pos))
    cache[key] = k
    return k


def total_prefill_traces(engines) -> int:
    """Global prefill compile count, deduped across replicas that share
    kernels (each replica reports its shared counter)."""
    seen = {id(e._kernels): e._kernels.traces for e in engines}
    return sum(seen.values())


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list
    max_new_tokens: int = 16
    eos_id: int = -1               # -1: never stop early
    arrival: float = 0.0
    # filled by the engine
    output: list = dataclasses.field(default_factory=list)
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None

    @property
    def done(self) -> bool:
        return self.finish_time is not None

    def reset_progress(self):
        """Forget generation progress (replica failure -> re-queue)."""
        self.output = []
        self.first_token_time = None
        self.finish_time = None


class ReplicaEngine:
    def __init__(self, model: Model, params, *, max_batch: int = 4,
                 max_seq: int = 256, cache_dtype=jnp.float32, rid: int = 0,
                 speed: float = 1.0, min_bucket: int = 8,
                 bucket_prompts: Optional[bool] = None):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.rid = rid
        self.speed = speed            # relative decode speed (hetero hardware)
        self.min_bucket = min_bucket
        self.draining = False         # drained replicas admit nothing new
        self.cache = model.init_serve_state(max_batch, max_seq, cache_dtype)
        self.pos = np.zeros(max_batch, np.int32)       # next cache index
        self.last_tok = np.zeros(max_batch, np.int32)
        self.slots: list = [None] * max_batch
        self.queue: deque = deque()
        self.clock = 0.0
        self.steps = 0
        if bucket_prompts is None:
            bucket_prompts = model.cfg.family in _BUCKET_FAMILIES
        self.bucket_prompts = bucket_prompts
        self._kernels = get_serve_kernels(model, max_seq, cache_dtype)
        self._prefill = self._kernels.prefill
        self._decode = self._kernels.decode

    @property
    def prefill_traces(self) -> int:
        """Prefill compilations of this replica's (shared) kernels."""
        return self._kernels.traces

    # ----------------------------------------------------------------- load
    @property
    def n_active(self) -> int:
        return sum(s is not None for s in self.slots)

    @property
    def load(self) -> int:
        return self.n_active + len(self.queue)

    def submit(self, req: Request):
        self.queue.append(req)

    def evacuate(self) -> list:
        """Failure path: pull every in-flight + queued request off this
        replica (generation progress is lost) so the caller can re-queue."""
        lost = [r for r in self.slots if r is not None] + list(self.queue)
        self.slots = [None] * self.max_batch
        self.queue.clear()
        for r in lost:
            r.reset_progress()
        return lost

    # ------------------------------------------------------------- plumbing
    def _insert_slot(self, slot: int, small_state, row: int, prompt_len: int,
                     first_tok: int, req: Request):
        def put(big, small):
            return big.at[:, slot].set(small[:, row])
        self.cache = jax.tree.map(put, self.cache, small_state)
        self.pos[slot] = prompt_len
        self.last_tok[slot] = first_tok
        self.slots[slot] = req

    def _admit_batch(self, slots: list, reqs: list, finished: list,
                     bucketed: bool):
        if bucketed:
            lens = [len(r.prompt) for r in reqs]
            sb = min(pow2_bucket(max(lens), self.min_bucket), self.max_seq)
            kb = pow2_bucket(len(reqs))
            toks = np.zeros((kb, sb), np.int32)
            lengths = np.ones(kb, np.int32)    # pad rows: length-1 dummies
            for i, r in enumerate(reqs):
                toks[i, :len(r.prompt)] = r.prompt
                lengths[i] = len(r.prompt)
            batch = {"tokens": jnp.asarray(toks),
                     "lengths": jnp.asarray(lengths)}
            logits, small, plen = self._prefill(self.params, batch)
            plen = np.asarray(plen)
        else:
            req = reqs[0]
            batch = {"tokens": jnp.asarray([req.prompt], jnp.int32)}
            extras = getattr(req, "extras", None)
            if extras:
                batch.update({k: jnp.asarray(v) for k, v in extras.items()})
            logits, small, plen = self._prefill(self.params, batch)
            plen = np.full(1, int(plen), np.int32)
        first = np.asarray(jnp.argmax(logits, axis=-1))
        for i, (slot, req) in enumerate(zip(slots, reqs)):
            tok = int(first[i])
            req.output.append(tok)
            req.first_token_time = self.clock
            if len(req.output) >= req.max_new_tokens or tok == req.eos_id:
                req.finish_time = self.clock
                finished.append(req)
                continue
            self._insert_slot(slot, small, i, int(plen[i]), tok, req)

    def _admit(self, finished: list):
        if self.draining:
            return
        free = [i for i in range(self.max_batch) if self.slots[i] is None]
        while free and self.queue:
            head_has_extras = getattr(self.queue[0], "extras", None)
            if not self.bucket_prompts or head_has_extras:
                # exact-length single admit (audio / extras-carrying requests)
                self._admit_batch([free.pop(0)], [self.queue.popleft()],
                                  finished, bucketed=False)
                continue
            group = []
            while (self.queue and len(group) < len(free)
                   and not getattr(self.queue[0], "extras", None)):
                group.append(self.queue.popleft())
            self._admit_batch([free.pop(0) for _ in group], group,
                              finished, bucketed=True)

    def step(self, dt: float = 1.0) -> list:
        """Admit + one decode step for all active slots. Returns finished
        (including requests that completed at prefill time)."""
        self.clock += dt
        finished: list = []
        self._admit(finished)
        if self.n_active == 0:
            return finished
        toks = jnp.asarray(self.last_tok[:, None])
        pos = jnp.asarray(self.pos)
        logits, self.cache = self._decode(self.params, self.cache, toks, pos)
        self.steps += 1
        next_toks = np.asarray(jnp.argmax(logits, axis=-1))
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            tok = int(next_toks[slot])
            req.output.append(tok)
            self.pos[slot] += 1
            self.last_tok[slot] = tok
            if (len(req.output) >= req.max_new_tokens or tok == req.eos_id
                    or self.pos[slot] >= self.max_seq - 1):
                req.finish_time = self.clock
                finished.append(req)
                self.slots[slot] = None
        return finished


def normalize_fractions(fr: np.ndarray, mask: Optional[np.ndarray] = None
                        ) -> np.ndarray:
    """Simplex-normalize routing fractions with a uniform fallback — the
    numpy twin of ``core.balancer._mask_normalize``. Non-finite or negative
    entries are zeroed; a zero/NaN sum falls back to uniform over the mask."""
    fr = np.asarray(fr, np.float64)
    fr = np.where(np.isfinite(fr) & (fr > 0.0), fr, 0.0)
    if mask is not None:
        fr = fr * (np.asarray(mask, np.float64) > 0.0)
    s = fr.sum()
    if s <= 1e-12:
        if mask is not None and (np.asarray(mask) > 0).any():
            m = (np.asarray(mask) > 0).astype(np.float64)
            return m / m.sum()
        return np.full(fr.shape[0], 1.0 / fr.shape[0])
    return fr / s


class ClusterFrontend:
    """Routes requests to replicas via balancer fractions (or queue depth)."""

    def __init__(self, replicas: list, policy: str = "lc",
                 fractions_fn=None, seed: int = 0):
        self.replicas = replicas
        self.policy = policy
        self.fractions_fn = fractions_fn
        self.rng = np.random.default_rng(seed)
        self.pending: deque = deque()
        self.finished: list = []
        self._rr = itertools.cycle(range(len(replicas)))

    def submit(self, req: Request):
        self.pending.append(req)

    def _route(self):
        while self.pending:
            req = self.pending.popleft()
            if self.policy == "rr":
                idx = next(self._rr)
            elif self.policy == "lc":
                loads = [r.load for r in self.replicas]
                idx = int(np.argmin(loads))
            elif self.policy == "fractions":
                fr = normalize_fractions(self.fractions_fn(self))
                idx = int(self.rng.choice(len(self.replicas), p=fr))
            else:
                raise ValueError(self.policy)
            self.replicas[idx].submit(req)

    def step(self, dt: float = 1.0):
        self._route()
        for r in self.replicas:
            self.finished.extend(r.step(dt))

    def run_until_drained(self, max_steps: int = 10_000):
        for _ in range(max_steps):
            self.step()
            if not self.pending and all(r.load == 0 for r in self.replicas):
                return
        raise RuntimeError("engine did not drain")
