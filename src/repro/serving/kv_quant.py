"""Int8 KV-cache quantization (per-token, per-head scales).

Backs §Perf cell C iteration 2: halves the decode memory stream. Writes
quantize each new (token, head) k/v vector to int8 with an f32 absmax scale;
reads dequantize on the fly (the matmul runs in bf16/f32 — v5e has no int8
MXU path exposed via XLA, so the win is HBM bytes, which is exactly what
decode is bound by).

Error model: absmax int8 over head_dim-sized vectors keeps relative error
~0.4%/√d; the attention-output error bound is checked by
tests/test_kv_quant.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize(x, axis=-1):
    """x: (..., d) -> (int8 values, f32 scales with `axis` reduced)."""
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis,
                     keepdims=True)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale.squeeze(axis).astype(jnp.float32)


def dequantize(q, scale, axis=-1):
    return q.astype(jnp.float32) * jnp.expand_dims(scale, axis)


def init_quant_kv_cache(batch: int, max_len: int, n_kv: int, head_dim: int):
    """Quantized analogue of attention.init_kv_cache."""
    return {
        "k_q": jnp.zeros((batch, max_len, n_kv, head_dim), jnp.int8),
        "v_q": jnp.zeros((batch, max_len, n_kv, head_dim), jnp.int8),
        "k_s": jnp.ones((batch, max_len, n_kv), jnp.float32),
        "v_s": jnp.ones((batch, max_len, n_kv), jnp.float32),
    }


def write_kv_quant(cache, k_new, v_new, pos):
    """Write one token's k/v (B, 1, G, d) at `pos` (scalar, or (B,) for the
    per-slot vector-``pos`` serving path)."""
    kq, ks = quantize(k_new)
    vq, vs = quantize(v_new)
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 1:
        rows = jnp.arange(cache["k_q"].shape[0])
        return {
            "k_q": cache["k_q"].at[rows, pos].set(kq[:, 0]),
            "v_q": cache["v_q"].at[rows, pos].set(vq[:, 0]),
            "k_s": cache["k_s"].at[rows, pos].set(ks[:, 0]),
            "v_s": cache["v_s"].at[rows, pos].set(vs[:, 0]),
        }
    upd = jax.lax.dynamic_update_slice
    return {
        "k_q": upd(cache["k_q"], kq, (0, pos, 0, 0)),
        "v_q": upd(cache["v_q"], vq, (0, pos, 0, 0)),
        "k_s": upd(cache["k_s"], ks, (0, pos, 0)),
        "v_s": upd(cache["v_s"], vs, (0, pos, 0)),
    }


def decode_attend_quant(q, cache, pos):
    """Single-token GQA attention over the quantized cache.

    q: (B, G, qpg, d); returns (B, G, qpg, d). Dequantizes K/V tile-wise —
    on TPU the dequant fuses into the VMEM load epilogue, so HBM traffic is
    the int8 bytes + scales (~half of bf16).
    """
    import numpy as np
    k = dequantize(cache["k_q"], cache["k_s"])      # (B, S, G, d) f32
    v = dequantize(cache["v_q"], cache["v_s"])
    d = q.shape[-1]
    s = jnp.einsum("bgqh,btgh->bgqt", q.astype(jnp.float32), k) / np.sqrt(d)
    mask = jnp.arange(k.shape[1]) <= pos
    s = jnp.where(mask[None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bgqt,btgh->bgqh", p, v).astype(q.dtype)
