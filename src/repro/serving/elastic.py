"""``ElasticClusterFrontend``: a request-level ``ClusterBackend`` over real
model replicas.

N serving nodes, each holding a mutable group of ``ReplicaEngine``s (real CPU
model forwards), driven by the same ``ControlPlane`` that drives the fluid
simulator. Operational semantics mirror ``ClusterSim``:

  * **cold start** — ``scale_to`` additions pass through a provisioning
    pipeline and only serve after ``provisioning_delay`` ticks;
  * **graceful drain** — removals stop admitting, hand queued work back to
    the node, finish their in-flight slots, then retire (no request is ever
    dropped by a scale-down);
  * **failure injection** — a failed replica loses its generation progress;
    every in-flight + queued request is reset and re-queued at the front of
    the node queue (``fail_replica`` for deterministic tests, ``failure_rate``
    for Bernoulli-per-tick injection);
  * **heterogeneity** — the replica factory may vary ``max_batch`` and
    ``speed`` per replica; speed>1 replicas run multiple decode sub-steps per
    tick via a credit accumulator, speed<1 skip ticks.

Work units: a node's "queue depth" is its count of unfinished requests, its
"capacity" is decode slots/tick (sum of ``max_batch * speed`` over live
replicas). Response times are measured end-to-end in ticks on finished
requests, with a queueing-theory estimate filling ticks where nothing
finishes, so the control plane sees the same metric names and shapes as the
fluid backend.

**Fleet-batched ticks** (default): live + draining replicas that share a
``(model, params, max_batch, max_seq, cache_dtype)`` are stacked into
``FleetGroup``s — across node boundaries — so one tick advances every
replica of a group with ONE jitted decode dispatch and one small batched
host sync, instead of a Python-dispatched jit call + per-slot ``int()``
syncs per replica. Groups survive scale-up (slab rows grow in pow2 steps),
graceful drain (a draining member keeps decoding in the fleet until empty)
and failure (its row is dropped and backfilled). Heterogeneous speeds run
as sub-step *rounds*: a round where only a subset of a group steps uses the
masked fleet kernel so non-stepping rows' state is untouched. Set
``fleet_batch=False`` to recover the per-replica ``step()`` loop (the
parity oracle). ``metrics()['decode_dispatches']`` counts this tick's
dispatches; the fleet path also feeds the measured per-replica service-rate
EMA (``metrics()['service_rate']``) that the control plane hands to the
GPSO planner once warm.

**Fleet-batched admission** (default with ``fleet_batch``): each round,
every stepping member *plans* its admissions on the host and the group
coordinator batches them — one jitted ``fleet_prefill`` per distinct pow2
length bucket across ALL nodes, writing admit rows
straight into the fleet slab, plus one ``fleet_chunk`` dispatch advancing
every mid-chunk long prompt (``ReplicaEngine(chunk_len=...)``). Cold-queue
admission cost is therefore O(distinct bucket shapes) per tick instead of
O(replicas). ``metrics()['prefill_dispatches']`` counts this tick's
admission dispatches (mirroring ``decode_dispatches``); set
``fleet_prefill=False`` to keep per-replica admission as the A/B oracle.

**Overlapped async ticks** (default with fleet batching): the fleet
dispatch methods stop blocking on the device — decode/prefill/chunk results
stay on the accelerator as pending futures (with the decode operands
persistent on device, see ``engine`` module docstring) and the deferred host
bookkeeping applies at ONE reconcile sync at the next tick's start. The host
half of tick *t* (metrics, queues, tier accounting, the control plane's
forecast→balance→scale) therefore overlaps the device computing tick *t*'s
decode: steady-state cost is ``max(host, device)`` instead of their sum, at
one blocking sync per fleet group per tick (``metrics()['syncs']``,
mirroring ``decode_dispatches``; ``metrics()['sync_wait_s']`` is the wall
time actually blocked — the host-vs-device tick breakdown). Token streams
and finish ticks are bit-identical to ``async_tick=False`` (the eager parity
oracle); only host-side *observation* — per-tick ``served``/latency metrics,
drained detection — lags by one tick, and since retires reconcile before
admission planning, a slot freed by tick *t*'s decode admits at *t+1*
exactly like the eager path (admission lags device state by at most one
tick under a full slab). Membership churn (drain retire, failure, scale-up)
force-flushes pending futures before rows unstack. ``decode_block=K``
additionally fuses K decode micro-steps into one dispatch+sync on ticks
with no pending admissions or chunk cursors, dropping syncs/tick to 1/K in
the saturated-decode regime — at the cost that a slot retiring mid-block
re-admits only at the block-end reconcile (admission lag <= K-1 ticks
under a full slab; see the engine docstring).

**Fleet-mesh sharding.** Pass ``mesh=`` (a mesh with a ``fleet`` axis,
e.g. ``launch.mesh.make_fleet_mesh``) and every fleet group shards its
slab's fleet axis over the N devices, so F replicas genuinely decode in
parallel — same ONE logical dispatch per tick (GSPMD partitions it, the
host still issues one), same ≤1 reconcile sync, bit-identical streams.
The contract lives in ``FleetGroup``: slab capacity stays a multiple of
the shard count (pad rows masked inactive, excluded from dispatch/retire
accounting), churn (scale-up growth, drain retire, failure row-drop)
keeps live rows dense so they re-balance across contiguous shard blocks,
and membership changes force-flush pending futures exactly like the
unsharded async path. Params replicate across the fleet axis. On CPU the
N devices are virtual (``--xla_force_host_platform_device_count``, set
before jax initializes — see ``launch/serve.py --devices``).

**SLO tiers.** Pass a ``workload.trace.TierSet`` (and create replicas with
the same ``tiers=``) to serve several QoS classes over one pool: every
replica queue becomes a weighted-deficit ``TieredQueue`` (premium admits
first, batch keeps a bounded non-starving share) and ``metrics()`` grows the
per-tier view the control plane observes — ``tier_queue`` (T, N) depths,
``tier_pressure`` (N,) weighted backlog for the GPSO SLO cost term,
``tier_ttft``/``tier_tbt`` means over this tick's completions,
``tier_served`` counts and the scalar ``tier_slo_cost`` feeding the
tier-weighted Eq.5 reward. Re-queue paths (drain hand-back, failure
evacuation, dead-node re-route) merge work back in original-arrival order,
so churn never scrambles the starvation accounting. Tiering reorders which
requests admit first; the fleet dispatch bounds (one decode dispatch per
group per tick, one prefill dispatch per distinct bucket shape) are
untouched.
"""
from __future__ import annotations

from collections import deque
from typing import Callable, Optional

import numpy as np

from repro.serving.engine import (FleetGroup, ReplicaEngine, Request,
                                  TieredQueue, normalize_fractions)
from repro.workload.trace import DEFAULT_TIERS, TierSet

_SERVICE_RATE_WARMUP = 8       # measured-rate ticks before the EMA is trusted
_SERVICE_RATE_ALPHA = 0.1


def _requeue_merged(queue, reqs) -> None:
    """Merge re-queued work back into ``queue`` (deque or TieredQueue)
    preserving *global* arrival order (rid tiebreak). Drain hand-backs and
    failure re-queues must not append or prepend blindly: either loses the
    original arrival ordering the tiered starvation accounting (and plain
    FIFO fairness) relies on."""
    merged = sorted(list(queue) + list(reqs),
                    key=lambda r: (r.arrival, r.rid))
    queue.clear()
    for r in merged:
        queue.append(r)


class _Node:
    __slots__ = ("live", "draining", "spawning", "queue", "credit")

    def __init__(self, tiers: TierSet):
        self.live: list = []        # serving ReplicaEngines
        self.draining: list = []    # finishing in-flight work, no admits
        self.spawning: list = []    # remaining cold-start ticks per add
        # node-level request queue: tier-aware (the deep backlog lives here
        # — replica queues only buffer up to max_batch), single-tier == FIFO
        self.queue: TieredQueue = TieredQueue(tiers)
        self.credit: dict = {}      # engine id -> fractional step credit

    def unfinished(self) -> int:
        return len(self.queue) + sum(e.load for e in self.live) + \
            sum(e.load for e in self.draining)


class ElasticClusterFrontend:
    """Node-structured elastic serving cluster (see module docstring)."""

    def __init__(self, make_replica: Callable[[int], ReplicaEngine],
                 num_nodes: int, *, initial_replicas: int = 1,
                 provisioning_delay: int = 0,
                 max_replicas_per_node: int = 8,
                 failure_rate: float = 0.0,
                 request_factory: Optional[Callable[[int, int], Request]] = None,
                 tick_seconds: float = 1.0, seed: int = 0,
                 est_tokens: float = 8.0, fleet_batch: bool = True,
                 fleet_prefill: bool = True, async_tick: bool = True,
                 decode_block: int = 1,
                 tiers: Optional[TierSet] = None, mesh=None):
        self.make_replica = make_replica
        self.num_nodes = num_nodes
        self.tiers = tiers or DEFAULT_TIERS
        self.provisioning_delay = int(provisioning_delay)
        self.max_replicas_per_node = max_replicas_per_node
        self.failure_rate = failure_rate
        self.request_factory = request_factory
        self.tick_seconds = tick_seconds
        self.fleet_batch = fleet_batch
        self.fleet_prefill = fleet_prefill and fleet_batch
        # serving mesh with a 'fleet' axis: fleet groups shard their slab's
        # fleet axis over it (N devices decode F replicas in parallel; see
        # FleetGroup's shard contract — capacity stays divisible by the
        # shard count, churn re-balances dense rows, params replicate)
        self.mesh = mesh if fleet_batch else None
        # the async tick needs the fleet dispatch paths end to end: with
        # either oracle mode (per-replica decode or per-replica admission)
        # the tick falls back to eager, blocking syncs
        self.async_tick = bool(async_tick) and self.fleet_prefill
        self.decode_block = max(1, int(decode_block)) if self.async_tick \
            else 1
        self.rng = np.random.default_rng(seed)
        self.nodes = [_Node(self.tiers) for _ in range(num_nodes)]
        self._rid = 0                # engine ids (replicas ever created)
        self._req_id = 0             # auto-generated request ids
        self._acc = 0.0              # fractional-arrival accumulator
        self.t = 0
        self.pending: deque = deque()
        self.finished: list = []
        self.failed_replicas = 0
        self.replica_ticks = 0
        self._fractions = np.full(num_nodes, 1.0 / num_nodes, np.float32)
        self._m: dict = {}
        self._est_tokens = float(est_tokens)  # EMA of tokens per request
        self._resp_est = 0.0
        self._kernel_objs: dict = {}
        self._fleets: dict = {}      # fleet_key -> FleetGroup (spans nodes)
        self._tick_dispatches = 0    # decode dispatches issued this tick
        self._tick_prefill_dispatches = 0  # admission dispatches this tick
        self._tick_syncs = 0         # blocking host syncs this tick
        self._tick_sync_wait = 0.0   # seconds blocked on device this tick
        self._retired_dispatches = 0  # dispatch counts of evicted groups
        self._retired_prefill_dispatches = 0  # of evicted groups + engines
        self._retired_syncs = 0      # sync counts of evicted groups/engines
        self._retired_sync_wait = 0.0
        self._async_stash: list = []  # finishes flushed by mid-tick churn
        self._srv_rate: Optional[float] = None  # per-replica req/tick EMA
        self._srv_obs = 0            # ticks the EMA has been fed
        for node in self.nodes:
            for _ in range(initial_replicas):
                self._go_live(node)

    # ----------------------------------------------------------- plumbing
    def _spawn(self) -> ReplicaEngine:
        eng = self.make_replica(self._rid)
        self._rid += 1
        # remember the (shared) serve kernels so compile counts survive
        # replica retirement/failure
        self._kernel_objs[id(eng._kernels)] = eng._kernels
        return eng

    def _go_live(self, node: _Node) -> ReplicaEngine:
        """Spawn a replica onto ``node`` and enroll it in its fleet group
        (groups span nodes: the fleet axis is per model-shape, not per
        node)."""
        eng = self._spawn()
        node.live.append(eng)
        if self.fleet_batch:
            g = self._fleets.get(eng.fleet_key)
            if g is None:
                g = self._fleets[eng.fleet_key] = FleetGroup(
                    eng.model, eng.params, max_batch=eng.max_batch,
                    max_seq=eng.max_seq, cache_dtype=eng.cache_dtype,
                    async_mode=self.async_tick,
                    decode_block=self.decode_block,
                    attn_backend=eng.attn_backend, mesh=self.mesh)
            g.add(eng)
        return eng

    def _leave_fleet(self, eng: ReplicaEngine, restore: bool):
        g = eng._fleet
        if g is None:
            return
        g.remove(eng, restore=restore)  # flushes the group's pending futures
        if not g.members:
            # evict the empty group so its high-water-mark slab doesn't pin
            # device memory forever (a re-spawn re-allocates from zeros)
            self._async_stash.extend(g.reconcile(force=True))
            self._retired_dispatches += g.dispatches
            self._retired_prefill_dispatches += g.prefill_dispatches
            self._retired_syncs += g.syncs
            self._retired_sync_wait += g.sync_wait
            self._fleets = {k: v for k, v in self._fleets.items()
                            if v is not g}

    def prefill_retraces(self) -> int:
        """Prefill-side compilations across every replica ever spawned —
        one deduped accounting over the bucketed, fleet-batched and chunked
        kernel variants (kernels are shared per model config, so retired
        replicas still count)."""
        return sum(k.prefill_traces for k in self._kernel_objs.values())

    def serve_kernel_traces(self) -> int:
        """Compilations across *every* serve-kernel variant (prefill +
        decode + fleet + chunk), deduped the same way."""
        return sum(k.total_traces for k in self._kernel_objs.values())

    def decode_dispatches(self) -> int:
        """Total jitted fleet decode dispatches issued (fleet mode),
        including groups since evicted."""
        return self._retired_dispatches + \
            sum(g.dispatches for g in self._fleets.values())

    def prefill_dispatches(self) -> int:
        """Total jitted admission dispatches issued: per-engine bucketed /
        exact-length / chunk calls plus the fleet-batched prefill and chunk
        dispatches, including retired engines and evicted groups."""
        live = sum(e.prefill_dispatches
                   for n in self.nodes for e in n.live + n.draining)
        return self._retired_prefill_dispatches + live + \
            sum(g.prefill_dispatches for g in self._fleets.values())

    def sync_count(self) -> int:
        """Total blocking host syncs performed (group reconciles + eager
        fetches), including retired engines and evicted groups — the async
        tick's ``syncs`` currency, mirroring ``decode_dispatches``."""
        live = sum(e.syncs for n in self.nodes for e in n.live + n.draining)
        return self._retired_syncs + live + \
            sum(g.syncs for g in self._fleets.values())

    def sync_wait_s(self) -> float:
        """Total wall seconds the host spent *blocked* on device results —
        the device half of the tick-wall breakdown (host half = tick wall
        minus this)."""
        live = sum(e.sync_wait
                   for n in self.nodes for e in n.live + n.draining)
        return self._retired_sync_wait + live + \
            sum(g.sync_wait for g in self._fleets.values())

    def _reconcile_all(self) -> list:
        """The per-tick reconcile point: flush every fleet group's pending
        device futures (one blocking sync per group) and collect the newly
        finished requests, plus any stashed by mid-tick churn flushes."""
        out, self._async_stash = self._async_stash, []
        for g in list(self._fleets.values()):
            out.extend(g.reconcile())
        return out

    @property
    def replicas(self) -> list:
        """All live replicas (diagnostics)."""
        return [e for n in self.nodes for e in n.live]

    @property
    def replicas_spawned(self) -> int:
        """Replicas ever created (incl. failed/retired ones)."""
        return self._rid

    def submit(self, req: Request):
        if req.arrival == 0.0:
            req.arrival = float(self.t)
        self.pending.append(req)

    # ------------------------------------------------- ClusterBackend API
    def up_mask(self) -> np.ndarray:
        return np.asarray([1.0 if n.live else 0.0 for n in self.nodes],
                          np.float32)

    def queue_depths(self) -> np.ndarray:
        return np.asarray([n.unfinished() for n in self.nodes], np.float32)

    def capacity(self) -> np.ndarray:
        """Decode slots/tick per node (live replicas only)."""
        return np.asarray(
            [sum(e.max_batch * e.speed for e in n.live) for n in self.nodes],
            np.float32)

    def request_capacity(self) -> np.ndarray:
        """Requests/tick per node at the current mean output length."""
        return self.capacity() / max(self._est_tokens, 1.0)

    def in_flight(self) -> np.ndarray:
        return np.asarray(
            [len(n.live) + len(n.spawning) for n in self.nodes], np.int32)

    @property
    def node_speed(self) -> np.ndarray:
        return np.asarray(
            [np.mean([e.speed for e in n.live]) if n.live else 1.0
             for n in self.nodes], np.float32)

    def observe(self, forecast: np.ndarray) -> np.ndarray:
        """Same Eq.1-3 feature layout as ``ClusterSim.observation``."""
        q = self.queue_depths()
        cap = self.request_capacity()
        total_cap = max(cap.sum(), 1e-9)
        load = q / max(q.sum(), 1.0)
        util_proxy = np.minimum(q / np.maximum(cap, 1e-9), 4.0) / 4.0
        capn = cap / total_cap
        up = self.up_mask()
        f = np.broadcast_to(forecast[None, :],
                            (self.num_nodes, forecast.shape[0]))
        obs = np.concatenate([load[:, None], util_proxy[:, None],
                              capn[:, None], up[:, None], f], axis=1)
        return obs.astype(np.float32)

    def route(self, fractions: np.ndarray) -> None:
        self._fractions = np.asarray(fractions, np.float64)

    def metrics(self) -> dict:
        return self._m

    def scale_to(self, target: np.ndarray) -> None:
        """Adds go through cold-start provisioning; removals drain first."""
        target = np.asarray(target)
        for i, node in enumerate(self.nodes):
            tgt = int(np.clip(target[i], 0, self.max_replicas_per_node))
            in_flight = len(node.live) + len(node.spawning)
            if tgt > in_flight:
                node.spawning.extend(
                    [self.provisioning_delay] * (tgt - in_flight))
            elif tgt < in_flight:
                rem = in_flight - tgt
                while rem and node.spawning:   # cancel pending spawns first
                    node.spawning.remove(max(node.spawning))
                    rem -= 1
                # drain live replicas, least-loaded first
                for eng in sorted(node.live, key=lambda e: e.load)[:rem]:
                    self._drain(node, eng)

    def _drain(self, node: _Node, eng: ReplicaEngine):
        eng.draining = True
        handed = list(eng.queue)         # un-admitted work goes back, merged
        eng.queue.clear()                # in arrival order (not appended —
        _requeue_merged(node.queue, handed)     # see _requeue_merged)
        node.live.remove(eng)
        node.draining.append(eng)

    # ------------------------------------------------------------ failures
    def fail_replica(self, node_idx: int, replica_idx: int = 0):
        """Deterministic failure injection (tests / chaos drills)."""
        node = self.nodes[node_idx]
        self._fail(node, node.live[replica_idx])

    def _fail(self, node: _Node, eng: ReplicaEngine):
        if eng._fleet is not None:
            # pending futures must commit BEFORE progress resets — a stale
            # token applied after evacuate() would corrupt the re-queued
            # request's stream
            self._async_stash.extend(eng._fleet.reconcile(force=True))
        lost = eng.evacuate()
        # lost work re-queues at its original arrival position (it is
        # usually the oldest work on the node, so it retries first — but by
        # arrival accounting, not by a blanket prepend that would jump any
        # newer lost request ahead of older queued ones)
        _requeue_merged(node.queue, lost)
        node.live.remove(eng)
        node.credit.pop(id(eng), None)
        self._leave_fleet(eng, restore=False)   # row dropped, not unstacked
        self._retired_prefill_dispatches += eng.prefill_dispatches
        self._retired_syncs += eng.syncs
        self._retired_sync_wait += eng.sync_wait
        self.failed_replicas += 1

    def _inject_failures(self):
        if self.failure_rate <= 0.0:
            return
        for node in self.nodes:
            for eng in list(node.live):
                if self.rng.random() < self.failure_rate:
                    self._fail(node, eng)

    # ------------------------------------------------------------- ticking
    def _advance_provisioning(self):
        for node in self.nodes:
            node.spawning = [d - 1 for d in node.spawning]
            ready = sum(1 for d in node.spawning if d <= 0)
            node.spawning = [d for d in node.spawning if d > 0]
            for _ in range(ready):
                self._go_live(node)

    def _generate_arrivals(self, arrival_rate: float):
        if self.request_factory is None or arrival_rate <= 0.0:
            return
        self._acc += arrival_rate * self.tick_seconds
        n = int(self._acc)
        self._acc -= n
        for _ in range(n):
            req = self.request_factory(self._req_id, self.t)
            self._req_id += 1
            req.arrival = float(self.t - 1)   # arrives as this tick begins
            self.pending.append(req)

    def _reroute_stranded(self):
        """A node with queued work but no live or provisioning replicas would
        strand it forever — hand it back for global re-routing (the elastic
        twin of the fluid sim's retry pool)."""
        for node in self.nodes:
            if node.queue and not node.live and not node.spawning:
                _requeue_merged(self.pending, node.queue)
                node.queue.clear()

    def _route_pending(self):
        mask = self.up_mask()
        if not (mask > 0).any():
            return                      # nothing can serve; hold requests
        fr = normalize_fractions(self._fractions, mask=mask)
        while self.pending:
            idx = int(self.rng.choice(self.num_nodes, p=fr))
            self.nodes[idx].queue.append(self.pending.popleft())

    def _dispatch(self, node: _Node):
        """Fill free replica slots from the node queue (least-loaded first,
        normalized by speed so fast replicas pull more work). The node
        queue hands out work in tiered weighted-deficit order (``pop``, not
        ``popleft``): the deep backlog lives here, so this is where premium
        traffic overtakes — single-tier pops stay plain FIFO."""
        while node.queue:
            cands = [e for e in node.live if e.load < e.max_batch]
            if not cands:
                return
            eng = min(cands, key=lambda e: e.load / max(e.speed, 1e-6))
            eng.submit(node.queue.pop())

    def tick(self, arrival_rate: float = 0.0) -> dict:
        self.t += 1
        prefill_before = self.prefill_dispatches()
        syncs_before = self.sync_count()
        wait_before = self.sync_wait_s()
        # async reconcile point: commit the previous tick's in-flight device
        # results (retires free their slots HERE, before admission planning,
        # so admission timing matches the eager oracle exactly)
        finished_now: list = self._reconcile_all()
        self._advance_provisioning()
        self._inject_failures()
        self._generate_arrivals(arrival_rate)
        self._reroute_stranded()
        self._route_pending()
        self._tick_dispatches = 0
        stepping: list = []          # (engine, n_substeps) across ALL nodes
        for node in self.nodes:
            self._dispatch(node)
            for eng in list(node.live) + list(node.draining):
                node.credit[id(eng)] = node.credit.get(id(eng), 0.0) + \
                    eng.speed
                n_sub = int(node.credit[id(eng)])
                node.credit[id(eng)] -= n_sub
                if n_sub <= 0:
                    continue
                eng.clock = float(self.t - 1)
                stepping.append((eng, n_sub))
        # sub-step rounds: round r advances every engine with n_sub > r, so
        # a homogeneous-speed cluster runs exactly one round and each fleet
        # group issues ONE decode dispatch (plus, under fleet admission, one
        # prefill dispatch per distinct bucket shape) for the whole tick.
        # Engines are independent within a tick (node queues were dispatched
        # above), so round interleaving matches stepping them one by one.
        max_sub = max((n for _, n in stepping), default=0)
        # a fused decode block may engage on single-round ticks whose
        # admission phase dispatched nothing (the group checks that);
        # unrouted work would mean admissions are imminent, so hold off
        allow_block = (self.decode_block > 1 and max_sub == 1
                       and not self.pending)
        for r in range(max_sub):
            if r > 0 and self.async_tick:
                # hetero sub-rounds: round r's admission may use slots the
                # previous round's decode freed, so reconcile between rounds
                # (homogeneous clusters run one round = one sync per tick)
                finished_now.extend(self._reconcile_all())
            round_engines = [(e, n) for e, n in stepping if n > r]
            ids = {id(e) for e, _ in round_engines}
            for eng, n in round_engines:
                finished_now.extend(eng.begin_step(
                    dt=1.0 / n,
                    admit=eng._fleet is None or not self.fleet_prefill))
            if self.fleet_prefill:
                for g in self._fleets.values():
                    finished_now.extend(g.admit_round(ids))
            for g in self._fleets.values():
                before = g.dispatches
                finished_now.extend(g.decode_round(
                    ids, allow_block=allow_block))
                self._tick_dispatches += g.dispatches - before
            for eng, _ in round_engines:     # engines outside any fleet
                if eng._fleet is None:
                    if eng.n_decoding:
                        self._tick_dispatches += 1
                    finished_now.extend(eng.finish_step())
        for node in self.nodes:
            for eng in list(node.draining):   # retire drained replicas
                if eng.load == 0:
                    node.draining.remove(eng)
                    node.credit.pop(id(eng), None)
                    # retired-empty: nothing worth unstacking from the slab
                    self._leave_fleet(eng, restore=False)
                    self._retired_prefill_dispatches += \
                        eng.prefill_dispatches
                    self._retired_syncs += eng.syncs
                    self._retired_sync_wait += eng.sync_wait
            self.replica_ticks += len(node.live)
        self._tick_prefill_dispatches = \
            self.prefill_dispatches() - prefill_before
        self._tick_syncs = self.sync_count() - syncs_before
        self._tick_sync_wait = self.sync_wait_s() - wait_before
        # finishes force-flushed by mid-tick churn (drain retires, failure
        # evacuations) land in stashes — collect them NOW so a drain loop
        # that terminates on this tick doesn't strand them
        for g in self._fleets.values():
            finished_now.extend(g.take_stash())
        finished_now.extend(self._async_stash)
        self._async_stash = []
        self.finished.extend(finished_now)
        self._m = self._compute_metrics(finished_now, arrival_rate)
        return self._m

    # -------------------------------------------------------------- metrics
    def _update_service_rate(self, finished_now: list):
        """EMA of measured per-replica requests/tick, fed to the autoscaler
        in place of the static ``unit_capacity`` once warm. Only ticks where
        the cluster is actually serving (work in flight or completions) count
        — idle ticks would drag the estimate to zero."""
        # draining replicas still finish work, so they count as servers —
        # dividing by live only would inflate the rate during scale-downs
        serving = sum(len(n.live) + len(n.draining) for n in self.nodes)
        busy = finished_now or any(n.unfinished() for n in self.nodes)
        if serving <= 0 or not busy:
            return
        rate = len(finished_now) / serving
        if self._srv_rate is None:
            self._srv_rate = rate
        else:
            self._srv_rate += _SERVICE_RATE_ALPHA * (rate - self._srv_rate)
        self._srv_obs += 1

    @property
    def service_rate(self) -> Optional[float]:
        """Measured per-replica req/tick, or None until the EMA warms up."""
        if self._srv_obs < _SERVICE_RATE_WARMUP or not self._srv_rate:
            return None
        return float(self._srv_rate)

    def tier_depths(self) -> np.ndarray:
        """Per-tier unfinished work per node, (T, N) in tier declaration
        order — node queues plus every replica's queued + in-flight slots.
        Counts come from the structures' own per-tier bookkeeping
        (``TieredQueue.depths`` / ``ReplicaEngine.tier_load``); a replica
        built with a different tier config falls back to counting its
        requests under the frontend's tier set."""
        out = np.zeros((len(self.tiers), self.num_nodes), np.float32)
        for i, node in enumerate(self.nodes):
            out[:, i] += node.queue.depths()
            for eng in list(node.live) + list(node.draining):
                tl = eng.tier_load()
                if len(tl) == len(self.tiers):
                    out[:, i] += tl
                else:
                    for req in list(eng.queue) + \
                            [r for r in eng.slots if r is not None]:
                        out[self.tiers.index(req.tier), i] += 1
        return out

    def _overdue_waiting(self) -> dict:
        """Per-tier count of requests still waiting for their first token
        whose age already exceeds the tier's TTFT target. Without this, a
        *starved* tier would report zero SLO violation — only completed
        requests can register a miss, and the reward would go unpenalized
        exactly when the tier is most violated."""
        overdue = {n: 0 for n in self.tiers.names}
        finite = [s for s in self.tiers.specs if np.isfinite(s.ttft_target)]
        if not finite:
            return overdue
        pools = [self.pending]
        for node in self.nodes:
            pools.append(node.queue)
            for eng in list(node.live) + list(node.draining):
                pools.append(eng.queue)
                pools.append(r for r in eng.slots if r is not None)
        for pool in pools:
            for req in pool:
                if req.first_token_time is not None:
                    continue
                spec = self.tiers.specs[self.tiers.index(req.tier)]
                if self.t - req.arrival > spec.ttft_target:
                    overdue[spec.name] += 1
        return overdue

    def _tier_metrics(self, finished_now: list) -> dict:
        """Per-tier latency/SLO view of this tick: queue depths, weighted
        pressure (the GPSO SLO-cost signal), TTFT/TBT means over this
        tick's completions and the tier-weighted SLO violation level the
        Eq.5 reward consumes (this tick's target misses plus the
        already-overdue waiting requests, so starvation is visible before
        anything completes). Untiered frontends emit NO tier keys — the
        control plane must keep planning with the original Eq.9/Eq.5
        objectives, bit-identical to the pre-tier behavior (a single-tier
        ``tier_pressure`` would be plain queue depth and silently flip the
        planner onto the tiered fitness)."""
        if len(self.tiers) <= 1:
            return {}
        tiers = self.tiers
        tq = self.tier_depths()
        overdue = self._overdue_waiting()
        ttft: dict = {}
        tbt: dict = {}
        served: dict = {n: 0 for n in tiers.names}
        viol: dict = {}
        for spec in tiers.specs:
            done = [r for r in finished_now if tiers.index(r.tier)
                    == tiers.index(spec.name)]
            served[spec.name] = len(done)
            late = overdue[spec.name]
            misses = late
            if done:
                ft = [r.first_token_time - r.arrival for r in done]
                bt = [(r.finish_time - r.first_token_time)
                      / max(len(r.output) - 1, 1) for r in done]
                ttft[spec.name] = float(np.mean(ft))
                tbt[spec.name] = float(np.mean(bt))
                misses += sum(float(f > spec.ttft_target
                                    or b > spec.tbt_target)
                              for f, b in zip(ft, bt))
            denom = len(done) + late
            if denom:
                viol[spec.name] = misses / denom
        return {
            "tier_queue": tq,
            "tier_pressure": tiers.pressure(tq),
            "tier_ttft": ttft,
            "tier_tbt": tbt,
            "tier_served": served,
            "tier_slo_cost": tiers.slo_cost(viol),
        }

    def _compute_metrics(self, finished_now: list, arrival_rate: float) -> dict:
        for r in finished_now:
            self._est_tokens += 0.05 * (len(r.output) - self._est_tokens)
        self._update_service_rate(finished_now)
        q = self.queue_depths()
        slots = np.asarray(
            [sum(e.max_batch for e in n.live) for n in self.nodes],
            np.float32)
        # demand/capacity utilization, saturating at 1 under backlog — the
        # same semantics as the fluid sim's served/capacity (a pure busy-slot
        # fraction dips between retire and re-admit and never signals
        # saturation to the HPA/RBAS threshold rules).
        util = np.where(slots > 0,
                        np.clip(q / np.maximum(slots, 1e-9), 0.0, 1.0), 0.0)
        up = self.up_mask()
        req_cap = self.request_capacity()
        if finished_now:
            resp = float(np.mean([r.finish_time - r.arrival
                                  for r in finished_now]))
            self._resp_est = resp
        else:
            # queueing estimate: backlog / service rate + one service time
            backlog = np.where(req_cap > 1e-9,
                               q / np.maximum(req_cap, 1e-9), 10.0)
            est = float(np.mean(backlog)) + self._est_tokens
            resp = max(self._resp_est, est) if q.sum() > 0 else self._resp_est
        overload = float(np.mean(np.where(
            req_cap > 1e-9,
            np.clip(q / np.maximum(req_cap, 1e-9) / 4.0, 0, 1), 1.0)))
        return {
            "utilization": util.astype(np.float32),
            "mean_utilization": float(np.mean(util[up > 0.5])
                                      if (up > 0.5).any() else 0.0),
            "response_time": resp,
            "served": float(len(finished_now)),
            "served_tokens": float(sum(len(r.output) for r in finished_now)),
            "overload": overload,
            "capacity": req_cap,
            "queue": q,
            "up": up,
            "active_replicas": np.asarray(
                [len(n.live) for n in self.nodes], np.int32),
            "replica_ticks": int(sum(len(n.live) for n in self.nodes)),
            "decode_dispatches": int(self._tick_dispatches),
            "prefill_dispatches": int(self._tick_prefill_dispatches),
            "syncs": int(self._tick_syncs),
            "sync_wait_s": float(self._tick_sync_wait),
            "fleet_groups": int(sum(1 for g in self._fleets.values()
                                    if len(g))),
            "service_rate": self.service_rate,
            **self._tier_metrics(finished_now),
        }

    # ------------------------------------------------------------ draining
    def run_until_drained(self, max_steps: int = 10_000):
        """Finish all outstanding work (controlled wind-down: chaos
        injection pauses so the backlog can actually clear)."""
        rate, self.failure_rate = self.failure_rate, 0.0
        try:
            for _ in range(max_steps):
                # safety: if scaling/failures left the whole cluster with no
                # capacity while work is outstanding, spawn one drain worker
                # (an aggressive scale-to-zero must never drop requests)
                if (self.pending or any(n.unfinished() for n in self.nodes)) \
                        and not any(n.live or n.spawning for n in self.nodes):
                    self._go_live(self.nodes[0])
                self.tick(0.0)
                if not self.pending and all(n.unfinished() == 0
                                            for n in self.nodes):
                    return
            raise RuntimeError("elastic cluster did not drain")
        finally:
            self.failure_rate = rate
