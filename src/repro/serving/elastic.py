"""``ElasticClusterFrontend``: a request-level ``ClusterBackend`` over real
model replicas.

N serving nodes, each holding a mutable group of ``ReplicaEngine``s (real CPU
model forwards), driven by the same ``ControlPlane`` that drives the fluid
simulator. Operational semantics mirror ``ClusterSim``:

  * **cold start** — ``scale_to`` additions pass through a provisioning
    pipeline and only serve after ``provisioning_delay`` ticks;
  * **graceful drain** — removals stop admitting, hand queued work back to
    the node, finish their in-flight slots, then retire (no request is ever
    dropped by a scale-down);
  * **failure injection** — a failed replica loses its generation progress;
    every in-flight + queued request is reset and re-queued at the front of
    the node queue (``fail_replica`` for deterministic tests, ``failure_rate``
    for Bernoulli-per-tick injection);
  * **heterogeneity** — the replica factory may vary ``max_batch`` and
    ``speed`` per replica; speed>1 replicas run multiple decode sub-steps per
    tick via a credit accumulator, speed<1 skip ticks.

Work units: a node's "queue depth" is its count of unfinished requests, its
"capacity" is decode slots/tick (sum of ``max_batch * speed`` over live
replicas). Response times are measured end-to-end in ticks on finished
requests, with a queueing-theory estimate filling ticks where nothing
finishes, so the control plane sees the same metric names and shapes as the
fluid backend.

**Fleet-batched ticks** (default): live + draining replicas that share a
``(model, params, max_batch, max_seq, cache_dtype)`` are stacked into
``FleetGroup``s — across node boundaries — so one tick advances every
replica of a group with ONE jitted decode dispatch and one small batched
host sync, instead of a Python-dispatched jit call + per-slot ``int()``
syncs per replica. Groups survive scale-up (slab rows grow in pow2 steps),
graceful drain (a draining member keeps decoding in the fleet until empty)
and failure (its row is dropped and backfilled). Heterogeneous speeds run
as sub-step *rounds*: a round where only a subset of a group steps uses the
masked fleet kernel so non-stepping rows' state is untouched. Set
``fleet_batch=False`` to recover the per-replica ``step()`` loop (the
parity oracle). ``metrics()['decode_dispatches']`` counts this tick's
dispatches; the fleet path also feeds the measured per-replica service-rate
EMA (``metrics()['service_rate']``) that the control plane hands to the
GPSO planner once warm.

**Fleet-batched admission** (default with ``fleet_batch``): each round,
every stepping member *plans* its admissions on the host and the group
coordinator batches them — one jitted ``fleet_prefill`` per distinct pow2
length bucket across ALL nodes, writing admit rows
straight into the fleet slab, plus one ``fleet_chunk`` dispatch advancing
every mid-chunk long prompt (``ReplicaEngine(chunk_len=...)``). Cold-queue
admission cost is therefore O(distinct bucket shapes) per tick instead of
O(replicas). ``metrics()['prefill_dispatches']`` counts this tick's
admission dispatches (mirroring ``decode_dispatches``); set
``fleet_prefill=False`` to keep per-replica admission as the A/B oracle.

**Overlapped async ticks** (default with fleet batching): the fleet
dispatch methods stop blocking on the device — decode/prefill/chunk results
stay on the accelerator as pending futures (with the decode operands
persistent on device, see ``engine`` module docstring) and the deferred host
bookkeeping applies at ONE reconcile sync at the next tick's start. The host
half of tick *t* (metrics, queues, tier accounting, the control plane's
forecast→balance→scale) therefore overlaps the device computing tick *t*'s
decode: steady-state cost is ``max(host, device)`` instead of their sum, at
one blocking sync per fleet group per tick (``metrics()['syncs']``,
mirroring ``decode_dispatches``; ``metrics()['sync_wait_s']`` is the wall
time actually blocked — the host-vs-device tick breakdown). Token streams
and finish ticks are bit-identical to ``async_tick=False`` (the eager parity
oracle); only host-side *observation* — per-tick ``served``/latency metrics,
drained detection — lags by one tick, and since retires reconcile before
admission planning, a slot freed by tick *t*'s decode admits at *t+1*
exactly like the eager path (admission lags device state by at most one
tick under a full slab). Membership churn (drain retire, failure, scale-up)
force-flushes pending futures before rows unstack. ``decode_block=K``
additionally fuses K decode micro-steps into one dispatch+sync on ticks
with no pending admissions or chunk cursors, dropping syncs/tick to 1/K in
the saturated-decode regime — at the cost that a slot retiring mid-block
re-admits only at the block-end reconcile (admission lag <= K-1 ticks
under a full slab; see the engine docstring).

**Fleet-mesh sharding.** Pass ``mesh=`` (a mesh with a ``fleet`` axis,
e.g. ``launch.mesh.make_fleet_mesh``) and every fleet group shards its
slab's fleet axis over the N devices, so F replicas genuinely decode in
parallel — same ONE logical dispatch per tick (GSPMD partitions it, the
host still issues one), same ≤1 reconcile sync, bit-identical streams.
The contract lives in ``FleetGroup``: slab capacity stays a multiple of
the shard count (pad rows masked inactive, excluded from dispatch/retire
accounting), churn (scale-up growth, drain retire, failure row-drop)
keeps live rows dense so they re-balance across contiguous shard blocks,
and membership changes force-flush pending futures exactly like the
unsharded async path. Params replicate across the fleet axis. On CPU the
N devices are virtual (``--xla_force_host_platform_device_count``, set
before jax initializes — see ``launch/serve.py --devices``).

**SLO tiers.** Pass a ``workload.trace.TierSet`` (and create replicas with
the same ``tiers=``) to serve several QoS classes over one pool: every
replica queue becomes a weighted-deficit ``TieredQueue`` (premium admits
first, batch keeps a bounded non-starving share) and ``metrics()`` grows the
per-tier view the control plane observes — ``tier_queue`` (T, N) depths,
``tier_pressure`` (N,) weighted backlog for the GPSO SLO cost term,
``tier_ttft``/``tier_tbt`` means over this tick's completions,
``tier_served`` counts and the scalar ``tier_slo_cost`` feeding the
tier-weighted Eq.5 reward. Re-queue paths (drain hand-back, failure
evacuation, dead-node re-route) merge work back in original-arrival order,
so churn never scrambles the starvation accounting. Tiering reorders which
requests admit first; the fleet dispatch bounds (one decode dispatch per
group per tick, one prefill dispatch per distinct bucket shape) are
untouched.

**Robustness layer** (closed-loop clients + spot preemption). Every rid
that enters ``submit`` is tracked by a ``RequestLedger`` until it lands in
exactly one terminal state — ``finished`` / ``timed_out`` / ``abandoned`` /
``rejected`` — so retry storms can never lose or double-serve a request:
a re-submitted rid is *suppressed* while an attempt is live (or already
served/abandoned) and accepted as a retry only from ``timed_out`` /
``rejected``, guaranteeing at most one attempt-object per rid in the
system. Deadlines (``Request.deadline_tick``) retire inside the existing
fleet/afleet retire rule (see ``engine``); queued work whose deadline
already passed is culled before it wastes a prefill — at the frontend
sweep for ``pending`` + node queues and at the replica queue head in
``plan_admission``. Spot preemption takes whole nodes: ``preempt_node``
(or a scripted ``ChaosSchedule`` event) starts a K-tick notice — every
live replica drains under the deadline, spawns are cancelled, and when
the notice expires whatever is still in flight is hard-dropped and
re-queued through the same evacuate + ``_requeue_merged`` path as a
failure. ``metrics()`` grows three always-on keys — ``goodput`` /
``timed_out`` (this tick's completions that met / missed their deadline)
and ``preempt_risk`` (per-node 0/1 notice-or-down signal the GPSO planner
consumes as Eq.9 risk cost) — all zeros when chaos is off, so streams and
planner behavior stay bit-identical to the pre-chaos stack.
"""
from __future__ import annotations

import re
from collections import deque
from typing import Callable, Optional

import numpy as np

from repro.serving.engine import (FleetGroup, ReplicaEngine, Request,
                                  TieredQueue, normalize_fractions)
from repro.workload.trace import DEFAULT_TIERS, TierSet

_SERVICE_RATE_WARMUP = 8       # measured-rate ticks before the EMA is trusted
_SERVICE_RATE_ALPHA = 0.1


def _requeue_merged(queue, reqs) -> None:
    """Merge re-queued work back into ``queue`` (deque or TieredQueue)
    preserving *global* arrival order (rid tiebreak). Drain hand-backs and
    failure re-queues must not append or prepend blindly: either loses the
    original arrival ordering the tiered starvation accounting (and plain
    FIFO fairness) relies on."""
    merged = sorted(list(queue) + list(reqs),
                    key=lambda r: (r.arrival, r.rid))
    queue.clear()
    for r in merged:
        queue.append(r)


_TERMINAL_STATES = ("finished", "timed_out", "abandoned", "rejected", "shed")
_RETRYABLE_STATES = ("timed_out", "rejected", "shed")


class RequestLedger:
    """Exactly-once request accounting for the frontend.

    Every rid is a state machine: ``live`` while an attempt is in the
    system, then exactly one of ``finished`` / ``timed_out`` /
    ``abandoned`` / ``rejected`` / ``shed``. Retries (same rid, fresh
    ``Request`` object) are accepted only from the retryable terminal states
    (``timed_out``, ``rejected``, ``shed``); a re-submit racing a live attempt or a
    completed/abandoned rid is *suppressed* — that single rule guarantees
    at most one attempt per rid is ever in flight, so no queue surgery is
    needed for duplicate suppression. A completion that arrives for an
    ``abandoned`` rid counts as ``wasted`` work (the client left; the
    tokens are not goodput); a completion for any other terminal state
    increments ``double_served``, the self-check that must stay 0.
    Per-tier rows count terminal *events* (a rid that times out twice and
    then finishes contributes 2 timed_out + 1 finished events)."""

    def __init__(self):
        self.state: dict = {}       # rid -> state
        self.tier: dict = {}        # rid -> tier name (at first register)
        self.submitted = 0          # distinct rids ever registered
        self.retries = 0            # accepted re-submits
        self.duplicates = 0         # suppressed re-submits
        self.wasted = 0             # completions of abandoned rids
        self.double_served = 0      # completions in a served state: MUST be 0
        self._per_tier: dict = {}

    def tier_row(self, tier: str) -> dict:
        return self._per_tier.setdefault(
            tier, {"finished": 0, "timed_out": 0, "abandoned": 0,
                   "rejected": 0, "shed": 0, "retries": 0})

    @property
    def per_tier(self) -> dict:
        return self._per_tier

    def register(self, req: Request) -> bool:
        """Admit ``req`` into the ledger. True = accept (fresh rid or a
        legal retry), False = suppress (duplicate of a live / finished /
        abandoned rid — the caller must NOT enqueue it)."""
        st = self.state.get(req.rid)
        if st is None:
            self.state[req.rid] = "live"
            self.tier[req.rid] = req.tier
            self.submitted += 1
            return True
        if st in _RETRYABLE_STATES:
            self.state[req.rid] = "live"
            self.retries += 1
            self.tier_row(self.tier[req.rid])["retries"] += 1
            return True
        self.duplicates += 1
        return False

    def reject(self, req: Request) -> None:
        """Admission control turned the (just-registered) attempt away."""
        self.state[req.rid] = "rejected"
        self.tier_row(self.tier[req.rid])["rejected"] += 1

    def shed(self, req: Request) -> None:
        """Overload shedding turned the attempt away: under total overload
        the router degrades gracefully by refusing lowest-tier traffic at
        admission instead of letting every queue grow without bound. An
        explicit terminal state — never silent loss — and retryable, so a
        backing-off client may come back once pressure clears."""
        self.state[req.rid] = "shed"
        self.tier_row(self.tier[req.rid])["shed"] += 1

    def abandon(self, rid: int) -> bool:
        """The client gave up on ``rid``. Legal from ``live`` (the attempt
        still in the system will complete as wasted work), ``timed_out``
        and ``rejected``; a no-op after ``finished`` (the client already
        got the answer)."""
        st = self.state.get(rid)
        if st in ("live",) + _RETRYABLE_STATES:
            self.state[rid] = "abandoned"
            self.tier_row(self.tier[rid])["abandoned"] += 1
            return True
        return False

    def resolve(self, req: Request) -> str:
        """Classify a completion coming out of the engines: ``finished``
        if it met its deadline, ``timed_out`` if it expired (deadline
        retire or queue cull), ``abandoned``+wasted if the client already
        left. Unknown rids (engine-level callers that bypassed ``submit``)
        are registered on the spot so the ledger still balances."""
        st = self.state.get(req.rid)
        if st is None:
            self.submitted += 1
            self.tier[req.rid] = req.tier
            st = "live"
        if st == "abandoned":
            self.wasted += 1
            return "abandoned"
        if st != "live":
            self.double_served += 1      # exactly-once violation
            return st
        end = "timed_out" if req.expired else "finished"
        self.state[req.rid] = end
        self.tier_row(self.tier[req.rid])[end] += 1
        return end

    def balance(self) -> dict:
        """Final-state histogram over all rids (+ the event counters)."""
        by = {k: 0 for k in ("live",) + _TERMINAL_STATES}
        for st in self.state.values():
            by[st] += 1
        by.update(submitted=self.submitted, retries=self.retries,
                  duplicates=self.duplicates, wasted=self.wasted,
                  double_served=self.double_served)
        return by

    def balanced(self) -> bool:
        """Conservation check: every submitted rid is in exactly one
        terminal state, and nothing was ever served twice."""
        b = self.balance()
        return (b["live"] == 0 and self.double_served == 0
                and sum(b[k] for k in _TERMINAL_STATES) == len(self.state))


class ChaosSchedule:
    """Deterministic scripted chaos: fail / preempt / recover / slow events
    keyed by tick, plus cell-level events for the multi-cell routing plane
    (``control.cells.MultiCellBackend``) and plane-level events for the
    two-level control hierarchy (``control.hierarchy``). Spec syntax
    (comma-separated)::

        preempt@12:n0:k3   # tick 12: preemption notice on node 0, K=3
        preempt@20:n1      # frontend-default notice
        fail@8:n1:r0       # tick 8: kill node 1's live replica 0
        fail@9:n0          # replica 0 by default
        recover@40:n0      # tick 40: bring node 0 back from 'down'
        slow@6:n1:x4       # tick 6: node 1's replicas run at 1/4 speed
        slow@18:n1:x1      # x1 clears the straggler (full speed again)
        cell_down@15:c0    # tick 15: blackout cell 0 (evacuate + re-route)
        cell_up@30:c0      # tick 30: restore cell 0 (provisioning applies)
        partition@10:c1:k6 # tick 10: cell 1's metrics feed stale for 6 ticks
        heal@14:c1         # end cell 1's partition early
        plane_down@12:k8   # tick 12: global control plane crashes, 8 ticks
        plane_down@12      # ...or until an explicit plane_up
        plane_up@20        # tick 20: global plane restarts (from checkpoint)

    Node-kind events are consumed by the backends' own ``_advance_chaos``
    (elastic frontend / fluid sim); cell- and plane-kind events are
    consumed by the routing plane. ``pop`` is non-destructive, so one
    schedule can feed both consumers — each filters to the kinds it owns.
    Plane events carry no target index (the global plane is a singleton);
    they are stored with index -1. Events validate at parse time (syntax)
    and again when applied (indices and liveness)."""

    NODE_KINDS = ("preempt", "fail", "recover", "slow")
    CELL_KINDS = ("cell_down", "cell_up", "partition", "heal")
    PLANE_KINDS = ("plane_down", "plane_up")

    _EVENT = re.compile(
        r"^(?P<kind>preempt|fail|recover|slow|cell_down|cell_up|partition"
        r"|heal)"
        r"@(?P<tick>\d+):(?P<scope>[nc])(?P<idx>\d+)"
        r"(?::(?P<argkind>[krx])(?P<arg>\d+))?$")
    _PLANE = re.compile(
        r"^(?P<kind>plane_down|plane_up)@(?P<tick>\d+)(?::k(?P<arg>\d+))?$")

    def __init__(self):
        self.events: dict = {}       # tick -> [(kind, node_or_cell, arg|None)]

    def add(self, tick: int, kind: str, node: int = -1,
            arg: Optional[int] = None):
        if kind not in self.NODE_KINDS + self.CELL_KINDS + self.PLANE_KINDS:
            raise ValueError(f"unknown chaos event kind {kind!r}")
        self.events.setdefault(int(tick), []).append((kind, int(node), arg))
        return self

    @classmethod
    def parse(cls, spec: str) -> "ChaosSchedule":
        sched = cls()
        for part in filter(None, (p.strip() for p in spec.split(","))):
            p = cls._PLANE.match(part)
            if p is not None:
                if p["kind"] == "plane_up" and p["arg"] is not None:
                    raise ValueError(
                        f"{part!r}: ':k' only applies to plane_down")
                sched.add(int(p["tick"]), p["kind"], -1,
                          int(p["arg"]) if p["arg"] is not None else None)
                continue
            m = cls._EVENT.match(part)
            if m is None:
                raise ValueError(
                    f"bad chaos event {part!r} — expected "
                    "'preempt@T:nN[:kK]', 'fail@T:nN[:rR]', 'recover@T:nN', "
                    "'slow@T:nN:xF', 'cell_down@T:cC', 'cell_up@T:cC', "
                    "'partition@T:cC[:kK]', 'heal@T:cC', "
                    "'plane_down@T[:kK]' or 'plane_up@T'")
            kind, scope, argkind = m["kind"], m["scope"], m["argkind"]
            want = "c" if kind in cls.CELL_KINDS else "n"
            if scope != want:
                raise ValueError(
                    f"{part!r}: {kind} targets a "
                    f"{'cell (cC)' if want == 'c' else 'node (nN)'}")
            if argkind == "k" and kind not in ("preempt", "partition"):
                raise ValueError(
                    f"{part!r}: ':k' only applies to preempt/partition")
            if argkind == "r" and kind != "fail":
                raise ValueError(f"{part!r}: ':r' only applies to fail")
            if argkind == "x" and kind != "slow":
                raise ValueError(f"{part!r}: ':x' only applies to slow")
            if kind == "slow" and argkind != "x":
                raise ValueError(
                    f"{part!r}: slow needs a ':xF' factor (x1 clears)")
            sched.add(int(m["tick"]), kind, int(m["idx"]),
                      int(m["arg"]) if m["arg"] is not None else None)
        return sched

    def pop(self, tick: int) -> list:
        return self.events.get(tick, [])


class _Node:
    __slots__ = ("live", "draining", "spawning", "queue", "credit",
                 "preempt_left", "down", "slow")

    def __init__(self, tiers: TierSet):
        self.live: list = []        # serving ReplicaEngines
        self.draining: list = []    # finishing in-flight work, no admits
        self.spawning: list = []    # remaining cold-start ticks per add
        # node-level request queue: tier-aware (the deep backlog lives here
        # — replica queues only buffer up to max_batch), single-tier == FIFO
        self.queue: TieredQueue = TieredQueue(tiers)
        self.credit: dict = {}      # engine id -> fractional step credit
        self.preempt_left = -1      # ticks of preemption notice left; -1=none
        self.down = False           # preempted away; needs recover_node
        self.slow = 1.0             # straggler speed factor (slow@t:nI:xF)

    def unfinished(self) -> int:
        return len(self.queue) + sum(e.load for e in self.live) + \
            sum(e.load for e in self.draining)


class ElasticClusterFrontend:
    """Node-structured elastic serving cluster (see module docstring)."""

    def __init__(self, make_replica: Callable[[int], ReplicaEngine],
                 num_nodes: int, *, initial_replicas: int = 1,
                 provisioning_delay: int = 0,
                 max_replicas_per_node: int = 8,
                 failure_rate: float = 0.0,
                 request_factory: Optional[Callable[[int, int], Request]] = None,
                 tick_seconds: float = 1.0, seed: int = 0,
                 est_tokens: float = 8.0, fleet_batch: bool = True,
                 fleet_prefill: bool = True, async_tick: bool = True,
                 decode_block: int = 1,
                 tiers: Optional[TierSet] = None, mesh=None,
                 preempt_notice: int = 0,
                 chaos: Optional[ChaosSchedule] = None,
                 max_queue: Optional[int] = None,
                 ledger: Optional[RequestLedger] = None):
        self.make_replica = make_replica
        self.num_nodes = num_nodes
        self.tiers = tiers or DEFAULT_TIERS
        self.provisioning_delay = int(provisioning_delay)
        self.max_replicas_per_node = max_replicas_per_node
        self.failure_rate = failure_rate
        self.preempt_notice = int(preempt_notice)  # default K for preemptions
        self.chaos = chaos                # scripted fail/preempt/recover
        self.max_queue = max_queue        # admission cap -> 'rejected' rids
        self.request_factory = request_factory
        self.tick_seconds = tick_seconds
        self.fleet_batch = fleet_batch
        self.fleet_prefill = fleet_prefill and fleet_batch
        # serving mesh with a 'fleet' axis: fleet groups shard their slab's
        # fleet axis over it (N devices decode F replicas in parallel; see
        # FleetGroup's shard contract — capacity stays divisible by the
        # shard count, churn re-balances dense rows, params replicate)
        self.mesh = mesh if fleet_batch else None
        # the async tick needs the fleet dispatch paths end to end: with
        # either oracle mode (per-replica decode or per-replica admission)
        # the tick falls back to eager, blocking syncs
        self.async_tick = bool(async_tick) and self.fleet_prefill
        self.decode_block = max(1, int(decode_block)) if self.async_tick \
            else 1
        self.rng = np.random.default_rng(seed)
        self.nodes = [_Node(self.tiers) for _ in range(num_nodes)]
        self._rid = 0                # engine ids (replicas ever created)
        self._req_id = 0             # auto-generated request ids
        self._acc = 0.0              # fractional-arrival accumulator
        self.t = 0
        self.pending: deque = deque()
        self.finished: list = []
        self.failed_replicas = 0
        self.preempted_replicas = 0   # hard-dropped at notice expiry
        self.preempted_nodes = 0
        self.replica_ticks = 0
        # ledger may be shared: a multi-cell routing plane passes one global
        # RequestLedger to every cell so exactly-once holds ACROSS cells
        # (an evacuated request re-routed to a sibling cell resolves in the
        # same state machine — double_served stays 0 federation-wide)
        self.ledger = RequestLedger() if ledger is None else ledger
        self._blackout_profile: Optional[list] = None
        self._lease: Optional[tuple] = None   # (min, max) total replicas
        self._tick_goodput = 0        # this tick's in-deadline completions
        self._tick_timed_out = 0      # this tick's expired completions
        self._fractions = np.full(num_nodes, 1.0 / num_nodes, np.float32)
        self._m: dict = {}
        self._est_tokens = float(est_tokens)  # EMA of tokens per request
        self._resp_est = 0.0
        self._kernel_objs: dict = {}
        self._fleets: dict = {}      # fleet_key -> FleetGroup (spans nodes)
        self._tick_dispatches = 0    # decode dispatches issued this tick
        self._tick_prefill_dispatches = 0  # admission dispatches this tick
        self._tick_syncs = 0         # blocking host syncs this tick
        self._tick_sync_wait = 0.0   # seconds blocked on device this tick
        self._retired_dispatches = 0  # dispatch counts of evicted groups
        self._retired_prefill_dispatches = 0  # of evicted groups + engines
        self._retired_syncs = 0      # sync counts of evicted groups/engines
        self._retired_sync_wait = 0.0
        self._async_stash: list = []  # finishes flushed by mid-tick churn
        self._srv_rate: Optional[float] = None  # per-replica req/tick EMA
        self._srv_obs = 0            # ticks the EMA has been fed
        for node in self.nodes:
            for _ in range(initial_replicas):
                self._go_live(node)

    # ----------------------------------------------------------- plumbing
    def _spawn(self) -> ReplicaEngine:
        eng = self.make_replica(self._rid)
        self._rid += 1
        # remember the (shared) serve kernels so compile counts survive
        # replica retirement/failure
        self._kernel_objs[id(eng._kernels)] = eng._kernels
        return eng

    def _go_live(self, node: _Node) -> ReplicaEngine:
        """Spawn a replica onto ``node`` and enroll it in its fleet group
        (groups span nodes: the fleet axis is per model-shape, not per
        node)."""
        eng = self._spawn()
        node.live.append(eng)
        if self.fleet_batch:
            g = self._fleets.get(eng.fleet_key)
            if g is None:
                g = self._fleets[eng.fleet_key] = FleetGroup(
                    eng.model, eng.params, max_batch=eng.max_batch,
                    max_seq=eng.max_seq, cache_dtype=eng.cache_dtype,
                    async_mode=self.async_tick,
                    decode_block=self.decode_block,
                    attn_backend=eng.attn_backend, mesh=self.mesh)
            g.add(eng)
        return eng

    def _leave_fleet(self, eng: ReplicaEngine, restore: bool):
        g = eng._fleet
        if g is None:
            return
        g.remove(eng, restore=restore)  # flushes the group's pending futures
        if not g.members:
            # evict the empty group so its high-water-mark slab doesn't pin
            # device memory forever (a re-spawn re-allocates from zeros)
            self._async_stash.extend(g.reconcile(force=True))
            self._retired_dispatches += g.dispatches
            self._retired_prefill_dispatches += g.prefill_dispatches
            self._retired_syncs += g.syncs
            self._retired_sync_wait += g.sync_wait
            self._fleets = {k: v for k, v in self._fleets.items()
                            if v is not g}

    def prefill_retraces(self) -> int:
        """Prefill-side compilations across every replica ever spawned —
        one deduped accounting over the bucketed, fleet-batched and chunked
        kernel variants (kernels are shared per model config, so retired
        replicas still count)."""
        return sum(k.prefill_traces for k in self._kernel_objs.values())

    def serve_kernel_traces(self) -> int:
        """Compilations across *every* serve-kernel variant (prefill +
        decode + fleet + chunk), deduped the same way."""
        return sum(k.total_traces for k in self._kernel_objs.values())

    def decode_dispatches(self) -> int:
        """Total jitted fleet decode dispatches issued (fleet mode),
        including groups since evicted."""
        return self._retired_dispatches + \
            sum(g.dispatches for g in self._fleets.values())

    def prefill_dispatches(self) -> int:
        """Total jitted admission dispatches issued: per-engine bucketed /
        exact-length / chunk calls plus the fleet-batched prefill and chunk
        dispatches, including retired engines and evicted groups."""
        live = sum(e.prefill_dispatches
                   for n in self.nodes for e in n.live + n.draining)
        return self._retired_prefill_dispatches + live + \
            sum(g.prefill_dispatches for g in self._fleets.values())

    def sync_count(self) -> int:
        """Total blocking host syncs performed (group reconciles + eager
        fetches), including retired engines and evicted groups — the async
        tick's ``syncs`` currency, mirroring ``decode_dispatches``."""
        live = sum(e.syncs for n in self.nodes for e in n.live + n.draining)
        return self._retired_syncs + live + \
            sum(g.syncs for g in self._fleets.values())

    def sync_wait_s(self) -> float:
        """Total wall seconds the host spent *blocked* on device results —
        the device half of the tick-wall breakdown (host half = tick wall
        minus this)."""
        live = sum(e.sync_wait
                   for n in self.nodes for e in n.live + n.draining)
        return self._retired_sync_wait + live + \
            sum(g.sync_wait for g in self._fleets.values())

    def _reconcile_all(self) -> list:
        """The per-tick reconcile point: flush every fleet group's pending
        device futures (one blocking sync per group) and collect the newly
        finished requests, plus any stashed by mid-tick churn flushes."""
        out, self._async_stash = self._async_stash, []
        for g in list(self._fleets.values()):
            out.extend(g.reconcile())
        return out

    @property
    def replicas(self) -> list:
        """All live replicas (diagnostics)."""
        return [e for n in self.nodes for e in n.live]

    @property
    def replicas_spawned(self) -> int:
        """Replicas ever created (incl. failed/retired ones)."""
        return self._rid

    def alloc_rid(self) -> int:
        """Hand out a fresh request id (shared counter with the open-loop
        arrival generator, so closed-loop clients never collide)."""
        rid = self._req_id
        self._req_id += 1
        return rid

    def _outstanding(self) -> int:
        return len(self.pending) + sum(n.unfinished() for n in self.nodes)

    def submit(self, req: Request) -> bool:
        """Submit one attempt. Returns False when the attempt was NOT
        enqueued: either suppressed as a duplicate (an attempt for this rid
        is live, or the rid already finished / was abandoned — exactly-once
        guarantee) or rejected by the ``max_queue`` admission cap. Retries
        of timed-out / rejected rids are accepted; each retry must be a
        FRESH ``Request`` object (never re-submit a served-on object)."""
        if req.arrival == 0.0:
            req.arrival = float(self.t)
        if not self.ledger.register(req):
            return False
        if self.max_queue is not None and self._outstanding() >= self.max_queue:
            self.ledger.reject(req)
            return False
        self.pending.append(req)
        return True

    def abandon(self, rid: int) -> bool:
        """Client-side abandonment: the rid's terminal state becomes
        ``abandoned``; a live attempt keeps running and its completion
        counts as wasted work (not goodput). Queued attempts with a
        deadline are culled by the expiry sweep; abandonment never reaches
        into queues, so streams are unaffected."""
        return self.ledger.abandon(rid)

    # ------------------------------------------------- ClusterBackend API
    def up_mask(self) -> np.ndarray:
        return np.asarray([1.0 if n.live else 0.0 for n in self.nodes],
                          np.float32)

    def queue_depths(self) -> np.ndarray:
        return np.asarray([n.unfinished() for n in self.nodes], np.float32)

    def capacity(self) -> np.ndarray:
        """Decode slots/tick per node (live replicas only, scaled by the
        node's straggler factor)."""
        return np.asarray(
            [sum(e.max_batch * e.speed for e in n.live) * n.slow
             for n in self.nodes],
            np.float32)

    def request_capacity(self) -> np.ndarray:
        """Requests/tick per node at the current mean output length."""
        return self.capacity() / max(self._est_tokens, 1.0)

    def in_flight(self) -> np.ndarray:
        return np.asarray(
            [len(n.live) + len(n.spawning) for n in self.nodes], np.int32)

    @property
    def node_speed(self) -> np.ndarray:
        return np.asarray(
            [(np.mean([e.speed for e in n.live]) if n.live else 1.0) * n.slow
             for n in self.nodes], np.float32)

    def observe(self, forecast: np.ndarray) -> np.ndarray:
        """Same Eq.1-3 feature layout as ``ClusterSim.observation``."""
        q = self.queue_depths()
        cap = self.request_capacity()
        total_cap = max(cap.sum(), 1e-9)
        load = q / max(q.sum(), 1.0)
        util_proxy = np.minimum(q / np.maximum(cap, 1e-9), 4.0) / 4.0
        capn = cap / total_cap
        up = self.up_mask()
        f = np.broadcast_to(forecast[None, :],
                            (self.num_nodes, forecast.shape[0]))
        obs = np.concatenate([load[:, None], util_proxy[:, None],
                              capn[:, None], up[:, None], f], axis=1)
        return obs.astype(np.float32)

    def route(self, fractions: np.ndarray) -> None:
        self._fractions = np.asarray(fractions, np.float64)

    def metrics(self) -> dict:
        return self._m

    def set_lease(self, min_replicas: int, max_replicas: int) -> None:
        """Bound every future ``scale_to`` to a capacity lease: the cell's
        TOTAL in-flight replica count (live + spawning, across nodes) is
        clamped into ``[min_replicas, max_replicas]``. Granted by the
        hierarchy's ``GlobalPlanner`` (see ``control/hierarchy.py``); the
        clamp holds even when the global plane itself issues the target,
        so a restored plane replaying a stale plan cannot overshoot the
        lease. ``set_lease(None)``-style clearing is spelled
        ``clear_lease()``."""
        lo, hi = int(min_replicas), int(max_replicas)
        if lo < 0 or hi < lo:
            raise ValueError(f"bad lease [{min_replicas}, {max_replicas}]")
        self._lease = (lo, hi)

    def clear_lease(self) -> None:
        self._lease = None

    @property
    def lease(self):
        return self._lease

    def _apply_lease(self, desired: dict) -> dict:
        """Clamp the requested per-node targets so the cell total lands in
        the lease. Trims largest-target-first, raises smallest-first
        (deterministic tie-break on node index); replicas held by doomed
        nodes (skipped by ``scale_to``) count against the lease."""
        if self._lease is None or not desired:
            return desired
        lo, hi = self._lease
        held = sum(len(n.live) + len(n.spawning)
                   for i, n in enumerate(self.nodes) if i not in desired)
        total = sum(desired.values()) + held
        while total > hi:
            i = max(desired, key=lambda j: (desired[j], -j))
            if desired[i] == 0:
                break
            desired[i] -= 1
            total -= 1
        while total < lo:
            room = [j for j in desired
                    if desired[j] < self.max_replicas_per_node]
            if not room:
                break
            i = min(room, key=lambda j: (desired[j], j))
            desired[i] += 1
            total += 1
        return desired

    def scale_to(self, target: np.ndarray) -> None:
        """Adds go through cold-start provisioning; removals drain first.
        When a capacity lease is set (``set_lease``) the cell total is
        clamped into it before any node-level action."""
        target = np.asarray(target)
        desired = {}
        for i, node in enumerate(self.nodes):
            if node.down or node.preempt_left >= 0:
                continue              # never spawn onto a doomed/dead node
            desired[i] = int(np.clip(target[i], 0,
                                     self.max_replicas_per_node))
        desired = self._apply_lease(desired)
        for i, tgt in desired.items():
            node = self.nodes[i]
            in_flight = len(node.live) + len(node.spawning)
            if tgt > in_flight:
                node.spawning.extend(
                    [self.provisioning_delay] * (tgt - in_flight))
            elif tgt < in_flight:
                rem = in_flight - tgt
                while rem and node.spawning:   # cancel pending spawns first
                    node.spawning.remove(max(node.spawning))
                    rem -= 1
                # drain live replicas, least-loaded first
                for eng in sorted(node.live, key=lambda e: e.load)[:rem]:
                    self._drain(node, eng)

    def _drain(self, node: _Node, eng: ReplicaEngine):
        eng.draining = True
        handed = list(eng.queue)         # un-admitted work goes back, merged
        eng.queue.clear()                # in arrival order (not appended —
        _requeue_merged(node.queue, handed)     # see _requeue_merged)
        node.live.remove(eng)
        node.draining.append(eng)

    # ------------------------------------------------------------ failures
    def _check_node(self, node_idx: int) -> _Node:
        """Shared validation for the chaos entry points: a clear
        ``ValueError`` instead of a raw ``IndexError`` (negative indices
        would otherwise silently wrap)."""
        if not isinstance(node_idx, (int, np.integer)):
            raise ValueError(
                f"node index must be an int, got {type(node_idx).__name__}")
        if not 0 <= node_idx < self.num_nodes:
            raise ValueError(
                f"node index {node_idx} out of range for "
                f"{self.num_nodes} nodes")
        return self.nodes[int(node_idx)]

    def fail_replica(self, node_idx: int, replica_idx: int = 0):
        """Deterministic failure injection (tests / chaos drills)."""
        node = self._check_node(node_idx)
        if node.down:
            raise ValueError(
                f"node n{node_idx} is down (preempted); nothing to fail")
        if not node.live:
            raise ValueError(f"node n{node_idx} has no live replicas")
        if not 0 <= replica_idx < len(node.live):
            raise ValueError(
                f"replica index {replica_idx} out of range: node "
                f"n{node_idx} has {len(node.live)} live replicas")
        self._fail(node, node.live[replica_idx])

    def preempt_node(self, node_idx: int, notice: Optional[int] = None):
        """Spot-preemption notice on a whole node: every live replica
        drains under the deadline, pending spawns are cancelled, no new
        work routes there (``up_mask`` drops to 0 once nothing is live).
        After ``notice`` ticks (default the frontend's ``preempt_notice``)
        whatever is still in flight is hard-dropped: evacuated, re-queued
        in arrival order, and the node goes ``down`` until
        ``recover_node``. ``notice<=0`` preempts immediately."""
        node = self._check_node(node_idx)
        if node.down:
            raise ValueError(f"node n{node_idx} is already down")
        if node.preempt_left >= 0:
            raise ValueError(
                f"node n{node_idx} already has a preemption notice "
                f"({node.preempt_left} ticks left)")
        left = self.preempt_notice if notice is None else int(notice)
        node.spawning = []            # a doomed node never finishes a spawn
        for eng in list(node.live):   # drain-under-deadline
            self._drain(node, eng)
        if left <= 0:
            self._preempt_finalize(node)
        else:
            node.preempt_left = left

    def recover_node(self, node_idx: int):
        """Bring a preempted node back into the schedulable pool (empty —
        capacity returns when the autoscaler targets it again)."""
        node = self._check_node(node_idx)
        if not node.down:
            raise ValueError(f"node n{node_idx} is not down")
        node.down = False

    def slow_node(self, node_idx: int, factor: int):
        """Deterministic straggler injection (``slow@t:nI:xF``): every
        replica on the node runs at 1/``factor`` speed — capacity,
        ``node_speed`` and per-tick step credit all scale down, so the
        router shifts work away and the autoscaler sees the lost
        throughput. ``factor == 1`` clears the straggler. Persists across
        replica churn (the factor lives on the node, not the engines)."""
        node = self._check_node(node_idx)
        if factor is None or not isinstance(factor, (int, np.integer)):
            raise ValueError(
                f"slow factor must be an int >= 1, got {factor!r}")
        if factor < 1:
            raise ValueError(f"slow factor must be >= 1, got {factor}")
        if node.down:
            raise ValueError(
                f"node n{node_idx} is down (preempted); nothing to slow")
        node.slow = 1.0 / int(factor)

    def blackout(self) -> list:
        """Cell blackout (the multi-cell routing plane's evacuation hook):
        hard-drop the ENTIRE cell now. Every node — healthy, under notice,
        or mid-drain — goes through the same ledger-safe failure path as a
        notice expiry (pending device futures flush BEFORE progress resets,
        in-flight work evacuates, queues hand back in arrival order), then
        the frontend's own pending pool is evacuated too and every stranded
        request is returned for the caller to re-route globally. The
        pre-blackout replica profile is remembered so ``restore`` can bring
        the cell back through normal provisioning."""
        self._blackout_profile = [
            len(n.live) + len(n.draining) + len(n.spawning)
            for n in self.nodes]
        for node in self.nodes:
            if node.down:
                continue
            node.preempt_left = -1    # a blackout supersedes any notice
            node.spawning = []
            for eng in list(node.live):
                self._drain(node, eng)
            self._preempt_finalize(node)
        out = list(self.pending)
        self.pending.clear()
        return out

    def restore(self) -> None:
        """Bring a blacked-out cell back: every down node recovers (empty)
        and the pre-blackout replica profile re-targets through the normal
        provisioning pipeline — capacity returns after the cold-start
        delay, exactly like any other scale-up."""
        for node in self.nodes:
            node.down = False
        if self._blackout_profile is not None:
            self.scale_to(np.asarray(self._blackout_profile, np.int32))
            self._blackout_profile = None

    def _preempt_finalize(self, node: _Node):
        """Notice expired: hard-drop every replica still finishing work
        (the failure path — reconcile-flush, evacuate, re-queue merged),
        hand the node queue back for global re-routing, mark the node
        down."""
        for eng in list(node.draining):
            self._destroy(node, eng, node.draining)
            self.preempted_replicas += 1
        for eng in list(node.live):      # defensive: nothing should be live
            self._destroy(node, eng, node.live)
            self.preempted_replicas += 1
        if node.queue:
            _requeue_merged(self.pending, node.queue)
            node.queue.clear()
        node.preempt_left = -1
        node.down = True
        self.preempted_nodes += 1

    def _advance_chaos(self):
        """Apply this tick's scripted chaos events, then advance preemption
        notice timers (a node whose notice hits zero finalizes here, so
        its evacuated work re-routes within the same tick)."""
        if self.chaos is not None:
            for kind, n, arg in self.chaos.pop(self.t):
                if kind not in ChaosSchedule.NODE_KINDS:
                    continue           # cell-kind events belong to the router
                if kind == "fail":
                    self.fail_replica(n, 0 if arg is None else arg)
                elif kind == "preempt":
                    self.preempt_node(n, notice=arg)
                elif kind == "slow":
                    self.slow_node(n, arg)
                else:
                    self.recover_node(n)
        for node in self.nodes:
            if node.preempt_left < 0:
                continue
            if node.preempt_left == 0:
                self._preempt_finalize(node)
            else:
                node.preempt_left -= 1

    def preempt_risk(self) -> np.ndarray:
        """Per-node preemption-risk signal for the GPSO planner: 1 while a
        node is under notice or down, else 0. All zeros when no chaos is
        active, which keeps the planner on its original Eq.9 objective
        (bit-parity with the pre-chaos stack)."""
        return np.asarray(
            [1.0 if (n.down or n.preempt_left >= 0) else 0.0
             for n in self.nodes], np.float32)

    def _fail(self, node: _Node, eng: ReplicaEngine):
        self._destroy(node, eng, node.live)
        self.failed_replicas += 1

    def _destroy(self, node: _Node, eng: ReplicaEngine, pool: list):
        if eng._fleet is not None:
            # pending futures must commit BEFORE progress resets — a stale
            # token applied after evacuate() would corrupt the re-queued
            # request's stream
            self._async_stash.extend(eng._fleet.reconcile(force=True))
        lost = eng.evacuate()
        # lost work re-queues at its original arrival position (it is
        # usually the oldest work on the node, so it retries first — but by
        # arrival accounting, not by a blanket prepend that would jump any
        # newer lost request ahead of older queued ones)
        _requeue_merged(node.queue, lost)
        pool.remove(eng)
        node.credit.pop(id(eng), None)
        self._leave_fleet(eng, restore=False)   # row dropped, not unstacked
        self._retired_prefill_dispatches += eng.prefill_dispatches
        self._retired_syncs += eng.syncs
        self._retired_sync_wait += eng.sync_wait

    def _inject_failures(self):
        if self.failure_rate <= 0.0:
            return
        for node in self.nodes:
            for eng in list(node.live):
                if self.rng.random() < self.failure_rate:
                    self._fail(node, eng)

    # ------------------------------------------------------------- ticking
    def _advance_provisioning(self):
        for node in self.nodes:
            node.spawning = [d - 1 for d in node.spawning]
            ready = sum(1 for d in node.spawning if d <= 0)
            node.spawning = [d for d in node.spawning if d > 0]
            for _ in range(ready):
                self._go_live(node)

    def _generate_arrivals(self, arrival_rate: float):
        if self.request_factory is None or arrival_rate <= 0.0:
            return
        self._acc += arrival_rate * self.tick_seconds
        n = int(self._acc)
        self._acc -= n
        for _ in range(n):
            req = self.request_factory(self._req_id, self.t)
            self._req_id += 1
            req.arrival = float(self.t - 1)   # arrives as this tick begins
            self.ledger.register(req)         # fresh rid: always accepted
            self.pending.append(req)

    def _cull_expired(self) -> list:
        """Sweep ``pending`` and the node queues for requests whose
        deadline has already passed — admitting them would waste routing
        and a prefill on a request that could emit at most one truncated
        token. (Replica-queue heads are culled by ``plan_admission``; a
        deep replica queue is bounded by ``max_batch``.) Culled requests
        are stamped finished-now so the ledger resolves them timed-out.
        No-op when nothing carries a deadline (chaos-off parity)."""
        expired: list = []

        def cull(q):
            dead = [r for r in q if r.out_of_time(self.t)]
            if dead:
                keep = [r for r in q if not r.out_of_time(self.t)]
                q.clear()
                for r in keep:
                    q.append(r)
            expired.extend(dead)

        cull(self.pending)
        for node in self.nodes:
            cull(node.queue)
        for r in expired:
            r.finish_time = float(self.t)
        return expired

    def _reroute_stranded(self):
        """A node with queued work but no live or provisioning replicas would
        strand it forever — hand it back for global re-routing (the elastic
        twin of the fluid sim's retry pool)."""
        for node in self.nodes:
            if node.queue and not node.live and not node.spawning:
                _requeue_merged(self.pending, node.queue)
                node.queue.clear()

    def _route_pending(self):
        mask = self.up_mask()
        if not (mask > 0).any():
            return                      # nothing can serve; hold requests
        fr = normalize_fractions(self._fractions, mask=mask)
        while self.pending:
            idx = int(self.rng.choice(self.num_nodes, p=fr))
            self.nodes[idx].queue.append(self.pending.popleft())

    def _dispatch(self, node: _Node):
        """Fill free replica slots from the node queue (least-loaded first,
        normalized by speed so fast replicas pull more work). The node
        queue hands out work in tiered weighted-deficit order (``pop``, not
        ``popleft``): the deep backlog lives here, so this is where premium
        traffic overtakes — single-tier pops stay plain FIFO."""
        while node.queue:
            cands = [e for e in node.live if e.load < e.max_batch]
            if not cands:
                return
            eng = min(cands, key=lambda e: e.load / max(e.speed, 1e-6))
            eng.submit(node.queue.pop())

    def tick(self, arrival_rate: float = 0.0) -> dict:
        self.t += 1
        prefill_before = self.prefill_dispatches()
        syncs_before = self.sync_count()
        wait_before = self.sync_wait_s()
        # async reconcile point: commit the previous tick's in-flight device
        # results (retires free their slots HERE, before admission planning,
        # so admission timing matches the eager oracle exactly)
        finished_now: list = self._reconcile_all()
        self._advance_provisioning()
        self._advance_chaos()     # scripted events + notice timers: their
        self._inject_failures()   # hand-backs re-route this same tick
        self._generate_arrivals(arrival_rate)
        finished_now.extend(self._cull_expired())
        self._reroute_stranded()
        self._route_pending()
        self._tick_dispatches = 0
        stepping: list = []          # (engine, n_substeps) across ALL nodes
        for node in self.nodes:
            self._dispatch(node)
            for eng in list(node.live) + list(node.draining):
                node.credit[id(eng)] = node.credit.get(id(eng), 0.0) + \
                    eng.speed * node.slow
                n_sub = int(node.credit[id(eng)])
                node.credit[id(eng)] -= n_sub
                if n_sub <= 0:
                    continue
                eng.clock = float(self.t - 1)
                stepping.append((eng, n_sub))
        # sub-step rounds: round r advances every engine with n_sub > r, so
        # a homogeneous-speed cluster runs exactly one round and each fleet
        # group issues ONE decode dispatch (plus, under fleet admission, one
        # prefill dispatch per distinct bucket shape) for the whole tick.
        # Engines are independent within a tick (node queues were dispatched
        # above), so round interleaving matches stepping them one by one.
        max_sub = max((n for _, n in stepping), default=0)
        # a fused decode block may engage on single-round ticks whose
        # admission phase dispatched nothing (the group checks that);
        # unrouted work would mean admissions are imminent, so hold off
        allow_block = (self.decode_block > 1 and max_sub == 1
                       and not self.pending)
        for r in range(max_sub):
            if r > 0 and self.async_tick:
                # hetero sub-rounds: round r's admission may use slots the
                # previous round's decode freed, so reconcile between rounds
                # (homogeneous clusters run one round = one sync per tick)
                finished_now.extend(self._reconcile_all())
            round_engines = [(e, n) for e, n in stepping if n > r]
            ids = {id(e) for e, _ in round_engines}
            for eng, n in round_engines:
                finished_now.extend(eng.begin_step(
                    dt=1.0 / n,
                    admit=eng._fleet is None or not self.fleet_prefill))
            if self.fleet_prefill:
                for g in self._fleets.values():
                    finished_now.extend(g.admit_round(ids))
            for g in self._fleets.values():
                before = g.dispatches
                finished_now.extend(g.decode_round(
                    ids, allow_block=allow_block))
                self._tick_dispatches += g.dispatches - before
            for eng, _ in round_engines:     # engines outside any fleet
                if eng._fleet is None:
                    if eng.n_decoding:
                        self._tick_dispatches += 1
                    finished_now.extend(eng.finish_step())
        for node in self.nodes:
            for eng in list(node.draining):   # retire drained replicas
                if eng.load == 0:
                    node.draining.remove(eng)
                    node.credit.pop(id(eng), None)
                    # retired-empty: nothing worth unstacking from the slab
                    self._leave_fleet(eng, restore=False)
                    self._retired_prefill_dispatches += \
                        eng.prefill_dispatches
                    self._retired_syncs += eng.syncs
                    self._retired_sync_wait += eng.sync_wait
            self.replica_ticks += len(node.live)
        self._tick_prefill_dispatches = \
            self.prefill_dispatches() - prefill_before
        self._tick_syncs = self.sync_count() - syncs_before
        self._tick_sync_wait = self.sync_wait_s() - wait_before
        # finishes force-flushed by mid-tick churn (drain retires, failure
        # evacuations) land in stashes — collect them NOW so a drain loop
        # that terminates on this tick doesn't strand them
        for g in self._fleets.values():
            finished_now.extend(g.take_stash())
        finished_now.extend(self._async_stash)
        self._async_stash = []
        self.finished.extend(finished_now)
        # conservation: land every completion in its terminal ledger state
        # (goodput = in-deadline finishes for a client that still wants
        # them; expired ones are timed_out; abandoned rids count wasted)
        self._tick_goodput = self._tick_timed_out = 0
        for r in finished_now:
            end = self.ledger.resolve(r)
            if end == "finished":
                self._tick_goodput += 1
            elif end == "timed_out":
                self._tick_timed_out += 1
        self._m = self._compute_metrics(finished_now, arrival_rate)
        return self._m

    # -------------------------------------------------------------- metrics
    def _update_service_rate(self, finished_now: list):
        """EMA of measured per-replica requests/tick, fed to the autoscaler
        in place of the static ``unit_capacity`` once warm. Only ticks where
        the cluster is actually serving (work in flight or completions) count
        — idle ticks would drag the estimate to zero."""
        # draining replicas still finish work, so they count as servers —
        # dividing by live only would inflate the rate during scale-downs
        serving = sum(len(n.live) + len(n.draining) for n in self.nodes)
        busy = finished_now or any(n.unfinished() for n in self.nodes)
        if serving <= 0 or not busy:
            return
        rate = len(finished_now) / serving
        if self._srv_rate is None:
            self._srv_rate = rate
        else:
            self._srv_rate += _SERVICE_RATE_ALPHA * (rate - self._srv_rate)
        self._srv_obs += 1

    @property
    def service_rate(self) -> Optional[float]:
        """Measured per-replica req/tick, or None until the EMA warms up."""
        if self._srv_obs < _SERVICE_RATE_WARMUP or not self._srv_rate:
            return None
        return float(self._srv_rate)

    def tier_depths(self) -> np.ndarray:
        """Per-tier unfinished work per node, (T, N) in tier declaration
        order — node queues plus every replica's queued + in-flight slots.
        Counts come from the structures' own per-tier bookkeeping
        (``TieredQueue.depths`` / ``ReplicaEngine.tier_load``); a replica
        built with a different tier config falls back to counting its
        requests under the frontend's tier set."""
        out = np.zeros((len(self.tiers), self.num_nodes), np.float32)
        for i, node in enumerate(self.nodes):
            out[:, i] += node.queue.depths()
            for eng in list(node.live) + list(node.draining):
                tl = eng.tier_load()
                if len(tl) == len(self.tiers):
                    out[:, i] += tl
                else:
                    for req in list(eng.queue) + \
                            [r for r in eng.slots if r is not None]:
                        out[self.tiers.index(req.tier), i] += 1
        return out

    def _overdue_waiting(self) -> dict:
        """Per-tier count of requests still waiting for their first token
        whose age already exceeds the tier's TTFT target. Without this, a
        *starved* tier would report zero SLO violation — only completed
        requests can register a miss, and the reward would go unpenalized
        exactly when the tier is most violated."""
        overdue = {n: 0 for n in self.tiers.names}
        finite = [s for s in self.tiers.specs if np.isfinite(s.ttft_target)]
        if not finite:
            return overdue
        pools = [self.pending]
        for node in self.nodes:
            pools.append(node.queue)
            for eng in list(node.live) + list(node.draining):
                pools.append(eng.queue)
                pools.append(r for r in eng.slots if r is not None)
        for pool in pools:
            for req in pool:
                if req.first_token_time is not None:
                    continue
                spec = self.tiers.specs[self.tiers.index(req.tier)]
                if self.t - req.arrival > spec.ttft_target:
                    overdue[spec.name] += 1
        return overdue

    def _tier_metrics(self, finished_now: list) -> dict:
        """Per-tier latency/SLO view of this tick: queue depths, weighted
        pressure (the GPSO SLO-cost signal), TTFT/TBT means over this
        tick's completions and the tier-weighted SLO violation level the
        Eq.5 reward consumes (this tick's target misses plus the
        already-overdue waiting requests, so starvation is visible before
        anything completes). Untiered frontends emit NO tier keys — the
        control plane must keep planning with the original Eq.9/Eq.5
        objectives, bit-identical to the pre-tier behavior (a single-tier
        ``tier_pressure`` would be plain queue depth and silently flip the
        planner onto the tiered fitness)."""
        if len(self.tiers) <= 1:
            return {}
        tiers = self.tiers
        tq = self.tier_depths()
        overdue = self._overdue_waiting()
        ttft: dict = {}
        tbt: dict = {}
        served: dict = {n: 0 for n in tiers.names}
        viol: dict = {}
        for spec in tiers.specs:
            rows = [r for r in finished_now if tiers.index(r.tier)
                    == tiers.index(spec.name)]
            # queue-culled expired requests never got a first token: they
            # are SLO misses, not latency samples
            done = [r for r in rows if r.first_token_time is not None]
            served[spec.name] = len(done)
            late = overdue[spec.name]
            misses = late + (len(rows) - len(done))
            if done:
                ft = [r.first_token_time - r.arrival for r in done]
                bt = [(r.finish_time - r.first_token_time)
                      / max(len(r.output) - 1, 1) for r in done]
                ttft[spec.name] = float(np.mean(ft))
                tbt[spec.name] = float(np.mean(bt))
                misses += sum(float(f > spec.ttft_target
                                    or b > spec.tbt_target)
                              for f, b in zip(ft, bt))
            denom = len(rows) + late
            if denom:
                viol[spec.name] = misses / denom
        return {
            "tier_queue": tq,
            "tier_pressure": tiers.pressure(tq),
            "tier_ttft": ttft,
            "tier_tbt": tbt,
            "tier_served": served,
            "tier_slo_cost": tiers.slo_cost(viol),
        }

    def _compute_metrics(self, finished_now: list, arrival_rate: float) -> dict:
        for r in finished_now:
            self._est_tokens += 0.05 * (len(r.output) - self._est_tokens)
        self._update_service_rate(finished_now)
        q = self.queue_depths()
        slots = np.asarray(
            [sum(e.max_batch for e in n.live) for n in self.nodes],
            np.float32)
        # demand/capacity utilization, saturating at 1 under backlog — the
        # same semantics as the fluid sim's served/capacity (a pure busy-slot
        # fraction dips between retire and re-admit and never signals
        # saturation to the HPA/RBAS threshold rules).
        util = np.where(slots > 0,
                        np.clip(q / np.maximum(slots, 1e-9), 0.0, 1.0), 0.0)
        up = self.up_mask()
        req_cap = self.request_capacity()
        if finished_now:
            resp = float(np.mean([r.finish_time - r.arrival
                                  for r in finished_now]))
            self._resp_est = resp
        else:
            # queueing estimate: backlog / service rate + one service time
            backlog = np.where(req_cap > 1e-9,
                               q / np.maximum(req_cap, 1e-9), 10.0)
            est = float(np.mean(backlog)) + self._est_tokens
            resp = max(self._resp_est, est) if q.sum() > 0 else self._resp_est
        overload = float(np.mean(np.where(
            req_cap > 1e-9,
            np.clip(q / np.maximum(req_cap, 1e-9) / 4.0, 0, 1), 1.0)))
        return {
            "utilization": util.astype(np.float32),
            "mean_utilization": float(np.mean(util[up > 0.5])
                                      if (up > 0.5).any() else 0.0),
            "response_time": resp,
            "served": float(len(finished_now)),
            "served_tokens": float(sum(len(r.output) for r in finished_now)),
            "overload": overload,
            "capacity": req_cap,
            "queue": q,
            "up": up,
            "active_replicas": np.asarray(
                [len(n.live) for n in self.nodes], np.int32),
            "replica_ticks": int(sum(len(n.live) for n in self.nodes)),
            "decode_dispatches": int(self._tick_dispatches),
            "prefill_dispatches": int(self._tick_prefill_dispatches),
            "syncs": int(self._tick_syncs),
            "sync_wait_s": float(self._tick_sync_wait),
            "fleet_groups": int(sum(1 for g in self._fleets.values()
                                    if len(g))),
            "service_rate": self.service_rate,
            # robustness view: all zeros when chaos/clients are off, so
            # the planner (guarded by .any()) and reward see no change
            "goodput": float(self._tick_goodput),
            "timed_out": float(self._tick_timed_out),
            "preempt_risk": self.preempt_risk(),
            # multi-cell view (PR 8): a single frontend IS one healthy cell
            # — staleness/risk/shed are identically zero here, and the
            # routing plane overrides them with real per-cell values. Key
            # presence is constant so planner guards stay shape-stable.
            "cell_staleness": np.zeros(1, np.float32),
            "cell_risk": np.zeros(1, np.float32),
            "shed": 0.0,
            # hierarchical-control view (PR 10): a single frontend has no
            # global plane above it and no lease unless the hierarchy set
            # one — identically zero here; MultiCellBackend overrides with
            # real plane-staleness / lease-utilization / local-action
            # counts. Key presence is constant (same contract as above).
            "plane_staleness": 0.0,
            "lease_util": np.zeros(1, np.float32),
            "local_actions": 0.0,
            **self._tier_metrics(finished_now),
        }

    # ------------------------------------------------------------ draining
    def run_until_drained(self, max_steps: int = 10_000):
        """Finish all outstanding work (controlled wind-down: chaos
        injection pauses so the backlog can actually clear)."""
        rate, self.failure_rate = self.failure_rate, 0.0
        chaos, self.chaos = self.chaos, None   # scripted events pause too;
        try:                                   # notice timers still expire
            for _ in range(max_steps):
                # safety: if scaling/failures left the whole cluster with no
                # capacity while work is outstanding, spawn one drain worker
                # (an aggressive scale-to-zero must never drop requests) —
                # on a node that is neither preempted-down nor under notice
                if (self.pending or any(n.unfinished() for n in self.nodes)) \
                        and not any(n.live or n.spawning for n in self.nodes):
                    host = next((n for n in self.nodes
                                 if not n.down and n.preempt_left < 0), None)
                    if host is None:           # everything preempted away:
                        host = self.nodes[0]   # force one node back up
                        host.down = False
                    self._go_live(host)
                self.tick(0.0)
                if not self.pending and all(n.unfinished() == 0
                                            for n in self.nodes):
                    return
            raise RuntimeError("elastic cluster did not drain")
        finally:
            self.failure_rate = rate
            self.chaos = chaos
