from repro.data.pipeline import (  # noqa: F401
    DataLoader, MarkovCorpus, prompt_workload,
)
