"""Synthetic data pipeline (offline container — no real corpora).

``MarkovCorpus`` generates token streams from a seeded sparse Markov chain
with Zipfian marginals and planted induction patterns — enough learnable
structure that a ~100M model's loss drops well below the unigram entropy
within a few hundred steps (the end-to-end train driver's acceptance check).

Deterministic per (seed, host): shard-disjoint streams for data parallelism.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class MarkovCorpus:
    vocab_size: int
    seed: int = 0
    branching: int = 8            # successors per state
    zipf_a: float = 1.2
    induction_p: float = 0.2      # chance to copy an earlier bigram

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        V, B = self.vocab_size, self.branching
        # Zipfian unigram prior over successor choices
        ranks = np.arange(1, V + 1, dtype=np.float64)
        zipf = 1.0 / ranks ** self.zipf_a
        zipf /= zipf.sum()
        self.successors = rng.choice(V, size=(V, B), p=zipf)
        probs = rng.dirichlet(np.ones(B) * 0.5, size=V)
        self.probs = probs.astype(np.float64)

    def sample(self, rng: np.random.Generator, batch: int, seq: int
               ) -> np.ndarray:
        V, B = self.vocab_size, self.branching
        out = np.empty((batch, seq), np.int64)
        state = rng.integers(0, V, size=batch)
        for t in range(seq):
            u = rng.random(batch)
            # vectorized categorical over each row's successor distribution
            cdf = np.cumsum(self.probs[state], axis=1)
            choice = (u[:, None] > cdf).sum(axis=1).clip(0, B - 1)
            state = self.successors[state, choice]
            # induction: occasionally replay token from 8 steps back
            if t >= 8:
                replay = rng.random(batch) < self.induction_p
                state = np.where(replay, out[:, t - 8], state)
            out[:, t] = state
        return out

    def unigram_entropy(self, n: int = 20000) -> float:
        rng = np.random.default_rng(123)
        toks = self.sample(rng, 8, n // 8).reshape(-1)
        _, counts = np.unique(toks, return_counts=True)
        p = counts / counts.sum()
        return float(-(p * np.log(p)).sum())


@dataclasses.dataclass
class DataLoader:
    """Sharded, prefetch-free loader: batch = global_batch // n_hosts rows."""
    corpus: MarkovCorpus
    global_batch: int
    seq_len: int
    host_id: int = 0
    n_hosts: int = 1
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(
            (self.seed * 1009 + self.host_id) % (2 ** 31))
        self.local_batch = self.global_batch // self.n_hosts

    def __iter__(self):
        return self

    def __next__(self):
        toks = self.corpus.sample(self._rng, self.local_batch,
                                  self.seq_len + 1)
        return {"tokens": toks[:, :-1].astype(np.int32),
                "targets": toks[:, 1:].astype(np.int32)}


def prompt_workload(vocab: int, n: int, seed: int = 0, max_len: int = 12,
                    max_new: int = 16):
    """Synthetic serving requests for the engine examples/tests."""
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        plen = int(rng.integers(2, max_len))
        out.append({
            "rid": i,
            "prompt": rng.integers(1, vocab, size=plen).tolist(),
            "max_new_tokens": int(rng.integers(4, max_new)),
        })
    return out
