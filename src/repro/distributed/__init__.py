from repro.distributed.sharding import (  # noqa: F401
    ShardPlan, batch_shardings, collective_bytes, make_shard_fn,
    param_shardings, serve_state_shardings,
)
from repro.distributed.elastic import (  # noqa: F401
    elastic_remesh, reshard_params, survivors_mesh,
)
