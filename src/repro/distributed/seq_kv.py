"""Sequence-sharded KV decode attention (flash-decode with LSE merge).

Baseline decode shards KV HEADS over the "model" axis; with tp > kv_heads
that forces kv replication (2x cache memory for the kv=8 archs at TP=16).
This op shards the cache SEQUENCE over "model" instead, keeps the LOGICAL
(unpadded) kv heads, computes per-shard partial attention, and merges with
the flash-decode log-sum-exp trick:

    m = pmax(m_i);  l = psum(l_i · e^{m_i−m});  acc = psum(acc_i · e^{m_i−m})

Per-device HBM traffic drops by the replication factor AND the per-step
collective is 3 tiny (B, H, d)-sized psums instead of a head-gather. The
§Perf cell C iteration quantifies the delta; this op is the implementation
(exercised by tests/test_seq_kv.py on an 8-device host mesh).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

NEG_INF = -1e30


def _local_partial(q, k, v, pos, s_offset):
    """Partial flash-decode over a local seq shard.

    q: (B, Hq, d); k, v: (B, S_loc, KV, d); mask positions > pos.
    Returns (m (B,Hq), l (B,Hq), acc (B,Hq,d)).
    """
    B, Hq, d = q.shape
    KV = k.shape[2]
    rep = Hq // KV
    kr = jnp.repeat(k, rep, axis=2).astype(jnp.float32)   # (B,S,Hq,d)
    vr = jnp.repeat(v, rep, axis=2).astype(jnp.float32)
    s = jnp.einsum("bhd,bthd->bht", q.astype(jnp.float32), kr) / np.sqrt(d)
    offs = s_offset + jnp.arange(k.shape[1])
    s = jnp.where((offs <= pos)[None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1)                                # (B,Hq)
    p = jnp.exp(s - m[..., None])
    p = jnp.where((offs <= pos)[None, None, :], p, 0.0)
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bht,bthd->bhd", p, vr)
    return m, l, acc


def seq_sharded_flash_decode(mesh, q, k_cache, v_cache, pos, *,
                             seq_axis: str = "model",
                             batch_axes=("data",)):
    """q: (B, Hq, d) [batch over `batch_axes`, replicated over `seq_axis`];
    k_cache/v_cache: (B, S, KV_logical, d) [S over `seq_axis`]; pos scalar.

    Returns (B, Hq, d) attention over cache[0..pos].
    """
    S = k_cache.shape[1]
    n = mesh.shape[seq_axis]
    S_loc = S // n
    ba = tuple(a for a in batch_axes if a in mesh.axis_names)
    b_spec = ba if ba else None

    def kernel(q, k, v, pos):
        idx = jax.lax.axis_index(seq_axis)
        m, l, acc = _local_partial(q, k, v, pos, idx * S_loc)
        m_g = jax.lax.pmax(m, seq_axis)
        corr = jnp.exp(m - m_g)
        l_g = jax.lax.psum(l * corr, seq_axis)
        acc_g = jax.lax.psum(acc * corr[..., None], seq_axis)
        safe = jnp.where(l_g > 0, l_g, 1.0)
        return (acc_g / safe[..., None]).astype(q.dtype)

    return shard_map(
        kernel, mesh=mesh,
        in_specs=(P(b_spec, None, None),
                  P(b_spec, seq_axis, None, None),
                  P(b_spec, seq_axis, None, None),
                  P()),
        out_specs=P(b_spec, None, None),
        check_rep=False,
    )(q, k_cache, v_cache, jnp.asarray(pos, jnp.int32))


def seq_kv_cache_bytes(cfg, B, S) -> int:
    """Stored bytes with logical (unpadded) kv heads — the memory win."""
    return 2 * cfg.num_layers * B * S * cfg.num_kv_heads * \
        cfg.resolved_head_dim * 2
