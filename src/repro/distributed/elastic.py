"""Elastic scaling: rebuild the mesh and re-place state when the data-parallel
width changes (scale-up from the autoscaler, or shrink after node failure).

The TP ("model") axis is fixed by the checkpointed layout; elasticity happens
on the data axes — exactly the knob the paper's GPSO autoscaler turns. The
resharding is a device_put from the old sharding to the new (XLA moves only
the shards that need to move).
"""
from __future__ import annotations

import jax
import numpy as np

from repro.distributed.sharding import ShardPlan, param_shardings
from repro.launch.mesh import make_mesh


def elastic_remesh(data: int, model: int, devices=None):
    """Build a (data, model) mesh over a device subset (shrink/grow)."""
    devices = devices if devices is not None else jax.devices()
    need = data * model
    if len(devices) < need:
        raise ValueError(f"need {need} devices, have {len(devices)}")
    sub = np.asarray(devices[:need]).reshape(data, model)
    from jax.sharding import Mesh
    return Mesh(sub, ("data", "model"))


def reshard_params(params, new_plan: ShardPlan):
    """Move live params onto a new mesh/plan (elastic scale event)."""
    shardings = param_shardings(new_plan, params)
    return jax.device_put(params, shardings)


def survivors_mesh(mesh, failed_indices, model: int):
    """Shrink after failures: drop the data-rows containing failed devices.

    failed_indices: flat indices into mesh.devices. Returns a new mesh with
    fewer data rows (the restart path pairs this with checkpoint restore).
    """
    devs = np.asarray(mesh.devices).reshape(-1, model)
    bad_rows = set()
    flat = list(np.asarray(mesh.devices).reshape(-1))
    for fi in failed_indices:
        bad_rows.add(fi // model)
    rows = [r for r in range(devs.shape[0]) if r not in bad_rows]
    if not rows:
        raise ValueError("no surviving data rows")
    from jax.sharding import Mesh
    return Mesh(devs[rows], ("data", "model"))
