"""Sharding rules: param-path → PartitionSpec, activation constraints, and
input shardings for every (arch × shape-kind).

Two regimes (DESIGN.md §4):
  train — FSDP over ("pod","data") on each tensor's non-TP dim + TP over
          "model" (heads / d_ff / vocab). Optimizer moments follow weights.
  serve — weights replicated over data axes, TP over "model"; KV caches
          shard batch over data and kv-heads over "model".

Rules are written against *trailing* dims so stacked-layer leading axes
(L, groups, ...) are automatically replicated. Any dim whose size does not
divide its mesh axes falls back to replication (e.g. batch=1 long_500k).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardPlan:
    mesh: Mesh
    mode: str                      # "train" | "serve"
    expert_sharding: str = "none"  # "none" | "data" (EP)

    @property
    def dp_axes(self):
        """Data-like axes (batch + FSDP). A dedicated 'expert' axis (the
        EP mesh refactor, e.g. (data=2, expert=8, model=16)) still carries
        batch/FSDP for the non-MoE tensors."""
        names = self.mesh.axis_names
        return tuple(a for a in ("pod", "data", "expert") if a in names)

    @property
    def ep_axis(self):
        """Axis holding the expert dim: an explicit 'expert' mesh axis, or
        the data axes when expert_sharding='data'."""
        if "expert" in self.mesh.axis_names:
            return ("expert",)
        if self.expert_sharding == "data":
            return self.dp_axes
        return None

    @property
    def expert_inner_axes(self):
        """Data axes usable for the within-expert dims (excludes ep_axis)."""
        ep = self.ep_axis or ()
        return tuple(a for a in self.dp_axes if a not in ep) or None

    @property
    def tp_axis(self):
        return "model" if "model" in self.mesh.axis_names else None

    @property
    def fsdp(self):
        """Weight-sharding data axes (None in serve mode -> replicated)."""
        return self.dp_axes if self.mode == "train" else None

    @property
    def tp_size(self) -> int:
        return self.mesh.shape.get("model", 1)


# --------------------------------------------------------------- param rules
def _trailing_rules(plan: ShardPlan, path_names: tuple) -> Optional[tuple]:
    """Spec for the trailing dims of a param, by leaf name (+ context)."""
    name = path_names[-1]
    in_moe = "moe" in path_names or "moe_layers" in path_names
    fsdp, tp = plan.fsdp, plan.tp_axis
    ep = plan.ep_axis if in_moe else None
    # MoE expert weights are ~95% of a MoE model's params: keep them
    # data-sharded even in serve mode (TP alone cannot hold 300-400B weights
    # in 16 GB/chip; the per-layer gather is one expert block, not the model).
    # Under EP the expert dim takes its own axis; within-expert dims use the
    # remaining data axes.
    moe_fsdp = plan.expert_inner_axes if ep else \
        (plan.dp_axes if in_moe else fsdp)
    table = {
        "embed": (tp, fsdp),            # (V, d)
        "lm_head": (fsdp, tp),          # (d, V)
        "patch_proj": (fsdp, tp),       # (d, d)
        "dec_pos": (None, fsdp),        # (S, d)
        "wq": (fsdp, tp, None),         # (d, nq, hd)
        "wk": (fsdp, tp, None),
        "wv": (fsdp, tp, None),
        "wo": (tp, None, fsdp),         # (nq, hd, d)
        "bq": (tp, None),
        "bk": (tp, None),
        "bv": (tp, None),
        "router": (fsdp, None),         # (d, E)
        "in_proj": (fsdp, None),        # (d, d_in_proj) — see DESIGN §4
        "out_proj": (tp, fsdp),         # (d_inner, d)
        "conv_w": (None, tp),           # (W, C)
        "conv_b": (tp,),
        "norm_scale": (tp,),            # (d_inner,)
        "head": (fsdp, None),
    }
    if name in ("w_gate", "w_up"):
        if in_moe and len(path_names) >= 2 and path_names[-2] != "shared":
            return (ep[0] if ep else None, moe_fsdp, tp)   # (E, d, ff)
        return (fsdp, tp)                                  # (d, ff)
    if name == "w_down":
        if in_moe and len(path_names) >= 2 and path_names[-2] != "shared":
            return (ep[0] if ep else None, tp, moe_fsdp)   # (E, ff, d)
        return (tp, fsdp)
    return table.get(name)


def _fits(spec_entry, dim: int, mesh: Mesh) -> bool:
    if spec_entry is None:
        return True
    axes = spec_entry if isinstance(spec_entry, tuple) else (spec_entry,)
    size = int(np.prod([mesh.shape[a] for a in axes]))
    return dim % size == 0


def param_pspec(plan: ShardPlan, path, leaf) -> P:
    names = tuple(
        p.key if hasattr(p, "key") else str(p) for p in path)
    right = _trailing_rules(plan, names)
    ndim = leaf.ndim
    if right is None or ndim < len(right):
        return P()
    lead = (None,) * (ndim - len(right))
    entries = []
    for e, dim in zip(lead + tuple(right), leaf.shape):
        entries.append(e if _fits(e, dim, plan.mesh) else None)
    return P(*entries)


def param_shardings(plan: ShardPlan, params):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(plan.mesh,
                                         param_pspec(plan, path, leaf)),
        params)


# ----------------------------------------------------------- activation tags
def make_shard_fn(plan: ShardPlan):
    """shard_fn(x, tag) used inside model code (GSPMD constraint hints)."""
    dp, tp = plan.dp_axes, plan.tp_axis
    # (E, B, C, d) dispatch buffer: E must follow the expert-weight sharding
    # (EP: E over the expert axes, batch over the rest) or GSPMD re-gathers
    # the expert weights to match the buffer.
    if plan.ep_axis:
        moe_buf = (plan.ep_axis, plan.expert_inner_axes, None, None)
    else:
        moe_buf = (None, dp, None, None)
    specs = {
        "act_btd": (dp, None, None),
        "logits": (dp, None, tp),
        "qkv": (dp, None, tp, None, None),
        "kv": (dp, None, tp, None),
        "moe_buf": moe_buf,
    }

    def shard_fn(x, tag):
        spec = specs.get(tag)
        if spec is None or x.ndim != len(spec):
            return x
        entries = [e if _fits(e, d, plan.mesh) else None
                   for e, d in zip(spec, x.shape)]
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(plan.mesh, P(*entries)))

    return shard_fn


# --------------------------------------------------------------- input specs
def batch_shardings(plan: ShardPlan, batch_specs):
    """Shardings for train/prefill inputs: batch dim over data axes."""
    dp = plan.dp_axes

    def one(spec):
        entries = [dp if _fits(dp, spec.shape[0], plan.mesh) else None]
        entries += [None] * (len(spec.shape) - 1)
        return NamedSharding(plan.mesh, P(*entries))

    return jax.tree.map(one, batch_specs)


def _serve_state_entries(name: str, ndim: int, dp, tp) -> tuple:
    """Per-dim axis entries for one serve-state leaf (batch over data, heads
    over model) — shared by the per-replica and fleet-slab rule sets.

    Leaf layouts (leading stack axis first):
      lm k/v            (L, B, S, G, hd)
      ssm 'ssm'         (L, B, H, P, N)
      ssm 'conv'        (L, B, W-1, C)
      hybrid attn_k/v   (n_inv, B, S, G, hd)
      encdec self/cross (L, B, S, G, hd)
    """
    if name in ("k", "v", "attn_k", "attn_v", "self_k", "self_v",
                "cross_k", "cross_v"):
        return (None, dp, None, tp, None)
    if name == "ssm":
        return (None, dp, tp, None, None)
    if name == "conv":
        return (None, dp, None, tp)
    return (None,) * ndim


def serve_state_shardings(plan: ShardPlan, state_specs, cfg):
    """Decode-state shardings: batch over data, heads over model (see
    ``_serve_state_entries`` for the leaf layouts)."""
    dp, tp = plan.dp_axes, plan.tp_axis

    def one(path, spec):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        shape = spec.shape
        entries = _serve_state_entries(name, len(shape), dp, tp)
        entries = [e if _fits(e, d, plan.mesh) else None
                   for e, d in zip(entries, shape)]
        return NamedSharding(plan.mesh, P(*entries))

    return jax.tree_util.tree_map_with_path(one, state_specs)


def fleet_slab_shardings(mesh: Mesh, slab_specs):
    """Shardings for a ``FleetGroup`` slab: the leading fleet axis maps over
    the mesh's ``fleet`` axis (F replicas decode on N devices in parallel);
    trailing per-replica dims reuse the serve-mode rules on any data/model
    axes also present (a pure ``('fleet',)`` serving mesh replicates them).
    Params are NOT sharded this way — they replicate over the fleet axis
    (every shard decodes its own slab rows with the full weights). A leading
    dim that does not divide the fleet axis falls back to replication, so
    callers must keep slab capacity a multiple of the shard count (see
    ``FleetGroup`` growth)."""
    if "fleet" not in mesh.axis_names:
        raise ValueError(
            f"serving mesh needs a 'fleet' axis, got {mesh.axis_names}")
    dp = tuple(a for a in ("pod", "data", "expert") if a in mesh.axis_names)
    dp = dp or None
    tp = "model" if "model" in mesh.axis_names else None

    def one(path, spec):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        shape = spec.shape
        entries = ("fleet",) + _serve_state_entries(name, len(shape) - 1,
                                                    dp, tp)
        entries = [e if _fits(e, d, mesh) else None
                   for e, d in zip(entries, shape)]
        return NamedSharding(mesh, P(*entries))

    return jax.tree_util.tree_map_with_path(one, slab_specs)


# -------------------------------------------------- HLO collective analysis
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

# per-device traffic multiplier per collective kind (ring algorithms)
_TRAFFIC_FACTOR = {
    "all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
    "all-to-all": 1.0, "collective-permute": 1.0,
}


def _shape_bytes(type_str: str) -> int:
    import re
    total = 0
    for m in re.finditer(r"(\w+)\[([\d,]*)\]", type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Parse an HLO dump; per-device collective traffic bytes by op kind.

    Uses result shapes × ring-traffic factors (all-reduce counts 2x). Returns
    {kind: bytes, ..., "total": bytes}.
    """
    import re
    pat = re.compile(
        r"=\s*((?:\([^)]*\))|(?:\w+\[[^\]]*\][^\s]*))\s+"
        r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
        r"collective-permute)(?:-start)?\(")
    out = {k: 0.0 for k in _TRAFFIC_FACTOR}
    for line in hlo_text.splitlines():
        m = pat.search(line)
        if not m:
            continue
        kind = m.group(2)
        out[kind] += _shape_bytes(m.group(1)) * _TRAFFIC_FACTOR[kind]
    out["total"] = sum(out.values())
    return out
