"""Decentralized two-level control: per-cell autoscalers under capacity
leases, with a crash-tolerant global plane.

The paper's fault-tolerance claim is that *decentralised decision-making*
keeps scaling responsive when the central coordinator degrades. PR 8 left
the federation with exactly one brain: ``ControlPlane`` over
``MultiCellBackend`` — a plane outage froze ALL autoscaling even while
every cell was healthy. This module splits control in two (the OptScaler
pattern: autonomous local reactive correctors bounded by a slower global
proactive plan):

  * ``CellController`` — one per cell, runs a reactive scale rule on the
    cell's OWN live signals every tick (local state is never stale), but
    only inside the cell's current **capacity lease**. Rule: sustained
    high utilization or queue-over-capacity adds replicas toward the
    lease max; sustained idleness retires them toward the lease min. All
    actions go through ``MultiCellBackend.scale_cell`` and the cell
    backend's own lease clamp, and are reported via
    ``note_local_action`` (→ the ``local_actions`` metric).
  * ``CellLease`` — ``[min_replicas, max_replicas]`` bounds plus the
    planner's proactive ``budget`` set-point. Granting a lease installs
    the bounds on the cell backend itself (``set_lease``), so even a
    confused global plane replaying a stale plan cannot overshoot.
  * ``GlobalPlanner`` — re-plans cross-cell leases every
    ``plan_interval`` ticks from the per-cell ``MetricsView``
    staleness/risk signals the router already maintains: demand shares
    (queue + in-flight work) are discounted by confidence decay on stale
    views and by preemption risk, budgets split a global replica budget
    proportionally, and ``lease_slack`` opens headroom above the budget
    for the local controllers to react into.
  * ``PlaneSupervisor`` — owns the global tick: while the plane is alive
    it steps the (optional) ``ControlPlane`` for forecasting/balancing
    and re-grants leases on the planner cadence; when
    ``MultiCellBackend.plane_alive`` goes false (``plane_down@t`` chaos)
    it ticks the backend directly — no global observation, no balancing,
    no lease changes — while every ``CellController`` keeps scaling
    inside its LAST lease at full tick rate. ``checkpoint()`` /
    ``restore()`` carry planner + plane + lease state across a crash: a
    freshly constructed supervisor that loads the checkpoint continues
    the exact decision stream (bit-identical plans and token streams —
    asserted in ``tests/test_hierarchy.py``). On the down→up transition
    the supervisor *reconciles*: it re-plans immediately from live cell
    state rather than replaying pre-crash scale targets, so no action is
    double-applied and the global ``RequestLedger`` stays exactly-once
    throughout (``double_served == 0``).

Outage semantics are deterministic: ``plane_down@t[:kK]`` lands inside
backend tick ``t`` (views start aging that tick); the supervisor observes
``plane_alive == False`` from the following ``step`` and suppresses the
global plane until the tick after ``plane_up`` lands. Scale-reaction
latency — ticks from a burst's onset to the first scale-up action — is
the headline A/B stat (`benchmarks/serve_bench.py` ``plane_outage``):
hierarchical control reacts during the outage, the centralized-frozen
baseline cannot react until restore.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.control.cells import MultiCellBackend


@dataclasses.dataclass
class CellLease:
    """Capacity lease for one cell: hard ``[min_replicas, max_replicas]``
    bounds on the cell's total in-flight replica count plus the planner's
    proactive ``budget`` set-point (min <= budget <= max)."""
    min_replicas: int
    max_replicas: int
    budget: int

    def __post_init__(self):
        if not (0 <= self.min_replicas <= self.budget <= self.max_replicas):
            raise ValueError(
                f"bad lease min={self.min_replicas} budget={self.budget} "
                f"max={self.max_replicas}")

    def astuple(self) -> tuple:
        return (self.min_replicas, self.max_replicas, self.budget)


class CellController:
    """Per-cell reactive autoscaler: acts EVERY tick on the cell's own
    live signals, bounded by the current lease. Decentralized by
    construction — it reads nothing global and keeps working when the
    global plane is dark.

    Rule (k8s-style with patience): utilization above ``hi`` (or queue
    exceeding ``surge`` ticks of capacity) for ``patience`` consecutive
    ticks adds one replica; utilization below ``lo`` with an empty queue
    for ``patience`` ticks removes one; ``cooldown`` ticks separate
    actions. Targets clamp into the lease before they reach the backend
    (which clamps again — the lease is enforced twice by design)."""

    def __init__(self, backend: MultiCellBackend, cell_index: int, *,
                 hi: float = 0.85, lo: float = 0.25, surge: float = 2.0,
                 patience: int = 2, cooldown: int = 2):
        self.backend = backend
        self.c = int(cell_index)
        self.hi = float(hi)
        self.lo = float(lo)
        self.surge = float(surge)
        self.patience = int(patience)
        self.cooldown = int(cooldown)
        self.lease: Optional[CellLease] = None
        self.actions = 0              # total local scale actions taken
        self.up_actions = 0
        self._over = 0
        self._under = 0
        self._last_action = -(10 ** 9)
        self.action_ticks: list = []  # backend tick of each action (stats)

    def grant(self, lease: CellLease) -> None:
        """Install a new lease: bounds land on the cell backend itself and
        the current replica count is pulled into range immediately (a
        shrunken lease takes effect now, not at the next pressure
        change)."""
        self.lease = lease
        cell = self.backend.cells[self.c]
        cell.set_lease(lease.min_replicas, lease.max_replicas)
        cur = self.backend.cell_in_flight(self.c)
        if cur < lease.min_replicas or cur > lease.max_replicas:
            tgt = int(np.clip(cur, lease.min_replicas, lease.max_replicas))
            self.backend.scale_cell(self.c, tgt)

    def _signals(self) -> tuple:
        """(utilization proxy, queue, capacity) from LIVE cell state."""
        cell = self.backend.cells[self.c]
        if self.backend._elastic[self.c]:
            q = float(cell.queue_depths().sum())
            cap = float(cell.request_capacity().sum())
        else:
            q = float(cell.state.queue.sum())
            cap = float(cell.capacity().sum()) * self.backend.tick_seconds
        m = self.backend._live_m[self.c]
        util = float(m.get("mean_utilization", 0.0)) if m else 0.0
        return util, q, cap

    def step(self) -> None:
        """One local control tick. No-op without a lease (centralized
        mode) or while the cell is blacked out."""
        if self.lease is None or not self.backend._alive[self.c]:
            self._over = self._under = 0
            return
        util, q, cap = self._signals()
        hot = util > self.hi or (cap > 1e-9 and q > self.surge * cap) \
            or (cap <= 1e-9 and q > 0.0)
        cold = util < self.lo and q <= 0.0
        self._over = self._over + 1 if hot else 0
        self._under = self._under + 1 if cold else 0
        t = self.backend.t
        if t - self._last_action < self.cooldown:
            return
        cur = self.backend.cell_in_flight(self.c)
        tgt = cur
        if self._over >= self.patience and cur < self.lease.max_replicas:
            tgt = cur + 1
        elif self._under >= self.patience and cur > self.lease.min_replicas:
            tgt = cur - 1
        if tgt == cur:
            return
        self.backend.scale_cell(self.c, tgt)
        self._last_action = t
        self._over = self._under = 0
        self.actions += 1
        if tgt > cur:
            self.up_actions += 1
        self.action_ticks.append(t)
        self.backend.note_local_action()


class GlobalPlanner:
    """Cross-cell lease planner: a pure function of the router's views —
    deterministic, stateless, safe to re-run from a checkpoint.

    Demand per cell = last-known queue + in-flight work, discounted by
    ``confidence_decay ** staleness`` (a dark cell's demand estimate is
    old) and by ``1 - risk`` (a doomed cell should not be granted budget
    it is about to lose). Budgets split ``total_budget`` proportionally
    (every alive cell keeps at least ``min_per_cell``); the lease opens
    ``lease_slack`` headroom above and below the budget so the local
    controllers can react without waiting for the next global plan."""

    def __init__(self, n_cells: int, *, total_budget: int,
                 max_per_cell: int, min_per_cell: int = 1,
                 lease_slack: float = 0.5, confidence_decay: float = 0.6):
        if total_budget < n_cells * min_per_cell:
            raise ValueError(
                f"total_budget {total_budget} cannot cover "
                f"{n_cells} cells x min {min_per_cell}")
        self.n_cells = int(n_cells)
        self.total_budget = int(total_budget)
        self.max_per_cell = int(max_per_cell)
        self.min_per_cell = int(min_per_cell)
        self.lease_slack = float(lease_slack)
        self.confidence_decay = float(confidence_decay)

    def plan(self, views: list, alive: np.ndarray,
             in_flight: np.ndarray) -> list:
        """One lease per cell (dead cells get an empty [0, 0] lease)."""
        demand = np.zeros(self.n_cells, np.float64)
        for c, v in enumerate(views):
            if not alive[c]:
                continue
            d = max(v.snap.get("queue", 0.0), 0.0) + max(int(in_flight[c]),
                                                         1)
            conf = self.confidence_decay ** v.staleness
            risk = float(np.clip(v.snap.get("risk", 0.0), 0.0, 1.0))
            demand[c] = d * conf * (1.0 - 0.8 * risk) + 1e-9
        total = demand.sum()
        leases = []
        for c in range(self.n_cells):
            if not alive[c] or total <= 0.0:
                leases.append(CellLease(0, 0, 0))
                continue
            budget = int(round(self.total_budget * demand[c] / total))
            budget = int(np.clip(budget, self.min_per_cell,
                                 self.max_per_cell))
            hi = int(np.clip(int(np.ceil(budget * (1.0 + self.lease_slack))),
                             budget, self.max_per_cell))
            lo = int(np.clip(int(np.floor(budget *
                                          (1.0 - self.lease_slack))),
                             0, budget))
            lo = max(lo, min(self.min_per_cell, budget))
            leases.append(CellLease(lo, hi, budget))
        return leases


class PlaneSupervisor:
    """Owns the global control tick and makes the global plane
    crash-tolerant. See module docstring for the full contract.

    ``plane`` is an optional ``ControlPlane`` (forecast + balance;
    construct it with ``scaler='none'`` — scaling authority belongs to
    the leases). With ``plane=None`` the supervisor runs the pure
    decentralized loop: backend tick + local controllers + lease plans.
    """

    def __init__(self, backend: MultiCellBackend, planner: GlobalPlanner,
                 controllers: list, *, plane=None, plan_interval: int = 10,
                 apply_budget: bool = True):
        self.backend = backend
        self.planner = planner
        self.controllers = list(controllers)
        self.plane = plane
        self.plan_interval = max(1, int(plan_interval))
        self.apply_budget = apply_budget
        self.leases: list = [None] * backend.n_cells
        self.plan_log: list = []      # (tick, [lease tuples]) per grant
        self.outage_steps = 0         # steps run with the plane dark
        self.restores = 0             # down->up reconciliations observed
        self._last_plan: Optional[int] = None
        self._saw_down = False

    # -------------------------------------------------- checkpoint/restore
    def checkpoint(self) -> dict:
        """Everything a restarted global-plane process needs: planner
        config is immutable, so the checkpoint is the lease state, the
        plan cadence phase, and the ``ControlPlane`` decision state.
        Cheap enough to take every plan interval."""
        return {
            "last_plan": self._last_plan,
            "leases": [lease.astuple() if lease is not None else None
                       for lease in self.leases],
            # controller DECISION state (patience counters + cooldown
            # clock) — stats counters reset with the process, but the
            # reactive rule must resume mid-stride for the restored run
            # to continue the exact decision stream
            "controllers": [(ctl._over, ctl._under, ctl._last_action)
                            for ctl in self.controllers],
            "plane": self.plane.state_dict() if self.plane is not None
            else None,
        }

    def restore(self, state: dict) -> None:
        """Load a checkpoint into this (possibly freshly constructed)
        supervisor. Pure state reinstatement — leases re-install their
        bounds on the cells (idempotent), but NO scale targets are
        replayed: current replica counts are live cell state the crashed
        plane has no authority to rewind. Reconciliation against live
        state happens on the next ``step`` via the normal down→up
        transition (or the plan cadence, if no outage happened)."""
        self._last_plan = state["last_plan"]
        self.leases = [CellLease(*t) if t is not None else None
                       for t in state["leases"]]
        for ctl, lease in zip(self.controllers, self.leases):
            ctl.lease = lease
            if lease is not None:
                self.backend.cells[ctl.c].set_lease(lease.min_replicas,
                                                    lease.max_replicas)
        for ctl, cs in zip(self.controllers,
                           state.get("controllers") or []):
            ctl._over, ctl._under, ctl._last_action = cs
        if self.plane is not None and state.get("plane") is not None:
            self.plane.load_state_dict(state["plane"])

    # --------------------------------------------------------------- plan
    def _grant(self, leases: list) -> None:
        self.leases = list(leases)
        for ctl, lease in zip(self.controllers, self.leases):
            if lease.max_replicas <= 0 and lease.min_replicas <= 0 \
                    and not self.backend._alive[ctl.c]:
                ctl.lease = None       # dead cell: nothing to control
                continue
            ctl.grant(lease)
            if self.apply_budget:
                # the proactive half: steer toward the planner's set-point
                # (the reactive controllers correct from there)
                self.backend.scale_cell(ctl.c, lease.budget)

    def _plan_now(self) -> None:
        in_flight = np.asarray(
            [self.backend.cell_in_flight(c)
             for c in range(self.backend.n_cells)], np.int64)
        leases = self.planner.plan(self.backend.views, self.backend._alive,
                                   in_flight)
        self._grant(leases)
        self._last_plan = self.backend.t
        self.plan_log.append(
            (self.backend.t, [lease.astuple() for lease in leases]))

    # --------------------------------------------------------------- tick
    def step(self, arrival_rate: float = 0.0) -> dict:
        """One global tick: plane work only while alive, local control
        always."""
        alive_before = self.backend.plane_alive
        if alive_before and self._saw_down:
            # down -> up observed: the restarted plane reconciles against
            # live cell state with a FRESH plan (never a replay of the
            # pre-crash targets)
            self._saw_down = False
            self.restores += 1
            self._last_plan = None
        if alive_before:
            if self.plane is not None:
                m = self.plane.step(arrival_rate)
            else:
                m = self.backend.tick(arrival_rate)
            # a crash landing inside THIS tick suppresses the grant too
            # (the plane that would sign it is already gone)
            if self.backend.plane_alive and (
                    self._last_plan is None
                    or self.backend.t - self._last_plan
                    >= self.plan_interval):
                self._plan_now()
        else:
            # plane dark: tick the data plane directly — no observation,
            # no balancing, no lease changes. Router weights ride the
            # confidence-decay/capacity fallback inside the backend.
            m = self.backend.tick(arrival_rate)
            self.outage_steps += 1
        if not self.backend.plane_alive:
            self._saw_down = True
        for ctl in self.controllers:
            ctl.step()
        return m

    # ------------------------------------------------------------- report
    def local_actions(self) -> int:
        return sum(ctl.actions for ctl in self.controllers)

    def summary(self) -> dict:
        return {
            "plans": len(self.plan_log),
            "local_actions": self.local_actions(),
            "local_up_actions": sum(c.up_actions for c in self.controllers),
            "outage_steps": int(self.outage_steps),
            "restores": int(self.restores),
            "leases": [lease.astuple() if lease is not None else None
                       for lease in self.leases],
        }
