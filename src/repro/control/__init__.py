"""Unified control plane: one forecast -> balance -> scale loop that drives
any ``ClusterBackend`` — the fluid ``ClusterSim`` and the request-level
``ElasticClusterFrontend`` alike."""
from repro.control.backend import ClusterBackend, SimBackend  # noqa: F401
from repro.control.cells import (  # noqa: F401
    CellRouter, MetricsView, MultiCellBackend,
)
from repro.control.hierarchy import (  # noqa: F401
    CellController, CellLease, GlobalPlanner, PlaneSupervisor,
)
from repro.control.plane import (  # noqa: F401
    METHOD_SPECS, ControlPlane, make_autoscaler,
)
