"""``ControlPlane``: the paper's forecast -> balance -> scale loop, extracted
from the per-tick code previously duplicated across ``sim/experiment.py`` and
``examples/autoscale_sim.py``, and generalized over any ``ClusterBackend``.

Per tick (Eq.1-11):

    1. GRU demand forecast R̂_{t+1:t+T} over a rolling arrivals window
       (last-value persistence when no trained forecaster is given),
    2. balancer action a_t (MADRL GCN+DDPG, or the RRA/LCA/WRR baselines),
    3. backend advances one dt under a_t,
    4. RL reward/replay (optional training),
    5. autoscaling: GPSO replans every ``scale_interval`` ticks with
       volatility-aware headroom + an instantaneous-overload emergency path;
       the HPA/RBAS rule baselines observe every tick.

The same plane instance drives the fluid simulator (training, figures) and
the request-level elastic engine (``repro.launch.serve``) unchanged.

With the elastic backend's (default) overlapped async tick, step 3 returns
after ONE blocking host sync: the forecast -> balance -> scale work of this
loop runs while the accelerator computes the tick's decode, so a faster
control cadence comes for free (``metrics()['sync_wait_s']`` is the only
blocked time). The metrics the plane observes then describe the device
state as of one tick earlier — scaling rules tolerate that lag by design
(production autoscalers poll far staler signals); the eager backend mode
(``async_tick=False``) restores synchronous observation when exact
sim-parity of the control trajectory matters.

**Two-level hierarchy and crash tolerance** (PR 10, see
``control/hierarchy.py``): under ``PlaneSupervisor`` this plane is the
GLOBAL half of a two-level loop — it forecasts and balances, while
scaling authority is delegated as per-cell capacity leases
(``[min, max]`` total-replica bounds, enforced by the cell backends'
``set_lease``) that a ``GlobalPlanner`` re-grants every
``plan_interval`` ticks and per-cell ``CellController``s act inside at
full tick rate. The plane is crash-tolerant through
``state_dict``/``load_state_dict``: the checkpoint carries every piece
of mutable decision state (forecast window, residual tracker, learned
fractions, tick counter, scaler internals), so a restarted process that
loads it continues the exact decision stream. During an outage
(``plane_down@t`` chaos) ``step`` must not run — the supervisor ticks
the backend directly, cells keep scaling inside their LAST lease, and
the router rides the confidence-decayed capacity fallback; on
``plane_up`` the supervisor restores the checkpoint and re-plans leases
from live cell state instead of replaying pre-crash targets (no
double-applied scale actions).
"""
from __future__ import annotations

import copy
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import balancer as bal
from repro.core.autoscaler import (GPSOAutoscaler, HPAAutoscaler,
                                   RBASAutoscaler, StaticAllocator)
from repro.core.forecaster import forecast as nn_forecast
from repro.core.forecaster import last_value_baseline

# (balancer, autoscaler) pairs for the paper's §4.2 comparison matrix.
METHOD_SPECS = {
    "RRA": ("rr", "static"),
    "LCA": ("lc", "static"),
    "HPA": ("rr", "hpa"),
    "RBAS": ("rr", "rbas"),
    "OURS": ("rl", "gpso"),
    # extra references beyond the paper's table + ablations
    "WRR": ("wrr", "static"),
    "OURS-GA": ("rl", "ga"),     # GA-only autoscaler (no PSO refinement)
    "OURS-RR": ("rr", "gpso"),   # GPSO scaling but round-robin balancing
}

_jit_forecast = jax.jit(nn_forecast)


def make_autoscaler(kind: str, cfg, unit_cap: float, seed=0):
    if kind == "gpso":
        return GPSOAutoscaler(cfg, unit_cap, seed)
    if kind == "ga":
        return GPSOAutoscaler(cfg, unit_cap, seed, optimizer="ga")
    if kind == "hpa":
        return HPAAutoscaler(cfg)
    if kind == "rbas":
        return RBASAutoscaler(cfg)
    if kind == "static":
        return StaticAllocator(max(1, cfg.max_replicas_per_node // 2))
    if kind == "none":
        return None
    raise ValueError(kind)


class ControlPlane:
    """Composes forecaster + balancer + autoscaler over a ClusterBackend."""

    def __init__(self, cfg, backend, *, balancer: str = "rr",
                 scaler: str = "static", unit_capacity: float = 1.0,
                 rl: Optional[bal.RLBalancer] = None,
                 forecaster_params=None, forecast_scale: float = 1.0,
                 train_rl: bool = False, explore: bool = False,
                 train_every: int = 2, seed: int = 0,
                 init_arrival: float = 1.0):
        if balancer == "rl" and rl is None:
            raise ValueError("balancer='rl' needs an RLBalancer instance")
        self.cfg = cfg
        self.backend = backend
        self.balancer = balancer
        self.rl = rl
        self.forecaster_params = forecaster_params
        self.forecast_scale = float(forecast_scale)
        self.train_rl = train_rl
        self.explore = explore
        self.train_every = train_every
        self.unit_capacity = unit_capacity
        self.scaler_kind = scaler
        self.scaler = make_autoscaler(scaler, cfg, unit_capacity, seed)
        n = backend.num_nodes
        self.t = 0
        self.window = np.full((cfg.forecast_window,), float(init_arrival),
                              np.float32)
        self.fractions = np.full((n,), 1.0 / n, np.float32)
        self._prev = None            # (obs, action, reward) for RL replay
        self._resid = np.zeros(64, np.float32)   # rolling forecast residuals
        self._prev_fc1 = None

    # -------------------------------------------------- checkpoint/restore
    def state_dict(self) -> dict:
        """Deep-copied snapshot of every piece of mutable decision state —
        loading it into a FRESH plane over the same backend continues the
        exact decision stream (asserted in ``tests/test_hierarchy.py``).
        The RL replay tuple is transient (one tick of context) and resets
        on restore; the rl balancer itself is externally owned."""
        return {
            "t": int(self.t),
            "window": self.window.copy(),
            "fractions": self.fractions.copy(),
            "resid": self._resid.copy(),
            "prev_fc1": self._prev_fc1,
            "scaler": copy.deepcopy(self.scaler),
        }

    def load_state_dict(self, state: dict) -> None:
        self.t = int(state["t"])
        self.window = state["window"].copy()
        self.fractions = state["fractions"].copy()
        self._resid = state["resid"].copy()
        self._prev_fc1 = state["prev_fc1"]
        self.scaler = copy.deepcopy(state["scaler"])
        self._prev = None

    # ------------------------------------------------------------ forecast
    def _forecast(self, arrival_rate: float) -> np.ndarray:
        if self.forecaster_params is not None:
            fc = np.asarray(_jit_forecast(
                self.forecaster_params,
                jnp.asarray(self.window[:, None] / self.forecast_scale)))[:, 0]
        else:
            fc = np.asarray(last_value_baseline(
                jnp.asarray(self.window[:, None] / self.forecast_scale),
                self.cfg.horizon))[:, 0]
        fc = fc.astype(np.float32)
        # rolling 1-step forecast-error tracker -> volatility-aware headroom
        if self._prev_fc1 is not None:
            self._resid = np.roll(self._resid, -1)
            self._resid[-1] = (arrival_rate / self.forecast_scale
                               - self._prev_fc1)
        self._prev_fc1 = float(fc[0])
        return fc

    # ------------------------------------------------------------- balance
    def _balance(self, obs, up, arrival_rate: float) -> np.ndarray:
        b = self.backend
        if self.balancer == "rr":
            fr = bal.round_robin(jnp.asarray(obs), jnp.asarray(up))
        elif self.balancer == "lc":
            fr = bal.least_connections(
                jnp.asarray(b.queue_depths()), jnp.asarray(up),
                jnp.float32(arrival_rate * self.cfg.tick_seconds))
        elif self.balancer == "wrr":
            fr = bal.weighted_capacity(jnp.asarray(obs), jnp.asarray(up),
                                       jnp.asarray(b.capacity()))
        elif self.balancer == "rl":
            fr = self.rl.act(jnp.asarray(obs), jnp.asarray(up),
                             explore=self.explore)
        else:
            raise ValueError(self.balancer)
        return np.asarray(fr)

    # --------------------------------------------------------------- scale
    def _scale(self, m: dict, fc: np.ndarray, arrival_rate: float):
        cfg = self.cfg
        in_flight = self.backend.in_flight()
        if self.scaler_kind in ("gpso", "ga"):
            # measured service rates: once the backend's finished-request EMA
            # is warm (``service_rate`` per live replica), the planner uses
            # it instead of the static unit_capacity guess — closing the loop
            # on replica throughput. Backends that don't measure (the fluid
            # sim) simply never emit the key and keep the constant.
            measured = m.get("service_rate")
            if measured:
                self.scaler.unit_capacity = float(measured)
            if self.t % cfg.scale_interval == 0 and self.t > 0:
                # provision for the P95 of predicted demand: forecast peak
                # plus 2 sigma of recent forecast error, so calm periods run
                # lean and bursty ones hold reserve.
                n = self.backend.num_nodes
                sigma = float(self._resid.std()) * self.forecast_scale
                peak = max(float(fc.max()) * self.forecast_scale,
                           float(arrival_rate)) + 2.0 * sigma
                node_demand = peak * np.maximum(self.fractions,
                                                1.0 / (4 * n))
                # tiered backends report a weighted per-node backlog; the
                # plan then optimizes Eq.9 + the SLO-violation cost term.
                # chaos-aware backends report per-node preemption risk; any
                # nonzero risk adds the Eq.9 spot-churn cost term
                target = self.scaler.plan(node_demand, self.t, in_flight,
                                          node_speed=self.backend.node_speed,
                                          slo_pressure=m.get("tier_pressure"),
                                          preempt_risk=m.get("preempt_risk"))
                self.backend.scale_to(target)
            else:
                # emergency path: instantaneous overload on a node triggers
                # an immediate scale-up without waiting for the plan interval
                hot = m["utilization"] > 0.95
                if hot.any():
                    target = in_flight + hot.astype(np.int32)
                    self.backend.scale_to(
                        np.minimum(target, cfg.max_replicas_per_node))
        elif self.scaler is not None and self.scaler_kind != "static":
            # rule-based scalers observe every tick (the k8s control loop)
            target = self.scaler.plan(m["utilization"], self.t, in_flight)
            self.backend.scale_to(target)
        # "static"/"none": the backend keeps its initial replica profile

    # ---------------------------------------------------------------- tick
    def step(self, arrival_rate: float) -> dict:
        """One forecast -> balance -> advance -> (learn) -> scale tick."""
        cfg = self.cfg
        fc = self._forecast(arrival_rate)
        obs = self.backend.observe(fc)
        up = self.backend.up_mask()
        self.fractions = self._balance(obs, up, arrival_rate)
        self.backend.route(self.fractions)
        m = self.backend.tick(arrival_rate)

        if self.balancer == "rl":
            # Eq.5, tier-weighted: backends serving tiered traffic report a
            # weighted SLO violation level; untiered backends omit the key
            # and the reward reduces to the original shape.
            reward = bal.reward_fn(m["response_time"], m["mean_utilization"],
                                   cfg.alpha, cfg.beta, m["overload"],
                                   slo_cost=cfg.slo_gamma *
                                   float(m.get("tier_slo_cost") or 0.0))
            if self._prev is not None and self.train_rl:
                self.rl.observe(self._prev[0], self._prev[1],
                                float(self._prev[2]), obs, up)
                if self.t % self.train_every == 0:
                    self.rl.train_step()
            self._prev = (obs, self.fractions, reward)

        self._scale(m, fc, arrival_rate)

        self.window = np.roll(self.window, -1)
        self.window[-1] = arrival_rate
        self.t += 1
        return m

    def run(self, arrivals: np.ndarray) -> list:
        """Drive a whole trace; returns the per-tick metrics dicts."""
        return [self.step(float(a)) for a in arrivals]
