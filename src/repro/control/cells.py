"""Multi-cell fault-tolerant routing plane: ``CellRouter`` + ``MultiCellBackend``.

The paper's decentralization claim ("decentralised decision-making ...
enhances fault tolerance") needs a plane that is not a single synchronous
brain over one cluster. This module treats N existing backends — fluid
``ClusterSim`` or request-level ``ElasticClusterFrontend``, mixed — as
*cells* behind one federated ``ClusterBackend``: the unchanged
``ControlPlane`` drives the federation exactly like a single cluster
(``num_nodes`` = number of cells, ``scale_to`` targets are per-cell replica
totals), while the router handles the intra-federation placement of every
request. Three failure classes are survived end-to-end:

  * **cell blackout** (``cell_down@t:cC`` / ``cell_up@t:cC`` in
    ``ChaosSchedule``): the dead cell's entire queue + in-flight work is
    evacuated through the PR 7 ledger path (``blackout()`` on the cell) and
    re-routed to siblings in arrival order. Exactly-once accounting is
    lifted to ONE global ``RequestLedger`` shared by every elastic cell,
    so ``double_served == 0`` holds *across* cells: a request that dies in
    cell A and finishes in cell B is still a single rid with a single
    terminal state.
  * **control-plane partition** (``partition@t:cC[:kK]`` / ``heal@t:cC``):
    a cell keeps serving but its metrics feed goes dark. The router keeps a
    per-cell ``MetricsView`` with a staleness clock; a stale cell's learned
    routing fraction is replaced by a reactive weighted-capacity estimate
    (last-known capacity) whose confidence decays geometrically with
    staleness, and a cell whose view exceeds ``max_staleness`` is
    hard-quarantined (no traffic, ``up_mask`` 0) until the feed heals —
    the decentralized-fallback design of ``core/decentralized.py``: keep
    making *safe* local decisions when consensus signals are missing.
  * **total overload**: when EVERY healthy cell's tier-weighted pressure
    per unit capacity exceeds ``shed_threshold``, the router degrades
    gracefully — admission-sheds the lowest-priority tiers first (never
    the top tier), each shed request landing in the explicit ``shed``
    ledger terminal (retryable, never silent loss). Queues stay bounded
    instead of the PR 7 flash-crowd collapse.
  * **global-plane outage** (``plane_down@t[:kK]`` / ``plane_up@t``, PR
    10): the whole global control plane — planner, balancer, metrics
    pipeline — goes dark at once. EVERY cell's feed ages together
    (``plane_staleness`` counts the dark ticks) and the router rides the
    same confidence-decayed capacity-weight fallback as a partition, but
    plane-caused staleness never *quarantines* a cell: quarantine exists
    to protect against one dark cell among fresh siblings, and when all
    views age in lockstep the safe local decision is capacity-weighted
    routing, not parking the federation. Cells keep serving AND — under
    the two-level hierarchy (``control/hierarchy.py``) — keep autoscaling
    inside their last granted capacity lease; the global planner's
    actions are suppressed until ``plane_up``, when the restarted plane
    reconciles from its checkpoint against live cell state
    (``PlaneSupervisor.restore``) without double-applying scale actions.

**Lease contract** (PR 10): a capacity lease is a per-cell
``[min_replicas, max_replicas]`` bound on the cell's TOTAL in-flight
replica count, granted by the hierarchy's ``GlobalPlanner`` and enforced
by the cell backends themselves (``set_lease`` on
``ElasticClusterFrontend`` / ``ClusterSim`` clamps every ``scale_to``) —
so both the local ``CellController`` and a restored global plane
replaying a stale plan are bounded by the same authority. During an
outage the LAST granted lease stays in force: local reactive scaling
continues inside it at full tick rate (the paper's decentralization
claim), and nothing can exceed the budget the dead planner granted.

Routing is additionally biased away from *doomed* cells before a blackout
lands: per-node ``preempt_risk`` aggregates to a per-cell risk score and
multiplies the cell's weight by ``(1 - risk_bias * risk)``.

Single-cell parity: with one healthy cell the router forwards every
request in submit order, overrides nothing the cell would not compute
itself, and issues zero extra device work — syncs and decode dispatches
per tick are identical to driving the frontend directly (asserted in
``tests/test_cells.py``).

Clients (``workload.clients.ClientPool``) submit to the *router*, not a
cell: ``MultiCellBackend`` exposes the same frontend facade
(``alloc_rid`` / ``submit`` / ``abandon`` / ``ledger`` / ``t`` /
``run_until_drained``) so the pool is reused unchanged.
"""
from __future__ import annotations

from collections import deque
from typing import Optional

import numpy as np

from repro.serving.elastic import (ChaosSchedule, RequestLedger,
                                   _requeue_merged)
from repro.serving.engine import Request, normalize_fractions
from repro.workload.trace import DEFAULT_TIERS, TierSet

_INDEFINITE = -1          # partition with no :k — lasts until heal@t:cC


class MetricsView:
    """Last-known view of one cell: derived scalars (``snap``) + the full
    metrics dict of the last *observed* tick, plus the staleness clock the
    router's confidence decay and quarantine rule run on. ``staleness`` is
    the number of ticks since the feed last delivered (0 = fresh)."""

    def __init__(self, snap: dict, metrics: dict):
        self.snap = snap
        self.metrics = metrics
        self.staleness = 0

    def update(self, snap: dict, metrics: dict) -> None:
        self.snap = snap
        self.metrics = metrics
        self.staleness = 0

    def age(self) -> None:
        """The feed did not deliver this tick (partition or blackout)."""
        self.staleness += 1

    def quarantined(self, max_staleness: int) -> bool:
        return self.staleness > max_staleness


class CellRouter:
    """Pure routing policy over per-cell views (no cluster state of its
    own — everything it knows arrives as ``MetricsView``s + the alive
    mask, so it degrades exactly as its information degrades).

    ``weights``: start from the control plane's learned per-cell fractions;
    for any stale cell, fall back to a reactive weighted-capacity share
    (last-known capacity over the healthy total) times a confidence factor
    ``confidence_decay ** staleness``; zero out dead and quarantined cells;
    bias every cell by ``1 - risk_bias * cell_risk``; renormalize. An
    all-dead federation yields all-zero weights (uniform-over-none) — the
    backend parks arrivals instead of routing them.

    ``shed_tiers``: tier names to admission-shed this tick. Sheds only
    when EVERY healthy cell's tier-weighted pressure per unit capacity
    exceeds ``shed_threshold`` (if one cell has room, route there instead),
    escalating one priority tier per threshold multiple, lowest first —
    the top tier is never shed (single-tier federations never shed)."""

    def __init__(self, n_cells: int, *, tiers: Optional[TierSet] = None,
                 max_staleness: int = 4, confidence_decay: float = 0.6,
                 risk_bias: float = 0.8,
                 shed_threshold: Optional[float] = None,
                 adaptive: bool = True):
        self.n_cells = int(n_cells)
        self.tiers = tiers or DEFAULT_TIERS
        self.max_staleness = int(max_staleness)
        self.confidence_decay = float(confidence_decay)
        self.risk_bias = float(risk_bias)
        self.shed_threshold = shed_threshold
        self.adaptive = adaptive      # False = static split (the A/B arm)

    def healthy(self, views: list, alive: np.ndarray,
                plane_staleness: int = 0) -> np.ndarray:
        """Alive and not quarantined. ``plane_staleness`` is subtracted
        from each view's clock before the quarantine check: staleness the
        whole federation shares (global plane down) is not evidence that
        ONE cell is dark — quarantining everything would park all traffic
        during an outage the cells themselves are healthy through."""
        return np.asarray(
            [bool(alive[c]) and max(
                views[c].staleness - int(plane_staleness), 0)
                <= self.max_staleness
             for c in range(len(views))], bool)

    def weights(self, fractions: np.ndarray, views: list,
                alive: np.ndarray, plane_staleness: int = 0) -> np.ndarray:
        c_n = len(views)
        if not self.adaptive:
            # routing disabled: a fixed uniform split that ignores health,
            # staleness and risk — the ablation baseline the bench A/Bs
            return np.full(c_n, 1.0 / c_n, np.float64)
        healthy = self.healthy(views, alive, plane_staleness)
        cap = np.asarray([max(v.snap.get("capacity", 0.0), 0.0)
                          for v in views], np.float64)
        total_cap = max(cap[healthy].sum(), 1e-9) if healthy.any() else 1e-9
        w = np.asarray(fractions, np.float64).copy() \
            if fractions is not None and len(fractions) == c_n \
            else np.full(c_n, 1.0 / c_n, np.float64)
        for c, v in enumerate(views):
            if v.staleness > 0:
                # stale view: the learned fraction was computed from data
                # this old too — replace with the reactive rule, confidence-
                # decayed so fresher siblings absorb the difference
                conf = self.confidence_decay ** v.staleness
                w[c] = (cap[c] / total_cap) * conf
        risk = np.asarray([np.clip(v.snap.get("risk", 0.0), 0.0, 1.0)
                           for v in views], np.float64)
        w = w * np.clip(1.0 - self.risk_bias * risk, 0.0, 1.0)
        return normalize_fractions(w, mask=healthy.astype(np.float64))

    def shed_tiers(self, views: list, alive: np.ndarray,
                   plane_staleness: int = 0) -> frozenset:
        if self.shed_threshold is None or len(self.tiers) <= 1 \
                or not self.adaptive:
            return frozenset()
        healthy = self.healthy(views, alive, plane_staleness)
        if not healthy.any():
            return frozenset()        # full blackout: park, don't shed
        ppc = [views[c].snap.get("pressure", 0.0)
               / max(views[c].snap.get("capacity", 0.0), 1e-9)
               for c in range(len(views)) if healthy[c]]
        x = min(ppc)
        if x <= self.shed_threshold:
            return frozenset()
        level = min(int(x / self.shed_threshold), len(self.tiers) - 1)
        order = self.tiers.priority   # high priority first
        return frozenset(self.tiers.names[i] for i in order[-level:])


class MultiCellBackend:
    """A federation of cells behind the single-cluster ``ClusterBackend``
    protocol (``num_nodes`` = number of cells) plus the frontend facade
    closed-loop clients need. See module docstring for the failure model.

    ``cells`` mixes ``ElasticClusterFrontend`` (request-level) and
    ``ClusterSim`` (fluid) instances. Elastic cells share ONE global
    ``RequestLedger`` (theirs is replaced) and always tick with zero
    open-loop arrival rate — the router owns rid allocation and arrival
    generation, so per-cell counters can never collide in the shared
    ledger. Fluid cells receive their routed share of the arrival-rate
    mass. Intra-cell placement is reactive weighted-capacity over the
    cell's own (locally fresh) node state — the decentralized half of the
    design: a partition starves the *global* view, never the local one."""

    def __init__(self, cells: list, *, tiers: Optional[TierSet] = None,
                 router: Optional[CellRouter] = None,
                 chaos: Optional[ChaosSchedule] = None,
                 request_factory=None, tick_seconds: float = 1.0,
                 max_queue: Optional[int] = None, seed: int = 0,
                 ledger: Optional[RequestLedger] = None):
        if not cells:
            raise ValueError("MultiCellBackend needs at least one cell")
        self.cells = list(cells)
        self.n_cells = len(self.cells)
        self.num_nodes = self.n_cells          # the plane sees cells as nodes
        self.tiers = tiers or DEFAULT_TIERS
        self.router = router or CellRouter(self.n_cells, tiers=self.tiers)
        self.chaos = chaos
        self.request_factory = request_factory
        self.tick_seconds = float(tick_seconds)
        self.max_queue = max_queue
        self.rng = np.random.default_rng(seed)
        self.ledger = RequestLedger() if ledger is None else ledger
        self._elastic = [self._is_elastic(c) for c in self.cells]
        for cell, el in zip(self.cells, self._elastic):
            if el:
                cell.ledger = self.ledger      # ONE ledger across the fleet
        self.t = 0
        self._req_id = 0
        self._acc = 0.0
        self.pending: deque = deque()          # global routable pool
        self.culled: list = []                 # expired before any cell
        self._alive = np.ones(self.n_cells, bool)
        self._partition = np.zeros(self.n_cells, np.int64)  # ticks left
        self._fractions = np.full(self.n_cells, 1.0 / self.n_cells,
                                  np.float64)
        self._weights = self._fractions.copy()
        self._shed_now: frozenset = frozenset()
        self.shed_total = 0
        self._shed_reported = 0
        self._culled_reported = 0
        self.evacuated_total = 0
        self.cell_downs = 0
        self.quarantine_ticks = 0
        # global-plane liveness (PR 10): 0 = up, >0 = ticks of outage left,
        # _INDEFINITE = down until an explicit plane_up. While down, every
        # view ages together and plane_staleness counts the dark ticks.
        self._plane_left = 0
        self._plane_stale = 0
        self.plane_outages = 0
        self.plane_outage_ticks = 0
        # hierarchy bookkeeping: CellControllers report their scale actions
        # here (note_local_action) so the federation metrics expose them
        self._local_actions_acc = 0
        self.local_actions_total = 0
        self._fluid_backlog = 0.0              # evacuated fluid work mass
        self._live_m: list = [{} for _ in self.cells]
        self.views = [MetricsView(*self._snapshot(c))
                      for c in range(self.n_cells)]
        self._m: dict = {}

    # ------------------------------------------------------------- plumbing
    @staticmethod
    def _is_elastic(cell) -> bool:
        return hasattr(cell, "submit") and hasattr(cell, "nodes")

    def _snapshot(self, c: int) -> tuple:
        """Fresh derived scalars + metrics dict for cell ``c`` (what the
        feed would deliver this tick). Only called when the feed is up."""
        cell = self.cells[c]
        m = self._live_m[c]
        if self._elastic[c]:
            q = float(cell.queue_depths().sum())
            cap = float(cell.request_capacity().sum())
            tiered = len(cell.tiers) > 1
            press = float(cell.tiers.pressure(cell.tier_depths()).sum()) \
                if tiered else q
            snap = {
                "queue": q, "capacity": cap, "pressure": press,
                "risk": float(cell.preempt_risk().mean()),
                "in_flight": int(cell.in_flight().sum()),
                "active": int(sum(len(n.live) for n in cell.nodes)),
                "speed": float(np.mean(cell.node_speed)),
                "util": float(m.get("mean_utilization", 0.0)),
            }
        else:
            s = cell.state
            q = float(s.queue.sum())
            cap = float(cell.capacity().sum()) * self.tick_seconds
            press = float(cell.tiers.pressure(cell.tier_queue).sum()) \
                if cell.tier_queue is not None else q
            snap = {
                "queue": q, "capacity": cap, "pressure": press,
                "risk": float(cell.preempt_risk().mean()),
                "in_flight": int((s.active + s.pending.sum(axis=1)).sum()),
                "active": int(s.active.sum()),
                "speed": float(np.mean(cell.node_speed)),
                "util": float(m.get("mean_utilization", 0.0)),
            }
        return snap, m

    def _elastic_cells(self):
        return [c for c in range(self.n_cells) if self._elastic[c]]

    def _outstanding(self) -> int:
        out = len(self.pending)
        for c in self._elastic_cells():
            out += self.cells[c]._outstanding()
        return out

    # ----------------------------------------------------- frontend facade
    def alloc_rid(self) -> int:
        rid = self._req_id
        self._req_id += 1
        return rid

    def submit(self, req: Request) -> bool:
        """Router-level submit (clients talk to the federation, not a
        cell). Duplicate suppression and admission shedding both happen
        HERE — a request never reaches a cell unless it is the rid's only
        live attempt and its tier is currently admitted."""
        if not any(self._elastic):
            raise RuntimeError(
                "submit() needs at least one request-level (elastic) cell")
        if req.arrival == 0.0:
            req.arrival = float(self.t)
        if not self.ledger.register(req):
            return False
        if self.max_queue is not None \
                and self._outstanding() >= self.max_queue:
            self.ledger.reject(req)
            return False
        if req.tier in self._shed_now:
            self.ledger.shed(req)
            self.shed_total += 1
            return False
        self.pending.append(req)
        return True

    def abandon(self, rid: int) -> bool:
        return self.ledger.abandon(rid)

    @property
    def finished(self) -> list:
        """All completions across the federation + router-level culls."""
        out = list(self.culled)
        for c in self._elastic_cells():
            out.extend(self.cells[c].finished)
        return out

    # fleet-stat aggregation over the elastic cells, so drivers report a
    # federation exactly like a single frontend (``launch.serve``)
    def _sum_attr(self, name: str) -> int:
        return sum(getattr(self.cells[c], name)
                   for c in self._elastic_cells())

    def _sum_call(self, name: str):
        return sum(getattr(self.cells[c], name)()
                   for c in self._elastic_cells())

    @property
    def replicas_spawned(self) -> int:
        return self._sum_attr("replicas_spawned")

    @property
    def failed_replicas(self) -> int:
        return self._sum_attr("failed_replicas")

    @property
    def replica_ticks(self) -> int:
        return self._sum_attr("replica_ticks")

    @property
    def preempted_nodes(self) -> int:
        return self._sum_attr("preempted_nodes")

    @property
    def preempted_replicas(self) -> int:
        return self._sum_attr("preempted_replicas")

    def decode_dispatches(self) -> int:
        return self._sum_call("decode_dispatches")

    def prefill_dispatches(self) -> int:
        return self._sum_call("prefill_dispatches")

    def sync_count(self) -> int:
        return self._sum_call("sync_count")

    def sync_wait_s(self) -> float:
        return float(self._sum_call("sync_wait_s"))

    def prefill_retraces(self) -> int:
        return self._sum_call("prefill_retraces")

    # -------------------------------------------------------- cell lifecycle
    def _check_cell(self, c: int):
        if not isinstance(c, (int, np.integer)) \
                or not 0 <= c < self.n_cells:
            raise ValueError(
                f"cell index {c!r} out of range for {self.n_cells} cells")

    def cell_down(self, c: int) -> None:
        """Blackout cell ``c``: evacuate everything it holds through the
        ledger-safe path and merge it back into the global pool in arrival
        order for re-routing (fluid cells return work *mass* instead)."""
        self._check_cell(c)
        if not self._alive[c]:
            raise ValueError(f"cell c{c} is already down")
        self._alive[c] = False
        self.cell_downs += 1
        if self._elastic[c]:
            evac = self.cells[c].blackout()
            self.evacuated_total += len(evac)
            _requeue_merged(self.pending, evac)
        else:
            self._fluid_backlog += self.cells[c].blackout()

    def cell_up(self, c: int) -> None:
        """Restore cell ``c`` (capacity returns through provisioning)."""
        self._check_cell(c)
        if self._alive[c]:
            raise ValueError(f"cell c{c} is not down")
        self.cells[c].restore()
        self._alive[c] = True

    # ------------------------------------------------------ plane lifecycle
    @property
    def plane_alive(self) -> bool:
        return self._plane_left == 0

    def plane_down(self, ticks: Optional[int] = None) -> None:
        """Crash the global control plane: from this tick until restore the
        metrics feed of EVERY cell goes dark together (views age,
        ``plane_staleness`` climbs) and any driver honoring the contract
        suppresses global planning/balancing/scaling. ``ticks`` bounds the
        outage (``plane_down@t:kK``); ``None`` lasts until ``plane_up``."""
        if self._plane_left != 0:
            raise ValueError("global plane is already down")
        self._plane_left = _INDEFINITE if ticks is None else int(ticks)
        if self._plane_left == 0:     # k0 is a no-op crash, not an error
            return
        self.plane_outages += 1

    def plane_up(self) -> None:
        """Restart the global plane: feeds refresh on the next tick and
        ``plane_staleness`` resets. The hierarchy's ``PlaneSupervisor``
        observes the transition and reconciles from its checkpoint."""
        if self._plane_left == 0:
            raise ValueError("global plane is not down")
        self._plane_left = 0

    def note_local_action(self, n: int = 1) -> None:
        """CellControllers report local scale actions for the federation's
        ``local_actions`` metric (and the cumulative total)."""
        self._local_actions_acc += int(n)
        self.local_actions_total += int(n)

    def _advance_chaos(self):
        if self.chaos is None:
            return
        for kind, c, arg in self.chaos.pop(self.t):
            if kind in ChaosSchedule.PLANE_KINDS:
                if kind == "plane_down":
                    self.plane_down(arg)
                else:
                    self.plane_up()
                continue
            if kind not in ChaosSchedule.CELL_KINDS:
                continue              # node-kind events belong to the cells
            self._check_cell(c)
            if kind == "cell_down":
                self.cell_down(c)
            elif kind == "cell_up":
                self.cell_up(c)
            elif kind == "partition":
                self._partition[c] = _INDEFINITE if arg is None else int(arg)
            else:                     # heal
                self._partition[c] = 0

    # ------------------------------------------------------------- arrivals
    def _generate_arrivals(self, arrival_rate: float, w: np.ndarray):
        """Open-loop arrivals: the elastic cells' combined routing share
        becomes discrete requests (router-owned rids); fluid cells consume
        their share as rate mass inside their own tick."""
        if self.request_factory is None or arrival_rate <= 0.0:
            return
        e_share = float(sum(w[c] for c in self._elastic_cells()))
        self._acc += arrival_rate * self.tick_seconds * e_share
        n = int(self._acc)
        self._acc -= n
        for _ in range(n):
            req = self.request_factory(self._req_id, self.t)
            self._req_id += 1
            req.arrival = float(self.t - 1)
            self.ledger.register(req)
            self.pending.append(req)

    def _distribute(self, w: np.ndarray, shed: frozenset):
        """Place the global pool: cull expired, shed overloaded tiers,
        route the rest to elastic cells ∝ weight. Zero total weight over
        elastic cells (full blackout) parks everything — the retry-pool
        semantics of satellite 1's all-false-mask rule."""
        eidx = self._elastic_cells()
        we = np.asarray([w[c] for c in eidx], np.float64)
        s = we.sum()
        routable = s > 1e-12
        if routable:
            we = we / s
        hold: deque = deque()
        while self.pending:
            req = self.pending.popleft()
            if req.out_of_time(self.t):
                req.finish_time = float(self.t)
                self.ledger.resolve(req)
                self.culled.append(req)
            elif req.tier in shed:
                self.ledger.shed(req)
                self.shed_total += 1
            elif not routable:
                hold.append(req)
            else:
                if len(eidx) == 1:
                    c = eidx[0]       # no rng draw: single-cell parity
                else:
                    c = eidx[int(self.rng.choice(len(eidx), p=we))]
                self.cells[c].pending.append(req)
        self.pending = hold

    # ------------------------------------------------- ClusterBackend API
    def up_mask(self) -> np.ndarray:
        return self.router.healthy(self.views, self._alive,
                                   self._plane_stale).astype(np.float32)

    def queue_depths(self) -> np.ndarray:
        return np.asarray([v.snap["queue"] for v in self.views], np.float32)

    def capacity(self) -> np.ndarray:
        return np.asarray([v.snap["capacity"] for v in self.views],
                          np.float32)

    def in_flight(self) -> np.ndarray:
        return np.asarray([v.snap["in_flight"] for v in self.views],
                          np.int32)

    @property
    def node_speed(self) -> np.ndarray:
        return np.asarray([v.snap["speed"] for v in self.views], np.float32)

    def preempt_risk(self) -> np.ndarray:
        """Per-cell aggregated risk (mean of the cell's per-node 0/1)."""
        return np.asarray([v.snap["risk"] for v in self.views], np.float32)

    def cell_staleness(self) -> np.ndarray:
        return np.asarray([v.staleness for v in self.views], np.float32)

    def observe(self, forecast: np.ndarray) -> np.ndarray:
        """Same Eq.1-3 feature layout as the single-cell backends, one row
        per CELL, built from the views — the plane honestly observes stale
        data for partitioned cells, never a side channel."""
        q = self.queue_depths()
        cap = self.capacity()
        load = q / max(q.sum(), 1.0)
        util_proxy = np.minimum(q / np.maximum(cap, 1e-9), 4.0) / 4.0
        capn = cap / max(cap.sum(), 1e-9)
        up = self.up_mask()
        f = np.broadcast_to(forecast[None, :],
                            (self.n_cells, forecast.shape[0]))
        obs = np.concatenate([load[:, None], util_proxy[:, None],
                              capn[:, None], up[:, None], f], axis=1)
        return obs.astype(np.float32)

    def route(self, fractions: np.ndarray) -> None:
        self._fractions = np.asarray(fractions, np.float64)

    def metrics(self) -> dict:
        return self._m

    def scale_to(self, target: np.ndarray) -> None:
        """Per-cell replica totals, split evenly across each cell's
        schedulable nodes (dead / doomed nodes and dead cells skipped).
        Cells under a capacity lease clamp their own total
        (``set_lease``)."""
        target = np.asarray(target)
        for c in range(self.n_cells):
            self.scale_cell(c, int(target[c]))

    def scale_cell(self, c: int, tgt: int) -> None:
        """Scale ONE cell to a total replica count (the hierarchy's
        ``CellController`` entry point: local actions touch only their own
        cell). Splits evenly across the cell's schedulable nodes; the
        cell's own lease clamp applies."""
        self._check_cell(c)
        if not self._alive[c]:
            return
        cell = self.cells[c]
        tgt = max(int(tgt), 0)
        if tgt == self.cell_in_flight(c):
            return                     # no total change: never reshuffle
        if self._elastic[c]:
            ok = [i for i, nd in enumerate(cell.nodes)
                  if not nd.down and nd.preempt_left < 0]
            if not ok:
                return
            per = np.zeros(cell.num_nodes, np.int32)
            base, rem = divmod(tgt, len(ok))
            for j, i in enumerate(ok):
                per[i] = base + (1 if j < rem else 0)
            cell.scale_to(per)
        else:
            s = cell.state
            ok = [i for i in range(cell.cfg.num_nodes)
                  if not cell._preempt_down[i] and s.notice_left[i] < 0]
            if not ok:
                return
            per = (s.active + s.pending.sum(axis=1)).copy()
            base, rem = divmod(tgt, len(ok))
            for j, i in enumerate(ok):
                per[i] = base + (1 if j < rem else 0)
            cell.scale_to(per)

    def cell_in_flight(self, c: int) -> int:
        """Live total in-flight replicas of ONE cell (local, never stale —
        what a CellController may legitimately observe at tick rate)."""
        self._check_cell(c)
        cell = self.cells[c]
        if self._elastic[c]:
            return int(cell.in_flight().sum())
        s = cell.state
        return int((s.active + s.pending.sum(axis=1)).sum())

    # ---------------------------------------------------------------- tick
    def tick(self, arrival_rate: float = 0.0) -> dict:
        self.t += 1
        self._advance_chaos()
        w = self.router.weights(self._fractions, self.views, self._alive,
                                self._plane_stale)
        self._weights = w
        self._shed_now = shed = self.router.shed_tiers(
            self.views, self._alive, self._plane_stale)
        self._generate_arrivals(arrival_rate, w)
        self._distribute(w, shed)
        # fluid share: routed rate mass + re-injected evacuated backlog
        fidx = [c for c in range(self.n_cells) if not self._elastic[c]]
        fluid_extra = np.zeros(self.n_cells, np.float64)
        if fidx and self._fluid_backlog > 0.0:
            wf = np.asarray([w[c] for c in fidx], np.float64)
            if wf.sum() > 1e-12:
                share = wf / wf.sum()
                for j, c in enumerate(fidx):
                    fluid_extra[c] = self._fluid_backlog * share[j] \
                        / max(self.tick_seconds, 1e-9)
                self._fluid_backlog = 0.0
        # a dark plane ages EVERY feed together (plane_staleness), on top
        # of any per-cell partition still running its own clock
        plane_dark = self._plane_left != 0
        if plane_dark:
            self._plane_stale += 1
            self.plane_outage_ticks += 1
            if self._plane_left > 0:
                self._plane_left -= 1
        else:
            self._plane_stale = 0
        for c, cell in enumerate(self.cells):
            if self._elastic[c]:
                # intra-cell routing: reactive weighted-capacity over the
                # cell's OWN (locally fresh) node state
                cell.route(normalize_fractions(cell.capacity(),
                                               mask=cell.up_mask()))
                self._live_m[c] = cell.tick(0.0)
            else:
                fr = normalize_fractions(cell.capacity(),
                                         mask=cell.state.up)
                rate = float(arrival_rate) * float(w[c]) + fluid_extra[c]
                self._live_m[c] = cell.tick(rate, fr)
            # feed update: partitioned cells age instead (their live
            # metrics exist — the plane just can't see them)
            if plane_dark or self._partition[c] != 0:
                self.views[c].age()
                if self._partition[c] > 0:
                    self._partition[c] -= 1
            else:
                self.views[c].update(*self._snapshot(c))
        healthy = self.router.healthy(self.views, self._alive,
                                      self._plane_stale)
        self.quarantine_ticks += int(
            sum(1 for c in range(self.n_cells)
                if self._alive[c] and not healthy[c]))
        self._m = self._aggregate(arrival_rate)
        return self._m

    # ------------------------------------------------------------- metrics
    def _lease_util(self) -> np.ndarray:
        util = np.zeros(self.n_cells, np.float32)
        for c, cell in enumerate(self.cells):
            lease = getattr(cell, "lease", None)
            if lease is not None and lease[1] > 0:
                util[c] = self.cell_in_flight(c) / float(lease[1])
        return util

    def _take_local_actions(self) -> int:
        n, self._local_actions_acc = self._local_actions_acc, 0
        return n

    def _aggregate(self, arrival_rate: float) -> dict:
        """Federation metrics. Plane-facing ARRAYS come from the views
        (honest staleness); scalar accounting counters (served / goodput /
        timed_out / shed, dispatch counters) sum the cells' live metrics —
        a partition degrades control, not the experiment's bookkeeping."""
        views = self.views
        live = self._live_m
        up = self.up_mask()
        util = np.asarray([v.snap["util"] for v in views], np.float32)
        served = float(sum(m.get("served", 0.0) for m in live))
        goodput = float(sum(m.get("goodput", 0.0) for m in live))
        timed_out = float(sum(m.get("timed_out", 0.0) for m in live))
        culled = len(self.culled) - self._culled_reported
        self._culled_reported = len(self.culled)
        timed_out += float(sum(1 for r in self.culled[-culled:]
                               if r.expired)) if culled else 0.0
        shed = float(self.shed_total - self._shed_reported)
        self._shed_reported = self.shed_total
        # response time: served-weighted over views (what the plane may see)
        resp_w = np.asarray([max(v.metrics.get("served", 0.0), 0.0)
                             for v in views], np.float64)
        resp_v = np.asarray([v.metrics.get("response_time", 0.0)
                             for v in views], np.float64)
        resp = float((resp_w * resp_v).sum() / resp_w.sum()) \
            if resp_w.sum() > 0 else float(resp_v.mean())
        overload = float(np.mean([v.metrics.get("overload", 0.0)
                                  for v in views]))
        m = {
            "utilization": util,
            "mean_utilization": float(np.mean(util[up > 0.5])
                                      if (up > 0.5).any() else 0.0),
            "response_time": resp,
            "served": served,
            "overload": overload,
            "capacity": self.capacity(),
            "queue": self.queue_depths(),
            "up": up,
            "active_replicas": np.asarray(
                [v.snap["active"] for v in views], np.int32),
            "replica_ticks": int(sum(m.get("replica_ticks", 0)
                                     for m in live)),
            "decode_dispatches": int(sum(m.get("decode_dispatches", 0)
                                         for m in live)),
            "prefill_dispatches": int(sum(m.get("prefill_dispatches", 0)
                                          for m in live)),
            "syncs": int(sum(m.get("syncs", 0) for m in live)),
            "sync_wait_s": float(sum(m.get("sync_wait_s", 0.0)
                                     for m in live)),
            "fleet_groups": int(sum(m.get("fleet_groups", 0)
                                    for m in live)),
            "goodput": goodput,
            "timed_out": timed_out,
            "preempt_risk": self.preempt_risk(),
            # the multi-cell degraded-mode view (zeros in single-cell
            # backends — see control/backend.py protocol docs)
            "cell_staleness": self.cell_staleness(),
            "cell_risk": self.preempt_risk(),
            "shed": shed,
            "shed_total": int(self.shed_total),
            "router_weights": self._weights.copy(),
            "router_pending": len(self.pending),
            "quarantined": np.asarray(
                [float(max(self.views[c].staleness - self._plane_stale, 0)
                       > self.router.max_staleness)
                 for c in range(self.n_cells)], np.float32),
            # hierarchical-control view (PR 10): plane-outage clock, lease
            # utilization (live in-flight over lease max, 0 when no lease)
            # and this tick's CellController scale actions — all zero in
            # centralized mode, so planner guards stay shape-stable
            "plane_staleness": float(self._plane_stale),
            "lease_util": self._lease_util(),
            "local_actions": float(self._take_local_actions()),
        }
        rates = [c.service_rate for e, c in zip(self._elastic, self.cells)
                 if e and c.service_rate]
        m["service_rate"] = float(np.mean(rates)) if rates else None
        if len(self.tiers) > 1:
            tq = np.zeros((len(self.tiers), self.n_cells), np.float32)
            for c, v in enumerate(views):
                cell_tq = v.metrics.get("tier_queue")
                if cell_tq is not None and len(cell_tq) == len(self.tiers):
                    tq[:, c] = np.asarray(cell_tq).sum(axis=1)
                else:
                    tq[self.tiers.priority[-1], c] = v.snap["queue"]
            costs = [m2.get("tier_slo_cost") for m2 in live
                     if m2.get("tier_slo_cost") is not None]
            tier_served: dict = {}
            for m2 in live:
                for k, n in (m2.get("tier_served") or {}).items():
                    tier_served[k] = tier_served.get(k, 0) + n
            m.update(tier_queue=tq, tier_pressure=self.tiers.pressure(tq),
                     tier_slo_cost=float(np.mean(costs)) if costs else 0.0,
                     tier_served=tier_served)
        return m

    # ------------------------------------------------------------ draining
    def run_until_drained(self, max_steps: int = 10_000):
        """Finish all outstanding work across the federation (chaos and
        partitions pause; blacked-out cells restore if parked work has
        nowhere else to go — the global twin of the frontend's drain-worker
        safety)."""
        chaos, self.chaos = self.chaos, None
        self._partition[:] = 0
        self._plane_left = 0          # a drain is a controlled wind-down:
        self._plane_stale = 0         # the plane outage ends with the run
        try:
            for _ in range(max_steps):
                if self._outstanding() == 0:
                    return
                eidx = self._elastic_cells()
                if self.pending and not any(self._alive[c] for c in eidx):
                    self.cell_up(eidx[0])     # parked work needs a home
                for c in eidx:
                    cell = self.cells[c]
                    if not self._alive[c] or cell._outstanding() == 0:
                        continue
                    if not any(n.live or n.spawning for n in cell.nodes):
                        host = next((n for n in cell.nodes if not n.down
                                     and n.preempt_left < 0), None)
                        if host is None:
                            host = cell.nodes[0]
                            host.down = False
                        cell._go_live(host)
                self.tick(0.0)
            raise RuntimeError("multi-cell federation did not drain")
        finally:
            self.chaos = chaos
