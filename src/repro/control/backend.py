"""The ``ClusterBackend`` protocol: what a cluster must expose for the
control plane to drive it, plus the adapter over the fluid ``ClusterSim``.

Two implementations exist:

  * ``SimBackend`` (here) — wraps ``repro.sim.cluster.ClusterSim``; cheap,
    used for RL training, baselines sweeps and the paper figures.
  * ``repro.serving.elastic.ElasticClusterFrontend`` — node groups of real
    ``ReplicaEngine`` model replicas with cold-start provisioning, graceful
    drain and failure injection; used by ``repro.launch.serve``.

The per-tick contract (what ``ControlPlane.step`` calls, in order):

    observe(forecast) -> (N, 4+T) features      # Eq.1-3 state
    route(fractions)                             # Eq.4 simplex allocation
    tick(arrival_rate) -> metrics dict           # advance one dt
    scale_to(target)                             # Eq.9 autoscaler plan

plus the read-only views balancers/autoscalers need: ``up_mask``,
``queue_depths``, ``capacity``, ``in_flight`` and ``node_speed``.

**Tiered metrics (optional).** A backend serving SLO-tiered traffic (see
``repro.workload.trace.TierSet``) additionally reports per-tier state in its
``tick()``/``metrics()`` dict — ``tier_queue`` (T, N) per-tier queue depths,
``tier_pressure`` (N,) tier-weighted backlog (consumed by the GPSO plan's
SLO-violation cost term) and the scalar ``tier_slo_cost`` in [0, 1] (the
tier-weighted violation level entering the Eq.5 reward); the elastic
backend also emits per-tier ``tier_ttft``/``tier_tbt``/``tier_served``.
Untiered backends simply omit the keys and the control plane falls back to
the original objective/reward — both implementations here emit the same key
set for the same tier configuration, which is what keeps policy rankings
consistent across the fluid and request-level backends.

**Robustness metrics (always on, PR 7).** Both backends' metrics dicts also
carry the failure-matrix signals:

  * ``goodput`` — scalar: completions this tick that beat their
    ``deadline_tick`` (requests without a deadline always count);
  * ``timed_out`` — scalar: completions this tick retired by deadline
    expiry (``Request.expired``) — truncated output, not goodput;
  * ``preempt_risk`` — (N,) float 0/1: nodes currently under a spot
    preemption notice (draining, will hard-drop). Consumed by the GPSO
    plan's preemption-risk cost term (``ClusterConfig.risk_lam``) so the
    planner shifts replicas off doomed nodes before the drop.

When chaos/deadlines are off these are identically zero and the planner's
``.any()`` guard keeps the base objective — untouched workloads see
bit-identical streams and plans. Exactly-once accounting (the
``RequestLedger``: every rid ends in exactly one of finished / timed-out /
abandoned / rejected / shed, never served twice) lives on the elastic
frontend as ``fe.ledger``; the fluid backend conserves work in aggregate
via its ``retry_pool`` instead.

**Multi-cell metrics (always on, PR 8).** A third implementation,
``repro.control.cells.MultiCellBackend``, federates N backends as *cells*
(``num_nodes`` = cell count) behind this same protocol. So that planner
guards stay shape-stable across all three, every backend's metrics dict
carries the degraded-mode keys:

  * ``cell_staleness`` — (C,) float: ticks since each cell's metrics feed
    last delivered (a control-plane partition ages it; past the router's
    ``max_staleness`` the cell is hard-quarantined);
  * ``cell_risk`` — (C,) float in [0, 1]: per-cell aggregate of the
    per-node ``preempt_risk`` — the router biases traffic away from
    doomed cells *before* a blackout lands;
  * ``shed`` — scalar: requests admission-shed this tick under total
    overload (lowest tiers first, each an explicit retryable ``shed``
    ledger terminal — bounded queues, never silent loss).

Single-cell backends (the two above) emit these as identical zeros —
``cell_staleness``/``cell_risk`` as ``np.zeros(1)``, ``shed`` as ``0.0``
— one frontend *is* one healthy, always-fresh cell; only the routing
plane produces nonzero values. The multi-cell backend additionally
reports ``shed_total``, ``router_weights`` (C,), ``router_pending``
(parked arrivals when no cell is routable) and ``quarantined`` (C,).

**Hierarchical-control metrics (always on, PR 10).** The two-level
control split (``repro.control.hierarchy``: per-cell ``CellController``
autoscalers inside ``GlobalPlanner`` capacity leases, a crash-tolerant
global plane under ``PlaneSupervisor``) adds three more always-on keys:

  * ``plane_staleness`` — scalar float: consecutive ticks the GLOBAL
    control plane has been dark (``plane_down@t`` chaos). While nonzero,
    every cell's feed ages together and the router falls back to
    confidence-decayed capacity weights — but plane-caused staleness
    never quarantines a cell (all views aging in lockstep is not
    evidence any one cell is dark);
  * ``lease_util`` — (C,) float: each cell's live in-flight replica
    count over its lease ``max_replicas`` (0 where no lease is set) —
    how much of the granted headroom the local controllers are using;
  * ``local_actions`` — scalar float: CellController scale actions taken
    since the previous tick's metrics (the decentralized half acting; in
    particular, nonzero DURING an outage is the paper's fault-tolerance
    claim made measurable).

Single-cell / centralized invocations emit identical zeros
(``plane_staleness``/``local_actions`` as ``0.0``, ``lease_util`` as
``np.zeros(1)``) — there is no plane above a lone frontend and no lease
unless the hierarchy granted one — keeping planner guards shape-stable
across every backend and control mode.
"""
from __future__ import annotations

from typing import Protocol, runtime_checkable

import numpy as np


@runtime_checkable
class ClusterBackend(Protocol):
    num_nodes: int

    # ------------------------------------------------------------ observe
    def observe(self, forecast: np.ndarray) -> np.ndarray:
        """Per-node features (N, 4+T): [load, util-proxy, cap, up] ++ fc."""
        ...

    def up_mask(self) -> np.ndarray:
        """(N,) 1.0 where the node can serve."""
        ...

    def queue_depths(self) -> np.ndarray:
        """(N,) outstanding work per node (request units)."""
        ...

    def capacity(self) -> np.ndarray:
        """(N,) service capacity per node (work units / tick)."""
        ...

    def in_flight(self) -> np.ndarray:
        """(N,) replicas active + provisioning (the autoscaler's view)."""
        ...

    @property
    def node_speed(self) -> np.ndarray:
        """(N,) relative hardware speed multipliers."""
        ...

    # -------------------------------------------------------------- drive
    def route(self, fractions: np.ndarray) -> None:
        """Set the balancer's simplex allocation for the next tick."""
        ...

    def tick(self, arrival_rate: float) -> dict:
        """Advance one tick under the routed fractions. Returns metrics."""
        ...

    def metrics(self) -> dict:
        """Metrics of the most recent tick."""
        ...

    def scale_to(self, target: np.ndarray) -> None:
        """Apply an autoscaler plan (per-node replica targets)."""
        ...


class SimBackend:
    """``ClusterBackend`` over the fluid simulator."""

    def __init__(self, sim):
        self.sim = sim
        self.num_nodes = sim.cfg.num_nodes
        self._fractions = np.full(self.num_nodes, 1.0 / self.num_nodes,
                                  np.float32)
        self._m: dict = {}

    @property
    def node_speed(self) -> np.ndarray:
        return self.sim.node_speed

    def observe(self, forecast: np.ndarray) -> np.ndarray:
        return self.sim.observation(forecast)

    def up_mask(self) -> np.ndarray:
        return self.sim.state.up.copy()

    def queue_depths(self) -> np.ndarray:
        return self.sim.state.queue.copy()

    def capacity(self) -> np.ndarray:
        return self.sim.capacity()

    def in_flight(self) -> np.ndarray:
        s = self.sim.state
        return s.active + s.pending.sum(axis=1)

    def route(self, fractions: np.ndarray) -> None:
        self._fractions = np.asarray(fractions, np.float32)

    def tick(self, arrival_rate: float) -> dict:
        self._m = self.sim.tick(arrival_rate, self._fractions)
        return self._m

    def metrics(self) -> dict:
        return self._m

    def scale_to(self, target: np.ndarray) -> None:
        self.sim.scale_to(target)
