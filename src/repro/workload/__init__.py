from repro.workload.trace import (  # noqa: F401
    LOAD_LEVELS, TraceConfig, generate_trace, make_forecast_dataset,
)
