from repro.workload.clients import ClientPool  # noqa: F401
from repro.workload.trace import (  # noqa: F401
    DEFAULT_TIERS, LOAD_LEVELS, TierSet, TierSpec, TraceConfig,
    generate_trace, make_forecast_dataset, parse_tiers,
)
