"""Closed-loop client population (the Locust idiom) for the elastic
frontend.

Open-loop traces (``workload.trace``) push an arrival *rate* regardless of
what the cluster does — fine for steady-state capacity planning, wrong for
overload: real clients wait for their answer (closed loop), time out, come
back with retries, and eventually give up. Under saturation that feedback
*amplifies* load (the retry storm) exactly when capacity is scarcest, which
is the regime where goodput — not raw tok/s — separates a robust autoscaler
from a fragile one.

``ClientPool`` models N users against one ``ElasticClusterFrontend``:

  * **think time** — after a success, a client waits ``Exp(think_time)``
    ticks before issuing its next request;
  * **timeout → retry** — each attempt carries ``deadline_tick = now +
    timeout`` (per-tier scalar or dict), so the *server* retires it inside
    the normal fleet retire rule; the client watches the frontend's
    ``RequestLedger`` and, on ``timed_out``/``rejected``, retries the SAME
    rid with a FRESH ``Request`` after capped exponential backoff with
    jitter, up to ``max_retries``;
  * **abandonment** — a client out of retry budget abandons the rid
    (``frontend.abandon``) and returns to thinking; a late completion for
    an abandoned rid is wasted work, not goodput;
  * **spawn-rate ramp** — ``spawn_rate`` activates users per tick (the
    flash-crowd shape: 1000 users arriving at 50/tick), default everyone
    at once.

Exactly-once accounting is the frontend's job (ledger suppression of a
retry racing its original completion); the pool's job is only to generate
the closed-loop pressure and tally the client-side view (per-tier issued /
ok / timed-out / retries / abandons and end-to-end response times of
successes). Drive it as ``pool.tick()`` immediately before each
``frontend.tick`` (or ``ControlPlane.step``); submissions land in
``pending`` and route on that same tick, exactly like open-loop arrivals.
"""
from __future__ import annotations

from typing import Callable, Optional, Union

import numpy as np

# NOTE: no ``repro.serving`` import here — ``serving.engine`` imports
# ``workload.trace``, so importing it back from the workload package would
# be circular. The pool only *consumes* ``Request`` objects produced by the
# caller's ``request_factory``.

_THINKING, _WAITING, _BACKOFF = 0, 1, 2


class _Client:
    __slots__ = ("state", "timer", "rid", "attempt", "sent_at", "tier")

    def __init__(self, timer: float):
        self.state = _THINKING
        self.timer = timer          # ticks left in thinking/backoff
        self.rid = -1               # rid of the in-flight / retried request
        self.attempt = 0            # attempts already issued for this rid
        self.sent_at = 0.0          # first-attempt issue tick (E2E latency)
        self.tier = "standard"


class ClientPool:
    """N closed-loop users driving a frontend (see module docstring)."""

    def __init__(self, frontend, num_clients: int, *,
                 request_factory: Callable[[int, int], Request],
                 think_time: float = 2.0,
                 timeout: Union[float, dict] = 8.0,
                 max_retries: int = 3,
                 backoff_base: float = 1.0, backoff_cap: float = 8.0,
                 spawn_rate: Optional[float] = None, seed: int = 0):
        self.fe = frontend
        self.request_factory = request_factory
        self.think_time = float(think_time)
        self.timeout = timeout
        self.max_retries = int(max_retries)
        self.backoff_base = float(backoff_base)
        self.backoff_cap = float(backoff_cap)
        self.spawn_rate = spawn_rate      # clients activated per tick
        self.rng = np.random.default_rng(seed)
        self._dormant = int(num_clients)  # not yet ramped in
        self._spawn_acc = 0.0
        self.clients: list = []
        self.quiesced = False             # stop issuing new work (wind-down)
        self.stats = self._zero_row()
        self.tier_stats: dict = {}
        self.latencies: list = []         # (tier, e2e ticks) of successes

    @staticmethod
    def _zero_row() -> dict:
        return {"issued": 0, "ok": 0, "timed_out": 0, "retries": 0,
                "abandoned": 0, "rejected": 0, "shed": 0}

    def _row(self, tier: str) -> dict:
        return self.tier_stats.setdefault(tier, self._zero_row())

    def _bump(self, tier: str, key: str, n: int = 1):
        self.stats[key] += n
        self._row(tier)[key] += n

    def _tier_timeout(self, tier: str) -> float:
        if isinstance(self.timeout, dict):
            return float(self.timeout.get(tier, self.timeout.get(
                "default", 8.0)))
        return float(self.timeout)

    def _think(self) -> float:
        return float(self.rng.exponential(self.think_time)) \
            if self.think_time > 0 else 0.0

    def _backoff(self, attempt: int) -> float:
        # capped exponential with full jitter: retries decorrelate instead
        # of re-synchronizing into a thundering herd
        cap = min(self.backoff_cap, self.backoff_base * (2.0 ** (attempt - 1)))
        return float(self.rng.uniform(0.0, max(cap, 1e-9)))

    @property
    def active_clients(self) -> int:
        return len(self.clients)

    @property
    def outstanding(self) -> int:
        return sum(1 for c in self.clients if c.state == _WAITING)

    def quiesce(self):
        """Stop issuing new requests (wind-down: in-flight attempts keep
        running and are harvested by later ``tick``s / ``finalize``)."""
        self.quiesced = True

    # ------------------------------------------------------------- ticking
    def _spawn_wave(self):
        if self._dormant <= 0:
            return
        if self.spawn_rate is None:
            n = self._dormant
        else:
            self._spawn_acc += float(self.spawn_rate)
            n = min(self._dormant, int(self._spawn_acc))
            self._spawn_acc -= n
        self._dormant -= n
        for _ in range(n):
            self.clients.append(_Client(self._think()))

    def _issue(self, c: _Client, now: int, retry: bool):
        if retry:
            c.attempt += 1
            self._bump(c.tier, "retries")
        else:
            c.rid = self.fe.alloc_rid()
            c.attempt = 1
            c.sent_at = float(now)
        # every attempt is a FRESH Request object (a served-on object must
        # never re-enter the queues) with a fresh deadline
        req = self.request_factory(c.rid, now)
        c.tier = req.tier
        req.deadline_tick = float(now) + self._tier_timeout(req.tier)
        self._bump(c.tier, "issued")
        accepted = self.fe.submit(req)
        if accepted:
            c.state = _WAITING
            return
        # admission said no — the cap ('rejected') or overload shedding
        # ('shed', multi-cell router): backoff-retry like a timeout,
        # abandon when out of budget
        st = self.fe.ledger.state.get(c.rid)
        self._bump(c.tier, "shed" if st == "shed" else "rejected")
        self._settle_failure(c)

    def _settle_failure(self, c: _Client):
        if c.attempt >= self.max_retries + 1 or self.quiesced:
            self.fe.abandon(c.rid)
            self._bump(c.tier, "abandoned")
            c.state = _THINKING
            c.timer = self._think()
        else:
            c.state = _BACKOFF
            c.timer = self._backoff(c.attempt)

    def tick(self):
        """One closed-loop round: harvest terminal rids from the ledger,
        ramp new users in, count down think/backoff timers and (re)issue
        requests. Call immediately before ``frontend.tick``."""
        now = int(self.fe.t)
        states = self.fe.ledger.state
        for c in self.clients:
            if c.state != _WAITING:
                continue
            st = states.get(c.rid)
            if st == "finished":
                self._bump(c.tier, "ok")
                self.latencies.append((c.tier, float(now) - c.sent_at))
                c.state = _THINKING
                c.timer = self._think()
            elif st in ("timed_out", "rejected", "shed"):
                if st == "timed_out":
                    self._bump(c.tier, "timed_out")
                elif st == "shed":
                    # queued at submit time, shed later by the router's
                    # admission sweep (pressure crossed the threshold)
                    self._bump(c.tier, "shed")
                self._settle_failure(c)
        self._spawn_wave()
        if self.quiesced:
            return
        for c in self.clients:
            if c.state == _WAITING:
                continue
            c.timer -= 1.0
            if c.timer > 0:
                continue
            self._issue(c, now, retry=(c.state == _BACKOFF))

    def finalize(self):
        """Post-drain harvest: classify whatever was still in flight when
        the driver stopped ticking (every attempt has completed by now —
        ``run_until_drained`` guarantees it)."""
        self.quiesce()
        states = self.fe.ledger.state
        for c in self.clients:
            if c.state == _BACKOFF:
                # a retry that will never be issued: abandon the rid so it
                # leaves its (terminal but retryable) state for good
                self.fe.abandon(c.rid)
                self._bump(c.tier, "abandoned")
            elif c.state == _WAITING:
                st = states.get(c.rid)
                if st == "finished":
                    self._bump(c.tier, "ok")
                    self.latencies.append(
                        (c.tier, float(self.fe.t) - c.sent_at))
                else:
                    if st == "timed_out":
                        self._bump(c.tier, "timed_out")
                    elif st == "shed":
                        self._bump(c.tier, "shed")
                    self.fe.abandon(c.rid)
                    self._bump(c.tier, "abandoned")
            else:
                continue
            c.state = _THINKING
            c.timer = self._think()

    # ------------------------------------------------------------- reports
    def summary(self) -> dict:
        """Client-side aggregate + per-tier rows (counts are attempts for
        ``issued``/``retries``, rids for ``ok``/``abandoned``)."""
        lat = [t for _, t in self.latencies]
        return {
            "clients": self.active_clients + self._dormant,
            "latency_mean": float(np.mean(lat)) if lat else None,
            "latency_p95": float(np.percentile(lat, 95)) if lat else None,
            **self.stats,
            "per_tier": {k: dict(v) for k, v in self.tier_stats.items()},
        }
