"""Google-Cluster-Data-style synthetic workload generator.

The real 2011/2019 Google cluster traces are not available offline; this
generator reproduces their documented stylized facts (cited in the trace
analysis literature):

  * strong diurnal cycle with ~2-4x peak-to-trough swing,
  * bursty arrivals: flash-crowd spikes with Pareto-distributed magnitude
    and exponential inter-arrival,
  * AR(1) short-term autocorrelation,
  * heavy-tailed per-task resource demand (lognormal),
  * occasional demand dips (maintenance windows).

Output: requests/sec per tick (and per-request cost multipliers for the
request-level engine). Deterministic per seed.

**SLO tiers.** Real inference fleets serve several QoS classes over one pool
(interactive premium traffic, default standard traffic, throughput-oriented
batch jobs). ``TierSpec``/``TierSet`` describe that mix: each tier has a
traffic ``share`` (workload sampling), a scheduling ``weight`` (the
weighted-deficit admission quantum in the serving engine — higher weight
admits first, lower weight keeps a bounded fraction so it never starves)
and optional TTFT/TBT targets in ticks (the SLO the reward and the GPSO
planner score against). ``parse_tiers`` reads the
``premium:0.2:w5,standard:0.5:w2,batch:0.3:w1`` CLI syntax (an optional 4th
``:T`` field is the TTFT target). The default is a single ``standard`` tier,
which makes every tier-aware code path byte-identical to the untiered
scheduler.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np


@dataclasses.dataclass(frozen=True)
class TierSpec:
    """One QoS class: traffic share, admission weight, latency targets."""
    name: str
    share: float = 1.0              # fraction of generated traffic
    weight: float = 1.0             # weighted-deficit admission quantum
    ttft_target: float = math.inf   # ticks; inf = no TTFT SLO
    tbt_target: float = math.inf    # ticks/token; inf = no TBT SLO


class TierSet:
    """Ordered collection of ``TierSpec``s with the derived views every
    layer needs: priority order (weight-descending, declaration-stable),
    name lookup with a safe fallback, share sampling for workload
    generators, and the tier-weighted aggregates (queue pressure, SLO
    violation cost) the planner and the Eq.5 reward consume."""

    def __init__(self, specs):
        specs = list(specs)
        if not specs:
            raise ValueError("TierSet needs at least one tier")
        self.specs = specs
        self.names = [s.name for s in specs]
        self._by_name = {s.name: i for i, s in enumerate(specs)}
        self.weights = np.asarray([s.weight for s in specs], np.float64)
        shares = np.asarray([max(s.share, 0.0) for s in specs], np.float64)
        self.shares = shares / max(shares.sum(), 1e-12)
        # priority: higher weight first; ties keep declaration order
        self.priority = sorted(range(len(specs)),
                               key=lambda i: (-specs[i].weight, i))
        self._rank = {t: r for r, t in enumerate(self.priority)}
        # unknown tier names map to the lowest-priority tier (conservative)
        self._fallback = self.priority[-1]

    def __len__(self) -> int:
        return len(self.specs)

    def __iter__(self):
        return iter(self.specs)

    def index(self, name: str) -> int:
        return self._by_name.get(name, self._fallback)

    def rank(self, name: str) -> int:
        """Priority rank of a tier name: 0 = highest priority."""
        return self._rank[self.index(name)]

    def sample(self, rng: np.random.Generator) -> str:
        """Draw a tier name by traffic share (workload stamping)."""
        return self.names[int(rng.choice(len(self.specs), p=self.shares))]

    # ------------------------------------------------- weighted aggregates
    def pressure(self, tier_queues: np.ndarray) -> np.ndarray:
        """Tier-weighted backlog per node: (T, N) queue depths -> (N,).

        Weights are normalized by their mean so a single-tier set reduces to
        the plain queue depth — the signal the GPSO planner's SLO cost term
        consumes (premium backlog weighs more than batch backlog)."""
        q = np.asarray(tier_queues, np.float64)
        w = self.weights / max(self.weights.mean(), 1e-12)
        return (w[:, None] * q).sum(axis=0).astype(np.float32)

    def slo_cost(self, violations) -> float:
        """Weighted mean SLO violation in [0, 1]: per-tier violation levels
        (dict name -> level or (T,) array) -> one Eq.5 penalty scalar."""
        if isinstance(violations, dict):
            v = np.asarray([violations.get(n, 0.0) for n in self.names],
                           np.float64)
        else:
            v = np.asarray(violations, np.float64)
        v = np.where(np.isfinite(v), v, 0.0)
        return float((self.weights * v).sum() / max(self.weights.sum(),
                                                    1e-12))


DEFAULT_TIERS = TierSet([TierSpec("standard")])


def parse_tiers(spec: str) -> TierSet:
    """Parse ``name:share:wW[:ttft]`` comma lists, e.g.
    ``premium:0.2:w5:4,standard:0.5:w2,batch:0.3:w1``. Empty string ->
    the single-tier default."""
    spec = (spec or "").strip()
    if not spec:
        return DEFAULT_TIERS
    tiers = []
    for part in spec.split(","):
        fields = part.strip().split(":")
        if not fields[0]:
            raise ValueError(f"bad tier spec {part!r}")
        name = fields[0]
        share = float(fields[1]) if len(fields) > 1 else 1.0
        weight = 1.0
        if len(fields) > 2:
            w = fields[2]
            weight = float(w[1:] if w.startswith("w") else w)
        ttft = float(fields[3]) if len(fields) > 3 else math.inf
        if share < 0 or weight <= 0 or ttft <= 0:
            raise ValueError(f"bad tier spec {part!r}")
        tiers.append(TierSpec(name, share=share, weight=weight,
                              ttft_target=ttft))
    return TierSet(tiers)


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    ticks: int = 2000
    base_rate: float = 400.0        # requests/sec at the diurnal mean
    diurnal_period: int = 600       # ticks per "day"
    diurnal_amp: float = 0.45       # relative amplitude
    ar_rho: float = 0.9             # AR(1) coefficient
    ar_sigma: float = 0.05          # AR(1) innovation (relative)
    burst_rate: float = 1 / 300.0   # bursts per tick (exp inter-arrival)
    burst_pareto_alpha: float = 1.5
    burst_scale: float = 0.8        # burst magnitude (x base rate)
    burst_decay: float = 0.92       # per-tick burst decay
    dip_rate: float = 1 / 900.0
    dip_depth: float = 0.5
    dip_len: int = 40
    cost_lognorm_sigma: float = 0.6  # per-request cost multiplier spread


def generate_trace(cfg: TraceConfig = TraceConfig(), seed: int = 0,
                   load_scale: float = 1.0) -> dict:
    """Returns {"arrivals": (T,) req/s, "cost_mult": (T,) mean cost mult}."""
    rng = np.random.default_rng(seed)
    T = cfg.ticks
    t = np.arange(T)
    diurnal = 1.0 + cfg.diurnal_amp * np.sin(2 * np.pi * t / cfg.diurnal_period
                                             - np.pi / 2)
    # AR(1) noise
    ar = np.zeros(T)
    innov = rng.normal(0, cfg.ar_sigma, T)
    for i in range(1, T):
        ar[i] = cfg.ar_rho * ar[i - 1] + innov[i]
    # bursts
    burst = np.zeros(T)
    level = 0.0
    for i in range(T):
        if rng.random() < cfg.burst_rate:
            level += (rng.pareto(cfg.burst_pareto_alpha) + 1) * cfg.burst_scale
        burst[i] = level
        level *= cfg.burst_decay
    # dips
    dip = np.ones(T)
    i = 0
    while i < T:
        if rng.random() < cfg.dip_rate:
            dip[i:i + cfg.dip_len] *= cfg.dip_depth
            i += cfg.dip_len
        i += 1
    arrivals = cfg.base_rate * load_scale * np.maximum(
        diurnal * (1 + ar) * dip + burst, 0.02)
    cost = np.exp(rng.normal(0, cfg.cost_lognorm_sigma, T)
                  - cfg.cost_lognorm_sigma ** 2 / 2)
    return {"arrivals": arrivals.astype(np.float32),
            "cost_mult": cost.astype(np.float32)}


LOAD_LEVELS = {"low": 0.5, "medium": 1.0, "high": 1.8, "ultra": 2.8}


def make_forecast_dataset(arrivals: np.ndarray, window: int, horizon: int):
    """Sliding windows for forecaster training: (M, W, 1), (M, T, 1)."""
    T = arrivals.shape[0]
    xs, ys = [], []
    scale = arrivals.mean()
    a = arrivals / scale
    for i in range(T - window - horizon):
        xs.append(a[i:i + window, None])
        ys.append(a[i + window:i + window + horizon, None])
    return np.stack(xs), np.stack(ys), scale
