"""Google-Cluster-Data-style synthetic workload generator.

The real 2011/2019 Google cluster traces are not available offline; this
generator reproduces their documented stylized facts (cited in the trace
analysis literature):

  * strong diurnal cycle with ~2-4x peak-to-trough swing,
  * bursty arrivals: flash-crowd spikes with Pareto-distributed magnitude
    and exponential inter-arrival,
  * AR(1) short-term autocorrelation,
  * heavy-tailed per-task resource demand (lognormal),
  * occasional demand dips (maintenance windows).

Output: requests/sec per tick (and per-request cost multipliers for the
request-level engine). Deterministic per seed.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    ticks: int = 2000
    base_rate: float = 400.0        # requests/sec at the diurnal mean
    diurnal_period: int = 600       # ticks per "day"
    diurnal_amp: float = 0.45       # relative amplitude
    ar_rho: float = 0.9             # AR(1) coefficient
    ar_sigma: float = 0.05          # AR(1) innovation (relative)
    burst_rate: float = 1 / 300.0   # bursts per tick (exp inter-arrival)
    burst_pareto_alpha: float = 1.5
    burst_scale: float = 0.8        # burst magnitude (x base rate)
    burst_decay: float = 0.92       # per-tick burst decay
    dip_rate: float = 1 / 900.0
    dip_depth: float = 0.5
    dip_len: int = 40
    cost_lognorm_sigma: float = 0.6  # per-request cost multiplier spread


def generate_trace(cfg: TraceConfig = TraceConfig(), seed: int = 0,
                   load_scale: float = 1.0) -> dict:
    """Returns {"arrivals": (T,) req/s, "cost_mult": (T,) mean cost mult}."""
    rng = np.random.default_rng(seed)
    T = cfg.ticks
    t = np.arange(T)
    diurnal = 1.0 + cfg.diurnal_amp * np.sin(2 * np.pi * t / cfg.diurnal_period
                                             - np.pi / 2)
    # AR(1) noise
    ar = np.zeros(T)
    innov = rng.normal(0, cfg.ar_sigma, T)
    for i in range(1, T):
        ar[i] = cfg.ar_rho * ar[i - 1] + innov[i]
    # bursts
    burst = np.zeros(T)
    level = 0.0
    for i in range(T):
        if rng.random() < cfg.burst_rate:
            level += (rng.pareto(cfg.burst_pareto_alpha) + 1) * cfg.burst_scale
        burst[i] = level
        level *= cfg.burst_decay
    # dips
    dip = np.ones(T)
    i = 0
    while i < T:
        if rng.random() < cfg.dip_rate:
            dip[i:i + cfg.dip_len] *= cfg.dip_depth
            i += cfg.dip_len
        i += 1
    arrivals = cfg.base_rate * load_scale * np.maximum(
        diurnal * (1 + ar) * dip + burst, 0.02)
    cost = np.exp(rng.normal(0, cfg.cost_lognorm_sigma, T)
                  - cfg.cost_lognorm_sigma ** 2 / 2)
    return {"arrivals": arrivals.astype(np.float32),
            "cost_mult": cost.astype(np.float32)}


LOAD_LEVELS = {"low": 0.5, "medium": 1.0, "high": 1.8, "ultra": 2.8}


def make_forecast_dataset(arrivals: np.ndarray, window: int, horizon: int):
    """Sliding windows for forecaster training: (M, W, 1), (M, T, 1)."""
    T = arrivals.shape[0]
    xs, ys = [], []
    scale = arrivals.mean()
    a = arrivals / scale
    for i in range(T - window - horizon):
        xs.append(a[i:i + window, None])
        ys.append(a[i + window:i + window + horizon, None])
    return np.stack(xs), np.stack(ys), scale
