"""Pallas TPU fused GCN layer: relu(Â · X · W + b) in one VMEM-resident pass.

This is the paper's own compute (Eq. 6) — it runs on every scheduling tick
of every node in decentralized mode, so it is latency-critical for the
control plane. Cluster graphs are small (N ≤ a few hundred nodes), so a
single program instance holds Â (N×N), X (N×F) and W (F×H) in VMEM and does
both matmuls back-to-back on the MXU with no HBM round-trip for the (N×F)
intermediate.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gcn_kernel(a_ref, x_ref, w_ref, b_ref, o_ref, *, relu):
    ax = jax.lax.dot(a_ref[...].astype(jnp.float32),
                     x_ref[...].astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    h = jax.lax.dot(ax, w_ref[...].astype(jnp.float32),
                    preferred_element_type=jnp.float32) + b_ref[...]
    if relu:
        h = jnp.maximum(h, 0.0)
    o_ref[...] = h.astype(o_ref.dtype)


def gcn_layer(a_hat, x, w, b, *, relu=True, interpret=False):
    """a_hat: (N, N); x: (N, F); w: (F, H); b: (H,). Returns (N, H)."""
    import functools
    N, F = x.shape
    H = w.shape[1]
    return pl.pallas_call(
        functools.partial(_gcn_kernel, relu=relu),
        in_specs=[
            pl.BlockSpec((N, N), lambda: (0, 0)),
            pl.BlockSpec((N, F), lambda: (0, 0)),
            pl.BlockSpec((F, H), lambda: (0, 0)),
            pl.BlockSpec((H,), lambda: (0,)),
        ],
        out_specs=pl.BlockSpec((N, H), lambda: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((N, H), x.dtype),
        interpret=interpret,
    )(a_hat, x, w, b)
