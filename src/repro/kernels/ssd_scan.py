"""Pallas TPU kernel for the Mamba-2 SSD blocked scan.

One program instance per (batch, chunk); the chunk axis is the innermost
(sequential) grid dimension, so the inter-chunk SSM state (H, P, N) lives in
VMEM scratch and is carried across chunk iterations — the HBM traffic is just
the chunk inputs/outputs (the SSD algorithm's whole point on TPU: the
semiseparable matrix is never materialized, and the intra-chunk terms are
MXU-shaped (Q×Q)·(Q×P) matmuls).

Single B/C group (G=1), matching mamba2-1.3b / zamba2-2.7b.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 64


def _ssd_kernel(x_ref, a_ref, b_ref, c_ref, y_ref, state_out_ref, state_scr):
    ci = pl.program_id(1)
    n_chunks = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0].astype(jnp.float32)        # (Q, H, P) dt-preweighted
    a = a_ref[0].astype(jnp.float32)        # (Q, H) log decays
    bm = b_ref[0].astype(jnp.float32)       # (Q, N)
    cm = c_ref[0].astype(jnp.float32)       # (Q, N)
    state = state_scr[...]                  # (H, P, N)

    a_cum = jnp.cumsum(a, axis=0)           # (Q, H)
    Q = a.shape[0]
    # L[q, k, h] = exp(a_cum[q] - a_cum[k]) for q >= k
    diff = a_cum[:, None, :] - a_cum[None, :, :]
    rows = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where((rows >= cols)[:, :, None], jnp.exp(diff), 0.0)  # (Q,Q,H)
    scores = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (Q,Q)
    y_diag = jnp.einsum("qkh,qk,khp->qhp", L, scores, x)
    y_off = jnp.einsum("qn,hpn,qh->qhp", cm, state, jnp.exp(a_cum))
    y_ref[0] = (y_diag + y_off).astype(y_ref.dtype)

    decay_out = jnp.exp(a_cum[-1:, :] - a_cum)                     # (Q, H)
    new_state = state * jnp.exp(a_cum[-1])[:, None, None] + \
        jnp.einsum("kn,khp,kh->hpn", bm, x, decay_out)
    state_scr[...] = new_state

    @pl.when(ci == n_chunks - 1)
    def _emit_state():
        state_out_ref[0] = new_state.astype(state_out_ref.dtype)


def ssd_scan(x, a, Bm, Cm, *, chunk=DEFAULT_CHUNK, interpret=False):
    """x: (B, T, H, P) dt-preweighted; a: (B, T, H) log decays;
    Bm, Cm: (B, T, N). Returns (y (B,T,H,P) f32, final state (B,H,P,N) f32).
    """
    B, T, H, P = x.shape
    N = Bm.shape[-1]
    chunk = min(chunk, T)
    assert T % chunk == 0, (T, chunk)

    return pl.pallas_call(
        _ssd_kernel,
        grid=(B, T // chunk),
        in_specs=[
            pl.BlockSpec((1, chunk, H, P), lambda b, ci: (b, ci, 0, 0)),
            pl.BlockSpec((1, chunk, H), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, ci: (b, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, H, P), lambda b, ci: (b, ci, 0, 0)),
            pl.BlockSpec((1, H, P, N), lambda b, ci: (b, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, H, P), jnp.float32),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((H, P, N), jnp.float32)],
        interpret=interpret,
    )(x, a, Bm, Cm)
