"""Jit'd public wrappers for the Pallas kernels with XLA fallbacks.

On TPU (the deployment target) ``use_kernel=True`` dispatches the Pallas
implementations; on this CPU container they run with ``interpret=True``
(tests) or fall back to the jnp reference path (models / dry-run, where the
XLA HLO is what the roofline reads).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.decode_attention import flash_decode
from repro.kernels.flash_attention import flash_attention
from repro.kernels.gcn_fused import gcn_layer
from repro.kernels.ssd_scan import ssd_scan


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "use_kernel",
                                             "interpret"))
def attention_op(q, k, v, *, causal=True, use_kernel=None, interpret=False):
    use_kernel = _on_tpu() if use_kernel is None else use_kernel
    if use_kernel or interpret:
        return flash_attention(q, k, v, causal=causal,
                               interpret=interpret or not _on_tpu())
    return ref.attention_ref(q, k, v, causal=causal)


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def decode_attention_op(q, k_cache, v_cache, pos, *, use_kernel=None,
                        interpret=False):
    use_kernel = _on_tpu() if use_kernel is None else use_kernel
    if use_kernel or interpret:
        return flash_decode(q, k_cache, v_cache, pos,
                            interpret=interpret or not _on_tpu())
    return ref.decode_attention_ref(q, k_cache, v_cache, pos)


@functools.partial(jax.jit, static_argnames=("chunk", "use_kernel",
                                             "interpret"))
def ssd_scan_op(x, a, Bm, Cm, *, chunk=64, use_kernel=None, interpret=False):
    use_kernel = _on_tpu() if use_kernel is None else use_kernel
    if use_kernel or interpret:
        return ssd_scan(x, a, Bm, Cm, chunk=chunk,
                        interpret=interpret or not _on_tpu())
    return ref.ssd_scan_ref(x, a, Bm, Cm, chunk)


@functools.partial(jax.jit, static_argnames=("relu", "use_kernel",
                                             "interpret"))
def gcn_layer_op(a_hat, x, w, b, *, relu=True, use_kernel=None,
                 interpret=False):
    use_kernel = _on_tpu() if use_kernel is None else use_kernel
    if use_kernel or interpret:
        return gcn_layer(a_hat, x, w, b, relu=relu,
                         interpret=interpret or not _on_tpu())
    return ref.gcn_layer_ref(a_hat, x, w, b, relu=relu)
