"""Pallas TPU flash-decode: single-token GQA attention over a KV cache.

The serving hot spot. Grid = (batch, kv_head, kv_block) with the kv axis
innermost-sequential; the running online-softmax state for the group's
q-heads lives in VMEM scratch. The current cache length ``pos`` arrives via
scalar prefetch (SMEM) so blocks past the valid range are skipped entirely —
decode cost is proportional to the *filled* cache, not the allocated one.

Layout: q (B, G, qpg, d) grouped; caches (B, G, S, d). One program instance
serves all q-heads of one kv group (they share the K/V stream — the GQA
arithmetic-intensity win on the MXU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_KV = 256
NEG_INF = -1e30


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                   *, scale, block_kv):
    ki = pl.program_id(2)
    n_kv = pl.num_programs(2)
    pos = pos_ref[pl.program_id(0)]      # per-row cache length (SMEM)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    k_start = ki * block_kv

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                # (qpg, d)
        k = k_ref[0, 0].astype(jnp.float32)                # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(cols <= pos, s, NEG_INF)
        m_prev, l_prev = m_scr[...], l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_prev * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + p @ v
        m_scr[...] = m_new

    pl.when(k_start <= pos)(_compute)

    @pl.when(ki == n_kv - 1)
    def _finalize():
        l = l_scr[...]
        safe = jnp.where(l > 0.0, l, 1.0)
        o_ref[0, 0] = (acc_scr[...] / safe[:, None]).astype(o_ref.dtype)


def flash_decode(q, k_cache, v_cache, pos, *, block_kv=DEFAULT_BLOCK_KV,
                 interpret=False):
    """q: (B, Hq, d); caches: (B, Hkv, S, d); pos: scalar int32 or (B,)
    int32 (per-row cache lengths — the serving slot-pool layout, where every
    slot sits at its own fill depth).

    Returns (B, Hq, d). Row b attends over cache positions 0..pos[b]
    inclusive.
    """
    B, Hq, d = q.shape
    Hkv, S = k_cache.shape[1], k_cache.shape[2]
    assert Hq % Hkv == 0
    qpg = Hq // Hkv
    block_kv = min(block_kv, S)
    assert S % block_kv == 0
    qg = q.reshape(B, Hkv, qpg, d)
    scale = 1.0 / np.sqrt(d)
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 0:
        pos_arr = jnp.full((B,), pos, jnp.int32)
    else:
        assert pos.shape == (B,), pos.shape
        pos_arr = pos

    kernel = functools.partial(_decode_kernel, scale=scale, block_kv=block_kv)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hkv, S // block_kv),
        in_specs=[
            pl.BlockSpec((1, 1, qpg, d), lambda b, g, ki, pos: (b, g, 0, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda b, g, ki, pos: (b, g, ki, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda b, g, ki, pos: (b, g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, qpg, d),
                               lambda b, g, ki, pos: (b, g, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((qpg,), jnp.float32),
            pltpu.VMEM((qpg,), jnp.float32),
            pltpu.VMEM((qpg, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, qpg, d), q.dtype),
        interpret=interpret,
    )(pos_arr, qg, k_cache, v_cache)
    return out.reshape(B, Hq, d)
