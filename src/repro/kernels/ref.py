"""Pure-jnp oracles for every Pallas kernel (the correctness source of truth).

Each function mirrors its kernel's signature exactly; tests sweep shapes and
dtypes and assert allclose between kernel (interpret=True on CPU) and oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def attention_ref(q, k, v, *, causal=True):
    """q: (B, Hq, S, d); k, v: (B, Hkv, T, d). GQA by kv-head repetition."""
    B, Hq, S, d = q.shape
    Hkv, T = k.shape[1], k.shape[2]
    rep = Hq // Hkv
    k = jnp.repeat(k, rep, axis=1)
    v = jnp.repeat(v, rep, axis=1)
    s = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(d)
    if causal:
        mask = jnp.tril(jnp.ones((S, T), bool), k=T - S)
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, pos):
    """q: (B, Hq, d); caches: (B, Hkv, S, d); pos: scalar int (attend 0..pos)."""
    B, Hq, d = q.shape
    Hkv, S = k_cache.shape[1], k_cache.shape[2]
    rep = Hq // Hkv
    k = jnp.repeat(k_cache, rep, axis=1).astype(jnp.float32)
    v = jnp.repeat(v_cache, rep, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhd,bhtd->bht", q.astype(jnp.float32), k) / np.sqrt(d)
    mask = jnp.arange(S) <= pos
    s = jnp.where(mask[None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bht,bhtd->bhd", p, v).astype(q.dtype)


def ssd_chunk_ref(x, a, Bm, Cm, state):
    """One SSD chunk, per the blocked algorithm.

    x:  (B, Q, H, P) — dt-preweighted inputs
    a:  (B, Q, H)    — log decays
    Bm, Cm: (B, Q, N) (single group)
    state: (B, H, P, N) carried in
    Returns (y (B,Q,H,P), new_state).
    """
    a = a.astype(jnp.float32)
    x = x.astype(jnp.float32)
    Bm = Bm.astype(jnp.float32)
    Cm = Cm.astype(jnp.float32)
    Q = x.shape[1]
    a_cum = jnp.cumsum(a, axis=1)                          # (B,Q,H)
    diff = a_cum[:, :, None, :] - a_cum[:, None, :, :]     # (B,Q,K,H)
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(tri[None, :, :, None], jnp.exp(diff), 0.0)
    scores = jnp.einsum("bqn,bkn->bqk", Cm, Bm)
    y_diag = jnp.einsum("bqkh,bqk,bkhp->bqhp", L, scores, x)
    y_off = jnp.einsum("bqn,bhpn,bqh->bqhp", Cm, state, jnp.exp(a_cum))
    decay_out = jnp.exp(a_cum[:, -1:, :] - a_cum)          # (B,Q,H)
    new_state = state * jnp.exp(a_cum[:, -1])[:, :, None, None] + \
        jnp.einsum("bkn,bkhp,bkh->bhpn", Bm, x, decay_out)
    return (y_diag + y_off), new_state


def ssd_scan_ref(x, a, Bm, Cm, chunk):
    """Multi-chunk reference: sequential ssd_chunk_ref over chunks."""
    B, T, H, P = x.shape
    N = Bm.shape[-1]
    state = jnp.zeros((B, H, P, N), jnp.float32)
    ys = []
    for c0 in range(0, T, chunk):
        y, state = ssd_chunk_ref(x[:, c0:c0 + chunk], a[:, c0:c0 + chunk],
                                 Bm[:, c0:c0 + chunk], Cm[:, c0:c0 + chunk],
                                 state)
        ys.append(y)
    return jnp.concatenate(ys, axis=1), state


def gcn_layer_ref(a_hat, x, w, b, *, relu=True):
    """relu(Â @ X @ W + b) — one GCN layer (paper Eq.6)."""
    h = (a_hat.astype(jnp.float32) @ x.astype(jnp.float32)) @ \
        w.astype(jnp.float32) + b.astype(jnp.float32)
    if relu:
        h = jnp.maximum(h, 0.0)
    return h.astype(x.dtype)
