"""Pallas TPU flash attention (causal GQA prefill).

TPU-native design (not a CUDA port): the grid is (batch, q_head, q_block,
kv_block) with the kv axis innermost — TPU grids execute the last axis
sequentially per core, so the online-softmax running state (m, l, acc) lives
in VMEM scratch that persists across kv iterations. Blocks are MXU-shaped
(multiples of 128 on the matmul dims); K/V tiles stream HBM→VMEM one
(block_kv, head_dim) tile at a time, so VMEM holds
O(block_q·d + 2·block_kv·d + block_q·block_kv) regardless of sequence length.

GQA is expressed in the index_map: q head h reads kv head h // q_per_group —
no materialized KV replication.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_KV = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale, block_q, block_kv, seq_len, causal):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    n_kv = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_kv

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                 # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)                 # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)                 # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            rows = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                      (block_q, block_kv), 0)
            cols = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                      (block_q, block_kv), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + p @ v
        m_scr[...] = m_new
        l_scr[...] = l_new

    if causal:
        # skip kv blocks strictly above the diagonal
        pl.when(k_start <= q_start + block_q - 1)(_compute)
    else:
        _compute()

    @pl.when(ki == n_kv - 1)
    def _finalize():
        l = l_scr[...]
        safe = jnp.where(l > 0.0, l, 1.0)
        o_ref[0, 0] = (acc_scr[...] / safe[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=True, block_q=DEFAULT_BLOCK_Q,
                    block_kv=DEFAULT_BLOCK_KV, interpret=False):
    """q: (B, Hq, S, d); k, v: (B, Hkv, S, d) with Hq % Hkv == 0.

    Returns (B, Hq, S, d).
    """
    B, Hq, S, d = q.shape
    Hkv = k.shape[1]
    assert Hq % Hkv == 0, (Hq, Hkv)
    qpg = Hq // Hkv
    block_q = min(block_q, S)
    block_kv = min(block_kv, S)
    assert S % block_q == 0 and S % block_kv == 0
    scale = 1.0 / np.sqrt(d)

    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_kv=block_kv,
        seq_len=S, causal=causal)

    return pl.pallas_call(
        kernel,
        grid=(B, Hq, S // block_q, S // block_kv),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda b, h, qi, ki: (b, h // qpg, ki, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda b, h, qi, ki: (b, h // qpg, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
