from repro.checkpoint.manager import (  # noqa: F401
    list_checkpoints, restore_latest, save_checkpoint,
)
