"""Fault-tolerant checkpointing: atomic, keep-k, auto-resume.

Self-contained (no orbax): each checkpoint is a directory of .npz leaf shards
plus a JSON manifest with the treedef and step metadata. Writes go to a temp
dir + atomic rename, so a crash mid-save never corrupts the latest
checkpoint; ``restore_latest`` skips incomplete/corrupt directories. This is
the restart path for node failures (the cluster-level fault-tolerance story
is in repro/sim + repro/core/decentralized).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

_MANIFEST = "manifest.json"
_DATA = "leaves.npz"


def _flatten_with_names(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_checkpoint(ckpt_dir: str, step: int, tree, *, keep: int = 3,
                    extra: dict = None) -> str:
    """Atomically write checkpoint `step`. Returns the final path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:010d}")
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    try:
        leaves, treedef = _flatten_with_names(tree)
        arrays = {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}
        np.savez(os.path.join(tmp, _DATA), **arrays)
        manifest = {
            "step": step,
            "n_leaves": len(leaves),
            "treedef": str(treedef),
            "time": time.time(),
            "extra": extra or {},
            "complete": True,
        }
        with open(os.path.join(tmp, _MANIFEST), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def list_checkpoints(ckpt_dir: str) -> list:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for d in sorted(os.listdir(ckpt_dir)):
        if not d.startswith("step_"):
            continue
        path = os.path.join(ckpt_dir, d)
        mpath = os.path.join(path, _MANIFEST)
        try:
            with open(mpath) as f:
                man = json.load(f)
            if man.get("complete"):
                out.append((man["step"], path, man))
        except (OSError, json.JSONDecodeError):
            continue  # incomplete/corrupt — skip
    return out


def restore_latest(ckpt_dir: str, tree_like):
    """Restore the newest intact checkpoint into `tree_like`'s structure.

    Returns (step, tree) or (None, None) when nothing restorable exists.
    """
    ckpts = list_checkpoints(ckpt_dir)
    for step, path, man in reversed(ckpts):
        try:
            with np.load(os.path.join(path, _DATA)) as data:
                leaves = [data[f"leaf_{i}"] for i in range(man["n_leaves"])]
            ref_leaves, treedef = jax.tree_util.tree_flatten(tree_like)
            if len(leaves) != len(ref_leaves):
                continue
            restored = [jnp.asarray(x, dtype=r.dtype)
                        for x, r in zip(leaves, ref_leaves)]
            return step, jax.tree_util.tree_unflatten(treedef, restored)
        except (OSError, ValueError, KeyError):
            continue  # corrupt — try the previous one
    return None, None
