from repro.sim.cluster import ClusterSim, ClusterState, init_state  # noqa: F401
from repro.sim.service_rate import (  # noqa: F401
    replica_decode_rate, replica_request_rate,
)
