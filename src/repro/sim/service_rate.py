"""Per-replica service rates derived from each arch's TPU-v5e roofline.

A "replica" is one TP=16 slice of v5e serving decode. Throughput model
(decode, batch B requests in flight):

    step_time = max( compute:  2·N_active·B / (chips·peak_flops),
                     memory:   weight_bytes/(chips·hbm_bw)
                               + B·kv_bytes_per_token/(chips·hbm_bw) )
    tokens/s  = B / step_time,   requests/s = tokens/s / avg_decode_len

This couples the paper's cluster-level experiments to real model economics:
a grok-1 replica is ~20x more expensive per request than granite-8b.
"""
from __future__ import annotations

from repro.configs.base import ArchConfig

PEAK_FLOPS = 197e12          # bf16 / chip (v5e)
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link
CHIPS_PER_REPLICA = 16
DEFAULT_BATCH = 64
AVG_DECODE_LEN = 128


def kv_bytes_per_token(cfg: ArchConfig, kv_dtype_bytes: int = 2) -> float:
    if cfg.family in ("ssm", "hybrid"):
        # mamba state is O(1); per-token HBM traffic ~ state read/write
        state = cfg.num_layers * cfg.ssm_heads * cfg.ssm_head_dim * \
            cfg.ssm_state * 4
        extra = 0.0
        if cfg.family == "hybrid" and cfg.attn_every:
            n_inv = (cfg.num_layers + cfg.attn_every - 1) // cfg.attn_every
            extra = 2 * n_inv * cfg.num_kv_heads * cfg.resolved_head_dim * \
                kv_dtype_bytes
        return state / 1000.0 + extra  # state reread amortized over context
    layers = cfg.num_layers
    return 2 * layers * cfg.num_kv_heads * cfg.resolved_head_dim * \
        kv_dtype_bytes


def replica_decode_rate(cfg: ArchConfig, batch: int = DEFAULT_BATCH,
                        context: int = 4096) -> float:
    """Decode tokens/sec of one TP-16 replica."""
    n_active = cfg.active_param_count()
    weight_bytes = n_active * 2
    flops_per_tok = 2 * n_active
    chips = CHIPS_PER_REPLICA
    compute_t = flops_per_tok * batch / (chips * PEAK_FLOPS)
    kv_traffic = batch * kv_bytes_per_token(cfg) * context
    memory_t = (weight_bytes + kv_traffic) / (chips * HBM_BW)
    step_t = max(compute_t, memory_t)
    return batch / step_t


def replica_request_rate(cfg: ArchConfig, batch: int = DEFAULT_BATCH,
                         context: int = 4096,
                         decode_len: int = AVG_DECODE_LEN) -> float:
    """Requests/sec of one replica (the simulator's unit_capacity)."""
    return replica_decode_rate(cfg, batch, context) / decode_len
