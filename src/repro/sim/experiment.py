"""Experiment runner: (balancer × autoscaler) over a workload trace.

Reproduces the paper's comparison matrix (§4.2):

    RRA   — round robin, static replicas
    LCA   — least connections, static replicas
    HPA   — round robin + Kubernetes HPA autoscaling
    RBAS  — round robin + rule-based autoscaling
    OURS  — MADRL (GCN+DDPG) balancer + GRU forecast + GPSO autoscaler

and produces the Fig.1/2/3 metrics (resource utilization, response time,
scaling efficiency) plus fairness/SLO/cost aggregates.

The per-tick loop itself lives in ``repro.control.ControlPlane`` — this
module just binds it to the fluid ``ClusterSim`` backend and aggregates the
figures; ``repro.launch.serve`` binds the identical plane to the
request-level elastic engine.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.configs.paper_cluster import ClusterConfig
from repro.control.backend import SimBackend
from repro.control.plane import METHOD_SPECS, ControlPlane  # noqa: F401
from repro.core import balancer as bal
from repro.sim.cluster import ClusterSim

METHODS = ("RRA", "LCA", "HPA", "RBAS", "OURS")


@dataclasses.dataclass
class EpisodeResult:
    name: str
    utilization: np.ndarray       # (T,) mean healthy-node utilization
    response_time: np.ndarray     # (T,)
    fairness: np.ndarray          # (T,) Jain index over node utilizations
    served: float
    replica_ticks: int
    unit_capacity: float
    cfg: ClusterConfig

    # ---------------------------------------------------------- aggregates
    def summary(self, warmup: int = 50, slo: float = 2.0) -> dict:
        u = self.utilization[warmup:]
        r = self.response_time[warmup:]
        f = self.fairness[warmup:]
        cap_work = self.replica_ticks * self.unit_capacity * \
            self.cfg.tick_seconds
        return {
            "mean_util": float(np.mean(u)),
            "std_util": float(np.std(u)),
            "mean_resp": float(np.mean(r)),
            "p95_resp": float(np.percentile(r, 95)),
            "fairness": float(np.mean(f)),
            "slo_attainment": float(np.mean(r < slo)),
            "scaling_efficiency": float(self.served / max(cap_work, 1e-9)),
            "cost": float(self.replica_ticks),
        }


def jain_fairness(x: np.ndarray) -> float:
    s, s2 = x.sum(), (x ** 2).sum()
    n = x.shape[0]
    return float(s * s / max(n * s2, 1e-12))


def collect_episode(plane: ControlPlane, arrivals: np.ndarray, name: str,
                    cfg: ClusterConfig, unit_capacity: float) -> EpisodeResult:
    """Drive a ControlPlane over a trace and aggregate the figure metrics.

    Backend-agnostic: works for SimBackend and ElasticClusterFrontend alike
    (both emit the same metric keys)."""
    T = arrivals.shape[0]
    utils, resps, fairs = np.zeros(T), np.zeros(T), np.zeros(T)
    served_total, replica_ticks = 0.0, 0
    for t in range(T):
        m = plane.step(float(arrivals[t]))
        utils[t] = m["mean_utilization"]
        resps[t] = m["response_time"]
        fairs[t] = jain_fairness(m["utilization"] + 1e-6)
        served_total += m["served"]
        replica_ticks += m["replica_ticks"]
    return EpisodeResult(name, utils, resps, fairs, served_total,
                         replica_ticks, unit_capacity, cfg)


def run_episode(cfg: ClusterConfig, trace: dict, method: str, *,
                unit_capacity: float,
                rl: Optional[bal.RLBalancer] = None,
                forecaster_params=None, forecast_scale: Optional[float] = None,
                train_rl: bool = False, explore: bool = False,
                failures: bool = True, seed: int = 0,
                train_every: int = 2) -> EpisodeResult:
    balancer_kind, scaler_kind = METHOD_SPECS[method]
    sim = ClusterSim(cfg, unit_capacity, seed=seed, failures=failures)
    arrivals = trace["arrivals"]
    if forecast_scale is None:
        forecast_scale = float(arrivals.mean())
    plane = ControlPlane(
        cfg, SimBackend(sim), balancer=balancer_kind, scaler=scaler_kind,
        unit_capacity=unit_capacity, rl=rl,
        forecaster_params=forecaster_params, forecast_scale=forecast_scale,
        train_rl=train_rl, explore=explore, train_every=train_every,
        seed=seed, init_arrival=float(arrivals[:10].mean()))
    return collect_episode(plane, arrivals, method, cfg, unit_capacity)


def train_rl_balancer(cfg: ClusterConfig, traces: list, *,
                      unit_capacity: float, forecaster_params=None,
                      forecast_scale: float = 1.0, episodes: int = 3,
                      seed: int = 0) -> bal.RLBalancer:
    """Train the MADRL balancer across trace episodes (exploration on)."""
    feat_dim = 4 + cfg.horizon
    rl = bal.RLBalancer(cfg, feat_dim, seed=seed)
    for ep in range(episodes):
        trace = traces[ep % len(traces)]
        run_episode(cfg, trace, "OURS", unit_capacity=unit_capacity, rl=rl,
                    forecaster_params=forecaster_params,
                    forecast_scale=forecast_scale, train_rl=True,
                    explore=True, failures=False, seed=seed + ep)
    return rl
