"""Experiment runner: (balancer × autoscaler) over a workload trace.

Reproduces the paper's comparison matrix (§4.2):

    RRA   — round robin, static replicas
    LCA   — least connections, static replicas
    HPA   — round robin + Kubernetes HPA autoscaling
    RBAS  — round robin + rule-based autoscaling
    OURS  — MADRL (GCN+DDPG) balancer + GRU forecast + GPSO autoscaler

and produces the Fig.1/2/3 metrics (resource utilization, response time,
scaling efficiency) plus fairness/SLO/cost aggregates.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_cluster import ClusterConfig
from repro.core import balancer as bal
from repro.core.autoscaler import (GPSOAutoscaler, HPAAutoscaler,
                                   RBASAutoscaler, StaticAllocator)
from repro.core.forecaster import forecast as nn_forecast
from repro.core.forecaster import last_value_baseline
from repro.sim.cluster import ClusterSim

METHODS = ("RRA", "LCA", "HPA", "RBAS", "OURS")


@dataclasses.dataclass
class EpisodeResult:
    name: str
    utilization: np.ndarray       # (T,) mean healthy-node utilization
    response_time: np.ndarray     # (T,)
    fairness: np.ndarray          # (T,) Jain index over node utilizations
    served: float
    replica_ticks: int
    unit_capacity: float
    cfg: ClusterConfig

    # ---------------------------------------------------------- aggregates
    def summary(self, warmup: int = 50, slo: float = 2.0) -> dict:
        u = self.utilization[warmup:]
        r = self.response_time[warmup:]
        f = self.fairness[warmup:]
        cap_work = self.replica_ticks * self.unit_capacity * \
            self.cfg.tick_seconds
        return {
            "mean_util": float(np.mean(u)),
            "std_util": float(np.std(u)),
            "mean_resp": float(np.mean(r)),
            "p95_resp": float(np.percentile(r, 95)),
            "fairness": float(np.mean(f)),
            "slo_attainment": float(np.mean(r < slo)),
            "scaling_efficiency": float(self.served / max(cap_work, 1e-9)),
            "cost": float(self.replica_ticks),
        }


def jain_fairness(x: np.ndarray) -> float:
    s, s2 = x.sum(), (x ** 2).sum()
    n = x.shape[0]
    return float(s * s / max(n * s2, 1e-12))


def _make_autoscaler(kind: str, cfg: ClusterConfig, unit_cap: float, seed=0):
    if kind == "gpso":
        return GPSOAutoscaler(cfg, unit_cap, seed)
    if kind == "ga":
        return GPSOAutoscaler(cfg, unit_cap, seed, optimizer="ga")
    if kind == "hpa":
        return HPAAutoscaler(cfg)
    if kind == "rbas":
        return RBASAutoscaler(cfg)
    if kind == "static":
        return StaticAllocator(max(1, cfg.max_replicas_per_node // 2))
    raise ValueError(kind)


METHOD_SPECS = {
    "RRA": ("rr", "static"),
    "LCA": ("lc", "static"),
    "HPA": ("rr", "hpa"),
    "RBAS": ("rr", "rbas"),
    "OURS": ("rl", "gpso"),
    # extra references beyond the paper's table + ablations
    "WRR": ("wrr", "static"),
    "OURS-GA": ("rl", "ga"),     # GA-only autoscaler (no PSO refinement)
    "OURS-RR": ("rr", "gpso"),   # GPSO scaling but round-robin balancing
}


_jit_forecast = jax.jit(nn_forecast)


def run_episode(cfg: ClusterConfig, trace: dict, method: str, *,
                unit_capacity: float,
                rl: Optional[bal.RLBalancer] = None,
                forecaster_params=None, forecast_scale: Optional[float] = None,
                train_rl: bool = False, explore: bool = False,
                failures: bool = True, seed: int = 0,
                train_every: int = 2) -> EpisodeResult:
    balancer_kind, scaler_kind = METHOD_SPECS[method]
    sim = ClusterSim(cfg, unit_capacity, seed=seed, failures=failures)
    scaler = _make_autoscaler(scaler_kind, cfg, unit_capacity, seed)
    arrivals = trace["arrivals"]
    if forecast_scale is None:
        forecast_scale = float(arrivals.mean())
    T = arrivals.shape[0]
    N = cfg.num_nodes
    W, H = cfg.forecast_window, cfg.horizon

    utils, resps, fairs = np.zeros(T), np.zeros(T), np.zeros(T)
    served_total, replica_ticks = 0.0, 0
    window = np.full((W,), arrivals[:10].mean(), np.float32)
    fractions = np.full((N,), 1.0 / N, np.float32)
    prev = None  # (obs, action) for RL replay
    resid = np.zeros(64, np.float32)  # rolling 1-step forecast residuals
    prev_fc1 = None

    for t in range(T):
        # ---- forecast R̂_{t+1:t+T} (Eq.1)
        if forecaster_params is not None:
            fc = np.asarray(_jit_forecast(
                forecaster_params,
                jnp.asarray(window[:, None] / forecast_scale)))[:, 0]
        else:
            fc = np.asarray(last_value_baseline(
                jnp.asarray(window[:, None] / forecast_scale), H))[:, 0]
        fc = fc.astype(np.float32)
        # rolling forecast-error tracker -> volatility-aware headroom
        if prev_fc1 is not None:
            resid = np.roll(resid, -1)
            resid[-1] = arrivals[t] / forecast_scale - prev_fc1
        prev_fc1 = float(fc[0])

        obs = sim.observation(fc)
        up = sim.state.up.copy()

        # ---- balancer action (Eq.4)
        if balancer_kind == "rr":
            fractions = np.asarray(bal.round_robin(jnp.asarray(obs),
                                                   jnp.asarray(up)))
        elif balancer_kind == "lc":
            fractions = np.asarray(bal.least_connections(
                jnp.asarray(sim.state.queue), jnp.asarray(up),
                jnp.float32(arrivals[t] * cfg.tick_seconds)))
        elif balancer_kind == "wrr":
            fractions = np.asarray(bal.weighted_capacity(
                jnp.asarray(obs), jnp.asarray(up),
                jnp.asarray(sim.capacity())))
        elif balancer_kind == "rl":
            assert rl is not None
            fractions = np.asarray(rl.act(jnp.asarray(obs), jnp.asarray(up),
                                          explore=explore))
        else:
            raise ValueError(balancer_kind)

        m = sim.tick(arrivals[t], fractions)

        # ---- reward (Eq.5) + replay
        if balancer_kind == "rl":
            reward = bal.reward_fn(m["response_time"], m["mean_utilization"],
                                   cfg.alpha, cfg.beta, m["overload"])
            if prev is not None and train_rl:
                rl.observe(prev[0], prev[1], float(prev[2]), obs, up)
                if t % train_every == 0:
                    rl.train_step()
            prev = (obs, fractions, reward)

        # ---- autoscaling: rule-based scalers observe every tick (the k8s
        # control loop); the GPSO plan runs on scale_interval.
        in_flight = sim.state.active + sim.state.pending.sum(axis=1)
        if scaler_kind in ("gpso", "ga"):
            if t % cfg.scale_interval == 0 and t > 0:
                # provision for the P95 of predicted demand: forecast peak
                # plus 2 sigma of recent forecast error (volatility-aware
                # headroom), so calm periods run lean and bursty ones hold
                # reserve.
                sigma = float(resid.std()) * forecast_scale
                peak = max(float(fc.max()) * forecast_scale,
                           float(arrivals[t])) + 2.0 * sigma
                node_demand = peak * np.maximum(fractions, 1.0 / (4 * N))
                target = scaler.plan(node_demand, t, in_flight,
                                     node_speed=sim.node_speed)
                sim.scale_to(target)
            else:
                # emergency path: instantaneous overload on a node triggers an
                # immediate scale-up without waiting for the plan interval
                hot = m["utilization"] > 0.95
                if hot.any():
                    target = in_flight + hot.astype(np.int32)
                    sim.scale_to(np.minimum(target,
                                            cfg.max_replicas_per_node))
        elif scaler_kind != "static":
            target = scaler.plan(m["utilization"], t, in_flight)
            sim.scale_to(target)

        utils[t] = m["mean_utilization"]
        resps[t] = m["response_time"]
        fairs[t] = jain_fairness(m["utilization"] + 1e-6)
        served_total += m["served"]
        replica_ticks += m["replica_ticks"]
        window = np.roll(window, -1)
        window[-1] = arrivals[t]

    return EpisodeResult(method, utils, resps, fairs, served_total,
                         replica_ticks, unit_capacity, cfg)


def train_rl_balancer(cfg: ClusterConfig, traces: list, *,
                      unit_capacity: float, forecaster_params=None,
                      forecast_scale: float = 1.0, episodes: int = 3,
                      seed: int = 0) -> bal.RLBalancer:
    """Train the MADRL balancer across trace episodes (exploration on)."""
    feat_dim = 4 + cfg.horizon
    rl = bal.RLBalancer(cfg, feat_dim, seed=seed)
    for ep in range(episodes):
        trace = traces[ep % len(traces)]
        run_episode(cfg, trace, "OURS", unit_capacity=unit_capacity, rl=rl,
                    forecaster_params=forecaster_params,
                    forecast_scale=forecast_scale, train_rl=True,
                    explore=True, failures=False, seed=seed + ep)
    return rl
