"""Fluid discrete-time cluster simulator for inference serving.

Models N serving nodes (each holding `replicas` model replicas whose unit
throughput comes from the arch's TPU-v5e roofline — see
``repro.sim.service_rate``). Per tick:

    arrivals --balancer fractions a_i--> per-node queues
    served_i = min(queue_i, capacity_i·dt)
    response_i ≈ queue_after/capacity (queueing) + 1/unit_rate (service)

plus the operational realities the paper's framework must survive at scale:
cold-start provisioning delay for new replicas, Poisson node failures with
repair times (queued work is re-routed), and straggler nodes with degraded
capacity. The tick update is a single jit'd function over (N,)-arrays.

**SLO tiers.** With ``tiers=TierSet(...)`` the per-node backlog is tracked
per priority class, mirroring the request-level engine's tiered queues:
arrivals split by tier share, and each node's served capacity drains tiers
in priority order (premium first — the fluid limit of weighted-deficit
admission under saturation). The aggregate dynamics are byte-identical to
the untiered sim (the same jit'd ``_tick_math`` runs on the summed queue);
tiering adds the per-tier breakdown the control plane observes:
``tier_queue`` (T, N), ``tier_pressure`` (N,) weighted backlog,
``tier_response`` per-tier latency estimates and the tier-weighted
``tier_slo_cost`` for the Eq.5 reward — the same metric keys the elastic
backend emits, so OURS and the baselines rank identically sim <-> elastic.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.workload.trace import TierSet


@dataclasses.dataclass
class ClusterState:
    queue: np.ndarray          # (N,) outstanding work (request-units)
    active: np.ndarray         # (N,) active replicas
    pending: np.ndarray        # (N, D) replicas arriving in d ticks
    up: np.ndarray             # (N,) 1 healthy / 0 failed
    down_left: np.ndarray      # (N,) ticks of repair remaining
    slow: np.ndarray           # (N,) straggler capacity multiplier
    slow_left: np.ndarray      # (N,) ticks of degradation remaining
    retry_pool: float          # work dropped from failed nodes, re-enqueued
    notice_left: np.ndarray    # (N,) spot-preemption notice ticks; -1 = none


def init_state(n_nodes: int, replicas: int, delay: int) -> ClusterState:
    return ClusterState(
        queue=np.zeros(n_nodes, np.float32),
        active=np.full(n_nodes, replicas, np.int32),
        pending=np.zeros((n_nodes, delay), np.int32),
        up=np.ones(n_nodes, np.float32),
        down_left=np.zeros(n_nodes, np.int32),
        slow=np.ones(n_nodes, np.float32),
        slow_left=np.zeros(n_nodes, np.int32),
        retry_pool=0.0,
        notice_left=np.full(n_nodes, -1, np.int32),
    )


@functools.partial(jax.jit, static_argnames=())
def _tick_math(queue, capacity, fractions, arrivals, dt, service_time):
    """Pure per-tick queueing update. Returns per-node metrics."""
    arr = arrivals * dt * fractions
    q1 = queue + arr
    served = jnp.minimum(q1, capacity * dt)
    q2 = q1 - served
    util = jnp.where(capacity > 1e-9, served / jnp.maximum(capacity * dt, 1e-9),
                     0.0)
    # delay a marginal arrival faces: residual queue / capacity + service
    resp = jnp.where(capacity > 1e-9, q2 / jnp.maximum(capacity, 1e-9),
                     10.0) + service_time
    # arrival-weighted mean response
    w = jnp.where(jnp.sum(arr) > 1e-9, arr / jnp.maximum(jnp.sum(arr), 1e-9),
                  jnp.ones_like(arr) / arr.shape[0])
    mean_resp = jnp.sum(w * resp)
    overload = jnp.mean(jnp.where(capacity * dt > 1e-9,
                                  jnp.clip(q2 / jnp.maximum(capacity * dt, 1e-9),
                                           0, 1), 1.0))
    return q2, served, util, mean_resp, overload


@dataclasses.dataclass
class ClusterSim:
    cfg: "ClusterConfig"
    unit_capacity: float                  # req/s per replica (from roofline)
    seed: int = 0
    failures: bool = True

    heterogeneous: bool = True
    tiers: Optional[TierSet] = None   # None -> untiered (single class)
    # scripted chaos (duck-typed ``serving.elastic.ChaosSchedule``: any
    # object with ``pop(tick) -> [(kind, node, arg)]``) and the default
    # spot-preemption notice length — the fluid mirror of the elastic
    # frontend's failure matrix
    chaos: Optional[object] = None
    preempt_notice: int = 0

    def __post_init__(self):
        self.rng = np.random.default_rng(self.seed)
        self.state = init_state(self.cfg.num_nodes,
                                max(1, self.cfg.max_replicas_per_node // 2),
                                self.cfg.provisioning_delay)
        self.service_time = 1.0 / self.unit_capacity
        self.tick_count = 0
        # per-tier backlog breakdown (invariant: sums to state.queue). A
        # single-tier set stays untiered: emitting tier_pressure (== plain
        # queue depth) would silently flip the GPSO planner onto the tiered
        # objective — the same guard the elastic backend applies, keeping
        # the two backends' metric key sets identical per tier config.
        self.tier_queue = None
        if self.tiers is not None and len(self.tiers) > 1:
            self.tier_queue = np.zeros((len(self.tiers), self.cfg.num_nodes),
                                       np.float32)
        # mixed hardware generations: persistent per-node speed multipliers
        if self.heterogeneous:
            self.node_speed = self.rng.choice(
                [0.6, 1.0, 1.4], size=self.cfg.num_nodes,
                p=[0.25, 0.5, 0.25]).astype(np.float32)
        else:
            self.node_speed = np.ones(self.cfg.num_nodes, np.float32)
        # preempted-away nodes: down until an explicit recover event (unlike
        # ordinary failures, which self-repair after ~mttr and keep their
        # replicas). Tracked separately so scale_to can refuse to provision
        # onto them without changing the ordinary-failure dynamics.
        self._preempt_down = np.zeros(self.cfg.num_nodes, bool)
        # deterministic straggler overlay (slow@t:nI:xF): multiplies into
        # capacity alongside the stochastic episode state, and survives
        # _advance_failures recomputing state.slow from slow_left each tick
        self._forced_slow = np.ones(self.cfg.num_nodes, np.float32)
        self._lease: Optional[tuple] = None   # (min, max) total replicas

    # ------------------------------------------------------------ dynamics
    def capacity(self) -> np.ndarray:
        s = self.state
        return (s.active * self.unit_capacity * self.node_speed * s.up *
                s.slow * self._forced_slow).astype(np.float32)

    def set_lease(self, min_replicas: int, max_replicas: int) -> None:
        """Bound future ``scale_to`` calls to a capacity lease on the cell's
        TOTAL in-flight replica count (fluid mirror of
        ``ElasticClusterFrontend.set_lease``)."""
        lo, hi = int(min_replicas), int(max_replicas)
        if lo < 0 or hi < lo:
            raise ValueError(f"bad lease [{min_replicas}, {max_replicas}]")
        self._lease = (lo, hi)

    def clear_lease(self) -> None:
        self._lease = None

    @property
    def lease(self):
        return self._lease

    def scale_to(self, target: np.ndarray):
        """Apply an autoscaler plan: scale-ups go through the provisioning
        pipeline (cold start); scale-downs are immediate. A capacity lease
        (``set_lease``) clamps the cell total first."""
        s = self.state
        target = np.asarray(target, np.int32)
        in_flight = s.active + s.pending.sum(axis=1)
        # never provision onto a node under a preemption notice or already
        # preempted away (ordinary failed nodes still accept adds: they
        # come back with their replicas after repair)
        doomed = (s.notice_left >= 0) | self._preempt_down
        if self._lease is not None:
            lo, hi = self._lease
            # adds on doomed nodes are suppressed below, so their effective
            # target never exceeds what they already hold
            eff = np.where(doomed, np.minimum(target, in_flight),
                           target).astype(np.int64)
            total = int(eff.sum())
            sched = np.nonzero(~doomed)[0]
            while total > hi and sched.size:
                cand = [i for i in sched if eff[i] > 0]
                if not cand:
                    break
                i = max(cand, key=lambda j: (eff[j], -j))
                eff[i] -= 1
                total -= 1
            while total < lo and sched.size:
                cand = [i for i in sched
                        if eff[i] < self.cfg.max_replicas_per_node]
                if not cand:
                    break
                i = min(cand, key=lambda j: (eff[j], j))
                eff[i] += 1
                total += 1
            target = eff.astype(np.int32)
        add = np.maximum(target - in_flight, 0)
        add = np.where(doomed, 0, add)
        if add.any():
            s.pending[:, -1] += add
        down = np.maximum(in_flight - target, 0)
        if down.any():
            # remove pending first, then active
            for i in np.nonzero(down)[0]:
                rem = down[i]
                for d in range(s.pending.shape[1] - 1, -1, -1):
                    take = min(rem, s.pending[i, d])
                    s.pending[i, d] -= take
                    rem -= take
                s.active[i] = max(s.active[i] - rem, 0)

    def _advance_provisioning(self):
        s = self.state
        s.active = s.active + s.pending[:, 0]
        s.pending = np.roll(s.pending, -1, axis=1)
        s.pending[:, -1] = 0

    # ------------------------------------------------------------- chaos
    def _check_node(self, i: int):
        if not isinstance(i, (int, np.integer)) \
                or not 0 <= i < self.cfg.num_nodes:
            raise ValueError(
                f"node index {i!r} out of range for {self.cfg.num_nodes} "
                "nodes")

    def preempt_node(self, i: int, notice: Optional[int] = None):
        """Spot-preemption notice on node ``i`` (the fluid mirror of
        ``ElasticClusterFrontend.preempt_node``): spawns cancel now, the
        node keeps draining its queue for the notice window, then whatever
        backlog remains dumps into the retry pool and the node goes down
        until an explicit ``recover_node``."""
        self._check_node(i)
        s = self.state
        if s.up[i] < 0.5:
            raise ValueError(f"node n{i} is already down")
        if s.notice_left[i] >= 0:
            raise ValueError(f"node n{i} already has a preemption notice")
        left = self.preempt_notice if notice is None else int(notice)
        s.pending[i, :] = 0
        if left <= 0:
            self._preempt_finalize(i)
        else:
            s.notice_left[i] = left

    def recover_node(self, i: int):
        self._check_node(i)
        s = self.state
        if not self._preempt_down[i]:
            raise ValueError(f"node n{i} is not preempted away")
        self._preempt_down[i] = False
        s.up[i] = 1.0
        s.down_left[i] = 0

    def slow_node(self, i: int, factor: int):
        """Deterministic straggler injection (``slow@t:nI:xF``), fluid
        mirror of ``ElasticClusterFrontend.slow_node``: node ``i``'s
        capacity multiplies by 1/``factor`` until cleared with
        ``factor == 1``. Lives in a separate overlay so the stochastic
        straggler episodes (``straggler_prob``) keep their own dynamics."""
        self._check_node(i)
        if factor is None or not isinstance(factor, (int, np.integer)):
            raise ValueError(
                f"slow factor must be an int >= 1, got {factor!r}")
        if factor < 1:
            raise ValueError(f"slow factor must be >= 1, got {factor}")
        if self._preempt_down[i]:
            raise ValueError(f"node n{i} is down (preempted); nothing to slow")
        self._forced_slow[i] = 1.0 / int(factor)

    def _preempt_finalize(self, i: int):
        s = self.state
        s.retry_pool += float(s.queue[i])
        s.queue[i] = 0.0
        if self.tier_queue is not None:
            self.tier_queue[:, i] = 0.0
        s.active[i] = 0
        s.pending[i, :] = 0
        s.up[i] = 0.0
        s.down_left[i] = 2**30       # no self-repair: recovery is scripted
        s.notice_left[i] = -1
        self._preempt_down[i] = True

    def blackout(self) -> float:
        """Cell blackout, fluid mirror of the elastic frontend's evacuation
        hook: every node preempts immediately (notices superseded, spawns
        cancelled) and the evacuated backlog mass — which lands in the
        retry pool — is drained out and returned for the routing plane to
        re-inject into sibling cells. Remembers the replica profile for
        ``restore``."""
        s = self.state
        self._blackout_profile = (s.active + s.pending.sum(axis=1)).copy()
        for i in range(self.cfg.num_nodes):
            if self._preempt_down[i]:
                continue
            s.notice_left[i] = -1
            s.pending[i, :] = 0
            self._preempt_finalize(i)
        work, s.retry_pool = float(s.retry_pool), 0.0
        return work

    def restore(self) -> None:
        """Recover every preempted-away node and re-target the pre-blackout
        replica profile through the provisioning pipeline (cold start)."""
        for i in range(self.cfg.num_nodes):
            if self._preempt_down[i]:
                self.recover_node(i)
        prof = getattr(self, "_blackout_profile", None)
        if prof is not None:
            self.scale_to(prof)
            self._blackout_profile = None

    def _advance_chaos(self):
        if self.chaos is not None:
            for kind, i, arg in self.chaos.pop(self.tick_count + 1):
                if kind not in ("preempt", "fail", "recover", "slow"):
                    continue     # cell/plane-kind events belong to the router
                if kind == "preempt":
                    self.preempt_node(i, notice=arg)
                elif kind == "recover":
                    self.recover_node(i)
                elif kind == "slow":
                    self.slow_node(i, arg)
                else:                 # "fail": whole node, ordinary repair
                    self._check_node(i)
                    s = self.state
                    if s.up[i] < 0.5:
                        raise ValueError(f"node n{i} is already down")
                    s.up[i] = 0.0
                    s.down_left[i] = self.rng.geometric(
                        1.0 / self.cfg.node_mttr)
                    s.retry_pool += float(s.queue[i])
                    s.queue[i] = 0.0
                    if self.tier_queue is not None:
                        self.tier_queue[:, i] = 0.0
        s = self.state
        for i in np.nonzero(s.notice_left >= 0)[0]:
            if s.notice_left[i] == 0:
                self._preempt_finalize(i)
            else:
                s.notice_left[i] -= 1

    def preempt_risk(self) -> np.ndarray:
        """Per-node spot-churn signal for the planner: 1 under notice or
        preempted away, else 0 (all zeros when chaos never fired)."""
        s = self.state
        return ((s.notice_left >= 0) | self._preempt_down).astype(np.float32)

    def _advance_failures(self):
        if not self.failures:
            return
        s, cfg = self.state, self.cfg
        n = cfg.num_nodes
        # recoveries
        s.down_left = np.maximum(s.down_left - 1, 0)
        recovered = (s.up < 0.5) & (s.down_left == 0)
        s.up[recovered] = 1.0
        # new failures
        fail = (self.rng.random(n) < 1.0 / cfg.node_mtbf) & (s.up > 0.5)
        if fail.any():
            s.up[fail] = 0.0
            s.down_left[fail] = self.rng.geometric(1.0 / cfg.node_mttr,
                                                   fail.sum())
            # failed nodes drop their queue into the retry pool (tier
            # identity dissolves there; re-arrivals re-split by share)
            s.retry_pool += float(s.queue[fail].sum())
            s.queue[fail] = 0.0
            if self.tier_queue is not None:
                self.tier_queue[:, fail] = 0.0
        # stragglers: degradation episodes persist for a sampled duration
        # (like failures do). Onset probability is normalized by the mean
        # episode length so the steady-state degraded node fraction stays
        # ~straggler_prob.
        s.slow_left = np.maximum(s.slow_left - 1, 0)
        mean_dur = max(cfg.straggler_mean_ticks, 1.0)
        onset = (self.rng.random(n) < cfg.straggler_prob / mean_dur) & \
            (s.slow_left == 0)
        if onset.any():
            s.slow_left[onset] = self.rng.geometric(1.0 / mean_dur,
                                                    onset.sum())
        s.slow = np.where(s.slow_left > 0, cfg.straggler_slowdown,
                          1.0).astype(np.float32)

    # ---------------------------------------------------------------- tick
    def tick(self, arrivals: float, fractions: np.ndarray) -> dict:
        """One dt step. fractions: (N,) simplex allocation from a balancer."""
        cfg = self.cfg
        self._advance_provisioning()
        self._advance_chaos()
        self._advance_failures()
        s = self.state
        arrivals = float(arrivals) + s.retry_pool / max(cfg.tick_seconds, 1e-9)
        s.retry_pool = 0.0
        cap = self.capacity()
        q2, served, util, mean_resp, overload = _tick_math(
            jnp.asarray(s.queue), jnp.asarray(cap), jnp.asarray(fractions),
            jnp.float32(arrivals), jnp.float32(cfg.tick_seconds),
            jnp.float32(self.service_time))
        s.queue = np.array(q2)  # np.array (copy): np.asarray of a jax array
        self.tick_count += 1    # is read-only and failure events mutate it
        util_np = np.asarray(util)
        m = {
            "utilization": util_np,
            "mean_utilization": float(np.mean(util_np[s.up > 0.5])
                                      if (s.up > 0.5).any() else 0.0),
            "response_time": float(mean_resp),
            "served": float(np.asarray(served).sum()),
            "overload": float(overload),
            "capacity": cap,
            "queue": s.queue.copy(),
            "up": s.up.copy(),
            "active_replicas": s.active.copy(),
            "replica_ticks": int(s.active.sum()),
            # multi-cell view (PR 8): one sim is one healthy cell — zeros
            # here; the routing plane overrides with real per-cell values
            "cell_staleness": np.zeros(1, np.float32),
            "cell_risk": np.zeros(1, np.float32),
            "shed": 0.0,
            # hierarchical-control view (PR 10): zeros for the same reason
            "plane_staleness": 0.0,
            "lease_util": np.zeros(1, np.float32),
            "local_actions": 0.0,
        }
        if self.tier_queue is not None:
            m.update(self._tier_tick(
                arrivals * cfg.tick_seconds * np.asarray(fractions,
                                                         np.float64),
                np.asarray(served, np.float64), cap))
        return m

    def _tier_tick(self, node_arrivals: np.ndarray, served: np.ndarray,
                   cap: np.ndarray) -> dict:
        """Per-tier bookkeeping around the aggregate update: split this
        tick's arrivals by tier share, drain each node's served mass through
        the tiers in priority order (premium first), and emit the same
        per-tier metric keys the elastic backend computes. The aggregate
        queue is untouched — Σ_t tier_queue == state.queue stays invariant
        up to float rounding."""
        tiers = self.tiers
        tq = self.tier_queue
        tq += tiers.shares[:, None] * node_arrivals[None, :]
        remaining = served.copy()
        for t in tiers.priority:              # premium drains first
            take = np.minimum(tq[t], remaining)
            tq[t] -= take
            remaining -= take
        np.clip(tq, 0.0, None, out=tq)
        # per-tier response estimate: a tier's marginal request waits behind
        # all backlog at its priority or higher, then one service time
        resp = {}
        viol = {}
        ahead = np.zeros(tq.shape[1], np.float64)
        up = self.state.up > 0.5
        for t in tiers.priority:
            ahead += tq[t]
            per_node = np.where(cap > 1e-9, ahead / np.maximum(cap, 1e-9),
                                10.0) + self.service_time
            spec = tiers.specs[t]
            r = float(np.mean(per_node[up]) if up.any() else 10.0)
            resp[spec.name] = r
            if np.isfinite(spec.ttft_target):
                viol[spec.name] = float(np.clip(
                    r / spec.ttft_target - 1.0, 0.0, 1.0))
        return {
            "tier_queue": tq.copy(),
            "tier_pressure": tiers.pressure(tq),
            "tier_response": resp,
            "tier_slo_cost": tiers.slo_cost(viol),
        }

    # ------------------------------------------------------- observations
    def observation(self, forecast: np.ndarray) -> np.ndarray:
        """Paper Eq.1-3 state: per-node [load, utilization-proxy, capacity,
        up] ++ forecast horizon (broadcast). (N, 4+T)."""
        s = self.state
        cap = self.capacity()
        total_cap = max(cap.sum(), 1e-9)
        load = s.queue / max(s.queue.sum(), 1.0)
        util_proxy = np.minimum(s.queue / np.maximum(cap, 1e-9), 4.0) / 4.0
        capn = cap / total_cap
        f = np.broadcast_to(forecast[None, :],
                            (self.cfg.num_nodes, forecast.shape[0]))
        obs = np.concatenate([load[:, None], util_proxy[:, None],
                              capn[:, None], s.up[:, None], f], axis=1)
        return obs.astype(np.float32)
