"""End-to-end serving driver: batched requests through replicated engines
behind the paper's control plane.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-8b \
        --replicas 3 --requests 48 --policy lc

Runs reduced-config model replicas (real forwards on CPU) behind the
ClusterFrontend; reports throughput + TTFT/latency percentiles per policy.
``--policy fractions`` uses capacity-weighted fractions (the shape of the
RL balancer's output; the trained MADRL policy itself is exercised in the
fluid simulator benchmarks, where training is cheap).
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--policy", default="lc",
                    choices=["rr", "lc", "fractions"])
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.data.pipeline import prompt_workload
    from repro.models.model import make_model
    from repro.serving.engine import ClusterFrontend, ReplicaEngine, Request

    cfg = get_config(args.arch).reduced()
    model = make_model(cfg, tp=1)
    params = model.init(jax.random.PRNGKey(args.seed), jnp.float32)
    print(f"[serve] arch={cfg.name} replicas={args.replicas} "
          f"policy={args.policy}")

    replicas = [ReplicaEngine(model, params, max_batch=args.max_batch,
                              max_seq=args.max_seq, rid=i)
                for i in range(args.replicas)]
    caps = np.ones(args.replicas)

    def fractions_fn(fe):
        loads = np.asarray([r.load for r in fe.replicas], np.float64)
        w = caps / (1.0 + loads)
        return w / w.sum()

    fe = ClusterFrontend(replicas, policy=args.policy,
                         fractions_fn=fractions_fn, seed=args.seed)
    work = prompt_workload(cfg.vocab_size, args.requests, seed=args.seed)
    t0 = time.time()
    for w in work:
        fe.submit(Request(w["rid"], w["prompt"],
                          max_new_tokens=w["max_new_tokens"]))
    fe.run_until_drained()
    wall = time.time() - t0
    done = fe.finished
    toks = sum(len(r.output) for r in done)
    ttft = np.array([r.first_token_time for r in done])
    lat = np.array([r.finish_time for r in done])
    print(f"[serve] {len(done)}/{args.requests} finished, {toks} tokens in "
          f"{wall:.2f}s ({toks/wall:.1f} tok/s)")
    print(f"[serve] TTFT p50={np.percentile(ttft,50):.1f} "
          f"p95={np.percentile(ttft,95):.1f} engine-steps; "
          f"finish p50={np.percentile(lat,50):.1f} "
          f"p95={np.percentile(lat,95):.1f}")
    steps = sum(r.steps for r in replicas)
    print(f"[serve] decode steps across replicas: {steps} "
          f"(batch efficiency {toks/max(steps*args.max_batch,1):.2f})")


if __name__ == "__main__":
    main()
