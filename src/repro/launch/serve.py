"""End-to-end serving driver: the paper's full control plane over an elastic
request-level cluster of real model replicas.

Two modes:

  * **Unified control loop** (the paper's system, §3) — when ``--autoscale``
    is set or ``--policy ours``: builds an ``ElasticClusterFrontend`` (N
    nodes of heterogeneous ``ReplicaEngine``s with cold-start provisioning,
    graceful drain and failure injection) and drives it with the same
    ``ControlPlane`` (forecast -> balance -> scale) that runs the fluid
    simulator, over a bursty synthetic trace:

        PYTHONPATH=src python -m repro.launch.serve --policy ours \
            --autoscale gpso --ticks 60

    ``--policy ours`` uses the MADRL (GCN+DDPG) balancer acting greedily
    (training it belongs to the cheap fluid simulator — see
    ``examples/autoscale_sim.py``); ``--autoscale gpso`` runs the Eq.9-11
    GPSO planner against the live replica counts.

  * **Legacy drain mode** — ``--policy rr|lc|fractions`` with
    ``--autoscale none``: a fixed batch of requests through the static
    ``ClusterFrontend``, reporting throughput + TTFT/latency percentiles.

Both report prefill retrace counts: prompts are padded to power-of-two
buckets so the engine compiles O(log max_seq) prefill variants total.

Admission flags: ``--chunk-len N`` streams prompts longer than N in fixed
chunks interleaved with decode (bounded TTFT/TBT tail); fleet mode batches
all same-bucket admits across replicas into one jitted prefill per distinct
bucket shape per tick (``--no-fleet-prefill`` restores per-replica
admission as the A/B oracle); ``--tiers premium:0.2:w5:4,standard:0.5:w2,
batch:0.3:w1`` serves an SLO-tiered mix (share : weighted-deficit weight :
optional TTFT target) through tiered replica queues, per-tier metrics and
the tier-weighted Eq.5/Eq.9 objectives — the default single tier is
bit-identical to the untiered scheduler.

Tick-overlap flags: the serve tick is asynchronous by default — fleet
dispatches return device futures reconciled at ONE host sync per tick, so
host bookkeeping and the control plane overlap the device's decode
(``--no-async`` restores the eager blocking tick as the bit-exact parity
oracle); ``--decode-block K`` fuses K decode micro-steps into one dispatch
and one sync on ticks that admit nothing (saturated decode pays 1/K syncs
per tick, trading up to K-1 ticks of admission lag under a full slab);
``--attn-backend pallas`` decodes attention through the flash-decode
kernel (interpret mode off-TPU) instead of the dense einsum.

Multi-cell flags: ``--cells N`` federates N elastic cells behind the
fault-tolerant routing plane (``control.cells.MultiCellBackend``) — the
same control plane drives the federation with cells as its "nodes";
``--cell-chaos 'cell_down@15:c0,partition@10:c1:k6,cell_up@30:c0'``
scripts blackouts and control-plane partitions (node-level ``--chaos``
lands on cell 0); ``--shed-threshold X`` arms total-overload admission
shedding (lowest tiers first, explicit ``shed`` ledger terminal);
``--static-split`` is the A/B arm that routes a fixed uniform split.

Hierarchy flags (PR 10): ``--hierarchy`` splits control in two —
per-cell ``CellController`` autoscalers act every tick inside capacity
leases that a ``GlobalPlanner`` re-grants every
``--plan-interval-global`` ticks with ``--lease-slack`` headroom, all
under a crash-tolerant ``PlaneSupervisor`` (the ``ControlPlane`` runs
forecast+balance only; scaling authority belongs to the leases).
``--cell-chaos 'plane_down@10:k6'`` crashes the GLOBAL plane: the
centralized loop freezes (no planning, no balancing) while the
hierarchical loop keeps autoscaling locally inside the last leases —
the A/B that ``benchmarks/serve_bench.py`` measures as scale-reaction
latency. ``slow@t:nI:xF`` in ``--chaos`` pins a deterministic straggler
(node I at 1/F speed until ``x1`` clears it).

Device scaling: ``--devices N`` shards every fleet group's slab over an
N-way ``('fleet',)`` mesh so F replicas decode on N devices in parallel
(same one-logical-dispatch / one-sync tick; bit-identical streams). On a
CPU box this exposes N *virtual* devices by setting
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` — which must happen
BEFORE the first jax import, which is why this module defers ``import jax``
into ``main()`` and errors clearly if jax already initialized.
``--mesh '4:fleet'`` passes an explicit mesh spec instead (a real
multi-chip mesh on GPU/TPU needs no XLA_FLAGS trick).
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def _percentiles(xs, qs=(50, 95)):
    xs = np.asarray(xs, np.float64)
    return [float(np.percentile(xs, q)) for q in qs]


def _parse_timeout(spec: str):
    """'8' -> scalar ticks; 'premium:4,batch:16' -> per-tier dict."""
    try:
        return float(spec)
    except ValueError:
        out = {}
        for part in filter(None, (p.strip() for p in spec.split(","))):
            name, _, val = part.partition(":")
            if not val:
                raise ValueError(f"bad timeout entry {part!r}")
            out[name] = float(val)
        return out


def run_control_loop(args, cfg, model, params, mesh=None):
    from repro.configs.paper_cluster import ClusterConfig
    from repro.control import (CellController, CellRouter, ControlPlane,
                               GlobalPlanner, MultiCellBackend,
                               PlaneSupervisor)
    from repro.core import balancer as bal
    from repro.serving import (ChaosSchedule, ElasticClusterFrontend,
                               ReplicaEngine, Request)
    from repro.workload import (ClientPool, TraceConfig, generate_trace,
                                parse_tiers)

    tiers = parse_tiers(args.tiers)
    multi = args.cells > 1
    if args.hierarchy and not multi:
        raise SystemExit("--hierarchy needs --cells > 1 (the two-level "
                         "split is over a federation of cells)")
    # multi-cell: the plane sees CELLS as nodes; a scale target is the
    # cell's total replica budget, so the per-"node" cap scales with the
    # cell's own node count
    ccfg = ClusterConfig(
        num_nodes=args.cells if multi else args.nodes,
        horizon=8, forecast_window=16,
        provisioning_delay=args.provision_delay,
        max_replicas_per_node=(args.nodes * args.max_replicas
                               if multi else args.max_replicas),
        min_replicas_per_node=1,      # never plan a node to zero capacity
        scale_interval=5, cooldown=8, straggler_prob=0.0, node_mtbf=1e12)
    rng = np.random.default_rng(args.seed)

    def make_replica(rid: int) -> ReplicaEngine:
        # heterogeneous pool: mixed hardware generations + batch budgets
        speed = float(rng.choice([0.7, 1.0, 1.4]))
        mb = int(rng.choice([max(2, args.max_batch // 2), args.max_batch]))
        return ReplicaEngine(model, params, max_batch=mb,
                             max_seq=args.max_seq, rid=rid, speed=speed,
                             chunk_len=args.chunk_len, tiers=tiers,
                             attn_backend=args.attn_backend)

    def request_factory(rid: int, tick: int) -> Request:
        plen = int(rng.integers(2, 12))
        req = Request(rid, rng.integers(1, cfg.vocab_size, plen).tolist(),
                      max_new_tokens=int(rng.integers(4, 12)))
        if len(tiers) > 1:     # single-tier: no extra rng draw, so default
            req.tier = tiers.sample(rng)      # invocations stay bit-exact
        return req

    est_tokens = 8.0
    chaos = ChaosSchedule.parse(args.chaos) if args.chaos else None

    def build_cell(cell_chaos):
        return ElasticClusterFrontend(
            make_replica, args.nodes, initial_replicas=args.replicas,
            provisioning_delay=args.provision_delay,
            max_replicas_per_node=args.max_replicas,
            failure_rate=args.failure_rate, request_factory=request_factory,
            seed=args.seed, est_tokens=est_tokens,
            fleet_batch=not args.no_fleet,
            fleet_prefill=not args.no_fleet_prefill,
            async_tick=not args.no_async, decode_block=args.decode_block,
            tiers=tiers, mesh=mesh,
            preempt_notice=args.preempt_notice, chaos=cell_chaos)

    if multi:
        # node-level --chaos lands on cell 0 (the scripted victim); cell
        # events drive the router
        cell_chaos = ChaosSchedule.parse(args.cell_chaos) \
            if args.cell_chaos else None
        router = CellRouter(
            args.cells, tiers=tiers,
            shed_threshold=args.shed_threshold or None,
            adaptive=not args.static_split)
        fe = MultiCellBackend(
            [build_cell(chaos if c == 0 else None)
             for c in range(args.cells)],
            tiers=tiers, router=router, chaos=cell_chaos,
            request_factory=request_factory, seed=args.seed)
    else:
        fe = build_cell(chaos)
    pool = None
    if args.clients > 0:
        # closed loop: the pool replaces the open-loop arrival trace (the
        # frontend's request_factory goes unused at arrival_rate 0)
        pool = ClientPool(
            fe, args.clients, request_factory=request_factory,
            think_time=args.think_time,
            timeout=_parse_timeout(args.timeout),
            max_retries=args.retries, spawn_rate=args.spawn_rate,
            seed=args.seed + 1)

    balancer = {"ours": "rl", "rr": "rr", "lc": "lc", "wrr": "wrr",
                "fractions": "wrr"}[args.policy]
    rl = None
    if balancer == "rl":
        rl = bal.RLBalancer(ccfg, 4 + ccfg.horizon, seed=args.seed)
    unit_cap = args.max_batch / est_tokens     # replica requests/tick
    trace = generate_trace(TraceConfig(ticks=args.ticks, base_rate=args.rate,
                                       diurnal_period=max(args.ticks, 2)),
                           seed=args.seed)
    arrivals = trace["arrivals"]
    # hierarchy mode: the ControlPlane keeps forecast + balance, but
    # scaling authority moves to the per-cell controllers under leases
    plane = ControlPlane(ccfg, fe, balancer=balancer,
                         scaler="none" if args.hierarchy
                         else args.autoscale,
                         unit_capacity=unit_cap,
                         rl=rl, forecast_scale=float(arrivals.mean()),
                         seed=args.seed,
                         init_arrival=float(arrivals[:5].mean()))
    sup = None
    if args.hierarchy:
        cell_cap = args.nodes * args.max_replicas
        planner = GlobalPlanner(args.cells,
                                total_budget=args.cells * cell_cap,
                                max_per_cell=cell_cap,
                                lease_slack=args.lease_slack)
        controllers = [CellController(fe, c) for c in range(args.cells)]
        sup = PlaneSupervisor(fe, planner, controllers, plane=plane,
                              plan_interval=args.plan_interval_global)

    print(f"[serve] unified loop: balancer={balancer} "
          f"autoscale={args.autoscale} nodes={args.nodes} "
          f"ticks={args.ticks}"
          + (f" cells={args.cells}" if multi else "")
          + (" hierarchy=on"
             f" plan-interval={args.plan_interval_global}" if sup else "")
          + (f" clients={args.clients}" if pool else "")
          + (f" chaos={args.chaos!r}" if chaos else "")
          + (f" cell-chaos={args.cell_chaos!r}"
             if multi and args.cell_chaos else ""))
    t0 = time.time()
    for t in range(args.ticks):
        if pool is not None:
            pool.tick()                     # closed loop drives arrivals
        rate = 0.0 if pool is not None else float(arrivals[t])
        if sup is not None:
            m = sup.step(rate)
        elif getattr(fe, "plane_alive", True):
            m = plane.step(rate)
        else:
            # centralized baseline under a plane outage: the one brain is
            # gone — tick the data plane, no planning/balancing/scaling
            m = fe.tick(rate)
        if t % 10 == 0 or t == args.ticks - 1:
            print(f"[serve] t={t:3d} arrivals={arrivals[t]:5.1f}/tick "
                  f"replicas={m['active_replicas'].tolist()} "
                  f"queue={m['queue'].astype(int).tolist()} "
                  f"util={m['mean_utilization']:.2f} "
                  f"resp={m['response_time']:.1f}t "
                  f"goodput={m['goodput']:.0f}")
    if pool is not None:
        pool.quiesce()
    fe.run_until_drained()
    if pool is not None:
        pool.finalize()
    wall = time.time() - t0

    done = fe.finished
    toks = sum(len(r.output) for r in done)
    traces = fe.prefill_retraces()
    print(f"[serve] {len(done)} requests, {toks} tokens in {wall:.2f}s "
          f"({toks / max(wall, 1e-9):.1f} tok/s); "
          f"replicas spawned={fe.replicas_spawned} "
          f"failed={fe.failed_replicas} "
          f"replica-ticks={fe.replica_ticks} "
          f"decode-dispatches={fe.decode_dispatches()} "
          f"prefill-dispatches={fe.prefill_dispatches()} "
          f"syncs={fe.sync_count()} "
          f"sync-wait={fe.sync_wait_s():.2f}s")
    # queue-culled deadline expiries land in fe.finished with NO first
    # token (ledger resolves them timed-out) — latency stats are over
    # requests that were actually served
    served = [r for r in done if r.first_token_time is not None]
    if served:
        ttft = _percentiles([r.first_token_time - r.arrival
                             for r in served])
        lat = _percentiles([r.finish_time - r.arrival for r in served])
        print(f"[serve] TTFT p50={ttft[0]:.1f} p95={ttft[1]:.1f} ticks; "
              f"latency p50={lat[0]:.1f} p95={lat[1]:.1f} ticks; "
              f"prefill retraces={traces}")
        if len(tiers) > 1:
            for spec in tiers.specs:
                sub = [r for r in served if tiers.index(r.tier)
                       == tiers.index(spec.name)]
                if not sub:
                    continue
                tt = _percentiles([r.first_token_time - r.arrival
                                   for r in sub])
                att = ""
                if np.isfinite(spec.ttft_target):
                    ok = np.mean([r.first_token_time - r.arrival
                                  <= spec.ttft_target for r in sub])
                    att = f" SLO({spec.ttft_target:g}t)={ok:.0%}"
                print(f"[serve]   tier {spec.name:<10} n={len(sub):4d} "
                      f"TTFT p50={tt[0]:.1f} p95={tt[1]:.1f}{att}")

    # ------------------------------------------------ robustness report
    led = fe.ledger
    states = led.balance()
    print(f"[serve] ledger: submitted={led.submitted} "
          f"finished={states['finished']} timed_out={states['timed_out']} "
          f"abandoned={states['abandoned']} rejected={states['rejected']} "
          f"shed={states['shed']} "
          f"retries={led.retries} duplicates={led.duplicates} "
          f"wasted={led.wasted} double_served={led.double_served} "
          f"balanced={led.balanced()}")
    for tname, row in sorted(led.per_tier.items()):
        total = max(row["finished"] + row["timed_out"]
                    + row["abandoned"] + row["rejected"], 1)
        print(f"[serve]   ledger tier {tname:<10} "
              f"goodput={row['finished']}/{total} "
              f"({row['finished'] / total:.0%}) "
              f"timed_out={row['timed_out']} abandoned={row['abandoned']} "
              f"rejected={row['rejected']} shed={row['shed']} "
              f"retries={row['retries']}")
    if fe.preempted_nodes or fe.preempted_replicas:
        print(f"[serve] preemptions: nodes={fe.preempted_nodes} "
              f"replicas={fe.preempted_replicas}")
    if multi:
        # degraded-mode report: what the routing plane absorbed
        stale = fe.cell_staleness().astype(int).tolist()
        print(f"[serve] cells: downs={fe.cell_downs} "
              f"evacuated={fe.evacuated_total} shed={fe.shed_total} "
              f"quarantine-ticks={fe.quarantine_ticks} "
              f"parked={len(fe.pending)} staleness={stale} "
              f"weights={np.round(fe._weights, 3).tolist()}")
        if fe.plane_outages:
            print(f"[serve] plane: outages={fe.plane_outages} "
                  f"dark-ticks={fe.plane_outage_ticks} "
                  f"local-actions={fe.local_actions_total}")
        if sup is not None:
            hs = sup.summary()
            print(f"[serve] hierarchy: plans={hs['plans']} "
                  f"local-actions={hs['local_actions']} "
                  f"(up={hs['local_up_actions']}) "
                  f"outage-steps={hs['outage_steps']} "
                  f"restores={hs['restores']} "
                  f"leases={hs['leases']}")
    if pool is not None:
        s = pool.summary()
        lm = s["latency_mean"]
        lp = s["latency_p95"]
        print(f"[serve] clients: n={s['clients']} issued={s['issued']} "
              f"ok={s['ok']} timed_out={s['timed_out']} "
              f"retries={s['retries']} abandoned={s['abandoned']} "
              f"rejected={s['rejected']} shed={s['shed']}"
              + (f" e2e mean={lm:.1f}t p95={lp:.1f}t"
                 if lm is not None else ""))
        for tname, row in sorted(s["per_tier"].items()):
            n_rids = max(row["ok"] + row["abandoned"], 1)
            print(f"[serve]   clients tier {tname:<10} "
                  f"goodput={row['ok']}/{n_rids} "
                  f"({row['ok'] / n_rids:.0%}) "
                  f"retries={row['retries']} abandoned={row['abandoned']}")


def run_drain_mode(args, cfg, model, params):
    from repro.data.pipeline import prompt_workload
    from repro.serving.engine import (ClusterFrontend, ReplicaEngine,
                                      Request, total_prefill_traces)

    if args.no_async or args.decode_block > 1:
        # the static ClusterFrontend always runs the eager blocking tick;
        # don't let an A/B arm silently not differ
        print("[serve] note: --no-async/--decode-block apply to the "
              "control-loop mode only; drain mode always ticks eagerly")

    replicas = [ReplicaEngine(model, params, max_batch=args.max_batch,
                              max_seq=args.max_seq, rid=i,
                              chunk_len=args.chunk_len,
                              attn_backend=args.attn_backend)
                for i in range(args.replicas)]
    caps = np.ones(args.replicas)

    def fractions_fn(fe):
        loads = np.asarray([r.load for r in fe.replicas], np.float64)
        w = caps / (1.0 + loads)
        return w / w.sum()

    fe = ClusterFrontend(replicas, policy=args.policy,
                         fractions_fn=fractions_fn, seed=args.seed)
    work = prompt_workload(cfg.vocab_size, args.requests, seed=args.seed)
    t0 = time.time()
    for w in work:
        fe.submit(Request(w["rid"], w["prompt"],
                          max_new_tokens=w["max_new_tokens"]))
    fe.run_until_drained()
    wall = time.time() - t0
    done = fe.finished
    toks = sum(len(r.output) for r in done)
    ttft = np.array([r.first_token_time for r in done])
    lat = np.array([r.finish_time for r in done])
    print(f"[serve] {len(done)}/{args.requests} finished, {toks} tokens in "
          f"{wall:.2f}s ({toks/wall:.1f} tok/s)")
    print(f"[serve] TTFT p50={np.percentile(ttft,50):.1f} "
          f"p95={np.percentile(ttft,95):.1f} engine-steps; "
          f"finish p50={np.percentile(lat,50):.1f} "
          f"p95={np.percentile(lat,95):.1f}")
    steps = sum(r.steps for r in replicas)
    traces = total_prefill_traces(replicas)
    print(f"[serve] decode steps across replicas: {steps} "
          f"(batch efficiency {toks/max(steps*args.max_batch,1):.2f}); "
          f"prefill retraces: {traces}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--policy", default="lc",
                    choices=["rr", "lc", "wrr", "fractions", "ours"])
    ap.add_argument("--autoscale", default=None,
                    choices=["none", "gpso", "ga", "hpa", "rbas", "static"])
    ap.add_argument("--nodes", type=int, default=2)
    ap.add_argument("--replicas", type=int, default=1,
                    help="initial replicas per node (control mode) / total "
                         "replicas (drain mode)")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--ticks", type=int, default=50)
    ap.add_argument("--rate", type=float, default=2.0,
                    help="mean request arrivals per tick (control mode)")
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--max-replicas", type=int, default=4)
    ap.add_argument("--provision-delay", type=int, default=3)
    ap.add_argument("--failure-rate", type=float, default=0.0)
    ap.add_argument("--clients", type=int, default=0,
                    help="closed-loop client count; >0 replaces the "
                         "open-loop arrival trace with a ClientPool")
    ap.add_argument("--think-time", type=float, default=2.0,
                    help="mean client think time between requests (ticks)")
    ap.add_argument("--timeout", default="8",
                    help="per-attempt deadline in ticks: scalar ('8') or "
                         "per-tier dict ('premium:4,batch:16,default:8')")
    ap.add_argument("--retries", type=int, default=3,
                    help="max retries per request before a client abandons")
    ap.add_argument("--spawn-rate", type=float, default=None,
                    help="clients activated per tick (flash-crowd ramp); "
                         "default: all at once")
    ap.add_argument("--preempt-notice", type=int, default=3,
                    help="ticks of drain notice before a preempted node's "
                         "rows are dropped (spot semantics)")
    ap.add_argument("--chaos", default="",
                    help="deterministic fault script, e.g. "
                         "'preempt@12:n0:k3,fail@8:n1:r0,recover@40:n0,"
                         "slow@5:n0:x4' (slow = straggler at 1/F speed "
                         "until 'x1' clears; multi-cell: node events land "
                         "on cell 0)")
    ap.add_argument("--cells", type=int, default=1,
                    help="federate N elastic cells behind the multi-cell "
                         "routing plane (control mode; 1 = single cell, "
                         "bit-identical to the direct frontend)")
    ap.add_argument("--cell-chaos", default="",
                    help="cell-level fault script for the routing plane, "
                         "e.g. 'cell_down@15:c0,partition@10:c1:k6,"
                         "cell_up@30:c0'; 'plane_down@10:k6'/'plane_up@20' "
                         "crash/restart the GLOBAL control plane")
    ap.add_argument("--hierarchy", action="store_true",
                    help="two-level control (needs --cells > 1): per-cell "
                         "reactive autoscalers inside GlobalPlanner "
                         "capacity leases under a crash-tolerant "
                         "PlaneSupervisor; the ControlPlane keeps "
                         "forecast+balance only")
    ap.add_argument("--plan-interval-global", type=int, default=10,
                    help="ticks between GlobalPlanner lease re-plans "
                         "(hierarchy mode)")
    ap.add_argument("--lease-slack", type=float, default=0.5,
                    help="lease headroom fraction above/below the planner "
                         "budget for local controllers to react into "
                         "(hierarchy mode)")
    ap.add_argument("--shed-threshold", type=float, default=0.0,
                    help="total-overload admission shedding: when every "
                         "healthy cell's tier pressure per unit capacity "
                         "exceeds this, shed lowest tiers first (0 = off; "
                         "multi-cell + tiers only)")
    ap.add_argument("--static-split", action="store_true",
                    help="disable adaptive cell routing (fixed uniform "
                         "split ignoring health/staleness/risk; the "
                         "multi-cell A/B baseline)")
    ap.add_argument("--no-fleet", action="store_true",
                    help="disable fleet-batched decode (per-replica jit "
                         "dispatch loop; A/B baseline)")
    ap.add_argument("--no-fleet-prefill", action="store_true",
                    help="disable fleet-batched admission (per-replica "
                         "prefill dispatches; A/B baseline)")
    ap.add_argument("--no-async", action="store_true",
                    help="disable the overlapped async tick (eager blocking "
                         "syncs after every dispatch; bit-exact parity "
                         "oracle)")
    ap.add_argument("--decode-block", type=int, default=1,
                    help="fuse K decode micro-steps into one dispatch+sync "
                         "on ticks that admit nothing (async mode; 1 = one "
                         "step per tick; >1 trades <= K-1 ticks of "
                         "admission lag under a full slab)")
    ap.add_argument("--attn-backend", default="einsum",
                    choices=["einsum", "pallas"],
                    help="decode attention backend: dense einsum reference "
                         "or the Pallas flash-decode kernel (interpret mode "
                         "off-TPU)")
    ap.add_argument("--chunk-len", type=int, default=0,
                    help="chunked-prefill width: prompts longer than this "
                         "admit in fixed-size chunks interleaved with decode "
                         "(0 = single-shot prefill)")
    ap.add_argument("--tiers", default="",
                    help="SLO tier mix 'name:share:wWEIGHT[:ttft],...' e.g. "
                         "'premium:0.2:w5:4,standard:0.5:w2,batch:0.3:w1' — "
                         "share of traffic, weighted-deficit admission "
                         "weight, optional TTFT target in ticks (control "
                         "mode; default: single tier, identical to the "
                         "untiered scheduler)")
    ap.add_argument("--devices", type=int, default=0,
                    help="shard fleet slabs over an N-way ('fleet',) mesh; "
                         "on CPU exposes N virtual devices via XLA_FLAGS "
                         "(must run before jax initializes — this flag "
                         "handles the ordering; 0 = unsharded)")
    ap.add_argument("--mesh", default="",
                    help="explicit serving mesh spec 'SHAPE:AXES' (e.g. "
                         "'4:fleet') over already-visible devices; must "
                         "include a 'fleet' axis. Overrides --devices' "
                         "mesh shape but not its virtual-device setup")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    # device-count setup MUST precede the first jax import: XLA reads
    # --xla_force_host_platform_device_count once at backend init
    # (launch.mesh itself never imports jax at module level)
    from repro.launch.mesh import (make_fleet_mesh, parse_mesh_spec,
                                   set_host_device_count)

    if args.devices > 0:
        set_host_device_count(args.devices)

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.models.model import make_model

    mesh = None
    if args.mesh:
        mesh = parse_mesh_spec(args.mesh)
    elif args.devices > 0:
        mesh = make_fleet_mesh(args.devices)
    if mesh is not None:
        print(f"[serve] mesh: {dict(zip(mesh.axis_names, mesh.shape.values()))}"
              f" over {len(mesh.devices.ravel())} device(s)")

    cfg = get_config(args.arch).reduced()
    model = make_model(cfg, tp=1)
    params = model.init(jax.random.PRNGKey(args.seed), jnp.float32)
    print(f"[serve] arch={cfg.name} policy={args.policy}")

    # --cells/--hierarchy only exist in the control loop: requesting them
    # must not silently fall through to the legacy drain mode
    control_mode = (args.policy == "ours"
                    or (args.autoscale or "none") != "none"
                    or args.cells > 1 or args.hierarchy)
    if control_mode:
        if args.autoscale is None:
            args.autoscale = "gpso" if args.policy == "ours" else "none"
        run_control_loop(args, cfg, model, params, mesh=mesh)
    else:
        if mesh is not None:
            print("[serve] note: --devices/--mesh apply to the control-loop "
                  "mode only; drain mode steps replicas without a fleet slab")
        if args.policy == "wrr":
            args.policy = "fractions"
        run_drain_mode(args, cfg, model, params)


if __name__ == "__main__":
    main()
