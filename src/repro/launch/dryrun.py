import os
os.environ["XLA_FLAGS"] = (os.environ.get("_REPRO_XLA_EXTRA", "") +
                           " --xla_force_host_platform_device_count=512")
# ^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this proves, without hardware:
  * the sharding config is coherent (lower+compile succeeds on the
    production mesh: 16x16 single-pod, 2x16x16 multi-pod),
  * it fits (compiled.memory_analysis() per-device bytes),
  * and it yields the roofline inputs (cost_analysis FLOPs/bytes + HLO
    collective traffic; see benchmarks/roofline.py).

Usage:
  python -m repro.launch.dryrun --arch mistral-nemo-12b --shape train_4k \
      --mesh single --out results/dryrun/cell.json
  python -m repro.launch.dryrun --all --mesh both --jobs 4   # orchestrator
"""
import argparse
import json
import subprocess
import sys
import time


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             opts: dict = None) -> dict:
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec

    from repro.configs import SHAPES, applicable_shapes, get_config
    from repro.distributed.sharding import (ShardPlan, batch_shardings,
                                            collective_bytes, make_shard_fn,
                                            param_shardings,
                                            serve_state_shardings)
    from repro.launch.mesh import make_production_mesh
    from repro.models.model import make_model, make_train_step
    from repro.models.optim import AdamW

    opts = opts or {}
    t0 = time.time()
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape not in applicable_shapes(cfg):
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "skipped": True,
                "reason": "long_500k needs sub-quadratic decode"}

    if opts.get("mesh_spec"):
        from repro.launch.mesh import parse_mesh_spec
        mesh = parse_mesh_spec(opts["mesh_spec"])
    else:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = mesh.size
    tp = mesh.shape["model"]
    mode = "train" if shape.kind == "train" else "serve"
    expert_sharding = opts.get("expert_sharding", "none")
    plan = ShardPlan(mesh, mode, expert_sharding)
    shard_fn = make_shard_fn(plan)
    remat = opts.get("remat", "full" if mode == "train" else "none")
    model = make_model(cfg, tp=tp, remat=remat)
    dtype = jnp.bfloat16

    # microbatching: cap the per-device activation-checkpoint footprint
    # (L x local_tokens/ga x d_model x 2B) at ~2.5 GiB
    dp = (mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
          * mesh.shape.get("expert", 1))
    local_tokens = shape.global_batch // dp * shape.seq_len
    grad_accum = opts.get("grad_accum", 0)
    if not grad_accum:
        ckpt_budget = 2.5 * 2**30
        grad_accum = 1
        while (cfg.num_layers * (local_tokens // grad_accum) * cfg.d_model * 2
               > ckpt_budget
               and shape.global_batch % (grad_accum * 2) == 0
               and shape.global_batch // (grad_accum * 2) >= dp):
            grad_accum *= 2
    # CE chunk: cap the (B_micro_local x chunk x V) f32 logits tile at ~0.5GiB
    local_rows = max(shape.global_batch // dp // grad_accum, 1)
    v_phys = model.dims.vocab
    loss_chunk = 2048
    while local_rows * loss_chunk * v_phys * 4 > 0.5 * 2**30 and \
            loss_chunk > 128:
        loss_chunk //= 2

    params_s = jax.eval_shape(
        lambda k: model.init(k, dtype), jax.random.PRNGKey(0))
    pshard = param_shardings(plan, params_s)
    specs = model.input_specs(shape)

    if shape.kind == "train":
        big = cfg.param_count() > 1e11
        moment_dtype = jnp.bfloat16 if big else jnp.float32
        accum_dtype = jnp.bfloat16 if opts.get("accum", "") == "bf16" \
            else jnp.float32
        opt = AdamW(lr=3e-4, moment_dtype=moment_dtype)
        opt_s = jax.eval_shape(opt.init, params_s)
        oshard = {
            "mu": param_shardings(plan, params_s),
            "nu": param_shardings(plan, params_s),
            "step": NamedSharding(mesh, PartitionSpec()),
        }
        bshard = batch_shardings(plan, specs)
        step_fn = make_train_step(model, opt, shard_fn=shard_fn,
                                  grad_accum=grad_accum,
                                  loss_chunk=loss_chunk,
                                  accum_dtype=accum_dtype)
        jitted = jax.jit(step_fn,
                         in_shardings=(pshard, oshard, bshard),
                         out_shardings=(pshard, oshard, None),
                         donate_argnums=(0, 1))
        lowered = jitted.lower(params_s, opt_s, specs)
    elif shape.kind == "prefill":
        bshard = batch_shardings(plan, specs)

        def prefill_fn(params, batch):
            return model.prefill(params, batch, cache_len=shape.seq_len,
                                 shard_fn=shard_fn)

        jitted = jax.jit(prefill_fn, in_shardings=(pshard, bshard))
        lowered = jitted.lower(params_s, specs)
    else:  # decode
        state_s = specs["state"]
        sshard = serve_state_shardings(plan, state_s, cfg)
        tshard = batch_shardings(plan, {"tokens": specs["tokens"]})["tokens"]
        pos_shard = NamedSharding(mesh, PartitionSpec())

        def decode_fn(params, state, tokens, pos):
            return model.decode(params, state, tokens, pos,
                                shard_fn=shard_fn)

        jitted = jax.jit(decode_fn,
                         in_shardings=(pshard, sshard, tshard, pos_shard),
                         out_shardings=(None, sshard),
                         donate_argnums=(1,))
        lowered = jitted.lower(params_s, state_s, specs["tokens"],
                               specs["pos"])

    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    coll = collective_bytes(compiled.as_text())

    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind, "mode": mode,
        "ok": True,
        "n_devices": n_dev, "tp": tp,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "flops_per_device": cost.get("flops", 0.0),
        "bytes_per_device": cost.get("bytes accessed", 0.0),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_hbm_bytes": (mem.argument_size_in_bytes
                               + mem.output_size_in_bytes
                               + mem.temp_size_in_bytes
                               - mem.alias_size_in_bytes),
        },
        "collectives": coll,
        "model": {
            "params": cfg.param_count(),
            "active_params": cfg.active_param_count(),
            "pad_flops_ratio": model.dims.pad_flops_ratio,
        },
        "shape_info": {"seq_len": shape.seq_len,
                       "global_batch": shape.global_batch,
                       "kind": shape.kind},
        "opts": dict(opts, grad_accum=grad_accum, remat=remat,
                     loss_chunk=loss_chunk),
    }
    print(f"[dryrun] {arch} x {shape_name} x {mesh_kind}: OK "
          f"(compile {t_compile:.0f}s, "
          f"peak/device {result['memory']['peak_hbm_bytes']/2**30:.2f} GiB, "
          f"flops/device {result['flops_per_device']:.3g})")
    print(f"[dryrun]   memory_analysis: {mem}")
    print(f"[dryrun]   collectives: { {k: f'{v/2**20:.1f}MiB' for k, v in coll.items()} }")
    return result


def _cells(mesh_kind: str):
    from repro.configs import ARCH_NAMES, applicable_shapes, get_config
    meshes = ["single", "multi"] if mesh_kind == "both" else [mesh_kind]
    for arch in ARCH_NAMES:
        for shape in applicable_shapes(get_config(arch)):
            for m in meshes:
                yield arch, shape.name, m


def orchestrate(args):
    """Run every cell in a subprocess pool; write one JSON per cell."""
    import itertools
    os.makedirs(args.outdir, exist_ok=True)
    cells = list(_cells(args.mesh))
    if args.filter:
        cells = [c for c in cells if args.filter in f"{c[0]}/{c[1]}/{c[2]}"]
    running, results = [], {}
    idx = 0
    while idx < len(cells) or running:
        while idx < len(cells) and len(running) < args.jobs:
            arch, shape, mesh = cells[idx]
            out = os.path.join(args.outdir, f"{arch}__{shape}__{mesh}.json")
            idx += 1
            if args.resume and os.path.exists(out):
                print(f"[orchestrator] skip existing {out}")
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--mesh", mesh,
                   "--out", out]
            if args.expert_sharding != "none":
                cmd += ["--expert-sharding", args.expert_sharding]
            if args.remat:
                cmd += ["--remat", args.remat]
            p = subprocess.Popen(cmd, stdout=subprocess.PIPE,
                                 stderr=subprocess.STDOUT, text=True)
            running.append((p, arch, shape, mesh, out, time.time()))
            print(f"[orchestrator] start {arch} x {shape} x {mesh} "
                  f"({idx}/{len(cells)})")
        time.sleep(2)
        still = []
        for (p, arch, shape, mesh, out, t0) in running:
            if p.poll() is None:
                if time.time() - t0 > args.timeout:
                    p.kill()
                    print(f"[orchestrator] TIMEOUT {arch} x {shape} x {mesh}")
                    with open(out, "w") as f:
                        json.dump({"arch": arch, "shape": shape,
                                   "mesh": mesh, "ok": False,
                                   "error": "timeout"}, f)
                else:
                    still.append((p, arch, shape, mesh, out, t0))
                continue
            tail = (p.stdout.read() or "")[-2000:]
            if p.returncode != 0 and not os.path.exists(out):
                print(f"[orchestrator] FAIL {arch} x {shape} x {mesh}:\n{tail}")
                with open(out, "w") as f:
                    json.dump({"arch": arch, "shape": shape, "mesh": mesh,
                               "ok": False, "error": tail[-1000:]}, f)
            else:
                print(f"[orchestrator] done {arch} x {shape} x {mesh} "
                      f"({time.time()-t0:.0f}s)")
        running = still
    # summary
    n_ok = n_skip = n_fail = 0
    for fn in os.listdir(args.outdir):
        if not fn.endswith(".json"):
            continue
        with open(os.path.join(args.outdir, fn)) as f:
            r = json.load(f)
        if r.get("ok"):
            n_ok += 1
        elif r.get("skipped"):
            n_skip += 1
        else:
            n_fail += 1
    print(f"[orchestrator] summary: {n_ok} ok, {n_skip} skipped, "
          f"{n_fail} failed")
    return n_fail


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--jobs", type=int, default=4)
    ap.add_argument("--outdir", default="results/dryrun")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--filter", default="")
    ap.add_argument("--timeout", type=float, default=1800.0)
    ap.add_argument("--expert-sharding", default="none",
                    choices=["none", "data"])
    ap.add_argument("--remat", default="")
    ap.add_argument("--grad-accum", type=int, default=0)
    ap.add_argument("--accum", default="", choices=["", "bf16"])
    ap.add_argument("--mesh-spec", default="",
                    help="e.g. 2x8x16:data,expert,model (overrides --mesh)")
    args = ap.parse_args()

    if args.all:
        sys.exit(1 if orchestrate(args) else 0)

    opts = {}
    if args.expert_sharding != "none":
        opts["expert_sharding"] = args.expert_sharding
    if args.remat:
        opts["remat"] = args.remat
    if args.grad_accum:
        opts["grad_accum"] = args.grad_accum
    if args.accum:
        opts["accum"] = args.accum
    if args.mesh_spec:
        opts["mesh_spec"] = args.mesh_spec
    try:
        result = run_cell(args.arch, args.shape, args.mesh, opts)
    except Exception as e:  # noqa: BLE001 — recorded as a failed cell
        import traceback
        result = {"arch": args.arch, "shape": args.shape, "mesh": args.mesh,
                  "ok": False, "error": traceback.format_exc()[-2000:]}
        print(f"[dryrun] FAILED: {e}")
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(result, f, indent=2)
    if not result.get("ok") and not result.get("skipped"):
        sys.exit(1)


if __name__ == "__main__":
    main()
