# Launchers: mesh.py (production meshes), dryrun.py (multi-pod lower+compile),
# train.py / serve.py (end-to-end drivers). dryrun must be run as a module
# (python -m repro.launch.dryrun) so its XLA_FLAGS line precedes jax init.
