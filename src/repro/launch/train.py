"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch granite-3-8b \
        --scale 100m --steps 300 --batch 16 --seq 256

Trains a scaled-down variant of the selected architecture on the synthetic
Markov corpus with the full production stack: AdamW + cosine schedule +
clipping, sequence-chunked CE, fault-tolerant checkpointing with auto-resume
(kill it mid-run and relaunch — it continues), and metrics logging. On a real
TPU mesh the same driver runs with ``--mesh data,model`` shardings.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import numpy as np


SCALES = {
    # ~100M-param decoder (whatever the arch family, same budget)
    "100m": dict(num_layers=10, d_model=640, num_heads=10, num_kv_heads=5,
                 head_dim=64, d_ff=2560, vocab_size=32000, max_seq_len=4096),
    "20m": dict(num_layers=6, d_model=320, num_heads=5, num_kv_heads=5,
                head_dim=64, d_ff=1280, vocab_size=8000, max_seq_len=2048),
    "smoke": dict(num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
                  head_dim=32, d_ff=256, vocab_size=512, max_seq_len=512),
}


def scaled_config(arch: str, scale: str):
    from repro.configs import get_config
    cfg = get_config(arch)
    if scale == "full":
        return cfg
    kw = dict(SCALES[scale])
    if cfg.family in ("ssm", "hybrid"):
        kw.update(num_heads=cfg.num_heads and 8, num_kv_heads=cfg.num_kv_heads
                  and 8, d_ff=kw["d_ff"], ssm_state=32, ssm_head_dim=32)
        if cfg.family == "ssm":
            kw.update(num_heads=0, num_kv_heads=0, d_ff=0)
    if cfg.uses_moe:
        kw.update(num_experts=min(cfg.num_experts, 8),
                  num_experts_per_tok=cfg.num_experts_per_tok,
                  moe_d_ff=kw["d_ff"] // 2)
    if cfg.is_encoder_decoder:
        kw.update(encoder_layers=4, encoder_seq_len=128)
    if cfg.family == "vlm":
        kw.update(num_patches=64)
    return dataclasses.replace(cfg, name=f"{arch}-{scale}", **kw)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--scale", default="100m", choices=[*SCALES, "full"])
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=6e-4)
    ap.add_argument("--warmup", type=int, default=40)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=20)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.checkpoint.manager import restore_latest, save_checkpoint
    from repro.data.pipeline import DataLoader, MarkovCorpus
    from repro.models.model import make_model, make_train_step
    from repro.models.optim import AdamW, cosine_schedule

    cfg = scaled_config(args.arch, args.scale)
    model = make_model(cfg, tp=1)
    print(f"[train] arch={cfg.name} params~{cfg.param_count()/1e6:.1f}M "
          f"family={cfg.family}")

    key = jax.random.PRNGKey(args.seed)
    params = model.init(key, jnp.float32)
    opt = AdamW(lr=cosine_schedule(args.lr, args.warmup, args.steps),
                weight_decay=0.01)
    opt_state = opt.init(params)
    step0 = 0
    if args.ckpt_dir:
        step, restored = restore_latest(args.ckpt_dir,
                                        {"params": params, "opt": opt_state})
        if step is not None:
            params, opt_state = restored["params"], restored["opt"]
            step0 = step
            print(f"[train] resumed from step {step0}")

    corpus = MarkovCorpus(cfg.vocab_size, seed=args.seed)
    loader = DataLoader(corpus, args.batch, args.seq, seed=args.seed)
    train_step = jax.jit(make_train_step(model, opt,
                                         grad_accum=args.grad_accum),
                         donate_argnums=(0, 1))

    def to_batch(np_batch):
        b = {"tokens": jnp.asarray(np_batch["tokens"])}
        if cfg.family == "vlm":
            b["patch_embeds"] = jnp.zeros(
                (args.batch, cfg.num_patches, cfg.d_model), jnp.float32)
        if cfg.family == "audio":
            b["frame_embeds"] = jax.random.normal(
                jax.random.PRNGKey(0),
                (args.batch, cfg.encoder_seq_len, cfg.d_model)) * 0.1
        return b

    it = iter(loader)
    losses = []
    t0 = time.time()
    for step in range(step0, args.steps):
        batch = to_batch(next(it))
        params, opt_state, metrics = train_step(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.time() - t0
            toks = (step - step0 + 1) * args.batch * args.seq
            print(f"[train] step {step:5d} loss {losses[-1]:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.2f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"tok/s {toks/max(dt,1e-9):.0f}")
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, step + 1,
                            {"params": params, "opt": opt_state})
    uni = corpus.unigram_entropy()
    final = float(np.mean(losses[-10:]))
    print(f"[train] final loss {final:.4f} (unigram entropy {uni:.3f}, "
          f"start {losses[0]:.3f})")
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"arch": cfg.name, "losses": losses,
                       "unigram_entropy": uni, "final": final}, f)
    return final, uni


if __name__ == "__main__":
    main()
