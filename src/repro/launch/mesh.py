"""Production mesh builders.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — required for the dry-run's
``xla_force_host_platform_device_count`` trick to work.
"""
from __future__ import annotations

import jax


def _axis_type_kwargs(n_axes: int) -> dict:
    """``AxisType`` landed after jax 0.4.37; Auto is that release's implicit
    behavior, so on older jax we simply omit the kwarg."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod: (pod=2, data=16, model=16) = 512 chips (DCN over 'pod')."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_mesh(shape, axes):
    """Elastic helper: any (shape, axes) over the available devices."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         **_axis_type_kwargs(len(axes)))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh for tests (requires xla_force_host_platform_device_count)."""
    return make_mesh((data, model), ("data", "model"))


def parse_mesh_spec(spec: str):
    """'2x8x16:data,expert,model' -> mesh. Same chip count, refactored axes
    (e.g. a dedicated expert axis for MoE archs whose expert count does not
    divide the data axis)."""
    shape_s, axes_s = spec.split(":")
    shape = tuple(int(x) for x in shape_s.split("x"))
    axes = tuple(axes_s.split(","))
    return make_mesh(shape, axes)
