"""Production mesh builders.

jax is imported INSIDE every function (never at module level) so importing
this module touches neither jax nor device state — required both for the
dry-run's ``xla_force_host_platform_device_count`` trick and for
``set_host_device_count`` below, which must run before jax initializes its
backend (``launch/serve.py --devices N`` calls it before ``import jax``).
"""
from __future__ import annotations

import os
import sys

_HOST_COUNT_FLAG = "--xla_force_host_platform_device_count"


def set_host_device_count(n: int) -> None:
    """Expose ``n`` virtual CPU devices (the SNIPPETS ``set_cpu_cores``
    idiom): sets ``XLA_FLAGS=--xla_force_host_platform_device_count=n``.

    Must run before jax initializes its backend — the flag is read once at
    backend init and silently ignored afterwards. If jax is already imported
    we probe the backend: an already-initialized backend with a different
    device count is a hard, *clear* error (the alternative is a mesh build
    failing later with an opaque "requires 4 devices, got 1")."""
    n = int(n)
    if n < 1:
        raise ValueError(f"need at least one device, got {n}")
    flags = [f for f in os.environ.get("XLA_FLAGS", "").split()
             if not f.startswith(_HOST_COUNT_FLAG)]
    flags.append(f"{_HOST_COUNT_FLAG}={n}")
    os.environ["XLA_FLAGS"] = " ".join(flags)
    if "jax" in sys.modules:
        import jax

        # device_count() initializes the backend: if it was NOT yet
        # initialized it picks up the flag we just set (count == n, fine);
        # if it WAS initialized the flag came too late — error clearly.
        have = jax.local_device_count()
        if have != n and jax.default_backend() == "cpu":
            raise RuntimeError(
                f"jax already initialized with {have} host device(s); "
                f"set_host_device_count({n}) (or --devices {n}) must run "
                "before the first jax backend use — move it ahead of any "
                "jax import/computation, or set XLA_FLAGS="
                f"{_HOST_COUNT_FLAG}={n} in the environment")


def _axis_type_kwargs(n_axes: int) -> dict:
    """``AxisType`` landed after jax 0.4.37; Auto is that release's implicit
    behavior, so on older jax we simply omit the kwarg."""
    import jax

    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod: (pod=2, data=16, model=16) = 512 chips (DCN over 'pod')."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Elastic helper: any (shape, axes) over the available devices."""
    import jax

    return jax.make_mesh(tuple(shape), tuple(axes),
                         **_axis_type_kwargs(len(axes)))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh for tests (requires xla_force_host_platform_device_count)."""
    return make_mesh((data, model), ("data", "model"))


def make_fleet_mesh(devices: int = 0):
    """1-D serving mesh: the fleet axis of a ``FleetGroup`` slab maps over
    ``devices`` devices (all visible devices when 0) so F replicas decode on
    N devices in parallel. On a CPU box call ``set_host_device_count(N)``
    (or ``serve.py --devices N``) *before* any jax use; on GPU/TPU the real
    devices are used as-is."""
    import jax

    n = int(devices) or jax.local_device_count()
    return make_mesh((n,), ("fleet",))


def parse_mesh_spec(spec: str):
    """'2x8x16:data,expert,model' -> mesh. Same chip count, refactored axes
    (e.g. a dedicated expert axis for MoE archs whose expert count does not
    divide the data axis)."""
    shape_s, axes_s = spec.split(":")
    shape = tuple(int(x) for x in shape_s.split("x"))
    axes = tuple(axes_s.split(","))
    return make_mesh(shape, axes)
