"""Demand forecasting DNN (the paper's deep-learning component of S_t).

GRU over a window of recent per-node load, predicting the next-T horizon
R̂_{t+1:t+T} (Eq. 1). Trained with MSE on trace windows; the autoscaler and
the MADRL state both consume its predictions. A last-value baseline is
provided for the tests' "beats-naive" check.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import he_init


def init_gru(key, in_dim: int, hidden: int) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wz": he_init(k1, (in_dim + hidden, hidden), jnp.float32),
        "wr": he_init(k2, (in_dim + hidden, hidden), jnp.float32),
        "wh": he_init(k3, (in_dim + hidden, hidden), jnp.float32),
        "bz": jnp.zeros((hidden,)), "br": jnp.zeros((hidden,)),
        "bh": jnp.zeros((hidden,)),
    }


def gru_step(p, h, x):
    xh = jnp.concatenate([x, h], axis=-1)
    z = jax.nn.sigmoid(xh @ p["wz"] + p["bz"])
    r = jax.nn.sigmoid(xh @ p["wr"] + p["br"])
    xrh = jnp.concatenate([x, r * h], axis=-1)
    h_new = jnp.tanh(xrh @ p["wh"] + p["bh"])
    return (1 - z) * h + z * h_new


def init_forecaster(key, in_dim: int, hidden: int, horizon: int) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "gru": init_gru(k1, in_dim, hidden),
        "head": he_init(k2, (hidden, horizon * in_dim), jnp.float32),
        "head_b": jnp.zeros((horizon * in_dim,)),
    }


def forecast(params, window):
    """window: (..., W, F) past loads -> (..., T, F) predicted horizon."""
    lead = window.shape[:-2]
    W, F = window.shape[-2:]
    h0 = jnp.zeros(lead + (params["gru"]["bz"].shape[0],))

    def body(h, x):
        return gru_step(params["gru"], h, x), None

    xs = jnp.moveaxis(window, -2, 0)          # (W, ..., F)
    h, _ = jax.lax.scan(body, h0, xs)
    out = h @ params["head"] + params["head_b"]
    horizon = out.shape[-1] // F
    return out.reshape(lead + (horizon, F))


def forecast_loss(params, window, target):
    pred = forecast(params, window)
    return jnp.mean(jnp.square(pred - target))


def last_value_baseline(window, horizon: int):
    """Persistence forecast: repeat the last observation."""
    last = window[..., -1:, :]
    reps = [1] * (window.ndim - 2) + [horizon, 1]
    return jnp.tile(last, reps)


def train_forecaster(key, windows, targets, hidden: int, *, steps=500,
                     lr=1e-2, batch=64):
    """windows: (M, W, F); targets: (M, T, F). Returns (params, losses)."""
    windows = jnp.asarray(windows)
    targets = jnp.asarray(targets)
    M, W, F = windows.shape
    horizon = targets.shape[1]
    params = init_forecaster(key, F, hidden, horizon)

    mu = jax.tree.map(jnp.zeros_like, params)
    nu = jax.tree.map(jnp.zeros_like, params)

    @jax.jit
    def step(params, mu, nu, i, key):
        idx = jax.random.randint(key, (batch,), 0, M)
        loss, grads = jax.value_and_grad(forecast_loss)(
            params, windows[idx], targets[idx])
        mu = jax.tree.map(lambda m, g: 0.9 * m + 0.1 * g, mu, grads)
        nu = jax.tree.map(lambda v, g: 0.999 * v + 0.001 * g * g, nu, grads)
        t = i + 1.0
        params = jax.tree.map(
            lambda p, m, v: p - lr * (m / (1 - 0.9 ** t))
            / (jnp.sqrt(v / (1 - 0.999 ** t)) + 1e-8), params, mu, nu)
        return params, mu, nu, loss

    losses = []
    for i in range(steps):
        key, sub = jax.random.split(key)
        params, mu, nu, loss = step(params, mu, nu, jnp.float32(i), sub)
        losses.append(float(loss))
    return params, losses
