"""Decentralized decision layer: gossip policy sync + gradient compression.

The paper's decentralization claim: each node runs a local agent (shared
policy) and decisions survive node failures. Mechanisms here:

  1. ``gossip_average`` — symmetric-mixing gossip over the topology; each
     round halves the disagreement spectral radius. Used to keep per-node
     policy replicas consistent without a central parameter server.
  2. ``ring_allreduce_shardmap`` — the same averaging as a JAX collective
     (shard_map + lax.psum over the data axis) for on-mesh execution: this is
     the production path (no NCCL emulation — native XLA collectives).
  3. ``topk_compress`` / ``ErrorFeedback`` — top-k sparsification with error
     feedback for the policy-sync traffic (the distributed-optimization trick
     for 1000+-node scale: sync bytes drop ~50-100x, convergence preserved by
     the EF residual).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from jax.sharding import PartitionSpec as P


def mixing_matrix(adjacency: np.ndarray) -> np.ndarray:
    """Metropolis-Hastings weights: doubly stochastic, symmetric."""
    A = np.asarray(adjacency, np.float64)
    n = A.shape[0]
    deg = A.sum(1)
    W = np.zeros_like(A)
    for i in range(n):
        for j in range(n):
            if i != j and A[i, j] > 0:
                W[i, j] = 1.0 / (1.0 + max(deg[i], deg[j]))
        W[i, i] = 1.0 - W[i].sum()
    return W.astype(np.float32)


def gossip_average(node_params, W, rounds: int = 1):
    """node_params: pytree with leading node axis N on every leaf."""
    Wj = jnp.asarray(W)

    def mix(x):
        for _ in range(rounds):
            x = jnp.einsum("nm,m...->n...", Wj, x)
        return x

    return jax.tree.map(mix, node_params)


def disagreement(node_params) -> float:
    """Max L2 distance of any node's params from the mean (consensus gap)."""
    gaps = []
    for x in jax.tree.leaves(node_params):
        mean = jnp.mean(x, axis=0, keepdims=True)
        gaps.append(jnp.max(jnp.sqrt(jnp.sum(
            jnp.square(x - mean), axis=tuple(range(1, x.ndim))))))
    return float(jnp.max(jnp.stack(gaps)))


# ------------------------------------------------------- compression + EF
def topk_compress(x, k_frac: float):
    """Keep the top k-fraction of |x| entries; return (sparse_x, kept_mask)."""
    flat = x.reshape(-1)
    k = max(1, int(flat.shape[0] * k_frac))
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    mask = jnp.zeros_like(flat).at[idx].set(1.0)
    return (flat * mask).reshape(x.shape), mask.reshape(x.shape)


@dataclasses.dataclass
class ErrorFeedback:
    """EF-SGD style residual accumulator for compressed collectives."""
    k_frac: float = 0.02

    def init(self, params):
        return jax.tree.map(jnp.zeros_like, params)

    def compress(self, grads, residual):
        """Returns (compressed grads to transmit, new residual)."""
        def one(g, r):
            corrected = g + r
            sparse, mask = topk_compress(corrected, self.k_frac)
            return sparse, corrected - sparse
        out = jax.tree.map(one, grads, residual)
        sparse = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
        new_res = jax.tree.map(lambda t: t[1], out,
                               is_leaf=lambda t: isinstance(t, tuple))
        return sparse, new_res


# ------------------------------------------------- on-mesh collective path
def psum_average_grads(grads, axis_name: str):
    """Data-parallel gradient averaging (inside shard_map/pjit)."""
    n = jax.lax.psum(1, axis_name)
    return jax.tree.map(lambda g: jax.lax.psum(g, axis_name) / n, grads)


def make_gossip_allreduce(mesh, axis: str = "data"):
    """shard_map'd parameter averaging over one mesh axis — the production
    decentralized-sync path (lowered to all-reduce on the ICI).

    Layout contract: every leaf's LEADING axis is the per-node replica axis,
    sharded over `axis`. After the call, every node's row holds the mean
    (consensus in one collective)."""
    from jax.experimental.shard_map import shard_map

    def avg(params):
        def inner(p):
            return jax.tree.map(
                lambda x: jax.lax.pmean(x, axis), p)
        spec = jax.tree.map(
            lambda x: P(axis, *([None] * (x.ndim - 1))), params)
        return shard_map(inner, mesh=mesh, in_specs=(spec,),
                         out_specs=spec)(params)

    return avg
