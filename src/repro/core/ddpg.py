"""GCN+DDPG hybrid policy for adaptive load distribution (paper §3.1).

Actor: node features --GCN(Eq.6)--> per-node embeddings --shared MLP-->
per-node logits --softmax--> simplex allocation A_t (Eq.4/7). The shared
per-node head IS the paper's "shared policy network with local information
fusion": every agent (node) runs the same head on its GCN-fused local view.

Critic: Q(S_t, A_t) — GCN embeddings concat per-node action, shared MLP,
summed over nodes (permutation-equivariant, so the same critic serves any
cluster size). Trained on the TD target (Eq.8) with target networks and a
replay buffer; soft (polyak) target updates.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.gcn import gcn_apply, init_gcn
from repro.models.layers import he_init


def init_mlp_head(key, in_dim, hidden, out_dim, final_scale=1.0):
    k1, k2 = jax.random.split(key)
    return {
        "w1": he_init(k1, (in_dim, hidden), jnp.float32),
        "b1": jnp.zeros((hidden,)),
        "w2": he_init(k2, (hidden, out_dim), jnp.float32) * final_scale,
        "b2": jnp.zeros((out_dim,)),
    }


def mlp_head(p, x):
    return jax.nn.relu(x @ p["w1"] + p["b1"]) @ p["w2"] + p["b2"]


def init_actor(key, feat_dim, cfg) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "gcn": init_gcn(k1, feat_dim, cfg.gcn_hidden, cfg.gcn_layers),
        "head": init_mlp_head(k2, cfg.gcn_hidden + feat_dim,
                              cfg.actor_hidden, 1, final_scale=0.01),
    }


def init_critic(key, feat_dim, cfg) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "gcn": init_gcn(k1, feat_dim, cfg.gcn_hidden, cfg.gcn_layers),
        "head": init_mlp_head(k2, cfg.gcn_hidden + feat_dim + 1,
                              cfg.critic_hidden, 1),
    }


def actor_logits(params, a_hat, obs):
    """obs: (..., N, F) -> per-node logits (..., N)."""
    h = gcn_apply(params["gcn"], a_hat, obs)
    h = jnp.concatenate([h, obs], axis=-1)     # local skip (info fusion)
    return mlp_head(params["head"], h)[..., 0]


def actor_action(params, a_hat, obs, up_mask=None, noise=None):
    """Simplex allocation over nodes (Eq.4). Noise (Eq.7) added to logits.

    up_mask: (..., N) 1 for healthy nodes — failed nodes get zero traffic
    (the decentralized fault-tolerance hook).
    """
    logits = actor_logits(params, a_hat, obs)
    if noise is not None:
        logits = logits + noise
    if up_mask is not None:
        logits = jnp.where(up_mask > 0, logits, -1e9)
    return jax.nn.softmax(logits, axis=-1)


def critic_q(params, a_hat, obs, action):
    """Q(S_t, A_t): (..., N, F), (..., N) -> (...)."""
    h = gcn_apply(params["gcn"], a_hat, obs)
    h = jnp.concatenate([h, obs, action[..., None]], axis=-1)
    q = mlp_head(params["head"], h)[..., 0]    # per-node q contribution
    return jnp.sum(q, axis=-1)


# ------------------------------------------------------------------ training
@dataclasses.dataclass
class ReplayBuffer:
    """Numpy ring buffer of (obs, action, reward, next_obs, up_mask)."""
    capacity: int
    n_nodes: int
    feat_dim: int

    def __post_init__(self):
        C, N, F = self.capacity, self.n_nodes, self.feat_dim
        self.obs = np.zeros((C, N, F), np.float32)
        self.act = np.zeros((C, N), np.float32)
        self.rew = np.zeros((C,), np.float32)
        self.nxt = np.zeros((C, N, F), np.float32)
        self.mask = np.ones((C, N), np.float32)
        self.size = 0
        self.ptr = 0

    def add(self, obs, act, rew, nxt, mask):
        i = self.ptr
        self.obs[i], self.act[i], self.rew[i] = obs, act, rew
        self.nxt[i], self.mask[i] = nxt, mask
        self.ptr = (i + 1) % self.capacity
        self.size = min(self.size + 1, self.capacity)

    def sample(self, rng: np.random.Generator, batch: int):
        idx = rng.integers(0, self.size, size=batch)
        return (self.obs[idx], self.act[idx], self.rew[idx], self.nxt[idx],
                self.mask[idx])


def polyak(target, online, tau):
    return jax.tree.map(lambda t, o: (1 - tau) * t + tau * o, target, online)


@dataclasses.dataclass
class DDPGState:
    actor: dict
    critic: dict
    actor_target: dict
    critic_target: dict


def init_ddpg(key, feat_dim, cfg) -> DDPGState:
    k1, k2 = jax.random.split(key)
    actor = init_actor(k1, feat_dim, cfg)
    critic = init_critic(k2, feat_dim, cfg)
    return DDPGState(actor, critic,
                     jax.tree.map(jnp.copy, actor),
                     jax.tree.map(jnp.copy, critic))


@functools.partial(jax.jit, static_argnames=("gamma", "tau", "actor_lr",
                                             "critic_lr"))
def ddpg_update(state_tuple, a_hat, batch, *, gamma, tau, actor_lr, critic_lr):
    """One TD + policy-gradient step (Eq.8). state_tuple = (actor, critic,
    actor_t, critic_t); batch = (obs, act, rew, nxt, mask)."""
    actor, critic, actor_t, critic_t = state_tuple
    obs, act, rew, nxt, mask = batch

    def clip_by_norm(grads, max_norm=1.0):
        g2 = sum(jnp.sum(jnp.square(g)) for g in jax.tree.leaves(grads))
        scale = jnp.minimum(1.0, max_norm / jnp.maximum(jnp.sqrt(g2), 1e-9))
        return jax.tree.map(lambda g: g * scale, grads)

    next_a = actor_action(actor_t, a_hat, nxt, up_mask=mask)
    target_q = rew + gamma * critic_q(critic_t, a_hat, nxt, next_a)
    target_q = jax.lax.stop_gradient(target_q)

    def critic_loss(c):
        q = critic_q(c, a_hat, obs, act)
        return jnp.mean(jnp.square(q - target_q))

    c_loss, c_grads = jax.value_and_grad(critic_loss)(critic)
    c_grads = clip_by_norm(c_grads)
    critic = jax.tree.map(lambda p, g: p - critic_lr * g, critic, c_grads)

    def actor_loss(a):
        action = actor_action(a, a_hat, obs, up_mask=mask)
        return -jnp.mean(critic_q(critic, a_hat, obs, action))

    a_loss, a_grads = jax.value_and_grad(actor_loss)(actor)
    a_grads = clip_by_norm(a_grads)
    actor = jax.tree.map(lambda p, g: p - actor_lr * g, actor, a_grads)

    actor_t = polyak(actor_t, actor, tau)
    critic_t = polyak(critic_t, critic, tau)
    return (actor, critic, actor_t, critic_t), {"critic_loss": c_loss,
                                                "actor_loss": a_loss}
