"""Load balancers: the paper's MADRL(GCN+DDPG) policy + the §4.2 baselines.

Every balancer maps per-tick cluster observations to a simplex allocation
a_t over nodes (Eq. 4): fractions of the tick's request mass per node. In the
fluid cluster simulator this is exact; in the request-level serving engine
the fractions drive per-request routing.

Baselines (paper §4.2): RRA (round robin -> uniform over healthy nodes),
LCA (least connections -> water-filling on queue depth, capacity-blind),
plus WRR (capacity-weighted) as an extra reference.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ddpg
from repro.core.gcn import make_topology, normalize_adjacency


def _mask_normalize(w, up_mask):
    w = jnp.where(up_mask > 0, w, 0.0)
    s = jnp.sum(w, axis=-1, keepdims=True)
    n_up = jnp.sum(up_mask, axis=-1, keepdims=True)
    uniform = up_mask / jnp.maximum(n_up, 1.0)
    return jnp.where(s > 1e-9, w / jnp.maximum(s, 1e-9), uniform)


def round_robin(obs, up_mask):
    """RRA: uniform over healthy nodes (per-request RR in the fluid limit)."""
    return _mask_normalize(jnp.ones_like(up_mask), up_mask)


def weighted_capacity(obs, up_mask, capacity):
    """WRR: fractions ∝ node capacity."""
    return _mask_normalize(capacity, up_mask)


def least_connections(queue, up_mask, total_arrivals):
    """LCA as water-filling: route this tick's arrivals so post-routing queue
    depths equalize from the bottom up (what per-request least-connections
    converges to within a tick). Capacity-blind, like the real algorithm.

    queue: (N,) outstanding work; total_arrivals: scalar mass to place.
    """
    N = queue.shape[-1]
    big = 1e18
    q = jnp.where(up_mask > 0, queue, big)
    order = jnp.argsort(q)
    qs = q[order]
    # find water level L: sum_i max(0, L - q_i) = total => for first k nodes
    csum = jnp.cumsum(qs)
    k = jnp.arange(1, N + 1)
    level = (csum + total_arrivals) / k            # candidate level using k lowest
    next_q = jnp.concatenate([qs[1:], jnp.full((1,), big)])
    feasible = (level >= qs) & (level <= next_q)
    k_star = jnp.argmax(feasible)                  # first feasible k
    L = level[k_star]
    alloc_sorted = jnp.clip(L - qs, 0.0, None) * (jnp.arange(N) <= k_star)
    alloc = jnp.zeros_like(q).at[order].set(alloc_sorted)
    alloc = jnp.where(up_mask > 0, alloc, 0.0)
    s = jnp.sum(alloc)
    return jnp.where(s > 1e-9, alloc / jnp.maximum(s, 1e-9),
                     _mask_normalize(jnp.ones_like(q), up_mask))


@dataclasses.dataclass
class RLBalancer:
    """The paper's balancer: GCN+DDPG actor producing A_t from S_t."""
    cluster_cfg: "ClusterConfig"
    feat_dim: int
    seed: int = 0

    def __post_init__(self):
        cfg = self.cluster_cfg
        self.a_hat = jnp.asarray(normalize_adjacency(
            make_topology(cfg.num_nodes, cfg.topology)))
        key = jax.random.PRNGKey(self.seed)
        self.state = ddpg.init_ddpg(key, self.feat_dim, cfg)
        self.buffer = ddpg.ReplayBuffer(cfg.buffer_size, cfg.num_nodes,
                                        self.feat_dim)
        self._rng = np.random.default_rng(self.seed)
        self._act = jax.jit(ddpg.actor_action)

    # -- acting ---------------------------------------------------------
    def act(self, obs, up_mask, explore: bool = False):
        noise = None
        if explore:
            noise = jnp.asarray(self._rng.normal(
                0.0, self.cluster_cfg.noise_sigma, obs.shape[:-1]))
        return self._act(self.state.actor, self.a_hat, obs,
                         up_mask=up_mask, noise=noise)

    # -- learning -------------------------------------------------------
    def observe(self, obs, action, reward, next_obs, up_mask):
        self.buffer.add(np.asarray(obs), np.asarray(action), float(reward),
                        np.asarray(next_obs), np.asarray(up_mask))

    def train_step(self):
        cfg = self.cluster_cfg
        if self.buffer.size < cfg.batch_size:
            return {}
        batch = self.buffer.sample(self._rng, cfg.batch_size)
        tup = (self.state.actor, self.state.critic,
               self.state.actor_target, self.state.critic_target)
        tup, metrics = ddpg.ddpg_update(
            tup, self.a_hat, batch, gamma=cfg.gamma, tau=cfg.tau,
            actor_lr=cfg.actor_lr, critic_lr=cfg.critic_lr)
        self.state = ddpg.DDPGState(*tup)
        return {k: float(v) for k, v in metrics.items()}


def reward_fn(response_time, utilization, alpha, beta, overload,
              slo_cost: float = 0.0):
    """Eq.5 (see DESIGN.md §8 for the utilization-term interpretation):
    R_t = -(α·ResponseTime + β·(idle-capacity + overload penalty)
            + tier-weighted SLO cost).

    Response time enters through log1p so transient queue blow-ups cannot
    destabilize the critic (reward stays O(1)). ``slo_cost`` is the
    tier-weighted SLO violation level of the tick (already scaled by the
    caller, e.g. ``cfg.slo_gamma * metrics['tier_slo_cost']``): with tiered
    traffic the policy is penalized more for premium-tier misses than for
    batch-tier ones; untiered runs pass 0 and recover the original Eq.5."""
    idle_cost = 1.0 - utilization
    rt_cost = float(np.log1p(response_time))
    return -(alpha * rt_cost + beta * (idle_cost + 2.0 * overload)
             + float(slo_cost))
