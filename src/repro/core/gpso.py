"""Hybrid Genetic-Particle-Swarm Optimization (paper §3.2, Eq. 9-11).

GA phase (roulette selection, single-point crossover, random mutation)
explores; its elite seeds the PSO phase (velocity/position updates, Eq.10-11)
which refines toward the global optimum. Fully vectorized over the population
in jnp, generations unrolled with ``lax.scan`` and the whole optimizer jit'd.

``fitness_fn`` maps (population (P, D), ctx pytree) -> costs (P,); lower is
better. ``ctx`` carries traced problem data (e.g. per-node demand) so the
jit'd optimizer compiles ONCE per (fitness_fn, n_dims, cfg) and is re-invoked
with fresh demands every scaling tick without retracing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def slo_violation_cost(load, pressure, target):
    """Tier-weighted SLO-violation cost term for Eq.9 objectives.

    load: (P, N) per-node load under each candidate allocation; pressure:
    (N,) tier-weighted backlog share per node (premium-heavy nodes weigh
    more — see ``workload.trace.TierSet.pressure``); target: scalar
    provisioning headroom. Returns (P,): the pressure-weighted mass of load
    above target, so the optimizer buys extra replicas for exactly the nodes
    whose backlog carries high-priority traffic. Zero pressure (or a
    single-tier workload) makes the term vanish and Eq.9 reduces to its
    untiered form."""
    return jnp.sum(pressure[None, :] * jnp.maximum(load - target, 0.0),
                   axis=-1)


def preemption_risk_cost(alloc, risk):
    """Spot-churn cost term for Eq.9 objectives.

    alloc: (P, N) candidate replica share per node; risk: (N,) per-node
    preemption-risk signal (1 while a node is under a spot notice or down,
    0 otherwise — see ``ElasticClusterFrontend.preempt_risk`` /
    ``ClusterSim``). Returns (P,): the allocation mass placed on at-risk
    nodes. Every replica bought there is expected to be evacuated and its
    in-flight work re-served, so the optimizer shifts capacity onto stable
    nodes *before* the notice expires instead of reacting to the drop.
    Zero risk makes the term vanish and Eq.9 reduces to its base form."""
    return jnp.sum(risk[None, :] * alloc, axis=-1)


def _roulette(key, costs, n: int):
    """Sample n indices with probability ∝ softmax(-normalized cost)."""
    z = (costs - costs.mean()) / (costs.std() + 1e-9)
    logits = -z
    return jax.random.categorical(key, logits, shape=(n,))


def ga_generation(key, pop, costs, ctx, *, crossover_p, mutation_p, elite,
                  lo, hi, fitness_fn):
    """One GA generation. pop: (P, D)."""
    P, D = pop.shape
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    order = jnp.argsort(costs)
    elites = pop[order[:elite]]

    n_child = P - elite
    pa = pop[_roulette(k1, costs, n_child)]
    pb = pop[_roulette(k2, costs, n_child)]
    # single-point crossover
    cut = jax.random.randint(k3, (n_child, 1), 1, D)
    cols = jnp.arange(D)[None, :]
    do_cross = jax.random.uniform(k4, (n_child, 1)) < crossover_p
    child = jnp.where((cols < cut) | ~do_cross, pa, pb)
    # random-reset mutation
    k5a, k5b = jax.random.split(k5)
    mut_mask = jax.random.uniform(k5a, child.shape) < mutation_p
    rand_vals = jax.random.uniform(k5b, child.shape, minval=lo, maxval=hi)
    child = jnp.where(mut_mask, rand_vals, child)

    new_pop = jnp.concatenate([elites, child], axis=0)
    return new_pop, fitness_fn(new_pop, ctx)


def pso_iteration(key, pos, vel, pbest, pbest_cost, gbest, gbest_cost, ctx, *,
                  w, c1, c2, lo, hi, fitness_fn):
    """Eq. 10-11."""
    k1, k2 = jax.random.split(key)
    r1 = jax.random.uniform(k1, pos.shape)
    r2 = jax.random.uniform(k2, pos.shape)
    vel = w * vel + c1 * r1 * (pbest - pos) + c2 * r2 * (gbest[None] - pos)
    pos = jnp.clip(pos + vel, lo, hi)
    costs = fitness_fn(pos, ctx)
    better = costs < pbest_cost
    pbest = jnp.where(better[:, None], pos, pbest)
    pbest_cost = jnp.where(better, costs, pbest_cost)
    i = jnp.argmin(pbest_cost)
    gb_cost, gb = pbest_cost[i], pbest[i]
    upd = gb_cost < gbest_cost
    return pos, vel, pbest, pbest_cost, \
        jnp.where(upd, gb, gbest), jnp.where(upd, gb_cost, gbest_cost)


@functools.partial(jax.jit, static_argnames=("fitness_fn", "n_dims", "cfg"))
def gpso_minimize(key, fitness_fn, n_dims: int, cfg, lo=0.0, hi=1.0,
                  ctx=None):
    """Hybrid GA->PSO. Returns (best_x (D,), best_cost, history (G+I,)).

    cfg needs: ga_pop, ga_generations, ga_elite, ga_crossover, ga_mutation,
    pso_iters, pso_inertia, pso_c1, pso_c2.
    """
    kinit, kga, kpso = jax.random.split(key, 3)
    pop = jax.random.uniform(kinit, (cfg.ga_pop, n_dims), minval=lo, maxval=hi)
    costs = fitness_fn(pop, ctx)

    def ga_body(carry, k):
        pop, costs = carry
        pop, costs = ga_generation(k, pop, costs, ctx,
                                   crossover_p=cfg.ga_crossover,
                                   mutation_p=cfg.ga_mutation,
                                   elite=cfg.ga_elite, lo=lo, hi=hi,
                                   fitness_fn=fitness_fn)
        return (pop, costs), jnp.min(costs)

    (pop, costs), ga_hist = jax.lax.scan(
        ga_body, (pop, costs), jax.random.split(kga, cfg.ga_generations))

    # GA elite seeds the swarm (the paper's "high quality chromosomes ...
    # establish the initial position of the particle swarm")
    order = jnp.argsort(costs)
    pos = pop[order]
    costs = costs[order]
    vel = jnp.zeros_like(pos)
    pbest, pbest_cost = pos, costs
    g_i = jnp.argmin(costs)
    gbest, gbest_cost = pos[g_i], costs[g_i]

    def pso_body(carry, k):
        pos, vel, pb, pbc, gb, gbc = carry
        out = pso_iteration(k, pos, vel, pb, pbc, gb, gbc, ctx,
                            w=cfg.pso_inertia, c1=cfg.pso_c1, c2=cfg.pso_c2,
                            lo=lo, hi=hi, fitness_fn=fitness_fn)
        return out, out[-1]

    (pos, vel, pbest, pbest_cost, gbest, gbest_cost), pso_hist = jax.lax.scan(
        pso_body, (pos, vel, pbest, pbest_cost, gbest, gbest_cost),
        jax.random.split(kpso, cfg.pso_iters))
    return gbest, gbest_cost, jnp.concatenate([ga_hist, pso_hist])


def ga_only_minimize(key, fitness_fn, n_dims: int, cfg, lo=0.0, hi=1.0,
                     ctx=None):
    """Ablation: GA without the PSO refinement."""
    kinit, kga = jax.random.split(key)
    pop = jax.random.uniform(kinit, (cfg.ga_pop, n_dims), minval=lo, maxval=hi)
    costs = fitness_fn(pop, ctx)

    def ga_body(carry, k):
        pop, costs = carry
        pop, costs = ga_generation(k, pop, costs, ctx,
                                   crossover_p=cfg.ga_crossover,
                                   mutation_p=cfg.ga_mutation,
                                   elite=cfg.ga_elite, lo=lo, hi=hi,
                                   fitness_fn=fitness_fn)
        return (pop, costs), jnp.min(costs)

    (pop, costs), hist = jax.lax.scan(
        ga_body, (pop, costs),
        jax.random.split(kga, cfg.ga_generations + cfg.pso_iters))
    i = jnp.argmin(costs)
    return pop[i], costs[i], hist
