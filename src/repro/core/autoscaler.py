"""Auto-scaling module (paper §3.2): forecast + GPSO resource planning,
plus the HPA and RBAS baselines from §4.2.

The optimization objective is Eq.9:
    min  Σ_i C_i·R_i + λ·max_i L_i(R)
where R_i is the replica count on node i and L_i(R) the node's load (demand /
provisioned capacity) under allocation R, with an unserved-demand penalty so
the optimizer can't zero out a loaded node.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.gpso import (ga_only_minimize, gpso_minimize,
                             preemption_risk_cost, slo_violation_cost)


def eq9_fitness(R, ctx):
    """Eq.9 population fitness: R (P, N) -> cost (P,).

    ctx = (node_demand (N,), unit_capacity, replica_cost, lam, target_load) —
    traced, so the jit'd GPSO compiles once and replans every tick without
    retracing. Loads are measured against ``target_load`` (provisioning
    headroom); load > 1 (true overload) draws an additional quadratic penalty.
    """
    demand, unit_capacity, replica_cost, lam, target = ctx
    unit_capacity = jnp.asarray(unit_capacity)         # scalar or (N,) speeds
    Rr = jnp.round(R)                                  # integer replicas
    cap = Rr * unit_capacity
    load = demand[None, :] / jnp.maximum(cap, 1e-6)
    # unserved demand (replicas==0 but demand>0) -> strong penalty
    unserved = jnp.maximum(demand[None, :] - cap, 0.0)
    overload = jnp.sum(jnp.square(jnp.maximum(load - 1.0, 0.0)), axis=-1)
    mean_unit = jnp.mean(unit_capacity)
    return (replica_cost * jnp.sum(Rr, axis=-1)
            + lam * jnp.max(load / target, axis=-1)
            + 20.0 * overload
            + 50.0 * jnp.sum(unserved, axis=-1) / mean_unit)


def eq9_tiered_fitness(R, ctx):
    """Eq.9 extended with the tier-weighted SLO-violation cost term.

    ctx = eq9 ctx ++ (slo_lam, pressure (N,)): ``pressure`` is the backends'
    ``tier_pressure`` metric normalized to a per-node share — nodes whose
    backlog is premium-heavy draw an extra penalty when their load exceeds
    the headroom target, so the planner provisions SLO-critical nodes first
    instead of treating every queued request alike."""
    demand, unit_capacity, replica_cost, lam, target, slo_lam, pressure = ctx
    unit_capacity = jnp.asarray(unit_capacity)
    Rr = jnp.round(R)
    cap = Rr * unit_capacity
    load = demand[None, :] / jnp.maximum(cap, 1e-6)
    base = eq9_fitness(R, (demand, unit_capacity, replica_cost, lam, target))
    return base + slo_lam * slo_violation_cost(load, pressure, target)


def eq9_risk_fitness(R, ctx):
    """Eq.9 extended with the spot preemption-risk cost term.

    ctx = eq9 ctx ++ (risk_lam, risk (N,)): ``risk`` is the backends'
    ``preempt_risk`` metric (1 on nodes under a preemption notice or down).
    Replicas placed on at-risk nodes cost extra — their work is expected to
    be evacuated and re-served — so the planner shifts capacity to stable
    nodes before the notice expires."""
    risk_lam, risk = ctx[5], ctx[6]
    return eq9_fitness(R, ctx[:5]) + \
        risk_lam * preemption_risk_cost(jnp.round(R), risk)


def eq9_tiered_risk_fitness(R, ctx):
    """Tiered Eq.9 + preemption risk (the full failure-matrix objective).

    ctx = eq9 ctx ++ (slo_lam, pressure) ++ (risk_lam, risk) — the tuple is
    extended in this fixed order so each fitness variant keeps a stable
    traced signature (one jit cache entry per variant)."""
    risk_lam, risk = ctx[7], ctx[8]
    return eq9_tiered_fitness(R, ctx[:7]) + \
        risk_lam * preemption_risk_cost(jnp.round(R), risk)


@dataclasses.dataclass
class GPSOAutoscaler:
    """The paper's autoscaler: demand forecast -> GPSO plan (Eq.9-11).

    optimizer='ga' drops the PSO refinement (the paper's implicit ablation:
    GA-only at the same evaluation budget). ``plan(slo_pressure=...)``
    switches to the tiered objective (Eq.9 + tier-weighted SLO cost)."""
    cluster_cfg: "ClusterConfig"
    unit_capacity: float
    seed: int = 0
    optimizer: str = "gpso"          # "gpso" | "ga"

    def __post_init__(self):
        self._key = jax.random.PRNGKey(self.seed)
        self._last_scale_down = -10**9

    def plan(self, node_demand: np.ndarray, tick: int,
             current: np.ndarray,
             node_speed: Optional[np.ndarray] = None,
             slo_pressure: Optional[np.ndarray] = None,
             preempt_risk: Optional[np.ndarray] = None) -> np.ndarray:
        """node_demand: (N,) forecast peak demand per node -> replicas (N,).

        slo_pressure: optional (N,) tier-weighted backlog (the backends'
        ``tier_pressure`` metric); when given, the plan optimizes the
        tiered Eq.9 objective. preempt_risk: optional (N,) spot-churn
        signal (``preempt_risk`` metric); when any node is at risk the
        objective gains the preemption-risk cost term. All-zero signals
        keep the base objective — bit-parity with the pre-chaos planner."""
        cfg = self.cluster_cfg
        n = node_demand.shape[0]
        if node_speed is None:
            node_speed = np.ones(n, np.float32)
        self._key, sub = jax.random.split(self._key)
        ctx = (jnp.asarray(node_demand, jnp.float32),
               jnp.asarray(self.unit_capacity * node_speed, jnp.float32),
               jnp.float32(cfg.replica_cost), jnp.float32(cfg.lam),
               jnp.float32(cfg.target_load))
        fitness = eq9_fitness
        if slo_pressure is not None and np.asarray(slo_pressure).any():
            p = np.asarray(slo_pressure, np.float64)
            p = p / max(p.sum(), 1e-9)       # per-node share, scale-free
            fitness = eq9_tiered_fitness
            ctx = ctx + (jnp.float32(cfg.slo_lam),
                         jnp.asarray(p, jnp.float32))
        if preempt_risk is not None and np.asarray(preempt_risk).any():
            fitness = eq9_tiered_risk_fitness \
                if fitness is eq9_tiered_fitness else eq9_risk_fitness
            ctx = ctx + (jnp.float32(getattr(cfg, "risk_lam", 1.0)),
                         jnp.asarray(preempt_risk, jnp.float32))
        minimize = gpso_minimize if self.optimizer == "gpso" else \
            ga_only_minimize
        best, cost, _ = minimize(
            sub, fitness, node_demand.shape[0], cfg,
            lo=float(cfg.min_replicas_per_node),
            hi=float(cfg.max_replicas_per_node), ctx=ctx)
        target = np.asarray(jnp.round(best), np.int32)
        # scale-down cooldown (flap damping)
        if (target < current).any():
            if tick - self._last_scale_down < cfg.cooldown:
                target = np.maximum(target, current)
            else:
                self._last_scale_down = tick
        return np.clip(target, cfg.min_replicas_per_node,
                       cfg.max_replicas_per_node)


@dataclasses.dataclass
class HPAAutoscaler:
    """Kubernetes Horizontal Pod Autoscaler baseline: per-node
    desired = ceil(current · u / u*), 10% tolerance, stabilization window for
    scale-down (the k8s defaults, scaled to sim ticks)."""
    cluster_cfg: "ClusterConfig"
    target_utilization: float = 0.6
    tolerance: float = 0.1
    window: int = 30

    def __post_init__(self):
        self._history: list = []

    def plan(self, utilization: np.ndarray, tick: int,
             current: np.ndarray) -> np.ndarray:
        cfg = self.cluster_cfg
        ratio = utilization / self.target_utilization
        desired = np.ceil(current * np.where(
            np.abs(ratio - 1.0) > self.tolerance, ratio, 1.0)).astype(np.int32)
        desired = np.maximum(desired, 1)
        self._history.append(desired)
        if len(self._history) > self.window:
            self._history.pop(0)
        # scale down only to the max desired over the stabilization window
        floor = np.max(np.stack(self._history), axis=0)
        desired = np.where(desired < current, np.minimum(floor, current),
                           desired)
        return np.clip(desired, cfg.min_replicas_per_node,
                       cfg.max_replicas_per_node)


@dataclasses.dataclass
class RBASAutoscaler:
    """Rule-Based Auto-Scaling baseline: threshold rules + cooldown."""
    cluster_cfg: "ClusterConfig"
    hi: float = 0.8
    lo: float = 0.3
    patience: int = 3
    cooldown: int = 20

    def __post_init__(self):
        self._over = None
        self._under = None
        self._last_action = -10**9

    def plan(self, utilization: np.ndarray, tick: int,
             current: np.ndarray) -> np.ndarray:
        cfg = self.cluster_cfg
        n = utilization.shape[0]
        if self._over is None:
            self._over = np.zeros(n, np.int32)
            self._under = np.zeros(n, np.int32)
        self._over = np.where(utilization > self.hi, self._over + 1, 0)
        self._under = np.where(utilization < self.lo, self._under + 1, 0)
        target = current.copy()
        if tick - self._last_action >= self.cooldown:
            up = self._over >= self.patience
            down = self._under >= self.patience
            if up.any() or down.any():
                target = current + up.astype(np.int32) - down.astype(np.int32)
                self._last_action = tick
                self._over[:] = 0
                self._under[:] = 0
        return np.clip(target, max(cfg.min_replicas_per_node, 1),
                       cfg.max_replicas_per_node)


@dataclasses.dataclass
class StaticAllocator:
    """No autoscaling (fixed replicas) — RRA/LCA rows in the paper's figures."""
    replicas: int = 4

    def plan(self, utilization, tick, current):
        return np.full_like(current, self.replicas)
