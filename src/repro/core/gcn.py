"""Graph Convolutional Network over the cluster topology (paper Eq. 6).

H^{l+1} = σ( D̃^{-1/2} Ã D̃^{-1/2} H^l W^l ),  Ã = A + I.

The normalized adjacency is precomputed once per topology. Inputs are
(N, F) node-feature matrices (or batched (B, N, F)). The fused Pallas kernel
in ``repro/kernels/gcn_fused.py`` implements one layer for the serving hot
path; this module is the reference XLA implementation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import he_init


def make_topology(n: int, kind: str = "ring+hub") -> np.ndarray:
    """Adjacency matrix (no self loops — Eq.6 adds I itself)."""
    A = np.zeros((n, n), np.float32)
    if kind in ("ring", "ring+hub"):
        for i in range(n):
            A[i, (i + 1) % n] = A[(i + 1) % n, i] = 1.0
    if kind in ("star", "ring+hub"):
        A[0, 1:] = A[1:, 0] = 1.0
    if kind == "full":
        A = np.ones((n, n), np.float32) - np.eye(n, dtype=np.float32)
    return A


def normalize_adjacency(A: np.ndarray) -> np.ndarray:
    """D̃^{-1/2} (A+I) D̃^{-1/2}."""
    A_t = A + np.eye(A.shape[0], dtype=A.dtype)
    d = A_t.sum(axis=1)
    d_inv_sqrt = 1.0 / np.sqrt(np.maximum(d, 1e-9))
    return (A_t * d_inv_sqrt[:, None]) * d_inv_sqrt[None, :]


def init_gcn(key, in_dim: int, hidden: int, n_layers: int,
             out_dim: int = 0) -> dict:
    dims = [in_dim] + [hidden] * (n_layers - 1) + [out_dim or hidden]
    keys = jax.random.split(key, len(dims) - 1)
    return {
        "w": [he_init(k, (dims[i], dims[i + 1]), jnp.float32)
              for i, k in enumerate(keys)],
        "b": [jnp.zeros((dims[i + 1],), jnp.float32)
              for i in range(len(dims) - 1)],
    }


def gcn_apply(params, a_hat, x, activation=jax.nn.relu,
              final_activation=None):
    """x: (..., N, F) -> (..., N, H). a_hat: (N, N) normalized adjacency."""
    h = x
    n_layers = len(params["w"])
    for i, (w, b) in enumerate(zip(params["w"], params["b"])):
        h = jnp.einsum("nm,...mf->...nf", a_hat, h) @ w + b
        if i < n_layers - 1:
            h = activation(h)
        elif final_activation is not None:
            h = final_activation(h)
    return h
