"""On-mesh decentralized sync: shard_map pmean averaging over the data axis
(the production gossip path) on a real 8-device host mesh."""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core.decentralized import make_gossip_allreduce, psum_average_grads
from repro.launch.mesh import make_mesh

mesh = make_mesh((8,), ("data",))
# per-node (data-sharded) policy replicas that have drifted apart
drift = jnp.arange(8.0)[:, None] * jnp.ones((8, 16))
params = {"w": jax.device_put(drift, NamedSharding(mesh, P("data", None)))}
avg = make_gossip_allreduce(mesh, "data")
# NB: make_gossip_allreduce averages ALL elements over the axis; for the
# per-node layout each shard holds its own replica row
out = avg(params)
got = np.asarray(out["w"])
want = np.full((8, 16), np.mean(np.arange(8.0)))
ok_avg = bool(np.allclose(got, want))

# psum_average_grads inside shard_map
from jax.experimental.shard_map import shard_map
def inner(g):
    return psum_average_grads(g, "data")
grads = {"w": jax.device_put(drift, NamedSharding(mesh, P("data", None)))}
out2 = shard_map(inner, mesh=mesh, in_specs=({"w": P("data", None)},),
                 out_specs={"w": P("data", None)})(grads)
got2 = np.asarray(out2["w"])
ok_grads = bool(np.allclose(got2, want))
print(json.dumps({"ok_avg": ok_avg, "ok_grads": ok_grads}))
"""


def test_mesh_gossip_and_grad_average(tmp_path):
    script = tmp_path / "run.py"
    script.write_text(_SCRIPT)
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, str(script)], capture_output=True,
                         text=True, env=env, timeout=300)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["ok_avg"] and res["ok_grads"]
