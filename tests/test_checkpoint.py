"""Fault-tolerant checkpointing: roundtrip, keep-k, corruption recovery."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import (list_checkpoints, restore_latest,
                                      save_checkpoint)


def _tree(key, scale=1.0):
    ks = jax.random.split(key, 3)
    return {"w": jax.random.normal(ks[0], (8, 8)) * scale,
            "nested": {"b": jax.random.normal(ks[1], (4,)) * scale,
                       "step": jnp.int32(7)}}


def test_roundtrip(tmp_path, key):
    t = _tree(key)
    save_checkpoint(str(tmp_path), 10, t)
    step, restored = restore_latest(str(tmp_path), t)
    assert step == 10
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_keep_k(tmp_path, key):
    t = _tree(key)
    for s in range(6):
        save_checkpoint(str(tmp_path), s, t, keep=3)
    steps = [s for s, _, _ in list_checkpoints(str(tmp_path))]
    assert steps == [3, 4, 5]


def test_corrupt_latest_falls_back(tmp_path, key):
    t = _tree(key)
    save_checkpoint(str(tmp_path), 1, t)
    save_checkpoint(str(tmp_path), 2, _tree(key, scale=2.0))
    # corrupt the newest data file
    with open(os.path.join(str(tmp_path), "step_0000000002", "leaves.npz"),
              "wb") as f:
        f.write(b"garbage")
    step, restored = restore_latest(str(tmp_path), t)
    assert step == 1
    assert restored is not None


def test_incomplete_dir_skipped(tmp_path, key):
    t = _tree(key)
    save_checkpoint(str(tmp_path), 5, t)
    # simulate crash mid-save: directory without complete manifest
    bad = os.path.join(str(tmp_path), "step_0000000009")
    os.makedirs(bad)
    with open(os.path.join(bad, "manifest.json"), "w") as f:
        json.dump({"complete": False}, f)
    step, _ = restore_latest(str(tmp_path), t)
    assert step == 5


def test_restore_empty_dir(tmp_path, key):
    step, tree = restore_latest(str(tmp_path), _tree(key))
    assert step is None and tree is None


def test_train_resume_continuity(tmp_path, key):
    """Optimizer state survives: resumed Adam step equals uninterrupted."""
    from repro.models.optim import AdamW
    opt = AdamW(lr=1e-2)
    params = {"w": jnp.ones((4, 4))}
    state = opt.init(params)
    grads = {"w": jnp.full((4, 4), 0.1)}
    # run 3 steps, checkpoint at 2
    p, s = params, state
    for i in range(2):
        p, s, _ = opt.update(grads, s, p)
    save_checkpoint(str(tmp_path), 2, {"params": p, "opt": s})
    p3, s3, _ = opt.update(grads, s, p)
    # resume
    _, restored = restore_latest(str(tmp_path), {"params": p, "opt": s})
    rp, rs = restored["params"], restored["opt"]
    rp3, rs3, _ = opt.update(grads, rs, rp)
    np.testing.assert_allclose(np.asarray(p3["w"]), np.asarray(rp3["w"]),
                               atol=1e-7)
