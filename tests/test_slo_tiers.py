"""SLO-tiered serving: priority classes from workload to reward.

Covers the tiered admission path end-to-end: weighted-deficit fairness
(batch never starves), premium-first ordering, the single-tier parity
oracle (bit-identical to the untiered scheduler), per-tier metrics
plumbing through the elastic backend, the fleet dispatch bound under
3-tier load (tiering reorders rows, never adds dispatches), the
arrival-order re-queue fix, tier-aware chunk scheduling, the tiered fluid
sim and the tier-weighted Eq.5 / Eq.9 objectives.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import make_model
from repro.serving import (ElasticClusterFrontend, ReplicaEngine, Request,
                           TieredQueue)
from repro.workload import DEFAULT_TIERS, TierSet, TierSpec, parse_tiers

MAX_SEQ = 64
TIERS = TierSet([
    TierSpec("premium", share=0.25, weight=5.0, ttft_target=4.0),
    TierSpec("standard", share=0.5, weight=2.0),
    TierSpec("batch", share=0.25, weight=1.0),
])


@pytest.fixture(scope="module")
def setup():
    c = get_config("granite-3-8b").reduced()
    m = make_model(c, tp=1)
    params = m.init(jax.random.PRNGKey(0), jnp.float32)
    return c, m, params


def _req(i, plen=4, n_new=3, tier=None):
    r = Request(i, [1 + (i + j) % 97 for j in range(plen)],
                max_new_tokens=n_new)
    if tier is not None:
        r.tier = tier
    return r


# ----------------------------------------------------------------- parsing
def test_parse_tiers():
    ts = parse_tiers("premium:0.2:w5:4,standard:0.5:w2,batch:0.3:w1")
    assert ts.names == ["premium", "standard", "batch"]
    assert np.allclose(ts.shares, [0.2, 0.5, 0.3])
    assert ts.weights.tolist() == [5.0, 2.0, 1.0]
    assert ts.specs[0].ttft_target == 4.0
    assert math.isinf(ts.specs[1].ttft_target)
    # priority = weight-descending; unknown names fall back to lowest tier
    assert ts.priority == [0, 1, 2]
    assert ts.rank("premium") == 0 and ts.rank("batch") == 2
    assert ts.index("no-such-tier") == ts.index("batch")
    assert len(parse_tiers("")) == 1          # default: single standard tier
    with pytest.raises(ValueError):
        parse_tiers("bad:0.5:w0")             # zero weight


def test_tier_pressure_and_slo_cost():
    tq = np.array([[4.0, 0.0], [0.0, 4.0], [0.0, 0.0]])   # (T, N)
    p = TIERS.pressure(tq)
    assert p[0] > p[1] > 0                    # premium backlog weighs more
    # single tier: pressure reduces to plain depth
    one = DEFAULT_TIERS.pressure(np.array([[3.0, 1.0]]))
    assert np.allclose(one, [3.0, 1.0])
    hi = TIERS.slo_cost({"premium": 1.0})
    lo = TIERS.slo_cost({"batch": 1.0})
    assert 0.0 < lo < hi <= 1.0
    assert TIERS.slo_cost({}) == 0.0


# ------------------------------------------------------- queue discipline
def test_premium_first_admission_ordering(setup):
    """Cold mixed queue: the first admissions are premium; standard admits
    before batch at equal banked credit."""
    c, m, params = setup
    eng = ReplicaEngine(m, params, max_batch=2, max_seq=MAX_SEQ, tiers=TIERS)
    for i in range(9):
        eng.submit(_req(i, n_new=2, tier=TIERS.names[i % 3]))
    admitted = []
    for _ in range(100):
        for r in eng.step():
            admitted.append(r)
        if eng.load == 0:
            break
    assert eng.load == 0
    admitted.sort(key=lambda r: (r.first_token_time, r.rid))
    # all premium requests (rids 0, 3, 6) beat every batch request
    prem_last = max(r.first_token_time for r in admitted
                    if r.tier == "premium")
    batch_first = min(r.first_token_time for r in admitted
                      if r.tier == "batch")
    assert admitted[0].tier == "premium"
    assert prem_last <= batch_first


def test_batch_tier_never_starves(setup):
    """Weighted-deficit fairness: under sustained premium load a batch
    request still admits within a bounded number of ticks (weight ratio
    5:1 -> roughly one batch admission per 5 premium ones)."""
    c, m, params = setup
    eng = ReplicaEngine(m, params, max_batch=2, max_seq=MAX_SEQ, tiers=TIERS)
    batch_req = _req(1000, n_new=2, tier="batch")
    batch_req.arrival = 0.0
    eng.submit(batch_req)
    rid = 0
    for _ in range(30):
        # keep the premium queue non-empty the whole time
        while sum(1 for r in eng.queue if r.tier == "premium") < 4:
            eng.submit(_req(rid, n_new=2, tier="premium"))
            rid += 1
        eng.step()
        if batch_req.first_token_time is not None:
            break
    assert batch_req.first_token_time is not None, "batch tier starved"
    assert batch_req.first_token_time <= 15.0


def test_single_tier_bit_identical(setup):
    """Parity oracle: the tiered machinery with the default single tier is
    bit-identical to itself under an explicit one-tier TierSet, and admits
    strictly FIFO (what the pre-tier scheduler did)."""
    c, m, params = setup

    def run(tiers):
        eng = ReplicaEngine(m, params, max_batch=2, max_seq=MAX_SEQ,
                            tiers=tiers)
        fin = []
        for i in range(8):
            eng.submit(_req(i, plen=3 + i % 4, n_new=3))
        for _ in range(200):
            fin.extend(eng.step())
            if eng.load == 0:
                break
        assert eng.load == 0
        return [(r.rid, tuple(r.output), r.first_token_time, r.finish_time)
                for r in sorted(fin, key=lambda r: r.rid)]

    assert run(None) == run(TierSet([TierSpec("standard")]))
    # FIFO: admission times are monotone in submit order
    times = [t for _, _, t, _ in run(None)]
    assert times == sorted(times)


# --------------------------------------------------- elastic metrics + fix
def _mk_factory(m, params, tiers, max_batch=4, chunk_len=0):
    def make_replica(rid):
        return ReplicaEngine(m, params, max_batch=max_batch, max_seq=MAX_SEQ,
                             rid=rid, tiers=tiers, chunk_len=chunk_len)
    return make_replica


def test_per_tier_metrics_plumbing(setup):
    c, m, params = setup

    rng = np.random.default_rng(0)

    def rf(rid, tick):
        return Request(rid, rng.integers(1, c.vocab_size, 5).tolist(),
                       max_new_tokens=3, tier=TIERS.sample(rng))

    fe = ElasticClusterFrontend(_mk_factory(m, params, TIERS), 2,
                                initial_replicas=1, request_factory=rf,
                                seed=0, tiers=TIERS)
    served = {n: 0 for n in TIERS.names}
    for _ in range(10):
        mm = fe.tick(3.0)
        assert mm["tier_queue"].shape == (3, 2)
        # tier breakdown must sum to the aggregate queue depths
        assert mm["tier_queue"].sum() == pytest.approx(
            fe.queue_depths().sum())
        assert mm["tier_pressure"].shape == (2,)
        assert 0.0 <= mm["tier_slo_cost"] <= 1.0
        assert sum(mm["tier_served"].values()) == int(mm["served"])
        for k, v in mm["tier_served"].items():
            served[k] += v
    assert any(served.values())
    fe.run_until_drained()


def test_starved_tier_registers_slo_cost(setup):
    """A tier with nothing *finishing* must still report SLO violation once
    its waiting requests age past the TTFT target (survivorship-bias
    regression: only counting completed requests hides exactly the state
    the tiered reward exists to penalize)."""
    c, m, params = setup
    fe = ElasticClusterFrontend(_mk_factory(m, params, TIERS, max_batch=1),
                                1, initial_replicas=1, tiers=TIERS)
    # saturate the single slot with long batch work, then park premium
    # requests in the queue past their 4-tick TTFT target
    fe.submit(_req(0, n_new=30, tier="batch"))
    fe.tick(0.0)
    for i in range(1, 4):
        fe.submit(_req(i, n_new=4, tier="premium"))
    cost = 0.0
    for _ in range(6):                     # age the queue past the target
        cost = fe.tick(0.0)["tier_slo_cost"]
    assert cost > 0.0, "starved premium tier must register SLO violation"
    fe.run_until_drained()


def test_requeue_keeps_arrival_order_mid_drain_failure(setup):
    """Regression: a failure landing while another replica drains must
    re-queue lost work at its original arrival position with its tier
    intact — not blanket-prepended/appended."""
    c, m, params = setup
    fe = ElasticClusterFrontend(_mk_factory(m, params, TIERS, max_batch=1),
                                1, initial_replicas=2, tiers=TIERS)
    reqs = []
    for t in range(3):                 # arrivals spread over distinct ticks
        for j in range(2):
            i = 2 * t + j
            r = _req(i, n_new=6, tier=TIERS.names[i % 3])
            fe.submit(r)
            reqs.append(r)
        fe.tick(0.0)
    node = fe.nodes[0]
    fe.scale_to(np.array([1]))         # drain one replica (hands queue back)
    assert len(node.draining) == 1
    fe.fail_replica(0, 0)              # mid-drain failure on the live one
    arrivals = [r.arrival for r in node.queue]
    assert arrivals == sorted(arrivals), "re-queue scrambled arrival order"
    tiers_kept = {r.rid: r.tier for r in node.queue}
    for rid, tier in tiers_kept.items():
        assert tier == TIERS.names[rid % 3], "re-queue lost the tier"
    fe.run_until_drained()
    assert all(r.done and len(r.output) == 6 for r in reqs)


def test_fleet_dispatch_bound_unchanged_under_tiers(setup):
    """Tiering costs ordering, not dispatches: a 3-tier cold burst still
    admits in ONE fleet prefill (one distinct bucket shape) and decodes in
    ONE fleet dispatch per tick, and the fleet path matches the
    per-replica oracle stream-for-stream."""
    c, m, params = setup
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, c.vocab_size, 6).tolist() for _ in range(16)]

    def burst(fleet):
        fe = ElasticClusterFrontend(
            _mk_factory(m, params, TIERS), 1, initial_replicas=2,
            max_replicas_per_node=2, seed=0, fleet_batch=fleet, tiers=TIERS)
        for i, p in enumerate(prompts):
            fe.submit(Request(i, list(p), max_new_tokens=3,
                              tier=TIERS.names[i % 3]))
        admit = fe.tick(0.0)
        decode_disp = []
        for _ in range(50):
            mm = fe.tick(0.0)
            if mm["decode_dispatches"]:
                decode_disp.append(mm["decode_dispatches"]
                                   / max(mm["fleet_groups"], 1))
            if not fe.pending and all(n.unfinished() == 0
                                      for n in fe.nodes):
                break
        return fe, admit, decode_disp

    fe_on, admit_on, dec_on = burst(True)
    fe_off, admit_off, _ = burst(False)
    assert admit_on["prefill_dispatches"] <= 1     # one distinct bucket shape
    assert admit_off["prefill_dispatches"] >= 2    # per-replica oracle
    assert dec_on and max(dec_on) <= 1.0           # ONE decode dispatch/tick
    snap = lambda fe: sorted((r.rid, tuple(r.output)) for r in fe.finished)
    assert snap(fe_on) == snap(fe_off)


# ------------------------------------------------- tier-aware chunk rules
def test_low_tier_chunk_yields_last_free_slot(setup):
    """A batch-tier chunk start must not take the last free slot while
    premium work waits (the long prefill would hold it for many ticks)."""
    c, m, params = setup
    eng = ReplicaEngine(m, params, max_batch=1, max_seq=MAX_SEQ,
                        chunk_len=8, tiers=TIERS)
    long_batch = _req(0, plen=24, n_new=2, tier="batch")
    prem = _req(1, plen=4, n_new=2, tier="premium")
    eng.submit(long_batch)
    eng.submit(prem)
    # bank enough deficit that WDRR would hand the pop to the batch tier
    eng.queue._deficit[TIERS.index("batch")] = 1.5
    eng.queue._deficit[TIERS.index("premium")] = 0.0
    plans = eng.plan_admission()
    # the single slot went to premium; the batch chunk start yielded
    assert eng.slots.count(None) == 0 or plans.bucketed or plans.singles
    admitted = [r for _, reqs in plans.bucketed for r in reqs] + \
        [r for _, r in plans.singles] + \
        [cur.req for cur in eng._chunks.values()]
    assert prem in admitted
    assert long_batch not in admitted
    assert any(r is long_batch for r in eng.queue)


def test_chunk_throttle_under_premium_decode(setup):
    """At most ONE below-decoding-tier chunk cursor advances per tick while
    a higher-tier slot is decoding (premium TBT protection); without
    pressure all cursors advance."""
    c, m, params = setup
    eng = ReplicaEngine(m, params, max_batch=3, max_seq=MAX_SEQ,
                        chunk_len=8, tiers=TIERS)
    eng.submit(_req(0, plen=4, n_new=20, tier="premium"))
    eng.submit(_req(1, plen=20, n_new=2, tier="batch"))
    eng.submit(_req(2, plen=20, n_new=2, tier="batch"))
    eng.step()
    assert len(eng._chunks) == 2 and eng.n_decoding == 1
    consumed = {s: cur.consumed for s, cur in eng._chunks.items()}
    eng.step()
    advanced = sum(1 for s, cur in eng._chunks.items()
                   if cur.consumed > consumed[s])
    assert advanced == 1, "low-tier chunk rows must throttle to one/tick"
    # no pressure (single tier): both cursors advance every tick
    eng2 = ReplicaEngine(m, params, max_batch=3, max_seq=MAX_SEQ,
                        chunk_len=8)
    eng2.submit(_req(0, plen=4, n_new=20))
    eng2.submit(_req(1, plen=20, n_new=2))
    eng2.submit(_req(2, plen=20, n_new=2))
    eng2.step()
    before = {s: cur.consumed for s, cur in eng2._chunks.items()}
    eng2.step()
    assert all(cur.consumed > before[s]
               for s, cur in eng2._chunks.items() if s in before)


# ----------------------------------------------------------- sim + reward
def test_sim_tier_queue_matches_aggregate():
    from repro.configs.paper_cluster import ClusterConfig
    from repro.sim.cluster import ClusterSim

    cfg = ClusterConfig(num_nodes=3, straggler_prob=0.0, node_mtbf=1e12)
    tiered = ClusterSim(cfg, 5.0, seed=0, failures=False,
                        heterogeneous=False, tiers=TIERS)
    plain = ClusterSim(cfg, 5.0, seed=0, failures=False,
                       heterogeneous=False)
    fr = np.full(3, 1.0 / 3, np.float32)
    for t in range(12):
        mt = tiered.tick(30.0, fr)
        mp = plain.tick(30.0, fr)
        # aggregate dynamics are untouched by the tier breakdown
        assert mt["response_time"] == pytest.approx(mp["response_time"])
        assert np.allclose(mt["queue"], mp["queue"])
        # invariant: tier queues sum to the aggregate queue
        assert np.allclose(mt["tier_queue"].sum(axis=0), mt["queue"],
                           atol=1e-4)
        assert "tier_queue" not in mp
    # premium drains first: under backlog its residual share sits below its
    # arrival share, batch above
    tq = mt["tier_queue"].sum(axis=1)
    if tq.sum() > 1.0:
        shares = tq / tq.sum()
        assert shares[0] <= TIERS.shares[0] + 1e-6
        assert shares[2] >= TIERS.shares[2] - 1e-6
    assert mt["tier_response"]["premium"] <= \
        mt["tier_response"]["batch"] + 1e-9


def test_reward_fn_tier_weighted():
    from repro.core.balancer import reward_fn

    base = reward_fn(2.0, 0.7, 1.0, 0.25, 0.1)
    assert reward_fn(2.0, 0.7, 1.0, 0.25, 0.1, slo_cost=0.0) == base
    assert reward_fn(2.0, 0.7, 1.0, 0.25, 0.1, slo_cost=0.5) < base


def test_eq9_tiered_fitness_prefers_pressured_node():
    from repro.configs.paper_cluster import ClusterConfig
    from repro.core.autoscaler import eq9_fitness, eq9_tiered_fitness

    cfg = ClusterConfig()
    demand = jnp.asarray([3.0, 3.0])
    base_ctx = (demand, jnp.asarray(1.0), jnp.float32(cfg.replica_cost),
                jnp.float32(cfg.lam), jnp.float32(cfg.target_load))
    # symmetric allocations: starve node 0 vs starve node 1
    R = jnp.asarray([[1.0, 4.0], [4.0, 1.0]])
    base = np.asarray(eq9_fitness(R, base_ctx))
    assert base[0] == pytest.approx(base[1])      # Eq.9 alone is symmetric
    pressure = jnp.asarray([1.0, 0.0])            # premium backlog on node 0
    ctx = base_ctx + (jnp.float32(cfg.slo_lam), pressure)
    tiered = np.asarray(eq9_tiered_fitness(R, ctx))
    assert tiered[0] > tiered[1], \
        "underserving the premium-heavy node must cost more"


def test_gpso_plan_accepts_pressure():
    from repro.configs.paper_cluster import ClusterConfig
    from repro.core.autoscaler import GPSOAutoscaler

    cfg = ClusterConfig(num_nodes=2, max_replicas_per_node=4,
                        min_replicas_per_node=0, ga_pop=16,
                        ga_generations=4, ga_elite=4, pso_iters=4,
                        cooldown=0)
    sc = GPSOAutoscaler(cfg, 1.0, seed=0)
    demand = np.array([2.0, 2.0], np.float32)
    cur = np.array([1, 1], np.int32)
    t0 = sc.plan(demand, 1, cur)
    t1 = sc.plan(demand, 2, cur, slo_pressure=np.array([4.0, 0.0]))
    assert t0.shape == t1.shape == (2,)
    assert (t1 >= 0).all() and (t1 <= 4).all()


# ------------------------------------------------------ tiered queue unit
def test_tiered_queue_wdrr_shares():
    """Pure queue unit: with weights 5:1 and both tiers backlogged, the
    batch tier gets ~1/6 of pops — never zero (no starvation), never more
    than its fair share plus one."""
    ts = TierSet([TierSpec("premium", weight=5.0),
                  TierSpec("batch", weight=1.0)])
    q = TieredQueue(ts)
    for i in range(60):
        q.append(Request(i, [1], tier="premium" if i < 30 else "batch"))
    pops = [q.pop().tier for _ in range(36)]
    batch_n = sum(1 for t in pops if t == "batch")
    assert pops[0] == "premium"
    assert 36 // 6 - 1 <= batch_n <= 36 // 6 + 1
    # arrival-order popleft (drain path) ignores priority
    q2 = TieredQueue(ts)
    a = Request(0, [1], tier="batch")
    b = Request(1, [1], tier="premium")
    a.arrival, b.arrival = 0.0, 1.0
    q2.append(b)
    q2.append(a)
    assert q2.popleft() is a
