"""Sequence-sharded flash-decode vs the dense oracle (8-device host mesh)."""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from repro.distributed.seq_kv import seq_sharded_flash_decode
from repro.kernels.ref import decode_attention_ref
from repro.launch.mesh import make_mesh

mesh = make_mesh((2, 4), ("data", "model"))
errs = []
for (B, Hq, KV, S, d, pos) in [(2, 8, 2, 256, 32, 100), (2, 4, 4, 512, 64, 0),
                               (4, 8, 1, 256, 32, 255)]:
    ks = jax.random.split(jax.random.PRNGKey(S + pos), 3)
    q = jax.random.normal(ks[0], (B, Hq, d))
    kc = jax.random.normal(ks[1], (B, S, KV, d))
    vc = jax.random.normal(ks[2], (B, S, KV, d))
    out = seq_sharded_flash_decode(mesh, q, kc, vc, pos)
    # oracle layout is (B, KV, S, d)
    ref = decode_attention_ref(q, kc.transpose(0, 2, 1, 3),
                               vc.transpose(0, 2, 1, 3), pos)
    errs.append(float(jnp.max(jnp.abs(out - ref))))
print(json.dumps({"errs": errs}))
"""


def test_seq_sharded_decode_matches_oracle(tmp_path):
    script = tmp_path / "run.py"
    script.write_text(_SCRIPT)
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, str(script)], capture_output=True,
                         text=True, env=env, timeout=300)
    assert out.returncode == 0, out.stderr[-3000:]
    errs = json.loads(out.stdout.strip().splitlines()[-1])["errs"]
    assert all(e < 1e-4 for e in errs), errs
