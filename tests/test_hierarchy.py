"""Decentralized two-level control (``control.hierarchy``) + the PR 10
chaos kinds.

Covers: chaos grammar for ``slow``/``plane_down``/``plane_up``;
deterministic straggler injection on both backends; capacity-lease
clamps on both backends; the per-cell reactive controller acting only
inside its lease; plane-outage semantics (lockstep view aging, no
quarantine, local scaling continues, reconcile-on-restore); and the
checkpoint/restore determinism contract — a supervisor restored mid-run
with no outage continues the exact plan stream and token streams, and a
supervisor with no controllers adds nothing to the data plane.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.paper_cluster import ClusterConfig
from repro.control import (CellController, CellLease, CellRouter,
                           ControlPlane, GlobalPlanner, MetricsView,
                           MultiCellBackend, PlaneSupervisor)
from repro.models import make_model
from repro.serving import (ChaosSchedule, ElasticClusterFrontend,
                           ReplicaEngine, Request)
from repro.sim.cluster import ClusterSim
from repro.workload import parse_tiers

MAX_SEQ = 64


@pytest.fixture(scope="module")
def setup():
    c = get_config("granite-3-8b").reduced()
    m = make_model(c, tp=1)
    params = m.init(jax.random.PRNGKey(0), jnp.float32)
    return c, m, params


def _factory(m, params, max_batch=2, tiers=None):
    def make_replica(rid):
        return ReplicaEngine(m, params, max_batch=max_batch, max_seq=MAX_SEQ,
                             rid=rid, tiers=tiers)
    return make_replica


def _req(i, plen=4, n_new=4, tier=None):
    r = Request(i, [1 + (i + j) % 97 for j in range(plen)],
                max_new_tokens=n_new)
    if tier is not None:
        r.tier = tier
    return r


def _cell(m, params, nodes=1, replicas=1, tiers=None, **kw):
    return ElasticClusterFrontend(_factory(m, params, tiers=tiers), nodes,
                                  initial_replicas=replicas, tiers=tiers,
                                  **kw)


def _view(queue=0.0, capacity=1.0, risk=0.0, staleness=0, in_flight=1):
    v = MetricsView({"queue": queue, "capacity": capacity,
                     "pressure": queue, "risk": risk,
                     "in_flight": in_flight, "active": 1,
                     "speed": 1.0, "util": 0.0}, {})
    v.staleness = staleness
    return v


def _fluid_cfg(**kw):
    kw.setdefault("num_nodes", 2)
    kw.setdefault("node_mtbf", 1e12)
    kw.setdefault("straggler_prob", 0.0)
    kw.setdefault("provisioning_delay", 1)
    kw.setdefault("max_replicas_per_node", 4)
    return ClusterConfig(**kw)


# ---------------------------------------------------------- chaos grammar
def test_chaos_parse_slow_and_plane_kinds():
    s = ChaosSchedule.parse("slow@3:n0:x4,plane_down@5:k3,plane_up@9")
    assert s.pop(3) == [("slow", 0, 4)]
    assert s.pop(5) == [("plane_down", -1, 3)]
    assert s.pop(9) == [("plane_up", -1, None)]
    # an unbounded outage carries no arg
    assert ChaosSchedule.parse("plane_down@5").pop(5) == \
        [("plane_down", -1, None)]
    with pytest.raises(ValueError, match="slow needs"):
        ChaosSchedule.parse("slow@3:n0")
    with pytest.raises(ValueError, match="targets a node"):
        ChaosSchedule.parse("slow@3:c0:x4")
    with pytest.raises(ValueError, match="only applies to slow"):
        ChaosSchedule.parse("preempt@3:n0:x4")
    with pytest.raises(ValueError, match="only applies to plane_down"):
        ChaosSchedule.parse("plane_up@9:k3")
    with pytest.raises(ValueError, match="unknown chaos event"):
        ChaosSchedule().add(1, "bogus")


# ------------------------------------------------ deterministic straggler
def test_elastic_slow_node_scales_capacity_and_clears(setup):
    c, m, params = setup
    fe = _cell(m, params)          # 1 node, 1 replica, max_batch 2
    assert fe.capacity().tolist() == [2.0]
    fe.slow_node(0, 4)
    assert fe.capacity().tolist() == [0.5]
    assert fe.node_speed.tolist() == [0.25]
    fe.slow_node(0, 1)             # x1 clears
    assert fe.capacity().tolist() == [2.0]
    with pytest.raises(ValueError, match=">= 1"):
        fe.slow_node(0, 0)
    with pytest.raises(ValueError, match="int"):
        fe.slow_node(0, 2.5)
    fe.preempt_node(0, notice=0)
    with pytest.raises(ValueError, match="down"):
        fe.slow_node(0, 2)


def test_elastic_slow_chaos_event_lands_and_clears(setup):
    c, m, params = setup
    fe = _cell(m, params,
               chaos=ChaosSchedule.parse("slow@2:n0:x4,slow@4:n0:x1"))
    fe.tick(0.0)
    assert fe.capacity().tolist() == [2.0]
    fe.tick(0.0)                   # t=2: straggler pinned
    assert fe.capacity().tolist() == [0.5]
    fe.tick(0.0)
    assert fe.capacity().tolist() == [0.5]
    fe.tick(0.0)                   # t=4: cleared
    assert fe.capacity().tolist() == [2.0]


def test_sim_slow_overlay_survives_failure_dynamics():
    sim = ClusterSim(_fluid_cfg(), 2.0, seed=0)
    base = sim.capacity().copy()
    sim.slow_node(0, 4)
    assert sim.capacity()[0] == pytest.approx(base[0] / 4)
    # _advance_failures recomputes state.slow every tick; the forced
    # overlay must persist through it
    fr = np.full(2, 0.5, np.float32)
    for _ in range(3):
        sim.tick(1.0, fr)
    assert sim.capacity()[0] == pytest.approx(base[0] / 4)
    assert sim.capacity()[1] == pytest.approx(base[1])
    sim.slow_node(0, 1)
    assert sim.capacity()[0] == pytest.approx(base[0])
    with pytest.raises(ValueError, match=">= 1"):
        sim.slow_node(0, -2)


# ----------------------------------------------------------- lease clamps
def test_elastic_lease_clamps_scale_to(setup):
    c, m, params = setup
    fe = _cell(m, params, nodes=2, max_replicas_per_node=4)
    fe.set_lease(0, 3)
    fe.scale_to([4, 4])            # wants 8, lease caps the TOTAL at 3
    assert int(fe.in_flight().sum()) == 3
    fe.set_lease(5, 8)             # lease floor pulls the total up
    fe.scale_to([0, 0])
    assert int(fe.in_flight().sum()) == 5
    fe.clear_lease()
    fe.scale_to([1, 0])
    assert int(fe.in_flight().sum()) == 1
    with pytest.raises(ValueError, match="bad lease"):
        fe.set_lease(3, 1)


def test_sim_lease_clamps_scale_to():
    sim = ClusterSim(_fluid_cfg(), 2.0, seed=0)

    def in_flight():
        s = sim.state
        return int((s.active + s.pending.sum(axis=1)).sum())

    sim.set_lease(0, 3)
    sim.scale_to(np.array([4, 4]))
    assert in_flight() == 3
    sim.set_lease(6, 8)
    sim.scale_to(np.array([1, 1]))
    assert in_flight() == 6
    sim.clear_lease()
    with pytest.raises(ValueError, match="bad lease"):
        sim.set_lease(-1, 2)


# ----------------------------------------------------- planner + controller
def test_global_planner_leases():
    p = GlobalPlanner(3, total_budget=6, max_per_cell=8, min_per_cell=1,
                      lease_slack=0.5)
    views = [_view(queue=30.0, in_flight=4), _view(queue=0.0, in_flight=1),
             _view(queue=30.0, in_flight=4)]
    alive = np.array([True, True, False])
    leases = p.plan(views, alive, np.array([4, 1, 4]))
    # dead cell: empty lease; busy cell out-budgets the idle one
    assert leases[2].astuple() == (0, 0, 0)
    assert leases[0].budget > leases[1].budget
    for lease in leases[:2]:
        assert lease.min_replicas <= lease.budget <= lease.max_replicas
        assert lease.max_replicas <= 8 and lease.min_replicas >= 1
    # a stale view's demand is confidence-discounted
    views[0].staleness = 4
    discounted = p.plan(views, alive, np.array([4, 1, 4]))
    assert discounted[0].budget < leases[0].budget
    # preemption risk discounts too
    risky = p.plan([_view(queue=30.0, risk=1.0, in_flight=4),
                    views[1], views[2]], alive, np.array([4, 1, 4]))
    assert risky[0].budget < leases[0].budget
    with pytest.raises(ValueError, match="cannot cover"):
        GlobalPlanner(4, total_budget=2, max_per_cell=4)
    with pytest.raises(ValueError, match="bad lease"):
        CellLease(3, 2, 4)


def test_cell_controller_scales_only_inside_lease():
    cells = [ClusterSim(_fluid_cfg(), 2.0, seed=s) for s in (0, 1)]
    mc = MultiCellBackend(cells)
    ctl = CellController(mc, 0, patience=1, cooldown=1)
    ctl.step()                     # no lease: a hard no-op
    assert ctl.actions == 0
    ctl.grant(CellLease(2, 5, 4))
    assert cells[0].lease == (2, 5)
    fr = np.full(2, 0.5, np.float32)
    for t in range(12):            # sustained overload on cell 0
        cells[0].state.queue[:] = 100.0
        mc.tick(0.0)
        ctl.step()
    # climbed to the lease max and STOPPED there (room existed beyond it)
    assert mc.cell_in_flight(0) == 5
    assert ctl.actions > 0 and ctl.up_actions == ctl.actions
    assert mc.local_actions_total == ctl.actions
    for t in range(12):            # sustained idleness: retire to the min
        cells[0].state.queue[:] = 0.0
        mc.tick(0.0)
        ctl.step()
    assert mc.cell_in_flight(0) == 2


# -------------------------------------------------------- plane outage
def test_router_plane_staleness_excuses_quarantine():
    r = CellRouter(2, max_staleness=2)
    views = [_view(staleness=4), _view(staleness=4)]
    alive = np.ones(2, bool)
    # same clock, no excuse: both quarantined; plane-caused: both healthy
    assert r.healthy(views, alive).tolist() == [False, False]
    assert r.healthy(views, alive, plane_staleness=4).tolist() == \
        [True, True]
    # a cell with its OWN residual staleness on top still quarantines
    views[0].staleness = 7
    assert r.healthy(views, alive, plane_staleness=4).tolist() == \
        [False, True]
    # confidence decay still uses FULL staleness: weights fall with age
    w = r.weights(np.full(2, 0.5), [_view(capacity=4.0, staleness=3),
                                    _view(capacity=4.0)],
                  alive, plane_staleness=3)
    assert 0.0 < w[0] < w[1]


def test_plane_outage_ages_views_without_quarantine():
    cells = [ClusterSim(_fluid_cfg(), 2.0, seed=s) for s in (0, 1)]
    mc = MultiCellBackend(
        cells, router=CellRouter(2, max_staleness=2),
        chaos=ChaosSchedule.parse("plane_down@2:k4"))
    stale, ups, weights = [], [], []
    for t in range(8):
        md = mc.tick(4.0)
        stale.append(int(md["plane_staleness"]))
        ups.append(md["up"].tolist())
        weights.append(md["router_weights"].copy())
    # the outage ages every view in lockstep for 4 ticks, then resets
    assert stale == [0, 1, 2, 3, 4, 0, 0, 0]
    # ... but never quarantines: both cells stay routable throughout,
    # riding capacity weights (a partition at this depth would park them)
    assert all(u == [1.0, 1.0] for u in ups)
    assert mc.quarantine_ticks == 0
    assert all(w.sum() == pytest.approx(1.0) for w in weights)
    assert mc.plane_outages == 1 and mc.plane_outage_ticks == 4
    md = mc.metrics()
    assert md["quarantined"].tolist() == [0.0, 0.0]


def test_plane_down_validation():
    mc = MultiCellBackend([ClusterSim(_fluid_cfg(), 2.0, seed=0)])
    with pytest.raises(ValueError, match="not down"):
        mc.plane_up()
    mc.plane_down(None)            # indefinite
    assert not mc.plane_alive
    with pytest.raises(ValueError, match="already down"):
        mc.plane_down(3)
    mc.plane_up()
    assert mc.plane_alive
    mc.plane_down(0)               # k0 crash is a no-op
    assert mc.plane_alive and mc.plane_outages == 1


def test_supervisor_outage_local_scaling_and_reconcile():
    """The tentpole's core claim: during a global-plane outage the cells
    keep autoscaling inside their last lease, the planner grants nothing,
    and on restore the plane reconciles with one fresh plan."""
    cells = [ClusterSim(_fluid_cfg(), 2.0, seed=s) for s in (0, 1)]
    mc = MultiCellBackend(cells,
                          chaos=ChaosSchedule.parse("plane_down@6:k6"))
    planner = GlobalPlanner(2, total_budget=8, max_per_cell=8,
                            lease_slack=0.5)
    controllers = [CellController(mc, c, patience=1, cooldown=1)
                   for c in range(2)]
    sup = PlaneSupervisor(mc, planner, controllers, plan_interval=5)
    for t in range(20):
        # calm until the outage, then a burst lands MID-OUTAGE — only
        # the local controllers can answer it
        sup.step(4.0 if t < 5 else 80.0)
    dark = set(range(6, 12))       # ticks the plane was down
    plan_ticks = [t for t, _ in sup.plan_log]
    # a plan was DUE at t=6 (interval 5, last plan t=1) — the crash
    # landing inside that tick suppresses it; none granted while dark
    assert not set(plan_ticks) & dark
    # reconcile: fresh plan the first tick back up, exactly one restore
    assert 12 in plan_ticks and sup.restores == 1
    assert sup.outage_steps == 5   # steps 7-11 observed plane_alive False
    assert mc.plane_outage_ticks == 6
    # local reactive scaling kept acting THROUGH the outage, inside leases
    dark_actions = [t for ctl in controllers for t in ctl.action_ticks
                    if t in dark]
    assert dark_actions, "controllers must act while the plane is dark"
    assert sup.local_actions() == mc.local_actions_total > 0
    for c, ctl in enumerate(controllers):
        assert ctl.lease is not None
        assert mc.cell_in_flight(c) <= ctl.lease.max_replicas
    s = sup.summary()
    assert s["plans"] == len(plan_ticks) and s["restores"] == 1


# ---------------------------------------------- checkpoint / determinism
def _fluid_hier(seed0=0, seed1=1, chaos=None):
    cells = [ClusterSim(_fluid_cfg(), 2.0, seed=s) for s in (seed0, seed1)]
    mc = MultiCellBackend(cells, chaos=chaos)
    cfg = ClusterConfig(num_nodes=2, horizon=4, forecast_window=8,
                        node_mtbf=1e12, straggler_prob=0.0)
    plane = ControlPlane(cfg, mc, balancer="rr", scaler="none",
                         unit_capacity=1.0, init_arrival=4.0)
    planner = GlobalPlanner(2, total_budget=8, max_per_cell=8)
    ctls = [CellController(mc, c) for c in range(2)]
    sup = PlaneSupervisor(mc, planner, ctls, plane=plane, plan_interval=4)
    return mc, plane, sup


def test_restore_mid_run_continues_exact_decision_stream():
    """Satellite 3: checkpoint at tick 8, hand everything global to a
    FRESHLY constructed plane + supervisor, restore, continue — the plan
    stream, balancer fractions and cluster trajectory must be identical
    to the uninterrupted run (no outage involved)."""
    rates = [4.0, 9.0, 2.0, 7.0] * 4
    mc_a, plane_a, sup_a = _fluid_hier()
    frac_a = []
    for r in rates:
        sup_a.step(r)
        frac_a.append(plane_a.fractions.copy())

    mc_b, plane_b, sup_b = _fluid_hier()
    frac_b = []
    for r in rates[:8]:
        sup_b.step(r)
        frac_b.append(plane_b.fractions.copy())
    ckpt = sup_b.checkpoint()
    # "process restart": fresh plane, planner, controllers, supervisor
    cfg = ClusterConfig(num_nodes=2, horizon=4, forecast_window=8,
                        node_mtbf=1e12, straggler_prob=0.0)
    plane_b2 = ControlPlane(cfg, mc_b, balancer="rr", scaler="none",
                            unit_capacity=1.0, init_arrival=4.0)
    sup_b2 = PlaneSupervisor(
        mc_b, GlobalPlanner(2, total_budget=8, max_per_cell=8),
        [CellController(mc_b, c) for c in range(2)],
        plane=plane_b2, plan_interval=4)
    sup_b2.restore(ckpt)
    for r in rates[8:]:
        sup_b2.step(r)
        frac_b.append(plane_b2.fractions.copy())

    assert sup_a.plan_log == sup_b.plan_log + sup_b2.plan_log
    assert all(np.array_equal(a, b) for a, b in zip(frac_a, frac_b))
    ma, mb = mc_a.metrics(), mc_b.metrics()
    assert np.array_equal(ma["queue"], mb["queue"])
    assert np.array_equal(ma["active_replicas"], mb["active_replicas"])
    assert [c.lease for c in mc_a.cells] == [c.lease for c in mc_b.cells]


def test_restore_token_digest_parity_elastic(setup):
    """Satellite 3 on the request-level backend: the restored run's token
    streams are bit-identical to the uninterrupted run's."""
    c, m, params = setup

    def build():
        mc = MultiCellBackend(
            [_cell(m, params, seed=1), _cell(m, params, seed=2)], seed=0)
        planner = GlobalPlanner(2, total_budget=4, max_per_cell=4)
        ctls = [CellController(mc, i) for i in range(2)]
        return mc, PlaneSupervisor(mc, planner, ctls, plan_interval=3)

    def drive(mc, sup, lo, hi):
        for t in range(lo, hi):
            mc.submit(_req(2 * t))
            mc.submit(_req(2 * t + 1))
            sup.step(0.0)

    mc_a, sup_a = build()
    drive(mc_a, sup_a, 0, 10)
    mc_a.run_until_drained()

    mc_b, sup_b = build()
    drive(mc_b, sup_b, 0, 5)
    ckpt = sup_b.checkpoint()
    # "process restart": fresh planner + controllers + supervisor over
    # the surviving data plane
    sup_b2 = PlaneSupervisor(
        mc_b, GlobalPlanner(2, total_budget=4, max_per_cell=4),
        [CellController(mc_b, i) for i in range(2)], plan_interval=3)
    sup_b2.restore(ckpt)
    drive(mc_b, sup_b2, 5, 10)
    mc_b.run_until_drained()

    def stream(mc):
        return sorted((r.rid, tuple(r.output)) for r in mc.finished)

    assert stream(mc_a) == stream(mc_b)
    assert sup_a.plan_log == sup_b.plan_log + sup_b2.plan_log
    assert mc_a.ledger.balanced() and mc_b.ledger.balanced()


def test_supervisor_without_controllers_is_stream_transparent(setup):
    """Chaos-off, lease-off: running the federation under a supervisor
    that grants nothing must not perturb the data plane at all — the PR 8
    digests survive the new machinery."""
    c, m, params = setup
    direct = MultiCellBackend([_cell(m, params, seed=3)])
    routed = MultiCellBackend([_cell(m, params, seed=3)])
    sup = PlaneSupervisor(routed, GlobalPlanner(1, total_budget=4,
                                                max_per_cell=4),
                          [], plan_interval=2)
    for t in range(5):
        direct.submit(_req(t))
        routed.submit(_req(t))
        md = direct.tick(0.0)
        mr = sup.step(0.0)
        assert mr["syncs"] == md["syncs"]
        assert mr["decode_dispatches"] == md["decode_dispatches"]
        assert mr["plane_staleness"] == 0.0 and mr["local_actions"] == 0.0
    direct.run_until_drained()
    routed.run_until_drained()

    def stream(mc):
        return sorted((r.rid, tuple(r.output)) for r in mc.finished)

    assert stream(direct) == stream(routed)
    assert routed.decode_dispatches() == direct.decode_dispatches()
    assert len(sup.plan_log) > 0   # it DID plan — just with no one to bind


# ----------------------------------------------- shed-retry vs cell_up race
def test_shed_retry_racing_cell_up_admitted_exactly_once(setup):
    """Satellite 2: a request shed under total overload whose backoff
    retry lands on the exact tick the blacked-out cell restores must be
    admitted exactly once — balanced ledger, double_served == 0."""
    c, m, params = setup
    tiers = parse_tiers("premium:0.5:w5:8,batch:0.5:w1")
    router = CellRouter(2, tiers=tiers, shed_threshold=1.0)
    mc = MultiCellBackend(
        [_cell(m, params, tiers=tiers, seed=1),
         _cell(m, params, tiers=tiers, seed=2)],
        tiers=tiers, router=router,
        chaos=ChaosSchedule.parse("cell_down@2:c0,cell_up@8:c0"), seed=0)
    for t in range(1, 4):          # overload the survivor through the down
        base = 10 * t
        for i in range(8):
            tier = "premium" if i % 2 == 0 else "batch"
            mc.submit(_req(base + i, n_new=4, tier=tier))
        mc.tick(0.0)
    shed_rids = [r for r, st in mc.ledger.state.items() if st == "shed"
                 and mc.ledger.tier[r] == "batch"]
    assert shed_rids, "overload must have shed batch traffic"
    rid = shed_rids[0]
    # the flash crowd is over: overload shedding disarms while the shed
    # client backs off, so its retry will be admitted
    mc.router.shed_threshold = None
    for t in range(4, 8):
        mc.tick(0.0)
    assert mc.submit(_req(rid, n_new=4, tier="batch"))   # the retry
    assert mc.ledger.state[rid] == "live"
    mc.tick(0.0)                   # t=8: cell_up fires THIS tick — the
    mc.run_until_drained()         # retry and the restore race
    assert mc.ledger.state[rid] == "finished"
    assert sum(1 for r in mc.finished if r.rid == rid) == 1
    assert mc.ledger.retries >= 1
    assert mc.ledger.double_served == 0
    assert mc.ledger.balanced()


# ------------------------------------------------------- always-on keys
def test_hierarchy_keys_zero_without_hierarchy():
    """Fluid federation, centralized mode: the PR 10 keys exist and are
    identically zero (shape-stable planner guards)."""
    mc = MultiCellBackend([ClusterSim(_fluid_cfg(), 2.0, seed=s)
                           for s in (0, 1)])
    md = mc.tick(2.0)
    assert md["plane_staleness"] == 0.0
    assert md["lease_util"].tolist() == [0.0, 0.0]
    assert md["local_actions"] == 0.0
    # with a lease granted, lease_util reports in_flight / lease max
    CellController(mc, 0).grant(CellLease(1, 8, 4))
    md = mc.tick(2.0)
    assert md["lease_util"][0] == pytest.approx(mc.cell_in_flight(0) / 8.0)
    assert md["lease_util"][1] == 0.0
