"""Paper core: GCN, DDPG, GPSO, forecaster, balancers, autoscalers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.configs.paper_cluster import ClusterConfig
from repro.core import balancer as bal
from repro.core import ddpg
from repro.core.autoscaler import (GPSOAutoscaler, HPAAutoscaler,
                                   RBASAutoscaler, eq9_fitness)
from repro.core.forecaster import (forecast, init_forecaster,
                                   last_value_baseline, train_forecaster)
from repro.core.gcn import gcn_apply, init_gcn, make_topology, \
    normalize_adjacency
from repro.core.gpso import ga_only_minimize, gpso_minimize

CFG = ClusterConfig(num_nodes=8)


# ------------------------------------------------------------------- GCN
def test_normalized_adjacency_spectrum():
    A = make_topology(12, "ring+hub")
    ah = normalize_adjacency(A)
    assert np.allclose(ah, ah.T)
    evals = np.linalg.eigvalsh(ah)
    assert evals.max() <= 1.0 + 1e-6          # Â spectral radius ≤ 1


def test_gcn_permutation_equivariance(key):
    """Relabeling nodes permutes GCN outputs accordingly."""
    n, f = 8, 5
    A = make_topology(n, "ring")
    ah = jnp.asarray(normalize_adjacency(A))
    params = init_gcn(key, f, 16, 2)
    x = jax.random.normal(key, (n, f))
    perm = np.random.default_rng(0).permutation(n)
    P = np.eye(n)[perm]
    ah_p = jnp.asarray(P @ np.asarray(ah) @ P.T)
    out = gcn_apply(params, ah, x)
    out_p = gcn_apply(params, ah_p, x[perm])
    np.testing.assert_allclose(np.asarray(out[perm]), np.asarray(out_p),
                               atol=1e-5, rtol=1e-5)


# ------------------------------------------------------------------ DDPG
def test_actor_outputs_simplex(key):
    st_ = ddpg.init_ddpg(key, 6, CFG)
    a_hat = jnp.asarray(normalize_adjacency(make_topology(8, "ring+hub")))
    obs = jax.random.normal(key, (8, 6))
    a = ddpg.actor_action(st_.actor, a_hat, obs)
    assert a.shape == (8,)
    assert float(jnp.min(a)) >= 0
    assert float(jnp.sum(a)) == pytest.approx(1.0, abs=1e-5)
    # failed nodes get zero traffic
    mask = jnp.asarray([1, 1, 0, 1, 1, 0, 1, 1], jnp.float32)
    a = ddpg.actor_action(st_.actor, a_hat, obs, up_mask=mask)
    assert float(a[2]) < 1e-6 and float(a[5]) < 1e-6
    assert float(jnp.sum(a)) == pytest.approx(1.0, abs=1e-5)


def test_ddpg_update_learns_critic(key):
    """On a fixed synthetic batch the critic loss decreases monotonically-ish."""
    feat = 6
    st_ = ddpg.init_ddpg(key, feat, CFG)
    a_hat = jnp.asarray(normalize_adjacency(make_topology(8, "ring+hub")))
    rng = np.random.default_rng(0)
    batch = (rng.normal(size=(32, 8, feat)).astype(np.float32),
             rng.dirichlet(np.ones(8), 32).astype(np.float32),
             rng.normal(size=32).astype(np.float32) * 0.1,
             rng.normal(size=(32, 8, feat)).astype(np.float32),
             np.ones((32, 8), np.float32))
    tup = (st_.actor, st_.critic, st_.actor_target, st_.critic_target)
    losses = []
    for _ in range(60):
        tup, m = ddpg.ddpg_update(tup, a_hat, batch, gamma=0.9, tau=0.05,
                                  actor_lr=1e-4, critic_lr=1e-2)
        losses.append(float(m["critic_loss"]))
    assert losses[-1] < losses[0] * 0.5, (losses[0], losses[-1])
    assert np.isfinite(losses).all()


def test_polyak_moves_target(key):
    st_ = ddpg.init_ddpg(key, 4, CFG)
    new = ddpg.polyak(st_.actor_target, jax.tree.map(lambda x: x + 1.0,
                                                     st_.actor), 0.1)
    for t, o in zip(jax.tree.leaves(new), jax.tree.leaves(st_.actor)):
        np.testing.assert_allclose(np.asarray(t), np.asarray(o) * 0.9
                                   + (np.asarray(o) + 1) * 0.1, atol=1e-6)


# ------------------------------------------------------------------ GPSO
def _sphere(x, ctx):
    return jnp.sum(jnp.square(x - 0.3), axis=-1)


def test_gpso_solves_sphere(key):
    best, cost, hist = gpso_minimize(key, _sphere, 12, CFG, lo=0.0, hi=1.0)
    assert float(cost) < 1e-2
    # history non-increasing (elitism + pbest/gbest)
    h = np.asarray(hist)
    assert (np.diff(h) <= 1e-6).all()


def test_gpso_beats_ga_only_on_eq9(key):
    demand = jnp.asarray(np.random.default_rng(0).uniform(50, 300, 8),
                         jnp.float32)
    ctx = (demand, jnp.float32(30.0), jnp.float32(1.0), jnp.float32(32.0),
           jnp.float32(0.7))
    _, c_hybrid, _ = gpso_minimize(key, eq9_fitness, 8, CFG, lo=0.0, hi=8.0,
                                   ctx=ctx)
    _, c_ga, _ = ga_only_minimize(key, eq9_fitness, 8, CFG, lo=0.0, hi=8.0,
                                  ctx=ctx)
    # same total evaluation budget: hybrid should be at least as good
    assert float(c_hybrid) <= float(c_ga) * 1.02


@given(seed=st.integers(0, 50))
@settings(max_examples=10, deadline=None)
def test_eq9_fitness_properties(seed):
    """More replicas with same demand never increases the max-load term, and
    unserved demand is penalized."""
    rng = np.random.default_rng(seed)
    demand = jnp.asarray(rng.uniform(10, 200, 6), jnp.float32)
    ctx = (demand, jnp.float32(30.0), jnp.float32(0.0), jnp.float32(10.0),
           jnp.float32(0.7))
    r_small = jnp.full((1, 6), 1.0)
    r_big = jnp.full((1, 6), 8.0)
    assert float(eq9_fitness(r_big, ctx)[0]) <= \
        float(eq9_fitness(r_small, ctx)[0])


# ------------------------------------------------------------- forecaster
def test_forecaster_beats_last_value(key):
    t = np.arange(3000, dtype=np.float32)
    sig = 1.0 + 0.5 * np.sin(2 * np.pi * t / 100)
    sig += np.random.default_rng(0).normal(0, 0.02, 3000).astype(np.float32)
    W, H = 32, 8
    xs = np.stack([sig[i:i + W, None] for i in range(2500)])
    ys = np.stack([sig[i + W:i + W + H, None] for i in range(2500)])
    params, losses = train_forecaster(key, xs, ys, 32, steps=400, lr=5e-3)
    pred = forecast(params, jnp.asarray(xs[-200:]))
    naive = last_value_baseline(jnp.asarray(xs[-200:]), H)
    mse_nn = float(jnp.mean(jnp.square(pred - ys[-200:])))
    mse_naive = float(jnp.mean(jnp.square(naive - ys[-200:])))
    assert mse_nn < 0.6 * mse_naive, (mse_nn, mse_naive)


# -------------------------------------------------------------- balancers
@given(seed=st.integers(0, 100))
@settings(max_examples=15, deadline=None)
def test_least_connections_waterfills(seed):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.uniform(0, 10, 8), jnp.float32)
    up = jnp.ones(8)
    total = float(rng.uniform(1, 50))
    fr = bal.least_connections(q, up, total)
    assert float(jnp.sum(fr)) == pytest.approx(1.0, abs=1e-4)
    assert float(jnp.min(fr)) >= -1e-6
    # post-routing queues of receiving nodes equalize at the water level
    post = np.asarray(q) + np.asarray(fr) * total
    recv = np.asarray(fr) > 1e-6
    if recv.any():
        lvl = post[recv]
        assert lvl.max() - lvl.min() < 1e-3
        # non-receiving nodes were already above the level
        if (~recv).any():
            assert post[~recv].min() >= lvl.max() - 1e-3


def test_round_robin_uniform_over_up():
    up = jnp.asarray([1, 0, 1, 1], jnp.float32)
    fr = bal.round_robin(None, up)
    np.testing.assert_allclose(np.asarray(fr), [1 / 3, 0, 1 / 3, 1 / 3],
                               atol=1e-6)


# ------------------------------------------------------------- autoscalers
def test_hpa_scales_up_on_high_util():
    h = HPAAutoscaler(CFG, target_utilization=0.6)
    cur = np.full(8, 2, np.int32)
    tgt = h.plan(np.full(8, 0.95, np.float32), 0, cur)
    assert (tgt > cur).all()


def test_hpa_stabilization_window_prevents_flapping():
    h = HPAAutoscaler(CFG, target_utilization=0.6, window=10)
    cur = np.full(8, 4, np.int32)
    h.plan(np.full(8, 0.9, np.float32), 0, cur)     # wants 6
    tgt = h.plan(np.full(8, 0.1, np.float32), 1, cur)  # wants 1, but window
    assert (tgt >= cur).all()


def test_rbas_patience_and_cooldown():
    r = RBASAutoscaler(CFG, patience=2, cooldown=5)
    cur = np.full(4, 4, np.int32)
    assert (r.plan(np.full(4, 0.9, np.float32), 0, cur) == cur).all()
    t1 = r.plan(np.full(4, 0.9, np.float32), 1, cur)
    assert (t1 == cur + 1).all()
    # cooldown blocks immediate re-scale
    for t in range(2, 5):
        assert (r.plan(np.full(4, 0.9, np.float32), t, cur) == cur).all()


def test_gpso_autoscaler_serves_demand():
    sc = GPSOAutoscaler(CFG, unit_capacity=30.0, seed=0)
    demand = np.full(8, 100.0, np.float32)
    plan = sc.plan(demand, tick=100, current=np.full(8, 1, np.int32))
    cap = plan * 30.0
    assert (cap >= demand).all()                    # no overload
    assert plan.sum() <= 8 * CFG.max_replicas_per_node
