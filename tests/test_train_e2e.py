"""End-to-end driver: short real training run (loss drops), resume works."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def _run_train(tmp_path, extra):
    env = dict(os.environ, PYTHONPATH=SRC)
    cmd = [sys.executable, "-m", "repro.launch.train", "--scale", "smoke",
           "--batch", "8", "--seq", "64", "--log-every", "20"] + extra
    out = subprocess.run(cmd, capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    return out.stdout


def test_train_loss_drops(tmp_path):
    res = tmp_path / "r.json"
    _run_train(tmp_path, ["--steps", "80", "--arch", "granite-3-8b",
                          "--out", str(res)])
    r = json.loads(res.read_text())
    # Markov corpus: loss must fall well below the start (learnable structure)
    assert r["final"] < 0.75 * r["losses"][0], (r["losses"][0], r["final"])


def test_train_resume_from_checkpoint(tmp_path):
    ck = tmp_path / "ckpt"
    _run_train(tmp_path, ["--steps", "30", "--arch", "granite-3-8b",
                          "--ckpt-dir", str(ck), "--ckpt-every", "20"])
    assert any(d.startswith("step_") for d in os.listdir(ck))
    out = _run_train(tmp_path, ["--steps", "40", "--arch", "granite-3-8b",
                                "--ckpt-dir", str(ck), "--ckpt-every", "20"])
    assert "resumed from step 20" in out
