"""Decentralized layer: gossip consensus, compression + error feedback."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.decentralized import (ErrorFeedback, disagreement,
                                      gossip_average, mixing_matrix,
                                      topk_compress)
from repro.core.gcn import make_topology


def test_mixing_matrix_doubly_stochastic():
    W = mixing_matrix(make_topology(10, "ring+hub"))
    np.testing.assert_allclose(W.sum(0), 1.0, atol=1e-5)
    np.testing.assert_allclose(W.sum(1), 1.0, atol=1e-5)
    assert np.allclose(W, W.T)
    assert (W >= -1e-9).all()


def test_gossip_converges_to_mean(key):
    n = 8
    W = mixing_matrix(make_topology(n, "ring+hub"))
    node_params = {"w": jax.random.normal(key, (n, 16, 4))}
    mean = jnp.mean(node_params["w"], axis=0)
    out = gossip_average(node_params, W, rounds=60)
    np.testing.assert_allclose(np.asarray(out["w"][0]), np.asarray(mean),
                               atol=1e-4)
    # preserves the mean exactly (doubly stochastic)
    np.testing.assert_allclose(np.asarray(jnp.mean(out["w"], axis=0)),
                               np.asarray(mean), atol=1e-5)


def test_gossip_disagreement_decays(key):
    n = 6
    W = mixing_matrix(make_topology(n, "ring"))
    p = {"w": jax.random.normal(key, (n, 32))}
    gaps = [disagreement(p)]
    for _ in range(5):
        p = gossip_average(p, W, rounds=5)
        gaps.append(disagreement(p))
    assert gaps[-1] < 0.05 * gaps[0]


def test_topk_compress_sparsity(key):
    x = jax.random.normal(key, (64, 64))
    sparse, mask = topk_compress(x, 0.05)
    kept = int(np.asarray(mask).sum())
    assert kept == int(64 * 64 * 0.05)
    # keeps the largest-magnitude entries
    thresh = np.sort(np.abs(np.asarray(x)).ravel())[-kept]
    assert float(jnp.min(jnp.abs(sparse[mask > 0]))) >= thresh - 1e-6


def _noisy_quadratic_errs(use_ef, key, steps=600, lr=0.05, k=0.05):
    """Coordinate 0 has a small, consistent gradient; the rest carry large
    zero-mean noise. Plain top-k never transmits coordinate 0 (always below
    the noise threshold); EF accumulates it until it crosses."""
    rng = np.random.default_rng(0)
    target = np.zeros(128, np.float32)
    target[0] = 1.0
    x = jnp.zeros((128,))
    ef = ErrorFeedback(k_frac=k)
    resid = ef.init({"x": x})
    for _ in range(steps):
        noise = np.zeros(128, np.float32)
        noise[1:] = rng.normal(0, 5.0, 127)
        g = {"x": (x - jnp.asarray(target)) + jnp.asarray(noise)}
        if use_ef:
            sparse, resid = ef.compress(g, resid)
        else:
            sparse = {"x": topk_compress(g["x"], k)[0]}
        x = x - lr * sparse["x"]
    return abs(float(x[0]) - 1.0)


def test_error_feedback_recovers_masked_coordinates(key):
    """EF transmits the small consistent gradient eventually -> converges on
    the masked coordinate; plain top-k stalls there. This is the property
    that makes compressed policy-sync safe at scale."""
    err_ef = _noisy_quadratic_errs(True, key)
    err_plain = _noisy_quadratic_errs(False, key)
    assert err_ef < 0.2, err_ef
    assert err_plain > 0.8, err_plain  # never updated coordinate 0
