"""Request-level engine: continuous batching exactness + frontend routing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import make_model
from repro.serving import ClusterFrontend, ReplicaEngine, Request


@pytest.fixture(scope="module")
def setup():
    c = get_config("granite-3-8b").reduced()
    m = make_model(c, tp=1)
    params = m.init(jax.random.PRNGKey(0), jnp.float32)
    return c, m, params


def _greedy_oracle(m, params, prompt, n):
    toks = list(prompt)
    for _ in range(n):
        logits, _ = m.forward(params, {"tokens": jnp.asarray([toks],
                                                             jnp.int32)})
        toks.append(int(jnp.argmax(logits[0, -1])))
    return toks[len(prompt):]


@pytest.mark.parametrize("arch", ["granite-3-8b", "mamba2-1.3b",
                                  "zamba2-2.7b"])
def test_continuous_batching_matches_sequential(arch):
    c = get_config(arch).reduced()
    m = make_model(c, tp=1)
    params = m.init(jax.random.PRNGKey(0), jnp.float32)
    eng = ReplicaEngine(m, params, max_batch=3, max_seq=64)
    rng = np.random.default_rng(1)
    reqs = [Request(i, list(rng.integers(1, 400, rng.integers(3, 9))),
                    max_new_tokens=6) for i in range(5)]
    for r in reqs:
        eng.submit(r)
    for _ in range(200):
        eng.step()
        if eng.load == 0:
            break
    assert all(r.done for r in reqs)
    for r in reqs[:3]:
        assert r.output == _greedy_oracle(m, params, r.prompt,
                                          r.max_new_tokens)


def test_slot_reuse_and_ttft_ordering(setup):
    c, m, params = setup
    eng = ReplicaEngine(m, params, max_batch=2, max_seq=64)
    reqs = [Request(i, [1, 2, 3], max_new_tokens=4) for i in range(6)]
    for r in reqs:
        eng.submit(r)
    for _ in range(100):
        eng.step()
        if eng.load == 0:
            break
    # queue order respected: earlier requests start no later
    ttfts = [r.first_token_time for r in reqs]
    assert all(a <= b for a, b in zip(ttfts, ttfts[1:]))
    assert eng.n_active == 0


def test_frontend_policies_drain(setup):
    c, m, params = setup
    for policy in ("rr", "lc"):
        engines = [ReplicaEngine(m, params, max_batch=2, max_seq=64, rid=i)
                   for i in range(2)]
        fe = ClusterFrontend(engines, policy=policy)
        for i in range(8):
            fe.submit(Request(i, [1, 2, 3, 4], max_new_tokens=3))
        fe.run_until_drained()
        assert len(fe.finished) == 8
        # both replicas did work under both policies
        assert all(e.steps > 0 for e in engines)


def test_lc_balances_load_better_than_static(setup):
    """LC routes around a busy replica."""
    c, m, params = setup
    engines = [ReplicaEngine(m, params, max_batch=2, max_seq=64, rid=i)
               for i in range(2)]
    # preload replica 0
    for i in range(4):
        engines[0].submit(Request(100 + i, [1, 2], max_new_tokens=8))
    fe = ClusterFrontend(engines, policy="lc")
    for i in range(4):
        fe.submit(Request(i, [1, 2], max_new_tokens=8))
    fe.run_until_drained()
    mine = [r for r in fe.finished if r.rid < 100]
    assert len(mine) == 4
    # the majority of frontend-routed requests should land on replica 1
    assert engines[1].steps >= engines[0].steps * 0.5
