"""Fleet-batched decode: one device dispatch per tick for the whole cluster.

Acceptance coverage for the fleet serving path:

  * fleet-vs-single parity — the same requests and seeds through per-replica
    ``step()`` and fleet-batched stepping produce identical token streams and
    finish ticks for the dense and ssm/hybrid families, including across a
    mid-run scale-up, a graceful drain, and a failure evacuation;
  * one jitted decode dispatch per fleet group per tick (4 same-model
    replicas spanning 2 nodes form ONE group);
  * slab membership churn (join mid-generation, unstack on leave);
  * the ``_admit_batch`` overflow fix (over-long prompts truncate instead of
    crashing the token-buffer copy);
  * the int8 KV-cache ``cache_dtype="int8"`` option (greedy parity with
    fp32, smaller pool bytes, rejected for stateful SSM families);
  * the measured service-rate EMA feeding the GPSO planner.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import make_model
from repro.serving import (ClusterFrontend, ElasticClusterFrontend,
                           FleetGroup, ReplicaEngine, Request)

MAX_SEQ = 64


@pytest.fixture(scope="module")
def setup():
    c = get_config("granite-3-8b").reduced()
    m = make_model(c, tp=1)
    params = m.init(jax.random.PRNGKey(0), jnp.float32)
    return c, m, params


def _make_reqs(n, n_new=6, seed=3, vocab=400):
    rng = np.random.default_rng(seed)
    return [Request(i, rng.integers(1, vocab, rng.integers(3, 9)).tolist(),
                    max_new_tokens=n_new) for i in range(n)]


def _snap(reqs):
    return {r.rid: (tuple(r.output), r.finish_time) for r in reqs}


# ------------------------------------------------------------------ parity
@pytest.mark.parametrize("arch", ["granite-3-8b", "mamba2-1.3b",
                                  "zamba2-2.7b"])
def test_fleet_matches_per_replica_across_churn(arch):
    """Same workload + seeds through fleet-batched and per-replica stepping,
    with a mid-run failure evacuation, a graceful drain (scale-down), and a
    scale-up: token streams and finish ticks must be identical."""
    c = get_config(arch).reduced()
    m = make_model(c, tp=1)
    params = m.init(jax.random.PRNGKey(0), jnp.float32)

    def factory(rid):
        return ReplicaEngine(m, params, max_batch=2, max_seq=MAX_SEQ, rid=rid)

    def run(fleet):
        fe = ElasticClusterFrontend(factory, 2, initial_replicas=2, seed=0,
                                    fleet_batch=fleet)
        reqs = _make_reqs(10)
        for r in reqs:
            fe.submit(r)
        fe.tick(0.0)
        fe.fail_replica(0, 0)            # failure: row dropped, work re-queued
        fe.tick(0.0)
        fe.scale_to(np.array([1, 1]))    # drain: member decodes until empty
        fe.tick(0.0)
        fe.scale_to(np.array([2, 2]))    # scale-up: slab rows grow
        fe.run_until_drained()
        return _snap(reqs), fe

    base, fe_off = run(False)
    fleet, fe_on = run(True)
    assert base == fleet
    assert fe_off.decode_dispatches() == 0
    assert fe_on.decode_dispatches() > 0


def test_one_dispatch_per_group_per_tick(setup):
    """4 same-model replicas across 2 nodes = ONE fleet group = ONE jitted
    decode dispatch per tick — and, under the async tick (default), at most
    ONE blocking host sync per tick (the reconcile of the previous tick's
    futures), even on ticks that also admit."""
    c, m, params = setup

    def factory(rid):
        return ReplicaEngine(m, params, max_batch=2, max_seq=MAX_SEQ, rid=rid)

    fe = ElasticClusterFrontend(factory, 2, initial_replicas=2, seed=0)
    for r in _make_reqs(16, n_new=8):
        fe.submit(r)
    mtr = fe.tick(0.0)                   # admit everywhere
    assert mtr["syncs"] <= 1             # admissions defer their sync too
    for _ in range(3):                   # saturated steady-state ticks
        mtr = fe.tick(0.0)
        assert mtr["fleet_groups"] == 1
        assert mtr["decode_dispatches"] == 1
        assert mtr["syncs"] == 1         # exactly the one reconcile
    assert len(fe.replicas) == 4
    # the eager oracle pays >= 1 sync per decode round PLUS admission
    # syncs: its total must exceed the async run's for the same workload
    fe_e = ElasticClusterFrontend(factory, 2, initial_replicas=2, seed=0,
                                  async_tick=False)
    for r in _make_reqs(16, n_new=8):
        fe_e.submit(r)
    for _ in range(4):
        mtr_e = fe_e.tick(0.0)
        assert mtr_e["syncs"] >= 1
    assert fe_e.sync_count() > fe.sync_count()


def test_fleet_join_and_leave_mid_generation(setup):
    """A standalone replica with in-flight slots joins a fleet (its cache
    rides into the slab) and later leaves (cache unstacks) without
    perturbing its greedy stream."""
    c, m, params = setup
    oracle = ReplicaEngine(m, params, max_batch=2, max_seq=MAX_SEQ)
    eng = ReplicaEngine(m, params, max_batch=2, max_seq=MAX_SEQ)
    other = ReplicaEngine(m, params, max_batch=2, max_seq=MAX_SEQ)
    reqs_o = _make_reqs(2, n_new=9)
    reqs_e = _make_reqs(2, n_new=9)
    for a, b in zip(reqs_o, reqs_e):
        oracle.submit(a)
        eng.submit(b)
    for _ in range(3):                       # standalone start
        oracle.step()
        eng.step()
    g = FleetGroup(m, params, max_batch=2, max_seq=MAX_SEQ,
                   cache_dtype=jnp.float32)
    g.add(eng)
    g.add(other)
    assert eng.cache is None and g.cap == 2
    for _ in range(3):                       # fleet middle
        oracle.step()
        eng.begin_step()
        g.decode_round()
    g.remove(eng)                            # unstack and finish standalone
    assert eng.cache is not None and eng._fleet is None
    for _ in range(30):
        oracle.step()
        eng.step()
        if eng.load == 0 and oracle.load == 0:
            break
    # identical prompts + seeds: the churned engine's streams and finish
    # clocks must match the untouched oracle's
    assert [r.output for r in reqs_e] == [r.output for r in reqs_o]
    assert [r.finish_time for r in reqs_e] == [r.finish_time for r in reqs_o]


# ------------------------------------------------- admit overflow truncation
def test_admit_truncates_overlong_prompt(setup):
    """A prompt longer than max_seq used to overflow the prefill token
    buffer; it must now keep its last max_seq-1 tokens and finish."""
    c, m, params = setup
    eng = ReplicaEngine(m, params, max_batch=2, max_seq=MAX_SEQ)
    rng = np.random.default_rng(7)
    long_prompt = rng.integers(1, 400, MAX_SEQ + 37).tolist()
    req = Request(0, long_prompt, max_new_tokens=4)
    eng.submit(req)
    for _ in range(40):
        eng.step()
        if eng.load == 0:
            break
    # finishes (the old code crashed copying into the token buffer); the
    # near-full cache legitimately retires it early via the cache-full rule
    assert req.done and 1 <= len(req.output) <= 4
    # matches running the truncated prompt explicitly
    ref = Request(1, long_prompt[-(MAX_SEQ - 1):], max_new_tokens=4)
    eng2 = ReplicaEngine(m, params, max_batch=2, max_seq=MAX_SEQ)
    eng2.submit(ref)
    for _ in range(40):
        eng2.step()
        if eng2.load == 0:
            break
    assert ref.output == req.output


# ------------------------------------------------------------- int8 KV pool
def test_int8_cache_matches_fp32_greedy(setup):
    c, m, params = setup
    prompts = [p.prompt for p in _make_reqs(4, seed=11)]

    def run(dtype):
        eng = ReplicaEngine(m, params, max_batch=2, max_seq=MAX_SEQ,
                            cache_dtype=dtype)
        reqs = [Request(i, list(p), max_new_tokens=5)
                for i, p in enumerate(prompts)]
        for r in reqs:
            eng.submit(r)
        for _ in range(60):
            eng.step()
            if eng.load == 0:
                break
        return [r.output for r in reqs]

    assert run("int8") == run(jnp.float32)


def test_int8_cache_capacity_gain(setup):
    """Same byte budget holds ~3.6x the decode slots (int8 payload + f32
    per-(token, head) scales vs f32 payload)."""
    c, m, params = setup

    def nbytes(dtype):
        st = jax.eval_shape(lambda: m.init_serve_state(4, MAX_SEQ, dtype))
        return sum(int(np.prod(l.shape)) * np.dtype(l.dtype).itemsize
                   for l in jax.tree.leaves(st))

    gain = nbytes(jnp.float32) / nbytes("int8")
    assert gain > 3.0


def test_int8_cache_rejected_for_ssm():
    c = get_config("mamba2-1.3b").reduced()
    m = make_model(c, tp=1)
    with pytest.raises(ValueError, match="int8"):
        m.init_serve_state(2, MAX_SEQ, "int8")


def test_int8_fleet_parity(setup):
    """int8 replicas fleet-batch too (the slab is just a bigger pytree)."""
    c, m, params = setup

    def factory(rid):
        return ReplicaEngine(m, params, max_batch=2, max_seq=MAX_SEQ,
                             rid=rid, cache_dtype="int8")

    def run(fleet):
        fe = ElasticClusterFrontend(factory, 1, initial_replicas=2, seed=0,
                                    fleet_batch=fleet)
        reqs = _make_reqs(6, n_new=5)
        for r in reqs:
            fe.submit(r)
        fe.run_until_drained()
        return _snap(reqs)

    assert run(True) == run(False)


# ------------------------------------------------------ measured service rate
def test_service_rate_ema_feeds_gpso_planner(setup):
    """The elastic backend measures per-replica req/tick from finished
    requests; once warm, the control plane hands it to the GPSO planner in
    place of the static unit_capacity constant."""
    from repro.configs.paper_cluster import ClusterConfig
    from repro.control import ControlPlane

    c, m, params = setup
    n_new = 4

    def factory(rid):
        return ReplicaEngine(m, params, max_batch=2, max_seq=MAX_SEQ, rid=rid)

    def request_factory(rid, tick):
        return Request(rid, [1 + rid % 50, 2, 3, 4], max_new_tokens=n_new)

    cfg = ClusterConfig(num_nodes=2, horizon=4, forecast_window=8,
                        provisioning_delay=1, max_replicas_per_node=2,
                        min_replicas_per_node=1, scale_interval=3, cooldown=6,
                        straggler_prob=0.0, node_mtbf=1e12)
    fe = ElasticClusterFrontend(factory, 2, initial_replicas=1,
                                provisioning_delay=1, max_replicas_per_node=2,
                                request_factory=request_factory, seed=0,
                                est_tokens=n_new)
    static_cap = 2.0 / n_new
    plane = ControlPlane(cfg, fe, balancer="rr", scaler="gpso",
                         unit_capacity=static_cap, seed=0, init_arrival=1.0)
    assert plane.scaler.unit_capacity == static_cap   # fallback pre-warm-up
    last = None
    for _ in range(20):
        last = plane.step(1.0)
    assert last["service_rate"] is not None and last["service_rate"] > 0
    assert plane.scaler.unit_capacity == pytest.approx(last["service_rate"])
    fe.run_until_drained()


def test_hetero_speed_masked_rounds_parity(setup):
    """Mixed replica speeds run sub-step rounds where only a subset of a
    group steps — the masked fleet kernel must leave non-stepping rows'
    state untouched (an SSM/KV state must never double-step)."""
    c, m, params = setup
    speeds = [0.5, 1.0, 2.0, 1.0]

    def factory(rid):
        return ReplicaEngine(m, params, max_batch=2, max_seq=MAX_SEQ,
                             rid=rid, speed=speeds[rid % 4])

    def run(fleet):
        fe = ElasticClusterFrontend(factory, 2, initial_replicas=2, seed=0,
                                    fleet_batch=fleet)
        reqs = _make_reqs(12, n_new=7, seed=9)
        for r in reqs:
            fe.submit(r)
        for _ in range(4):
            fe.tick(0.0)
        fe.run_until_drained()
        return _snap(reqs)

    assert run(True) == run(False)


def test_cluster_frontend_fleet_batch_parity(setup):
    """The static ClusterFrontend supports fleet batching too."""
    c, m, params = setup

    def run(fleet):
        engines = [ReplicaEngine(m, params, max_batch=2, max_seq=MAX_SEQ,
                                 rid=i) for i in range(2)]
        fe = ClusterFrontend(engines, policy="rr", fleet_batch=fleet)
        reqs = _make_reqs(6, n_new=4)
        for r in reqs:
            fe.submit(r)
        fe.run_until_drained()
        return _snap(reqs)

    assert run(True) == run(False)
