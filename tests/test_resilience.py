"""Fault-tolerance behaviors: the cluster keeps serving through failures and
adaptive balancers route around degraded capacity."""
import numpy as np
import pytest

from repro.configs.paper_cluster import ClusterConfig
from repro.core import balancer as bal
from repro.sim.cluster import ClusterSim
from repro.sim.experiment import run_episode
from repro.workload import TraceConfig, generate_trace


def test_serving_survives_repeated_failures():
    """Heavy failure injection: no work is lost and latency recovers."""
    cfg = ClusterConfig(num_nodes=8, node_mtbf=200.0, node_mttr=30.0)
    trace = generate_trace(TraceConfig(ticks=400), seed=2, load_scale=1.0)
    r = run_episode(cfg, trace, "LCA", unit_capacity=30.0, seed=3,
                    failures=True)
    s = r.summary(warmup=20)
    assert np.isfinite(list(s.values())).all()
    assert s["slo_attainment"] > 0.5   # cluster keeps serving through churn


def test_capacity_aware_beats_blind_under_stragglers():
    """With heterogeneous + straggling nodes, queue/capacity-aware balancing
    (LC) yields lower latency than capacity-blind RR — the gap the paper's
    adaptive balancer exploits."""
    cfg = ClusterConfig(num_nodes=8, straggler_prob=0.15,
                        straggler_slowdown=0.25)
    trace = generate_trace(TraceConfig(ticks=400), seed=5, load_scale=1.2)
    rr = run_episode(cfg, trace, "RRA", unit_capacity=30.0, seed=4,
                     failures=True).summary(20)
    lc = run_episode(cfg, trace, "LCA", unit_capacity=30.0, seed=4,
                     failures=True).summary(20)
    assert lc["mean_resp"] < rr["mean_resp"]


def test_rl_balancer_zeroes_failed_nodes():
    cfg = ClusterConfig(num_nodes=6)
    rl = bal.RLBalancer(cfg, 4 + cfg.horizon, seed=0)
    import jax.numpy as jnp
    obs = np.random.default_rng(0).normal(
        size=(6, 4 + cfg.horizon)).astype(np.float32)
    up = jnp.asarray([1, 1, 0, 1, 0, 1], jnp.float32)
    a = np.asarray(rl.act(jnp.asarray(obs), up))
    assert a[2] < 1e-6 and a[4] < 1e-6
    assert a.sum() == pytest.approx(1.0, abs=1e-4)


def test_retry_pool_drains_after_mass_failure():
    cfg = ClusterConfig(num_nodes=4, node_mtbf=1e12, provisioning_delay=2)
    sim = ClusterSim(cfg, 30.0, seed=0, failures=True)
    sim.state.queue[:] = 10.0
    # force a failure by hand
    sim.state.up[0] = 0.0
    sim.state.down_left[0] = 50
    sim.state.retry_pool += float(sim.state.queue[0])
    sim.state.queue[0] = 0.0
    fr = np.array([0, 1 / 3, 1 / 3, 1 / 3], np.float32)
    m = sim.tick(0.0, fr)
    assert sim.state.retry_pool == 0.0          # re-enqueued immediately
    # the failed node's work went to healthy nodes (served there or queued)
    assert m["served"] + sim.state.queue[1:].sum() == pytest.approx(
        10.0 + 30.0, rel=1e-3)
