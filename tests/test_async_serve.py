"""Overlapped async serving tick: late single sync, fused decode blocks,
device-resident operands, and the Pallas decode-attention backend.

Acceptance coverage for the async tick contract (see ``serving.engine``
module docstring):

  * **bit-parity** — async mode produces identical token streams and finish
    ticks to the eager oracle (``async_tick=False``) for the dense, ssm and
    hybrid families, including the churn matrix: failure evacuation,
    graceful drain, scale-up joins, and continuous arrivals with
    provisioning — all with device futures pending when membership changes;
  * **admission-lag bound** — a slot freed by tick t's decode is re-admitted
    at tick t+1 under a full slab, exactly like the eager path (the host
    observes device state at most one tick late, admission never lags the
    oracle);
  * **sync bound** — steady-state async ticks cost ONE blocking host sync
    (``metrics()['syncs']``) while the eager path pays one per decode round
    plus one per admission dispatch;
  * **decode_block** — K fused micro-steps per dispatch are bit-exact vs K
    single steps, drop syncs/tick below 1, and never engage while work is
    waiting (so admission latency is untouched);
  * **moe single-admit path** — exact-length admits keep parity in async
    mode (the eager single-admit sync + device-operand registration);
  * **pallas backend** — ``attn_backend="pallas"`` decodes through the
    flash-decode kernel (CPU interpret mode) with per-row cache positions,
    matching the dense einsum path stream-for-stream.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import ref
from repro.kernels.decode_attention import flash_decode
from repro.models import make_model
from repro.serving import ElasticClusterFrontend, ReplicaEngine, Request

MAX_SEQ = 64


@pytest.fixture(scope="module")
def setup():
    c = get_config("granite-3-8b").reduced()
    m = make_model(c, tp=1)
    params = m.init(jax.random.PRNGKey(0), jnp.float32)
    return c, m, params


def _make_reqs(n, n_new=6, seed=3, vocab=400):
    rng = np.random.default_rng(seed)
    return [Request(i, rng.integers(1, vocab, rng.integers(3, 9)).tolist(),
                    max_new_tokens=n_new) for i in range(n)]


def _snap(reqs):
    return {r.rid: (tuple(r.output), r.finish_time, r.first_token_time)
            for r in reqs}


def _snap_fe(fe):
    return sorted((r.rid, tuple(r.output), r.finish_time)
                  for r in fe.finished)


# ------------------------------------------------------------------ parity
@pytest.mark.parametrize("arch", ["granite-3-8b", "mamba2-1.3b",
                                  "zamba2-2.7b"])
def test_async_matches_eager_across_churn(arch):
    """Async vs eager through the full churn matrix — failure (progress
    reset with futures in flight), drain, scale-up — must be bit-identical
    in streams AND finish clocks for dense/ssm/hybrid."""
    c = get_config(arch).reduced()
    m = make_model(c, tp=1)
    params = m.init(jax.random.PRNGKey(0), jnp.float32)

    def factory(rid):
        return ReplicaEngine(m, params, max_batch=2, max_seq=MAX_SEQ,
                             rid=rid)

    def run(async_tick):
        fe = ElasticClusterFrontend(factory, 2, initial_replicas=2, seed=0,
                                    async_tick=async_tick)
        reqs = _make_reqs(10)
        for r in reqs:
            fe.submit(r)
        fe.tick(0.0)
        fe.fail_replica(0, 0)        # evacuate with decode futures pending
        fe.tick(0.0)
        fe.scale_to(np.array([1, 1]))
        fe.tick(0.0)
        fe.scale_to(np.array([2, 2]))
        fe.run_until_drained()
        return _snap(reqs), fe

    eager, fe_e = run(False)
    async_, fe_a = run(True)
    assert eager == async_
    # async mode paid strictly fewer blocking syncs for the same work
    assert fe_a.sync_count() < fe_e.sync_count()


def test_async_matches_eager_with_arrivals_and_scaling(setup):
    """Continuous arrivals + cold-start provisioning + scale-down/up churn:
    the regression scenario where a mid-tick force-flush (drained-replica
    retirement) must not lose or reorder finishes."""
    c, m, params = setup

    def factory(rid):
        return ReplicaEngine(m, params, max_batch=2, max_seq=MAX_SEQ,
                             rid=rid)

    def run(async_tick):
        def rf(rid, tick):
            return Request(rid, [1 + rid % 50, 2, 3, 4], max_new_tokens=4)

        fe = ElasticClusterFrontend(factory, 2, initial_replicas=1,
                                    provisioning_delay=2,
                                    request_factory=rf, seed=0,
                                    est_tokens=4, async_tick=async_tick)
        for t in range(24):
            fe.tick(1.6)
            if t == 5:
                fe.scale_to(np.array([2, 1]))
            if t == 12:
                fe.scale_to(np.array([2, 2]))
            if t == 18:
                fe.scale_to(np.array([1, 2]))
        fe.run_until_drained()
        return _snap_fe(fe)

    assert run(True) == run(False)


def test_moe_single_admit_async_parity():
    """moe replicas use exact-length single admits (eager per-request
    prefill sync + device-operand registration via ``write_slot``) — the
    async decode around them must still match the eager oracle."""
    c = get_config("grok-1-314b").reduced()
    m = make_model(c, tp=1)
    params = m.init(jax.random.PRNGKey(0), jnp.float32)

    def factory(rid):
        return ReplicaEngine(m, params, max_batch=2, max_seq=MAX_SEQ,
                             rid=rid)

    def run(async_tick):
        fe = ElasticClusterFrontend(factory, 1, initial_replicas=2, seed=0,
                                    async_tick=async_tick)
        reqs = _make_reqs(5, n_new=4, seed=11)
        for r in reqs:
            fe.submit(r)
        fe.run_until_drained()
        return _snap(reqs)

    assert run(True) == run(False)


# -------------------------------------------------------- admission timing
def test_admission_lag_bound_under_full_slab(setup):
    """A queued request waiting on a full slab admits on the tick right
    after a slot retires — identical to the eager oracle (retire/slot-free
    reconciles BEFORE admission planning, so the host's one-tick-stale view
    never delays an admission)."""
    c, m, params = setup

    def factory(rid):
        return ReplicaEngine(m, params, max_batch=2, max_seq=MAX_SEQ,
                             rid=rid)

    def run(async_tick):
        fe = ElasticClusterFrontend(factory, 1, initial_replicas=1, seed=0,
                                    max_replicas_per_node=1,
                                    async_tick=async_tick)
        # 2 slots; first two requests fill the slab, the third waits
        short = [Request(0, [5, 6, 7], max_new_tokens=3),
                 Request(1, [8, 9, 10], max_new_tokens=5),
                 Request(2, [11, 12, 13], max_new_tokens=3)]
        for r in short:
            fe.submit(r)
        for _ in range(20):
            fe.tick(0.0)
            if all(r.done for r in short):
                break
        return [(r.first_token_time, r.finish_time) for r in short]

    eager = run(False)
    async_ = run(True)
    assert eager == async_
    # the waiting request admitted exactly one tick after the first retire
    finish0 = eager[0][1]
    assert eager[2][0] == finish0 + 1


# ----------------------------------------------------------- sync accounting
def test_syncs_per_tick_bound(setup):
    """Steady-state async ticks cost exactly ONE blocking sync (the
    reconcile) while keeping one decode dispatch per group; the eager
    oracle pays more whenever it admits."""
    c, m, params = setup

    def factory(rid):
        return ReplicaEngine(m, params, max_batch=2, max_seq=MAX_SEQ,
                             rid=rid)

    fe = ElasticClusterFrontend(factory, 1, initial_replicas=2, seed=0,
                                async_tick=True)
    for r in _make_reqs(4, n_new=10):
        fe.submit(r)
    ticks = []
    for _ in range(30):
        mtr = fe.tick(0.0)
        ticks.append((mtr["syncs"], mtr["decode_dispatches"]))
        if not fe.pending and all(n.unfinished() == 0 for n in fe.nodes):
            break
    assert all(s <= 1 for s, _ in ticks)
    steady = [t for t in ticks if t[1] == 1]
    assert steady and all(s == 1 for s, _ in steady[1:])
    assert all(d <= 1 for _, d in ticks)


# -------------------------------------------------------------- decode block
def test_decode_block_exact_vs_single_steps(setup):
    """decode_block=4 (one fused dispatch + one (K,F,B) sync per 4 ticks)
    must be bit-exact vs single-step async AND the eager oracle, with
    strictly fewer syncs and dispatches."""
    c, m, params = setup

    def factory(rid):
        return ReplicaEngine(m, params, max_batch=2, max_seq=MAX_SEQ,
                             rid=rid)

    def run(async_tick, block=1):
        fe = ElasticClusterFrontend(factory, 1, initial_replicas=2, seed=0,
                                    async_tick=async_tick,
                                    decode_block=block)
        reqs = _make_reqs(4, n_new=12, seed=5)   # fills 2x2 slots, no queue
        for r in reqs:
            fe.submit(r)
        ticks = 0
        for _ in range(60):
            fe.tick(0.0)
            ticks += 1
            if not fe.pending and all(n.unfinished() == 0
                                      for n in fe.nodes):
                break
        return _snap(reqs), fe, ticks

    s_eager, fe_e, _ = run(False)
    s_async, fe_a, _ = run(True)
    s_block, fe_b, ticks_b = run(True, block=4)
    assert s_eager == s_async == s_block
    assert fe_b.sync_count() < fe_a.sync_count() < fe_e.sync_count()
    assert fe_b.decode_dispatches() < fe_a.decode_dispatches()
    # block mode averages under one sync AND one dispatch per tick
    assert fe_b.sync_count() / ticks_b < 1.0


def test_decode_block_admission_lag_bounded(setup):
    """A block never engages on a tick that admitted anything (pending
    admissions veto it), and queued work behind a full slab re-admits at
    the block-end reconcile — token CONTENT is identical to decode_block=1
    and the TTFT/finish lag is bounded by K-1 ticks (the documented
    latency-for-throughput trade)."""
    c, m, params = setup
    K = 4

    def factory(rid):
        return ReplicaEngine(m, params, max_batch=2, max_seq=MAX_SEQ,
                             rid=rid)

    def run(block):
        fe = ElasticClusterFrontend(factory, 1, initial_replicas=1, seed=0,
                                    max_replicas_per_node=1,
                                    async_tick=True, decode_block=block)
        reqs = _make_reqs(6, n_new=6, seed=7)    # 2 slots, 4 queued behind
        for r in reqs:
            fe.submit(r)
        fe.run_until_drained()
        return reqs, fe

    base, fe1 = run(1)
    blocked, feK = run(K)
    for rb, rk in zip(base, blocked):
        assert rb.output == rk.output            # greedy streams unchanged
        assert 0 <= rk.first_token_time - rb.first_token_time <= K - 1
        assert 0 <= rk.finish_time - rb.finish_time <= K - 1
    # the fused window really engaged: fewer syncs for the same work
    assert feK.sync_count() < fe1.sync_count()


def test_decode_block_vetoed_by_single_admits():
    """Eager single admits (moe exact-length path) bypass ``pending``; the
    ``_admitted`` flag must still veto fused-block engagement on the tick
    that admitted, keeping streams identical to decode_block=1 for a
    workload whose every admission tick also decodes."""
    c = get_config("grok-1-314b").reduced()
    m = make_model(c, tp=1)
    params = m.init(jax.random.PRNGKey(0), jnp.float32)

    def factory(rid):
        return ReplicaEngine(m, params, max_batch=2, max_seq=MAX_SEQ,
                             rid=rid)

    def run(block):
        fe = ElasticClusterFrontend(factory, 1, initial_replicas=2, seed=0,
                                    async_tick=True, decode_block=block)
        reqs = _make_reqs(4, n_new=10, seed=17)   # fills 2x2, singles-only
        for r in reqs:
            fe.submit(r)
        fe.run_until_drained()
        return reqs, fe

    base, _ = run(1)
    blocked, feK = run(4)
    for rb, rk in zip(base, blocked):
        assert rb.output == rk.output
        assert rk.first_token_time == rb.first_token_time  # admit tick
        assert 0 <= rk.finish_time - rb.finish_time <= 3   # fused windows


# ------------------------------------------------------------ chunked + tiers
def test_chunked_prefill_async_parity(setup):
    """Chunked admission (cursor advance at dispatch, final-chunk commit at
    reconcile) keeps chunk-by-chunk == single-shot parity in async mode."""
    c, m, params = setup

    def factory(rid):
        return ReplicaEngine(m, params, max_batch=2, max_seq=MAX_SEQ,
                             rid=rid, chunk_len=8)

    def run(async_tick):
        rng = np.random.default_rng(2)
        fe = ElasticClusterFrontend(factory, 1, initial_replicas=2, seed=0,
                                    async_tick=async_tick)
        reqs = [Request(i, rng.integers(1, 400, ln).tolist(),
                        max_new_tokens=4)
                for i, ln in enumerate([30, 5, 45, 6, 20, 7])]
        for r in reqs:
            fe.submit(r)
        fe.run_until_drained()
        return _snap(reqs)

    assert run(True) == run(False)


def test_tiered_async_parity(setup):
    """Weighted-deficit tiered admission reorders identically under async
    ticks (queue work is host-state, never deferred)."""
    from repro.workload import TierSet, TierSpec

    c, m, params = setup
    tiers = TierSet([TierSpec("premium", share=0.34, weight=5.0,
                              ttft_target=3.0),
                     TierSpec("standard", share=0.33, weight=2.0),
                     TierSpec("batch", share=0.33, weight=1.0)])

    def factory(rid):
        return ReplicaEngine(m, params, max_batch=2, max_seq=MAX_SEQ,
                             rid=rid, tiers=tiers)

    def run(async_tick):
        fe = ElasticClusterFrontend(factory, 1, initial_replicas=2, seed=0,
                                    async_tick=async_tick, tiers=tiers)
        reqs = _make_reqs(9, n_new=4, seed=13)
        for i, r in enumerate(reqs):
            r.tier = tiers.names[i % 3]
            fe.submit(r)
        fe.run_until_drained()
        return _snap(reqs)

    assert run(True) == run(False)


# ------------------------------------------------------------ pallas backend
@pytest.mark.parametrize("B,Hq,Hkv,S,d", [(2, 4, 2, 128, 32),
                                          (3, 4, 1, 256, 64)])
def test_flash_decode_per_row_pos(B, Hq, Hkv, S, d):
    """flash_decode now takes per-row cache lengths (the serving slot-pool
    layout): each row must match the scalar-pos reference run row by row."""
    ks = jax.random.split(jax.random.PRNGKey(B * S), 3)
    q = jax.random.normal(ks[0], (B, Hq, d), jnp.float32)
    kc = jax.random.normal(ks[1], (B, Hkv, S, d), jnp.float32)
    vc = jax.random.normal(ks[2], (B, Hkv, S, d), jnp.float32)
    pos = jnp.asarray([7 + 13 * b for b in range(B)], jnp.int32)
    out = flash_decode(q, kc, vc, pos, block_kv=128, interpret=True)
    for b in range(B):
        want = ref.decode_attention_ref(q[b:b + 1], kc[b:b + 1],
                                        vc[b:b + 1], int(pos[b]))
        np.testing.assert_allclose(np.asarray(out[b]),
                                   np.asarray(want[0]), atol=2e-5,
                                   rtol=2e-5)


def test_pallas_backend_stream_parity(setup):
    """ReplicaEngine(attn_backend="pallas") serves the same greedy streams
    as the dense einsum reference (CPU interpret mode), at mixed per-slot
    cache depths."""
    c, m, params = setup

    def run(backend):
        eng = ReplicaEngine(m, params, max_batch=2, max_seq=32,
                            attn_backend=backend)
        rng = np.random.default_rng(5)
        reqs = [Request(i, rng.integers(1, 400, 4 + 3 * i).tolist(),
                        max_new_tokens=4) for i in range(3)]
        for r in reqs:
            eng.submit(r)
        for _ in range(20):
            eng.step()
            if eng.load == 0:
                break
        return _snap(reqs)

    assert run("pallas") == run("einsum")


def test_pallas_backend_rejected_for_ssm():
    c = get_config("mamba2-1.3b").reduced()
    m = make_model(c, tp=1)
    params = m.init(jax.random.PRNGKey(0), jnp.float32)
    with pytest.raises(ValueError, match="pallas"):
        ReplicaEngine(m, params, max_batch=2, max_seq=32,
                      attn_backend="pallas")
