import dataclasses

import jax
import pytest

# NOTE: no XLA_FLAGS here — smoke tests and benches must see 1 device.
# Multi-device sharding tests spawn subprocesses that set the flag themselves.


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


def reduced_no_drop(cfg):
    """Reduced config with MoE capacity high enough that nothing drops
    (exactness tests)."""
    c = cfg.reduced()
    if c.uses_moe:
        c = dataclasses.replace(c, capacity_factor=float(c.num_experts))
    return c
