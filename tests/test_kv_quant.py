"""Int8 KV cache: roundtrip error bounds + attention-output fidelity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.serving.kv_quant import (decode_attend_quant, dequantize,
                                    init_quant_kv_cache, quantize,
                                    write_kv_quant)


@given(seed=st.integers(0, 100), scale=st.floats(0.01, 100.0))
@settings(max_examples=25, deadline=None)
def test_quantize_roundtrip_bounded(seed, scale):
    x = jax.random.normal(jax.random.PRNGKey(seed), (4, 64)) * scale
    q, s = quantize(x)
    back = dequantize(q, s)
    err = jnp.max(jnp.abs(back - x))
    # absmax int8: error <= absmax/254 per row
    bound = jnp.max(jnp.abs(x), axis=-1) / 254.0 + 1e-7
    assert float(err) <= float(jnp.max(bound)) * 1.001


def test_quantize_zero_row_safe():
    q, s = quantize(jnp.zeros((2, 8)))
    assert float(jnp.abs(dequantize(q, s)).max()) == 0.0


def test_quant_attention_close_to_exact():
    """Full decode attention over a quantized cache stays within ~1% of the
    exact bf16-cache result."""
    B, G, qpg, S, d = 2, 2, 2, 128, 32
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, G, qpg, d))
    cache = init_quant_kv_cache(B, S, G, d)
    exact_k = np.zeros((B, S, G, d), np.float32)
    exact_v = np.zeros((B, S, G, d), np.float32)
    for t in range(64):
        kt = jax.random.normal(jax.random.PRNGKey(100 + t), (B, 1, G, d))
        vt = jax.random.normal(jax.random.PRNGKey(200 + t), (B, 1, G, d))
        cache = write_kv_quant(cache, kt, vt, t)
        exact_k[:, t] = np.asarray(kt[:, 0])
        exact_v[:, t] = np.asarray(vt[:, 0])
    pos = 63
    out_q = decode_attend_quant(q, cache, pos)
    # exact reference
    s = jnp.einsum("bgqh,btgh->bgqt", q, jnp.asarray(exact_k)) / np.sqrt(d)
    mask = jnp.arange(S) <= pos
    s = jnp.where(mask[None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out_ref = jnp.einsum("bgqt,btgh->bgqh", p, jnp.asarray(exact_v))
    rel = float(jnp.max(jnp.abs(out_q - out_ref)) /
                (jnp.max(jnp.abs(out_ref)) + 1e-9))
    assert rel < 0.02, rel


def test_quant_cache_bytes_halved():
    B, S, G, d = 4, 1024, 8, 128
    c = init_quant_kv_cache(B, S, G, d)
    q_bytes = sum(np.asarray(v).nbytes for v in c.values())
    bf16_bytes = 2 * B * S * G * d * 2
    assert q_bytes < 0.6 * bf16_bytes
