"""Optional-hypothesis shim: ``from _hyp import given, settings, st``.

Tier-1 must collect (and run the non-property tests) on machines without
``hypothesis`` installed (see requirements.txt). When it is available the
property tests run as written; when it is not, ``@given`` turns the test
into a skip instead of an ImportError at collection time.
"""
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                       # pragma: no cover - env dependent
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def settings(*a, **k):
        return lambda f: f

    def given(*a, **k):
        def deco(f):
            def _skip():
                pytest.skip("hypothesis not installed")
            _skip.__name__ = f.__name__
            _skip.__doc__ = f.__doc__
            return _skip
        return deco
