"""Config registry, analytic param counts, padded-dims invariants."""
import math

import pytest
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.configs import ARCH_NAMES, SHAPES, applicable_shapes, get_config
from repro.models.dims import padded_dims, q_head_mask

EXPECTED_B = {
    "mistral-nemo-12b": 12, "qwen2.5-14b": 14, "command-r-35b": 35,
    "granite-3-8b": 8, "grok-1-314b": 314,
    "llama4-maverick-400b-a17b": 400, "zamba2-2.7b": 2.7,
    # whisper: 74M nameplate + 17M extended decoder-position table (the
    # assigned prefill_32k cell needs 32k learned positions; DESIGN.md §8)
    "internvl2-2b": 2, "mamba2-1.3b": 1.3, "whisper-base": 0.091,
}


def test_all_archs_registered():
    assert len(ARCH_NAMES) == 10
    assert set(EXPECTED_B) == set(ARCH_NAMES)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_param_count_matches_nameplate(arch):
    cfg = get_config(arch)
    n = cfg.param_count() / 1e9
    assert n == pytest.approx(EXPECTED_B[arch], rel=0.2), (arch, n)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_active_leq_total(arch):
    cfg = get_config(arch)
    assert cfg.active_param_count() <= cfg.param_count()
    if cfg.uses_moe:
        assert cfg.active_param_count() < 0.5 * cfg.param_count()


def test_applicable_shapes():
    # long_500k only for sub-quadratic archs
    longs = [a for a in ARCH_NAMES
             if SHAPES["long_500k"] in applicable_shapes(get_config(a))]
    assert sorted(longs) == ["mamba2-1.3b", "zamba2-2.7b"]
    # 40 assigned cells; 32 applicable after the directed long_500k skips
    total = sum(len(applicable_shapes(get_config(a))) for a in ARCH_NAMES)
    assert total == 32


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_padded_dims_tp16(arch):
    cfg = get_config(arch)
    d = padded_dims(cfg, tp=16)
    if cfg.num_heads == 0:
        assert d.n_q == 0
        return
    assert d.n_q % 16 == 0 and d.n_kv % 16 == 0
    assert sum(d.q_real) == cfg.num_heads           # every real head present
    assert d.vocab % 2048 == 0 and d.vocab >= cfg.vocab_size
    assert d.n_q == d.n_kv * d.q_per_group


@given(h_per_kv=st.integers(1, 8), kv=st.sampled_from([1, 2, 4, 8, 16, 32]),
       tp=st.sampled_from([1, 2, 4, 8, 16]))
@settings(max_examples=40, deadline=None)
def test_padded_dims_properties(h_per_kv, kv, tp):
    """For any GQA geometry where kv and tp are compatible, padding preserves
    the real-head count and produces tp-divisible physical heads."""
    if kv >= tp and kv % tp != 0:
        return
    if kv < tp and tp % kv != 0:
        return
    import dataclasses

    from repro.configs.base import ArchConfig
    cfg = ArchConfig(name="t", family="dense", num_layers=1, d_model=128,
                     num_heads=h_per_kv * kv, num_kv_heads=kv, d_ff=256,
                     vocab_size=1000, head_dim=32)
    d = padded_dims(cfg, tp=tp)
    assert d.n_q % tp == 0
    assert d.n_kv % tp == 0
    assert sum(d.q_real) == cfg.num_heads
    assert 0 < d.pad_flops_ratio <= 1.0
    mask = q_head_mask(d)
    assert mask.sum() == cfg.num_heads
