"""Multi-cell fault-tolerant routing plane (``control.cells``).

Covers the federation's three failure classes end to end — cell blackout
(evacuation + re-route with a single global ledger), control-plane
partition (staleness decay, reactive fallback, hard quarantine) and total
overload (tier-aware admission shedding) — plus the invariants that make
it safe to always run through the router: single-cell parity (identical
streams, zero extra syncs/dispatches), all-false-mask parking (satellite 1
of PR 8) and the always-on degraded-mode metric keys.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.paper_cluster import ClusterConfig
from repro.control import CellRouter, MetricsView, MultiCellBackend
from repro.models import make_model
from repro.serving import (ChaosSchedule, ElasticClusterFrontend,
                           ReplicaEngine, Request)
from repro.sim.cluster import ClusterSim
from repro.workload import ClientPool, parse_tiers

MAX_SEQ = 64


@pytest.fixture(scope="module")
def setup():
    c = get_config("granite-3-8b").reduced()
    m = make_model(c, tp=1)
    params = m.init(jax.random.PRNGKey(0), jnp.float32)
    return c, m, params


def _factory(m, params, max_batch=2, tiers=None):
    def make_replica(rid):
        return ReplicaEngine(m, params, max_batch=max_batch, max_seq=MAX_SEQ,
                             rid=rid, tiers=tiers)
    return make_replica


def _req(i, plen=4, n_new=4, tier=None):
    r = Request(i, [1 + (i + j) % 97 for j in range(plen)],
                max_new_tokens=n_new)
    if tier is not None:
        r.tier = tier
    return r


def _cell(m, params, nodes=1, replicas=1, tiers=None, **kw):
    return ElasticClusterFrontend(_factory(m, params, tiers=tiers), nodes,
                                  initial_replicas=replicas, tiers=tiers,
                                  **kw)


def _view(queue=0.0, capacity=1.0, pressure=None, risk=0.0, staleness=0):
    v = MetricsView({"queue": queue, "capacity": capacity,
                     "pressure": queue if pressure is None else pressure,
                     "risk": risk, "in_flight": 0, "active": 1,
                     "speed": 1.0, "util": 0.0}, {})
    v.staleness = staleness
    return v


# -------------------------------------------------------- router policy
def test_router_weights_fresh_stale_quarantined_dead():
    r = CellRouter(4, max_staleness=2, confidence_decay=0.5, risk_bias=0.8)
    views = [_view(capacity=4.0), _view(capacity=4.0, staleness=1),
             _view(capacity=4.0, staleness=3), _view(capacity=4.0)]
    alive = np.array([True, True, True, False])
    fr = np.array([0.4, 0.3, 0.2, 0.1])
    w = r.weights(fr, views, alive)
    # dead + quarantined cells carry zero weight; the rest sum to one
    assert w[2] == 0.0 and w[3] == 0.0
    assert w.sum() == pytest.approx(1.0)
    # stale cell 1 was replaced by its confidence-decayed capacity share
    # (4/8 * 0.5 = 0.25 pre-normalization, vs cell 0's learned 0.4)
    assert w[1] == pytest.approx(0.25 / (0.4 + 0.25))
    assert w[0] > w[1]
    # deeper staleness -> geometrically less weight
    views[1].staleness = 2
    w2 = r.weights(fr, views, alive)
    assert w2[1] < w[1]


def test_router_risk_bias_shifts_traffic():
    r = CellRouter(2, risk_bias=0.8)
    views = [_view(capacity=4.0, risk=1.0), _view(capacity=4.0)]
    alive = np.ones(2, bool)
    w = r.weights(np.array([0.5, 0.5]), views, alive)
    # a doomed cell (every node under notice) keeps only 1-risk_bias of
    # its weight before renormalization
    assert w[0] == pytest.approx(0.2 / 1.2)
    assert w[1] > w[0]


def test_router_all_dead_parks_not_uniform():
    """Satellite 1: an all-false healthy mask must yield uniform-over-none
    (all zeros), never a uniform split over dead cells."""
    r = CellRouter(3)
    views = [_view() for _ in range(3)]
    w = r.weights(np.full(3, 1 / 3), views, np.zeros(3, bool))
    assert w.tolist() == [0.0, 0.0, 0.0]


def test_router_static_split_ignores_health():
    r = CellRouter(2, adaptive=False)
    views = [_view(risk=1.0, staleness=9), _view()]
    w = r.weights(np.array([0.9, 0.1]), views, np.ones(2, bool))
    assert w.tolist() == [0.5, 0.5]


def test_shed_tiers_policy():
    tiers = parse_tiers("premium:0.3:w5:4,standard:0.3:w2,batch:0.4:w1")
    r = CellRouter(2, tiers=tiers, shed_threshold=2.0)
    alive = np.ones(2, bool)
    # one healthy cell has room -> no shedding (route there instead)
    views = [_view(pressure=40.0, capacity=4.0), _view(pressure=1.0,
                                                       capacity=4.0)]
    assert r.shed_tiers(views, alive) == frozenset()
    # every cell past the threshold -> lowest tier sheds first
    views = [_view(pressure=10.0, capacity=4.0),
             _view(pressure=9.0, capacity=4.0)]
    assert r.shed_tiers(views, alive) == frozenset({"batch"})
    # deeper overload escalates, but the top tier is NEVER shed
    views = [_view(pressure=400.0, capacity=4.0),
             _view(pressure=400.0, capacity=4.0)]
    assert r.shed_tiers(views, alive) == frozenset({"batch", "standard"})
    # full blackout parks instead of shedding
    assert r.shed_tiers(views, np.zeros(2, bool)) == frozenset()
    # no threshold / single tier -> disabled
    assert CellRouter(2, tiers=tiers).shed_tiers(views, alive) == frozenset()
    assert CellRouter(2, shed_threshold=2.0).shed_tiers(
        [_view(pressure=400.0, capacity=4.0)] * 2, alive) == frozenset()


# -------------------------------------------------- single-cell parity
def test_single_cell_parity_streams_and_dispatches(setup):
    """Routing one cell through the plane is free: identical token streams
    and identical sync/dispatch counts vs driving the frontend directly."""
    c, m, params = setup
    direct = _cell(m, params, nodes=2, replicas=1, seed=3)
    routed = MultiCellBackend([_cell(m, params, nodes=2, replicas=1,
                                     seed=3)])
    for t in range(4):
        for i in range(2):
            rid = 2 * t + i
            direct.submit(_req(rid))
            routed.submit(_req(rid))
        md = direct.tick(0.0)
        mr = routed.tick(0.0)
        assert mr["syncs"] == md["syncs"]
        assert mr["decode_dispatches"] == md["decode_dispatches"]
        assert mr["prefill_dispatches"] == md["prefill_dispatches"]
    direct.run_until_drained()
    routed.run_until_drained()

    def stream(fe):
        return sorted((r.rid, tuple(r.output)) for r in fe.finished)

    assert stream(routed) == stream(direct)
    assert routed.sync_count() == direct.sync_count()
    assert routed.decode_dispatches() == direct.decode_dispatches()
    assert routed.ledger.balanced() and direct.ledger.balanced()


def test_degraded_mode_keys_always_on(setup):
    """Single-cell backends emit the multi-cell keys as identical zeros
    (shape-stable planner guards — control/backend.py contract)."""
    c, m, params = setup
    fe = _cell(m, params)
    fe.submit(_req(0))
    m1 = fe.tick(0.0)
    sim = ClusterSim(ClusterConfig(num_nodes=2, node_mtbf=1e12,
                                   straggler_prob=0.0), 2.0, seed=0)
    m2 = sim.tick(1.0, np.full(2, 0.5, np.float32))
    for md in (m1, m2):
        assert md["cell_staleness"].tolist() == [0.0]
        assert md["cell_risk"].tolist() == [0.0]
        assert md["shed"] == 0.0
        # PR 10 hierarchy keys: same zeros contract
        assert md["plane_staleness"] == 0.0
        assert md["lease_util"].tolist() == [0.0]
        assert md["local_actions"] == 0.0
    fe.run_until_drained()


# ------------------------------------------------------- cell blackout
def test_blackout_evacuates_exactly_once(setup):
    """Kill a cell mid-flight under retrying clients: everything it held
    re-routes to the sibling, the single global ledger stays balanced and
    nothing is ever served twice ACROSS cells."""
    c, m, params = setup
    rng = np.random.default_rng(0)

    def request_factory(rid, tick):
        plen = int(rng.integers(2, 8))
        return Request(rid, rng.integers(1, c.vocab_size, plen).tolist(),
                       max_new_tokens=int(rng.integers(3, 8)))

    mc = MultiCellBackend(
        [_cell(m, params, seed=1), _cell(m, params, seed=2)],
        chaos=ChaosSchedule.parse("cell_down@4:c0,cell_up@10:c0"), seed=0)
    pool = ClientPool(mc, 8, request_factory=request_factory,
                      think_time=1.0, timeout=10.0, max_retries=2, seed=5)
    for t in range(16):
        pool.tick()
        mc.tick(0.0)
    pool.quiesce()
    mc.run_until_drained()
    pool.finalize()
    assert mc.cell_downs == 1
    assert mc.evacuated_total > 0            # the blackout caught real work
    b = mc.ledger.balance()
    assert b["live"] == 0 and b["double_served"] == 0
    assert mc.ledger.balanced()
    assert pool.stats["ok"] > 0
    # the two cells share ONE ledger object
    assert mc.cells[0].ledger is mc.ledger is mc.cells[1].ledger


def test_full_blackout_parks_arrivals_then_recovers(setup):
    """Satellite 1 end to end: when every cell is dark the router parks
    arrivals (zero weights, retry-pool semantics) instead of routing them
    into a dead cell, and serves them after restore."""
    c, m, params = setup
    mc = MultiCellBackend(
        [_cell(m, params, seed=1)],
        chaos=ChaosSchedule.parse("cell_down@2:c0,cell_up@5:c0"))
    for i in range(3):
        mc.submit(_req(i))
    mc.tick(0.0)
    for i in range(3, 5):
        mc.submit(_req(i))          # arrive INTO the outage
    m2 = mc.tick(0.0)               # t=2: blackout fires
    assert m2["up"].tolist() == [0.0]
    assert m2["router_weights"].tolist() == [0.0]
    assert m2["router_pending"] > 0          # parked, not lost or culled
    m3 = mc.tick(0.0)
    assert m3["router_pending"] == m2["router_pending"]
    mc.run_until_drained()
    assert sorted(r.rid for r in mc.finished) == list(range(5))
    assert mc.ledger.balanced()
    assert mc.ledger.double_served == 0


# ------------------------------------------------ partition + quarantine
def test_partition_staleness_decay_and_quarantine():
    """Fluid federation (no model forwards): a partitioned cell's view
    ages, its routing weight decays geometrically, and past max_staleness
    it is hard-quarantined (zero weight, up_mask 0) until the feed heals."""
    cfg = ClusterConfig(num_nodes=2, node_mtbf=1e12, straggler_prob=0.0)
    cells = [ClusterSim(cfg, 2.0, seed=s) for s in (0, 1)]
    mc = MultiCellBackend(
        cells, router=CellRouter(2, max_staleness=2, confidence_decay=0.5),
        chaos=ChaosSchedule.parse("partition@2:c0:k4"))
    weights, stale = [], []
    for t in range(8):
        md = mc.tick(4.0)
        weights.append(float(md["router_weights"][0]))
        stale.append(int(md["cell_staleness"][0]))
    # the feed goes dark at t=2 and ages for k=4 ticks, then heals
    assert stale == [0, 1, 2, 3, 4, 0, 0, 0]
    # weights are computed at tick START (one view-age behind the reported
    # staleness): decay while stale-but-trusted, then hard quarantine
    assert weights[2] < weights[1] and weights[3] < weights[2]
    assert weights[4] == 0.0 and weights[5] == 0.0
    # heal: the view refreshes and weight recovers
    assert weights[6] > 0.0
    assert mc.quarantine_ticks == 2
    md = mc.metrics()
    assert md["quarantined"].tolist() == [0.0, 0.0]


# ------------------------------------------------------ overload shedding
def test_overload_sheds_lowest_tier_with_ledger_terminal(setup):
    """Total overload degrades gracefully: the batch tier is admission-shed
    with an explicit retryable ledger terminal, premium keeps serving, and
    conservation still balances with the 5-state histogram."""
    c, m, params = setup
    tiers = parse_tiers("premium:0.5:w5:6,batch:0.5:w1")
    router = CellRouter(2, tiers=tiers, shed_threshold=2.0)
    mc = MultiCellBackend(
        [_cell(m, params, tiers=tiers, seed=1),
         _cell(m, params, tiers=tiers, seed=2)],
        tiers=tiers, router=router, seed=0)
    for t in range(8):
        base = 10 * t
        for i in range(10):       # ~5x the federation's capacity
            tier = "premium" if i % 2 == 0 else "batch"
            mc.submit(_req(base + i, n_new=6, tier=tier))
        mc.tick(0.0)
    assert mc.shed_total > 0
    per = mc.ledger.per_tier
    assert per["batch"]["shed"] > 0
    assert per.get("premium", {}).get("shed", 0) == 0   # top tier protected
    mc.run_until_drained()
    assert mc.ledger.double_served == 0
    bal = mc.ledger.balance()
    assert bal["live"] == 0
    assert bal["submitted"] == sum(
        bal[k] for k in ("finished", "timed_out", "abandoned", "rejected",
                         "shed"))


# --------------------------------------------------------- chaos plumbing
def test_cell_chaos_validation_and_filtering(setup):
    c, m, params = setup
    mc = MultiCellBackend([_cell(m, params)])
    with pytest.raises(ValueError, match="out of range"):
        mc.cell_down(3)
    with pytest.raises(ValueError, match="not down"):
        mc.cell_up(0)
    mc.cell_down(0)
    with pytest.raises(ValueError, match="already down"):
        mc.cell_down(0)
    mc.cell_up(0)
    # node-kind events in a shared schedule are ignored by the router
    # (they belong to the cells) and cell kinds by the cells
    mc2 = MultiCellBackend(
        [_cell(m, params, chaos=ChaosSchedule.parse("preempt@1:n0:k1"))],
        chaos=ChaosSchedule.parse("preempt@1:n0:k1"))
    mc2.submit(_req(0))
    mc2.tick(0.0)
    assert mc2._alive.tolist() == [True]     # router skipped the node event
    assert mc2.cells[0].preempt_risk().tolist() == [1.0]  # cell applied it
    mc2.run_until_drained()
    assert mc2.ledger.balanced()
