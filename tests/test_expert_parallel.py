"""Expert parallelism: EP-sharded training step is numerically equivalent to
the baseline sharding (same params, same batch) on a real 8-device mesh."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import dataclasses
from repro.configs import get_config
from repro.distributed.sharding import (ShardPlan, batch_shardings,
                                        make_shard_fn, param_shardings)
from repro.launch.mesh import make_mesh, parse_mesh_spec
from repro.models.model import make_model, make_train_step
from repro.models.optim import AdamW

cfg = get_config("llama4-maverick-400b-a17b").reduced()
cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.num_experts))
model = make_model(cfg, tp=2)
params = model.init(jax.random.PRNGKey(0), jnp.float32)
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                      cfg.vocab_size)}
opt = AdamW(lr=1e-3)

losses = {}
for tag, mesh, es in [
    ("baseline", make_mesh((4, 2), ("data", "model")), "none"),
    ("ep_data", make_mesh((4, 2), ("data", "model")), "data"),
    ("ep_mesh", parse_mesh_spec("2x2x2:data,expert,model"), "none"),
]:
    plan = ShardPlan(mesh, "train", expert_sharding=es)
    p = jax.device_put(params, param_shardings(plan, params))
    o = jax.device_put(opt.init(params),
                       {"mu": param_shardings(plan, params),
                        "nu": param_shardings(plan, params),
                        "step": jax.sharding.NamedSharding(
                            mesh, jax.sharding.PartitionSpec())})
    b = jax.device_put(batch, batch_shardings(plan, batch))
    step = jax.jit(make_train_step(model, opt, shard_fn=make_shard_fn(plan)))
    p2, o2, m = step(p, o, b)
    losses[tag] = float(m["loss"])
print(json.dumps(losses))
"""


def test_ep_equivalent_to_baseline(tmp_path):
    script = tmp_path / "run.py"
    script.write_text(_SCRIPT)
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, str(script)], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    losses = json.loads(out.stdout.strip().splitlines()[-1])
    base = losses["baseline"]
    assert np.isfinite(base)
    # EP variants compute the SAME math, only sharded differently
    assert losses["ep_data"] == pytest.approx(base, rel=1e-4)
    assert losses["ep_mesh"] == pytest.approx(base, rel=1e-4)
