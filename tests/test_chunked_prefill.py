"""Chunked prefill + fleet-batched admission: the serving admission path.

Acceptance coverage:

  * chunked-vs-single-shot prefill parity for dense / ssm / hybrid, with
    prompt lengths straddling chunk boundaries (C-1, C, C+1, multiples) and
    the ``max_seq - 1`` truncation edge;
  * decode interleaving — a mid-chunk slot is held out of decode (``hold``
    mask) so a concurrent short request's stream and finish ticks are
    untouched by a long prompt streaming in;
  * fleet-batched prefill parity (one vmapped dispatch per distinct bucket
    shape vs per-replica admission) and the ``prefill_dispatches`` metric
    bound: dispatches per tick <= distinct (bucket_batch, bucket_len)
    shapes, not O(replicas);
  * chunked admission inside a fleet across churn (failure, drain,
    scale-up);
  * moe replicas default to the exact-length single-admit path (bucketed
    padding changes expert-capacity drops);
  * the deduped retrace accounting counts the fleet/chunk prefill kernel
    variants.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import make_model
from repro.serving import (ElasticClusterFrontend, ReplicaEngine, Request,
                           total_prefill_traces, total_serve_traces)

MAX_SEQ = 64
CHUNK = 8


@pytest.fixture(scope="module")
def setup():
    c = get_config("granite-3-8b").reduced()
    m = make_model(c, tp=1)
    params = m.init(jax.random.PRNGKey(0), jnp.float32)
    return c, m, params


def _reqs(lens, n_new=5, seed=5, vocab=400):
    rng = np.random.default_rng(seed)
    return [Request(i, rng.integers(1, vocab, L).tolist(),
                    max_new_tokens=n_new) for i, L in enumerate(lens)]


def _snap(reqs):
    return {r.rid: (tuple(r.output), r.finish_time) for r in reqs}


# ------------------------------------------------- chunked vs single-shot
@pytest.mark.parametrize("arch", ["granite-3-8b", "mamba2-1.3b",
                                  "zamba2-2.7b"])
def test_chunked_matches_single_shot(arch):
    """Prompt lengths straddling chunk boundaries (C±1, multiples) and the
    max_seq-1 truncation edge: token streams must match single-shot
    prefill."""
    c = get_config(arch).reduced()
    m = make_model(c, tp=1)
    params = m.init(jax.random.PRNGKey(0), jnp.float32)
    lens = [CHUNK - 1, CHUNK, CHUNK + 1, 2 * CHUNK, 2 * CHUNK + 1, 30,
            MAX_SEQ + 13]          # last one truncates to max_seq-1

    def run(chunk_len):
        eng = ReplicaEngine(m, params, max_batch=4, max_seq=MAX_SEQ,
                            chunk_len=chunk_len)
        reqs = _reqs(lens)
        for r in reqs:
            eng.submit(r)
        for _ in range(200):
            eng.step()
            if eng.load == 0:
                break
        assert eng.load == 0
        return [r.output for r in reqs]

    assert run(CHUNK) == run(0)


def test_chunking_does_not_perturb_concurrent_decode(setup):
    """While a long prompt streams in chunks, a short request sharing the
    engine decodes every tick with its state untouched (the hold mask):
    stream AND finish tick match a solo run."""
    c, m, params = setup
    rng = np.random.default_rng(11)
    long_prompt = rng.integers(1, 400, 40).tolist()
    short_prompt = rng.integers(1, 400, 4).tolist()

    def run(with_long):
        eng = ReplicaEngine(m, params, max_batch=2, max_seq=MAX_SEQ,
                            chunk_len=CHUNK)
        short = Request(0, list(short_prompt), max_new_tokens=8)
        eng.submit(short)
        if with_long:
            eng.submit(Request(1, list(long_prompt), max_new_tokens=4))
        for _ in range(60):
            eng.step()
            if eng.load == 0:
                break
        assert eng.load == 0
        return short.output, short.finish_time

    assert run(True) == run(False)


def test_chunked_ttft_spreads_over_ticks(setup):
    """A chunked long prompt produces its first token after ceil(len/C)
    engine steps — admission work is spread instead of front-loaded."""
    c, m, params = setup
    plen = 3 * CHUNK + 2           # 4 chunks
    req = Request(0, np.random.default_rng(0).integers(
        1, 400, plen).tolist(), max_new_tokens=3)
    eng = ReplicaEngine(m, params, max_batch=2, max_seq=MAX_SEQ,
                        chunk_len=CHUNK)
    eng.submit(req)
    for _ in range(30):
        eng.step()
        if eng.load == 0:
            break
    assert req.done
    assert req.first_token_time == pytest.approx(4.0)   # ceil(26/8) ticks


# ------------------------------------------------- fleet-batched admission
def test_fleet_prefill_parity_and_dispatch_bound(setup):
    """4 same-model replicas across 2 nodes: same-bucket admits collapse to
    one vmapped prefill dispatch per distinct (kb, sb) shape — never one per
    replica — with streams and finish ticks identical to per-replica
    admission."""
    c, m, params = setup

    def factory(rid):
        return ReplicaEngine(m, params, max_batch=2, max_seq=MAX_SEQ,
                             rid=rid)

    def run(fp):
        fe = ElasticClusterFrontend(factory, 2, initial_replicas=2, seed=0,
                                    fleet_prefill=fp)
        # equal lengths -> one (kb, sb) shape once every replica admits a
        # full pair
        reqs = _reqs([6] * 8, n_new=4, seed=2)
        for r in reqs:
            fe.submit(r)
        mtr = fe.tick(0.0)
        fe.run_until_drained()
        return _snap(reqs), mtr, fe

    s_on, m_on, fe_on = run(True)
    s_off, m_off, fe_off = run(False)
    assert s_on == s_off
    # admission tick: <= 2 distinct shapes (kb in {1,2} x one sb bucket);
    # the per-replica oracle pays one dispatch per admitting replica
    assert 1 <= m_on["prefill_dispatches"] <= 2
    assert m_off["prefill_dispatches"] == 4
    assert fe_on.prefill_dispatches() < fe_off.prefill_dispatches()


def test_fleet_chunked_parity_across_churn():
    """Chunked admission inside a fleet survives failure, drain and
    scale-up with streams + finish ticks identical to the per-replica
    path (hybrid: carried ssm/conv state AND offset KV writes)."""
    c = get_config("zamba2-2.7b").reduced()
    m = make_model(c, tp=1)
    params = m.init(jax.random.PRNGKey(0), jnp.float32)

    def factory(rid):
        return ReplicaEngine(m, params, max_batch=2, max_seq=MAX_SEQ,
                             rid=rid, chunk_len=CHUNK)

    def run(fleet):
        fe = ElasticClusterFrontend(factory, 2, initial_replicas=2, seed=0,
                                    fleet_batch=fleet)
        rng = np.random.default_rng(9)
        reqs = [Request(i, rng.integers(1, 400,
                                        int(rng.integers(3, 40))).tolist(),
                        max_new_tokens=6) for i in range(10)]
        for r in reqs:
            fe.submit(r)
        fe.tick(0.0)
        fe.fail_replica(0, 0)
        fe.tick(0.0)
        fe.scale_to(np.array([1, 1]))
        fe.tick(0.0)
        fe.scale_to(np.array([2, 2]))
        fe.run_until_drained()
        return _snap(reqs)

    assert run(True) == run(False)


# --------------------------------------------------------- moe exactness
def test_moe_defaults_to_exact_length_admission():
    """MoE replicas skip the bucketed path by default: expert capacity
    scales with the padded bucket, so padded prefill can drop different
    tokens than the per-prompt oracle. Exact-length single admits match the
    full-forward greedy oracle."""
    c = get_config("grok-1-314b").reduced()
    m = make_model(c, tp=1)
    params = m.init(jax.random.PRNGKey(0), jnp.float32)
    eng = ReplicaEngine(m, params, max_batch=2, max_seq=MAX_SEQ)
    assert not eng.bucket_prompts          # moe -> exact-length by default
    assert eng.chunk_len == 0              # and no chunked admission
    rng = np.random.default_rng(4)
    reqs = [Request(i, rng.integers(1, 400, 5 + i).tolist(),
                    max_new_tokens=4) for i in range(2)]
    for r in reqs:
        eng.submit(r)
    for _ in range(40):
        eng.step()
        if eng.load == 0:
            break
    assert eng.load == 0
    # first-token parity with the full-forward oracle: exact-length prefill
    # runs the same shapes as the oracle, so capacity drops match. (Later
    # decode tokens are inherently incomparable for moe — the oracle
    # recomputes the whole sequence so its capacity grows with it, while
    # decode routes one token at a time.)
    for r in reqs:
        logits, _ = m.forward(
            params, {"tokens": jnp.asarray([r.prompt], jnp.int32)})
        assert r.output[0] == int(jnp.argmax(logits[0, -1]))


# ----------------------------------------------------- trace accounting
def test_trace_accounting_counts_fleet_and_chunk_variants(setup):
    """total_prefill_traces must include the fleet_prefill / chunk kernel
    compilations (deduped via the shared kernel object), and the full serve
    accounting also covers the decode variants."""
    c, m, params = setup

    def factory(rid):
        return ReplicaEngine(m, params, max_batch=2, max_seq=MAX_SEQ,
                             rid=rid, chunk_len=CHUNK)

    fe = ElasticClusterFrontend(factory, 1, initial_replicas=2, seed=0)
    for r in _reqs([6, 6, 20, 20], n_new=4, seed=7):
        fe.submit(r)
    fe.run_until_drained()
    engines = fe.replicas
    counts = engines[0]._kernels.trace_counts

    def n(*variants):        # async mode compiles the afleet_* twins
        return sum(counts.get(v, 0) for v in variants)

    assert n("fleet_prefill", "afleet_prefill") >= 1
    assert n("fleet_chunk", "afleet_chunk") >= 1
    assert fe.prefill_retraces() == total_prefill_traces(engines)
    assert total_prefill_traces(engines) >= \
        n("fleet_prefill", "afleet_prefill", "fleet_chunk", "afleet_chunk")
    # the all-variant accounting additionally covers decode kernels
    assert total_serve_traces(engines) >= \
        total_prefill_traces(engines) + n("fleet", "afleet")
    assert fe.serve_kernel_traces() == total_serve_traces(engines)
