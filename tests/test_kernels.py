"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import flash_decode
from repro.kernels.flash_attention import flash_attention
from repro.kernels.gcn_fused import gcn_layer
from repro.kernels.ssd_scan import ssd_scan

TOLS = {jnp.float32: dict(atol=2e-5, rtol=2e-5),
        jnp.bfloat16: dict(atol=3e-2, rtol=3e-2)}


@pytest.mark.parametrize("B,Hq,Hkv,S,d", [
    (1, 4, 4, 128, 64), (2, 4, 2, 256, 64), (1, 8, 1, 256, 128),
    (2, 6, 2, 384, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(B, Hq, Hkv, S, d, dtype, causal):
    ks = jax.random.split(jax.random.PRNGKey(B * S + d), 3)
    q = jax.random.normal(ks[0], (B, Hq, S, d), dtype)
    k = jax.random.normal(ks[1], (B, Hkv, S, d), dtype)
    v = jax.random.normal(ks[2], (B, Hkv, S, d), dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_kv=128,
                          interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **TOLS[dtype])


@pytest.mark.parametrize("B,Hq,Hkv,S,d,pos", [
    (1, 4, 4, 256, 64, 0), (2, 4, 2, 512, 64, 100), (1, 8, 1, 256, 128, 255),
    (3, 4, 1, 512, 32, 384),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_sweep(B, Hq, Hkv, S, d, pos, dtype):
    ks = jax.random.split(jax.random.PRNGKey(pos + S), 3)
    q = jax.random.normal(ks[0], (B, Hq, d), dtype)
    kc = jax.random.normal(ks[1], (B, Hkv, S, d), dtype)
    vc = jax.random.normal(ks[2], (B, Hkv, S, d), dtype)
    out = flash_decode(q, kc, vc, pos, block_kv=128, interpret=True)
    want = ref.decode_attention_ref(q, kc, vc, pos)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **TOLS[dtype])


@pytest.mark.parametrize("B,T,H,P,N,chunk", [
    (1, 64, 2, 16, 8, 32), (2, 256, 4, 32, 16, 64), (1, 128, 8, 16, 32, 32),
])
def test_ssd_scan_sweep(B, T, H, P, N, chunk):
    ks = jax.random.split(jax.random.PRNGKey(T + N), 4)
    x = jax.random.normal(ks[0], (B, T, H, P))
    a = -jnp.abs(jax.random.normal(ks[1], (B, T, H))) * 0.2
    Bm = jax.random.normal(ks[2], (B, T, N))
    Cm = jax.random.normal(ks[3], (B, T, N))
    y, st = ssd_scan(x, a, Bm, Cm, chunk=chunk, interpret=True)
    y_ref, st_ref = ref.ssd_scan_ref(x, a, Bm, Cm, chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref),
                               atol=1e-4, rtol=1e-4)


def test_ssd_kernel_matches_model_path():
    """Kernel agrees with the model's lax.scan SSD (dt folded, A=0 case and
    general case)."""
    from repro.models.ssd import ssd_chunked
    B, T, H, P, N = 2, 128, 4, 16, 8
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    x = jax.random.normal(ks[0], (B, T, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, T, N))
    Cm = jax.random.normal(ks[4], (B, T, N))
    # model path computes y from (x, dt, A); kernel takes pre-folded inputs
    y_model, st_model = ssd_chunked(x, dt, A, Bm[:, :, None, :],
                                    Cm[:, :, None, :], 32)
    xdt = x * dt[..., None]
    a = dt * A[None, None, :]
    y_k, st_k = ssd_scan(xdt, a, Bm, Cm, chunk=32, interpret=True)
    np.testing.assert_allclose(np.asarray(y_model), np.asarray(y_k),
                               atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(st_model), np.asarray(st_k),
                               atol=1e-3, rtol=1e-3)


@pytest.mark.parametrize("N,F,H", [(8, 12, 16), (16, 36, 64), (32, 8, 8)])
@pytest.mark.parametrize("relu", [True, False])
def test_gcn_fused_sweep(N, F, H, relu):
    ks = jax.random.split(jax.random.PRNGKey(N * F), 4)
    A = jax.random.uniform(ks[0], (N, N))
    X = jax.random.normal(ks[1], (N, F))
    W = jax.random.normal(ks[2], (F, H))
    b = jax.random.normal(ks[3], (H,))
    out = gcn_layer(A, X, W, b, relu=relu, interpret=True)
    want = ref.gcn_layer_ref(A, X, W, b, relu=relu)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_gcn_kernel_matches_module():
    """Fused kernel == repro.core.gcn layer math (Eq.6 with Â precomputed)."""
    from repro.core.gcn import gcn_apply, init_gcn, make_topology, \
        normalize_adjacency
    key = jax.random.PRNGKey(0)
    a_hat = jnp.asarray(normalize_adjacency(make_topology(12, "ring+hub")))
    params = init_gcn(key, 6, 16, 1)
    x = jax.random.normal(key, (12, 6))
    want = gcn_apply(params, a_hat, x, final_activation=jax.nn.relu)
    got = gcn_layer(a_hat, x, params["w"][0], params["b"][0], relu=True,
                    interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)
