"""Control-plane integration: episodes run, methods differ sensibly, and the
end-to-end OURS pipeline (forecast + MADRL + GPSO) beats static baselines on
a stressed trace."""
import numpy as np
import pytest

from repro.configs.paper_cluster import ClusterConfig
from repro.core import balancer as bal
from repro.sim.experiment import METHOD_SPECS, run_episode
from repro.workload import TraceConfig, generate_trace

CFG = ClusterConfig(num_nodes=6)
TRACE = generate_trace(TraceConfig(ticks=250), seed=0, load_scale=1.8)


@pytest.mark.parametrize("method", ["RRA", "LCA", "HPA", "RBAS"])
def test_episode_runs(method):
    r = run_episode(CFG, TRACE, method, unit_capacity=30.0, seed=1)
    s = r.summary(warmup=20)
    assert np.isfinite(list(s.values())).all()
    assert 0 <= s["mean_util"] <= 1
    assert s["cost"] > 0


def test_ours_untrained_runs_and_scales():
    rl = bal.RLBalancer(CFG, 4 + CFG.horizon, seed=0)
    r = run_episode(CFG, TRACE, "OURS", unit_capacity=30.0, rl=rl, seed=1)
    s = r.summary(warmup=20)
    assert np.isfinite(list(s.values())).all()
    # the autoscaler must have acted (cost differs from the static profile)
    static = run_episode(CFG, TRACE, "RRA", unit_capacity=30.0, seed=1)
    assert s["cost"] != static.summary(20)["cost"]


def test_autoscaled_beats_static_on_latency_under_load():
    """At 1.8x load the static 4-replica cluster saturates; any working
    autoscaler (incl. ours) must cut response time substantially."""
    rl = bal.RLBalancer(CFG, 4 + CFG.horizon, seed=0)
    ours = run_episode(CFG, TRACE, "OURS", unit_capacity=30.0, rl=rl,
                       seed=1).summary(20)
    rra = run_episode(CFG, TRACE, "RRA", unit_capacity=30.0,
                      seed=1).summary(20)
    assert ours["mean_resp"] < 0.72 * rra["mean_resp"]  # ≥28% faster (paper)
    assert ours["scaling_efficiency"] > 0


def test_rl_training_improves_or_holds_reward():
    """DDPG training on the sim is stable (no NaN) and the critic learns."""
    rl = bal.RLBalancer(CFG, 4 + CFG.horizon, seed=0)
    tr = generate_trace(TraceConfig(ticks=150), seed=3, load_scale=1.5)
    run_episode(CFG, tr, "OURS", unit_capacity=30.0, rl=rl, train_rl=True,
                explore=True, failures=False, seed=2)
    m = rl.train_step()
    assert np.isfinite(m.get("critic_loss", 0.0))
    import jax.numpy as jnp
    obs = np.random.default_rng(0).normal(
        size=(CFG.num_nodes, 4 + CFG.horizon)).astype(np.float32)
    a = rl.act(jnp.asarray(obs), jnp.ones(CFG.num_nodes))
    assert float(jnp.sum(a)) == pytest.approx(1.0, abs=1e-4)
    assert bool(jnp.isfinite(a).all())


def test_methods_registered():
    for m in ("RRA", "LCA", "HPA", "RBAS", "OURS"):
        assert m in METHOD_SPECS
