"""Sharded fleet slab: F replicas decode on N devices, bit-identically.

Acceptance coverage for the fleet-mesh serving path (ISSUE 6):

  * sharded == unsharded parity — identical token streams and finish clocks
    through a 4-device ``('fleet',)`` mesh for the dense, ssm and hybrid
    families, across the churn matrix (mid-run failure evacuation, graceful
    drain, scale-up) and for the async ``decode_block=4``, chunked-prefill
    and SLO-tier modes;
  * the dispatch/sync contract survives sharding: still ONE logical decode
    dispatch per fleet group per tick and at most ONE blocking reconcile
    sync per tick (GSPMD partitions the dispatch; it must not multiply it);
  * pow2 growth keeps the fleet axis divisible by the shard count
    (3 -> 4 -> 8 members under 4 devices allocates caps 4, 4, 8) with pad
    rows masked inactive and excluded from dispatch/retire accounting
    (dispatch counts match the unsharded oracle exactly);
  * slab + operand shardings stay pinned to the fleet axis through donated
    dispatches, churn backfills and slab growth (no silent re-gather).

Multi-device CPU needs ``--xla_force_host_platform_device_count`` set
before jax's backend initializes, so the whole matrix runs in ONE
subprocess (jax is already single-device in the pytest process) that
prints a JSON summary; the host-side tests assert on slices of it.
"""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import json
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_fleet_mesh
from repro.models import make_model
from repro.serving import (ClusterFrontend, ElasticClusterFrontend,
                           FleetGroup, ReplicaEngine, Request)
from repro.workload.trace import DEFAULT_TIERS

MAX_SEQ = 64
mesh = make_fleet_mesh()
out = {"n_dev": jax.local_device_count()}


def make_reqs(n, n_new=6, seed=3, vocab=400, long=False, tiers=None):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        plen = rng.integers(20, 40) if long else rng.integers(3, 9)
        kw = {}
        if tiers:
            kw["tier"] = tiers.names[rng.integers(0, len(tiers.names))]
        reqs.append(Request(i, rng.integers(1, vocab, plen).tolist(),
                            max_new_tokens=n_new, **kw))
    return reqs


def snap(reqs):
    return {r.rid: (tuple(r.output), r.finish_time) for r in reqs}


def model_for(arch):
    c = get_config(arch).reduced()
    m = make_model(c, tp=1)
    return m, m.init(jax.random.PRNGKey(0), jnp.float32)


# ---- churn-matrix parity per family (failure, drain, scale-up mid-run)
out["parity"] = {}
out["dispatch_match"] = {}
for arch in ("granite-3-8b", "mamba2-1.3b", "zamba2-2.7b"):
    m, params = model_for(arch)

    def factory(rid):
        return ReplicaEngine(m, params, max_batch=2, max_seq=MAX_SEQ,
                             rid=rid)

    def run(use_mesh):
        fe = ElasticClusterFrontend(factory, 2, initial_replicas=2, seed=0,
                                    mesh=mesh if use_mesh else None)
        reqs = make_reqs(10)
        for r in reqs:
            fe.submit(r)
        fe.tick(0.0)
        fe.fail_replica(0, 0)          # row drop + swap-backfill mid-run
        fe.tick(0.0)
        fe.scale_to(np.array([1, 1]))  # graceful drain
        fe.tick(0.0)
        fe.scale_to(np.array([2, 2]))  # scale-up: slab grows
        fe.run_until_drained()
        return snap(reqs), fe

    base, fe0 = run(False)
    shard, fe1 = run(True)
    out["parity"][arch] = base == shard
    # pad rows must not inflate the dispatch/sync accounting
    out["dispatch_match"][arch] = (
        fe0.decode_dispatches() == fe1.decode_dispatches()
        and fe0.sync_count() == fe1.sync_count())

# ---- mode parity on the dense family: block4 / chunked / tiers
m, params = model_for("granite-3-8b")
out["modes"] = {}
for label, kw in (("block4", dict(decode_block=4, n=12)),
                  ("chunk", dict(chunk_len=8, long=True, n=8)),
                  ("tiers", dict(tiers=DEFAULT_TIERS, n=12))):
    chunk_len = kw.pop("chunk_len", 0)
    tiers = kw.pop("tiers", None)
    n = kw.pop("n")
    long = kw.pop("long", False)
    decode_block = kw.pop("decode_block", 1)

    def factory(rid):
        ekw = {}
        if chunk_len:
            ekw["chunk_len"] = chunk_len
        if tiers:
            ekw["tiers"] = tiers
        return ReplicaEngine(m, params, max_batch=2, max_seq=MAX_SEQ,
                             rid=rid, **ekw)

    def run(use_mesh):
        fe = ElasticClusterFrontend(factory, 2, initial_replicas=2, seed=0,
                                    decode_block=decode_block, tiers=tiers,
                                    mesh=mesh if use_mesh else None)
        reqs = make_reqs(n, tiers=tiers, long=long)
        for r in reqs:
            fe.submit(r)
        fe.tick(0.0)
        fe.scale_to(np.array([2, 2]))
        fe.run_until_drained()
        return snap(reqs)

    out["modes"][label] = run(False) == run(True)

# ---- dispatch/sync bound per tick under sharding (saturated slab)
def factory(rid):
    return ReplicaEngine(m, params, max_batch=2, max_seq=MAX_SEQ, rid=rid)

fe = ElasticClusterFrontend(factory, 2, initial_replicas=2, seed=0,
                            mesh=mesh)
for r in make_reqs(16, n_new=8):
    fe.submit(r)
ticks = []
for _ in range(4):
    mtr = fe.tick(0.0)
    ticks.append({"groups": mtr["fleet_groups"],
                  "dispatches": mtr["decode_dispatches"],
                  "syncs": mtr["syncs"]})
out["ticks"] = ticks
fe.run_until_drained()

# ---- growth divisibility: 3 -> 4 -> 8 members under 4 shards
g = FleetGroup(m, params, max_batch=2, max_seq=MAX_SEQ, mesh=mesh)
caps = []
engs = [ReplicaEngine(m, params, max_batch=2, max_seq=MAX_SEQ, rid=i)
        for i in range(8)]
for i, e in enumerate(engs):
    g.add(e)
    if i + 1 in (3, 4, 5, 8):
        caps.append([i + 1, g.cap])
out["growth_caps"] = caps
out["growth_divisible"] = all(c % 4 == 0 for _, c in caps)

# a 3-member fleet (1 pad row on the 4-wide slab) must match the
# unsharded 3-member fleet stream-for-stream and dispatch-for-dispatch
def run3(use_mesh):
    engines = [ReplicaEngine(m, params, max_batch=2, max_seq=MAX_SEQ, rid=i)
               for i in range(3)]
    fe = ClusterFrontend(engines, policy="rr", fleet_batch=True,
                         mesh=mesh if use_mesh else None)
    reqs = make_reqs(9, n_new=5, seed=11)
    for r in reqs:
        fe.submit(r)
    fe.run_until_drained()
    disp = sum(gr.dispatches for gr in fe.fleets.values())
    return snap(reqs), disp

(s0, d0), (s1, d1) = run3(False), run3(True)
out["growth_parity"] = s0 == s1 and d0 == d1

# ---- sharding stays pinned after the dispatches above
stable = True
for leaf in jax.tree.leaves(g.slab):
    spec = leaf.sharding.spec
    stable &= bool(spec) and spec[0] == "fleet"
out["sharding_stable"] = stable

print("RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def result(tmp_path_factory):
    script = tmp_path_factory.mktemp("shard") / "run.py"
    script.write_text(_SCRIPT)
    env = dict(os.environ, PYTHONPATH=os.path.abspath(SRC))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, str(script)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


def test_mesh_visible(result):
    assert result["n_dev"] == 4


@pytest.mark.parametrize("arch", ["granite-3-8b", "mamba2-1.3b",
                                  "zamba2-2.7b"])
def test_sharded_parity_across_churn(result, arch):
    """Token streams + finish clocks identical to the unsharded fleet
    through failure / drain / scale-up, per model family."""
    assert result["parity"][arch]
    assert result["dispatch_match"][arch]


@pytest.mark.parametrize("mode", ["block4", "chunk", "tiers"])
def test_sharded_parity_modes(result, mode):
    """decode_block fusion, chunked prefill and SLO tiers all hold parity
    under the fleet mesh."""
    assert result["modes"][mode]


def test_one_dispatch_one_sync_per_tick_sharded(result):
    """Sharding partitions the dispatch, it must not multiply it: one
    logical decode dispatch per group per tick, <= 1 blocking sync."""
    for i, t in enumerate(result["ticks"]):
        assert t["groups"] == 1, result["ticks"]
        assert t["syncs"] <= 1, result["ticks"]
        if i > 0:                       # first tick only admits
            assert t["dispatches"] == 1, result["ticks"]


def test_growth_keeps_fleet_axis_divisible(result):
    """3 -> 4 -> 5 -> 8 members under 4 shards allocates caps 4, 4, 8, 8:
    per-shard sub-capacity grows pow2, fleet axis stays divisible."""
    assert result["growth_caps"] == [[3, 4], [4, 4], [5, 8], [8, 8]]
    assert result["growth_divisible"]
    assert result["growth_parity"]      # pad row inert: streams + dispatches


def test_slab_sharding_stable(result):
    """Donated dispatches and churn must leave the slab pinned to the
    fleet axis (a silent re-gather would serialize the fleet again)."""
    assert result["sharding_stable"]
