"""Cluster simulator invariants + workload generator properties."""
import numpy as np
import pytest
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.configs.paper_cluster import ClusterConfig
from repro.sim.cluster import ClusterSim
from repro.workload import TraceConfig, generate_trace

CFG = ClusterConfig(num_nodes=6, provisioning_delay=5)


def _uniform(n):
    return np.full(n, 1.0 / n, np.float32)


@given(seed=st.integers(0, 50), rate=st.floats(1.0, 500.0))
@settings(max_examples=15, deadline=None)
def test_work_conservation(seed, rate):
    """arrivals == served + queued (no failures -> no work lost)."""
    sim = ClusterSim(CFG, 30.0, seed=seed, failures=False)
    total_in, total_served = 0.0, 0.0
    for _ in range(50):
        m = sim.tick(rate, _uniform(6))
        total_in += rate * CFG.tick_seconds
        total_served += m["served"]
    assert total_served + sim.state.queue.sum() == pytest.approx(
        total_in, rel=1e-4)


@given(seed=st.integers(0, 30))
@settings(max_examples=10, deadline=None)
def test_utilization_bounds(seed):
    sim = ClusterSim(CFG, 30.0, seed=seed, failures=True)
    rng = np.random.default_rng(seed)
    for _ in range(80):
        m = sim.tick(float(rng.uniform(0, 400)), _uniform(6))
        assert 0.0 <= m["mean_utilization"] <= 1.0 + 1e-6
        assert (m["utilization"] >= -1e-6).all()
        assert (m["utilization"] <= 1.0 + 1e-6).all()
        assert m["response_time"] >= 0.0


def test_latency_increases_with_load():
    lo = ClusterSim(CFG, 30.0, seed=1, failures=False)
    hi = ClusterSim(CFG, 30.0, seed=1, failures=False)
    r_lo = [lo.tick(100.0, _uniform(6))["response_time"] for _ in range(60)]
    r_hi = [hi.tick(3000.0, _uniform(6))["response_time"] for _ in range(60)]
    assert np.mean(r_hi) > np.mean(r_lo)


def test_provisioning_delay_honored():
    sim = ClusterSim(CFG, 30.0, seed=0, failures=False)
    before = sim.state.active.copy()
    sim.scale_to(before + 2)
    for t in range(CFG.provisioning_delay - 1):
        sim.tick(10.0, _uniform(6))
        assert (sim.state.active == before).all(), t
    sim.tick(10.0, _uniform(6))
    assert (sim.state.active == before + 2).all()


def test_scale_down_immediate():
    sim = ClusterSim(CFG, 30.0, seed=0, failures=False)
    before = sim.state.active.copy()
    sim.scale_to(np.maximum(before - 1, 0))
    assert (sim.state.active == np.maximum(before - 1, 0)).all()


def test_failed_node_work_rerouted():
    cfg = ClusterConfig(num_nodes=4, node_mtbf=1.0, node_mttr=1e9,
                        provisioning_delay=2)
    sim = ClusterSim(cfg, 30.0, seed=3, failures=True)
    sim.state.queue[:] = 25.0
    total_before = sim.state.queue.sum()
    m = sim.tick(0.0, _uniform(4))
    # every node fails (mtbf=1) -> queues drop to retry pool and re-enter
    # conservation: served + remaining queue + pool == total (arrivals=0)
    assert (m["served"] + sim.state.queue.sum() + sim.state.retry_pool
            == pytest.approx(total_before, rel=1e-4))


def test_heterogeneous_capacity():
    sim = ClusterSim(CFG, 30.0, seed=0, failures=False, heterogeneous=True)
    caps = sim.capacity()
    assert len(set(np.round(caps, 3))) > 1  # mixed hardware generations


# ---------------------------------------------------------------- workload
def test_trace_deterministic_and_positive():
    a = generate_trace(TraceConfig(ticks=500), seed=5)
    b = generate_trace(TraceConfig(ticks=500), seed=5)
    np.testing.assert_array_equal(a["arrivals"], b["arrivals"])
    assert (a["arrivals"] > 0).all()


def test_trace_diurnal_and_bursts():
    t = generate_trace(TraceConfig(ticks=1800, burst_rate=1 / 100), seed=1)
    arr = t["arrivals"]
    # diurnal: autocorrelation at the period ≈ high
    period = 600
    x = arr - arr.mean()
    ac = np.corrcoef(x[:-period], x[period:])[0, 1]
    assert ac > 0.2
    # bursts: heavy right tail
    assert arr.max() > 2.5 * np.median(arr)


def test_load_scale_scales_mean():
    lo = generate_trace(TraceConfig(ticks=400), seed=2, load_scale=1.0)
    hi = generate_trace(TraceConfig(ticks=400), seed=2, load_scale=2.0)
    assert hi["arrivals"].mean() == pytest.approx(
        2 * lo["arrivals"].mean(), rel=0.05)
