"""Failure matrix: closed-loop clients, deadlines, spot preemption and
exactly-once request accounting.

Covers the robustness layer end to end: chaos entry-point validation
(ValueError, not IndexError), deadline retirement inside the fleet retire
rule, duplicate suppression + retry (the exactly-once guarantee of the
``RequestLedger``), whole-node preemption notices (drain-under-deadline,
hard drop, re-queue, scripted ``ChaosSchedule``), conservation across the
full churn x chaos matrix, the fluid-sim mirror, the GPSO preemption-risk
cost term, and bit-identical chaos-off streams vs the PR 6 baseline.
"""
import hashlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.paper_cluster import ClusterConfig
from repro.core.autoscaler import (GPSOAutoscaler, eq9_fitness,
                                   eq9_risk_fitness)
from repro.models import make_model
from repro.serving import (ChaosSchedule, ElasticClusterFrontend,
                           ReplicaEngine, Request)
from repro.sim.cluster import ClusterSim
from repro.workload import ClientPool, parse_tiers

MAX_SEQ = 64


@pytest.fixture(scope="module")
def setup():
    c = get_config("granite-3-8b").reduced()
    m = make_model(c, tp=1)
    params = m.init(jax.random.PRNGKey(0), jnp.float32)
    return c, m, params


def _factory(m, params, max_batch=2, tiers=None):
    def make_replica(rid):
        return ReplicaEngine(m, params, max_batch=max_batch, max_seq=MAX_SEQ,
                             rid=rid, tiers=tiers)
    return make_replica


def _req(i, plen=4, n_new=4, deadline=None):
    r = Request(i, [1 + (i + j) % 97 for j in range(plen)],
                max_new_tokens=n_new)
    r.deadline_tick = deadline
    return r


# ------------------------------------------------------------- validation
def test_chaos_entry_points_validate(setup):
    c, m, params = setup
    fe = ElasticClusterFrontend(_factory(m, params), 2, initial_replicas=1)
    with pytest.raises(ValueError, match="out of range"):
        fe.fail_replica(5)
    with pytest.raises(ValueError, match="out of range"):
        fe.fail_replica(-1)          # negative must not wrap
    with pytest.raises(ValueError, match="replica index"):
        fe.fail_replica(0, replica_idx=3)
    with pytest.raises(ValueError, match="must be an int"):
        fe.fail_replica("n0")
    with pytest.raises(ValueError, match="out of range"):
        fe.preempt_node(9)
    with pytest.raises(ValueError, match="not down"):
        fe.recover_node(0)
    fe.preempt_node(0, notice=2)
    with pytest.raises(ValueError, match="already has a preemption"):
        fe.preempt_node(0)
    with pytest.raises(ValueError, match="no live replicas"):
        fe.fail_replica(0)           # live drained away by the notice
    for _ in range(4):
        fe.tick(0.0)
    assert fe.nodes[0].down and fe.preempted_nodes == 1
    with pytest.raises(ValueError, match="already down"):
        fe.preempt_node(0)
    fe.recover_node(0)
    assert not fe.nodes[0].down


def test_chaos_schedule_parse_errors():
    s = ChaosSchedule.parse("preempt@12:n0:k3, fail@8:n1:r1 ,recover@40:n0")
    assert s.pop(12) == [("preempt", 0, 3)]
    assert s.pop(8) == [("fail", 1, 1)]
    assert s.pop(40) == [("recover", 0, None)]
    assert s.pop(13) == []
    for bad in ("explode@3:n0", "preempt@3", "fail@3:n0:k2",
                "preempt@3:n0:r2", "preempt@x:n0"):
        with pytest.raises(ValueError):
            ChaosSchedule.parse(bad)


# --------------------------------------------------------------- deadlines
def test_deadline_retires_in_fleet_and_queue_cull(setup):
    c, m, params = setup
    fe = ElasticClusterFrontend(_factory(m, params), 1, initial_replicas=1)
    fe.submit(_req(0, n_new=12, deadline=3.0))    # expires mid-decode
    fe.submit(_req(1, n_new=12, deadline=50.0))   # comfortable
    fe.submit(_req(2, n_new=12, deadline=1.0))    # expires while queued
    for _ in range(30):
        fe.tick(0.0)
        assert fe.metrics()["syncs"] <= 1         # bounds hold under expiry
    fe.run_until_drained()
    done = {r.rid: r for r in fe.finished}
    assert done[0].expired and len(done[0].output) < 12
    assert done[0].finish_time <= done[0].deadline_tick + 1
    assert not done[1].expired and len(done[1].output) == 12
    # rid 2 never got a slot past its deadline: culled, zero tokens
    assert done[2].expired and done[2].output == []
    b = fe.ledger.balance()
    assert b["finished"] == 1 and b["timed_out"] == 2 and b["live"] == 0
    assert fe.ledger.balanced()


# ----------------------------------------------- exactly-once + retry path
def test_duplicate_suppression_and_retry(setup):
    c, m, params = setup
    fe = ElasticClusterFrontend(_factory(m, params), 1, initial_replicas=1)
    assert fe.submit(_req(7, n_new=4)) is True
    assert fe.submit(_req(7, n_new=4)) is False      # live -> suppressed
    assert fe.ledger.duplicates == 1
    fe.run_until_drained()
    assert [r.rid for r in fe.finished] == [7]       # served exactly once
    assert fe.submit(_req(7)) is False               # finished -> suppressed
    assert fe.ledger.double_served == 0

    # timeout -> retry accepted, fresh attempt served
    fe2 = ElasticClusterFrontend(_factory(m, params), 1, initial_replicas=1)
    fe2.submit(_req(0, n_new=12, deadline=2.0))
    for _ in range(8):
        fe2.tick(0.0)
    assert fe2.ledger.state[0] == "timed_out"
    assert fe2.submit(_req(0, n_new=4, deadline=100.0)) is True
    assert fe2.ledger.retries == 1
    fe2.run_until_drained()
    assert fe2.ledger.state[0] == "finished"
    served = [r for r in fe2.finished if r.rid == 0 and not r.expired]
    assert len(served) == 1                          # exactly one good serve
    assert fe2.ledger.balanced()

    # abandoned rid: late completion counts wasted, not served
    fe3 = ElasticClusterFrontend(_factory(m, params), 1, initial_replicas=1)
    fe3.submit(_req(5, n_new=6))
    fe3.tick(0.0)                                    # in flight
    assert fe3.abandon(5) is True
    assert fe3.submit(_req(5)) is False              # abandoned -> suppressed
    fe3.run_until_drained()
    assert fe3.ledger.wasted == 1 and fe3.ledger.double_served == 0
    assert fe3.ledger.balanced()


def test_rejection_under_queue_cap(setup):
    c, m, params = setup
    fe = ElasticClusterFrontend(_factory(m, params), 1, initial_replicas=1,
                                max_queue=2)
    assert fe.submit(_req(0)) and fe.submit(_req(1))
    assert fe.submit(_req(2)) is False               # cap hit -> rejected
    assert fe.ledger.state[2] == "rejected"
    fe.run_until_drained()
    assert fe.submit(_req(2)) is True                # retry after rejection
    fe.run_until_drained()
    b = fe.ledger.balance()
    assert b["finished"] == 3 and b["rejected"] == 0 and fe.ledger.balanced()


# -------------------------------------------------------------- preemption
def test_preempt_notice_drains_then_drops(setup):
    c, m, params = setup
    fe = ElasticClusterFrontend(_factory(m, params), 2, initial_replicas=1,
                                seed=1)
    for i in range(6):
        fe.submit(_req(i, n_new=10))
    fe.tick(0.0)
    fe.preempt_node(0, notice=2)
    assert not fe.nodes[0].live and fe.nodes[0].draining
    assert fe.up_mask().tolist() == [0.0, 1.0]
    assert fe.preempt_risk().tolist() == [1.0, 0.0]
    assert not fe.nodes[0].spawning
    fe.scale_to(np.array([3, 1]))                    # refused on noticed node
    assert not fe.nodes[0].spawning
    for _ in range(4):
        fe.tick(0.0)
    assert fe.nodes[0].down and not fe.nodes[0].draining
    assert fe.preempted_nodes == 1
    fe.run_until_drained()
    assert sorted(r.rid for r in fe.finished) == list(range(6))  # none lost
    assert all(len(r.output) == 10 for r in fe.finished)
    assert fe.ledger.balanced()
    # scripted schedule drives the same machinery
    fe2 = ElasticClusterFrontend(
        _factory(m, params), 2, initial_replicas=1, seed=1,
        chaos=ChaosSchedule.parse("preempt@2:n0:k1,recover@6:n0"))
    for i in range(4):
        fe2.submit(_req(i, n_new=8))
    for t in range(7):
        fe2.tick(0.0)
    assert fe2.preempted_nodes == 1 and not fe2.nodes[0].down  # recovered
    fe2.run_until_drained()
    assert sorted(r.rid for r in fe2.finished) == list(range(4))
    assert fe2.ledger.balanced()


# ------------------------------------------------------- edge ordering
def test_same_tick_preempt_recover_applies_in_order(setup):
    """Same-tick events apply in spec order: an immediate (k0) preemption
    hard-drops and downs the node, then the recover in the SAME tick
    brings it back — net effect one preemption, node schedulable again,
    every evacuated request re-served exactly once."""
    c, m, params = setup
    fe = ElasticClusterFrontend(
        _factory(m, params), 2, initial_replicas=1, seed=1,
        chaos=ChaosSchedule.parse("preempt@3:n0:k0,recover@3:n0"))
    for i in range(6):
        fe.submit(_req(i, n_new=8))
    for _ in range(4):
        fe.tick(0.0)
    assert fe.preempted_nodes == 1
    assert not fe.nodes[0].down              # recovered within the tick
    fe.scale_to(np.array([1, 1]))            # schedulable again (empty)
    assert fe.nodes[0].spawning
    fe.run_until_drained()
    assert sorted(r.rid for r in fe.finished) == list(range(6))
    assert fe.ledger.balanced() and fe.ledger.double_served == 0


def test_cell_down_races_inflight_drain(setup):
    """A blackout landing while a node is mid-drain under a preemption
    notice must supersede the notice and push everything through the same
    ledger-safe evacuation path — balanced accounting, nothing lost or
    double-served across the re-route to the sibling cell."""
    from repro.control import MultiCellBackend

    c, m, params = setup
    cell0 = ElasticClusterFrontend(
        _factory(m, params), 2, initial_replicas=1, seed=1,
        chaos=ChaosSchedule.parse("preempt@2:n0:k4"))
    cell1 = ElasticClusterFrontend(_factory(m, params), 2,
                                   initial_replicas=1, seed=2)
    mc = MultiCellBackend(
        [cell0, cell1],
        chaos=ChaosSchedule.parse("cell_down@3:c0,cell_up@8:c0"), seed=0)
    for i in range(8):
        mc.submit(_req(i, n_new=8))
    for t in range(4):
        mc.tick(0.0)
        if t == 1:
            # notice active on cell 0's node 0, drain in flight
            assert cell0.nodes[0].draining or cell0.preempt_risk()[0] == 1.0
    assert mc.cell_downs == 1 and mc.evacuated_total > 0
    mc.run_until_drained()
    assert sorted(r.rid for r in mc.finished) == list(range(8))
    assert mc.ledger.balanced() and mc.ledger.double_served == 0


# ------------------------------------------------------ conservation matrix
def test_conservation_full_churn_matrix(setup):
    """Drain + stochastic failure + preemption mid-drain + retry storm, all
    at once: every rid lands in exactly one terminal state, nothing is lost
    or double-served, and the per-tick dispatch/sync bounds hold."""
    c, m, params = setup
    tiers = parse_tiers("premium:0.3:w5:4,batch:0.7:w1")
    rng = np.random.default_rng(0)

    def request_factory(rid, tick):
        plen = int(rng.integers(2, 8))
        req = Request(rid, rng.integers(1, c.vocab_size, plen).tolist(),
                      max_new_tokens=int(rng.integers(3, 8)))
        req.tier = tiers.sample(rng)
        return req

    fe = ElasticClusterFrontend(
        _factory(m, params, tiers=tiers), 2, initial_replicas=2,
        provisioning_delay=1, failure_rate=0.05, seed=7, tiers=tiers,
        preempt_notice=2,
        chaos=ChaosSchedule.parse("preempt@8:n0:k2,recover@16:n0"))
    pool = ClientPool(
        fe, 24, request_factory=request_factory, think_time=1.0,
        timeout={"premium": 6.0, "batch": 12.0}, max_retries=2,
        backoff_base=1.0, spawn_rate=8.0, seed=5)
    for t in range(24):
        pool.tick()
        fe.tick(0.0)
        g = fe.metrics()["fleet_groups"]
        assert fe.metrics()["syncs"] <= max(g, 1)
        if t == 6:
            fe.scale_to(np.array([1, 2]))            # drain mid-chaos
    pool.quiesce()
    fe.run_until_drained()
    pool.finalize()
    b = fe.ledger.balance()
    assert b["live"] == 0 and b["double_served"] == 0
    assert fe.ledger.balanced()
    assert b["submitted"] == sum(
        b[k] for k in ("finished", "timed_out", "abandoned", "rejected"))
    assert pool.stats["ok"] > 0
    # goodput metric never counted an expired or wasted completion
    assert b["finished"] >= pool.stats["ok"]


# ---------------------------------------------------------- chaos-off parity
def _stream_digest(c, m, params, tiers_spec):
    tiers = parse_tiers(tiers_spec)
    rng = np.random.default_rng(3)

    def make_replica(rid):
        return ReplicaEngine(m, params, max_batch=2, max_seq=MAX_SEQ,
                             rid=rid, tiers=tiers)

    def request_factory(rid, tick):
        plen = int(rng.integers(2, 10))
        req = Request(rid, rng.integers(1, c.vocab_size, plen).tolist(),
                      max_new_tokens=int(rng.integers(3, 9)))
        if len(tiers) > 1:
            req.tier = tiers.sample(rng)
        return req

    fe = ElasticClusterFrontend(
        make_replica, 2, initial_replicas=2, provisioning_delay=2,
        failure_rate=0.08, request_factory=request_factory, seed=3,
        decode_block=1, tiers=tiers)
    for t in range(24):
        fe.tick(1.5)
        if t == 10:
            fe.scale_to(np.array([1, 2]))
        if t == 16:
            fe.scale_to(np.array([2, 2]))
    fe.run_until_drained()
    assert fe.ledger.balanced()          # conservation even without chaos
    h = hashlib.sha256()
    for r in sorted(fe.finished, key=lambda r: r.rid):
        h.update(repr((r.rid, r.tier, tuple(r.output), r.arrival,
                       r.first_token_time, r.finish_time)).encode())
    return h.hexdigest()


# digests recorded at PR 6 HEAD (c7bc9d4) with the identical scenario: the
# robustness layer must not perturb chaos-off streams by a single token
PR6_DIGESTS = {
    "": "3f86fe8880df84967200ef88d76052939ef9b6e53945a14cb48176a1b6db416c",
    "premium:0.3:w5:4,batch:0.7:w1":
        "0be2c9199887ef732c13007cb4fbc39842bfd9a5687b7267982b07da8ee67f0b",
}


@pytest.mark.parametrize("tiers_spec", list(PR6_DIGESTS))
def test_chaos_off_streams_bit_identical_to_pr6(setup, tiers_spec):
    c, m, params = setup
    assert _stream_digest(c, m, params, tiers_spec) == PR6_DIGESTS[tiers_spec]


# ------------------------------------------------------------- fluid mirror
def _sim_cfg(**kw):
    kw.setdefault("num_nodes", 4)
    kw.setdefault("provisioning_delay", 2)
    kw.setdefault("node_mtbf", 1e12)
    kw.setdefault("straggler_prob", 0.0)
    return ClusterConfig(**kw)


def test_sim_preemption_mirror():
    sim = ClusterSim(_sim_cfg(), unit_capacity=10.0, seed=0,
                     heterogeneous=False,
                     chaos=ChaosSchedule.parse("preempt@3:n0:k2,recover@9:n0"))
    fr = np.full(4, 0.25)
    for t in range(1, 3):
        sim.tick(100.0, fr)
    assert sim.preempt_risk().tolist() == [0.0] * 4
    sim.tick(100.0, fr)                       # t=3: notice lands
    assert sim.preempt_risk()[0] == 1.0
    assert sim.state.pending[0].sum() == 0    # spawns cancelled
    sim.scale_to(np.array([6, 6, 6, 6]))      # refused on the noticed node
    assert sim.state.pending[0].sum() == 0
    assert sim.state.pending[1].sum() > 0
    q_before = float(sim.state.queue.sum() + sim.state.retry_pool)
    for t in range(4, 7):
        sim.tick(0.0, fr)
    # expired: node 0 down, replicas gone, queue conserved via retry pool
    assert sim.state.up[0] == 0.0 and sim.state.active[0] == 0
    assert float(sim.state.queue.sum() + sim.state.retry_pool) <= q_before
    assert sim._preempt_down[0]
    for t in range(7, 10):
        sim.tick(0.0, fr)                     # t=9: scripted recovery
    assert sim.state.up[0] == 1.0 and not sim._preempt_down[0]
    assert sim.preempt_risk().tolist() == [0.0] * 4
    with pytest.raises(ValueError):
        sim.preempt_node(99)
    with pytest.raises(ValueError):
        sim.recover_node(1)


# ------------------------------------------------------------- planner risk
def test_gpso_preemption_risk_term():
    cfg = _sim_cfg(num_nodes=2)
    demand = jnp.asarray([5.0, 5.0])
    base_ctx = (demand, jnp.asarray(10.0), jnp.float32(1.0),
                jnp.float32(cfg.lam), jnp.float32(cfg.target_load))
    risk = jnp.asarray([1.0, 0.0])
    ctx = base_ctx + (jnp.float32(cfg.risk_lam), risk)
    risky = jnp.asarray([[4.0, 1.0]])
    safe = jnp.asarray([[1.0, 4.0]])
    # same base cost by symmetry; the risk term must separate them
    assert float(eq9_fitness(risky, base_ctx)[0]) == pytest.approx(
        float(eq9_fitness(safe, base_ctx)[0]))
    assert float(eq9_risk_fitness(risky, ctx)[0]) > \
        float(eq9_risk_fitness(safe, ctx)[0])
    # end to end: the planner shifts capacity off the at-risk node
    scaler = GPSOAutoscaler(cfg, unit_capacity=10.0, seed=0)
    cur = np.array([2, 2], np.int32)
    tgt = scaler.plan(np.array([5.0, 5.0], np.float32), 40, cur,
                      preempt_risk=np.array([1.0, 0.0], np.float32))
    assert tgt[0] <= tgt[1]
    # all-zero risk keeps the base objective: identical plan to omitting it
    s1 = GPSOAutoscaler(cfg, unit_capacity=10.0, seed=0)
    s2 = GPSOAutoscaler(cfg, unit_capacity=10.0, seed=0)
    t1 = s1.plan(np.array([5.0, 3.0], np.float32), 40, cur)
    t2 = s2.plan(np.array([5.0, 3.0], np.float32), 40, cur,
                 preempt_risk=np.zeros(2, np.float32))
    assert (t1 == t2).all()


# ------------------------------------------------------- closed-loop clients
def test_client_pool_flash_ramp_and_stats(setup):
    c, m, params = setup

    def request_factory(rid, tick):
        return _req(rid, plen=3, n_new=4)

    fe = ElasticClusterFrontend(_factory(m, params), 1, initial_replicas=2)
    pool = ClientPool(fe, 10, request_factory=request_factory,
                      think_time=1.0, timeout=20.0, max_retries=1,
                      spawn_rate=4.0, seed=2)
    ramp = []
    for _ in range(20):
        pool.tick()
        ramp.append(pool.active_clients)
        fe.tick(0.0)
    assert ramp[0] == 4 and ramp[1] == 8 and ramp[2] == 10  # spawn ramp
    pool.quiesce()
    fe.run_until_drained()
    pool.finalize()
    s = pool.summary()
    assert s["ok"] > 0 and s["latency_mean"] is not None
    assert fe.ledger.balanced()
    # every rid the pool ever created ends ok or abandoned client-side
    # (the pool is the frontend's only traffic source here)
    assert fe.ledger.submitted == s["ok"] + s["abandoned"]
    # attempts >= distinct rids (retries re-use the rid)
    assert s["issued"] >= fe.ledger.submitted
