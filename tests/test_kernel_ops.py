"""Public kernel wrappers (ops.py): dispatch + fallback correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.ops import (attention_op, decode_attention_op,
                               gcn_layer_op, ssd_scan_op)


def test_attention_op_paths_agree(key):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 4, 128, 32))
    k = jax.random.normal(ks[1], (1, 2, 128, 32))
    v = jax.random.normal(ks[2], (1, 2, 128, 32))
    xla = attention_op(q, k, v, causal=True, use_kernel=False)
    pallas = attention_op(q, k, v, causal=True, interpret=True)
    np.testing.assert_allclose(np.asarray(xla), np.asarray(pallas),
                               atol=1e-5, rtol=1e-5)


def test_decode_op_paths_agree(key):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (2, 4, 32))
    kc = jax.random.normal(ks[1], (2, 2, 256, 32))
    vc = jax.random.normal(ks[2], (2, 2, 256, 32))
    xla = decode_attention_op(q, kc, vc, 100, use_kernel=False)
    pallas = decode_attention_op(q, kc, vc, 100, interpret=True)
    np.testing.assert_allclose(np.asarray(xla), np.asarray(pallas),
                               atol=1e-5, rtol=1e-5)


def test_ssd_op_paths_agree(key):
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (1, 128, 2, 16))
    a = -jnp.abs(jax.random.normal(ks[1], (1, 128, 2))) * 0.1
    Bm = jax.random.normal(ks[2], (1, 128, 8))
    Cm = jax.random.normal(ks[3], (1, 128, 8))
    y1, s1 = ssd_scan_op(x, a, Bm, Cm, chunk=32, use_kernel=False)
    y2, s2 = ssd_scan_op(x, a, Bm, Cm, chunk=32, interpret=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4,
                               rtol=1e-4)


def test_gcn_op_paths_agree(key):
    ks = jax.random.split(key, 4)
    A = jax.random.uniform(ks[0], (12, 12))
    X = jax.random.normal(ks[1], (12, 6))
    W = jax.random.normal(ks[2], (6, 16))
    b = jax.random.normal(ks[3], (16,))
    xla = gcn_layer_op(A, X, W, b, use_kernel=False)
    pallas = gcn_layer_op(A, X, W, b, interpret=True)
    np.testing.assert_allclose(np.asarray(xla), np.asarray(pallas),
                               atol=1e-5, rtol=1e-5)
