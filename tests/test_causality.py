"""Causality property: for every autoregressive family, logits at position t
must not depend on tokens after t (catches mask/offset bugs in attention,
SSD scan, chunked attention and the hybrid shared block)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.models import make_model

DECODER_ARCHS = [a for a in ARCH_NAMES
                 if get_config(a).family in ("dense", "moe", "ssm", "hybrid")]


@pytest.mark.parametrize("arch", DECODER_ARCHS)
def test_future_tokens_do_not_affect_past_logits(arch, key):
    c = get_config(arch).reduced()
    m = make_model(c, tp=1)
    params = m.init(key, jnp.float32)
    B, S, t = 2, 48, 20
    toks = jax.random.randint(key, (B, S), 0, c.vocab_size)
    toks2 = toks.at[:, t + 1:].set(
        jax.random.randint(jax.random.PRNGKey(9), (B, S - t - 1), 0,
                           c.vocab_size))
    l1, _ = m.forward(params, {"tokens": toks})
    l2, _ = m.forward(params, {"tokens": toks2})
    np.testing.assert_allclose(np.asarray(l1[:, :t + 1]),
                               np.asarray(l2[:, :t + 1]), atol=1e-5,
                               rtol=1e-5)
    # sanity: the future positions DO change
    assert float(np.max(np.abs(np.asarray(l1[:, t + 1:])
                               - np.asarray(l2[:, t + 1:])))) > 1e-4


def test_vlm_text_does_not_affect_patch_positions(key):
    c = get_config("internvl2-2b").reduced()
    m = make_model(c, tp=1)
    params = m.init(key, jnp.float32)
    B, S = 2, 16
    patches = jax.random.normal(key, (B, c.num_patches, c.d_model)) * 0.1
    t1 = jax.random.randint(key, (B, S), 0, c.vocab_size)
    t2 = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, c.vocab_size)
    l1, _ = m.forward(params, {"tokens": t1, "patch_embeds": patches})
    l2, _ = m.forward(params, {"tokens": t2, "patch_embeds": patches})
    P = c.num_patches
    np.testing.assert_allclose(np.asarray(l1[:, :P]), np.asarray(l2[:, :P]),
                               atol=1e-5, rtol=1e-5)


def test_whisper_decoder_causal_encoder_bidir(key):
    c = get_config("whisper-base").reduced()
    m = make_model(c, tp=1)
    params = m.init(key, jnp.float32)
    B, S, t = 2, 24, 10
    frames = jax.random.normal(key, (B, c.encoder_seq_len, c.d_model)) * 0.1
    toks = jax.random.randint(key, (B, S), 0, c.vocab_size)
    toks2 = toks.at[:, t + 1:].set(0)
    l1, _ = m.forward(params, {"tokens": toks, "frame_embeds": frames})
    l2, _ = m.forward(params, {"tokens": toks2, "frame_embeds": frames})
    np.testing.assert_allclose(np.asarray(l1[:, :t + 1]),
                               np.asarray(l2[:, :t + 1]), atol=1e-5,
                               rtol=1e-5)
    # encoder frames affect ALL decoder positions (cross-attn is global).
    # NB: a CONSTANT shift sits in LayerNorm's null space — perturb with
    # noise, not a constant (that was a real test-design lesson).
    frames2 = frames + 0.05 * jax.random.normal(jax.random.PRNGKey(5),
                                                frames.shape)
    l3, _ = m.forward(params, {"tokens": toks, "frame_embeds": frames2})
    assert float(np.max(np.abs(np.asarray(l1) - np.asarray(l3)))) > 1e-5
