"""Model substrate: per-arch smoke, serve-path consistency, padding
equivalence, MoE dispatch vs dense oracle, SSD vs naive recurrence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_no_drop
from repro.configs import ARCH_NAMES, get_config
from repro.models import make_model
from repro.models.dims import padded_dims
from repro.models.model import make_train_step
from repro.models.optim import AdamW


def _batch(c, key, B=2, S=32, full_tokens=None):
    toks = full_tokens if full_tokens is not None else \
        jax.random.randint(key, (B, S), 0, c.vocab_size)
    b = {"tokens": toks}
    if c.family == "vlm":
        b["patch_embeds"] = jax.random.normal(
            key, (B, c.num_patches, c.d_model)) * 0.1
    if c.family == "audio":
        b["frame_embeds"] = jax.random.normal(
            key, (B, c.encoder_seq_len, c.d_model)) * 0.1
    return b


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_forward_and_train_step(arch, key):
    """Reduced config: one forward + one train step, shapes + finiteness."""
    c = get_config(arch).reduced()
    m = make_model(c, tp=1)
    params = m.init(key, jnp.float32)
    B, S = 2, 64
    batch = _batch(c, key, B, S)
    logits, aux = m.forward(params, batch)
    S_total = S + (c.num_patches if c.family == "vlm" else 0)
    assert logits.shape == (B, S_total, m.dims.vocab)
    assert bool(jnp.isfinite(logits).all())
    opt = AdamW(lr=1e-3)
    st = opt.init(params)
    step = jax.jit(make_train_step(m, opt))
    p2, st2, metrics = step(params, st, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    delta = sum(float(jnp.sum(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(p2),
                                jax.tree.leaves(params)))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_prefill_decode_consistency(arch, key):
    """prefill(S) == forward(S) last logits; decode(S+1th) == forward(S+1)."""
    c = reduced_no_drop(get_config(arch))
    m = make_model(c, tp=1)
    params = m.init(key, jnp.float32)
    B, S = 2, 32
    toks = jax.random.randint(key, (B, S + 1), 0, c.vocab_size)
    batch = _batch(c, key, B, S, full_tokens=toks[:, :S])
    full, _ = m.forward(params, batch)
    pre, state, pos = m.prefill(params, batch, cache_len=64,
                                cache_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(full[:, -1]), np.asarray(pre),
                               atol=2e-4, rtol=2e-4)
    full2, _ = m.forward(params, dict(batch, tokens=toks))
    dec, _ = m.decode(params, state, toks[:, S:S + 1], jnp.int32(pos))
    np.testing.assert_allclose(np.asarray(full2[:, -1]), np.asarray(dec),
                               atol=2e-4, rtol=2e-4)


def test_head_padding_equivalence(key):
    """A tp-padded model built from an unpadded one's weights (zero-filled
    pad slots) computes identical outputs — padding is exactly inert."""
    c = get_config("qwen2.5-14b").reduced()  # 40H-style padding arch family
    c = dataclasses.replace(c, num_heads=5 * 2, num_kv_heads=2, head_dim=16)
    m1 = make_model(c, tp=1)
    d1 = m1.dims
    m4 = make_model(c, tp=4)   # kv=2 < tp=4 -> replication + q padding
    d4 = m4.dims
    assert d4.n_kv == 4 and d4.n_q % 4 == 0
    p1 = m1.init(key, jnp.float32)
    p4 = jax.tree.map(jnp.copy, m4.init(key, jnp.float32))

    # map unpadded weights into the padded layout, leaf-by-leaf
    rep = d4.kv_rep
    qpg1, qpg4 = d1.q_per_group, d4.q_per_group
    p4 = jax.device_get(p4)
    p1_np = jax.device_get(p1)
    for lname in ("layers",):
        a1 = p1_np[lname]["attn"]
        a4 = p4[lname]["attn"]
        wq = np.zeros_like(a4["wq"])
        wo = np.zeros_like(a4["wo"])
        wk = np.zeros_like(a4["wk"])
        wv = np.zeros_like(a4["wv"])
        bq = np.zeros_like(a4["bq"]) if "bq" in a4 else None
        bk = np.zeros_like(a4["bk"]) if "bk" in a4 else None
        bv = np.zeros_like(a4["bv"]) if "bv" in a4 else None
        for g in range(d1.n_kv):
            for r in range(rep):
                pg = g * rep + r
                wk[:, :, pg] = a1["wk"][:, :, g]
                wv[:, :, pg] = a1["wv"][:, :, g]
                if bk is not None:
                    bk[:, pg] = a1["bk"][:, g]
                    bv[:, pg] = a1["bv"][:, g]
            for j in range(qpg1):
                r, jj = divmod(j, qpg4)
                p_phys = (g * rep + r) * qpg4 + jj
                p_log = g * qpg1 + j
                wq[:, :, p_phys] = a1["wq"][:, :, p_log]
                wo[:, p_phys] = a1["wo"][:, p_log]
                if bq is not None:
                    bq[:, p_phys] = a1["bq"][:, p_log]
        p4[lname]["attn"].update(
            {k: v for k, v in dict(wq=wq, wk=wk, wv=wv, wo=wo, bq=bq,
                                   bk=bk, bv=bv).items() if v is not None})
        p4[lname]["ffn_norm"] = p1_np[lname]["ffn_norm"]
        p4[lname]["attn_norm"] = p1_np[lname]["attn_norm"]
        p4[lname]["mlp"] = p1_np[lname]["mlp"]
    # shared non-layer leaves: vocab may be padded
    v1 = p1_np["embed"].shape[0]
    emb = np.zeros_like(p4["embed"])
    emb[:v1] = p1_np["embed"]
    p4["embed"] = emb
    if "lm_head" in p4:
        head = np.zeros_like(p4["lm_head"])
        head[:, :v1] = p1_np["lm_head"]
        p4["lm_head"] = head
    p4["final_norm"] = p1_np["final_norm"]
    p4 = jax.tree.map(jnp.asarray, p4)

    m = make_model(c, tp=1)
    batch = _batch(c, key, 2, 16)
    out1, _ = m1.forward(p1, batch)
    out4, _ = m4.forward(p4, batch)
    np.testing.assert_allclose(np.asarray(out1),
                               np.asarray(out4[:, :, :v1]),
                               atol=1e-4, rtol=1e-4)


def test_moe_dispatch_matches_dense_oracle(key):
    from repro.models.moe import init_moe, moe_apply, moe_dense_oracle
    E, K, d, ff = 4, 2, 32, 64
    p = init_moe(key, d, ff, E, jnp.float32, shared_expert=True,
                 activation="swiglu")
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 8, d))
    y, aux = moe_apply(p, x, num_experts=E, top_k=K,
                       capacity_factor=float(E), activation="swiglu")
    y_ref = moe_dense_oracle(p, x, num_experts=E, top_k=K,
                             activation="swiglu")
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=1e-4, rtol=1e-4)
    assert float(aux) > 0


def test_moe_capacity_drops_bounded(key):
    """With cf=1.0 some tokens may drop, but outputs stay finite and within
    the convex hull scale of expert outputs."""
    from repro.models.moe import init_moe, moe_apply
    E, K, d, ff = 4, 2, 16, 32
    p = init_moe(key, d, ff, E, jnp.float32, False, "swiglu")
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, d))
    y, aux = moe_apply(p, x, num_experts=E, top_k=K, capacity_factor=1.0,
                       activation="swiglu")
    assert bool(jnp.isfinite(y).all())


def test_ssd_chunked_matches_recurrence(key):
    from repro.models.ssd import ssd_chunked, ssd_reference
    B, T, H, P, G, N = 2, 96, 4, 16, 1, 8
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, T, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, T, G, N))
    Cm = jax.random.normal(ks[4], (B, T, G, N))
    y1, s1 = ssd_chunked(x, dt, A, Bm, Cm, chunk=32)
    y2, s2 = ssd_reference(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               atol=1e-3, rtol=1e-3)


def test_ssd_decode_continues_prefill(key):
    """Running T steps with the decode recurrence == chunked full-seq."""
    from repro.models.ssd import ssd_chunked, ssd_decode_step
    B, T, H, P, G, N = 1, 33, 2, 8, 1, 4
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, T, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, T, G, N))
    Cm = jax.random.normal(ks[4], (B, T, G, N))
    y_full, s_full = ssd_chunked(x, dt, A, Bm, Cm, chunk=16)
    s = jnp.zeros((B, H, P, N))
    ys = []
    for t in range(T):
        y, s = ssd_decode_step(s, x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t])
        ys.append(y)
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_seq),
                               atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(s_full), np.asarray(s),
                               atol=1e-3, rtol=1e-3)


def test_grad_accum_equivalence(key):
    """grad_accum=2 gives (numerically) the same update as grad_accum=1."""
    c = get_config("granite-3-8b").reduced()
    m = make_model(c, tp=1)
    params = m.init(key, jnp.float32)
    opt = AdamW(lr=1e-3)
    batch = _batch(c, key, 4, 16)
    s1 = jax.jit(make_train_step(m, opt, grad_accum=1))(
        params, opt.init(params), batch)
    s2 = jax.jit(make_train_step(m, opt, grad_accum=2))(
        params, opt.init(params), batch)
    for a, b in zip(jax.tree.leaves(s1[0]), jax.tree.leaves(s2[0])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-5, rtol=5e-4)
