"""Distributed runtime: sharding rules, HLO collective accounting, elastic
remesh, and a small-mesh dry-run in a subprocess (8 host devices)."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.distributed.sharding import collective_bytes

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_collective_bytes_parser():
    hlo = textwrap.dedent("""
      %ag = bf16[16,1024]{1,0} all-gather(bf16[2,1024]{1,0} %x), dims={0}
      %ar.1 = f32[512]{0} all-reduce(f32[512]{0} %y), to_apply=%add
      %rs = f32[64,8]{1,0} reduce-scatter(f32[512,8]{1,0} %z), dims={0}
      %cp = u32[4]{0} collective-permute(u32[4]{0} %w)
      %fusion.all-reduce-like = f32[9]{0} fusion(f32[9]{0} %v)
      %ard = (f32[8]{0}, f32[8]{0}) all-reduce-start(f32[8]{0} %q)
    """)
    out = collective_bytes(hlo)
    assert out["all-gather"] == 16 * 1024 * 2
    assert out["all-reduce"] == 512 * 4 * 2 + 2 * 8 * 4 * 2  # 2x ring factor
    assert out["reduce-scatter"] == 64 * 8 * 4
    assert out["collective-permute"] == 4 * 4
    assert out["total"] == sum(v for k, v in out.items() if k != "total")


_SUBPROC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.distributed.sharding import (ShardPlan, batch_shardings,
                                        make_shard_fn, param_shardings,
                                        serve_state_shardings)
from repro.launch.mesh import make_mesh
from repro.models.model import make_model, make_train_step
from repro.models.optim import AdamW

cfg = get_config(sys.argv[1]).reduced()
mesh = make_mesh((4, 2), ("data", "model"))
model = make_model(cfg, tp=2)
plan = ShardPlan(mesh, "train")
shard_fn = make_shard_fn(plan)
params = model.init(jax.random.PRNGKey(0), jnp.float32)
pshard = param_shardings(plan, params)
params = jax.device_put(params, pshard)
opt = AdamW(lr=1e-3)
opt_state = jax.device_put(opt.init(params),
                           {"mu": pshard, "nu": pshard,
                            "step": jax.sharding.NamedSharding(
                                mesh, jax.sharding.PartitionSpec())})
B, S = 8, 32
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                      cfg.vocab_size)}
if cfg.family == "vlm":
    batch["patch_embeds"] = jax.random.normal(
        jax.random.PRNGKey(2), (B, cfg.num_patches, cfg.d_model))
if cfg.family == "audio":
    batch["frame_embeds"] = jax.random.normal(
        jax.random.PRNGKey(2), (B, cfg.encoder_seq_len, cfg.d_model))
batch = jax.device_put(batch, batch_shardings(plan, batch))
step = jax.jit(make_train_step(model, opt, shard_fn=shard_fn))
p2, o2, metrics = step(params, opt_state, batch)
loss_sharded = float(metrics["loss"])

# single-device reference
model1 = make_model(cfg, tp=1)
# NB: padded tp=2 model has its own params; check finiteness + serve path
serve_plan = ShardPlan(mesh, "serve")
state = model.init_serve_state(B, 64, jnp.float32)
sshard = serve_state_shardings(serve_plan, jax.eval_shape(lambda: state), cfg)
state = jax.device_put(state, sshard)
logits, state2 = jax.jit(lambda p, s, t, pos: model.decode(p, s, t, pos))(
    params, state, jnp.zeros((B, 1), jnp.int32), jnp.int32(0))
print(json.dumps({"loss": loss_sharded,
                  "decode_finite": bool(jnp.isfinite(logits).all()),
                  "n_dev": len(jax.devices())}))
"""


@pytest.mark.parametrize("arch", ["granite-3-8b", "grok-1-314b",
                                  "mamba2-1.3b"])
def test_small_mesh_train_and_decode(arch, tmp_path):
    """Real 8-device (host) mesh: sharded train step + decode run and stay
    finite. Covers dense, MoE and SSM sharding rules."""
    script = tmp_path / "run.py"
    script.write_text(_SUBPROC_SCRIPT)
    env = dict(os.environ, PYTHONPATH=os.path.abspath(SRC))
    out = subprocess.run([sys.executable, str(script), arch],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["n_dev"] == 8
    assert np.isfinite(res["loss"])
    assert res["decode_finite"]


_ELASTIC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.distributed.elastic import elastic_remesh, reshard_params, \
    survivors_mesh
from repro.distributed.sharding import ShardPlan, param_shardings
from repro.models.model import make_model

cfg = get_config("granite-3-8b").reduced()
model = make_model(cfg, tp=2)
params = model.init(jax.random.PRNGKey(0), jnp.float32)
m1 = elastic_remesh(4, 2)
p1 = reshard_params(params, ShardPlan(m1, "train"))
# simulate losing devices 6,7 (data row 3) -> shrink to 3x2
m2 = survivors_mesh(m1, [6], 2)
assert m2.shape["data"] == 3, m2.shape
p2 = reshard_params(p1, ShardPlan(m2, "train"))
ok = all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(p2))
import numpy as np
same = all(np.allclose(np.asarray(a), np.asarray(b))
           for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
print(json.dumps({"ok": ok, "same": same}))
"""


def test_elastic_remesh_preserves_params(tmp_path):
    script = tmp_path / "run.py"
    script.write_text(_ELASTIC_SCRIPT)
    env = dict(os.environ, PYTHONPATH=os.path.abspath(SRC))
    out = subprocess.run([sys.executable, str(script)], capture_output=True,
                         text=True, env=env, timeout=300)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["ok"] and res["same"]


def test_param_pspec_rules():
    """Sharding specs: TP dims land on 'model', FSDP on data, scan dims
    replicated; non-divisible dims fall back to replication."""
    import jax

    from repro.configs import get_config
    from repro.distributed.sharding import ShardPlan, param_pspec

    class FakeLeaf:
        def __init__(self, shape):
            self.shape = shape
            self.ndim = len(shape)

    # build a mesh of host devices = 1; use spec logic only via _fits with
    # a real (1,1) mesh — divisibility always ok for size-1 axes.
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    plan = ShardPlan(mesh, "train")

    class P_:  # path element stub
        def __init__(self, k):
            self.key = k

    spec = param_pspec(plan, (P_("layers"), P_("attn"), P_("wq")),
                       FakeLeaf((4, 128, 8, 32)))
    assert spec == jax.sharding.PartitionSpec(None, ("data",), "model", None)
    spec = param_pspec(plan, (P_("embed"),), FakeLeaf((1000, 128)))
    assert spec == jax.sharding.PartitionSpec("model", ("data",))
    # serve mode: fsdp -> replicated
    plan_s = ShardPlan(mesh, "serve")
    spec = param_pspec(plan_s, (P_("embed"),), FakeLeaf((1000, 128)))
    assert spec == jax.sharding.PartitionSpec("model", None)
    # unknown leaves replicate
    spec = param_pspec(plan, (P_("A_log"),), FakeLeaf((4, 8)))
    assert spec == jax.sharding.PartitionSpec()
