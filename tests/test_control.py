"""Unified control plane + elastic request-level backend.

Covers the ClusterBackend contract both ways: operational semantics of the
elastic engine (cold-start provisioning, drain-before-remove, failure
re-queue, heterogeneous replicas), the bucketed-prefill retrace bound, the
routing-fraction guard, straggler persistence in the fluid sim, and ranking
parity between the fluid and request-level backends under the same plane.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.paper_cluster import ClusterConfig
from repro.control import ControlPlane, SimBackend
from repro.models import make_model
from repro.serving import (ClusterFrontend, ElasticClusterFrontend,
                           ReplicaEngine, Request, normalize_fractions)
from repro.sim.cluster import ClusterSim
from repro.sim.experiment import collect_episode

MAX_SEQ = 64


@pytest.fixture(scope="module")
def setup():
    c = get_config("granite-3-8b").reduced()
    m = make_model(c, tp=1)
    params = m.init(jax.random.PRNGKey(0), jnp.float32)
    return c, m, params


def _factory(m, params, max_batch=2, speed=1.0):
    def make_replica(rid):
        return ReplicaEngine(m, params, max_batch=max_batch, max_seq=MAX_SEQ,
                             rid=rid, speed=speed)
    return make_replica


def _req(i, plen=4, n_new=4):
    return Request(i, [1 + (i + j) % 97 for j in range(plen)],
                   max_new_tokens=n_new)


# ---------------------------------------------------------------- elastic
def test_scale_up_respects_provisioning_delay(setup):
    c, m, params = setup
    fe = ElasticClusterFrontend(_factory(m, params), 1, initial_replicas=1,
                                provisioning_delay=3)
    fe.scale_to(np.array([3]))
    assert fe.in_flight().tolist() == [3]
    live = []
    for _ in range(4):
        fe.tick(0.0)
        live.append(len(fe.nodes[0].live))
    # cold start: nothing serves before the delay elapses, then all arrive
    assert live == [1, 1, 3, 3]


def test_drain_before_remove_finishes_in_flight(setup):
    c, m, params = setup
    fe = ElasticClusterFrontend(_factory(m, params), 1, initial_replicas=2)
    reqs = [_req(i, n_new=6) for i in range(4)]
    for r in reqs:
        fe.submit(r)
    fe.tick(0.0)                          # route + admit across both replicas
    assert all(e.n_active > 0 for e in fe.nodes[0].live)
    fe.scale_to(np.array([1]))            # remove one replica
    node = fe.nodes[0]
    assert len(node.live) == 1 and len(node.draining) == 1
    drained = node.draining[0]
    assert drained.draining and drained.n_active > 0
    fe.run_until_drained()
    # no dropped in-flight work: every request finished with full output
    assert all(r.done and len(r.output) == 6 for r in reqs)
    assert node.draining == [] and len(node.live) == 1
    # a draining replica admits nothing new
    fe.submit(_req(99, n_new=2))
    fe.run_until_drained()
    assert drained.steps <= 6 + 1         # only its original slot work


def test_replica_failure_requeues_lost_work(setup):
    c, m, params = setup
    fe = ElasticClusterFrontend(_factory(m, params), 1, initial_replicas=2)
    reqs = [_req(i, n_new=5) for i in range(4)]
    for r in reqs:
        fe.submit(r)
    fe.tick(0.0)
    victim = fe.nodes[0].live[0]
    carried = [r for r in victim.slots if r is not None] + list(victim.queue)
    assert carried, "victim replica should hold work"
    fe.fail_replica(0, 0)
    assert fe.failed_replicas == 1
    assert len(fe.nodes[0].live) == 1
    # lost requests had their progress reset and sit back in the node queue
    assert all(not r.done and r.output == [] for r in carried)
    fe.run_until_drained()
    assert all(r.done and len(r.output) == 5 for r in reqs)


def test_dead_node_work_reroutes_to_healthy_node(setup):
    """When every replica on a node dies, its queued work must migrate to
    healthy nodes (the elastic twin of the sim's retry pool) instead of
    stranding forever."""
    c, m, params = setup
    fe = ElasticClusterFrontend(_factory(m, params), 2, initial_replicas=1)
    fe.route(np.array([1.0, 0.0]))        # pin everything to node 0
    reqs = [_req(i, n_new=3) for i in range(4)]
    for r in reqs:
        fe.submit(r)
    fe.tick(0.0)
    fe.fail_replica(0, 0)                 # node 0 now has no replicas
    assert fe.up_mask().tolist() == [0.0, 1.0]
    fe.route(np.array([0.5, 0.5]))        # routing guard masks dead node
    fe.run_until_drained()
    assert all(r.done and len(r.output) == 3 for r in reqs)


def test_heterogeneous_speed_drains_faster(setup):
    c, m, params = setup

    def drain_ticks(speed):
        fe = ElasticClusterFrontend(_factory(m, params, max_batch=2,
                                             speed=speed), 1,
                                    initial_replicas=1)
        for i in range(6):
            fe.submit(_req(i, n_new=6))
        for t in range(1, 200):
            fe.tick(0.0)
            if fe.nodes[0].unfinished() == 0 and not fe.pending:
                return t
        raise AssertionError("did not drain")

    # a 2x-speed replica runs two decode sub-steps per tick via the credit
    # scheduler -> roughly half the wall-clock ticks
    assert drain_ticks(2.0) < 0.7 * drain_ticks(1.0)


# ---------------------------------------------------- prefill retrace bound
def test_prefill_retraces_bounded_by_buckets(setup):
    """Acceptance: prefill compiles O(log max_seq) bucketed variants, not
    once per distinct prompt length."""
    c, m, params = setup
    eng = ReplicaEngine(m, params, max_batch=4, max_seq=MAX_SEQ)
    t0 = eng.prefill_traces        # kernels are shared across replicas of
    lens = list(range(2, 31))      # the same model; count this run's delta
    for i, L in enumerate(lens):
        eng.submit(_req(i, plen=L, n_new=2))
    for _ in range(400):
        eng.step()
        if eng.load == 0:
            break
    assert eng.load == 0
    compiles = eng.prefill_traces - t0
    len_buckets = int(np.log2(MAX_SEQ // eng.min_bucket)) + 1
    batch_buckets = int(np.log2(eng.max_batch)) + 1
    assert compiles <= len_buckets * batch_buckets
    assert compiles < len(set(lens))   # beats once-per-prompt-length


def test_replicas_share_compiled_kernels(setup):
    """A cold-started replica of the same model reuses compiled serve
    kernels instead of re-jitting (scale-ups must not stall on XLA)."""
    c, m, params = setup
    e1 = ReplicaEngine(m, params, max_batch=2, max_seq=MAX_SEQ)
    e1.submit(_req(0, plen=4, n_new=2))
    e1.step()
    before = e1.prefill_traces
    e2 = ReplicaEngine(m, params, max_batch=2, max_seq=MAX_SEQ)
    assert e2._prefill is e1._prefill
    e2.submit(_req(1, plen=4, n_new=2))
    e2.step()
    assert e2.prefill_traces == before    # same shape -> zero new compiles


# ------------------------------------------------------- fraction guard
def test_normalize_fractions_guards_zero_and_nan():
    n = 4
    uniform = np.full(n, 0.25)
    assert np.allclose(normalize_fractions(np.zeros(n)), uniform)
    assert np.allclose(normalize_fractions(np.full(n, np.nan)), uniform)
    assert np.allclose(normalize_fractions(np.array([-1.0, 0, 0, 0])),
                       uniform)
    masked = normalize_fractions(np.zeros(n), mask=np.array([1, 1, 0, 0]))
    assert np.allclose(masked, [0.5, 0.5, 0, 0])
    fr = normalize_fractions(np.array([np.inf, 1.0, 0, 0]))
    assert np.isfinite(fr).all() and fr.sum() == pytest.approx(1.0)
    # all-false mask: uniform-over-NONE (zeros), never a uniform split over
    # dead nodes — callers park arrivals instead of routing them (PR 8)
    dead = normalize_fractions(np.ones(n), mask=np.zeros(n))
    assert dead.tolist() == [0.0] * n
    assert normalize_fractions(np.full(n, np.nan),
                               mask=np.zeros(n)).tolist() == [0.0] * n


def test_frontend_fractions_policy_survives_bad_fn(setup):
    c, m, params = setup
    engines = [ReplicaEngine(m, params, max_batch=2, max_seq=MAX_SEQ, rid=i)
               for i in range(2)]
    fe = ClusterFrontend(engines, policy="fractions",
                         fractions_fn=lambda fe: np.zeros(2))
    for i in range(4):
        fe.submit(_req(i, n_new=2))
    fe.run_until_drained()
    assert len(fe.finished) == 4


# ------------------------------------------------- straggler persistence
def test_straggler_slowdown_persists_across_ticks():
    cfg = ClusterConfig(num_nodes=4, straggler_prob=0.0, node_mtbf=1e12)
    sim = ClusterSim(cfg, 30.0, seed=0, failures=True)
    sim.state.slow_left[:] = 3
    uniform = np.full(4, 0.25, np.float32)
    slows = []
    for _ in range(4):
        sim.tick(1.0, uniform)
        slows.append(float(sim.state.slow[0]))
    # degraded for the sampled duration, then recovers (the old code reset
    # the multiplier from a fresh Bernoulli draw every tick)
    assert slows[:2] == pytest.approx([cfg.straggler_slowdown] * 2)
    assert slows[-1] == 1.0


# ------------------------------------------------------- backend parity
def _parity_cfg():
    return ClusterConfig(
        num_nodes=2, horizon=4, forecast_window=8, provisioning_delay=2,
        max_replicas_per_node=2, min_replicas_per_node=1, scale_interval=3,
        cooldown=6, straggler_prob=0.0, node_mtbf=1e12)


N_NEW = 4          # fixed decode length -> replica rate = max_batch / N_NEW

_PARITY_TIERS = None     # module default: untiered


def _run_elastic(m, params, cfg, arrivals, scaler, tiers=None):
    def request_factory(rid, tick):
        req = Request(rid, [1 + rid % 50, 2, 3, 4], max_new_tokens=N_NEW)
        if tiers is not None:
            req.tier = tiers.names[rid % len(tiers)]
        return req

    # eager (synchronous) ticks: the fluid sim observes its own tick
    # synchronously, so the apples-to-apples ranking comparison runs the
    # engine's eager oracle too — the async tick intentionally delays
    # metric observation by one tick, which shifts WHICH node the scaler
    # grows first (legit controller divergence, not a serving difference)
    fe = ElasticClusterFrontend(
        _factory(m, params, max_batch=2), cfg.num_nodes, initial_replicas=1,
        provisioning_delay=cfg.provisioning_delay,
        max_replicas_per_node=cfg.max_replicas_per_node,
        request_factory=request_factory, seed=0, est_tokens=N_NEW,
        async_tick=False, tiers=tiers)
    plane = ControlPlane(cfg, fe, balancer="rr", scaler=scaler,
                         unit_capacity=2.0 / N_NEW, seed=0,
                         init_arrival=float(arrivals[:5].mean()))
    return collect_episode(plane, arrivals, scaler, cfg,
                           unit_capacity=2.0 / N_NEW)


def _run_sim(cfg, arrivals, scaler, tiers=None):
    sim = ClusterSim(cfg, 2.0 / N_NEW, seed=0, failures=False,
                     heterogeneous=False, tiers=tiers)
    plane = ControlPlane(cfg, SimBackend(sim), balancer="rr", scaler=scaler,
                         unit_capacity=2.0 / N_NEW, seed=0,
                         init_arrival=float(arrivals[:5].mean()))
    return collect_episode(plane, arrivals, scaler, cfg,
                           unit_capacity=2.0 / N_NEW)


def _ranking_parity(m, params, tiers=None):
    """Shared body: static vs rbas ranking must agree sim <-> elastic."""
    # 1.6 req/tick vs static capacity of 2 nodes x 1 replica x 0.5 req/tick:
    # static saturates, the autoscaler can double capacity.
    arrivals = np.full(36, 1.6, np.float32)
    cfg = _parity_cfg()
    rankings = {}
    for backend in ("sim", "engine"):
        res = {}
        for scaler in ("static", "rbas"):
            if backend == "sim":
                r = _run_sim(cfg, arrivals, scaler, tiers=tiers)
            else:
                r = _run_elastic(m, params, cfg, arrivals, scaler,
                                 tiers=tiers)
            res[scaler] = r.summary(warmup=8)["mean_resp"]
        rankings[backend] = sorted(res, key=res.get)
    assert rankings["sim"] == rankings["engine"]
    assert rankings["sim"][0] == "rbas"   # autoscaling wins under saturation


def test_method_ranking_matches_across_backends(setup):
    """The same ControlPlane over the fluid sim and the request-level engine
    must rank scaling policies identically: under a saturating trace, the
    rule-based autoscaler beats the static allocation on response time on
    BOTH backends (the paper's qualitative claim, ported to real forwards)."""
    c, m, params = setup
    _ranking_parity(m, params)


def test_method_ranking_matches_across_backends_3tier(setup):
    """Backend-ranking parity holds under SLO-tiered traffic too: both
    backends run the tiered queues/metrics path (premium-first fluid drain
    vs weighted-deficit request admission) and still rank the scaling
    policies identically."""
    from repro.workload import TierSet, TierSpec

    c, m, params = setup
    tiers = TierSet([TierSpec("premium", share=0.34, weight=5.0,
                              ttft_target=4.0),
                     TierSpec("standard", share=0.33, weight=2.0),
                     TierSpec("batch", share=0.33, weight=1.0)])
    _ranking_parity(m, params, tiers=tiers)


def test_async_observation_shifts_decisions_at_most_one_tick(setup):
    """Stale-observation contract: the async tick's metrics describe the
    device state one tick earlier, so on a fixed trace every rule-based
    ``scale_to`` decision of the async backend must appear among the eager
    oracle's decisions within one plan interval (rbas plans every tick,
    window t-1..t+1) — staleness may DELAY a decision, never diverge it.
    Pinned on a single-node backend: with several nodes the lag legally
    shifts WHICH node grows first (see ``_run_elastic``), which compounds
    into different per-node trajectories; the total-capacity decision is
    the contract."""
    c, m, params = setup
    arrivals = np.full(28, 1.6, np.float32)
    cfg = ClusterConfig(
        num_nodes=1, horizon=4, forecast_window=8, provisioning_delay=2,
        max_replicas_per_node=4, min_replicas_per_node=1, scale_interval=3,
        cooldown=6, straggler_prob=0.0, node_mtbf=1e12)

    def decisions(async_tick):
        def request_factory(rid, tick):
            return Request(rid, [1 + rid % 50, 2, 3, 4],
                           max_new_tokens=N_NEW)

        fe = ElasticClusterFrontend(
            _factory(m, params, max_batch=2), 1, initial_replicas=1,
            provisioning_delay=cfg.provisioning_delay,
            max_replicas_per_node=cfg.max_replicas_per_node,
            request_factory=request_factory, seed=0, est_tokens=N_NEW,
            async_tick=async_tick)
        plane = ControlPlane(cfg, fe, balancer="rr", scaler="rbas",
                             unit_capacity=2.0 / N_NEW, seed=0,
                             init_arrival=float(arrivals[:5].mean()))
        out = []
        orig = fe.scale_to

        def spy(target):
            out.append(int(np.asarray(target).sum()))
            orig(target)

        fe.scale_to = spy
        for a in arrivals:
            plane.step(float(a))
        return out

    eager = decisions(False)
    lagged = decisions(True)
    assert len(eager) == len(lagged) == len(arrivals)
    for t, d in enumerate(lagged):
        lo, hi = max(t - 1, 0), min(t + 1, len(eager) - 1)
        assert d in eager[lo:hi + 1], (t, d, eager[lo:hi + 1])
    assert eager[-1] == lagged[-1]       # same steady-state capacity


def test_ours_stack_runs_on_elastic_backend(setup):
    """Full OURS wiring (RL balancer + GPSO autoscaler) drives the elastic
    backend end-to-end and produces finite metrics + scaling actions."""
    from repro.core import balancer as bal

    c, m, params = setup
    cfg = _parity_cfg()
    rl = bal.RLBalancer(cfg, 4 + cfg.horizon, seed=0)

    def request_factory(rid, tick):
        return Request(rid, [1, 2, 3, 4], max_new_tokens=N_NEW)

    fe = ElasticClusterFrontend(
        _factory(m, params, max_batch=2), cfg.num_nodes, initial_replicas=1,
        provisioning_delay=1,
        max_replicas_per_node=cfg.max_replicas_per_node,
        request_factory=request_factory, seed=0, est_tokens=N_NEW)
    plane = ControlPlane(cfg, fe, balancer="rl", scaler="gpso",
                         unit_capacity=2.0 / N_NEW, rl=rl, seed=0,
                         init_arrival=1.5)
    for _ in range(8):
        m_ = plane.step(1.5)
    assert np.isfinite(m_["response_time"])
    assert np.isfinite(m_["mean_utilization"])
    assert (fe.in_flight() >= 1).all()
    fe.run_until_drained()
    assert all(r.done for r in fe.finished)
