"""Quickstart: every layer of the framework in one script.

    PYTHONPATH=src python examples/quickstart.py

1. builds a reduced assigned architecture, trains a few steps,
2. serves batched requests through the continuous-batching engine
   (prompts are padded to power-of-two buckets, so prefill compiles
   O(log max_seq) variants instead of once per prompt length),
3. runs the paper's control plane (forecast -> MADRL balance -> GPSO scale)
   on a bursty trace and prints the resulting SLO/utilization.

Steps 2 and 3 are two backends of ONE loop: ``repro.control.ControlPlane``
drives any ``ClusterBackend`` — here the fluid ``ClusterSim`` (via
``run_episode``), and in ``python -m repro.launch.serve --policy ours
--autoscale gpso`` the request-level ``ElasticClusterFrontend``, where the
same forecast->balance->scale tick provisions/drains real model replicas.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.paper_cluster import ClusterConfig
from repro.core import balancer as bal
from repro.models import make_model
from repro.models.model import make_train_step
from repro.models.optim import AdamW
from repro.serving import ClusterFrontend, ReplicaEngine, Request
from repro.sim.experiment import run_episode
from repro.workload import TraceConfig, generate_trace

# ---- 1. model substrate -----------------------------------------------
cfg = get_config("mistral-nemo-12b").reduced()
model = make_model(cfg, tp=1)
params = model.init(jax.random.PRNGKey(0), jnp.float32)
opt = AdamW(lr=1e-3)
step = jax.jit(make_train_step(model, opt))
opt_state = opt.init(params)
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 64), 0,
                                      cfg.vocab_size)}
for i in range(3):
    params, opt_state, m = step(params, opt_state, batch)
    print(f"[quickstart] train step {i}: loss={float(m['loss']):.3f}")

# ---- 2. serving engine -------------------------------------------------
replicas = [ReplicaEngine(model, params, max_batch=2, max_seq=64, rid=i)
            for i in range(2)]
fe = ClusterFrontend(replicas, policy="lc")
for i in range(6):
    fe.submit(Request(i, [1, 2, 3, 4], max_new_tokens=4))
fe.run_until_drained()
print(f"[quickstart] served {len(fe.finished)} requests, "
      f"{sum(len(r.output) for r in fe.finished)} tokens")

# ---- 3. the paper's control plane (fluid backend) ----------------------
# run_episode binds ControlPlane to a SimBackend; swap in an
# ElasticClusterFrontend and the identical plane drives real replicas.
ccfg = ClusterConfig(num_nodes=6)
trace = generate_trace(TraceConfig(ticks=200), seed=0, load_scale=1.5)
rl = bal.RLBalancer(ccfg, 4 + ccfg.horizon, seed=0)
res = run_episode(ccfg, trace, "OURS", unit_capacity=30.0, rl=rl, seed=1)
s = res.summary(warmup=20)
print(f"[quickstart] control plane: util={s['mean_util']:.2f} "
      f"p95={s['p95_resp']:.2f}s slo={s['slo_attainment']:.2f} "
      f"cost={s['cost']:.0f} replica-ticks")
