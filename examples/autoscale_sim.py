"""The paper's experiment in miniature: OURS vs all §4.2 baselines on one
bursty Google-cluster-style trace (trains the forecaster + MADRL first).

    PYTHONPATH=src python examples/autoscale_sim.py [--ticks 400]

Each row is a ``ControlPlane`` episode over the fluid ``SimBackend``; the
same plane drives the request-level elastic engine in
``python -m repro.launch.serve --policy ours --autoscale gpso``.
"""
import argparse

import jax
import numpy as np

from repro.configs.paper_cluster import ClusterConfig
from repro.core.forecaster import train_forecaster
from repro.sim.experiment import run_episode, train_rl_balancer
from repro.workload import (TraceConfig, generate_trace,
                            make_forecast_dataset)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ticks", type=int, default=400)
    ap.add_argument("--load", type=float, default=1.8)
    args = ap.parse_args()

    cfg = ClusterConfig(num_nodes=8)
    trace = generate_trace(TraceConfig(ticks=args.ticks), seed=0,
                           load_scale=args.load)

    print("[sim] training demand forecaster (GRU)...")
    ftrace = generate_trace(TraceConfig(ticks=1200), seed=7,
                            load_scale=args.load)
    X, Y, _ = make_forecast_dataset(ftrace["arrivals"], cfg.forecast_window,
                                    cfg.horizon)
    fp, losses = train_forecaster(jax.random.PRNGKey(0), X, Y,
                                  cfg.forecast_hidden, steps=300)
    print(f"[sim] forecaster mse {losses[0]:.3f} -> {losses[-1]:.3f}")

    print("[sim] training MADRL balancer (GCN+DDPG)...")
    rl = train_rl_balancer(
        cfg, [generate_trace(TraceConfig(ticks=400), seed=s,
                             load_scale=args.load) for s in range(3)],
        unit_capacity=30.0, episodes=4, forecaster_params=fp)

    print(f"\n{'method':6s} {'util':>6s} {'resp(s)':>8s} {'p95':>8s} "
          f"{'SLO':>5s} {'fair':>6s} {'eff':>6s} {'cost':>7s}")
    for meth, kw in (("RRA", {}), ("LCA", {}), ("HPA", {}), ("RBAS", {}),
                     ("OURS", {"rl": rl, "forecaster_params": fp})):
        s = run_episode(cfg, trace, meth, unit_capacity=30.0, seed=1,
                        **kw).summary()
        print(f"{meth:6s} {s['mean_util']:6.3f} {s['mean_resp']:8.3f} "
              f"{s['p95_resp']:8.3f} {s['slo_attainment']:5.2f} "
              f"{s['fairness']:6.3f} {s['scaling_efficiency']:6.3f} "
              f"{s['cost']:7.0f}")


if __name__ == "__main__":
    main()
