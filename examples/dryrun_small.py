"""Sharded train + decode on a real (host-device) mesh — the dry-run
machinery at laptop scale. Run as a standalone script (sets XLA device
count before importing jax).

    PYTHONPATH=src python examples/dryrun_small.py --arch grok-1-314b
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import argparse  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.distributed.sharding import (ShardPlan, batch_shardings,  # noqa: E402
                                        make_shard_fn, param_shardings)
from repro.launch.mesh import make_mesh  # noqa: E402
from repro.models.model import make_model, make_train_step  # noqa: E402
from repro.models.optim import AdamW  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="grok-1-314b")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    mesh = make_mesh((4, 2), ("data", "model"))
    print(f"[dryrun-small] {cfg.name} on mesh {dict(mesh.shape)}")
    model = make_model(cfg, tp=2)
    plan = ShardPlan(mesh, "train")
    params = model.init(jax.random.PRNGKey(0), jnp.float32)
    params = jax.device_put(params, param_shardings(plan, params))
    opt = AdamW(lr=1e-3)
    opt_state = opt.init(params)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                          cfg.vocab_size)}
    batch = jax.device_put(batch, batch_shardings(plan, batch))
    step = jax.jit(make_train_step(model, opt, shard_fn=make_shard_fn(plan)))
    lowered = step.lower(params, opt_state, batch)
    compiled = lowered.compile()
    print("[dryrun-small] memory:", compiled.memory_analysis())
    params, opt_state, metrics = compiled(params, opt_state, batch)
    print(f"[dryrun-small] sharded train step OK, "
          f"loss={float(metrics['loss']):.3f}")
    for name in ("embed",):
        print(f"[dryrun-small] {name} sharding:",
              params[name].sharding.spec)


if __name__ == "__main__":
    main()
