"""Kernel microbenches.

On this CPU container the Pallas kernels execute in interpret mode (Python
loop — timings are NOT hardware-representative), so each bench times the
jnp reference path (what the dry-run rooflines measure) and reports the
kernel's ANALYTIC VMEM working set + arithmetic intensity as the derived
column — the numbers that matter for the TPU deployment.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def bench_flash_attention():
    B, Hq, Hkv, S, d = 1, 8, 2, 2048, 128
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Hq, S, d), jnp.float32)
    k = jax.random.normal(ks[1], (B, Hkv, S, d), jnp.float32)
    v = jax.random.normal(ks[2], (B, Hkv, S, d), jnp.float32)
    fn = jax.jit(lambda q, k, v: ref.attention_ref(q, k, v, causal=True))
    us = _time(fn, q, k, v)
    bq, bk = 128, 128
    vmem_kib = (bq * d + 2 * bk * d + bq * bk + bq * d) * 4 / 1024
    flops = 4 * B * Hq * S * S * d * 0.5
    return [("kernel/flash_attention_ref", us,
             f"vmem_tile={vmem_kib:.0f}KiB|flops={flops:.3g}")]


def bench_flash_decode():
    B, Hq, Hkv, S, d = 8, 8, 2, 8192, 128
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, Hq, d), jnp.float32)
    kc = jax.random.normal(ks[1], (B, Hkv, S, d), jnp.float32)
    vc = jax.random.normal(ks[2], (B, Hkv, S, d), jnp.float32)
    fn = jax.jit(lambda q, k, v: ref.decode_attention_ref(q, k, v, S - 1))
    us = _time(fn, q, kc, vc)
    bytes_ = kc.size * 4 * 2
    ai = (4 * B * Hq * S * d) / bytes_
    return [("kernel/flash_decode_ref", us,
             f"cache_bytes={bytes_:.3g}|arith_intensity={ai:.2f}")]


def bench_ssd_scan():
    B, T, H, P, N = 2, 2048, 8, 64, 64
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    x = jax.random.normal(ks[0], (B, T, H, P))
    a = -jnp.abs(jax.random.normal(ks[1], (B, T, H))) * 0.1
    Bm = jax.random.normal(ks[2], (B, T, N))
    Cm = jax.random.normal(ks[3], (B, T, N))
    fn = jax.jit(lambda *args: ref.ssd_scan_ref(*args, 64)[0])
    us = _time(fn, x, a, Bm, Cm)
    state_kib = H * P * N * 4 / 1024
    return [("kernel/ssd_scan_ref", us,
             f"state_scratch={state_kib:.0f}KiB|chunk=64")]


def bench_gcn_fused():
    N, F, H = 16, 36, 64
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    A = jax.random.uniform(ks[0], (N, N))
    X = jax.random.normal(ks[1], (N, F))
    W = jax.random.normal(ks[2], (F, H))
    b = jax.random.normal(ks[3], (H,))
    fn = jax.jit(lambda *a: ref.gcn_layer_ref(*a))
    us = _time(fn, A, X, W, b)
    return [("kernel/gcn_fused_ref", us,
             f"control_plane_tick_cost|N={N}")]


def main():
    out = []
    out += bench_flash_attention()
    out += bench_flash_decode()
    out += bench_ssd_scan()
    out += bench_gcn_fused()
    return out


if __name__ == "__main__":
    for row in main():
        print(",".join(str(x) for x in row))
