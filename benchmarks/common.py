"""Shared control-plane setup for the paper-figure benchmarks.

Trains the GRU forecaster + MADRL balancer once per process and caches the
trained state on disk (results/cache/) so the three figure benches and the
claims table share one controller.
"""
from __future__ import annotations

import os
import pickle

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.paper_cluster import ClusterConfig
from repro.core import balancer as bal
from repro.core.forecaster import train_forecaster
from repro.sim.experiment import run_episode, train_rl_balancer
from repro.sim.service_rate import replica_request_rate
from repro.workload import (LOAD_LEVELS, TraceConfig, generate_trace,
                            make_forecast_dataset)

CACHE_DIR = "results/cache"
CLUSTER = ClusterConfig(num_nodes=8)
SERVED_ARCH = "granite-3-8b"          # the model the cluster serves
UNIT_CAP = 30.0                       # req/s per replica (see service_rate)
TRAIN_LOAD = 1.8
BENCH_TICKS = 600
METHODS = ("RRA", "LCA", "HPA", "RBAS", "OURS")


def real_unit_capacity() -> float:
    """Roofline-derived req/s of one TP-16 replica serving SERVED_ARCH."""
    return replica_request_rate(get_config(SERVED_ARCH))


def _cache(name):
    os.makedirs(CACHE_DIR, exist_ok=True)
    return os.path.join(CACHE_DIR, name)


def get_controller(seed: int = 0, force: bool = False):
    """Returns (forecaster_params, rl_balancer). Cached on disk."""
    path = _cache("controller.pkl")
    rl = bal.RLBalancer(CLUSTER, 4 + CLUSTER.horizon, seed=seed)
    if os.path.exists(path) and not force:
        with open(path, "rb") as f:
            blob = pickle.load(f)
        fp = blob["forecaster"]
        rl.state = blob["ddpg"]
        return fp, rl
    ftrace = generate_trace(TraceConfig(ticks=2400), seed=97,
                            load_scale=TRAIN_LOAD)
    X, Y, _ = make_forecast_dataset(ftrace["arrivals"],
                                    CLUSTER.forecast_window, CLUSTER.horizon)
    fp, _ = train_forecaster(jax.random.PRNGKey(seed), X, Y,
                             CLUSTER.forecast_hidden, steps=400)
    traces = [generate_trace(TraceConfig(ticks=400), seed=s,
                             load_scale=TRAIN_LOAD) for s in range(3)]
    rl = train_rl_balancer(CLUSTER, traces, unit_capacity=UNIT_CAP,
                           episodes=6, forecaster_params=fp, seed=seed)
    with open(path, "wb") as f:
        pickle.dump({"forecaster": fp, "ddpg": rl.state}, f)
    return fp, rl


def run_method(method: str, load_scale: float, seed: int = 1,
               ticks: int = BENCH_TICKS, controller=None):
    trace = generate_trace(TraceConfig(ticks=ticks), seed=7,
                           load_scale=load_scale)
    kw = {}
    if method.startswith("OURS"):
        fp, rl = controller or get_controller()
        kw = {"rl": rl, "forecaster_params": fp}
    return run_episode(CLUSTER, trace, method, unit_capacity=UNIT_CAP,
                       seed=seed, **kw)
