"""Component ablations of the paper's framework (beyond the paper's own
tables): what does each piece of OURS buy?

  OURS      = GRU forecast + MADRL balancer + GPSO autoscaler
  OURS-GA   = GA-only autoscaler (no PSO refinement, same eval budget)
  OURS-LV   = last-value forecast instead of the GRU
  OURS-RR   = GPSO scaling but round-robin balancing (no MADRL)

Writes results/ablations.csv.
"""
from __future__ import annotations

import csv
import os

from benchmarks.common import CLUSTER, UNIT_CAP, get_controller
from repro.sim.experiment import run_episode
from repro.workload import TraceConfig, generate_trace


def main() -> list:
    fp, rl = get_controller()
    trace = generate_trace(TraceConfig(ticks=600), seed=7, load_scale=1.8)
    variants = {
        "OURS": dict(method="OURS", rl=rl, forecaster_params=fp),
        "OURS-GA": dict(method="OURS-GA", rl=rl, forecaster_params=fp),
        "OURS-LV": dict(method="OURS", rl=rl, forecaster_params=None),
        "OURS-RR": dict(method="OURS-RR"),
    }
    rows, out = [], []
    for name, kw in variants.items():
        method = kw.pop("method")
        s = run_episode(CLUSTER, trace, method, unit_capacity=UNIT_CAP,
                        seed=1, **kw).summary()
        rows.append([name, s["mean_util"], s["mean_resp"], s["p95_resp"],
                     s["slo_attainment"], s["scaling_efficiency"], s["cost"]])
        out.append((f"ablation/{name}", 0.0,
                    f"resp={s['mean_resp']:.3f}|eff="
                    f"{s['scaling_efficiency']:.3f}|cost={s['cost']:.0f}"))
    os.makedirs("results", exist_ok=True)
    with open("results/ablations.csv", "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["variant", "mean_util", "mean_resp", "p95_resp", "slo",
                    "scaling_efficiency", "cost"])
        w.writerows(rows)
    return out


if __name__ == "__main__":
    for r in main():
        print(",".join(str(x) for x in r))
