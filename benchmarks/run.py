"""Benchmark entrypoint — one harness per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  fig1_utilization/*      — paper Fig.1 (resource utilization over time)
  fig2_response/*         — paper Fig.2 (response time vs load)
  fig3_scaling/*          — paper Fig.3 (scaling efficiency vs load)
  claims/*                — the +35% / -28% headline validation
  serve/*                 — elastic request-level engine (tok/s, TTFT,
                            prefill retraces) -> results/BENCH_serve.json
  roofline/*              — per (arch x shape) roofline terms (§Roofline)
  kernel/*                — kernel microbenches

Artifacts land under results/ (CSVs + JSON).
"""
from __future__ import annotations

import sys


def main() -> None:
    rows = []
    args = set(sys.argv[1:])
    all_ = not args

    if all_ or "figs" in args:
        from benchmarks.common import get_controller
        from benchmarks.fig_benches import (fig1_utilization,
                                            fig2_response_time,
                                            fig3_scaling_efficiency,
                                            paper_claims)
        controller = get_controller()
        rows += fig1_utilization(controller)
        rows += fig2_response_time(controller)
        rows += fig3_scaling_efficiency(controller)
        rows += paper_claims(controller)
    if all_ or "serve" in args:
        from benchmarks.serve_bench import main as serve_main
        rows += serve_main()
    if all_ or "ablations" in args:
        from benchmarks.ablations import main as ablations_main
        rows += ablations_main()
    if all_ or "roofline" in args:
        from benchmarks.roofline import main as roofline_main
        rows += roofline_main()
    if all_ or "kernels" in args:
        from benchmarks.kernels_bench import main as kernels_main
        rows += kernels_main()

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
