"""Roofline analysis per (arch × shape) on the single-pod production mesh.

Methodology (see EXPERIMENTS.md §Roofline):
  * XLA-CPU ``cost_analysis`` counts while-loop bodies ONCE (verified by
    calibration), so compiled numbers are recorded as artifacts but the
    roofline terms are ANALYTIC: trip-count-aware FLOP counts and explicit
    HBM/ICI stream models derived from the sharding plan actually used by
    the dry-run (FSDP×TP train, TP(+expert-data) serve, grad-accum ga,
    remat='full').
  * compute   = FLOPs_per_device / peak_flops
  * memory    = HBM_bytes_per_device / hbm_bw
  * collective= ICI_bytes_per_device / ici_bw   (ring-factor accounting)
  * MODEL_FLOPS = 6·N_active·D (train) or 2·N_active·D (serve);
    ratio = MODEL_FLOPS / device_FLOPs×chips — exposes padding, attention,
    and remat-recompute overheads.

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import glob
import json
import os

from repro.configs import SHAPES, applicable_shapes, get_config
from repro.models.dims import padded_dims

PEAK = 197e12
HBM = 819e9
ICI = 50e9
CHIPS = 256
TP = 16
DP = 16


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    # terms (seconds, per device per step)
    compute: float
    memory: float
    collective: float
    model_flops: float
    device_flops: float
    hbm_bytes: float
    coll_bytes: float
    opts: dict

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute, "memory": self.memory,
                 "collective": self.collective}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / max(self.device_flops * CHIPS, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """useful-compute time / total bound time (how close the step is to
        the pure-MODEL_FLOPS roofline)."""
        ideal = self.model_flops / CHIPS / PEAK
        actual = max(self.compute, self.memory, self.collective)
        return ideal / max(actual, 1e-12)

    def lever(self) -> str:
        d = self.dominant
        if d == "collective":
            return ("reduce FSDP re-gathers (ga x weight all-gather "
                    "dominates): lower ga / persist gathered weights / 2D "
                    "sharded gather")
        if d == "memory":
            if self.opts.get("kind") == "decode":
                return ("KV-cache stream dominates: seq-sharded KV + "
                        "LSE-merge flash-decode halves per-device bytes "
                        "(removes kv-head replication)")
            return ("attention score traffic dominates: fused (flash) "
                    "attention kernel removes the S^2 HBM stream")
        return ("compute-bound: raise per-chip utilization (larger "
                "microbatch if memory allows; MXU-aligned head padding "
                "already minimal)")


def _attn_flops(cfg, B, S_q, S_kv, n_heads, causal, factor):
    hd = cfg.resolved_head_dim
    c = 0.5 if causal and S_q == S_kv else 1.0
    return factor * B * cfg_layers_attn(cfg) * n_heads * hd * S_q * S_kv * c


def cfg_layers_attn(cfg):
    if cfg.family == "hybrid":
        return (cfg.num_layers + cfg.attn_every - 1) // cfg.attn_every
    if cfg.family == "ssm":
        return 0
    return cfg.num_layers


def _ssd_flops_per_token(cfg):
    if cfg.family not in ("ssm", "hybrid"):
        return 0
    Q, N, P, H, L = (cfg.ssm_chunk, cfg.ssm_state, cfg.ssm_head_dim,
                     cfg.ssm_heads, cfg.num_layers)
    per_tok_head = 2 * Q * (N + P) + 4 * N * P
    return L * H * per_tok_head


def _matmul_params(cfg, dims):
    """Active params participating in matmuls (embedding lookup excluded),
    at PHYSICAL (padded) sizes."""
    n = cfg.active_param_count()
    # head padding
    if cfg.num_heads:
        pad = dims.pad_flops_ratio
        hd = cfg.resolved_head_dim
        attn_logical = cfg.num_layers * (
            cfg.d_model * cfg.num_heads * hd * 2
            + 2 * cfg.d_model * cfg.num_kv_heads * hd)
        attn_phys = cfg.num_layers * (
            cfg.d_model * dims.n_q * hd * 2
            + 2 * cfg.d_model * dims.n_kv * hd)
        n += attn_phys - attn_logical
    if not cfg.tie_embeddings:
        n -= cfg.vocab_size * cfg.d_model      # lookup table: no flops
    # padded vocab head
    n += (dims.vocab - cfg.vocab_size) * cfg.d_model
    return max(n, 0)


def analytic_cell(arch: str, shape_name: str, opts=None) -> Cell:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    dims = padded_dims(cfg, tp=TP)
    opts = dict(opts or {})
    B, S = shape.global_batch, shape.seq_len
    kind = shape.kind
    opts["kind"] = kind
    S_tot = S + (cfg.num_patches if cfg.family == "vlm" else 0)
    T = B * S_tot
    n_mm = _matmul_params(cfg, dims)
    w_bytes = cfg.param_count() * 2           # bf16 weights, logical
    ga = opts.get("grad_accum", 1)

    # EP geometry: a dedicated expert axis ('mesh_spec') or the data axes
    # ('expert_sharding'='data'); `inner` = data axes left for within-expert
    ep, inner = 1, DP
    if opts.get("mesh_spec"):
        # e.g. 2x8x16:data,expert,model
        shp, axs = opts["mesh_spec"].split(":")
        sizes = dict(zip(axs.split(","), map(int, shp.split("x"))))
        ep = sizes.get("expert", 1)
        inner = sizes.get("data", 1) * sizes.get("pod", 1)
    elif opts.get("expert_sharding") == "data" and cfg.uses_moe:
        ep, inner = DP, 1
    grad_b = 2 if opts.get("accum") == "bf16" else 4
    flash = opts.get("flash_attention", False)
    ne_bytes = _non_expert_bytes(cfg) if cfg.uses_moe else w_bytes
    ex_bytes = w_bytes - ne_bytes

    if kind == "train":
        mm_factor, attn_factor = 8, 16        # fwd2+bwd4+remat2 / 4*(2+1+1)
        flops = mm_factor * n_mm * T
        flops += _attn_flops(cfg, B, S_tot, S_tot, dims.n_q, True,
                             attn_factor)
        if cfg.family == "audio":
            Se = cfg.encoder_seq_len
            flops += _attn_flops(cfg, B, Se, Se, dims.n_q, False, attn_factor)
            flops += _attn_flops(cfg, B, S_tot, Se, dims.n_q, False,
                                 attn_factor)
        flops += 4 * _ssd_flops_per_token(cfg) * T   # ~fwd+bwd+remat
        model_flops = 6 * cfg.active_param_count() * T
        dev_flops = flops / CHIPS
        # --- HBM stream (per device) ---
        toks_loc = T // (DP)                   # per data shard
        toks_micro = toks_loc // ga
        act_stream = 12 * cfg.num_layers * toks_micro * cfg.d_model * 2 * ga \
            * 3                               # fwd+bwd+remat passes
        score_bytes = 0
        if cfg.has_attention and not flash:
            Bl = max(B // DP, 1) // ga if B // DP >= ga else 1
            h_loc = max(dims.n_q // TP, 1)
            score_bytes = (cfg_layers_attn(cfg) * Bl * h_loc * S_tot ** 2
                           * 0.5 * 4 * 4) * ga   # f32, ~4 passes, causal half
        w_stream = 3 * ga * (ne_bytes / TP + ex_bytes / (TP * ep))
        opt_stream = 6 * cfg.param_count() * 4 / CHIPS
        hbm = act_stream + score_bytes + w_stream + opt_stream
        # --- collectives (per device) ---
        fsdp_gather = 3 * ga * (ne_bytes / TP) * (DP - 1) / DP
        fsdp_gather += 3 * ga * (ex_bytes / (TP * ep)) * (inner - 1) / \
            max(inner, 1)
        grad_sync = 2 * ne_bytes / 2 * grad_b / TP * (DP - 1) / DP
        grad_sync += 2 * ex_bytes / 2 * grad_b / (TP * ep) * (inner - 1) / \
            max(inner, 1)
        a2a = 0.0
        if ep > 1:
            n_moe = len([l for l in range(cfg.num_layers)
                         if l % cfg.moe_every == 0])
            a2a = 2 * 3 * ga * n_moe * toks_micro * cfg.d_model * 2 \
                * (DP - 1) / DP                # dispatch+combine, fwd+bwd+rm
        tp_ar = 2 * 4 * cfg.num_layers * ga * (toks_micro // 1) \
            * cfg.d_model * 2 * (TP - 1) / TP / DP
        coll = fsdp_gather + grad_sync + a2a + tp_ar
    else:
        is_decode = kind == "decode"
        T_step = B if is_decode else T
        flops = 2 * n_mm * T_step
        if cfg.has_attention:
            if is_decode:
                flops += _attn_flops(cfg, B, 1, S, dims.n_q, False, 4)
            else:
                flops += _attn_flops(cfg, B, S_tot, S_tot, dims.n_q, True, 4)
        if cfg.family == "audio":
            Se = cfg.encoder_seq_len
            if is_decode:
                flops += _attn_flops(cfg, B, 1, Se, dims.n_q, False, 4)
            else:
                flops += _attn_flops(cfg, B, Se, Se, dims.n_q, False, 4)
                flops += _attn_flops(cfg, B, S_tot, Se, dims.n_q, False, 4)
        flops += 2 * _ssd_flops_per_token(cfg) * T_step
        model_flops = 2 * cfg.active_param_count() * T_step
        dev_flops = flops / CHIPS
        # --- HBM ---
        w_loc = w_bytes / TP if not cfg.uses_moe else (
            ex_bytes / CHIPS + ne_bytes / TP)
        kv_total = _kv_cache_bytes(cfg, dims, B, S)
        if opts.get("kv_seq_shard") and cfg.num_kv_heads:
            # sequence-sharded, UNPADDED kv heads: removes the replication
            # factor dims.n_kv / num_kv_heads from stored + streamed bytes
            kv_total *= cfg.num_kv_heads / max(dims.n_kv, 1)
        kv_loc = kv_total / min(B, DP) / TP
        if opts.get("kv_dtype") == "int8":
            kv_loc *= 0.5
        if is_decode:
            hbm = w_loc + kv_loc               # read weights + full cache
        else:
            toks_loc = T // DP
            act = 8 * cfg.num_layers * toks_loc * cfg.d_model * 2
            score_bytes = 0
            if cfg.has_attention and not flash:
                Bl = max(B // DP, 1)
                h_loc = max(dims.n_q // TP, 1)
                score_bytes = (cfg_layers_attn(cfg) * Bl * h_loc
                               * S_tot ** 2 * 0.5 * 4 * 2)
            hbm = w_loc + act + score_bytes + kv_loc
        toks_loc_serve = max(T_step // DP, 1)
        coll = 2 * 2 * cfg.num_layers * toks_loc_serve * cfg.d_model * 2 \
            * (TP - 1) / TP
        if opts.get("kv_seq_shard"):
            # LSE-merge: psum of (m, l, acc) per layer — acc is (B,1,H,hd)
            coll += 3 * cfg.num_layers * max(B // DP, 1) * cfg.num_heads \
                * cfg.resolved_head_dim * 4
        if cfg.uses_moe:
            if ep > 1:   # EP serving: tokens routed, weights stay put
                coll += 2 * cfg.num_layers * toks_loc_serve * cfg.d_model \
                    * 2 * (DP - 1) / DP
            else:        # expert d-gather over the data axis per step
                coll += ex_bytes / TP * (DP - 1) / DP

    return Cell(arch, shape_name, flops / CHIPS / PEAK, hbm / HBM,
                coll / ICI, model_flops, dev_flops, hbm, coll, opts)


def _non_expert_bytes(cfg):
    e_ff = cfg.moe_d_ff or cfg.d_ff
    mult = 3 if cfg.activation == "swiglu" else 2
    n_moe = len([l for l in range(cfg.num_layers) if l % cfg.moe_every == 0])
    expert_params = n_moe * cfg.num_experts * mult * cfg.d_model * e_ff
    return (cfg.param_count() - expert_params) * 2


def _kv_cache_bytes(cfg, dims, B, S):
    hd = cfg.resolved_head_dim
    if cfg.family in ("ssm", "hybrid"):
        st = cfg.num_layers * B * cfg.ssm_heads * cfg.ssm_head_dim * \
            cfg.ssm_state * 4
        if cfg.family == "hybrid" and cfg.attn_every:
            n_inv = cfg_layers_attn(cfg)
            st += 2 * n_inv * B * S * dims.n_kv * hd * 2
        return st
    L = cfg.num_layers
    kv = 2 * L * B * S * dims.n_kv * hd * 2
    if cfg.family == "audio":
        kv += 2 * L * B * cfg.encoder_seq_len * dims.n_kv * hd * 2
    return kv


def load_dryrun(outdir="results/dryrun"):
    cells = {}
    for f in glob.glob(os.path.join(outdir, "*__single.json")):
        r = json.load(open(f))
        if r.get("ok"):
            cells[(r["arch"], r["shape"])] = r
    return cells


def full_table(outdir="results/dryrun"):
    dr = load_dryrun(outdir)
    rows = []
    from repro.configs import ARCH_NAMES
    for arch in ARCH_NAMES:
        for shape in applicable_shapes(get_config(arch)):
            art = dr.get((arch, shape.name), {})
            cell = analytic_cell(arch, shape.name,
                                 art.get("opts", {}))
            rows.append((cell, art))
    return rows


def main():
    rows = full_table()
    out_csv = []
    print(f"{'arch':26s} {'shape':12s} {'comp(s)':>9s} {'mem(s)':>9s} "
          f"{'coll(s)':>9s} {'bound':>10s} {'useful':>7s} {'roofline':>8s}")
    for cell, art in rows:
        print(f"{cell.arch:26s} {cell.shape:12s} {cell.compute:9.4f} "
              f"{cell.memory:9.4f} {cell.collective:9.4f} "
              f"{cell.dominant:>10s} {cell.useful_ratio:7.3f} "
              f"{cell.roofline_fraction:8.3f}")
        out_csv.append([cell.arch, cell.shape, cell.compute, cell.memory,
                        cell.collective, cell.dominant, cell.useful_ratio,
                        cell.roofline_fraction, cell.model_flops,
                        cell.device_flops, cell.hbm_bytes, cell.coll_bytes,
                        art.get("memory", {}).get("peak_hbm_bytes", ""),
                        art.get("flops_per_device", ""),
                        art.get("collectives", {}).get("total", ""),
                        cell.lever()])
    import csv
    os.makedirs("results", exist_ok=True)
    with open("results/roofline.csv", "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["arch", "shape", "compute_s", "memory_s", "collective_s",
                    "dominant", "useful_ratio", "roofline_fraction",
                    "model_flops", "device_flops", "hbm_bytes", "coll_bytes",
                    "dryrun_peak_hbm", "dryrun_flops_body",
                    "dryrun_coll_body", "lever"])
        w.writerows(out_csv)
    return [(f"roofline/{c.arch}/{c.shape}", 0.0,
             f"dominant={c.dominant}|roofline={c.roofline_fraction:.3f}")
            for c, _ in rows]


if __name__ == "__main__":
    main()
