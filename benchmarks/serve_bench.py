"""Elastic serving-engine benchmark: the perf trajectory of the request path.

A small ``ElasticClusterFrontend`` run with real CPU forwards under the
unified control plane, reporting tokens/sec, TTFT and end-to-end latency
percentiles (in ticks), and the prefill retrace count (bucketed prompts
should compile O(log max_seq) variants, not one per distinct prompt length).

Artifacts: ``results/BENCH_serve.json`` — tracked across PRs so serving-path
regressions (throughput or recompiles) show up in review.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

RESULTS = "results"
TICKS = 30
NODES = 2
MAX_BATCH = 4
MAX_SEQ = 64
N_NEW = 6


def main() -> list:
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.configs.paper_cluster import ClusterConfig
    from repro.control import ControlPlane
    from repro.models import make_model
    from repro.serving import ElasticClusterFrontend, ReplicaEngine, Request

    cfg = get_config("granite-3-8b").reduced()
    model = make_model(cfg, tp=1)
    params = model.init(jax.random.PRNGKey(0), jnp.float32)
    ccfg = ClusterConfig(num_nodes=NODES, horizon=4, forecast_window=8,
                         provisioning_delay=2, max_replicas_per_node=2,
                         min_replicas_per_node=1, scale_interval=4,
                         cooldown=6, straggler_prob=0.0, node_mtbf=1e12)
    rng = np.random.default_rng(0)

    def make_replica(rid):
        return ReplicaEngine(model, params, max_batch=MAX_BATCH,
                             max_seq=MAX_SEQ, rid=rid)

    def request_factory(rid, tick):
        plen = int(rng.integers(2, 14))
        return Request(rid, rng.integers(1, cfg.vocab_size, plen).tolist(),
                       max_new_tokens=N_NEW)

    fe = ElasticClusterFrontend(
        make_replica, NODES, initial_replicas=1, provisioning_delay=2,
        max_replicas_per_node=2, request_factory=request_factory, seed=0,
        est_tokens=N_NEW)
    plane = ControlPlane(ccfg, fe, balancer="rr", scaler="rbas",
                         unit_capacity=MAX_BATCH / N_NEW, seed=0,
                         init_arrival=2.0)
    t0 = time.time()
    for _ in range(TICKS):
        plane.step(2.0)
    fe.run_until_drained()
    wall = time.time() - t0

    done = fe.finished
    toks = sum(len(r.output) for r in done)
    ttft = np.asarray([r.first_token_time - r.arrival for r in done])
    lat = np.asarray([r.finish_time - r.arrival for r in done])
    retraces = fe.prefill_retraces()
    blob = {
        "requests": len(done),
        "tokens": toks,
        "wall_s": round(wall, 3),
        "tok_per_s": round(toks / max(wall, 1e-9), 2),
        "ttft_p50_ticks": float(np.percentile(ttft, 50)),
        "ttft_p95_ticks": float(np.percentile(ttft, 95)),
        "latency_p50_ticks": float(np.percentile(lat, 50)),
        "latency_p95_ticks": float(np.percentile(lat, 95)),
        "prefill_retraces": int(retraces),
        "live_replicas": len([e for n in fe.nodes for e in n.live]),
        "replica_ticks": fe.replica_ticks,
    }
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "BENCH_serve.json"), "w") as f:
        json.dump(blob, f, indent=2, sort_keys=True)

    us = wall * 1e6 / max(toks, 1)
    return [
        ("serve/elastic_tok_per_s", us, f"{blob['tok_per_s']}tok/s"),
        ("serve/ttft_p95", blob["ttft_p95_ticks"] * 1e6,
         f"p50={blob['ttft_p50_ticks']:.1f}t"),
        ("serve/latency_p95", blob["latency_p95_ticks"] * 1e6,
         f"p50={blob['latency_p50_ticks']:.1f}t"),
        ("serve/prefill_retraces", float(retraces),
         f"{len(done)}req"),
    ]


if __name__ == "__main__":
    for row in main():
        print(row)
