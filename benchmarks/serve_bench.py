"""Elastic serving-engine benchmark: the perf trajectory of the request path.

Three phases over real CPU forwards:

  * **fleet vs per-replica** — the same saturating workload through 4
    same-model replicas (2 nodes x 2) with fleet-batched decode ON and OFF:
    tokens/sec both ways, the speedup, and ``decode_dispatches_per_tick``
    (fleet mode must issue ONE jitted decode per fleet group per tick);
  * **tick-cost scaling** — saturated steps/sec at fleet sizes 1/2/4/8 on
    one node (a fleet-batched hot loop should be near-flat: tick cost is one
    dispatch regardless of replica count);
  * **control-plane run** — the original ControlPlane-driven trace for
    TTFT/latency percentiles and the prefill retrace bound, plus the int8
    KV-cache capacity gain (``cache_dtype="int8"``).

Artifacts: ``results/BENCH_serve.json`` — tracked across PRs so serving-path
regressions (throughput, recompiles, dispatch counts) show up in review.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

RESULTS = "results"
TICKS = 30
NODES = 2
MAX_BATCH = 4
MAX_SEQ = 64
N_NEW = 6
FLEET_SIZES = (1, 2, 4, 8)


def _mk(model, params, cfg):
    from repro.serving import ReplicaEngine

    def make_replica(rid):
        return ReplicaEngine(model, params, max_batch=MAX_BATCH,
                             max_seq=MAX_SEQ, rid=rid)
    return make_replica


def _request_factory(cfg, rng):
    from repro.serving import Request

    def request_factory(rid, tick):
        plen = int(rng.integers(2, 14))
        return Request(rid, rng.integers(1, cfg.vocab_size, plen).tolist(),
                       max_new_tokens=N_NEW)
    return request_factory


FLEET_MAX_BATCH = 2      # small per-replica batches: the dispatch-bound
FLEET_N_NEW = 32         # regime the fleet path targets (decode-heavy)
FLEET_RATE = 0.4


def bench_fleet_vs_loop(model, params, cfg) -> dict:
    """Same workload, 4 same-model replicas, fleet decode on vs off.

    Paired/interleaved measurement: both frontends advance in alternating
    tick chunks so machine noise hits both modes equally (CI boxes are
    noisy; a sequential A-then-B timing swings 2-3x run to run)."""
    from repro.serving import ElasticClusterFrontend, ReplicaEngine, Request

    def make_fe(fleet):
        rng = np.random.default_rng(0)

        def mk(rid):
            return ReplicaEngine(model, params, max_batch=FLEET_MAX_BATCH,
                                 max_seq=MAX_SEQ, rid=rid)

        def rf(rid, tick):
            plen = int(rng.integers(2, 14))
            return Request(rid,
                           rng.integers(1, cfg.vocab_size, plen).tolist(),
                           max_new_tokens=FLEET_N_NEW)

        return ElasticClusterFrontend(
            mk, NODES, initial_replicas=2, max_replicas_per_node=2,
            fleet_batch=fleet, request_factory=rf, seed=0,
            est_tokens=FLEET_N_NEW)

    loop_fe, fleet_fe = make_fe(False), make_fe(True)
    for fe in (loop_fe, fleet_fe):       # warm compiles + fill slots
        for _ in range(6):
            fe.tick(FLEET_RATE)
    wall = {False: 0.0, True: 0.0}
    toks = {False: 0, True: 0}
    disp, groups = 0, 0
    for _ in range(10):                  # 10 rounds x 6-tick chunks
        for fe, key in ((loop_fe, False), (fleet_fe, True)):
            done0 = sum(len(r.output) for r in fe.finished)
            t0 = time.perf_counter()
            for _ in range(6):
                m = fe.tick(FLEET_RATE)
                if key:
                    disp += m["decode_dispatches"]
                    groups += max(m["fleet_groups"], 1)
            wall[key] += time.perf_counter() - t0
            toks[key] += sum(len(r.output) for r in fe.finished) - done0
    loop_tps = toks[False] / max(wall[False], 1e-9)
    fleet_tps = toks[True] / max(wall[True], 1e-9)
    return {
        "tok_per_s": round(fleet_tps, 2),
        "tok_per_s_per_replica_loop": round(loop_tps, 2),
        "fleet_speedup": round(fleet_tps / max(loop_tps, 1e-9), 2),
        "decode_dispatches_per_tick": round(disp / max(groups, 1), 3),
    }


def bench_tick_scaling(model, params, cfg) -> dict:
    """Saturated steps/sec vs fleet size (flat curve == batched hot loop)."""
    from repro.serving import ElasticClusterFrontend, Request

    steps_per_s = {}
    for size in FLEET_SIZES:
        fe = ElasticClusterFrontend(
            _mk(model, params, cfg), 1, initial_replicas=size,
            max_replicas_per_node=size, seed=0, est_tokens=N_NEW)
        rid = 0
        rng = np.random.default_rng(1)

        def refill():
            nonlocal rid
            while (len(fe.pending) + sum(n.unfinished() for n in fe.nodes)
                   < 2 * size * MAX_BATCH):
                plen = int(rng.integers(2, 14))
                fe.submit(Request(
                    rid, rng.integers(1, cfg.vocab_size, plen).tolist(),
                    max_new_tokens=32))
                rid += 1

        for _ in range(3):                 # warm compiles + fill slots
            refill()
            fe.tick(0.0)
        t0 = time.time()
        timed = 12
        for _ in range(timed):
            refill()
            fe.tick(0.0)
        steps_per_s[str(size)] = round(timed / max(time.time() - t0, 1e-9), 2)
    return {"steps_per_s": steps_per_s}


def bench_int8_capacity(model) -> dict:
    """Bytes of one replica's KV pool, fp32 vs int8 codec."""
    import jax
    import jax.numpy as jnp

    def nbytes(dtype):
        st = jax.eval_shape(
            lambda: model.init_serve_state(MAX_BATCH, MAX_SEQ, dtype))
        return int(sum(int(np.prod(l.shape)) * np.dtype(l.dtype).itemsize
                       for l in jax.tree.leaves(st)))

    fp32, int8 = nbytes(jnp.float32), nbytes("int8")
    return {
        "kv_pool_bytes_fp32": fp32,
        "kv_pool_bytes_int8": int8,
        "kv_capacity_gain_int8": round(fp32 / int8, 2),
    }


def bench_control_plane(model, params, cfg) -> dict:
    """The original autoscaled trace: latency percentiles + retraces."""
    from repro.configs.paper_cluster import ClusterConfig
    from repro.control import ControlPlane
    from repro.serving import ElasticClusterFrontend

    ccfg = ClusterConfig(num_nodes=NODES, horizon=4, forecast_window=8,
                         provisioning_delay=2, max_replicas_per_node=2,
                         min_replicas_per_node=1, scale_interval=4,
                         cooldown=6, straggler_prob=0.0, node_mtbf=1e12)
    rng = np.random.default_rng(0)
    fe = ElasticClusterFrontend(
        _mk(model, params, cfg), NODES, initial_replicas=1,
        provisioning_delay=2, max_replicas_per_node=2,
        request_factory=_request_factory(cfg, rng), seed=0,
        est_tokens=N_NEW)
    plane = ControlPlane(ccfg, fe, balancer="rr", scaler="rbas",
                         unit_capacity=MAX_BATCH / N_NEW, seed=0,
                         init_arrival=2.0)
    t0 = time.time()
    for _ in range(TICKS):
        plane.step(2.0)
    fe.run_until_drained()
    wall = time.time() - t0

    done = fe.finished
    toks = sum(len(r.output) for r in done)
    ttft = np.asarray([r.first_token_time - r.arrival for r in done])
    lat = np.asarray([r.finish_time - r.arrival for r in done])
    return {
        "requests": len(done),
        "tokens": toks,
        "wall_s": round(wall, 3),
        "plane_tok_per_s": round(toks / max(wall, 1e-9), 2),
        "ttft_p50_ticks": float(np.percentile(ttft, 50)),
        "ttft_p95_ticks": float(np.percentile(ttft, 95)),
        "latency_p50_ticks": float(np.percentile(lat, 50)),
        "latency_p95_ticks": float(np.percentile(lat, 95)),
        "prefill_retraces": int(fe.prefill_retraces()),
        "live_replicas": len(fe.replicas),
        "replica_ticks": fe.replica_ticks,
    }


def main() -> list:
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.models import make_model

    cfg = get_config("granite-3-8b").reduced()
    model = make_model(cfg, tp=1)
    params = model.init(jax.random.PRNGKey(0), jnp.float32)

    blob = {}
    blob.update(bench_fleet_vs_loop(model, params, cfg))
    blob.update(bench_tick_scaling(model, params, cfg))
    blob.update(bench_int8_capacity(model))
    blob.update(bench_control_plane(model, params, cfg))
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "BENCH_serve.json"), "w") as f:
        json.dump(blob, f, indent=2, sort_keys=True)

    flat = blob["steps_per_s"]
    return [
        ("serve/elastic_tok_per_s", 1e6 / max(blob["tok_per_s"], 1e-9),
         f"{blob['tok_per_s']}tok/s fleet"),
        ("serve/fleet_speedup_x", blob["fleet_speedup"] * 1e6,
         f"vs {blob['tok_per_s_per_replica_loop']}tok/s loop"),
        ("serve/decode_dispatches_per_tick",
         blob["decode_dispatches_per_tick"] * 1e6, "per fleet group"),
        ("serve/steps_per_s_8_replicas", 1e6 / max(flat["8"], 1e-9),
         f"1rep={flat['1']}/s 8rep={flat['8']}/s"),
        ("serve/ttft_p95", blob["ttft_p95_ticks"] * 1e6,
         f"p50={blob['ttft_p50_ticks']:.1f}t"),
        ("serve/latency_p95", blob["latency_p95_ticks"] * 1e6,
         f"p50={blob['latency_p50_ticks']:.1f}t"),
        ("serve/prefill_retraces", float(blob["prefill_retraces"]),
         f"{blob['requests']}req"),
    ]


if __name__ == "__main__":
    for row in main():
        print(row)
