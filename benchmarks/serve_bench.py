"""Elastic serving-engine benchmark: the perf trajectory of the request path.

Phases over real CPU forwards:

  * **fleet vs per-replica** — the same saturating workload through 4
    same-model replicas (2 nodes x 2) with fleet-batched decode ON and OFF:
    tokens/sec both ways, the speedup, and ``decode_dispatches_per_tick``
    (fleet mode must issue ONE jitted decode per fleet group per tick);
  * **fleet prefill A/B** — a cold-queue burst into 4 idle replicas with
    fleet-batched admission ON and OFF: drain ticks/wall both ways and
    ``prefill_dispatches`` on the admission tick (fleet mode pays one
    dispatch per *distinct bucket shape*; the per-replica oracle pays one
    per admitting replica);
  * **chunked prefill A/B** — a workload salted with near-``max_seq``
    prompts, chunking ON and OFF: short-request TTFT p95 (must stay flat)
    and the p95 per-tick wall time (a single-shot long prefill stalls the
    whole tick — the decode-TBT tail chunking is meant to bound);
  * **SLO tiers A/B** — the same mildly-saturating 3-tier request stream
    through tiered weighted-deficit admission and the untiered FIFO
    scheduler: per-tier TTFT/TBT p50/p95 + SLO attainment, the batch tier's
    max wait (starvation bound), aggregate tok/s both ways and the fleet
    dispatch bounds under tiering (ordering changes, dispatches don't);
  * **tick-cost scaling + async A/B** — saturated ticks/sec at fleet sizes
    1/2/4/8 on one node, paired async-tick vs eager-oracle (same workload,
    interleaved chunks), reporting ``syncs_per_tick`` (async must pay ONE
    blocking sync per tick; eager pays one per fetch) and the
    host-vs-device tick-wall split (``sync_wait`` fraction). At the largest
    size a ``decode_block=4`` arm fuses 4 micro-steps per dispatch —
    dispatches AND syncs drop to 1/4 per tick;
  * **shard scaling** — saturated decode `steps_per_s` vs device count
    (1/2/4/8 virtual CPU devices) with the fleet slab sharded over an
    N-way ``('fleet',)`` mesh, at fixed total fleet F=8 (strong scaling)
    and fixed per-device fleet F=2N (weak scaling). Each point runs in a
    subprocess because ``xla_force_host_platform_device_count`` is read
    once at jax backend init; the steady-state compile-excluded per-tick
    method matches the tick-scaling phase. NB: virtual devices time-slice
    the host's real cores — on a single-core box the curve measures
    sharding *overhead*, not speedup; the near-linear regime needs
    >= N real cores (or real accelerators);
  * **control-plane run** — the original ControlPlane-driven trace for
    TTFT/latency percentiles and the prefill retrace bound, plus the int8
    KV-cache capacity gain (``cache_dtype="int8"``);
  * **failure matrix** — closed-loop ``ClientPool`` traffic through the
    chaos cells: chaos-off baseline, scripted spot preemption (notice,
    drain, hard drop, recovery), a retry storm (tight timeouts + both
    nodes preempted back-to-back) and a 1000-user flash crowd ramping in
    at 50 users/tick. Each cell reports goodput fraction, SLO attainment,
    retries/abandons, the per-tick goodput curve and the request-
    conservation ledger (must balance: every rid exactly-once terminal,
    ``double_served == 0`` — asserted, not just recorded). The matrix also
    drives the PR 8 multi-cell routing plane (2 elastic cells behind
    ``MultiCellBackend`` + ``CellRouter``, one GLOBAL ledger): a cell
    blackout routed vs a health-blind static split (routed goodput must be
    strictly higher), a control-plane partition (staleness decay +
    quarantine), tier-aware overload shedding, and the flash-crowd-1000
    re-run through the router with shedding armed — premium-tier goodput
    must beat the unrouted aggregate collapse, with every shed an explicit
    ledger terminal. PR 10 adds the **plane-outage A/B**: the same
    10-tick global-plane blackout with a load burst landing mid-outage,
    run hierarchical (per-cell autoscalers under capacity leases, the
    ``PlaneSupervisor`` loop) vs centralized-frozen (the PR 8 single
    ``ControlPlane``, driver frozen while ``plane_alive`` is false).
    Hierarchical must win on goodput AND scale-reaction latency (ticks
    from the burst to the first replica added) — both asserted. A
    ``plane_flap`` cell (two outages back-to-back plus a checkpoint/
    restore supervisor swap between them) proves repeated crash/restore
    keeps the ledger exactly-once.

Tick-wall stats separate *steady-state* ticks from ticks that hit an XLA
compile (``serve_kernel_traces`` delta > 0): a single ~1s retrace inside a
40-tick window used to masquerade as a fat p95 tail.

Artifacts: ``results/BENCH_serve.json`` — tracked across PRs so serving-path
regressions (throughput, recompiles, dispatch counts) show up in review.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

RESULTS = "results"
TICKS = 30
NODES = 2
MAX_BATCH = 4
MAX_SEQ = 64
N_NEW = 6
FLEET_SIZES = (1, 2, 4, 8)


def _mk(model, params, cfg):
    from repro.serving import ReplicaEngine

    def make_replica(rid):
        return ReplicaEngine(model, params, max_batch=MAX_BATCH,
                             max_seq=MAX_SEQ, rid=rid)
    return make_replica


def _request_factory(cfg, rng):
    from repro.serving import Request

    def request_factory(rid, tick):
        plen = int(rng.integers(2, 14))
        return Request(rid, rng.integers(1, cfg.vocab_size, plen).tolist(),
                       max_new_tokens=N_NEW)
    return request_factory


FLEET_MAX_BATCH = 2      # small per-replica batches: the dispatch-bound
FLEET_N_NEW = 32         # regime the fleet path targets (decode-heavy)
FLEET_RATE = 0.4


def bench_fleet_vs_loop(model, params, cfg) -> dict:
    """Same workload, 4 same-model replicas, fleet decode on vs off.

    Paired/interleaved measurement: both frontends advance in alternating
    tick chunks so machine noise hits both modes equally (CI boxes are
    noisy; a sequential A-then-B timing swings 2-3x run to run)."""
    from repro.serving import ElasticClusterFrontend, ReplicaEngine, Request

    def make_fe(fleet):
        rng = np.random.default_rng(0)

        def mk(rid):
            return ReplicaEngine(model, params, max_batch=FLEET_MAX_BATCH,
                                 max_seq=MAX_SEQ, rid=rid)

        def rf(rid, tick):
            plen = int(rng.integers(2, 14))
            return Request(rid,
                           rng.integers(1, cfg.vocab_size, plen).tolist(),
                           max_new_tokens=FLEET_N_NEW)

        return ElasticClusterFrontend(
            mk, NODES, initial_replicas=2, max_replicas_per_node=2,
            fleet_batch=fleet, request_factory=rf, seed=0,
            est_tokens=FLEET_N_NEW)

    loop_fe, fleet_fe = make_fe(False), make_fe(True)
    for fe in (loop_fe, fleet_fe):       # warm compiles + fill slots: long
        for _ in range(30):              # enough to hit every admission
            fe.tick(FLEET_RATE)          # batch/bucket shape (XLA compiles
                                         # are ~1s each, 500x a steady tick)
    wall = {False: 0.0, True: 0.0}
    toks = {False: 0, True: 0}
    disp, groups = 0, 0
    for _ in range(10):                  # 10 rounds x 6-tick chunks
        for fe, key in ((loop_fe, False), (fleet_fe, True)):
            done0 = sum(len(r.output) for r in fe.finished)
            t0 = time.perf_counter()
            for _ in range(6):
                m = fe.tick(FLEET_RATE)
                if key:
                    disp += m["decode_dispatches"]
                    groups += max(m["fleet_groups"], 1)
            wall[key] += time.perf_counter() - t0
            toks[key] += sum(len(r.output) for r in fe.finished) - done0
    loop_tps = toks[False] / max(wall[False], 1e-9)
    fleet_tps = toks[True] / max(wall[True], 1e-9)
    return {
        "tok_per_s": round(fleet_tps, 2),
        "tok_per_s_per_replica_loop": round(loop_tps, 2),
        "fleet_speedup": round(fleet_tps / max(loop_tps, 1e-9), 2),
        "decode_dispatches_per_tick": round(disp / max(groups, 1), 3),
    }


PREFILL_BURST = 32       # cold-queue burst size (admission-bound regime)


def bench_fleet_prefill(model, params, cfg) -> dict:
    """Cold-queue drain A/B at 4 replicas: fleet-batched admission on/off.

    Burst prompts land in one pow2 length bucket, so fleet mode pays one
    vmapped prefill dispatch per distinct (kb, sb) shape per tick while the
    per-replica oracle pays one per admitting replica. Paired/interleaved
    bursts so machine noise hits both modes equally."""
    from repro.serving import ElasticClusterFrontend, ReplicaEngine, Request

    def make_fe(fp):
        def mk(rid):
            return ReplicaEngine(model, params, max_batch=MAX_BATCH,
                                 max_seq=MAX_SEQ, rid=rid)
        return ElasticClusterFrontend(
            mk, NODES, initial_replicas=2, max_replicas_per_node=2,
            seed=0, est_tokens=N_NEW, fleet_prefill=fp)

    fes = {True: make_fe(True), False: make_fe(False)}
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size,
                            int(rng.integers(5, 9))).tolist()
               for _ in range(PREFILL_BURST)]
    for fe in fes.values():                  # warm ALL admission shapes
        for burst in (len(prompts), 20, 9):  # (full + partial bursts cover
            for i in range(burst):           # the pow2 batch ladder; an XLA
                fe.submit(Request(1000 + i, list(prompts[i]),  # compile is
                                  max_new_tokens=2))           # ~1s, 500x a
            fe.run_until_drained()                             # warm tick)
    walls = {True: [], False: []}
    ticks = {True: 0, False: 0}
    disp = {True: 0, False: 0}
    admit_ticks = {True: 0, False: 0}
    rounds = 6
    for rd in range(rounds):                 # interleaved cold bursts
        for key, fe in fes.items():
            for i, p in enumerate(prompts):
                fe.submit(Request(rd * 100 + i, list(p), max_new_tokens=2))
            t0 = time.perf_counter()
            for _ in range(200):
                m = fe.tick(0.0)
                ticks[key] += 1
                if m["prefill_dispatches"]:
                    disp[key] += m["prefill_dispatches"]
                    admit_ticks[key] += 1
                if not fe.pending and all(n.unfinished() == 0
                                          for n in fe.nodes):
                    break
            walls[key].append(time.perf_counter() - t0)
    # median round: a single straggler XLA retrace (~1s) would swamp a
    # ~20ms drain and invert the comparison
    med = {k: float(np.median(v)) for k, v in walls.items()}
    return {
        "prefill_dispatches_per_tick_fleet":
            round(disp[True] / max(admit_ticks[True], 1), 3),
        "prefill_dispatches_per_tick_loop":
            round(disp[False] / max(admit_ticks[False], 1), 3),
        "cold_drain_ticks_fleet": round(ticks[True] / rounds, 2),
        "cold_drain_ticks_loop": round(ticks[False] / rounds, 2),
        "cold_drain_wall_s_fleet": round(med[True], 4),
        "cold_drain_wall_s_loop": round(med[False], 4),
        "cold_drain_speedup": round(med[False] / max(med[True], 1e-9), 2),
    }


CHUNK_LEN = 64           # chunk width for the long-context phase
CHUNK_MAX_SEQ = 512      # long-context engine shape: a single-shot prefill
CHUNK_LONG = 500         # of a ~500-token prompt visibly stalls a tick
LONG_EVERY = 8           # every 8th request is a near-max_seq prompt: the
                         # ceil(500/64)=8-tick chunk stream fits the
                         # inter-arrival gap, so chunking smooths a bursty
                         # spike rather than fighting saturation (under
                         # saturated long-work arrival no scheduler can
                         # shrink per-tick work)


def bench_chunked(model, params, cfg) -> dict:
    """Long-prompt workload A/B: chunked admission on/off.

    Short-request TTFT p95 must stay flat, while the p95 per-tick wall time
    (the decode-TBT proxy: every slot's next token waits for the tick) drops
    because a long prompt's prefill compute is spread over ceil(len/chunk)
    ticks instead of spiking one admission call."""
    from repro.serving import ElasticClusterFrontend, ReplicaEngine, Request

    def run(chunk_len):
        rng = np.random.default_rng(0)

        def mk(rid):
            return ReplicaEngine(model, params, max_batch=MAX_BATCH,
                                 max_seq=CHUNK_MAX_SEQ, rid=rid,
                                 chunk_len=chunk_len)

        def rf(rid, tick):
            if rid % LONG_EVERY == 0:
                plen = CHUNK_LONG
            else:
                plen = int(rng.integers(4, 10))
            return Request(rid,
                           rng.integers(1, cfg.vocab_size, plen).tolist(),
                           max_new_tokens=N_NEW)

        # eager ticks: this phase isolates CHUNKING's effect on the
        # tick-wall tail; the async tick would smear a single-shot long
        # prefill's cost across neighboring ticks and confound the A/B
        fe = ElasticClusterFrontend(
            mk, NODES, initial_replicas=2, max_replicas_per_node=2,
            request_factory=rf, seed=0, est_tokens=N_NEW, async_tick=False)
        for _ in range(24):                  # warm compiles + fill slots
            fe.tick(1.0)                     # (long: every admission/chunk
                                             # batch shape must compile
                                             # before the timed window)
        tick_wall = []                       # (wall_s, compiled?, sync_s)
        for _ in range(40):
            traces0 = fe.serve_kernel_traces()
            sync0 = fe.sync_wait_s()
            t0 = time.perf_counter()
            fe.tick(1.0)
            tick_wall.append((time.perf_counter() - t0,
                              fe.serve_kernel_traces() - traces0,
                              fe.sync_wait_s() - sync0))
        fe.run_until_drained()
        short = [r for r in fe.finished if len(r.prompt) < CHUNK_LONG]
        longs = [r for r in fe.finished if len(r.prompt) >= CHUNK_LONG]
        ttft = [r.first_token_time - r.arrival for r in short]
        lttft = [r.first_token_time - r.arrival for r in longs]
        # steady-state ticks only: a tick that hit an XLA retrace (~1s) is
        # a cold-path event, not the serving tail the p95 is meant to bound
        steady = [w for w, d, _ in tick_wall if d == 0]
        sync_s = [s for _, d, s in tick_wall if d == 0]
        return {
            "ttft_p95_ticks": float(np.percentile(ttft, 95)),
            "long_ttft_p95_ticks": float(np.percentile(lttft, 95)),
            "tick_wall_p95_ms":
                round(float(np.percentile(steady, 95)) * 1e3, 2),
            "tick_wall_mean_ms":
                round(float(np.mean(steady)) * 1e3, 2),
            "tick_wall_sync_mean_ms":        # device-blocked share; the
                round(float(np.mean(sync_s)) * 1e3, 2),  # rest is host work
            "compile_ticks": int(sum(1 for _, d, _ in tick_wall if d)),
        }

    on, off = run(CHUNK_LEN), run(0)
    return {"chunked": {"on": on, "off": off,
                        "chunk_len": CHUNK_LEN,
                        "tick_wall_p95_ratio_off_over_on":
                            round(off["tick_wall_p95_ms"] /
                                  max(on["tick_wall_p95_ms"], 1e-9), 2)}}


TIER_RATE = 3.0          # req/tick into ~2.7 req/tick of capacity: mildly
TIER_TICKS = 36          # saturating, so admission order actually matters
TIER_NEW = 6


def bench_tiers(model, params, cfg) -> dict:
    """Mixed 3-tier workload A/B: tiered weighted-deficit admission vs the
    untiered FIFO scheduler on the identical request stream.

    Reports per-tier TTFT/TBT p50/p95 and SLO attainment, the batch tier's
    max wait (starvation bound), aggregate tok/s both ways (tiering must
    cost ordering, not throughput) and the fleet dispatch bounds during the
    tiered run (one decode dispatch per group per tick; prefill dispatches
    per admission tick at the distinct-bucket-shape bound). Paired,
    interleaved tick chunks like the fleet A/B so machine noise hits both
    modes equally."""
    from repro.serving import ElasticClusterFrontend, ReplicaEngine, Request
    from repro.workload import TierSet, TierSpec

    tiers = TierSet([
        TierSpec("premium", share=0.25, weight=5.0, ttft_target=4.0),
        TierSpec("standard", share=0.5, weight=2.0, ttft_target=8.0),
        TierSpec("batch", share=0.25, weight=1.0),
    ])

    def make_fe(ts):
        rng = np.random.default_rng(0)

        def mk(rid):
            return ReplicaEngine(model, params, max_batch=MAX_BATCH,
                                 max_seq=MAX_SEQ, rid=rid, tiers=ts)

        def rf(rid, tick):
            plen = int(rng.integers(2, 14))
            req = Request(rid,
                          rng.integers(1, cfg.vocab_size, plen).tolist(),
                          max_new_tokens=TIER_NEW)
            # stamp tiers in BOTH runs (identical rng stream): the untiered
            # frontend ignores the field, so the A/B measures pure ordering
            req.tier = tiers.sample(rng)
            return req

        return ElasticClusterFrontend(
            mk, NODES, initial_replicas=2, max_replicas_per_node=2,
            request_factory=rf, seed=0, est_tokens=TIER_NEW, tiers=ts)

    fes = {"tiered": make_fe(tiers), "untiered": make_fe(None)}
    for fe in fes.values():                  # warm compiles + fill slots
        for _ in range(12):
            fe.tick(TIER_RATE)
    wall = {k: 0.0 for k in fes}
    toks = {k: 0 for k in fes}
    disp = {"decode": [], "prefill": 0, "admit_ticks": 0}
    for _ in range(TIER_TICKS // 6):         # interleaved 6-tick chunks
        for key, fe in fes.items():
            done0 = sum(len(r.output) for r in fe.finished)
            t0 = time.perf_counter()
            for _ in range(6):
                m = fe.tick(TIER_RATE)
                if key == "tiered":
                    if m["decode_dispatches"]:
                        disp["decode"].append(
                            m["decode_dispatches"]
                            / max(m["fleet_groups"], 1))
                    if m["prefill_dispatches"]:
                        disp["prefill"] += m["prefill_dispatches"]
                        disp["admit_ticks"] += 1
            wall[key] += time.perf_counter() - t0
            toks[key] += sum(len(r.output) for r in fe.finished) - done0
    for fe in fes.values():
        fe.run_until_drained()

    def percentile_block(fe):
        out = {}
        for spec in tiers.specs:
            sub = [r for r in fe.finished
                   if tiers.index(r.tier) == tiers.index(spec.name)]
            if not sub:
                continue
            ttft = [r.first_token_time - r.arrival for r in sub]
            tbt = [(r.finish_time - r.first_token_time)
                   / max(len(r.output) - 1, 1) for r in sub]
            row = {
                "n": len(sub),
                "ttft_p50": float(np.percentile(ttft, 50)),
                "ttft_p95": float(np.percentile(ttft, 95)),
                "ttft_max": float(np.max(ttft)),
                "tbt_p50": float(np.percentile(tbt, 50)),
                "tbt_p95": float(np.percentile(tbt, 95)),
            }
            if np.isfinite(spec.ttft_target):
                row["slo_attainment"] = float(np.mean(
                    np.asarray(ttft) <= spec.ttft_target))
            out[spec.name] = row
        return out

    tiered_tps = toks["tiered"] / max(wall["tiered"], 1e-9)
    untiered_tps = toks["untiered"] / max(wall["untiered"], 1e-9)
    per_tier = {k: percentile_block(fe) for k, fe in fes.items()}
    return {"tiers": {
        "mix": "premium:0.25:w5:4,standard:0.5:w2:8,batch:0.25:w1",
        "per_tier": per_tier,
        "premium_ttft_p95_tiered":
            per_tier["tiered"]["premium"]["ttft_p95"],
        "premium_ttft_p95_untiered":
            per_tier["untiered"]["premium"]["ttft_p95"],
        "batch_ttft_max_tiered": per_tier["tiered"]["batch"]["ttft_max"],
        "tok_per_s_tiered": round(tiered_tps, 2),
        "tok_per_s_untiered": round(untiered_tps, 2),
        "tok_per_s_ratio": round(tiered_tps / max(untiered_tps, 1e-9), 3),
        "decode_dispatches_per_tick":
            round(float(np.max(disp["decode"])) if disp["decode"] else 0.0,
                  3),
        "prefill_dispatches_per_admit_tick":
            round(disp["prefill"] / max(disp["admit_ticks"], 1), 3),
    }}


TICK_MODES = (("async", dict(async_tick=True)),
              ("eager", dict(async_tick=False)),
              ("block4", dict(async_tick=True, decode_block=4)))


def bench_tick_scaling(model, params, cfg) -> dict:
    """Saturated ticks/sec vs fleet size, paired async/eager (+ fused
    decode blocks at every size).

    The async tick must pay exactly ONE blocking host sync per tick (the
    reconcile) regardless of fleet size, with the decode dispatch of tick t
    overlapping tick t's host bookkeeping; decode_block=4 drops both the
    dispatch and the sync to 1/4 per tick (the slab is saturated, the queue
    is deep, so no admissions interrupt the fused windows). Interleaved
    tick chunks so machine noise hits every mode equally."""
    from repro.serving import ElasticClusterFrontend, Request

    out = {"steps_per_s": {}, "steps_per_s_eager": {},
           "steps_per_s_block4": {}}
    key_of = {"async": "steps_per_s", "eager": "steps_per_s_eager",
              "block4": "steps_per_s_block4"}

    class _Feeder:
        """Keeps one frontend saturated (slab full + deep queue) with an
        identical request stream per mode. 48-token outputs keep the timed
        window (44 ticks incl. warmup) inside the generation horizon: pure
        decode, no finishes, no admission retraces."""

        def __init__(self, fe, size):
            self.fe, self.size = fe, size
            self.rid = 0
            self.rng = np.random.default_rng(1)

        def refill(self):
            fe = self.fe
            while (len(fe.pending) + sum(n.unfinished() for n in fe.nodes)
                   < 2 * self.size * MAX_BATCH):
                plen = int(self.rng.integers(2, 14))
                fe.submit(Request(
                    self.rid,
                    self.rng.integers(1, cfg.vocab_size, plen).tolist(),
                    max_new_tokens=48))
                self.rid += 1

    stats = {}
    for size in FLEET_SIZES:
        fes = {}
        feeders = {}
        for mode, kw in TICK_MODES:
            fe = ElasticClusterFrontend(
                _mk(model, params, cfg), 1, initial_replicas=size,
                max_replicas_per_node=size, seed=0, est_tokens=N_NEW, **kw)
            fes[mode] = fe
            feeders[mode] = _Feeder(fe, size)
            for _ in range(8):             # warm compiles + fill slots
                feeders[mode].refill()
                fe.tick(0.0)
        walls = {m: [] for m in fes}       # (tick wall, compiled?) pairs
        syncs = {m: 0 for m in fes}
        disp = {m: 0 for m in fes}
        sync_wait = {m: 0.0 for m in fes}
        order = list(fes)
        for _ in range(6):                 # interleaved, rotated 6-tick
            for mode in order:             # chunks: noise hits all modes
                fe = fes[mode]
                feeders[mode].refill()
                s0, w0, d0 = (fe.sync_count(), fe.sync_wait_s(),
                              fe.decode_dispatches())
                for _ in range(6):
                    tr0 = fe.serve_kernel_traces()
                    t0 = time.perf_counter()
                    fe.tick(0.0)
                    walls[mode].append((time.perf_counter() - t0,
                                        fe.serve_kernel_traces() > tr0))
                syncs[mode] += fe.sync_count() - s0
                sync_wait[mode] += fe.sync_wait_s() - w0
                disp[mode] += fe.decode_dispatches() - d0
            order = order[1:] + order[:1]
        for mode in fes:
            kept = [w for w, compiled in walls[mode] if not compiled]
            out[key_of[mode]][str(size)] = round(
                len(kept) / max(sum(kept), 1e-9), 2)
        n = {m: len(walls[m]) for m in fes}
        stats[size] = {m: (syncs[m] / n[m], disp[m] / n[m],
                           sync_wait[m] / max(sum(w for w, _ in walls[m]),
                                              1e-9))
                       for m in fes}
    big = max(FLEET_SIZES)
    s8 = stats[big]
    out.update({
        # methodology changed in PR 5: steps_per_s is now steady-state
        # ticks/sec over compile-free per-tick walls (feeder refill and
        # XLA retraces excluded), where earlier PRs timed a raw
        # ticks/elapsed window — cross-PR comparisons of this key straddle
        # that change
        "steps_per_s_method": "steady-state per-tick walls, compile ticks "
                              "and feeder excluded (PR 5); previously raw "
                              "window ticks/elapsed",
        "async_speedup_8": round(
            out["steps_per_s"][str(big)]
            / max(out["steps_per_s_eager"][str(big)], 1e-9), 3),
        "block4_speedup_8": round(
            out["steps_per_s_block4"][str(big)]
            / max(out["steps_per_s_eager"][str(big)], 1e-9), 3),
        "syncs_per_tick": round(s8["async"][0], 3),
        "syncs_per_tick_eager": round(s8["eager"][0], 3),
        "syncs_per_tick_block4": round(s8["block4"][0], 3),
        "decode_dispatches_per_tick_block4": round(s8["block4"][1], 3),
        "sync_wait_frac_8": round(s8["async"][2], 3),
        "sync_wait_frac_8_eager": round(s8["eager"][2], 3),
    })
    return out


SHARD_DEVICES = (1, 2, 4, 8)
SHARD_FLEET = 8              # strong-scaling total fleet size
SHARD_WEAK_PER_DEV = 2       # weak scaling: F = 2 * devices

_SHARD_WORKER = r"""
import os, sys
n, F = int(sys.argv[1]), int(sys.argv[2])
if n > 1:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d" % n
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import json, time
import numpy as np
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.launch.mesh import make_fleet_mesh
from repro.models import make_model
from repro.serving import ElasticClusterFrontend, ReplicaEngine, Request

MAX_BATCH, MAX_SEQ = 4, 64
cfg = get_config("granite-3-8b").reduced()
model = make_model(cfg, tp=1)
params = model.init(jax.random.PRNGKey(0), jnp.float32)
mesh = make_fleet_mesh(n) if n > 1 else None

def mk(rid):
    return ReplicaEngine(model, params, max_batch=MAX_BATCH,
                         max_seq=MAX_SEQ, rid=rid)

fe = ElasticClusterFrontend(mk, 1, initial_replicas=F,
                            max_replicas_per_node=F, seed=0,
                            est_tokens=6, mesh=mesh)
rng = np.random.default_rng(1)
rid = 0

def refill():
    global rid
    while (len(fe.pending) + sum(nd.unfinished() for nd in fe.nodes)
           < 2 * F * MAX_BATCH):
        plen = int(rng.integers(2, 14))
        fe.submit(Request(rid,
                          rng.integers(1, cfg.vocab_size, plen).tolist(),
                          max_new_tokens=48))
        rid += 1

for _ in range(8):                       # warm compiles + fill the slab
    refill()
    fe.tick(0.0)
walls = []
s0, d0, ticks = fe.sync_count(), fe.decode_dispatches(), 0
for _ in range(6):                       # 6 rounds x 6-tick chunks
    refill()
    for _ in range(6):
        tr0 = fe.serve_kernel_traces()
        t0 = time.perf_counter()
        fe.tick(0.0)
        walls.append((time.perf_counter() - t0,
                      fe.serve_kernel_traces() > tr0))
        ticks += 1
kept = [w for w, compiled in walls if not compiled]
print("WORKER " + json.dumps({
    "devices": n, "fleet": F, "n_dev_seen": jax.local_device_count(),
    "steps_per_s": round(len(kept) / max(sum(kept), 1e-9), 2),
    "syncs_per_tick": round((fe.sync_count() - s0) / ticks, 3),
    "decode_dispatches_per_tick":
        round((fe.decode_dispatches() - d0) / ticks, 3),
}))
"""


def bench_shard_scaling() -> dict:
    """Sharded-slab decode throughput vs device count, strong + weak.

    One subprocess per point: the virtual-device flag binds at jax backend
    init, so each device count needs a fresh interpreter. Method matches
    ``bench_tick_scaling``: saturated slab, steady-state compile-excluded
    per-tick walls. The dispatch/sync columns double as the contract
    check — sharding must keep 1 logical dispatch and <= 1 sync per tick
    at every width."""
    import subprocess
    import sys
    import tempfile

    with tempfile.NamedTemporaryFile("w", suffix=".py", delete=False) as f:
        f.write(_SHARD_WORKER)
        worker = f.name
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(os.path.dirname(__file__), "..", "src"),
                    env.get("PYTHONPATH")) if p)
    env.pop("XLA_FLAGS", None)

    def run_point(devices, fleet):
        out = subprocess.run([sys.executable, worker, str(devices),
                              str(fleet)], capture_output=True, text=True,
                             env=env, timeout=900)
        if out.returncode != 0:
            raise RuntimeError(f"shard worker {devices}d/{fleet}F failed:\n"
                               + out.stderr[-2000:])
        line = [l for l in out.stdout.splitlines()
                if l.startswith("WORKER ")][-1]
        return json.loads(line[len("WORKER "):])

    strong = [run_point(n, SHARD_FLEET) for n in SHARD_DEVICES]
    weak = [run_point(n, SHARD_WEAK_PER_DEV * n) for n in SHARD_DEVICES]
    os.unlink(worker)
    base = strong[0]["steps_per_s"]
    ncores = os.cpu_count() or 1
    return {"shard_scaling": {
        "method": "one subprocess per device count (virtual-device flag "
                  "binds at backend init); saturated slab, steady-state "
                  "per-tick walls, compile ticks and feeder excluded — "
                  "same method as steps_per_s",
        "host_cores": ncores,
        "note": ("virtual devices time-slice %d real core(s): expect "
                 "flat-to-negative strong scaling below %d cores; the "
                 "contract columns (1 dispatch, <=1 sync per tick) are "
                 "hardware-independent" % (ncores, max(SHARD_DEVICES))),
        "strong_fleet": SHARD_FLEET,
        "strong": strong,
        "weak_per_device": SHARD_WEAK_PER_DEV,
        "weak": weak,
        "strong_speedup_4dev": round(
            strong[2]["steps_per_s"] / max(base, 1e-9), 3),
        "strong_speedup_8dev": round(
            strong[3]["steps_per_s"] / max(base, 1e-9), 3),
    }}


def bench_int8_capacity(model) -> dict:
    """Bytes of one replica's KV pool, fp32 vs int8 codec."""
    import jax
    import jax.numpy as jnp

    def nbytes(dtype):
        st = jax.eval_shape(
            lambda: model.init_serve_state(MAX_BATCH, MAX_SEQ, dtype))
        return int(sum(int(np.prod(l.shape)) * np.dtype(l.dtype).itemsize
                       for l in jax.tree.leaves(st)))

    fp32, int8 = nbytes(jnp.float32), nbytes("int8")
    return {
        "kv_pool_bytes_fp32": fp32,
        "kv_pool_bytes_int8": int8,
        "kv_capacity_gain_int8": round(fp32 / int8, 2),
    }


MATRIX_CELLS = {
    # chaos-off vs chaos-on at identical load isolates the fault's goodput
    # cost; the storm cell tightens timeouts and drops BOTH nodes so the
    # retry amplification actually bites; the flash crowd is the headline
    # closed-loop overload (1000 users, 50/tick ramp, tiny capacity)
    "chaos_off": dict(clients=48, ticks=32, timeout=10.0, retries=2),
    "spot_preemption": dict(clients=48, ticks=32, timeout=10.0, retries=2,
                            chaos="preempt@10:n0:k3,recover@22:n0"),
    "retry_storm": dict(clients=64, ticks=32, timeout=4.0, retries=3,
                        think=0.5,
                        chaos="preempt@8:n0:k2,preempt@10:n1:k2,"
                              "recover@18:n0,recover@20:n1"),
    "flash_crowd_1000": dict(clients=1000, ticks=40, timeout=6.0,
                             retries=1, spawn_rate=50.0, think=4.0),
}


def _run_matrix_cell(model, params, cfg, *, clients, ticks, timeout,
                     retries, chaos=None, spawn_rate=None, think=1.5,
                     seed=0) -> dict:
    from repro.serving import (ChaosSchedule, ElasticClusterFrontend,
                               ReplicaEngine, Request)
    from repro.workload import ClientPool

    rng = np.random.default_rng(seed)

    def mk(rid):
        return ReplicaEngine(model, params, max_batch=MAX_BATCH,
                             max_seq=MAX_SEQ, rid=rid)

    def rf(rid, tick):
        plen = int(rng.integers(2, 10))
        return Request(rid, rng.integers(1, cfg.vocab_size, plen).tolist(),
                       max_new_tokens=4)

    fe = ElasticClusterFrontend(
        mk, NODES, initial_replicas=2, max_replicas_per_node=2,
        provisioning_delay=2, request_factory=rf, seed=seed,
        est_tokens=4, preempt_notice=3,
        chaos=ChaosSchedule.parse(chaos) if chaos else None)
    pool = ClientPool(fe, clients, request_factory=rf, think_time=think,
                      timeout=timeout, max_retries=retries,
                      spawn_rate=spawn_rate, seed=seed + 1)
    curve = []
    for _ in range(ticks):
        pool.tick()
        m = fe.tick(0.0)
        curve.append(int(m["goodput"]))
    pool.quiesce()
    fe.run_until_drained()
    pool.finalize()
    led, s = fe.ledger, pool.summary()
    states = led.balance()
    total = max(led.submitted, 1)
    return {
        "clients": clients, "ticks": ticks, "chaos": chaos or "",
        "spawn_rate": spawn_rate,
        "submitted": led.submitted,
        "finished": states["finished"], "timed_out": states["timed_out"],
        "abandoned": states["abandoned"], "rejected": states["rejected"],
        "retries": led.retries, "duplicates": led.duplicates,
        "wasted": led.wasted, "double_served": led.double_served,
        "goodput_frac": round(states["finished"] / total, 3),
        "slo_attainment": round(s["ok"] / max(s["ok"] + s["abandoned"], 1),
                                3),
        "client_e2e_p95_ticks": s["latency_p95"],
        "preempted_nodes": fe.preempted_nodes,
        "ledger_balanced": led.balanced(),
        "goodput_curve": curve,
    }


MC_TIERS = "premium:0.3:w5:4,batch:0.7:w1"


def _run_multicell_cell(model, params, cfg, *, clients, ticks, timeout,
                        retries, cell_chaos=None, adaptive=True,
                        tiers_spec="", shed_threshold=None,
                        spawn_rate=None, think=1.5, seed=0) -> dict:
    """One failure-matrix cell through the multi-cell routing plane: 2
    elastic cells behind ``MultiCellBackend``, closed-loop clients on the
    router facade, one GLOBAL ledger. ``adaptive=False`` is the A/B arm:
    a fixed uniform split that keeps routing into dead/stale cells."""
    from repro.control import CellRouter, MultiCellBackend
    from repro.serving import (ChaosSchedule, ElasticClusterFrontend,
                               ReplicaEngine, Request)
    from repro.workload import ClientPool, parse_tiers

    tiers = parse_tiers(tiers_spec)
    rng = np.random.default_rng(seed)

    def mk(rid):
        return ReplicaEngine(model, params, max_batch=MAX_BATCH,
                             max_seq=MAX_SEQ, rid=rid, tiers=tiers)

    def rf(rid, tick):
        plen = int(rng.integers(2, 10))
        req = Request(rid, rng.integers(1, cfg.vocab_size, plen).tolist(),
                      max_new_tokens=4)
        if len(tiers) > 1:
            # deterministic tier per rid: a retry re-issues in the SAME
            # tier, so per-tier ledger rows attribute whole rids
            req.tier = tiers.names[0] if rid % 10 < 3 else tiers.names[-1]
        return req

    def cell(seed_):
        return ElasticClusterFrontend(
            mk, NODES, initial_replicas=2, max_replicas_per_node=2,
            provisioning_delay=2, seed=seed_, est_tokens=4,
            preempt_notice=3, tiers=tiers)

    router = CellRouter(2, tiers=tiers, shed_threshold=shed_threshold,
                        adaptive=adaptive)
    mc = MultiCellBackend(
        [cell(seed), cell(seed + 100)], tiers=tiers, router=router,
        chaos=ChaosSchedule.parse(cell_chaos) if cell_chaos else None,
        seed=seed)
    pool = ClientPool(mc, clients, request_factory=rf, think_time=think,
                      timeout=timeout, max_retries=retries,
                      spawn_rate=spawn_rate, seed=seed + 1)
    curve = []
    for _ in range(ticks):
        pool.tick()
        m = mc.tick(0.0)
        curve.append(int(m["goodput"]))
    pool.quiesce()
    mc.run_until_drained()
    pool.finalize()
    led, s = mc.ledger, pool.summary()
    states = led.balance()
    total = max(led.submitted, 1)
    row = {
        "cells": 2, "clients": clients, "ticks": ticks,
        "cell_chaos": cell_chaos or "", "adaptive_routing": adaptive,
        "spawn_rate": spawn_rate, "tiers": tiers_spec,
        "shed_threshold": shed_threshold,
        "submitted": led.submitted,
        "finished": states["finished"], "timed_out": states["timed_out"],
        "abandoned": states["abandoned"], "rejected": states["rejected"],
        "shed": states["shed"],
        "retries": led.retries, "duplicates": led.duplicates,
        "wasted": led.wasted, "double_served": led.double_served,
        "goodput_frac": round(states["finished"] / total, 3),
        "slo_attainment": round(s["ok"] / max(s["ok"] + s["abandoned"], 1),
                                3),
        "client_e2e_p95_ticks": s["latency_p95"],
        "shed_total": mc.shed_total,
        "evacuated": mc.evacuated_total, "cell_downs": mc.cell_downs,
        "quarantine_ticks": mc.quarantine_ticks,
        "ledger_balanced": led.balanced(),
        "goodput_curve": curve,
    }
    if len(tiers) > 1:
        per = {}
        for name in tiers.names:
            r_ = led.per_tier.get(name)
            if r_ is None:
                continue
            tot = max(r_["finished"] + r_["timed_out"] + r_["abandoned"]
                      + r_["rejected"] + r_["shed"], 1)
            per[name] = {
                "goodput_frac": round(r_["finished"] / tot, 3),
                **{k: r_[k] for k in ("finished", "timed_out", "abandoned",
                                      "rejected", "shed", "retries")},
            }
            cl = s["per_tier"].get(name)
            if cl:
                per[name]["slo_attainment"] = round(
                    cl["ok"] / max(cl["ok"] + cl["abandoned"], 1), 3)
        row["per_tier"] = per
    return row


PLANE_CHAOS = "plane_down@8:k10"     # dark backend ticks 8..17, up at 18
PLANE_BURST_TICK = 12                # burst cohort released MID-outage


def _run_plane_cell(model, params, cfg, *, hierarchy,
                    cell_chaos=PLANE_CHAOS, dark_windows=((8, 18),),
                    base_clients=8, burst_clients=40,
                    burst_tick=PLANE_BURST_TICK, ticks=32, timeout=8.0,
                    retries=1, think=3.0, plan_interval=6,
                    restart_supervisor_at=None, seed=0) -> dict:
    """One plane-outage arm: 2 elastic cells, a closed-loop base load plus
    a dormant client cohort released mid-outage (the burst the dead plane
    cannot see). ``hierarchy=True`` runs ``PlaneSupervisor`` + per-cell
    ``CellController``s under leases; ``hierarchy=False`` is the PR 8
    baseline — one central ``ControlPlane`` whose driver freezes while
    ``plane_alive`` is false. Leases are bounds-only
    (``apply_budget=False``) and span the full fleet, so BOTH arms can
    reach the same max capacity — the A/B isolates who may *act* during
    the outage, not capacity limits. Scale-reaction latency = ticks from
    the burst to the first rise of total in-flight replicas above the
    burst-onset count. ``restart_supervisor_at`` simulates a global-plane
    process crash: checkpoint, fresh supervisor + controllers, restore,
    keep running."""
    from repro.configs.paper_cluster import ClusterConfig
    from repro.control import (CellController, ControlPlane, GlobalPlanner,
                               MultiCellBackend, PlaneSupervisor)
    from repro.serving import (ChaosSchedule, ElasticClusterFrontend,
                               ReplicaEngine, Request)
    from repro.workload import ClientPool

    rng = np.random.default_rng(seed)

    def mk(rid):
        return ReplicaEngine(model, params, max_batch=MAX_BATCH,
                             max_seq=MAX_SEQ, rid=rid)

    def rf(rid, tick):
        plen = int(rng.integers(2, 10))
        return Request(rid, rng.integers(1, cfg.vocab_size, plen).tolist(),
                       max_new_tokens=4)

    def cell(seed_):
        return ElasticClusterFrontend(
            mk, NODES, initial_replicas=1, max_replicas_per_node=2,
            provisioning_delay=2, seed=seed_, est_tokens=4,
            preempt_notice=3)

    mc = MultiCellBackend([cell(seed), cell(seed + 100)],
                          chaos=ChaosSchedule.parse(cell_chaos), seed=seed)
    cell_cap = NODES * 2                 # 4 per cell, 8 fleet-wide
    sup = plane = None

    def mk_ctls():
        # patience/cooldown 1: the bench measures best-case local reaction
        return [CellController(mc, c, patience=1, cooldown=1)
                for c in range(2)]

    if hierarchy:
        planner = GlobalPlanner(2, total_budget=2 * cell_cap,
                                max_per_cell=cell_cap, lease_slack=0.5)
        sup = PlaneSupervisor(mc, planner, mk_ctls(),
                              plan_interval=plan_interval,
                              apply_budget=False)
    else:
        ccfg = ClusterConfig(num_nodes=2, horizon=8, forecast_window=16,
                             provisioning_delay=2,
                             max_replicas_per_node=cell_cap,
                             min_replicas_per_node=1, scale_interval=5,
                             cooldown=8, straggler_prob=0.0,
                             node_mtbf=1e12)
        plane = ControlPlane(ccfg, mc, balancer="rr", scaler="rbas",
                             unit_capacity=MAX_BATCH / 4, seed=seed,
                             init_arrival=2.0)

    # one pool, one rid space: spawn_rate is re-read every tick, so the
    # burst cohort stays dormant (rate 0) until the release tick
    pool = ClientPool(mc, base_clients + burst_clients,
                      request_factory=rf, think_time=think,
                      timeout=timeout, max_retries=retries,
                      spawn_rate=float(base_clients), seed=seed + 1)

    def in_flight():
        return sum(mc.cell_in_flight(c) for c in range(2))

    # stats survive a supervisor swap via these accumulators
    hist = {"plans": 0, "restores": 0, "outage_steps": 0}
    action_ticks: list = []
    curve, replica_curve = [], []
    base_if, reaction, restarts = None, None, 0
    for t in range(ticks):
        if t == 1:
            pool.spawn_rate = 0.0        # base cohort is in; hold the rest
        if burst_clients and t == burst_tick:
            pool.spawn_rate = float(burst_clients)
            base_if = in_flight()
        if sup is not None and restart_supervisor_at == t:
            ckpt = sup.checkpoint()
            smry = sup.summary()
            for k in hist:
                hist[k] += smry[k]
            action_ticks += [tk for c in sup.controllers
                             for tk in c.action_ticks]
            sup = PlaneSupervisor(mc, sup.planner, mk_ctls(),
                                  plan_interval=plan_interval,
                                  apply_budget=False)
            sup.restore(ckpt)
            restarts += 1
        pool.tick()
        if sup is not None:
            m = sup.step(0.0)
        elif getattr(mc, "plane_alive", True):
            m = plane.step(0.0)
        else:
            m = mc.tick(0.0)             # centralized arm: plane frozen
        curve.append(int(m["goodput"]))
        replica_curve.append(in_flight())
        if (reaction is None and base_if is not None
                and in_flight() > base_if):
            reaction = t - burst_tick
    pool.quiesce()
    mc.run_until_drained()
    pool.finalize()
    if sup is not None:
        smry = sup.summary()
        for k in hist:
            hist[k] += smry[k]
        action_ticks += [tk for c in sup.controllers
                         for tk in c.action_ticks]
    dark_actions = sum(1 for tk in action_ticks
                       if any(a <= tk < b for a, b in dark_windows))
    led, s = mc.ledger, pool.summary()
    states = led.balance()
    total = max(led.submitted, 1)
    row = {
        "hierarchy": bool(hierarchy), "cells": 2,
        "base_clients": base_clients, "burst_clients": burst_clients,
        "burst_tick": burst_tick if burst_clients else None,
        "ticks": ticks, "cell_chaos": cell_chaos,
        "submitted": led.submitted,
        "finished": states["finished"], "timed_out": states["timed_out"],
        "abandoned": states["abandoned"], "rejected": states["rejected"],
        "shed": states["shed"],
        "retries": led.retries, "duplicates": led.duplicates,
        "wasted": led.wasted, "double_served": led.double_served,
        "goodput_frac": round(states["finished"] / total, 3),
        "slo_attainment": round(s["ok"] / max(s["ok"] + s["abandoned"], 1),
                                3),
        "client_e2e_p95_ticks": s["latency_p95"],
        "plane_outages": mc.plane_outages,
        "plane_dark_ticks": mc.plane_outage_ticks,
        "local_actions": mc.local_actions_total,
        "local_actions_dark": dark_actions,
        "scale_reaction_ticks": reaction,
        "replica_curve": replica_curve,
        "ledger_balanced": led.balanced(),
        "goodput_curve": curve,
    }
    if sup is not None:
        row.update(plans=hist["plans"], restores=hist["restores"],
                   outage_steps=hist["outage_steps"],
                   supervisor_restarts=restarts)
    return row


def bench_failure_matrix(model, params, cfg) -> dict:
    """Closed-loop clients through the chaos cells (see MATRIX_CELLS) plus
    the multi-cell routing-plane cells (PR 8): cell blackout routed vs a
    static uniform split, a control-plane partition, total-overload
    shedding, and the flash-crowd-1000 re-run through the router with
    tier-aware shedding armed.

    Conservation is asserted per cell: an unbalanced ledger or a
    double-served rid fails the bench outright — a goodput number over
    lost/duplicated requests is not a goodput number. The multi-cell
    contracts are asserted too: routed goodput strictly above the static
    split under a blackout, and premium flash-crowd goodput above the
    PR 7 aggregate collapse with every shed an explicit ledger terminal.

    PR 10 plane-outage contracts (see ``_run_plane_cell``): hierarchical
    goodput strictly above centralized-frozen, hierarchical scale-reaction
    latency strictly below, local scale actions observed DURING the dark
    window, and the flap cell's two restores with a balanced ledger."""
    out = {}
    for name, kw in MATRIX_CELLS.items():
        cell = _run_matrix_cell(model, params, cfg, **kw)
        assert cell["ledger_balanced"], f"{name}: ledger unbalanced"
        assert cell["double_served"] == 0, f"{name}: rid served twice"
        out[name] = cell
    out["goodput_drop_spot_preemption"] = round(
        out["chaos_off"]["goodput_frac"]
        - out["spot_preemption"]["goodput_frac"], 3)
    out["goodput_drop_retry_storm"] = round(
        out["chaos_off"]["goodput_frac"]
        - out["retry_storm"]["goodput_frac"], 3)

    # ---- multi-cell cells (2 elastic cells behind the routing plane) ----
    # moderate load: the surviving cell must have headroom to absorb the
    # re-routed traffic for routing to pay off. Under total overload the
    # healthy cell saturates either way and a deeper queue only admits
    # requests closer to expiry — that regime belongs to the shedding
    # cells below, not this A/B. Tight deadlines + one retry make the
    # static split PAY for spraying into the dark cell.
    blackout = dict(clients=16, ticks=32, timeout=6.0, retries=1,
                    think=2.0, cell_chaos="cell_down@8:c0,cell_up@24:c0")
    mc_cells = {
        "cell_blackout": dict(blackout),
        "cell_blackout_static_split": dict(blackout, adaptive=False),
        "stale_partition": dict(clients=48, ticks=32, timeout=10.0,
                                retries=2,
                                cell_chaos="partition@8:c0:k12"),
        "overload_shed": dict(clients=96, ticks=32, timeout=8.0, retries=1,
                              think=0.5, tiers_spec=MC_TIERS,
                              shed_threshold=3.0),
        "flash_crowd_1000_routed": dict(clients=1000, ticks=40,
                                        timeout=6.0, retries=1,
                                        spawn_rate=50.0, think=4.0,
                                        tiers_spec=MC_TIERS,
                                        shed_threshold=3.0),
    }
    for name, kw in mc_cells.items():
        cell = _run_multicell_cell(model, params, cfg, **kw)
        assert cell["ledger_balanced"], f"{name}: global ledger unbalanced"
        assert cell["double_served"] == 0, \
            f"{name}: rid served twice across cells"
        out[name] = cell
    # the routing plane must BEAT a health-blind uniform split when a cell
    # goes dark (this is the point of the router — asserted, not hoped)
    out["routed_vs_static_goodput_gain"] = round(
        out["cell_blackout"]["goodput_frac"]
        - out["cell_blackout_static_split"]["goodput_frac"], 3)
    assert (out["cell_blackout"]["goodput_frac"]
            > out["cell_blackout_static_split"]["goodput_frac"]), \
        "adaptive routing did not beat the static split under blackout"
    # tier-aware shedding must rescue the premium tier from the PR 7
    # flash-crowd collapse (aggregate goodput was ~1.3% with no shedding)
    fc = out["flash_crowd_1000_routed"]
    assert fc["shed_total"] > 0, "flash crowd never tripped the shed"
    assert (fc["per_tier"]["premium"]["goodput_frac"]
            > out["flash_crowd_1000"]["goodput_frac"]), \
        "shedding failed to lift premium goodput above the collapse"

    # ---- plane-outage A/B (PR 10): hierarchical vs centralized-frozen ---
    # identical chaos, identical client streams; the burst lands mid-
    # outage, so only the arm that can act without the global plane reacts
    plane_cells = {
        "plane_outage_hier": dict(hierarchy=True),
        "plane_outage_centralized": dict(hierarchy=False),
        # two blackouts back-to-back + a checkpoint/restore supervisor
        # swap between them: repeated crash/restore, no burst cohort
        "plane_flap": dict(hierarchy=True,
                           cell_chaos="plane_down@6:k6,plane_down@18:k6",
                           dark_windows=((6, 12), (18, 24)),
                           base_clients=12, burst_clients=0, ticks=30,
                           restart_supervisor_at=14),
    }
    for name, kw in plane_cells.items():
        cell = _run_plane_cell(model, params, cfg, **kw)
        assert cell["ledger_balanced"], f"{name}: global ledger unbalanced"
        assert cell["double_served"] == 0, \
            f"{name}: rid served twice across a plane outage"
        out[name] = cell
    hier, cen = out["plane_outage_hier"], out["plane_outage_centralized"]
    assert hier["goodput_frac"] > cen["goodput_frac"], \
        "hierarchical control did not beat the frozen centralized plane"
    assert hier["local_actions_dark"] > 0, \
        "no local scale action landed during the plane outage"
    h_r = hier["scale_reaction_ticks"]
    # a never-reacting arm scores the remaining window (lower bound)
    c_r = cen["scale_reaction_ticks"]
    c_eff = c_r if c_r is not None else cen["ticks"] - PLANE_BURST_TICK
    assert h_r is not None and h_r < c_eff, \
        f"hierarchical reaction {h_r} not below centralized {c_eff}"
    out["plane_outage_goodput_gain"] = round(
        hier["goodput_frac"] - cen["goodput_frac"], 3)
    out["plane_scale_reaction_gain_ticks"] = int(c_eff - h_r)
    flap = out["plane_flap"]
    assert flap["restores"] == 2, \
        f"flap saw {flap['restores']} restores, expected 2"
    assert flap["supervisor_restarts"] == 1 and flap["plans"] > 0, \
        "checkpoint/restore swap did not keep the plan loop running"
    return {"failure_matrix": out}


def bench_control_plane(model, params, cfg) -> dict:
    """The original autoscaled trace: latency percentiles + retraces."""
    from repro.configs.paper_cluster import ClusterConfig
    from repro.control import ControlPlane
    from repro.serving import ElasticClusterFrontend

    ccfg = ClusterConfig(num_nodes=NODES, horizon=4, forecast_window=8,
                         provisioning_delay=2, max_replicas_per_node=2,
                         min_replicas_per_node=1, scale_interval=4,
                         cooldown=6, straggler_prob=0.0, node_mtbf=1e12)
    rng = np.random.default_rng(0)
    fe = ElasticClusterFrontend(
        _mk(model, params, cfg), NODES, initial_replicas=1,
        provisioning_delay=2, max_replicas_per_node=2,
        request_factory=_request_factory(cfg, rng), seed=0,
        est_tokens=N_NEW)
    plane = ControlPlane(ccfg, fe, balancer="rr", scaler="rbas",
                         unit_capacity=MAX_BATCH / N_NEW, seed=0,
                         init_arrival=2.0)
    t0 = time.time()
    for _ in range(TICKS):
        plane.step(2.0)
    fe.run_until_drained()
    wall = time.time() - t0

    done = fe.finished
    toks = sum(len(r.output) for r in done)
    ttft = np.asarray([r.first_token_time - r.arrival for r in done])
    lat = np.asarray([r.finish_time - r.arrival for r in done])
    return {
        "requests": len(done),
        "tokens": toks,
        "wall_s": round(wall, 3),
        "plane_tok_per_s": round(toks / max(wall, 1e-9), 2),
        "ttft_p50_ticks": float(np.percentile(ttft, 50)),
        "ttft_p95_ticks": float(np.percentile(ttft, 95)),
        "latency_p50_ticks": float(np.percentile(lat, 50)),
        "latency_p95_ticks": float(np.percentile(lat, 95)),
        "prefill_retraces": int(fe.prefill_retraces()),
        "live_replicas": len(fe.replicas),
        "replica_ticks": fe.replica_ticks,
    }


def main() -> list:
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.models import make_model

    cfg = get_config("granite-3-8b").reduced()
    model = make_model(cfg, tp=1)
    params = model.init(jax.random.PRNGKey(0), jnp.float32)

    blob = {}
    blob.update(bench_fleet_vs_loop(model, params, cfg))
    blob.update(bench_fleet_prefill(model, params, cfg))
    blob.update(bench_chunked(model, params, cfg))
    blob.update(bench_tiers(model, params, cfg))
    blob.update(bench_tick_scaling(model, params, cfg))
    blob.update(bench_shard_scaling())
    blob.update(bench_int8_capacity(model))
    blob.update(bench_control_plane(model, params, cfg))
    blob.update(bench_failure_matrix(model, params, cfg))
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "BENCH_serve.json"), "w") as f:
        json.dump(blob, f, indent=2, sort_keys=True)

    flat = blob["steps_per_s"]
    return [
        ("serve/elastic_tok_per_s", 1e6 / max(blob["tok_per_s"], 1e-9),
         f"{blob['tok_per_s']}tok/s fleet"),
        ("serve/fleet_speedup_x", blob["fleet_speedup"] * 1e6,
         f"vs {blob['tok_per_s_per_replica_loop']}tok/s loop"),
        ("serve/decode_dispatches_per_tick",
         blob["decode_dispatches_per_tick"] * 1e6, "per fleet group"),
        ("serve/prefill_dispatches_per_tick",
         blob["prefill_dispatches_per_tick_fleet"] * 1e6,
         f"vs {blob['prefill_dispatches_per_tick_loop']} per-replica"),
        ("serve/cold_drain_speedup_x", blob["cold_drain_speedup"] * 1e6,
         f"{blob['cold_drain_wall_s_loop']}s loop vs "
         f"{blob['cold_drain_wall_s_fleet']}s fleet"),
        ("serve/chunked_tick_wall_p95_ms",
         blob["chunked"]["on"]["tick_wall_p95_ms"] * 1e6,
         f"{blob['chunked']['off']['tick_wall_p95_ms']}ms single-shot"),
        ("serve/premium_ttft_p95_tiered",
         blob["tiers"]["premium_ttft_p95_tiered"] * 1e6,
         f"vs {blob['tiers']['premium_ttft_p95_untiered']}t untiered, "
         f"tok/s ratio {blob['tiers']['tok_per_s_ratio']}"),
        ("serve/batch_ttft_max_tiered",
         blob["tiers"]["batch_ttft_max_tiered"] * 1e6,
         "batch-tier starvation bound (ticks)"),
        ("serve/steps_per_s_8_replicas", 1e6 / max(flat["8"], 1e-9),
         f"1rep={flat['1']}/s 8rep={flat['8']}/s "
         f"(eager {blob['steps_per_s_eager']['8']}/s, "
         f"block4 {blob['steps_per_s_block4']['8']}/s)"),
        ("serve/async_speedup_8", blob["async_speedup_8"] * 1e6,
         f"block4 {blob['block4_speedup_8']}x vs eager"),
        ("serve/shard_strong_speedup_4dev",
         blob["shard_scaling"]["strong_speedup_4dev"] * 1e6,
         f"F=8 over 1/2/4/8 virtual devices on "
         f"{blob['shard_scaling']['host_cores']} core(s); "
         f"8dev {blob['shard_scaling']['strong_speedup_8dev']}x"),
        ("serve/syncs_per_tick", blob["syncs_per_tick"] * 1e6,
         f"eager {blob['syncs_per_tick_eager']}, "
         f"block4 {blob['syncs_per_tick_block4']}"),
        ("serve/ttft_p95", blob["ttft_p95_ticks"] * 1e6,
         f"p50={blob['ttft_p50_ticks']:.1f}t"),
        ("serve/latency_p95", blob["latency_p95_ticks"] * 1e6,
         f"p50={blob['latency_p50_ticks']:.1f}t"),
        ("serve/prefill_retraces", float(blob["prefill_retraces"]),
         f"{blob['requests']}req"),
        ("serve/goodput_chaos_off",
         blob["failure_matrix"]["chaos_off"]["goodput_frac"] * 1e6,
         f"spot {blob['failure_matrix']['spot_preemption']['goodput_frac']}"
         f" storm {blob['failure_matrix']['retry_storm']['goodput_frac']}"),
        ("serve/goodput_flash_crowd_1000",
         blob["failure_matrix"]["flash_crowd_1000"]["goodput_frac"] * 1e6,
         f"{blob['failure_matrix']['flash_crowd_1000']['retries']} retries,"
         f" {blob['failure_matrix']['flash_crowd_1000']['abandoned']}"
         " abandoned"),
        ("serve/goodput_cell_blackout_routed",
         blob["failure_matrix"]["cell_blackout"]["goodput_frac"] * 1e6,
         f"static split "
         f"{blob['failure_matrix']['cell_blackout_static_split']['goodput_frac']}, "
         f"gain {blob['failure_matrix']['routed_vs_static_goodput_gain']}"),
        ("serve/goodput_flash_crowd_premium_routed",
         blob["failure_matrix"]["flash_crowd_1000_routed"]["per_tier"][
             "premium"]["goodput_frac"] * 1e6,
         f"{blob['failure_matrix']['flash_crowd_1000_routed']['shed_total']}"
         f" shed, vs "
         f"{blob['failure_matrix']['flash_crowd_1000']['goodput_frac']}"
         " aggregate unrouted"),
        ("serve/goodput_plane_outage_hier",
         blob["failure_matrix"]["plane_outage_hier"]["goodput_frac"] * 1e6,
         f"centralized-frozen "
         f"{blob['failure_matrix']['plane_outage_centralized']['goodput_frac']},"
         f" gain {blob['failure_matrix']['plane_outage_goodput_gain']}"),
        ("serve/plane_scale_reaction_ticks",
         blob["failure_matrix"]["plane_outage_hier"]
         ["scale_reaction_ticks"] * 1e6,
         f"burst mid-outage; centralized "
         f"{blob['failure_matrix']['plane_outage_centralized']['scale_reaction_ticks']}t,"
         f" {blob['failure_matrix']['plane_outage_hier']['local_actions_dark']}"
         " dark-window actions, flap restores="
         f"{blob['failure_matrix']['plane_flap']['restores']}"),
    ]


if __name__ == "__main__":
    for row in main():
        print(row)
