"""Paper figure reproductions (Figs. 1-3) + headline-claims table.

Each bench writes a CSV under results/ and returns summary rows for
``benchmarks.run``'s CSV contract.
"""
from __future__ import annotations

import csv
import json
import os
import time

import numpy as np

from benchmarks.common import (BENCH_TICKS, METHODS, get_controller,
                               run_method)
from repro.workload import LOAD_LEVELS


def _write_csv(path, header, rows):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        w.writerows(rows)


def fig1_utilization(controller=None) -> list:
    """Fig.1: resource utilization over time (medium-high load)."""
    rows, out = [], []
    series = {}
    for m in METHODS:
        t0 = time.time()
        r = run_method(m, load_scale=1.5, controller=controller)
        s = r.summary()
        series[m] = r.utilization
        rows.append([m, s["mean_util"], s["std_util"], s["fairness"]])
        out.append((f"fig1_utilization/{m}", (time.time() - t0) * 1e6,
                    f"mean_util={s['mean_util']:.3f}|std={s['std_util']:.3f}"))
    T = len(next(iter(series.values())))
    _write_csv("results/fig1_utilization.csv",
               ["tick"] + list(series), [[t] + [series[m][t] for m in series]
                                         for t in range(T)])
    _write_csv("results/fig1_summary.csv",
               ["method", "mean_util", "std_util", "fairness"], rows)
    return out


def fig2_response_time(controller=None) -> list:
    """Fig.2: response time vs load level."""
    rows, out = [], []
    for level, scale in LOAD_LEVELS.items():
        for m in METHODS:
            t0 = time.time()
            s = run_method(m, load_scale=scale, controller=controller
                           ).summary()
            rows.append([level, m, s["mean_resp"], s["p95_resp"],
                         s["slo_attainment"]])
            out.append((f"fig2_response/{level}/{m}",
                        (time.time() - t0) * 1e6,
                        f"mean={s['mean_resp']:.3f}s|p95={s['p95_resp']:.3f}s"))
    _write_csv("results/fig2_response_time.csv",
               ["load", "method", "mean_resp_s", "p95_resp_s", "slo"], rows)
    return out


def fig3_scaling_efficiency(controller=None) -> list:
    """Fig.3: scaling efficiency vs load level."""
    rows, out = [], []
    for level, scale in LOAD_LEVELS.items():
        for m in METHODS:
            t0 = time.time()
            s = run_method(m, load_scale=scale, controller=controller
                           ).summary()
            rows.append([level, m, s["scaling_efficiency"], s["cost"]])
            out.append((f"fig3_scaling/{level}/{m}", (time.time() - t0) * 1e6,
                        f"eff={s['scaling_efficiency']:.3f}|cost={s['cost']:.0f}"))
    _write_csv("results/fig3_scaling_efficiency.csv",
               ["load", "method", "scaling_efficiency", "replica_ticks"],
               rows)
    return out


def paper_claims(controller=None) -> list:
    """Validate the paper's headline numbers: +35% load-balancing (capacity)
    efficiency and -28% response delay vs conventional methods, at high load.

    'Conventional' = the non-learned baselines (RRA/LCA/RBAS); HPA reported
    separately as the strongest k8s-native comparison.
    """
    res = {m: run_method(m, load_scale=1.8, controller=controller).summary()
           for m in METHODS}
    conv_resp = np.mean([res[m]["mean_resp"] for m in ("RRA", "LCA", "RBAS")])
    conv_eff = np.mean([res[m]["scaling_efficiency"]
                        for m in ("HPA", "RBAS")])   # scalers only: efficiency
    # of *provisioned* capacity is only meaningful for methods that scale
    ours = res["OURS"]
    resp_delta = 1.0 - ours["mean_resp"] / conv_resp
    eff_delta = ours["scaling_efficiency"] / conv_eff - 1.0
    claims = {
        "response_reduction_vs_conventional": resp_delta,
        "paper_claim_response": 0.28,
        "efficiency_gain_vs_scalers": eff_delta,
        "paper_claim_efficiency": 0.35,
        "per_method": res,
    }
    os.makedirs("results", exist_ok=True)
    with open("results/paper_claims.json", "w") as f:
        json.dump(claims, f, indent=2, default=float)
    return [("claims/response_reduction", 0.0,
             f"ours_vs_conventional=-{resp_delta*100:.1f}%|paper=-28%"),
            ("claims/efficiency_gain", 0.0,
             f"ours_vs_scalers=+{eff_delta*100:.1f}%|paper=+35%")]
