"""Fast serving smoke for CI: tiny model, 2 replicas, hard asserts.

Guards the two admission-path invariants cheap enough for every PR:

  * **fleet admission dispatch bound** — a cold burst of same-length
    prompts must admit in <= (distinct bucket shapes) jitted prefill
    dispatches per tick, never one per replica (here: equal lengths + equal
    group sizes -> exactly ONE shape -> ONE dispatch, vs 2 for the
    per-replica oracle);
  * **TTFT regression bound** — with chunked admission on, short requests
    sharing the cluster with near-``max_seq`` prompts must keep their TTFT
    p95 within the same small constant as a short-only run would give
    (admission is interleaved, not front-loaded).

Exits non-zero on violation (plain asserts); prints the measured numbers so
CI logs double as a mini-benchmark.
"""
from __future__ import annotations

import numpy as np

MAX_SEQ = 64
MAX_BATCH = 4
CHUNK = 8
TTFT_P95_BOUND = 4.0     # ticks; generous vs the ~1-2 ticks measured


def main():
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.models import make_model
    from repro.serving import ElasticClusterFrontend, ReplicaEngine, Request

    cfg = get_config("granite-3-8b").reduced()
    model = make_model(cfg, tp=1)
    params = model.init(jax.random.PRNGKey(0), jnp.float32)
    rng = np.random.default_rng(0)

    # ---- fleet admission dispatch bound -------------------------------
    # 2 replicas x full batch of equal-length prompts: every replica
    # admits a (kb=4, sb=8) group -> ONE distinct bucket shape
    prompts = [rng.integers(1, cfg.vocab_size, 6).tolist()
               for _ in range(2 * MAX_BATCH)]

    def burst_fe(fp):
        def mk(rid):
            return ReplicaEngine(model, params, max_batch=MAX_BATCH,
                                 max_seq=MAX_SEQ, rid=rid)
        fe = ElasticClusterFrontend(mk, 1, initial_replicas=2,
                                    max_replicas_per_node=2, seed=0,
                                    fleet_prefill=fp)
        for i, p in enumerate(prompts):
            fe.submit(Request(i, list(p), max_new_tokens=3))
        return fe, fe.tick(0.0)

    fe_on, m_on = burst_fe(True)
    fe_off, m_off = burst_fe(False)
    distinct_shapes = 1
    print(f"[smoke] admission tick prefill_dispatches: "
          f"fleet={m_on['prefill_dispatches']} "
          f"per-replica={m_off['prefill_dispatches']} "
          f"(distinct bucket shapes={distinct_shapes})")
    assert m_on["prefill_dispatches"] <= distinct_shapes, \
        "fleet admission must cost <= one dispatch per distinct bucket shape"
    assert m_off["prefill_dispatches"] >= 2, \
        "per-replica oracle should pay one dispatch per admitting replica"
    fe_on.run_until_drained()
    fe_off.run_until_drained()
    snap = lambda fe: sorted((r.rid, tuple(r.output)) for r in fe.finished)
    assert snap(fe_on) == snap(fe_off), "fleet admission changed streams"

    # ---- chunked-admission TTFT bound ---------------------------------
    def mk_chunk(rid):
        return ReplicaEngine(model, params, max_batch=MAX_BATCH,
                             max_seq=MAX_SEQ, rid=rid, chunk_len=CHUNK)

    def rf(rid, tick):
        plen = MAX_SEQ - 2 if rid % 4 == 0 else int(rng.integers(4, 10))
        return Request(rid, rng.integers(1, cfg.vocab_size, plen).tolist(),
                       max_new_tokens=4)

    fe = ElasticClusterFrontend(mk_chunk, 1, initial_replicas=2,
                                max_replicas_per_node=2, request_factory=rf,
                                seed=0, est_tokens=4)
    for _ in range(30):
        fe.tick(1.0)
    fe.run_until_drained()
    short = [r for r in fe.finished if len(r.prompt) < MAX_SEQ - 2]
    ttft_p95 = float(np.percentile(
        [r.first_token_time - r.arrival for r in short], 95))
    print(f"[smoke] chunked run: {len(fe.finished)} requests, "
          f"short TTFT p95={ttft_p95:.1f} ticks (bound {TTFT_P95_BOUND})")
    assert ttft_p95 <= TTFT_P95_BOUND, \
        "chunked admission regressed short-request TTFT"
    print("[smoke] OK")


if __name__ == "__main__":
    main()
