"""Fast serving smoke for CI: tiny model, 2 replicas, hard asserts.

Guards the admission-path invariants cheap enough for every PR:

  * **fleet admission dispatch bound** — a cold burst of same-length
    prompts must admit in <= (distinct bucket shapes) jitted prefill
    dispatches per tick, never one per replica (here: equal lengths + equal
    group sizes -> exactly ONE shape -> ONE dispatch, vs 2 for the
    per-replica oracle);
  * **TTFT regression bound** — with chunked admission on, short requests
    sharing the cluster with near-``max_seq`` prompts must keep their TTFT
    p95 within the same small constant as a short-only run would give
    (admission is interleaved, not front-loaded);
  * **SLO tiers** — a 3-tier cold burst must (a) give premium a TTFT p95
    no worse than the untiered FIFO baseline on the identical workload,
    (b) still finish every batch-tier request (no starvation), and (c)
    keep the fleet dispatch bounds: tiering reorders which rows enter the
    one fleet prefill/decode per tick, it never adds dispatches;
  * **async tick contract** — on the same 3-tier config the (default)
    async tick must pay at most ONE blocking host sync per tick
    (``metrics()['syncs'] <= 1``, admissions included) and produce token
    streams bit-identical to the eager oracle; with ``decode_block=4`` the
    fused windows must engage (total syncs / ticks < 1);
  * **multi-cell chaos drill** — 2 elastic cells behind the fault-tolerant
    routing plane (``control.cells``) with a scripted ``cell_down`` under
    retrying clients: the single global ledger must balance with
    ``double_served == 0`` across the evacuation + re-route, and each cell
    must keep <= 1 sync and <= 1 decode dispatch per group per tick
    (churn-flush ticks excepted, same accounting as the chaos drill);
  * **plane-crash drill** — the same federation under the two-level
    hierarchy (``control.hierarchy``) with the GLOBAL plane crashed for 6
    ticks (``plane_down@4:k6``) while retrying clients ramp up: the
    per-cell controllers must keep taking scale actions inside their
    leases DURING the outage, the supervisor must reconcile exactly once
    on restore, the ledger must balance with ``double_served == 0``, and
    the per-cell sync/dispatch bounds must hold throughout;
  * **sharded fleet parity** — a child process with 4 virtual devices
    (``xla_force_host_platform_device_count=4``; the flag must precede
    jax's backend init, hence the subprocess) runs the same workload
    through a 4-way ``('fleet',)`` mesh and unsharded: token streams +
    finish clocks must match bit-for-bit and the sharded run must keep
    <= 1 blocking sync and one decode dispatch per group per tick.

Exits non-zero on violation (plain asserts); prints the measured numbers so
CI logs double as a mini-benchmark.
"""
from __future__ import annotations

import os
import subprocess
import sys

import numpy as np

MAX_SEQ = 64
MAX_BATCH = 4
CHUNK = 8
TTFT_P95_BOUND = 4.0     # ticks; generous vs the ~1-2 ticks measured


def main():
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.models import make_model
    from repro.serving import ElasticClusterFrontend, ReplicaEngine, Request

    cfg = get_config("granite-3-8b").reduced()
    model = make_model(cfg, tp=1)
    params = model.init(jax.random.PRNGKey(0), jnp.float32)
    rng = np.random.default_rng(0)

    # ---- fleet admission dispatch bound -------------------------------
    # 2 replicas x full batch of equal-length prompts: every replica
    # admits a (kb=4, sb=8) group -> ONE distinct bucket shape
    prompts = [rng.integers(1, cfg.vocab_size, 6).tolist()
               for _ in range(2 * MAX_BATCH)]

    def burst_fe(fp):
        def mk(rid):
            return ReplicaEngine(model, params, max_batch=MAX_BATCH,
                                 max_seq=MAX_SEQ, rid=rid)
        fe = ElasticClusterFrontend(mk, 1, initial_replicas=2,
                                    max_replicas_per_node=2, seed=0,
                                    fleet_prefill=fp)
        for i, p in enumerate(prompts):
            fe.submit(Request(i, list(p), max_new_tokens=3))
        return fe, fe.tick(0.0)

    fe_on, m_on = burst_fe(True)
    fe_off, m_off = burst_fe(False)
    distinct_shapes = 1
    print(f"[smoke] admission tick prefill_dispatches: "
          f"fleet={m_on['prefill_dispatches']} "
          f"per-replica={m_off['prefill_dispatches']} "
          f"(distinct bucket shapes={distinct_shapes})")
    assert m_on["prefill_dispatches"] <= distinct_shapes, \
        "fleet admission must cost <= one dispatch per distinct bucket shape"
    assert m_off["prefill_dispatches"] >= 2, \
        "per-replica oracle should pay one dispatch per admitting replica"
    fe_on.run_until_drained()
    fe_off.run_until_drained()
    snap = lambda fe: sorted((r.rid, tuple(r.output)) for r in fe.finished)
    assert snap(fe_on) == snap(fe_off), "fleet admission changed streams"

    # ---- chunked-admission TTFT bound ---------------------------------
    def mk_chunk(rid):
        return ReplicaEngine(model, params, max_batch=MAX_BATCH,
                             max_seq=MAX_SEQ, rid=rid, chunk_len=CHUNK)

    def rf(rid, tick):
        plen = MAX_SEQ - 2 if rid % 4 == 0 else int(rng.integers(4, 10))
        return Request(rid, rng.integers(1, cfg.vocab_size, plen).tolist(),
                       max_new_tokens=4)

    fe = ElasticClusterFrontend(mk_chunk, 1, initial_replicas=2,
                                max_replicas_per_node=2, request_factory=rf,
                                seed=0, est_tokens=4)
    for _ in range(30):
        fe.tick(1.0)
    fe.run_until_drained()
    short = [r for r in fe.finished if len(r.prompt) < MAX_SEQ - 2]
    ttft_p95 = float(np.percentile(
        [r.first_token_time - r.arrival for r in short], 95))
    print(f"[smoke] chunked run: {len(fe.finished)} requests, "
          f"short TTFT p95={ttft_p95:.1f} ticks (bound {TTFT_P95_BOUND})")
    assert ttft_p95 <= TTFT_P95_BOUND, \
        "chunked admission regressed short-request TTFT"

    # ---- 3-tier premium TTFT + dispatch bounds ------------------------
    from repro.workload import TierSet, TierSpec

    tiers = TierSet([TierSpec("premium", share=0.34, weight=5.0,
                              ttft_target=3.0),
                     TierSpec("standard", share=0.33, weight=2.0),
                     TierSpec("batch", share=0.33, weight=1.0)])
    burst = [rng.integers(1, cfg.vocab_size, 6).tolist() for _ in range(24)]

    def tier_burst(ts, async_tick=True, decode_block=1, n_new=3):
        def mk(rid):
            return ReplicaEngine(model, params, max_batch=MAX_BATCH,
                                 max_seq=MAX_SEQ, rid=rid, tiers=ts)
        fe = ElasticClusterFrontend(mk, 1, initial_replicas=2,
                                    max_replicas_per_node=2, seed=0,
                                    async_tick=async_tick,
                                    decode_block=decode_block, tiers=ts)
        for i, p in enumerate(burst):
            req = Request(i, list(p), max_new_tokens=n_new)
            if ts is not None:
                req.tier = tiers.names[i % 3]
            fe.submit(req)
        admit_m = fe.tick(0.0)
        max_decode, max_syncs, ticks = 0.0, admit_m["syncs"], 1
        for _ in range(200):
            m = fe.tick(0.0)
            ticks += 1
            if m["decode_dispatches"]:
                max_decode = max(max_decode, m["decode_dispatches"]
                                 / max(m["fleet_groups"], 1))
            if async_tick:
                max_syncs = max(max_syncs, m["syncs"])
            if not fe.pending and all(n.unfinished() == 0
                                      for n in fe.nodes):
                break
        return fe, admit_m, max_decode, max_syncs, ticks

    fe_t, admit_t, dec_t, sync_t, _ = tier_burst(tiers)
    fe_u, admit_u, _, _, _ = tier_burst(None)

    def ttft95(fe, pred):
        return float(np.percentile(
            [r.first_token_time - r.arrival
             for r in fe.finished if pred(r)], 95))

    prem = lambda r: r.rid % 3 == 0          # the same request population
    prem_tiered = ttft95(fe_t, prem)
    prem_untiered = ttft95(fe_u, prem)
    batch_done = [r for r in fe_t.finished if r.rid % 3 == 2]
    print(f"[smoke] 3-tier burst: premium TTFT p95 tiered={prem_tiered:.1f} "
          f"untiered={prem_untiered:.1f}; batch finished={len(batch_done)}/8; "
          f"admit prefill_dispatches={admit_t['prefill_dispatches']} "
          f"max decode_dispatches/group={dec_t:.1f}")
    assert prem_tiered <= prem_untiered, \
        "tiered premium TTFT p95 must not exceed the untiered baseline"
    assert len(batch_done) == 8, "batch tier starved under tiering"
    assert admit_t["prefill_dispatches"] <= 1, \
        "tiering must not add admission dispatches (one bucket shape)"
    assert admit_t["prefill_dispatches"] <= admit_u["prefill_dispatches"]
    assert dec_t <= 1.0, \
        "tiering must keep ONE fleet decode dispatch per group per tick"

    # ---- async tick: syncs_per_tick bound + eager stream parity -------
    assert sync_t <= 1, \
        "async tick must pay at most ONE blocking sync per tick"
    fe_e, _, _, _, _ = tier_burst(tiers, async_tick=False)
    snap_async = snap(fe_t)
    snap_eager = snap(fe_e)
    assert snap_async == snap_eager, \
        "async tick changed token streams vs the eager oracle"

    # decode_block=4: longer outputs so fused windows engage once the
    # admission wave passes; total syncs must amortize below 1/tick
    fe_b, _, _, _, ticks_b = tier_burst(tiers, decode_block=4, n_new=16)
    fe_r, _, _, _, _ = tier_burst(tiers, decode_block=1, n_new=16)
    spt = fe_b.sync_count() / ticks_b
    print(f"[smoke] async: max syncs/tick={sync_t} (streams == eager); "
          f"decode_block=4: syncs/tick={spt:.2f} over {ticks_b} ticks")
    assert spt < 1.0, "decode_block=4 must amortize syncs below 1/tick"
    # finish ticks may lag <= K-1 inside fused windows; token content is
    # the invariant
    toks_b = sorted((r.rid, tuple(r.output)) for r in fe_b.finished)
    toks_r = sorted((r.rid, tuple(r.output)) for r in fe_r.finished)
    assert toks_b == toks_r, "fused decode blocks changed token content"

    # ---- chaos drill: preemption + closed-loop clients ----------------
    # scripted spot preemption mid-load under retrying clients: the ledger
    # must balance (every rid exactly-once terminal, nothing served twice)
    # and the tick contract must hold through drain + hard drop
    from repro.serving import ChaosSchedule
    from repro.workload import ClientPool

    def mk_chaosrep(rid):
        return ReplicaEngine(model, params, max_batch=MAX_BATCH,
                             max_seq=MAX_SEQ, rid=rid)

    def cf(rid, tick):
        return Request(rid, rng.integers(1, cfg.vocab_size, 5).tolist(),
                       max_new_tokens=4)

    fe_c = ElasticClusterFrontend(
        mk_chaosrep, 2, initial_replicas=2, max_replicas_per_node=2,
        provisioning_delay=2, request_factory=cf, seed=0,
        preempt_notice=3,
        chaos=ChaosSchedule.parse("preempt@6:n0:k3,recover@14:n0"))
    pool = ClientPool(fe_c, 12, request_factory=cf, think_time=1.0,
                      timeout=6.0, max_retries=2, seed=1)
    max_syncs_c = max_disp_c = 0.0
    churn_over = steady_over = 0
    for _ in range(20):
        n_before = sum(len(n.live) + len(n.draining) for n in fe_c.nodes)
        pool.tick()
        m = fe_c.tick(0.0)
        n_after = sum(len(n.live) + len(n.draining) for n in fe_c.nodes)
        # steady-state contract: ONE reconcile sync per live fleet group
        # per tick. Membership churn (drain retire, preemption drop)
        # legitimately force-flushes that group's pending futures — one
        # extra sync on the tick a group's rows unstack, never more.
        over = m["syncs"] - max(m["fleet_groups"], 1)
        if over > 0:
            if n_after != n_before:
                churn_over += 1
                assert over <= 1, "churn tick paid more than one flush"
            else:
                steady_over += 1
        max_syncs_c = max(max_syncs_c, m["syncs"])
        if m["decode_dispatches"]:
            max_disp_c = max(max_disp_c, m["decode_dispatches"]
                             / max(m["fleet_groups"], 1))
    pool.quiesce()
    fe_c.run_until_drained()
    pool.finalize()
    led = fe_c.ledger
    s = pool.summary()
    print(f"[smoke] chaos drill: preempted_nodes={fe_c.preempted_nodes} "
          f"submitted={led.submitted} ok={s['ok']} retries={s['retries']} "
          f"abandoned={s['abandoned']} double_served={led.double_served} "
          f"max syncs/tick={max_syncs_c:.0f} "
          f"(churn flush ticks={churn_over}) "
          f"max decode_dispatches/group={max_disp_c:.1f}")
    assert fe_c.preempted_nodes >= 1, "scripted preemption did not fire"
    assert led.balanced(), f"ledger unbalanced under chaos: {led.balance()}"
    assert led.double_served == 0, "a request was served twice"
    assert s["ok"] > 0, "no goodput under the chaos drill"
    assert steady_over == 0, \
        "chaos broke the one-sync-per-group bound on a churn-free tick"
    assert max_disp_c <= 1.0, \
        "chaos broke the one-decode-dispatch-per-group bound"

    # ---- multi-cell chaos drill: cell blackout under the router -------
    # 2 elastic cells behind the routing plane, a scripted blackout while
    # retrying clients keep pressure on: the ONE global ledger must stay
    # balanced with nothing double-served across the evacuation + re-route,
    # and every cell must keep the per-tick sync/dispatch bounds (the
    # router adds zero device work of its own)
    from repro.control import MultiCellBackend

    def mc_cell(seed):
        return ElasticClusterFrontend(
            mk_chaosrep, 2, initial_replicas=1, max_replicas_per_node=2,
            provisioning_delay=2, seed=seed)

    mc = MultiCellBackend(
        [mc_cell(0), mc_cell(1)],
        chaos=ChaosSchedule.parse("cell_down@6:c0,cell_up@14:c0"), seed=0)
    pool_mc = ClientPool(mc, 12, request_factory=cf, think_time=1.0,
                         timeout=8.0, max_retries=2, seed=2)
    mc_churn = mc_steady = 0
    max_disp_mc = 0.0
    for _ in range(22):
        before = [sum(len(n.live) + len(n.draining) for n in cell.nodes)
                  for cell in mc.cells]
        pool_mc.tick()
        mc.tick(0.0)
        for cell, n_before in zip(mc.cells, before):
            m = cell.metrics()
            if not m:
                continue
            n_after = sum(len(n.live) + len(n.draining)
                          for n in cell.nodes)
            over = m["syncs"] - max(m["fleet_groups"], 1)
            if over > 0:
                if n_after != n_before:
                    mc_churn += 1      # churn flush: blackout/restore tick
                else:
                    mc_steady += 1
            if m["decode_dispatches"]:
                max_disp_mc = max(max_disp_mc, m["decode_dispatches"]
                                  / max(m["fleet_groups"], 1))
    pool_mc.quiesce()
    mc.run_until_drained()
    pool_mc.finalize()
    led_mc = mc.ledger
    s_mc = pool_mc.summary()
    print(f"[smoke] multi-cell drill: cell_downs={mc.cell_downs} "
          f"evacuated={mc.evacuated_total} submitted={led_mc.submitted} "
          f"ok={s_mc['ok']} retries={s_mc['retries']} "
          f"double_served={led_mc.double_served} "
          f"(churn flush ticks={mc_churn}) "
          f"max decode_dispatches/group/cell={max_disp_mc:.1f}")
    assert mc.cell_downs == 1, "scripted cell blackout did not fire"
    assert mc.evacuated_total > 0, "blackout caught no in-flight work"
    assert led_mc.balanced(), \
        f"global ledger unbalanced across cells: {led_mc.balance()}"
    assert led_mc.double_served == 0, \
        "a request was served twice across cells"
    assert s_mc["ok"] > 0, "no goodput through the multi-cell drill"
    assert mc_steady == 0, \
        "a cell broke the one-sync-per-group bound on a churn-free tick"
    assert max_disp_mc <= 1.0, \
        "a cell broke the one-decode-dispatch-per-group bound"

    # ---- plane-crash drill: two-level control through a global outage --
    # the hierarchy's fault-tolerance claim, asserted: with the global
    # plane dark for 6 ticks the per-cell controllers keep autoscaling
    # inside their last leases, the restored plane reconciles exactly
    # once, exactly-once accounting survives, and the device-work bounds
    # hold per cell
    from repro.control import (CellController, GlobalPlanner,
                               PlaneSupervisor)

    mc_h = MultiCellBackend(
        [mc_cell(0), mc_cell(1)],
        chaos=ChaosSchedule.parse("plane_down@4:k6"), seed=0)
    planner = GlobalPlanner(2, total_budget=4, max_per_cell=4,
                            lease_slack=0.5)
    ctls = [CellController(mc_h, c, patience=1, cooldown=1)
            for c in range(2)]
    sup = PlaneSupervisor(mc_h, planner, ctls, plan_interval=10)
    pool_h = ClientPool(mc_h, 12, request_factory=cf, think_time=1.0,
                        timeout=8.0, max_retries=2, spawn_rate=1.0, seed=3)
    h_steady = 0
    max_disp_h = max_stale = 0.0
    for _ in range(20):
        before = [sum(len(n.live) + len(n.draining) for n in cell.nodes)
                  for cell in mc_h.cells]
        pool_h.tick()
        m = sup.step(0.0)
        max_stale = max(max_stale, m["plane_staleness"])
        for cell, n_before in zip(mc_h.cells, before):
            cm = cell.metrics()
            if not cm:
                continue
            n_after = sum(len(n.live) + len(n.draining)
                          for n in cell.nodes)
            over = cm["syncs"] - max(cm["fleet_groups"], 1)
            if over > 0 and n_after == n_before:
                h_steady += 1
            if cm["decode_dispatches"]:
                max_disp_h = max(max_disp_h, cm["decode_dispatches"]
                                 / max(cm["fleet_groups"], 1))
    pool_h.quiesce()
    mc_h.run_until_drained()
    pool_h.finalize()
    led_h = mc_h.ledger
    s_h = pool_h.summary()
    dark = set(range(4, 10))
    dark_actions = sum(1 for ctl in ctls for t in ctl.action_ticks
                       if t in dark)
    print(f"[smoke] plane-crash drill: outages={mc_h.plane_outages} "
          f"dark-ticks={mc_h.plane_outage_ticks} "
          f"max plane_staleness={max_stale:.0f} "
          f"local-actions={sup.local_actions()} (in-outage={dark_actions}) "
          f"restores={sup.restores} plans={len(sup.plan_log)} "
          f"ok={s_h['ok']} double_served={led_h.double_served} "
          f"max decode_dispatches/group/cell={max_disp_h:.1f}")
    assert mc_h.plane_outages == 1 and mc_h.plane_outage_ticks == 6, \
        "scripted plane crash did not run its course"
    assert all(ctl.lease is not None for ctl in ctls), \
        "the planner never granted a lease"
    assert dark_actions > 0, \
        "cells must keep autoscaling while the global plane is dark"
    assert sup.restores == 1, "the restored plane must reconcile once"
    assert led_h.balanced(), \
        f"ledger unbalanced through the plane crash: {led_h.balance()}"
    assert led_h.double_served == 0, \
        "reconcile double-applied work after the plane restore"
    assert s_h["ok"] > 0, "no goodput through the plane-crash drill"
    assert h_steady == 0, \
        "a cell broke the one-sync-per-group bound on a churn-free tick"
    assert max_disp_h <= 1.0, \
        "a cell broke the one-decode-dispatch-per-group bound"

    # ---- sharded fleet parity (child process: 4 virtual devices) ------
    env = dict(os.environ, SMOKE_SHARD_CHILD="1",
               XLA_FLAGS="--xla_force_host_platform_device_count=4")
    child = subprocess.run([sys.executable, os.path.abspath(__file__)],
                           capture_output=True, text=True, env=env,
                           timeout=600)
    sys.stdout.write(child.stdout)
    assert child.returncode == 0, \
        f"sharded smoke child failed:\n{child.stderr[-3000:]}"
    print("[smoke] OK")


def sharded_child():
    """Runs with 4 virtual devices (parent set XLA_FLAGS pre-spawn):
    sharded-vs-unsharded parity + the per-tick dispatch/sync bounds."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.launch.mesh import make_fleet_mesh
    from repro.models import make_model
    from repro.serving import ElasticClusterFrontend, ReplicaEngine, Request

    assert jax.local_device_count() == 4, jax.local_device_count()
    mesh = make_fleet_mesh()
    cfg = get_config("granite-3-8b").reduced()
    model = make_model(cfg, tp=1)
    params = model.init(jax.random.PRNGKey(0), jnp.float32)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, int(rng.integers(3, 9)))
               .tolist() for _ in range(12)]

    def run(use_mesh):
        def mk(rid):
            return ReplicaEngine(model, params, max_batch=MAX_BATCH,
                                 max_seq=MAX_SEQ, rid=rid)
        fe = ElasticClusterFrontend(mk, 2, initial_replicas=2, seed=0,
                                    mesh=mesh if use_mesh else None)
        for i, p in enumerate(prompts):
            fe.submit(Request(i, list(p), max_new_tokens=6))
        max_syncs = max_disp = 0
        for _ in range(200):
            m = fe.tick(0.0)
            max_syncs = max(max_syncs, m["syncs"])
            max_disp = max(max_disp, m["decode_dispatches"]
                           / max(m["fleet_groups"], 1))
            if not fe.pending and all(n.unfinished() == 0
                                      for n in fe.nodes):
                break
        fe.run_until_drained()
        streams = sorted((r.rid, tuple(r.output), r.finish_time)
                         for r in fe.finished)
        return streams, max_syncs, max_disp

    s_on, syncs_on, disp_on = run(True)
    s_off, _, _ = run(False)
    print(f"[smoke] sharded fleet ({jax.local_device_count()} devices): "
          f"max syncs/tick={syncs_on} "
          f"max decode_dispatches/group={disp_on:.1f}")
    assert s_on == s_off, "sharded fleet changed streams vs unsharded"
    assert syncs_on <= 1, "sharded tick must keep <= 1 blocking sync"
    assert disp_on <= 1.0, \
        "sharding must keep ONE logical decode dispatch per group per tick"


if __name__ == "__main__":
    if os.environ.get("SMOKE_SHARD_CHILD"):
        sharded_child()
    else:
        main()
